"""Shared benchmark utilities.  Every bench prints ``name,us_per_call,derived``
CSV rows (the harness contract) plus human-readable detail to stderr."""
from __future__ import annotations

import os
import sys
import time

import jax


def is_smoke() -> bool:
    """True when ``benchmarks/run.py --smoke`` set REPRO_BENCH_SMOKE: benches
    shrink to CI-per-commit scale (tiny shapes, few iters) but still emit the
    same CSV rows and results/*.json artifacts, so the perf trajectory gets a
    trace on every push instead of only on manual runs."""
    return os.environ.get("REPRO_BENCH_SMOKE") == "1"


def emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def note(msg: str) -> None:
    print(f"    # {msg}", file=sys.stderr, flush=True)


def time_fn(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median wall time (µs) of fn(*args) with block_until_ready."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def tiny_lm(d_model=64, n_layers=2, vocab=256, heads=4, kv=2, ff=128):
    """A small dense LM for CPU-scale quality benches."""
    from repro.models.config import ModelConfig
    return ModelConfig(name="bench-lm", family="dense", n_layers=n_layers,
                       d_model=d_model, n_heads=heads, n_kv_heads=kv,
                       d_ff=ff, vocab_size=vocab, max_seq=128,
                       dtype="float32")
