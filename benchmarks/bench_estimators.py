"""Paper Table 11 (SPSA vs one-point at fixed forward passes) and Table 6
(n-SPSA sample schedules), on a CPU-scale prompt-classification task."""
from __future__ import annotations

import jax

from benchmarks.common import emit, note, tiny_lm
from repro.core import MeZO, MeZOConfig
from repro.data.synthetic import PromptClassification
from repro.models import bundle, transformer

FORWARD_BUDGET = 1600
BATCH = 32


def _train_and_eval(cfg, task, opt, steps):
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    for s in range(steps):
        params, state, _ = step(params, state, task.batch_for_step(s, BATCH))
    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits
    return task.eval_accuracy(cfg, logits_fn, params, jax.random.PRNGKey(77), 512)


def run():
    cfg = tiny_lm(d_model=96, n_layers=3, vocab=256, ff=192)
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=2)

    # Table 11: same forward-pass budget — SPSA (2/step) vs one-point (1/step)
    acc_spsa = _train_and_eval(cfg, task, MeZO(MeZOConfig(lr=2e-4, eps=1e-3)),
                               FORWARD_BUDGET // 2)
    acc_1p = _train_and_eval(
        cfg, task, MeZO(MeZOConfig(lr=2e-5, eps=1e-2, estimator="one_point",
                                   clip_projected_grad=50.0)),
        FORWARD_BUDGET)
    emit("estimators/spsa_acc_at_budget", 0.0, f"{acc_spsa:.3f}")
    emit("estimators/one_point_acc_at_budget", 0.0, f"{acc_1p:.3f}")
    note(f"Table 11 proxy: SPSA {acc_spsa:.3f} vs one-point {acc_1p:.3f} "
         f"at {FORWARD_BUDGET} forwards (paper: two-point wins)")

    # Table 6: n-SPSA at fixed forward budget (n=1 vs n=4, lr scaled)
    acc_n1 = acc_spsa
    acc_n4 = _train_and_eval(
        cfg, task, MeZO(MeZOConfig(lr=8e-4, eps=1e-3, n=4)),
        FORWARD_BUDGET // 8)
    emit("estimators/nspsa_n1_acc", 0.0, f"{acc_n1:.3f}")
    emit("estimators/nspsa_n4_acc", 0.0, f"{acc_n4:.3f}")
    note(f"Table 6 proxy: n=1 {acc_n1:.3f} vs n=4 {acc_n4:.3f} at fixed "
         f"forwards (paper: marginal gains at best)")


if __name__ == "__main__":
    run()
