"""Estimator comparisons.

Paper sections: Table 11 (SPSA vs one-point at fixed forward passes) and
Table 6 (n-SPSA sample schedules), on a CPU-scale prompt-classification task.

Plus the batched-seed section: spsa vs n_spsa(B) vs fzoo(B) per-step
wall-clock and steps-to-loss on a tiny LM.  FZOO evaluates its B seed streams
with ONE vmapped forward over the ``perturb_many`` stacked-params view, so
its per-step cost must come in well under B× the spsa step — that
amortization ratio is the headline number, written (with the full records) to
``results/bench_estimators.json`` for machine consumption / CI artifacts.

``run.py --smoke`` shrinks budgets to CI-per-commit scale (same rows, same
JSON schema).
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import emit, is_smoke, note, time_fn, tiny_lm
from repro import zo
from repro.core import MeZO, MeZOConfig
from repro.data.synthetic import PromptClassification, lm_batch
from repro.models import bundle, transformer

FORWARD_BUDGET = 160 if is_smoke() else 1600
BATCH = 32
OUT_PATH = os.path.join("results", "bench_estimators.json")

FZOO_B = 8
DESCENT_STEPS = 30 if is_smoke() else 150


def _train_and_eval(cfg, task, opt, steps):
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    state = opt.init(0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    for s in range(steps):
        params, state, _ = step(params, state, task.batch_for_step(s, BATCH))
    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits
    return task.eval_accuracy(cfg, logits_fn, params, jax.random.PRNGKey(77), 512)


def _tables_11_and_6(records):
    cfg = tiny_lm(d_model=96, n_layers=3, vocab=256, ff=192)
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=2)

    # Table 11: same forward-pass budget — SPSA (2/step) vs one-point (1/step)
    acc_spsa = _train_and_eval(cfg, task, MeZO(MeZOConfig(lr=2e-4, eps=1e-3)),
                               FORWARD_BUDGET // 2)
    acc_1p = _train_and_eval(
        cfg, task, MeZO(MeZOConfig(lr=2e-5, eps=1e-2, estimator="one_point",
                                   clip_projected_grad=50.0)),
        FORWARD_BUDGET)
    emit("estimators/spsa_acc_at_budget", 0.0, f"{acc_spsa:.3f}")
    emit("estimators/one_point_acc_at_budget", 0.0, f"{acc_1p:.3f}")
    note(f"Table 11 proxy: SPSA {acc_spsa:.3f} vs one-point {acc_1p:.3f} "
         f"at {FORWARD_BUDGET} forwards (paper: two-point wins)")

    # Table 6: n-SPSA at fixed forward budget (n=1 vs n=4, lr scaled)
    acc_n1 = acc_spsa
    acc_n4 = _train_and_eval(
        cfg, task, MeZO(MeZOConfig(lr=8e-4, eps=1e-3, n=4)),
        FORWARD_BUDGET // 8)
    emit("estimators/nspsa_n1_acc", 0.0, f"{acc_n1:.3f}")
    emit("estimators/nspsa_n4_acc", 0.0, f"{acc_n4:.3f}")
    note(f"Table 6 proxy: n=1 {acc_n1:.3f} vs n=4 {acc_n4:.3f} at fixed "
         f"forwards (paper: marginal gains at best)")
    records.append({"section": "tables_11_6",
                    "spsa_acc": float(acc_spsa), "one_point_acc": float(acc_1p),
                    "nspsa_n4_acc": float(acc_n4),
                    "forward_budget": FORWARD_BUDGET})


def _batched_seed_section(records):
    """spsa vs n_spsa(B) vs fzoo(B): per-step wall-clock + steps-to-loss."""
    cfg = tiny_lm(d_model=128, n_layers=2, ff=256, vocab=512)
    b = bundle(cfg)
    params0 = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    mk_batch = lambda s: lm_batch(s, 0, 4, 32, cfg.vocab_size)
    batch0 = mk_batch(0)

    # fzoo's std normalization rescales g by ~1/σ(loss diffs) ≈ 1/(ε·σ_rel),
    # so its lr sits orders of magnitude under the spsa lr at equal step size.
    optimizers = [
        ("spsa", zo.mezo(lr=1e-4, eps=1e-3)),
        (f"n_spsa_{FZOO_B}", zo.mezo(lr=1e-4, eps=1e-3, n=FZOO_B)),
        (f"fzoo_{FZOO_B}", zo.fzoo(lr=2e-6, eps=1e-3, batch_seeds=FZOO_B)),
    ]
    base_us = None
    for name, opt in optimizers:
        state = opt.init(params0, seed=0)
        step = jax.jit(opt.step_fn(loss_fn))
        us = time_fn(step, params0, state, batch0,
                     iters=3 if is_smoke() else 5)

        # loss trajectory (fresh state, per-step batches)
        p, st = params0, opt.init(params0, seed=0)
        l0 = None
        losses = []
        for s in range(DESCENT_STEPS):
            p, st, m = step(p, st, mk_batch(s))
            losses.append(float(m["loss"]))
            if l0 is None:
                l0 = losses[0]
        target = 0.98 * l0
        steps_to = next((i + 1 for i, l in enumerate(losses) if l <= target),
                        None)
        rec = {"section": "batched_seed", "estimator": name,
               "us_per_step": us, "final_loss": losses[-1],
               "first_loss": l0, "steps_to_98pct": steps_to,
               "descent_steps": DESCENT_STEPS}
        if name == "spsa":
            base_us = us
        else:
            rec["vs_spsa_step"] = us / base_us
        if name.startswith("fzoo"):
            # the acceptance number: batching must amortize — one vmapped
            # B-forward + B rank-1 passes must beat B sequential spsa steps
            rec["amortization_vs_Bx_spsa"] = us / (FZOO_B * base_us)
        records.append(rec)
        emit(f"estimators/{name}_us_per_step", us,
             f"final_loss={losses[-1]:.4f}")
        note(f"{name}: {us/1e3:.2f} ms/step, loss {l0:.4f} -> "
             f"{losses[-1]:.4f} in {DESCENT_STEPS} steps"
             + (f", steps_to_98pct={steps_to}" if steps_to else ""))
    fz = next(r for r in records if r.get("estimator", "").startswith("fzoo"))
    emit("estimators/fzoo_amortization", 0.0,
         f"{fz['amortization_vs_Bx_spsa']:.3f}x_of_Bx_spsa")
    note(f"fzoo({FZOO_B}) per-step = "
         f"{fz['amortization_vs_Bx_spsa']:.3f} × (B × spsa per-step) "
         f"(<1 means the batched forward amortizes)")


def run():
    records = []
    _batched_seed_section(records)
    if not is_smoke():
        _tables_11_and_6(records)
    else:
        note("smoke mode: skipping the Table 11/6 accuracy sweeps "
             "(eval-heavy); batched-seed section recorded")
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"bench": "estimators", "smoke": is_smoke(),
                   "platform": jax.default_backend(),
                   "batch_seeds": FZOO_B,
                   "records": records}, f, indent=2)
    note(f"JSON written to {OUT_PATH}")


if __name__ == "__main__":
    run()
