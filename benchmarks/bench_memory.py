"""Paper Figure 3 / Figure 4 / Table 22: memory of MeZO vs backprop-Adam FT
vs inference, from COMPILED memory analysis (static, no allocation).

Model: OPT-13B width at L=4 (per-layer memory is depth-independent), f32
(the CPU backend float-normalizes bf16, which would distort byte counts —
see EXPERIMENTS.md methodology note 3).

Two MeZO variants are profiled:
  * ``mezo_inplace``  — Algorithm 1's literal structure: five separately
    donated calls (perturb / forward / perturb / forward / update); the peak
    across phases is the paper's "same memory as inference" claim.
  * ``mezo_fused``    — the single-jit fused step used for wall-clock speed:
    XLA's liveness keeps ~2.2 parameter buffers (θ+εz and θ−εz overlap),
    trading memory for scheduling freedom.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, note
from repro.core import MeZO, MeZOConfig
from repro.models import all_archs, bundle
from repro.train.adam import Adam, AdamConfig

SEQ = 400        # the paper profiles MultiRC, ~400 tokens/example
BATCH = 2


def _ma(compiled):
    ma = compiled.memory_analysis()
    return int(ma.argument_size_in_bytes) + int(ma.temp_size_in_bytes)


def run():
    base = all_archs()["opt-13b"].cfg
    cfg = dataclasses.replace(base, n_layers=4, dtype="float32")
    b = bundle(cfg)
    psds = b.param_shapes()
    specs = {"tokens": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32),
             "labels": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.int32),
             "loss_mask": jax.ShapeDtypeStruct((BATCH, SEQ), jnp.float32)}
    loss_fn = b.loss_fn()

    # inference
    peak_inf = _ma(jax.jit(loss_fn).lower(psds, specs).compile())

    # MeZO, Algorithm-1 structure (the paper's per-tensor loop): each leaf's
    # perturb/update is its OWN donated dispatch, so the device-resident set
    # is params + one call's transients.  Peak = max(inference,
    # params + worst per-leaf-call temps).
    key_sds = jax.eval_shape(lambda: jax.random.PRNGKey(0))
    params_bytes = sum(int(jnp.prod(jnp.asarray(x.shape))) * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(psds))
    leaves = jax.tree_util.tree_leaves(psds)
    biggest = max(leaves, key=lambda x: x.size)

    def leaf_perturb(x, k):
        return x + 1e-3 * jax.random.normal(k, x.shape, x.dtype)

    c = jax.jit(leaf_perturb, donate_argnums=(0,)) \
        .lower(biggest, key_sds).compile()
    ma = c.memory_analysis()
    leaf_extra = int(ma.temp_size_in_bytes) + int(ma.output_size_in_bytes)
    peak_inplace = max(peak_inf, params_bytes + leaf_extra)

    # MeZO fused single-jit step
    opt = MeZO(MeZOConfig(lr=1e-6, eps=1e-3))
    ssds = jax.eval_shape(lambda: opt.init(0))
    peak_fused = _ma(jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
                     .lower(psds, ssds, specs).compile())

    # Adam FT
    adam = Adam(AdamConfig(lr=1e-5))
    asds = jax.eval_shape(adam.init, psds)
    peak_ft = _ma(jax.jit(adam.step_fn(loss_fn), donate_argnums=(0,))
                  .lower(psds, asds, specs).compile())

    emit("memory/inference_bytes", 0.0, str(peak_inf))
    emit("memory/mezo_inplace_bytes", 0.0, str(peak_inplace))
    emit("memory/mezo_fused_bytes", 0.0, str(peak_fused))
    emit("memory/ft_adam_bytes", 0.0, str(peak_ft))
    emit("memory/mezo_inplace_over_inference", 0.0,
         f"{peak_inplace/peak_inf:.2f}")
    emit("memory/ft_over_inference", 0.0, f"{peak_ft/peak_inf:.2f}")
    note(f"inference {peak_inf/1e9:.2f} GB | MeZO in-place "
         f"{peak_inplace/1e9:.2f} GB ({peak_inplace/peak_inf:.2f}x) | "
         f"MeZO fused {peak_fused/1e9:.2f} GB | FT-Adam {peak_ft/1e9:.2f} GB "
         f"({peak_ft/peak_inf:.2f}x)")
    note("the paper's 12x gap is this FT factor grown by long-seq/batch "
         "activation stashes (B=2,S=400 keeps activations small here) and "
         "f32 Adam moments on bf16 params (4x, not 2x, per weight byte)")

    # ---- Figure 4 analytic: largest OPT per A100 budget ------------------- #
    note("Fig.4 analytic (bf16 params, f32 Adam moments, + activations):")
    for gb, name in ((80, "1xA100"), (160, "2xA100"), (320, "4xA100"),
                     (640, "8xA100")):
        mezo_max = gb / 2.2
        ft_max = gb / 12.5
        note(f"  {name}: FT-Adam <= {ft_max:.0f}B params; MeZO/inference <= "
             f"{mezo_max:.0f}B params (paper 1xA100: 2.7B vs 30B)")
    emit("memory/fig4_mezo_vs_ft_model_ratio", 0.0, f"{12.5/2.2:.1f}")


if __name__ == "__main__":
    run()
