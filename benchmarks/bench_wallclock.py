"""Paper Table 23 proxy: per-step wall-clock of MeZO vs backprop FT.

The paper's absolute numbers are A100-specific; the portable claims are
(1) a MeZO step (2 forwards, no activation stash) is faster than an FT step
(forward+backward+Adam), and (2) the gap grows with model size.  Measured
here on CPU across three widths."""
from __future__ import annotations

import dataclasses

import jax

from benchmarks.common import emit, note, time_fn, tiny_lm
from repro.core import MeZO, MeZOConfig
from repro.data.synthetic import lm_batch
from repro.models import bundle
from repro.train.adam import Adam, AdamConfig


def run():
    for d, L, ff, tag in ((64, 2, 128, "s"), (128, 4, 256, "m"),
                          (256, 4, 512, "l")):
        cfg = tiny_lm(d_model=d, n_layers=L, ff=ff, vocab=512)
        b = bundle(cfg)
        params = b.init(jax.random.PRNGKey(0))
        loss_fn = b.loss_fn()
        batch = lm_batch(0, 0, 8, 64, cfg.vocab_size)

        mezo = MeZO(MeZOConfig(lr=1e-4, eps=1e-3))
        t_mezo = time_fn(jax.jit(mezo.step_fn(loss_fn)), params, mezo.init(0),
                         batch)
        adam = Adam(AdamConfig(lr=1e-4))
        t_ft = time_fn(jax.jit(adam.step_fn(loss_fn)), params,
                       adam.init(params), batch)
        emit(f"wallclock/mezo_step_{tag}", t_mezo, f"d={d},L={L}")
        emit(f"wallclock/ft_step_{tag}", t_ft, f"d={d},L={L}")
        emit(f"wallclock/ft_over_mezo_{tag}", 0.0, f"{t_ft / t_mezo:.2f}")
        note(f"{tag}: MeZO {t_mezo/1e3:.1f} ms vs FT {t_ft/1e3:.1f} ms "
             f"per step ({t_ft/t_mezo:.2f}x)  [paper 30B: 7.74x]")


if __name__ == "__main__":
    run()
