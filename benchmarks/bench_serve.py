"""Multi-tenant serving bench: cache hit rate, cold/warm TTFT, and
materialization cost vs ledger length (repro.serve.tenants).

Three measurements over N synthetic LoRA tenants sharing one frozen base:

  * COLD vs WARM time-to-first-token through one ServeEngine — wave 1 visits
    every tenant cold (materialization = ledger replay lands in TTFT), wave 2
    revisits them cache-warm.  The warm wave is ASSERTED to perform zero
    ``apply_rank1`` folds (the hit path is pure leaf replacement) — the bench
    fails, not just degrades, if materialization sneaks back onto the hot
    path.
  * Hit rate / evictions under a byte budget sized to hold only half the
    tenants, driven by a skewed request mix (the DeltaCache working-set
    story).
  * SHARED-TEMPLATE prefix economy: waves of template+suffix prompts
    (serve/tenants/synth.template_requests) through the paged-KV engine —
    prefill tokens computed vs submitted (ASSERTED < 1x and <= 0.5x) and
    warm-prefix vs cold TTFT (ASSERTED >= 2x) — the radix-prefix-cache
    story.
  * Materialization µs vs ledger length, raw replay vs compacted delta+tail.

Emits ``name,us_per_call,derived`` CSV rows and a JSON record to
``results/bench_serve.json`` (CI artifact; ``run.py --smoke`` scale).
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import emit, is_smoke, note, tiny_lm
from repro.core.trajectory import replay
from repro.models import bundle
from repro.serve.engine import ServeEngine
from repro.serve.tenants import (compact, composition_for_ledger,
                                 lora_runtime, make_lora_tenants, materialize,
                                 serve_load, synthetic_requests,
                                 template_requests, tenant_name)
from repro.serve.tenants.synth import lora_params0

OUT_PATH = os.path.join("results", "bench_serve.json")

N_TENANTS = 8 if is_smoke() else 64
TRAIN_STEPS = 4 if is_smoke() else 24
N_REQUESTS = 24 if is_smoke() else 128
NEW_TOKENS = 4 if is_smoke() else 8
KEEP_TAIL = 2 if is_smoke() else 8


def _pctl(sorted_rows, q):
    return sorted_rows[min(len(sorted_rows) - 1, int(len(sorted_rows) * q))]


def run():
    cfg = tiny_lm()
    base = bundle(cfg).init(jax.random.PRNGKey(0))
    store = make_lora_tenants(cfg, base, N_TENANTS, steps=TRAIN_STEPS,
                              batch=4)
    tenants = store.tenants()
    results: dict = {"smoke": is_smoke(), "n_tenants": N_TENANTS,
                     "train_steps": TRAIN_STEPS,
                     "store_bytes": store.nbytes()}

    # -- cold vs warm TTFT (unbounded cache, every tenant twice) ------------ #
    rt = lora_runtime(cfg, base, store, cache_bytes=1 << 30)
    engine = ServeEngine(cfg, base, slots=4, max_len=64)
    wave = [(t, r) for t, (_, r) in zip(
        tenants, synthetic_requests(N_TENANTS, cfg.vocab_size, tenants,
                                    seed=1, max_new_tokens=NEW_TOKENS))]
    cold_rows = serve_load(engine, rt, wave)
    folds_before_warm = rt.records_replayed
    wave2 = [(t, r) for t, (_, r) in zip(
        tenants, synthetic_requests(N_TENANTS, cfg.vocab_size, tenants,
                                    seed=2, max_new_tokens=NEW_TOKENS))]
    warm_rows = serve_load(engine, rt, wave2)
    if rt.records_replayed != folds_before_warm:
        raise AssertionError(
            f"warm wave replayed {rt.records_replayed - folds_before_warm} "
            "ledger records — the cache-hit path must do ZERO apply_rank1 "
            "folds")
    cold = sorted(r["ttft_s"] * 1e6 for r in cold_rows)
    warm = sorted(r["ttft_s"] * 1e6 for r in warm_rows)
    results["cold_ttft_us"] = {"p50": _pctl(cold, 0.5), "p99": _pctl(cold, 0.99)}
    results["warm_ttft_us"] = {"p50": _pctl(warm, 0.5), "p99": _pctl(warm, 0.99)}
    results["warm_zero_folds"] = True
    emit("serve/cold_ttft_p50", _pctl(cold, 0.5), f"p99={_pctl(cold, 0.99):.0f}us")
    emit("serve/warm_ttft_p50", _pctl(warm, 0.5), f"p99={_pctl(warm, 0.99):.0f}us")

    # -- hit rate under a half-working-set byte budget ---------------------- #
    delta_bytes = rt.delta(tenants[0]).nbytes
    budget = max(delta_bytes, delta_bytes * N_TENANTS // 2)
    rt2 = lora_runtime(cfg, base, store, cache_bytes=budget)
    engine2 = ServeEngine(cfg, base, slots=4, max_len=64)
    tagged = synthetic_requests(N_REQUESTS, cfg.vocab_size, tenants, seed=3,
                                max_new_tokens=NEW_TOKENS, skew=2.0)
    rows = serve_load(engine2, rt2, tagged)
    st = rt2.stats
    results["budget_bytes"] = budget
    results["delta_bytes"] = delta_bytes
    results["hit_rate"] = st["hit_rate"]
    results["evictions"] = st["evictions"]
    results["requests"] = len(rows)
    tput = sum(r["n_out"] for r in rows) / max(sum(r["total_s"] for r in rows),
                                               1e-9)
    emit("serve/hit_rate", 0.0,
         f"{st['hit_rate']:.2f} (evictions={st['evictions']}, "
         f"budget={budget}B)")

    # -- shared-template prefix economy (paged KV + radix cache) ------------ #
    # Realistic prompt-heavy traffic: every request = one of K fixed task
    # templates + a short fresh suffix (serve/tenants/synth.template_requests).
    # Waves of exactly `slots` base-model requests so TTFT is pure prefill
    # latency (no queue wait).  Cold = fresh engine, empty radix; warm = the
    # engine that already served the templates.  Both prefill bucket shapes
    # are pre-compiled on a throwaway engine (the chunk-prefill jit cache is
    # process-global), so the spread measures computation, not compilation.
    TPL_SLOTS, TPL_LEN, TPL_MAXLEN = 4, 160, 256
    TPL_WAVES = 3

    def tpl_wave(seed):
        return template_requests(TPL_SLOTS, cfg.vocab_size, [None],
                                 n_templates=2, template_len=TPL_LEN,
                                 seed=seed, max_new_tokens=NEW_TOKENS,
                                 template_seed=7, rid0=seed * 100)

    def tpl_engine():
        return ServeEngine(cfg, base, slots=TPL_SLOTS, max_len=TPL_MAXLEN)

    warmup = tpl_engine()
    serve_load(warmup, rt, tpl_wave(90))         # compiles cold bucket
    serve_load(warmup, rt, tpl_wave(91))         # compiles warm bucket
    cold_tpl = []
    for i in range(TPL_WAVES):
        rows_c = serve_load(tpl_engine(), rt, tpl_wave(200 + i))
        cold_tpl += [r["ttft_s"] * 1e6 for r in rows_c]
    eng_tpl = tpl_engine()
    serve_load(eng_tpl, rt, tpl_wave(300))       # populate the radix cache
    st0 = eng_tpl.prefix_stats()
    warm_tpl = []
    for i in range(1, TPL_WAVES + 1):
        rows_w = serve_load(eng_tpl, rt, tpl_wave(300 + i))
        warm_tpl += [r["ttft_s"] * 1e6 for r in rows_w]
    st1 = eng_tpl.prefix_stats()
    sub = st1["prefill_tokens_submitted"] - st0["prefill_tokens_submitted"]
    comp_tok = (st1["prefill_tokens_computed"]
                - st0["prefill_tokens_computed"])
    if not comp_tok < sub:
        raise AssertionError(
            f"shared-template workload computed {comp_tok} of {sub} "
            "submitted prefill tokens — the radix prefix cache reused "
            "NOTHING")
    if comp_tok > 0.5 * sub:
        raise AssertionError(
            f"shared-template workload computed {comp_tok}/{sub} prefill "
            "tokens (> 0.5x submitted) — prefix reuse regressed")
    cold_tpl.sort()
    warm_tpl.sort()
    cold_p50, warm_p50 = _pctl(cold_tpl, 0.5), _pctl(warm_tpl, 0.5)
    if warm_p50 * 2 > cold_p50:
        raise AssertionError(
            f"warm-prefix TTFT p50 {warm_p50:.0f}us is not >=2x better than "
            f"cold {cold_p50:.0f}us")
    results["prefix"] = {
        "template_len": TPL_LEN, "block": eng_tpl.block,
        "cold_ttft_us": {"p50": cold_p50, "p99": _pctl(cold_tpl, 0.99)},
        "warm_ttft_us": {"p50": warm_p50, "p99": _pctl(warm_tpl, 0.99)},
        "warm_speedup": cold_p50 / max(warm_p50, 1e-9),
        "prefill_tokens_submitted": sub,
        "prefill_tokens_computed": comp_tok,
        "computed_over_submitted": comp_tok / max(sub, 1),
        "prefix_hit_rate": st1["prefix_hit_rate"],
        "pool_blocks": st1["pool_blocks"],
        "radix_nodes": st1["radix_nodes"],
    }
    emit("serve/prefix_cold_ttft_p50", cold_p50,
         f"template={TPL_LEN}tok")
    emit("serve/prefix_warm_ttft_p50", warm_p50,
         f"x{cold_p50 / max(warm_p50, 1e-9):.1f}_vs_cold")
    emit("serve/prefix_reuse", 0.0,
         f"computed={comp_tok}/{sub};hit_rate={st1['prefix_hit_rate']:.2f}")
    note(f"shared-template workload: {comp_tok}/{sub} prefill tokens "
         f"computed ({comp_tok / max(sub, 1):.0%}), warm-prefix TTFT p50 "
         f"{warm_p50 / 1e3:.1f} ms vs cold {cold_p50 / 1e3:.1f} ms "
         f"({cold_p50 / max(warm_p50, 1e-9):.1f}x)")

    # -- materialization cost vs ledger length, raw vs compacted ------------ #
    led = store.ledger(tenant_name(0))
    opt = composition_for_ledger(led)
    p0 = lora_params0(cfg, base, led)
    by_len = {}
    import time as _t
    for frac in (0.25, 0.5, 1.0):
        n = max(1, int(len(led) * frac))
        t0 = _t.perf_counter()
        jax.block_until_ready(
            jax.tree_util.tree_leaves(replay(p0, led, opt, to_idx=n))[0])
        by_len[n] = (_t.perf_counter() - t0) * 1e6
    comp = compact(p0, led, opt, keep_tail=KEEP_TAIL)
    t0 = _t.perf_counter()
    jax.block_until_ready(
        jax.tree_util.tree_leaves(materialize(p0, comp, opt))[0])
    comp_us = (_t.perf_counter() - t0) * 1e6
    results["materialize_us_by_len"] = by_len
    results["compacted"] = {"us": comp_us, "tail": len(comp.tail),
                            "record_bytes": comp.nbytes,
                            "raw_bytes": led.nbytes()}
    full_us = by_len[max(by_len)]
    emit("serve/materialize_full", full_us, f"{len(led)}_records")
    emit("serve/materialize_compacted", comp_us,
         f"tail={len(comp.tail)},x{full_us / max(comp_us, 1e-9):.1f}")
    note(f"{N_TENANTS} tenants ({store.nbytes()} B of ledgers): cold TTFT "
         f"p50 {_pctl(cold, 0.5) / 1e3:.1f} ms vs warm "
         f"{_pctl(warm, 0.5) / 1e3:.1f} ms (zero folds asserted); hit rate "
         f"{st['hit_rate']:.2f} at half-working-set budget; throughput "
         f"{tput:.1f} tok/s")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
