"""Multi-tenant serving bench: cache hit rate, cold/warm TTFT, and
materialization cost vs ledger length (repro.serve.tenants).

Three measurements over N synthetic LoRA tenants sharing one frozen base:

  * COLD vs WARM time-to-first-token through one ServeEngine — wave 1 visits
    every tenant cold (materialization = ledger replay lands in TTFT), wave 2
    revisits them cache-warm.  The warm wave is ASSERTED to perform zero
    ``apply_rank1`` folds (the hit path is pure leaf replacement) — the bench
    fails, not just degrades, if materialization sneaks back onto the hot
    path.
  * Hit rate / evictions under a byte budget sized to hold only half the
    tenants, driven by a skewed request mix (the DeltaCache working-set
    story).
  * Materialization µs vs ledger length, raw replay vs compacted delta+tail.

Emits ``name,us_per_call,derived`` CSV rows and a JSON record to
``results/bench_serve.json`` (CI artifact; ``run.py --smoke`` scale).
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import emit, is_smoke, note, tiny_lm
from repro.core.trajectory import replay
from repro.models import bundle
from repro.serve.engine import ServeEngine
from repro.serve.tenants import (compact, composition_for_ledger,
                                 lora_runtime, make_lora_tenants, materialize,
                                 serve_load, synthetic_requests, tenant_name)
from repro.serve.tenants.synth import lora_params0

OUT_PATH = os.path.join("results", "bench_serve.json")

N_TENANTS = 8 if is_smoke() else 64
TRAIN_STEPS = 4 if is_smoke() else 24
N_REQUESTS = 24 if is_smoke() else 128
NEW_TOKENS = 4 if is_smoke() else 8
KEEP_TAIL = 2 if is_smoke() else 8


def _pctl(sorted_rows, q):
    return sorted_rows[min(len(sorted_rows) - 1, int(len(sorted_rows) * q))]


def run():
    cfg = tiny_lm()
    base = bundle(cfg).init(jax.random.PRNGKey(0))
    store = make_lora_tenants(cfg, base, N_TENANTS, steps=TRAIN_STEPS,
                              batch=4)
    tenants = store.tenants()
    results: dict = {"smoke": is_smoke(), "n_tenants": N_TENANTS,
                     "train_steps": TRAIN_STEPS,
                     "store_bytes": store.nbytes()}

    # -- cold vs warm TTFT (unbounded cache, every tenant twice) ------------ #
    rt = lora_runtime(cfg, base, store, cache_bytes=1 << 30)
    engine = ServeEngine(cfg, base, slots=4, max_len=64)
    wave = [(t, r) for t, (_, r) in zip(
        tenants, synthetic_requests(N_TENANTS, cfg.vocab_size, tenants,
                                    seed=1, max_new_tokens=NEW_TOKENS))]
    cold_rows = serve_load(engine, rt, wave)
    folds_before_warm = rt.records_replayed
    wave2 = [(t, r) for t, (_, r) in zip(
        tenants, synthetic_requests(N_TENANTS, cfg.vocab_size, tenants,
                                    seed=2, max_new_tokens=NEW_TOKENS))]
    warm_rows = serve_load(engine, rt, wave2)
    if rt.records_replayed != folds_before_warm:
        raise AssertionError(
            f"warm wave replayed {rt.records_replayed - folds_before_warm} "
            "ledger records — the cache-hit path must do ZERO apply_rank1 "
            "folds")
    cold = sorted(r["ttft_s"] * 1e6 for r in cold_rows)
    warm = sorted(r["ttft_s"] * 1e6 for r in warm_rows)
    results["cold_ttft_us"] = {"p50": _pctl(cold, 0.5), "p99": _pctl(cold, 0.99)}
    results["warm_ttft_us"] = {"p50": _pctl(warm, 0.5), "p99": _pctl(warm, 0.99)}
    results["warm_zero_folds"] = True
    emit("serve/cold_ttft_p50", _pctl(cold, 0.5), f"p99={_pctl(cold, 0.99):.0f}us")
    emit("serve/warm_ttft_p50", _pctl(warm, 0.5), f"p99={_pctl(warm, 0.99):.0f}us")

    # -- hit rate under a half-working-set byte budget ---------------------- #
    delta_bytes = rt.delta(tenants[0]).nbytes
    budget = max(delta_bytes, delta_bytes * N_TENANTS // 2)
    rt2 = lora_runtime(cfg, base, store, cache_bytes=budget)
    engine2 = ServeEngine(cfg, base, slots=4, max_len=64)
    tagged = synthetic_requests(N_REQUESTS, cfg.vocab_size, tenants, seed=3,
                                max_new_tokens=NEW_TOKENS, skew=2.0)
    rows = serve_load(engine2, rt2, tagged)
    st = rt2.stats
    results["budget_bytes"] = budget
    results["delta_bytes"] = delta_bytes
    results["hit_rate"] = st["hit_rate"]
    results["evictions"] = st["evictions"]
    results["requests"] = len(rows)
    tput = sum(r["n_out"] for r in rows) / max(sum(r["total_s"] for r in rows),
                                               1e-9)
    emit("serve/hit_rate", 0.0,
         f"{st['hit_rate']:.2f} (evictions={st['evictions']}, "
         f"budget={budget}B)")

    # -- materialization cost vs ledger length, raw vs compacted ------------ #
    led = store.ledger(tenant_name(0))
    opt = composition_for_ledger(led)
    p0 = lora_params0(cfg, base, led)
    by_len = {}
    import time as _t
    for frac in (0.25, 0.5, 1.0):
        n = max(1, int(len(led) * frac))
        t0 = _t.perf_counter()
        jax.block_until_ready(
            jax.tree_util.tree_leaves(replay(p0, led, opt, to_idx=n))[0])
        by_len[n] = (_t.perf_counter() - t0) * 1e6
    comp = compact(p0, led, opt, keep_tail=KEEP_TAIL)
    t0 = _t.perf_counter()
    jax.block_until_ready(
        jax.tree_util.tree_leaves(materialize(p0, comp, opt))[0])
    comp_us = (_t.perf_counter() - t0) * 1e6
    results["materialize_us_by_len"] = by_len
    results["compacted"] = {"us": comp_us, "tail": len(comp.tail),
                            "record_bytes": comp.nbytes,
                            "raw_bytes": led.nbytes()}
    full_us = by_len[max(by_len)]
    emit("serve/materialize_full", full_us, f"{len(led)}_records")
    emit("serve/materialize_compacted", comp_us,
         f"tail={len(comp.tail)},x{full_us / max(comp_us, 1e-9):.1f}")
    note(f"{N_TENANTS} tenants ({store.nbytes()} B of ledgers): cold TTFT "
         f"p50 {_pctl(cold, 0.5) / 1e3:.1f} ms vs warm "
         f"{_pctl(warm, 0.5) / 1e3:.1f} ms (zero folds asserted); hit rate "
         f"{st['hit_rate']:.2f} at half-working-set budget; throughput "
         f"{tput:.1f} tok/s")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(results, f, indent=2)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
