"""Paper Theorem 1 / Lemma 3: ZO-SGD convergence depends on the Hessian's
local effective rank r, NOT the parameter dimension d.

Setup: quadratics L(θ) = ½ θᵀ H θ with H having r eigenvalues of 1 and the
rest ~0 — vary d at fixed r (rate should be ~constant) and vary r at fixed d
(rate should degrade ∝ r).  This is the claim that explains why MeZO can
fine-tune billion-parameter LMs."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, note
from repro.core import MeZO, MeZOConfig


def steps_to_eps(d: int, r: int, seed: int = 0, eps_target: float = 0.1,
                 lr: float = 0.02, max_steps: int = 8000) -> int:
    key = jax.random.PRNGKey(seed)
    diag = jnp.concatenate([jnp.ones((r,)), jnp.full((d - r,), 1e-4)])
    theta0 = jax.random.normal(key, (d,)) * jnp.where(diag > 0.5, 1.0, 0.0)

    def loss_fn(p, batch):
        return 0.5 * jnp.sum(diag * p["w"] ** 2)

    opt = MeZO(MeZOConfig(lr=lr, eps=1e-4))
    params = {"w": theta0}
    state = opt.init(seed)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    l0 = float(loss_fn(params, None))
    for s in range(max_steps):
        params, state, m = step(params, state, None)
        if s % 25 == 0 and float(loss_fn(params, None)) < eps_target * l0:
            return s
    return max_steps


def run():
    r = 8
    by_d = {}
    for d in (32, 128, 512):
        t = int(np.median([steps_to_eps(d, r, seed=s) for s in range(3)]))
        by_d[d] = t
        emit(f"theory/steps_r{r}_d{d}", 0.0, str(t))
    slowdown_d = by_d[512] / max(by_d[32], 1)
    emit("theory/dim_slowdown_512_over_32", 0.0, f"{slowdown_d:.2f}")
    note(f"fixed r={r}: steps {by_d} -> {slowdown_d:.2f}x for 16x more dims "
         f"(classical bound predicts ~16x; Thm 1 predicts ~1x)")

    d = 256
    by_r = {}
    for rr in (2, 8, 32):
        t = int(np.median([steps_to_eps(d, rr, seed=s) for s in range(3)]))
        by_r[rr] = t
        emit(f"theory/steps_d{d}_r{rr}", 0.0, str(t))
    slowdown_r = by_r[32] / max(by_r[2], 1)
    emit("theory/rank_slowdown_32_over_2", 0.0, f"{slowdown_r:.2f}")
    note(f"fixed d={d}: steps {by_r} -> {slowdown_r:.2f}x for 16x more rank "
         f"(Thm 1 predicts ~16x)")


if __name__ == "__main__":
    run()
