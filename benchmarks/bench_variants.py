"""Paper Appendix reproductions beyond the core tables:

* Tables 8/9/10 — variance/expectation-modified SPSA (D = parameter norms /
  ZO gradient norms / normalized-gradient estimate) vs plain MeZO at equal
  forward budget (paper: no consistent win — a negative result we confirm).
* Table 19 — LP-MeZO: linear-probe the head with Adam first, then MeZO.
* Table 1's ICL column — in-context learning with k demonstrations and no
  updates, vs MeZO fine-tuning.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, note, tiny_lm
from repro import zo
from repro.data.synthetic import PromptClassification
from repro.models import bundle, transformer
from repro.train.adam import Adam, AdamConfig

STEPS = 700
BATCH = 32


def run():
    cfg = tiny_lm(d_model=96, n_layers=3, vocab=256, ff=192)
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=4)
    b = bundle(cfg)
    params0 = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()

    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits

    def acc(p):
        return task.eval_accuracy(cfg, logits_fn, p, jax.random.PRNGKey(7), 512)

    def train(opt, state, steps=STEPS):
        p = jax.tree_util.tree_map(jnp.copy, params0)
        step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
        for s in range(steps):
            p, state, _ = step(p, state, task.batch_for_step(s, BATCH))
        return p

    # plain MeZO reference
    mezo = zo.mezo(lr=2e-4, eps=1e-3)
    a_plain = acc(train(mezo, mezo.init(params0, seed=0)))
    emit("variants/mezo_plain", 0.0, f"{a_plain:.3f}")

    # Table 9: D = parameter norms
    vopt = zo.mezo_rescaled(lr=2e-4, eps=1e-3, d_source="param_norm")
    a_pn = acc(train(vopt, vopt.init(params0, seed=0)))
    emit("variants/d_param_norm", 0.0, f"{a_pn:.3f}")

    # Table 8: D = ZO-estimated gradient norms (Proposition 1 probes)
    vopt = zo.mezo_rescaled(lr=2e-4, eps=1e-3, d_source="grad_norm_zo",
                            probe_loss_fn=loss_fn,
                            probe_batch=task.batch_for_step(0, BATCH))
    a_gn = acc(train(vopt, vopt.init(params0, seed=0)))
    emit("variants/d_grad_norm_zo", 0.0, f"{a_gn:.3f}")

    # Table 10: expectation-modified (normalized-gradient estimate)
    vopt = zo.mezo_rescaled(lr=2e-4, eps=1e-3, d_source="param_norm",
                            modify_expectation=True)
    a_em = acc(train(vopt, vopt.init(params0, seed=0)))
    emit("variants/expectation_modified", 0.0, f"{a_em:.3f}")
    note(f"Tables 8/9/10 proxy: plain {a_plain:.3f} | D=param-norm {a_pn:.3f}"
         f" | D=ZO-grad-norm {a_gn:.3f} | expectation-mod {a_em:.3f} "
         f"(paper: no consistent win over plain)")

    # --- Table 19: LP-MeZO ------------------------------------------------ #
    # linear probe: Adam on the vocab head only, base frozen
    head0 = {"head": params0["head"]}

    def head_loss(hp, batch):
        merged = dict(params0)
        merged["head"] = hp["head"]
        return loss_fn(merged, batch)

    adam = Adam(AdamConfig(lr=5e-3, total_steps=40))
    st = adam.init(head0)
    hstep = jax.jit(adam.step_fn(head_loss))
    hp = head0
    for s in range(40):
        hp, st, _ = hstep(hp, st, task.batch_for_step(s, BATCH))
    lp_params = dict(params0)
    lp_params["head"] = hp["head"]
    a_lp = acc(lp_params)
    emit("variants/linear_probe", 0.0, f"{a_lp:.3f}")

    mezo2 = zo.mezo(lr=2e-4, eps=1e-3)
    p = jax.tree_util.tree_map(jnp.copy, lp_params)
    step = jax.jit(mezo2.step_fn(loss_fn), donate_argnums=(0,))
    state = mezo2.init(lp_params, seed=0)
    for s in range(STEPS):
        p, state, _ = step(p, state, task.batch_for_step(s, BATCH))
    a_lpmezo = acc(p)
    emit("variants/lp_mezo", 0.0, f"{a_lpmezo:.3f}")
    note(f"Table 19 proxy: LP {a_lp:.3f} -> LP-MeZO {a_lpmezo:.3f} "
         f"(vs MeZO {a_plain:.3f})")

    # --- Table 1 ICL column ------------------------------------------------ #
    for k in (1, 4):
        a_icl = task.eval_icl(cfg, logits_fn, params0, jax.random.PRNGKey(8),
                              k_shots=k, n=256)
        emit(f"variants/icl_{k}shot", 0.0, f"{a_icl:.3f}")
    note("ICL on an untrained tiny LM hovers near chance — the paper's ICL "
         "column presumes a pretrained LM; recorded for the comparison shape")


if __name__ == "__main__":
    run()
