"""Execution-engine bench: local vs seed-parallel step wall-clock, buffer
donation, and the per-step bytes-on-wire / bytes-live story.

The engine's pitch is that estimator × backend × plan is a full matrix, so
this bench times the SAME optimizer composition lowered onto different plans
(``repro.exec.StepProgram``) on a tiny LM:

  * ``local``            — the facade's jit+donate step (2 forwards);
  * ``seed_parallel(n)`` — n seed groups on batch slices at the step's
                           center (2n forwards over 1/n-sized slices: ≈ the
                           local step's FLOPs, n× direction averaging).

Each plan is measured twice — through the plain jitted step and through
``StepProgram.compiled_step_fn`` (donated parameter buffer) — and the
compiled executable's ``memory_analysis`` is recorded per variant: the
MeZO claim is inference-memory training, so *peak live parameter bytes*
(arguments + outputs + XLA temporaries, donation aliasing netted out by the
compiler) is the number that has to stay flat as the plan fans out.  The
seed-parallel update chain is ONE fused ``affine_many`` application since
the multi-seed kernel landed, so the n_groups sweep also traces that
before/after.

Bytes-on-wire per step (what a multi-host deployment would move):

  * seed-parallel: the 2n loss scalars (2 × f32 per group) — MeZO's entire
    inter-replica traffic;
  * a DP backprop baseline would all-reduce the full gradient (4·|θ| bytes)
    — the contrast column.

Emits ``name,us_per_call,derived`` CSV rows and a JSON record to
``results/bench_exec.json`` (CI artifact; ``run.py --smoke`` scale).
"""
from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.common import emit, is_smoke, note, time_fn, tiny_lm
from repro import exec as zexec
from repro import zo
from repro.data.synthetic import lm_batch
from repro.models import bundle
from repro.tree_utils import tree_size

OUT_PATH = os.path.join("results", "bench_exec.json")

GROUPS = (1, 2, 4)
BATCH = 8 if is_smoke() else 32
SEQ = 32 if is_smoke() else 64

# Tracked baseline: seed_parallel(4) step wall-clock as a multiple of the
# local plan on the CPU mesh.  6.6x was the pre-fused chain (n sequential
# rank-1 applications per step); 2.90x is where the fused ``affine_many``
# group-update chain landed it, and recent runs measure ~2.70x.  The
# measured ratio is recorded next to this trajectory in the JSON artifact
# every run AND hard-asserted against SP4_VS_LOCAL_MAX: a chain falling off
# the fused path jumps the ratio back toward 6.6x, which the bound catches
# while staying comfortably above run-to-run CPU-mesh noise.
SP4_VS_LOCAL_BASELINE = {
    "pre_fused_chain": 6.6,       # n sequential rank-1 applications
    "fused_affine_many": 2.90,    # one fused multi-seed application
}
SP4_VS_LOCAL_MAX = 3.0


def _mem_stats(compiled) -> dict:
    """Executable-level memory analysis (None-safe: some backends return
    nothing).  ``peak_live_bytes`` = args + outputs + temps − donation
    aliasing, the buffer footprint a training host must actually hold."""
    try:
        m = compiled.memory_analysis()
        if m is None:
            return {}
        arg = int(m.argument_size_in_bytes)
        out = int(m.output_size_in_bytes)
        tmp = int(m.temp_size_in_bytes)
        alias = int(getattr(m, "alias_size_in_bytes", 0))
        return {"arg_bytes": arg, "out_bytes": out, "temp_bytes": tmp,
                "alias_bytes": alias,
                "peak_live_bytes": arg + out + tmp - alias}
    except Exception:                                   # pragma: no cover
        return {}


def _measure_plain(prog, loss_fn, params, batch):
    state = prog.init(params, seed=0)
    step = jax.jit(prog.step_fn(loss_fn))
    t = time_fn(step, params, state, batch,
                warmup=2, iters=3 if is_smoke() else 7)
    mem = _mem_stats(step.lower(params, state, batch).compile())
    return t, mem


def _measure_donated(prog, loss_fn, params, batch):
    """Donated steps consume their parameter buffer: re-feed the returned
    params each call (time_fn would replay a deleted buffer)."""
    state = prog.init(params, seed=0)
    step = prog.compiled_step_fn(loss_fn)
    mem = _mem_stats(step.lower(params, state, batch).compile())
    p = jax.tree_util.tree_map(lambda x: x + 0, params)   # private copy
    for _ in range(2):                                    # warmup
        p, state, _ = step(p, state, batch)
    jax.block_until_ready(p)
    ts = []
    for _ in range(3 if is_smoke() else 7):
        t0 = time.perf_counter()
        p, state, _ = step(p, state, batch)
        jax.block_until_ready(p)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6, mem


def run() -> None:
    cfg = tiny_lm(d_model=64, n_layers=2, vocab=256, ff=128)
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    batch = lm_batch(1, 0, BATCH, SEQ, cfg.vocab_size)
    n_params = tree_size(params)
    param_bytes = 4 * int(n_params)

    records = []
    mk = lambda: zo.mezo(lr=1e-5, eps=1e-3)

    def one_plan(name, plan, n_groups, wire):
        prog = zexec.StepProgram(mk(), plan)
        t_plain, mem_plain = _measure_plain(prog, loss_fn, params, batch)
        t_don, mem_don = _measure_donated(prog, loss_fn, params, batch)
        peak_p = mem_plain.get("peak_live_bytes")
        peak_d = mem_don.get("peak_live_bytes")
        deriv = f"donated={t_don:.1f}us;wire_B={wire}"
        if peak_p and peak_d:
            deriv += (f";peak_live_MB={peak_p / 1e6:.2f}"
                      f";peak_live_donated_MB={peak_d / 1e6:.2f}")
        emit(f"exec/{name}", t_plain, deriv)
        records.append({"plan": name.split("_")[0] if "parallel" not in name
                        else "seed_parallel",
                        "n_groups": n_groups,
                        "us_per_step": t_plain,
                        "us_per_step_donated": t_don,
                        "wire_bytes_per_step": wire,
                        "memory": mem_plain,
                        "memory_donated": mem_don})
        return t_plain

    t_local = one_plan("local_spsa", zexec.local(), 1, 0)
    sp4_vs_local = None
    for n in GROUPS:
        t_sp = one_plan(f"seed_parallel_{n}", zexec.seed_parallel(n), n,
                        8 * n)
        records[-1]["vs_local"] = t_sp / t_local
        note(f"seed_parallel({n}): {t_sp / t_local:.2f}x local")
        if n == 4:
            sp4_vs_local = t_sp / t_local
    if sp4_vs_local is not None:
        if sp4_vs_local > SP4_VS_LOCAL_MAX:
            raise AssertionError(
                f"seed_parallel(4) step is {sp4_vs_local:.2f}x the local "
                f"plan (bound {SP4_VS_LOCAL_MAX:.1f}x) — the group-update "
                "chain likely fell off the fused affine_many path "
                f"(trajectory: {SP4_VS_LOCAL_BASELINE['pre_fused_chain']}x "
                "pre-fused -> "
                f"{SP4_VS_LOCAL_BASELINE['fused_affine_many']}x fused)")
        emit("exec/sp4_overhead_vs_local", 0.0,
             f"measured={sp4_vs_local:.2f}x;"
             f"baseline={SP4_VS_LOCAL_BASELINE['fused_affine_many']:.2f}x")
        note(f"sp(4) mesh overhead: {sp4_vs_local:.2f}x local (trajectory "
             f"{SP4_VS_LOCAL_BASELINE['pre_fused_chain']:.1f}x pre-fused -> "
             f"{SP4_VS_LOCAL_BASELINE['fused_affine_many']:.2f}x fused "
             f"baseline)")

    don = [r for r in records if r["memory"] and r["memory_donated"]]
    for r in don:
        pl, dn = (r["memory"]["peak_live_bytes"],
                  r["memory_donated"]["peak_live_bytes"])
        note(f"{r['plan']}(n={r['n_groups']}): peak live {pl / 1e6:.2f} MB "
             f"-> {dn / 1e6:.2f} MB donated "
             f"(params themselves: {param_bytes / 1e6:.2f} MB)")

    dp_grad_bytes = 4 * n_params
    note(f"bytes-on-wire contrast: seed-parallel(4) moves 32 B/step; a DP "
         f"backprop all-reduce would move {dp_grad_bytes / 1e6:.1f} MB/step "
         f"({dp_grad_bytes // 32}x)")
    emit("exec/dp_gradient_allreduce_bytes", 0.0,
         f"bytes={dp_grad_bytes};ratio_vs_sp4={dp_grad_bytes // 32}")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"model_params": int(n_params),
                   "param_bytes": param_bytes,
                   "batch": BATCH, "seq": SEQ,
                   "smoke": is_smoke(), "records": records,
                   "sp4_vs_local": sp4_vs_local,
                   "sp4_vs_local_baseline": SP4_VS_LOCAL_BASELINE,
                   "dp_gradient_allreduce_bytes": int(dp_grad_bytes)},
                  f, indent=2)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
