"""Execution-engine bench: local vs seed-parallel step wall-clock plus the
per-step bytes-on-wire story.

The engine's pitch is that estimator × backend × plan is a full matrix, so
this bench times the SAME optimizer composition lowered onto different plans
(``repro.exec.StepProgram``) on a tiny LM:

  * ``local``            — the facade's jit+donate step (2 forwards);
  * ``seed_parallel(n)`` — n seed groups on batch slices at the step's
                           center (2n forwards over 1/n-sized slices: ≈ the
                           local step's FLOPs, n× direction averaging).

Bytes-on-wire per step (what a multi-host deployment would move):

  * seed-parallel: the 2n loss scalars (2 × f32 per group) — MeZO's entire
    inter-replica traffic;
  * async: one (step, worker, g, lr) contribution per worker (~16 B);
  * a DP backprop baseline would all-reduce the full gradient (4·|θ| bytes)
    — the contrast column.

Emits ``name,us_per_call,derived`` CSV rows and a JSON record to
``results/bench_exec.json`` (CI artifact; ``run.py --smoke`` scale).
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import emit, is_smoke, note, time_fn, tiny_lm
from repro import exec as zexec
from repro import zo
from repro.data.synthetic import lm_batch
from repro.models import bundle
from repro.tree_utils import tree_size

OUT_PATH = os.path.join("results", "bench_exec.json")

GROUPS = (1, 2, 4)
BATCH = 8 if is_smoke() else 32
SEQ = 32 if is_smoke() else 64


def _step_time_us(prog, loss_fn, params, batch):
    state = prog.init(params, seed=0)
    step = jax.jit(prog.step_fn(loss_fn))
    return time_fn(step, params, state, batch,
                   warmup=2, iters=3 if is_smoke() else 7)


def run() -> None:
    cfg = tiny_lm(d_model=64, n_layers=2, vocab=256, ff=128)
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    batch = lm_batch(1, 0, BATCH, SEQ, cfg.vocab_size)
    n_params = tree_size(params)

    records = []
    mk = lambda: zo.mezo(lr=1e-5, eps=1e-3)
    t_local = _step_time_us(zexec.StepProgram(mk(), zexec.local()),
                            loss_fn, params, batch)
    emit("exec/local_spsa", t_local, "plan=local")
    records.append({"plan": "local", "n_groups": 1, "us_per_step": t_local,
                    "wire_bytes_per_step": 0})
    for n in GROUPS:
        t_sp = _step_time_us(
            zexec.StepProgram(mk(), zexec.seed_parallel(n)),
            loss_fn, params, batch)
        wire = 8 * n          # 2n loss scalars, f32
        emit(f"exec/seed_parallel_{n}", t_sp,
             f"vs_local={t_sp / t_local:.2f}x;wire_B={wire}")
        records.append({"plan": "seed_parallel", "n_groups": n,
                        "us_per_step": t_sp, "wire_bytes_per_step": wire,
                        "vs_local": t_sp / t_local})

    dp_grad_bytes = 4 * n_params
    note(f"bytes-on-wire contrast: seed-parallel(4) moves 32 B/step; a DP "
         f"backprop all-reduce would move {dp_grad_bytes / 1e6:.1f} MB/step "
         f"({dp_grad_bytes // 32}x)")
    emit("exec/dp_gradient_allreduce_bytes", 0.0,
         f"bytes={dp_grad_bytes};ratio_vs_sp4={dp_grad_bytes // 32}")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"model_params": int(n_params), "batch": BATCH, "seq": SEQ,
                   "smoke": is_smoke(), "records": records,
                   "dp_gradient_allreduce_bytes": int(dp_grad_bytes)},
                  f, indent=2)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
