"""Fused multi-seed kernel vs the per-seed chain it replaces.

The ``zo_fused_multi`` pitch is HBM arithmetic: a B-stream update chain as B
single-seed ``zo_affine`` launches reads and writes θ through HBM B times,
the fused chain kernel exactly once; the B-way fan-out re-reads x B times
per-seed, once fused.  On a CPU host both lowerings run through the Pallas
interpreter, so wall-clock here measures launch/interpretation overhead
rather than memory bandwidth — but that overhead scales with launch count
the same way HBM traffic does, so fused < per-seed at B ≥ 4 is still the
pass/fail line (the bandwidth claim itself is the TPU nightly's job).

Output: CSV rows plus ``results/bench_kernel_multi.json`` with the fused and
per-seed timings per B for both shapes of the kernel (chain and fan-out).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, is_smoke, note, time_fn
from repro.perturb import pallas as pallas_mod

OUT_PATH = os.path.join("results", "bench_kernel_multi.json")

BS = (1, 4, 8)


def _chain_seq(x, seeds, a, b):
    for j in range(seeds.shape[0]):
        x = pallas_mod.zo_affine(x, int(seeds[j]), float(a[j]), float(b[j]),
                                 interpret=True)
    return x


def _fanout_seq(x, seeds, a, b):
    return jnp.stack([
        pallas_mod.zo_affine(x, int(seeds[j]), float(a[j]), float(b[j]),
                             interpret=True)
        for j in range(seeds.shape[0])])


def run() -> None:
    rows = 256 if is_smoke() else 1024
    x = jax.random.normal(jax.random.PRNGKey(0), (rows, 512))
    iters = 3 if is_smoke() else 5
    records = []
    for B in BS:
        seeds = jnp.arange(B, dtype=jnp.int32) * 7 + 11
        a = jnp.linspace(0.9, 1.0, B)
        b = jnp.linspace(-0.02, 0.02, B)

        t_chain = time_fn(pallas_mod.zo_affine_chain, x, seeds, a, b,
                          warmup=1, iters=iters)
        t_chain_seq = time_fn(_chain_seq, x, seeds, a, b,
                              warmup=1, iters=iters)
        emit(f"kernel_multi/chain_B{B}", t_chain,
             f"per_seed={t_chain_seq:.1f}us;speedup={t_chain_seq / t_chain:.2f}x")

        t_fan = time_fn(pallas_mod.zo_affine_multi, x, seeds, a, b,
                        warmup=1, iters=iters)
        t_fan_seq = time_fn(_fanout_seq, x, seeds, a, b,
                            warmup=1, iters=iters)
        emit(f"kernel_multi/fanout_B{B}", t_fan,
             f"per_seed={t_fan_seq:.1f}us;speedup={t_fan_seq / t_fan:.2f}x")

        records.append({"B": B, "elements": int(x.size),
                        "chain_fused_us": t_chain,
                        "chain_per_seed_us": t_chain_seq,
                        "fanout_fused_us": t_fan,
                        "fanout_per_seed_us": t_fan_seq})
        if B >= 4:
            status = ("fused wins" if t_chain < t_chain_seq
                      else "fused SLOWER — regression")
            note(f"B={B} chain: fused {t_chain:.0f}us vs per-seed "
                 f"{t_chain_seq:.0f}us ({status})")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"rows": rows, "cols": 512, "smoke": is_smoke(),
                   "interpret": True, "records": records}, f, indent=2)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
