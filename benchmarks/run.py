# One function per paper table. Print ``name,us_per_call,derived`` CSV.
#
#   Table 1 / 18 (quality)      -> bench_quality
#   Figure 3 / 4, Table 22      -> bench_memory
#   Table 23 (wall-clock)       -> bench_wallclock
#   Table 11 / Table 6          -> bench_estimators
#   Table 3 (non-differentiable)-> bench_nondiff
#   §2.1 storage                -> bench_storage
#   Theorem 1 / Lemma 3         -> bench_theory
#   §Roofline (dry-run derived) -> bench_roofline
#   Tables 8/9/10/19, ICL column -> bench_variants
#   §2.1 serving consequence    -> bench_serve (multi-tenant adapter cache)
#
# Usage: PYTHONPATH=src python -m benchmarks.run [--only quality,theory]
#        PYTHONPATH=src python -m benchmarks.run --smoke     # CI per-commit
import argparse
import os
import sys
import time
import traceback

BENCHES = [
    ("storage", "benchmarks.bench_storage"),
    ("perturb", "benchmarks.bench_perturb"),
    ("select", "benchmarks.bench_select"),
    ("subleaf", "benchmarks.bench_subleaf"),
    ("exec", "benchmarks.bench_exec"),
    ("kernel_multi", "benchmarks.bench_kernel_multi"),
    ("wallclock", "benchmarks.bench_wallclock"),
    ("memory", "benchmarks.bench_memory"),
    ("roofline", "benchmarks.bench_roofline"),
    ("theory", "benchmarks.bench_theory"),
    ("estimators", "benchmarks.bench_estimators"),
    ("serve", "benchmarks.bench_serve"),
    ("nondiff", "benchmarks.bench_nondiff"),
    ("quality", "benchmarks.bench_quality"),
    ("variants", "benchmarks.bench_variants"),
]

# CI-per-commit subset: benches that finish in seconds at smoke scale and
# leave results/*.json artifacts (the perf trajectory per commit).
SMOKE_BENCHES = ("storage,perturb,select,subleaf,exec,kernel_multi,"
                 "estimators,serve,quality")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names (default: all)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes + reduced iters, restricted to the "
                         f"fast subset ({SMOKE_BENCHES}) unless --only is "
                         "given; sets REPRO_BENCH_SMOKE=1 for the benches")
    args = ap.parse_args()
    if args.smoke:
        os.environ["REPRO_BENCH_SMOKE"] = "1"
        if args.only is None:
            args.only = SMOKE_BENCHES
    only = set(args.only.split(",")) if args.only else None

    print("name,us_per_call,derived")
    failures = 0
    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        print(f"    # --- {name} ---", file=sys.stderr, flush=True)
        try:
            mod = __import__(module, fromlist=["run"])
            mod.run()
            print(f"    # {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception:
            failures += 1
            print(f"{name}/FAILED,0,error")
            traceback.print_exc()
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
