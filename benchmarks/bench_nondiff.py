"""Paper Table 3: MeZO optimizing NON-DIFFERENTIABLE objectives — accuracy
for classification, F1 for span extraction — vs the cross-entropy objective.
Backprop cannot touch these objectives (zero gradient a.e.)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, note, tiny_lm
from repro.core import MeZO, MeZOConfig
from repro.core.nondiff import negative_accuracy, negative_f1
from repro.data.synthetic import PromptClassification, SpanExtraction
from repro.models import bundle, transformer

STEPS = 600
BATCH = 128   # accuracy is a step function: bigger batches make
              # the +/- eps accuracies differ more often


def run():
    cfg = tiny_lm(d_model=96, n_layers=3, vocab=256, ff=192)
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=3)
    b = bundle(cfg)
    params0 = b.init(jax.random.PRNGKey(0))

    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits

    def acc_eval(p):
        return task.eval_accuracy(cfg, logits_fn, p, jax.random.PRNGKey(5), 512)

    # accuracy objective: the metric itself, at the label slot over label words
    words = task.label_word(jnp.arange(task.n_classes))

    def acc_objective(p, batch):
        slot = logits_fn(p, batch)[:, task.body_len, :]
        return negative_accuracy(slot[:, words], batch["cls"])

    acc0 = acc_eval(params0)
    # eps larger than CE fine-tuning: the objective only responds when a
    # perturbation flips at least one prediction (tuned: eps=0.02)
    opt = MeZO(MeZOConfig(lr=5e-4, eps=2e-2))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(acc_objective), donate_argnums=(0,))
    params = jax.tree_util.tree_map(jnp.copy, params0)
    for s in range(STEPS):
        params, state, _ = step(params, state, task.batch_for_step(s, BATCH))
    acc_nd = acc_eval(params)

    # cross-entropy reference (same budget)
    loss_fn = b.loss_fn()
    opt2 = MeZO(MeZOConfig(lr=2e-4, eps=1e-3))
    st2 = opt2.init(0)
    step2 = jax.jit(opt2.step_fn(loss_fn), donate_argnums=(0,))
    p2 = jax.tree_util.tree_map(jnp.copy, params0)
    for s in range(STEPS):
        p2, st2, _ = step2(p2, st2, task.batch_for_step(s, BATCH))
    acc_ce = acc_eval(p2)

    emit("nondiff/zero_shot_acc", 0.0, f"{acc0:.3f}")
    emit("nondiff/mezo_accuracy_objective", 0.0, f"{acc_nd:.3f}")
    emit("nondiff/mezo_cross_entropy", 0.0, f"{acc_ce:.3f}")
    note(f"Table 3 proxy: zero-shot {acc0:.3f} -> accuracy-objective "
         f"{acc_nd:.3f} (CE reference {acc_ce:.3f}); paper: ND works, CE "
         f"slightly stronger")


if __name__ == "__main__":
    run()
