"""Sub-leaf tile skipping (ISSUE 9): perturbed bytes/step and wallclock of
``rows(block=R, k=K)`` vs ``full`` on a large-embedding config, both backends.

The claim under test: a rows selection's cost scales with the selected
FRACTION of every tensor, not with the leaf set —

* **bytes/step**: ``Selection.selected_bytes`` (the per-step perturb
  read-modify-write traffic) must be ≤ 0.30× full at 25 % rows (asserted);
* **wallclock**: the pallas tile-skip launch (selected tiles only — no z
  generation, no reads, no writes for the rest) must beat a *masked-multiply
  strawman* — full-grid generation followed by ``where(mask)`` — strictly,
  at 25 % selection (asserted).  The strawman is what a selection layer
  without kernel support would do: same output, ~4× the generated z and
  touched bytes.

Block size is chosen tile-aligned (R rows × 512 cols = the kernel's 131072-
element tile) so every unselected tile is skipped whole — the geometry the
trace-time skip is designed for.  Results land in
``results/bench_subleaf.json`` (asserted present by CI bench-smoke).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, is_smoke, note, time_fn
from repro import select
from repro.perturb import StreamRef, get_backend

OUT_PATH = os.path.join("results", "bench_subleaf.json")

BLOCK_ROWS = 256          # × 512-wide rows = exactly one kernel tile
BYTES_RATIO_MAX = 0.30    # acceptance: bytes/step at 25 % rows ≤ 0.30× full


def _params(smoke: bool) -> dict:
    # one big embedding (the sub-leaf motivation: a single leaf holding most
    # of the bytes, where leaf-wise selection can't help) + a small head
    n_rows = 4096 if smoke else 16384            # 16 / 64 kernel tiles
    key = jax.random.PRNGKey(0)
    return {"emb": jax.random.normal(key, (n_rows, 512), jnp.float32),
            "head": jnp.ones((512,), jnp.float32)}


def _total_bytes(params) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(params))


def _perturb_fn(backend: str, sel):
    be = get_backend(backend)
    ref = StreamRef(jax.random.PRNGKey(7))
    if sel is not None:
        ref = ref.with_selection(sel, 0)

    @jax.jit
    def step(p):
        return be.perturb(p, ref, 1e-3)

    return step


def _strawman_fn(backend: str, sel, params):
    """Masked multiply: FULL z generation + ``where(selected, θ+εz, θ)`` —
    the same output as the tile-skip path, none of the savings."""
    be = get_backend(backend)
    ref = StreamRef(jax.random.PRNGKey(7))
    masks = []
    for p in jax.tree_util.tree_leaves(params):
        rb = sel.block_mask(p, 0)
        masks.append(jnp.ones(p.shape, bool) if rb is None else
                     jnp.asarray(np.asarray(
                         rb.element_mask(np.arange(p.size)),
                         dtype=bool)).reshape(p.shape))
    masks = tuple(masks)

    @jax.jit
    def step(p):
        full = be.perturb(p, ref, 1e-3)          # whole-grid generation
        flat_p = jax.tree_util.tree_leaves(p)
        flat_f = jax.tree_util.tree_leaves(full)
        out = [jnp.where(m, f, x)
               for m, f, x in zip(masks, flat_f, flat_p)]
        return jax.tree_util.tree_unflatten(
            jax.tree_util.tree_structure(p), out)

    return step


def run() -> None:
    smoke = is_smoke()
    params = _params(smoke)
    total = _total_bytes(params)
    sel_25 = select.rows(block=BLOCK_ROWS, k=4)      # 25 % of blocks/step
    sel_6 = select.rows(block=BLOCK_ROWS, k=16)      # 6.25 %
    variants = {"full": None, "rows_25pct": sel_25, "rows_6_25pct": sel_6}

    res = {"smoke": smoke,
           "emb_shape": list(params["emb"].shape),
           "total_bytes": total,
           "bytes_per_step": {}, "bytes_ratio": {}, "wallclock_us": {}}

    for name, sel in variants.items():
        b = total if sel is None else sel.selected_bytes(params, phase=0)
        res["bytes_per_step"][name] = b
        res["bytes_ratio"][name] = b / total
        note(f"{name}: {b/1e6:.2f} MB perturbed/step "
             f"({b/total:.1%} of {total/1e6:.1f} MB)")

    for backend in ("pallas-interpret", "xla"):
        times = {}
        for name, sel in variants.items():
            times[name] = time_fn(_perturb_fn(backend, sel), params)
            emit(f"subleaf/{backend}_{name}", times[name],
                 f"{res['bytes_per_step'][name]/1e6:.2f}MB")
        times["strawman_25pct"] = time_fn(
            _strawman_fn(backend, sel_25, params), params)
        emit(f"subleaf/{backend}_strawman_25pct", times["strawman_25pct"],
             "full-gen+mask")
        res["wallclock_us"][backend] = times
        note(f"{backend}: full {times['full']:.0f}us, rows(25%) "
             f"{times['rows_25pct']:.0f}us, rows(6.25%) "
             f"{times['rows_6_25pct']:.0f}us, strawman(25%) "
             f"{times['strawman_25pct']:.0f}us")

    # acceptance: perturbed bytes ≤ 0.30× at 25 % rows
    ratio = res["bytes_ratio"]["rows_25pct"]
    assert ratio <= BYTES_RATIO_MAX, \
        f"25% rows perturbs {ratio:.2%} of bytes (> {BYTES_RATIO_MAX:.0%})"
    # acceptance: the pallas tile-skip beats the masked-multiply strawman
    pk = res["wallclock_us"]["pallas-interpret"]
    speedup = pk["strawman_25pct"] / pk["rows_25pct"]
    res["tile_skip_vs_strawman_speedup_25pct"] = speedup
    emit("subleaf/tile_skip_speedup_vs_strawman", pk["rows_25pct"],
         f"{speedup:.2f}x")
    assert pk["rows_25pct"] < pk["strawman_25pct"], \
        (f"tile-skip ({pk['rows_25pct']:.0f}us) not faster than the "
         f"masked-multiply strawman ({pk['strawman_25pct']:.0f}us)")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(res, f, indent=1)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
