"""Paper §2.1 storage trick: a fine-tuning run serialized as (seed, g_t
scalars).  Measures REAL ledger bytes from our implementation vs LoRA /
prefix / full checkpoints for OPT-66B-scale fine-tuning."""
from __future__ import annotations

import jax

from benchmarks.common import emit, note
from repro.core import MeZO, MeZOConfig, TrajectoryLedger
from repro.models import all_archs, peft
from repro.tree_utils import tree_bytes, tree_size


def run():
    # real ledger from a short run, extrapolated to the paper's 20K steps
    import jax.numpy as jnp
    t = jax.random.normal(jax.random.PRNGKey(0), (32,))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["w"] - t) ** 2)
    opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-3))
    params = {"w": jnp.zeros((32,))}
    state = opt.init(0)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float16")
    step = jax.jit(opt.step_fn(loss_fn))
    for s in range(100):
        params, state, m = step(params, state, None)
        led.append(s, float(m["projected_grad"]), float(m["lr"]))
    bytes_per_step = led.nbytes() / 100
    ledger_20k = int(bytes_per_step * 20_000)
    emit("storage/ledger_bytes_20k_steps", 0.0, str(ledger_20k))

    cfg = all_archs()["opt-66b"].cfg
    lora = jax.eval_shape(lambda k: peft.init_lora(cfg, k),
                          jax.random.PRNGKey(0))
    pre = jax.eval_shape(lambda k: peft.init_prefix(cfg, k, 5),
                         jax.random.PRNGKey(0))
    lora_b = tree_bytes(lora)
    pre_b = tree_bytes(pre)
    full_b = cfg.n_params() * 2
    emit("storage/lora_ckpt_bytes_opt66b", 0.0, str(lora_b))
    emit("storage/prefix_ckpt_bytes_opt66b", 0.0, str(pre_b))
    emit("storage/full_ckpt_bytes_opt66b", 0.0, str(full_b))
    emit("storage/lora_over_ledger", 0.0, f"{lora_b/ledger_20k:.0f}")
    note(f"ledger(20K steps) {ledger_20k/1e3:.0f} KB vs LoRA "
         f"{lora_b/1e6:.0f} MB vs prefix {pre_b/1e6:.1f} MB vs full "
         f"{full_b/1e9:.0f} GB  (paper: <0.1MB vs 38MB vs 12MB)")


if __name__ == "__main__":
    run()
