"""Paper §2.1 storage trick: a fine-tuning run serialized as (seed, g_t
scalars).  Measures REAL ledger bytes from our implementation vs LoRA /
prefix / full checkpoints for OPT-66B-scale fine-tuning, plus the serving
layer's compaction trade (raw long-ledger replay vs stored delta + tail)."""
from __future__ import annotations

import time

import jax

from benchmarks.common import emit, is_smoke, note
from repro.core import MeZO, MeZOConfig, TrajectoryLedger
from repro.models import all_archs, peft
from repro.tree_utils import tree_bytes, tree_size


def run():
    # real ledger from a short run, extrapolated to the paper's 20K steps
    import jax.numpy as jnp
    t = jax.random.normal(jax.random.PRNGKey(0), (32,))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["w"] - t) ** 2)
    opt = MeZO(MeZOConfig(lr=1e-3, eps=1e-3))
    params = {"w": jnp.zeros((32,))}
    state = opt.init(0)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float16")
    step = jax.jit(opt.step_fn(loss_fn))
    for s in range(100):
        params, state, m = step(params, state, None)
        led.append(s, float(m["projected_grad"]), float(m["lr"]))
    bytes_per_step = led.nbytes() / 100
    ledger_20k = int(bytes_per_step * 20_000)
    emit("storage/ledger_bytes_20k_steps", 0.0, str(ledger_20k))

    cfg = all_archs()["opt-66b"].cfg
    lora = jax.eval_shape(lambda k: peft.init_lora(cfg, k),
                          jax.random.PRNGKey(0))
    pre = jax.eval_shape(lambda k: peft.init_prefix(cfg, k, 5),
                         jax.random.PRNGKey(0))
    lora_b = tree_bytes(lora)
    pre_b = tree_bytes(pre)
    full_b = cfg.n_params() * 2
    emit("storage/lora_ckpt_bytes_opt66b", 0.0, str(lora_b))
    emit("storage/prefix_ckpt_bytes_opt66b", 0.0, str(pre_b))
    emit("storage/full_ckpt_bytes_opt66b", 0.0, str(full_b))
    emit("storage/lora_over_ledger", 0.0, f"{lora_b/ledger_20k:.0f}")
    note(f"ledger(20K steps) {ledger_20k/1e3:.0f} KB vs LoRA "
         f"{lora_b/1e6:.0f} MB vs prefix {pre_b/1e6:.1f} MB vs full "
         f"{full_b/1e9:.0f} GB  (paper: <0.1MB vs 38MB vs 12MB)")

    # -- compaction (repro.serve.tenants): a long-lived tenant's ledger ----- #
    # raw materialization replays every record; the compacted form stores one
    # changed-leaf delta + a short replayable tail — O(tail) per cold start.
    from repro import zo
    from repro.core.trajectory import replay
    from repro.serve.tenants import compact, materialize
    n_steps = 300 if is_smoke() else 10_000
    keep_tail = 64
    t2 = jax.random.normal(jax.random.PRNGKey(1), (256,))
    loss2 = lambda p, b: 0.5 * jnp.sum((p["w"] - t2) ** 2)
    opt2 = zo.mezo(lr=1e-3, eps=1e-3)
    params0 = {"w": jnp.zeros((256,))}
    state2 = opt2.init(params0, seed=0)
    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    step2 = jax.jit(opt2.step_fn(loss2))
    p = params0
    for s in range(n_steps):
        p, state2, m = step2(p, state2, None)
        led2.append(s, float(m["projected_grad"]), float(m["lr"]))

    t0 = time.perf_counter()
    jax.block_until_ready(replay(params0, led2, opt2)["w"])
    raw_us = (time.perf_counter() - t0) * 1e6
    comp = compact(params0, led2, opt2, keep_tail=keep_tail)
    t0 = time.perf_counter()
    jax.block_until_ready(materialize(params0, comp, opt2)["w"])
    comp_us = (time.perf_counter() - t0) * 1e6
    emit("storage/compaction_raw_replay", raw_us,
         f"{n_steps}_steps_{led2.nbytes()}B")
    emit("storage/compaction_delta_tail", comp_us,
         f"tail={keep_tail}_{comp.nbytes}B")
    note(f"compaction: {n_steps}-step ledger ({led2.nbytes()} B) cold-"
         f"materializes in {raw_us/1e3:.0f} ms raw vs {comp_us/1e3:.0f} ms "
         f"as delta+{keep_tail}-record tail ({comp.nbytes} B stored, "
         f"{raw_us/max(comp_us, 1e-9):.1f}x)")


if __name__ == "__main__":
    run()
