"""Perturbation-backend comparison: per-step wall-clock and peak memory of
the same MeZO composition under each ``repro.perturb`` backend.

Backends:
  * ``xla``              — threefry z as HBM temporaries (default).
  * ``pallas-interpret`` — the fused kernel under Pallas interpret mode
                           (CPU-runnable; measures interpreter overhead, not
                           kernel speed).
  * ``pallas``           — the compiled kernel (TPU; recorded as unavailable
                           when the host platform cannot compile it).

Peak memory is the compiled step's static analysis (argument + temp bytes),
the same methodology as bench_memory; on backends/platforms where XLA does
not expose it the record says so instead of guessing.

Output: CSV rows on stdout (the ``benchmarks/run.py`` contract) plus one JSON
document at ``results/bench_perturb.json`` for machine consumption.
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import emit, note, time_fn, tiny_lm
from repro import zo
from repro.data.synthetic import lm_batch
from repro.models import bundle

BACKENDS = ("xla", "pallas-interpret", "pallas")
OUT_PATH = os.path.join("results", "bench_perturb.json")


def _peak_bytes(step_fn, params, state, batch):
    compiled = jax.jit(step_fn).lower(params, state, batch).compile()
    ma = compiled.memory_analysis()
    if ma is None or not hasattr(ma, "temp_size_in_bytes"):
        return None
    return int(ma.argument_size_in_bytes) + int(ma.temp_size_in_bytes)


def run():
    cfg = tiny_lm(d_model=128, n_layers=2, ff=256, vocab=512)
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    batch = lm_batch(0, 0, 4, 32, cfg.vocab_size)

    records = []
    baseline_us = None
    for backend in BACKENDS:
        rec = {"backend": backend, "status": "ok"}
        try:
            if backend == "pallas":
                # force the COMPILED kernel: off-TPU get_backend("pallas")
                # silently falls back to interpret mode, which would just
                # duplicate the pallas-interpret row instead of reporting
                # "unavailable" honestly
                from repro.perturb import PallasBackend
                be = PallasBackend(interpret=False)
            else:
                from repro.perturb import get_backend
                be = get_backend(backend)
            if hasattr(be, "interpret"):
                rec["interpret"] = bool(be.interpret)
            opt = zo.mezo(lr=1e-4, eps=1e-3, backend=be)
            state = opt.init(params, seed=0)
            step_fn = opt.step_fn(loss_fn)
            us = time_fn(jax.jit(step_fn), params, state, batch)
            rec["us_per_step"] = us
            try:
                rec["peak_bytes"] = _peak_bytes(step_fn, params, state, batch)
            except Exception as e:      # CPU backend may not expose analysis
                rec["peak_bytes"] = None
                rec["peak_bytes_error"] = f"{type(e).__name__}: {e}"
            if backend == "xla":
                baseline_us = us
            slow = (us / baseline_us) if baseline_us else 0.0
            emit(f"perturb/{backend}_step", us, f"vs_xla={slow:.2f}x")
            pk = rec["peak_bytes"]
            emit(f"perturb/{backend}_peak_bytes", 0.0,
                 str(pk) if pk is not None else "unavailable")
            note(f"{backend}: {us/1e3:.2f} ms/step, peak "
                 f"{pk/1e6:.2f} MB" if pk else
                 f"{backend}: {us/1e3:.2f} ms/step, peak unavailable")
        except Exception as e:
            # e.g. compiled pallas on a host without a TPU lowering
            rec["status"] = "unavailable"
            rec["error"] = f"{type(e).__name__}: {e}"
            emit(f"perturb/{backend}_step", 0.0, "unavailable")
            note(f"{backend}: unavailable ({rec['error'][:120]})")
        records.append(rec)

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"bench": "perturb_backends",
                   "platform": jax.default_backend(),
                   "model": {"d_model": 128, "n_layers": 2, "ff": 256},
                   "records": records}, f, indent=2)
    note(f"JSON written to {OUT_PATH}")


if __name__ == "__main__":
    run()
