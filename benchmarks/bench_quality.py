"""Paper Tables 1/18 proxy: zero-shot vs MeZO (full / LoRA / prefix) vs FT
(Adam) on a synthetic prompt-based classification task, CPU-scale.

Protocol mirrors the paper's setting: the base LM is first PRETRAINED (200
Adam steps of LM loss with the label slot masked out — token features, no
task answer), then each method adapts that base.  Reproduces the paper's
qualitative ordering: zero-shot < MeZO ≈ MeZO-PEFT ≈ FT, plus Appendix A's
ablation (MeZO is much weaker without the prompt formulation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit, note, tiny_lm, time_fn
from repro.core import MeZO, MeZOConfig
from repro.data.synthetic import PromptClassification
from repro.models import bundle, peft, transformer
from repro.train.adam import Adam, AdamConfig

MEZO_STEPS = 900
FT_STEPS = 60
PRETRAIN_STEPS = 200
BATCH = 32


def _train(loss_fn, params, opt, task, steps, donate=True):
    params = jax.tree_util.tree_map(jnp.copy, params)   # donation-safe
    state = opt.init(params, seed=0)   # uniform protocol: no dispatch
    step = jax.jit(opt.step_fn(loss_fn),
                   donate_argnums=(0,) if donate else ())
    for s in range(steps):
        batch = task.batch_for_step(s, BATCH)
        params, state, m = step(params, state, batch)
    return params


def run():
    cfg = tiny_lm(d_model=96, n_layers=3, vocab=256, ff=192)
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=1)
    b = bundle(cfg)
    params0 = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()

    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits

    def acc(p):
        return task.eval_accuracy(cfg, logits_fn, p, jax.random.PRNGKey(10_000), 512)

    # ---- pretrain the base: LM loss, label slot masked out ---------------- #
    def pretrain_batch(s):
        bt = task.batch_for_step(s, BATCH)
        mask = jnp.ones_like(bt["loss_mask"]).at[:, task.body_len].set(0.0)
        mask = mask.at[:, -1].set(0.0)
        return {**bt, "loss_mask": mask}

    adam = Adam(AdamConfig(lr=3e-3, total_steps=PRETRAIN_STEPS))
    st = adam.init(params0)
    astep = jax.jit(adam.step_fn(loss_fn), donate_argnums=(0,))
    base = jax.tree_util.tree_map(jnp.copy, params0)
    for s in range(PRETRAIN_STEPS):
        base, st, _ = astep(base, st, pretrain_batch(s))

    acc0 = acc(base)
    emit("quality/zero_shot_acc", 0.0, f"{acc0:.3f}")

    # --- MeZO full-parameter
    mezo = MeZO(MeZOConfig(lr=2e-4, eps=1e-3))
    t_us = time_fn(jax.jit(mezo.step_fn(loss_fn)), base, mezo.init(0),
                   task.batch_for_step(0, BATCH))
    p_mezo = _train(loss_fn, base, mezo, task, MEZO_STEPS)
    acc_mezo = acc(p_mezo)
    emit("quality/mezo_acc", t_us, f"{acc_mezo:.3f}")

    # --- MeZO without prompt (paper App. A ablation).  Run from the SCRATCH
    # init: the ablation isolates whether the prompt formulation makes the
    # landscape optimizable — from a well-pretrained base even the bare
    # class-id readout is easy, which would mask the effect.
    task_np = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=1,
                                   prompt=False)
    p_np = _train(loss_fn, params0, MeZO(MeZOConfig(lr=2e-4, eps=1e-3)),
                  task_np, MEZO_STEPS)
    acc_np = task_np.eval_accuracy(cfg, logits_fn, p_np,
                                   jax.random.PRNGKey(10_000), 512)
    p_scratch = _train(loss_fn, params0, MeZO(MeZOConfig(lr=2e-4, eps=1e-3)),
                       task, MEZO_STEPS)
    acc_scratch = acc(p_scratch)
    emit("quality/mezo_no_prompt_acc", t_us, f"{acc_np:.3f}")
    emit("quality/mezo_prompt_scratch_acc", t_us, f"{acc_scratch:.3f}")

    # --- MeZO + LoRA (paper grid's lr family, r=8 α=16)
    lora0 = peft.init_lora(cfg, jax.random.PRNGKey(2))
    lora_loss = peft.lora_loss_fn(cfg, base)
    lora_t = _train(lora_loss, lora0, MeZO(MeZOConfig(lr=2e-3, eps=1e-3)),
                    task, MEZO_STEPS, donate=False)
    acc_lora = acc(peft.merge_lora(base, lora_t))
    emit("quality/mezo_lora_acc", 0.0, f"{acc_lora:.3f}")

    # --- MeZO + prefix (m=5, real-activation init, paper's ε=1e-1)
    pre0 = peft.init_prefix_from_tokens(cfg, base, jax.random.PRNGKey(3), m=5)
    pre_loss = peft.prefix_loss_fn(cfg, base)
    pre_t = _train(pre_loss, pre0, MeZO(MeZOConfig(lr=3e-2, eps=1e-1)),
                   task, MEZO_STEPS, donate=False)

    def prefix_logits(p, batch):
        lg, _ = peft._forward_with_prefix(cfg, base, pre_t, batch)
        return lg

    acc_pre = task.eval_accuracy(cfg, prefix_logits, pre_t,
                                 jax.random.PRNGKey(10_000), 512)
    emit("quality/mezo_prefix_acc", 0.0, f"{acc_pre:.3f}")

    # --- FT with Adam (the paper's 12x-memory comparator)
    adam = Adam(AdamConfig(lr=5e-3, total_steps=FT_STEPS))
    t_ft = time_fn(jax.jit(adam.step_fn(loss_fn)), base,
                   adam.init(base), task.batch_for_step(0, BATCH))
    p_ft = _train(loss_fn, base, adam, task, FT_STEPS)
    acc_ft = acc(p_ft)
    emit("quality/ft_adam_acc", t_ft, f"{acc_ft:.3f}")

    note(f"zero-shot {acc0:.3f} | MeZO {acc_mezo:.3f} (no-prompt {acc_np:.3f})"
         f" | LoRA {acc_lora:.3f} | prefix {acc_pre:.3f} | FT {acc_ft:.3f}")
    gap = acc_ft - max(acc_mezo, acc_lora, acc_pre)
    emit("quality/mezo_vs_ft_gap", 0.0, f"{gap:.3f}")


if __name__ == "__main__":
    run()
