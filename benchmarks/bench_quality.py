"""Optimization-quality gates, two sections:

1. **Per-family steps-to-loss** (always, smoke-scaled in CI): one MeZO run per
   architecture family (dense, moe, ssm, encdec) on its registry smoke config,
   recording the loss trajectory, the step count to a 2 % loss reduction, and
   a non-differentiable (−accuracy, paper §3.3) companion run.  Results land
   in ``results/bench_quality.json`` — the nightly-CI artifact that keeps
   speed work from silently regressing optimization quality on any family.
   The MoE run exercises the registry's default expert-wise selection
   (``moe_experts(G)``: router frozen, one expert group per step).

2. **Paper Tables 1/18 proxy** (full runs only): zero-shot vs MeZO (full /
   LoRA / prefix) vs FT (Adam) on synthetic prompt classification — the
   paper's qualitative ordering zero-shot < MeZO ≈ MeZO-PEFT ≈ FT, plus
   Appendix A's no-prompt ablation.
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp

from benchmarks.common import emit, is_smoke, note, tiny_lm, time_fn
from repro import zo
from repro.core import MeZO, MeZOConfig
from repro.data.synthetic import PromptClassification
from repro.models import bundle, family_arch, peft, transformer
from repro.train.adam import Adam, AdamConfig

OUT_PATH = os.path.join("results", "bench_quality.json")

MEZO_STEPS = 900
FT_STEPS = 60
PRETRAIN_STEPS = 200
BATCH = 32

# Families under the quality gate, with the per-family MeZO hyperparameters
# (CPU-smoke scale; lr tuned so the cycle-mean CE loss decreases ~1-2 % within
# the smoke step budget on the 2-layer d64 registry smoke configs).
FAMILIES = ("dense", "moe", "ssm", "encdec")
FAMILY_HP = {
    "dense": dict(lr=1e-4, eps=1e-3),
    "moe": dict(lr=3e-4, eps=1e-3),
    "ssm": dict(lr=1e-4, eps=1e-3),
    "encdec": dict(lr=3e-4, eps=1e-3),
}
MOE_EXPERT_GROUPS = 2
N_BATCHES = 4       # fixed-batch cycle length; metrics are per-cycle means


# --------------------------------------------------------------------------- #
# Section 1: per-family steps-to-loss (the nightly quality gate)
# --------------------------------------------------------------------------- #
def _family_cfg(fam):
    cfg = family_arch(fam, smoke=True)
    if fam == "moe":
        # the grouped layout so the registry default selection becomes
        # moe_experts(G) — the bench exercises the same hook as
        # ``launch/train --select auto``
        cfg = cfg.replace(expert_groups=MOE_EXPERT_GROUPS)
    return cfg


def _run_family(fam: str, steps: int, batch: int, seq: int,
                objective: str = "ce", selection=None) -> dict:
    cfg = _family_cfg(fam)
    b = bundle(cfg)
    sel = b.default_selection() if selection is None else selection
    hp = FAMILY_HP[fam]
    opt = zo.mezo(lr=hp["lr"], eps=hp["eps"],
                  selection=None if sel == "full" else sel)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn(objective=objective)
    # a short cycle of fixed batches: small enough to make progress visible
    # within the smoke budget, more than one so the run is not pure
    # single-batch memorization.  Per-step losses are measured on rotating
    # batches, so the trend metric is the per-CYCLE mean (batch composition
    # otherwise masks a 1 % improvement behind 3 % batch-to-batch spread).
    key = jax.random.PRNGKey(7)
    batches = [b.make_batch(jax.random.fold_in(key, i), batch, seq)
               for i in range(N_BATCHES)]
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    t_us = time_fn(step, params, state, batches[0])
    losses = []
    for s in range(steps):
        params, state, m = step(params, state, batches[s % N_BATCHES])
        losses.append(float(m["loss"]))
    cyc = [sum(losses[i:i + N_BATCHES]) / N_BATCHES
           for i in range(0, steps - steps % N_BATCHES, N_BATCHES)]
    target = cyc[0] * 0.995
    cycles_to = next((i + 1 for i, v in enumerate(cyc) if v <= target), None)
    return {"arch": cfg.name, "selection": sel, "objective": objective,
            "steps": steps, "us_per_step": t_us,
            "loss_first": cyc[0], "loss_final": cyc[-1],
            "loss_min": min(cyc),
            "reduction_pct": (100.0 * (cyc[0] - cyc[-1]) / cyc[0]
                              if cyc[0] else 0.0),
            "steps_to_995pct": None if cycles_to is None
            else cycles_to * N_BATCHES,
            "cycle_means": cyc, "losses": losses}


def _family_quality() -> dict:
    smoke = is_smoke()
    steps = 64 if smoke else 256
    acc_steps = 16 if smoke else 128
    batch, seq = (4, 16) if smoke else (8, 32)
    out = {"smoke": smoke, "estimator": "spsa", "families": {}}
    for fam in FAMILIES:
        rec = _run_family(fam, steps, batch, seq)
        # the non-differentiable companion (paper §3.3): −accuracy through
        # the same registry surface; backprop gets zero gradient on this,
        # MeZO does not (tests/test_nondiff.py asserts it trains)
        acc = _run_family(fam, acc_steps, batch, seq, objective="accuracy")
        rec["objective_accuracy"] = {
            "steps": acc["steps"], "acc_first": -acc["loss_first"],
            "acc_final": -acc["loss_final"], "acc_best": -acc["loss_min"]}
        out["families"][fam] = rec
        emit(f"quality/{fam}_steps_to_loss", rec["us_per_step"],
             f"{rec['loss_first']:.3f}->{rec['loss_final']:.3f}"
             f"@{rec['steps_to_995pct']}")
        emit(f"quality/{fam}_nondiff_acc", 0.0,
             f"{rec['objective_accuracy']['acc_first']:.3f}->"
             f"{rec['objective_accuracy']['acc_final']:.3f}")
        note(f"{fam}: {rec['arch']} sel={rec['selection']} "
             f"loss {rec['loss_first']:.3f}->{rec['loss_final']:.3f} "
             f"({rec['reduction_pct']:.2f}% red, 99.5% at step "
             f"{rec['steps_to_995pct']})")
    out["selection"] = _selection_quality(steps, batch, seq)
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump(out, f, indent=1)
    note(f"wrote {OUT_PATH}")
    return out


# Sparse-perturbation quality comparison (Wang et al., 2024 / ISSUE 9): the
# same dense run under leaf-wise block_cyclic(k) and sub-leaf rows(block,k)
# schedules vs the full selection.  Every variant runs the SAME step count —
# a ZO step is 2 forwards regardless of selection, so equal steps is equal
# forward budget; what changes is perturbed bytes/step (k× fewer) and the
# estimator's perturbed subspace per step.
SELECTION_VARIANTS = ("full", "block_cyclic(4)", "rows(block=16,k=4)")


def _selection_quality(steps: int, batch: int, seq: int) -> dict:
    out = {}
    for spec in SELECTION_VARIANTS:
        rec = _run_family("dense", steps, batch, seq, selection=spec)
        out[spec] = {k: rec[k] for k in
                     ("steps", "us_per_step", "loss_first", "loss_final",
                      "loss_min", "reduction_pct", "steps_to_995pct",
                      "cycle_means")}
        emit(f"quality/select_{spec}", rec["us_per_step"],
             f"{rec['loss_first']:.3f}->{rec['loss_final']:.3f}"
             f"@{rec['steps_to_995pct']}")
        note(f"selection {spec}: loss {rec['loss_first']:.3f}->"
             f"{rec['loss_final']:.3f} ({rec['reduction_pct']:.2f}% red, "
             f"99.5% at step {rec['steps_to_995pct']}) at equal forward "
             f"budget ({rec['steps']} steps)")
    return out


# --------------------------------------------------------------------------- #
# Section 2: the paper Tables 1/18 proxy (full runs only)
# --------------------------------------------------------------------------- #
def _train(loss_fn, params, opt, task, steps, donate=True):
    params = jax.tree_util.tree_map(jnp.copy, params)   # donation-safe
    state = opt.init(params, seed=0)   # uniform protocol: no dispatch
    step = jax.jit(opt.step_fn(loss_fn),
                   donate_argnums=(0,) if donate else ())
    for s in range(steps):
        batch = task.batch_for_step(s, BATCH)
        params, state, m = step(params, state, batch)
    return params


def _paper_proxy():
    cfg = tiny_lm(d_model=96, n_layers=3, vocab=256, ff=192)
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=1)
    b = bundle(cfg)
    params0 = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()

    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits

    def acc(p):
        return task.eval_accuracy(cfg, logits_fn, p, jax.random.PRNGKey(10_000), 512)

    # ---- pretrain the base: LM loss, label slot masked out ---------------- #
    def pretrain_batch(s):
        bt = task.batch_for_step(s, BATCH)
        mask = jnp.ones_like(bt["loss_mask"]).at[:, task.body_len].set(0.0)
        mask = mask.at[:, -1].set(0.0)
        return {**bt, "loss_mask": mask}

    adam = Adam(AdamConfig(lr=3e-3, total_steps=PRETRAIN_STEPS))
    st = adam.init(params0)
    astep = jax.jit(adam.step_fn(loss_fn), donate_argnums=(0,))
    base = jax.tree_util.tree_map(jnp.copy, params0)
    for s in range(PRETRAIN_STEPS):
        base, st, _ = astep(base, st, pretrain_batch(s))

    acc0 = acc(base)
    emit("quality/zero_shot_acc", 0.0, f"{acc0:.3f}")

    # --- MeZO full-parameter
    mezo = MeZO(MeZOConfig(lr=2e-4, eps=1e-3))
    t_us = time_fn(jax.jit(mezo.step_fn(loss_fn)), base, mezo.init(0),
                   task.batch_for_step(0, BATCH))
    p_mezo = _train(loss_fn, base, mezo, task, MEZO_STEPS)
    acc_mezo = acc(p_mezo)
    emit("quality/mezo_acc", t_us, f"{acc_mezo:.3f}")

    # --- MeZO without prompt (paper App. A ablation).  Run from the SCRATCH
    # init: the ablation isolates whether the prompt formulation makes the
    # landscape optimizable — from a well-pretrained base even the bare
    # class-id readout is easy, which would mask the effect.
    task_np = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=1,
                                   prompt=False)
    p_np = _train(loss_fn, params0, MeZO(MeZOConfig(lr=2e-4, eps=1e-3)),
                  task_np, MEZO_STEPS)
    acc_np = task_np.eval_accuracy(cfg, logits_fn, p_np,
                                   jax.random.PRNGKey(10_000), 512)
    p_scratch = _train(loss_fn, params0, MeZO(MeZOConfig(lr=2e-4, eps=1e-3)),
                       task, MEZO_STEPS)
    acc_scratch = acc(p_scratch)
    emit("quality/mezo_no_prompt_acc", t_us, f"{acc_np:.3f}")
    emit("quality/mezo_prompt_scratch_acc", t_us, f"{acc_scratch:.3f}")

    # --- MeZO + LoRA (paper grid's lr family, r=8 α=16)
    lora0 = peft.init_lora(cfg, jax.random.PRNGKey(2))
    lora_loss = peft.lora_loss_fn(cfg, base)
    lora_t = _train(lora_loss, lora0, MeZO(MeZOConfig(lr=2e-3, eps=1e-3)),
                    task, MEZO_STEPS, donate=False)
    acc_lora = acc(peft.merge_lora(base, lora_t))
    emit("quality/mezo_lora_acc", 0.0, f"{acc_lora:.3f}")

    # --- MeZO + prefix (m=5, real-activation init, paper's ε=1e-1)
    pre0 = peft.init_prefix_from_tokens(cfg, base, jax.random.PRNGKey(3), m=5)
    pre_loss = peft.prefix_loss_fn(cfg, base)
    pre_t = _train(pre_loss, pre0, MeZO(MeZOConfig(lr=3e-2, eps=1e-1)),
                   task, MEZO_STEPS, donate=False)

    def prefix_logits(p, batch):
        lg, _ = peft._forward_with_prefix(cfg, base, pre_t, batch)
        return lg

    acc_pre = task.eval_accuracy(cfg, prefix_logits, pre_t,
                                 jax.random.PRNGKey(10_000), 512)
    emit("quality/mezo_prefix_acc", 0.0, f"{acc_pre:.3f}")

    # --- FT with Adam (the paper's 12x-memory comparator)
    adam = Adam(AdamConfig(lr=5e-3, total_steps=FT_STEPS))
    t_ft = time_fn(jax.jit(adam.step_fn(loss_fn)), base,
                   adam.init(base), task.batch_for_step(0, BATCH))
    p_ft = _train(loss_fn, base, adam, task, FT_STEPS)
    acc_ft = acc(p_ft)
    emit("quality/ft_adam_acc", t_ft, f"{acc_ft:.3f}")

    note(f"zero-shot {acc0:.3f} | MeZO {acc_mezo:.3f} (no-prompt {acc_np:.3f})"
         f" | LoRA {acc_lora:.3f} | prefix {acc_pre:.3f} | FT {acc_ft:.3f}")
    gap = acc_ft - max(acc_mezo, acc_lora, acc_pre)
    emit("quality/mezo_vs_ft_gap", 0.0, f"{gap:.3f}")


def run():
    _family_quality()
    if not is_smoke():
        _paper_proxy()


if __name__ == "__main__":
    run()
