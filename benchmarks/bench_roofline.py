"""Roofline summary from the dry-run artifacts (EXPERIMENTS.md §Roofline).
Emits the per-cell dominant-bottleneck terms and the hillclimb before/after
for the three chosen cells."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit, note
from repro.analysis.report import load_latest


def run():
    path = "results/dryrun.jsonl"
    if not os.path.exists(path):
        note("results/dryrun.jsonl missing — run python -m repro.launch.dryrun")
        emit("roofline/cells", 0.0, "0")
        return
    recs = load_latest(path, "single")
    ok = [r for r in recs.values() if r["status"] == "ok"]
    emit("roofline/cells", 0.0, str(len(ok)))
    for r in ok:
        emit(f"roofline/{r['arch']}/{r['cell']}", r["step_s"] * 1e6,
             f"bottleneck={r['bottleneck']};fraction={r['roofline_fraction']:.3f}")
    if os.path.exists("results/hillclimb.jsonl"):
        with open("results/hillclimb.jsonl") as f:
            for line in f:
                h = json.loads(line)
                if h["status"] != "ok":
                    continue
                emit(f"roofline/hillclimb/{h.get('tag','')}",
                     h["step_s"] * 1e6,
                     f"{h['arch']}/{h['cell']};fraction="
                     f"{h['roofline_fraction']:.3f}")


if __name__ == "__main__":
    run()
