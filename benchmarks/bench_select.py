"""Parameter-selection bench: full vs block_cyclic(k) vs peft(lora) step
wall-clock plus the perturbed-bytes-per-step story.

The selection layer's pitch is that skipped leaves cost ZERO z generation
(not a masked multiply), so a block-scheduled or PEFT run's perturb/update
traffic shrinks with the selected fraction while the forward pass is
unchanged.  This bench times the SAME spsa composition under different
selections on a tiny LM and reports:

  * ``us_per_step``          — jitted end-to-end step wall-clock;
  * ``perturbed_bytes``      — bytes of the leaves the step reads-modifies-
                               writes for z (selection.selected_bytes,
                               averaged over schedule phases);
  * ``selected_fraction``    — selected / total parameters.

Emits ``name,us_per_call,derived`` CSV rows and a JSON record to
``results/bench_select.json`` (CI artifact; ``run.py --smoke`` scale).
"""
from __future__ import annotations

import json
import os

import jax

from benchmarks.common import emit, is_smoke, note, time_fn, tiny_lm
from repro import select, zo
from repro.data.synthetic import lm_batch
from repro.models import bundle, peft
from repro.tree_utils import tree_bytes, tree_size

OUT_PATH = os.path.join("results", "bench_select.json")

BATCH = 8 if is_smoke() else 32
SEQ = 32 if is_smoke() else 64
BLOCK_K = 4


def _step_time_us(opt, loss_fn, params, batch):
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    return time_fn(step, params, state, batch,
                   warmup=2, iters=3 if is_smoke() else 7)


def _avg_selected_bytes(sel, params) -> int:
    if sel is None:
        return tree_bytes(params)
    phases = range(sel.n_phases)
    return sum(sel.selected_bytes(params, p) for p in phases) // sel.n_phases


def run() -> None:
    cfg = tiny_lm(d_model=64, n_layers=2, vocab=256, ff=128)
    b = bundle(cfg)
    base = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()
    batch = lm_batch(1, 0, BATCH, SEQ, cfg.vocab_size)

    lora = peft.init_lora(cfg, jax.random.PRNGKey(1))
    merged = peft.peft_params(base, lora, "lora")
    peft_loss = peft.peft_loss_fn(cfg, "lora")

    cases = [
        ("full", None, loss_fn, base, batch),
        (f"block_cyclic_{BLOCK_K}", select.block_cyclic(BLOCK_K),
         loss_fn, base, batch),
        ("peft_lora", select.peft("lora"), peft_loss, merged, batch),
    ]

    records = []
    t_full = None
    for name, sel, lfn, params, bt in cases:
        opt = zo.mezo(lr=1e-5, eps=1e-3, selection=sel)
        t = _step_time_us(opt, lfn, params, bt)
        pb = _avg_selected_bytes(sel, params)
        total = tree_bytes(params)
        frac = pb / total
        if t_full is None:
            t_full = t
        emit(f"select/{name}", t,
             f"vs_full={t / t_full:.2f}x;perturbed_B={pb};frac={frac:.3f}")
        records.append({
            "selection": "full" if sel is None else sel.spec,
            "us_per_step": t,
            "perturbed_bytes_per_step": int(pb),
            "total_param_bytes": int(total),
            "selected_fraction": frac,
            "params": int(tree_size(params)),
            "vs_full": t / t_full,
        })

    note(f"perturbed bytes/step: full={records[0]['perturbed_bytes_per_step']}"
         f" block_cyclic({BLOCK_K})="
         f"{records[1]['perturbed_bytes_per_step']} peft(lora)="
         f"{records[2]['perturbed_bytes_per_step']} (forward FLOPs equal — "
         "only the z read-modify-write traffic shrinks)")

    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as f:
        json.dump({"batch": BATCH, "seq": SEQ, "block_k": BLOCK_K,
                   "smoke": is_smoke(), "records": records}, f, indent=2)
    note(f"wrote {OUT_PATH}")


if __name__ == "__main__":
    run()
