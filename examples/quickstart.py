"""Quickstart: fine-tune a small LM with MeZO — two forward passes per step,
inference-grade memory — on a prompt-based classification task, and compare
against zero-shot and backprop-Adam FT (the paper's core comparison, scaled
to CPU).

    PYTHONPATH=src python examples/quickstart.py [--steps 600]
"""
import argparse

import jax

from repro import zo
from repro.data.synthetic import PromptClassification
from repro.models import bundle, transformer
from repro.models.config import ModelConfig
from repro.train.adam import Adam, AdamConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=600)
    ap.add_argument("--batch", type=int, default=32)
    args = ap.parse_args()

    cfg = ModelConfig(name="quickstart-lm", family="dense", n_layers=3,
                      d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
                      vocab_size=256, max_seq=64, dtype="float32")
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=0)
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    loss_fn = b.loss_fn()

    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits

    def accuracy(p):
        return task.eval_accuracy(cfg, logits_fn, p, jax.random.PRNGKey(99), 512)

    print(f"zero-shot accuracy: {accuracy(params):.3f}")

    # ---- MeZO: Algorithm 1, in-place via buffer donation ----------------- #
    # zo.mezo composes spsa(eps) with the scalar transform chain; swap in
    # zo.mezo_adam / zo.mezo_rescaled (or your own estimator) freely.
    opt = zo.mezo(lr=2e-4, eps=1e-3)
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    p = jax.tree_util.tree_map(lambda x: x.copy(), params)  # params donated
    for s in range(args.steps):
        batch = task.batch_for_step(s, args.batch)
        p, state, m = step(p, state, batch)
        if s % 100 == 0:
            print(f"  MeZO step {s:5d}  loss {float(m['loss']):.4f}  "
                  f"g {float(m['projected_grad']):+.3e}")
    print(f"MeZO accuracy after {args.steps} steps: {accuracy(p):.3f}")

    # ---- FT with Adam (needs grads + moments: the 12x-memory path) ------- #
    ft_steps = max(args.steps // 15, 20)
    adam = Adam(AdamConfig(lr=5e-3, total_steps=ft_steps))
    ast = adam.init(params)
    astep = jax.jit(adam.step_fn(loss_fn), donate_argnums=(0,))
    pf = params
    for s in range(ft_steps):
        pf, ast, m = astep(pf, ast, task.batch_for_step(s, args.batch))
    print(f"FT(Adam) accuracy after {ft_steps} steps: {accuracy(pf):.3f}")
    print("(paper: MeZO approaches FT with many more but far cheaper steps)")


if __name__ == "__main__":
    main()
