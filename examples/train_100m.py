"""End-to-end training driver: a ~100 M-parameter LM trained with MeZO for a
few hundred steps through the full production stack — resumable step-indexed
data pipeline, checkpoint manager, MeZO scalar ledger, crash recovery.

    PYTHONPATH=src python examples/train_100m.py --steps 300          # full
    PYTHONPATH=src python examples/train_100m.py --smoke              # tiny/CI

Kill it mid-run and re-invoke: it resumes bitwise-exactly from the last full
checkpoint + ledger tail (see tests/test_fault_tolerance.py for the proof).
"""
import argparse
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro import exec as zexec
from repro import select, zo
from repro.checkpoint.manager import CheckpointManager
from repro.core import TrajectoryLedger
from repro.data.pipeline import DataSpec, Pipeline
from repro.models import bundle
from repro.models.config import ModelConfig
from repro.train.loop import HeartbeatMonitor, train
from repro.tree_utils import tree_size


def _assert_frozen_rows(loss_fn, params, opt, sel, batch):
    """One probe step before training: everything the phase-0 selection does
    NOT cover must be bitwise-frozen (no perturbation residue, no update, no
    decay) — the frozen-row guarantee sub-leaf ``rows(...)`` selections make.
    Cheap (one jitted step on the initial params) and loud: a backend that
    wrote an unselected band would abort the run here, not corrupt it."""
    state = opt.init(params, seed=0)
    p1, _, _ = jax.jit(opt.step_fn(loss_fn))(params, state, batch)
    leaf_mask = sel.leaf_mask(params, 0)
    frozen = checked = 0
    for i, ((path, before), after) in enumerate(zip(
            jax.tree_util.tree_flatten_with_path(params)[0],
            jax.tree_util.tree_leaves(p1))):
        if not jnp.issubdtype(before.dtype, jnp.floating):
            continue
        b = np.asarray(before).reshape(-1)
        a = np.asarray(after).reshape(-1)
        if not leaf_mask[i]:                 # whole leaf inactive at phase 0
            m = np.zeros(b.size, bool)
        else:
            rb = sel.block_mask(before, phase=0)
            m = (np.ones(b.size, bool) if rb is None else
                 np.asarray(rb.element_mask(np.arange(b.size))).astype(bool))
        checked += 1
        if (~m).any():
            assert (a[~m] == b[~m]).all(), \
                f"unselected rows of {jax.tree_util.keystr(path)} moved"
            frozen += int((~m).sum())
    print(f"frozen-row probe: {frozen} unselected elements bitwise-frozen "
          f"across {checked} leaves at phase 0")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + few steps (CI)")
    ap.add_argument("--ckpt-dir", default="/tmp/mezo_100m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--exec-plan", default="local",
                    choices=["local", "seed_parallel"],
                    help="execution plan (repro.exec): seed_parallel "
                         "evaluates --n-groups seed groups on batch slices "
                         "at the step's center and averages the directions")
    ap.add_argument("--n-groups", type=int, default=1,
                    help="seed groups per step for --exec-plan seed_parallel")
    ap.add_argument("--select", default=None,
                    help="parameter selection spec (repro.select), e.g. "
                         "'block_cyclic(4)' or 'rows(block=256,k=4)' — "
                         "rows(...) perturbs ~1/k of every tensor per step "
                         "(sub-leaf tile skipping); recorded in the ledger "
                         "(MZOL5) and checkpoint meta")
    args = ap.parse_args()

    if args.smoke:
        cfg = ModelConfig(name="lm-smoke", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=512, max_seq=128, dtype="float32")
        args.steps = min(args.steps, 20)
    else:
        # ~100M params: 12L x d768 x ff3072, 16K vocab
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                          vocab_size=16384, max_seq=1024, dtype="float32")

    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {tree_size(params)/1e6:.1f} M params")

    pipe = Pipeline(DataSpec("lm", batch=args.batch, seq=args.seq,
                             vocab=cfg.vocab_size, seed=0))
    sel = select.resolve_selection(args.select)
    opt = zo.mezo(lr=1e-5, eps=1e-3, selection=sel)
    if sel is not None:
        bytes_ph0 = sel.selected_bytes(params, phase=0)
        total = sum(x.size * x.dtype.itemsize
                    for x in jax.tree_util.tree_leaves(params))
        print(f"selection {sel.spec}: {bytes_ph0/1e6:.2f} MB perturbed at "
              f"phase 0 ({bytes_ph0/total:.1%} of {total/1e6:.1f} MB)")
        _assert_frozen_rows(b.loss_fn(), params, opt, sel, pipe.batch(0))
    if args.exec_plan == "seed_parallel":
        # the engine lowers the same optimizer onto the sliced-batch plan;
        # checkpoints/ledger record (exec_plan, n_groups) and a resume under
        # a different n_groups refuses instead of re-pairing seeds
        opt = zexec.StepProgram(opt, zexec.seed_parallel(args.n_groups))
        print(f"exec plan: seed_parallel(n_groups={args.n_groups})")
    ckpt = CheckpointManager(args.ckpt_dir, interval=50, keep=2)
    ledger = TrajectoryLedger(base_seed=0, grad_dtype="float32")

    result = train(b.loss_fn(), params, opt, pipe, total_steps=args.steps,
                   ckpt=ckpt, ledger=ledger, monitor=HeartbeatMonitor(),
                   log_every=20, verbose=True)
    print(f"ran {result.steps_run} steps (resumed from {result.resumed_from})")
    print(f"loss trajectory: {[f'{l:.3f}' for _, l in result.losses[:8]]} ...")
    print(f"ledger: {len(ledger)} scalar entries = {ledger.nbytes()} bytes "
          f"(the entire run, replayable)")
    print(f"checkpoints in {args.ckpt_dir}: steps {ckpt.steps()}")


if __name__ == "__main__":
    main()
