"""End-to-end training driver: a ~100 M-parameter LM trained with MeZO for a
few hundred steps through the full production stack — resumable step-indexed
data pipeline, checkpoint manager, MeZO scalar ledger, crash recovery.

    PYTHONPATH=src python examples/train_100m.py --steps 300          # full
    PYTHONPATH=src python examples/train_100m.py --smoke              # tiny/CI

Kill it mid-run and re-invoke: it resumes bitwise-exactly from the last full
checkpoint + ledger tail (see tests/test_fault_tolerance.py for the proof).
"""
import argparse
import os

import jax

from repro import exec as zexec
from repro import zo
from repro.checkpoint.manager import CheckpointManager
from repro.core import TrajectoryLedger
from repro.data.pipeline import DataSpec, Pipeline
from repro.models import bundle
from repro.models.config import ModelConfig
from repro.train.loop import HeartbeatMonitor, train
from repro.tree_utils import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + few steps (CI)")
    ap.add_argument("--ckpt-dir", default="/tmp/mezo_100m")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--exec-plan", default="local",
                    choices=["local", "seed_parallel"],
                    help="execution plan (repro.exec): seed_parallel "
                         "evaluates --n-groups seed groups on batch slices "
                         "at the step's center and averages the directions")
    ap.add_argument("--n-groups", type=int, default=1,
                    help="seed groups per step for --exec-plan seed_parallel")
    args = ap.parse_args()

    if args.smoke:
        cfg = ModelConfig(name="lm-smoke", family="dense", n_layers=2,
                          d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                          vocab_size=512, max_seq=128, dtype="float32")
        args.steps = min(args.steps, 20)
    else:
        # ~100M params: 12L x d768 x ff3072, 16K vocab
        cfg = ModelConfig(name="lm-100m", family="dense", n_layers=12,
                          d_model=768, n_heads=12, n_kv_heads=4, d_ff=3072,
                          vocab_size=16384, max_seq=1024, dtype="float32")

    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    print(f"model {cfg.name}: {tree_size(params)/1e6:.1f} M params")

    pipe = Pipeline(DataSpec("lm", batch=args.batch, seq=args.seq,
                             vocab=cfg.vocab_size, seed=0))
    opt = zo.mezo(lr=1e-5, eps=1e-3)
    if args.exec_plan == "seed_parallel":
        # the engine lowers the same optimizer onto the sliced-batch plan;
        # checkpoints/ledger record (exec_plan, n_groups) and a resume under
        # a different n_groups refuses instead of re-pairing seeds
        opt = zexec.StepProgram(opt, zexec.seed_parallel(args.n_groups))
        print(f"exec plan: seed_parallel(n_groups={args.n_groups})")
    ckpt = CheckpointManager(args.ckpt_dir, interval=50, keep=2)
    ledger = TrajectoryLedger(base_seed=0, grad_dtype="float32")

    result = train(b.loss_fn(), params, opt, pipe, total_steps=args.steps,
                   ckpt=ckpt, ledger=ledger, monitor=HeartbeatMonitor(),
                   log_every=20, verbose=True)
    print(f"ran {result.steps_run} steps (resumed from {result.resumed_from})")
    print(f"loss trajectory: {[f'{l:.3f}' for _, l in result.losses[:8]]} ...")
    print(f"ledger: {len(ledger)} scalar entries = {ledger.nbytes()} bytes "
          f"(the entire run, replayable)")
    print(f"checkpoints in {args.ckpt_dir}: steps {ckpt.steps()}")


if __name__ == "__main__":
    main()
