"""Batched serving demo: continuous batching over slot-recycled KV caches,
driving a model whose "fine-tune" is a replayed MeZO seed-chain — the
storage story end to end (train -> 0.3 KB artifact -> serve).

    PYTHONPATH=src python examples/serve_batch.py
"""
import jax

from repro import zo
from repro.core import TrajectoryLedger, replay
from repro.data.synthetic import PromptClassification
from repro.models import bundle
from repro.models.config import ModelConfig
from repro.serve.engine import Request, ServeEngine


def main():
    cfg = ModelConfig(name="serve-lm", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      max_seq=128, dtype="float32")
    b = bundle(cfg)
    params0 = b.init(jax.random.PRNGKey(0))

    # --- "fine-tune" briefly, record ONLY the scalar ledger ---------------- #
    task = PromptClassification(vocab=cfg.vocab_size, seed=0)
    opt = zo.mezo(lr=2e-4, eps=1e-3)
    state = opt.init(params0, seed=0)
    ledger = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    step = jax.jit(opt.step_fn(b.loss_fn()))
    p = params0
    for s in range(60):
        p, state, m = step(p, state, task.batch_for_step(s, 16))
        ledger.append(s, float(m["projected_grad"]), float(m["lr"]))
    blob = ledger.to_bytes()
    print(f"fine-tuned 60 steps; checkpoint artifact = {len(blob)} bytes")

    # --- a 'serving node' reconstructs the tuned params from the blob ----- #
    led2 = TrajectoryLedger.from_bytes(blob)
    tuned = replay(params0, led2, opt)       # the optimizer IS the replayer

    engine = ServeEngine(cfg, tuned, slots=3, max_len=96)
    prompts = [[10, 20, 30], [40, 50], [60, 70, 80, 90], [11, 12], [13]]
    reqs = [Request(i, pr, max_new_tokens=8) for i, pr in enumerate(prompts)]
    for r in reqs:
        engine.submit(r)
    steps = 0
    while any(not r.done for r in reqs):
        engine.step()
        steps += 1
    for r in reqs:
        print(f"request {r.rid}: prompt {r.prompt_ids} -> {r.out_ids}")
    print(f"served {len(reqs)} requests on 3 slots in {steps} decode steps "
          f"(continuous batching)")


if __name__ == "__main__":
    main()
