"""Multi-tenant serving demo: N LoRA fine-tunes of ONE frozen base, each
persisted as nothing but its scalar trajectory ledger, served through a
single continuous-batching engine — the paper's §2.1 storage trick turned
into a serving story end to end:

    train N tenants -> N ledgers (~130 B each)
                    -> AdapterStore (content-hash keyed)
                    -> compact()    (delta + replayable tail)
                    -> DeltaCache   (byte-budgeted LRU; warm hits do ZERO
                                     replay folds)
                    -> one decode step batches requests from different
                       tenants (stacked LoRA deltas, vmap over slots)

    PYTHONPATH=src python examples/serve_batch.py
"""
import time

import jax

from repro.models import bundle
from repro.models.config import ModelConfig
from repro.serve.engine import ServeEngine
from repro.serve.tenants import (lora_runtime, make_lora_tenants, serve_load,
                                 synthetic_requests)

N_TENANTS = 6
N_REQUESTS = 18


def main():
    cfg = ModelConfig(name="serve-lm", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                      max_seq=128, dtype="float32")
    params0 = bundle(cfg).init(jax.random.PRNGKey(0))

    # --- N tenants fine-tune LoRA over the SAME frozen base --------------- #
    t0 = time.time()
    store = make_lora_tenants(cfg, params0, N_TENANTS, steps=8, batch=8)
    print(f"trained {len(store)} LoRA tenants in {time.time() - t0:.1f}s; "
          f"ALL their checkpoints together: {store.nbytes()} bytes")

    # --- a serving host: delta cache + compaction over the store ---------- #
    runtime = lora_runtime(cfg, params0, store, cache_bytes=32_000_000)
    for t in store.tenants():
        comp = runtime.compact_tenant(t, keep_tail=2)
    print(f"compacted every ledger to delta + {len(comp.tail)}-record tail "
          f"(cold materialization is O(tail), bitwise-equal to full replay)")

    # --- one engine serves a skewed mix across every tenant --------------- #
    engine = ServeEngine(cfg, params0, slots=3, max_len=96)
    tagged = synthetic_requests(N_REQUESTS, cfg.vocab_size, store.tenants(),
                                seed=0, max_new_tokens=8)
    t0 = time.time()
    rows = serve_load(engine, runtime, tagged)
    dt = time.time() - t0

    for tenant, req in tagged[:6]:
        print(f"  [{tenant}] req {req.rid}: {req.prompt_ids} -> {req.out_ids}")
    st = runtime.stats
    tokens = sum(r["n_out"] for r in rows)
    print(f"served {len(rows)} requests / {N_TENANTS} tenants / {tokens} "
          f"tokens on 3 slots in {dt:.2f}s — mixed-adapter decode batches "
          "different tenants in ONE step")
    print(f"cache: {st['hits']} hits / {st['misses']} misses "
          f"(hit rate {st['hit_rate']:.2f}); ledger records replayed: "
          f"{st['records_replayed']} (warm hits replay nothing)")


if __name__ == "__main__":
    main()
