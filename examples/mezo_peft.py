"""MeZO × PEFT (paper §3 / App. E.5): fine-tune ONLY a LoRA or prefix tree
with zeroth-order steps; the frozen base model is closed over.

Also demonstrates the paper's App. F.3 observation: MeZO's convergence rate
is roughly independent of the number of tuned parameters (full vs LoRA vs
prefix), supporting the effective-rank theory.

    PYTHONPATH=src python examples/mezo_peft.py
"""
import jax

from repro import zo
from repro.data.synthetic import PromptClassification
from repro.models import bundle, peft
from repro.models.config import ModelConfig
from repro.tree_utils import tree_size

STEPS = 500
BATCH = 32


def run_variant(name, loss_fn, tree0, lr, eps):
    opt = zo.mezo(lr=lr, eps=eps)
    state = opt.init(tree0, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    t = tree0
    losses = []
    for s in range(STEPS):
        t, state, m = step(t, state, task.batch_for_step(s, BATCH))
        if s % 50 == 0:
            losses.append(float(m["loss"]))
    print(f"{name:12s} params={tree_size(tree0):8d}  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return t


if __name__ == "__main__":
    cfg = ModelConfig(name="peft-lm", family="dense", n_layers=3, d_model=96,
                      n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=256,
                      max_seq=64, dtype="float32")
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=0)
    b = bundle(cfg)
    base = b.init(jax.random.PRNGKey(0))

    print("== MeZO full-parameter ==")
    run_variant("full", b.loss_fn(), base, lr=2e-4, eps=1e-3)

    print("== MeZO (LoRA r=8) ==")
    lora0 = peft.init_lora(cfg, jax.random.PRNGKey(1))
    run_variant("lora", peft.lora_loss_fn(cfg, base), lora0, lr=1e-3, eps=1e-3)

    print("== MeZO (prefix m=5, real-activation init) ==")
    pre0 = peft.init_prefix_from_tokens(cfg, base, jax.random.PRNGKey(2), m=5)
    run_variant("prefix", peft.prefix_loss_fn(cfg, base), pre0, lr=5e-3,
                eps=1e-1)
    print("(paper App. F.3: similar convergence despite 100-1000x fewer params)")
