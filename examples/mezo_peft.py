"""MeZO × PEFT on the unified selection path (paper §3 / App. E.5): the
frozen base and the PEFT tree ride in ONE merged parameter tree
(``peft.peft_params``) and a ``repro.select.peft(mode)`` selection scopes the
optimizer to the PEFT subtree — the base leaves are never perturbed, never
updated, never decayed (asserted below).  No tree-swap closures: full, LoRA,
prefix, and block-cyclic sparse runs all use the same optimizer surface.

Also demonstrates the paper's App. F.3 observation: MeZO's convergence rate
is roughly independent of the number of tuned parameters (full vs LoRA vs
prefix), supporting the effective-rank theory.

    PYTHONPATH=src python examples/mezo_peft.py
"""
import jax

from repro import select, zo
from repro.data.synthetic import PromptClassification
from repro.models import bundle, peft
from repro.models.config import ModelConfig
from repro.tree_utils import tree_max_abs_diff, tree_size

STEPS = 500
BATCH = 32


def run_variant(name, loss_fn, tree0, lr, eps, selection=None):
    opt = zo.mezo(lr=lr, eps=eps, selection=selection)
    state = opt.init(tree0, seed=0)
    step = jax.jit(opt.step_fn(loss_fn))
    t = tree0
    losses = []
    for s in range(STEPS):
        t, state, m = step(t, state, task.batch_for_step(s, BATCH))
        if s % 50 == 0:
            losses.append(float(m["loss"]))
    sel = opt.selection
    tuned = (tree_size(tree0) if sel is None
             else sel.selected_size(tree0))
    print(f"{name:12s} tuned={tuned:8d}/{tree_size(tree0):8d}  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")
    return t


if __name__ == "__main__":
    cfg = ModelConfig(name="peft-lm", family="dense", n_layers=3, d_model=96,
                      n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=256,
                      max_seq=64, dtype="float32")
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=0)
    b = bundle(cfg)
    base = b.init(jax.random.PRNGKey(0))

    print("== MeZO full-parameter ==")
    run_variant("full", b.loss_fn(), base, lr=2e-4, eps=1e-3)

    print("== MeZO block-cyclic(4): ~1/4 of the tree perturbed per step ==")
    run_variant("block_cyc4", b.loss_fn(), base, lr=2e-4, eps=1e-3,
                selection=select.block_cyclic(4))

    print("== MeZO (LoRA r=8, merged tree + peft selection) ==")
    lora0 = peft.init_lora(cfg, jax.random.PRNGKey(1))
    merged = peft.peft_params(base, lora0, "lora")
    out = run_variant("lora", peft.peft_loss_fn(cfg, "lora"), merged,
                      lr=1e-3, eps=1e-3, selection=select.peft("lora"))
    assert tree_max_abs_diff(out["base"], base) == 0.0, \
        "selection must leave the frozen base bitwise-untouched"

    print("== MeZO (prefix m=5, real-activation init, merged tree) ==")
    pre0 = peft.init_prefix_from_tokens(cfg, base, jax.random.PRNGKey(2), m=5)
    merged = peft.peft_params(base, pre0, "prefix")
    out = run_variant("prefix", peft.peft_loss_fn(cfg, "prefix"), merged,
                      lr=5e-3, eps=1e-1, selection=select.peft("prefix"))
    assert tree_max_abs_diff(out["base"], base) == 0.0
    print("(paper App. F.3: similar convergence despite 100-1000x fewer "
          "params; base tree bitwise-frozen by the selection)")
