"""Non-differentiable objectives (paper §3.3 / Table 3): MeZO directly
maximizes ACCURACY (argmax-based, zero gradient a.e.) and span-F1 — things
backpropagation cannot optimize.

    PYTHONPATH=src python examples/nondiff_accuracy.py
"""
import jax
import jax.numpy as jnp

from repro import zo
from repro.core.nondiff import negative_accuracy, negative_f1
from repro.data.synthetic import PromptClassification, SpanExtraction
from repro.models import bundle, transformer
from repro.models.config import ModelConfig

STEPS = 500
BATCH = 128   # accuracy is a step function: large batches + larger eps
              # make the +/- eps evaluations differ often enough to learn

cfg = ModelConfig(name="nd-lm", family="dense", n_layers=3, d_model=96,
                  n_heads=4, n_kv_heads=2, d_ff=192, vocab_size=256,
                  max_seq=64, dtype="float32")


def main():
    task = PromptClassification(vocab=cfg.vocab_size, n_classes=2, seed=0)
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    words = task.label_word(jnp.arange(task.n_classes))

    def logits_fn(p, batch):
        return transformer.forward(cfg, p, tokens=batch["tokens"]).logits

    def objective(p, batch):                       # -accuracy: a STEP function
        slot = logits_fn(p, batch)[:, task.body_len, :]
        return negative_accuracy(slot[:, words], batch["cls"])

    def accuracy(p):
        return task.eval_accuracy(cfg, logits_fn, p, jax.random.PRNGKey(9), 512)

    print(f"zero-shot accuracy: {accuracy(params):.3f}")
    print("optimizing ACCURACY directly (backprop would see zero gradient):")
    opt = zo.mezo(lr=5e-4, eps=2e-2)
    state = opt.init(params, seed=0)
    step = jax.jit(opt.step_fn(objective), donate_argnums=(0,))
    for s in range(STEPS):
        params, state, m = step(params, state, task.batch_for_step(s, BATCH))
        if s % 100 == 0:
            print(f"  step {s:5d}  batch-accuracy {-float(m['loss']):.3f}")
    print(f"final accuracy: {accuracy(params):.3f}")


if __name__ == "__main__":
    main()
