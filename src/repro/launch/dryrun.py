import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import: jax locks the device count on first init.
# The dry-run (and ONLY the dry-run) builds the 256/512-chip production
# meshes out of host-platform placeholder devices; smoke tests and benches
# see the normal single device.

"""Multi-pod dry-run: lower + compile every (architecture × shape × mesh)
cell and record memory/cost/collective analysis for the roofline report.

Usage:
  python -m repro.launch.dryrun                      # all cells, both meshes
  python -m repro.launch.dryrun --arch qwen2-7b --cell train_4k --mesh single
  python -m repro.launch.dryrun --set attention_impl=chunked --tag chunked
  python -m repro.launch.dryrun --ep-mesh --arch mixtral-8x7b   # EP hillclimb

Outputs one JSON line per case to results/dryrun.jsonl (append).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import flops as flops_lib
from repro.analysis import roofline as roofline_lib
from repro import exec as zexec
from repro import zo
from repro.distributed.sharding import (infer_batch_spec,
                                        make_activation_resolver,
                                        param_shardings)
from repro.launch.mesh import make_ep_mesh, make_production_mesh
from repro.models import all_archs, bundle, cells_for
from repro.models.common import shard_resolver
from repro.models.config import ALL_CELLS
from repro.models.rwkv6 import RWKVLayerState


def _ns(mesh, spec):
    return NamedSharding(mesh, spec)


def batch_sharding_tree(cfg, specs: dict, mesh):
    """Map the input_specs dict (incl. nested caches/states) to shardings."""
    out = {}
    for name, sds in specs.items():
        if name == "cache":
            out[name] = {
                "k": _ns(mesh, infer_batch_spec("cache_k", sds["k"].shape, mesh)),
                "v": _ns(mesh, infer_batch_spec("cache_v", sds["v"].shape, mesh)),
                "pos": _ns(mesh, infer_batch_spec("cache_pos_arr",
                                                  sds["pos"].shape, mesh)),
            }
        elif name == "cross_kv":
            out[name] = {
                "k": _ns(mesh, infer_batch_spec("cross_k", sds["k"].shape, mesh)),
                "v": _ns(mesh, infer_batch_spec("cross_v", sds["v"].shape, mesh)),
            }
        elif name == "state":
            if isinstance(sds, RWKVLayerState):
                out[name] = RWKVLayerState(
                    shift_tm=_ns(mesh, infer_batch_spec("rwkv_shift",
                                                        sds.shift_tm.shape, mesh)),
                    shift_cm=_ns(mesh, infer_batch_spec("rwkv_shift",
                                                        sds.shift_cm.shape, mesh)),
                    wkv=_ns(mesh, infer_batch_spec("rwkv_wkv", sds.wkv.shape, mesh)),
                )
            else:
                out[name] = _ns(mesh, infer_batch_spec("ssm_state", sds.shape, mesh))
        else:
            out[name] = _ns(mesh, infer_batch_spec(name, sds.shape, mesh))
    return out


def replicated_tree(tree, mesh):
    return jax.tree_util.tree_map(lambda _: _ns(mesh, P()), tree)


def _compile_case(cfg, b, cell, mesh, donate: bool = True,
                  backend: str = "xla", estimator: str = "spsa",
                  batch_seeds: int = 8, exec_plan: str = "local",
                  n_groups: int = 1, selection: str = "full"):
    """Lower + compile the cell's step function; returns the compiled exe."""
    specs = b.input_specs(cell)
    params_sds = b.param_shapes()
    pshard = param_shardings(params_sds, mesh)
    bshard = batch_sharding_tree(cfg, specs, mesh)
    resolver_p = make_activation_resolver(mesh, cfg)
    resolver = lambda logical, shape: (
        _ns(mesh, resolver_p(logical, shape))
        if resolver_p(logical, shape) is not None else None)

    if cell.kind == "train":
        if estimator == "fzoo":
            opt = zo.fzoo(lr=1e-6, eps=1e-3, batch_seeds=batch_seeds,
                          backend=backend, selection=selection)
        else:
            opt = zo.mezo(lr=1e-6, eps=1e-3, estimator=estimator,
                          backend=backend, selection=selection)
        # the engine lowers the same composition onto the requested plan;
        # the dry-run proves each (estimator × backend × plan) cell COMPILES
        # on the production meshes, not just the blessed local path
        plan = (zexec.seed_parallel(n_groups, mesh=mesh)
                if exec_plan == "seed_parallel" else zexec.local())
        prog = zexec.StepProgram(opt, plan)
        state_sds = jax.eval_shape(lambda: prog.init(seed=0))
        sshard = replicated_tree(state_sds, mesh)
        step = prog.step_fn(b.loss_fn())
        jitted = jax.jit(step, in_shardings=(pshard, sshard, bshard),
                         donate_argnums=(0,) if donate else ())
        args = (params_sds, state_sds, specs)
    elif cell.kind == "prefill":
        jitted = jax.jit(b.prefill_fn(), in_shardings=(pshard, bshard))
        args = (params_sds, specs)
    else:
        jitted = jax.jit(b.decode_fn(), in_shardings=(pshard, bshard),
                         donate_argnums=(1,) if donate else ())
        args = (params_sds, specs)

    with mesh:
        with shard_resolver(resolver):
            lowered = jitted.lower(*args)
    return lowered.compile()


def _cost_triple(compiled):
    """(flops, hbm_bytes, collective_bytes) per chip from a compiled exe."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    try:
        coll = roofline_lib.collective_stats(compiled.as_text())
    except Exception:
        coll = {"total_bytes": 0}
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            float(coll.get("total_bytes", 0)), coll)


def calibrate_loop_costs(arch, cell, mesh, overrides: dict):
    """XLA's cost analysis counts while-loop bodies ONCE, not × trip count.
    All sequential recurrences in this codebase are loop-free (chunked
    matmul + associative_scan), leaving exactly one loop: the scan over
    layers.  Compile UNROLLED 1- and 2-layer variants of the same cell —
    per-layer cost = f(2) − f(1) exactly (layers are homogeneous) — and
    return (outside, per_layer) triples for extrapolation to the real L."""
    cals = {}
    for L in (1, 2):
        over = dict(overrides)
        over.update(n_layers=L, scan_layers=False)
        if arch.cfg.family == "encdec":
            over["encoder_layers"] = L
        cfg_L = dataclasses.replace(arch.cfg, **over)
        compiled = _compile_case(cfg_L, bundle(cfg_L), cell, mesh, donate=False)
        cals[L] = _cost_triple(compiled)[:3]
    per_layer = tuple(cals[2][i] - cals[1][i] for i in range(3))
    outside = tuple(cals[1][i] - per_layer[i] for i in range(3))
    return outside, per_layer


def run_case(arch_id: str, cell, mesh, mesh_name: str, overrides: dict,
             optimizer: str = "mezo", verbose: bool = True,
             calibrate: bool = True, backend: str = "xla",
             estimator: str = "spsa", batch_seeds: int = 8,
             exec_plan: str = "local", n_groups: int = 1,
             selection: str = "full") -> dict:
    arch = all_archs()[arch_id]
    cfg = arch.cfg
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    b = bundle(cfg)
    chips = int(mesh.devices.size)
    rec = {"arch": arch_id, "cell": cell.name, "mesh": mesh_name,
           "chips": chips, "optimizer": optimizer,
           "perturb_backend": backend, "estimator": estimator,
           "batch_seeds": batch_seeds if estimator == "fzoo" else 1,
           "exec_plan": exec_plan,
           "n_groups": n_groups if exec_plan == "seed_parallel" else 1,
           "selection": selection,
           "overrides": {k: str(v) for k, v in overrides.items()},
           "status": "ok"}
    t0 = time.time()
    try:
        compiled = _compile_case(cfg, b, cell, mesh, backend=backend,
                                 estimator=estimator,
                                 batch_seeds=batch_seeds,
                                 exec_plan=exec_plan, n_groups=n_groups,
                                 selection=selection)
        t_compile = time.time() - t0
        flops_raw, hbm_raw, coll_raw, coll_detail = _cost_triple(compiled)
        rec["raw"] = {"flops": flops_raw, "hbm_bytes": hbm_raw,
                      "collective_bytes": coll_raw}

        # loop-trip correction via 1/2-layer unrolled calibration compiles
        flops, hbm, coll_b = flops_raw, hbm_raw, coll_raw
        if calibrate and cfg.scan_layers:
            t1 = time.time()
            outside, per_layer = calibrate_loop_costs(arch, cell, mesh,
                                                      overrides)
            L = cfg.n_layers
            flops = outside[0] + L * per_layer[0]
            hbm = outside[1] + L * per_layer[1]
            coll_b = outside[2] + L * per_layer[2]
            rec["calibration"] = {"outside": outside, "per_layer": per_layer,
                                  "calib_s": round(time.time() - t1, 2)}

        model_fl = flops_lib.model_flops(cfg, cell, optimizer)
        roof = roofline_lib.Roofline(
            arch=arch_id, cell=cell.name, mesh=mesh_name, chips=chips,
            flops_per_chip=flops, hbm_bytes_per_chip=hbm,
            link_bytes_per_chip=coll_b,
            model_flops=model_fl["model_flops"],
            model_flops_6nd=model_fl["model_flops_6nd"],
            collectives=coll_detail).finalize()
        rec.update(dataclasses.asdict(roof))
        rec["compile_s"] = round(t_compile, 2)
        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis_str"] = str(ma)[:2000] if ma is not None else None
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes"):
                if hasattr(ma, k):
                    rec.setdefault("memory_analysis", {})[k] = int(getattr(ma, k))
        except Exception as e:  # CPU backend may not support it
            rec["memory_analysis_str"] = f"unavailable: {e}"
        if verbose:
            print(f"[dryrun] {arch_id:22s} {cell.name:12s} {mesh_name:6s} "
                  f"OK  compile={t_compile:6.1f}s "
                  f"flops/chip={rec['flops_per_chip']:.3e} "
                  f"bottleneck={rec['bottleneck']:10s} "
                  f"roofline={rec['roofline_fraction']:.3f}", flush=True)
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        if verbose:
            print(f"[dryrun] {arch_id:22s} {cell.name:12s} {mesh_name:6s} "
                  f"FAIL {rec['error'][:200]}", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="arch id (default: all assigned)")
    ap.add_argument("--cell", default=None,
                    help="train_4k|prefill_32k|decode_32k|long_500k")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--ep-mesh", action="store_true",
                    help="use the expert-parallel mesh factorization (MoE)")
    ap.add_argument("--mesh-shape", default=None,
                    help="override data,model (e.g. 32,8) — same 256 chips, "
                         "different DP/TP factorization (hillclimb lever)")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (e.g. attention_impl=chunked)")
    ap.add_argument("--optimizer", default="mezo", choices=["mezo"])
    ap.add_argument("--estimator", default="spsa",
                    choices=["spsa", "one_point", "fzoo"],
                    help="gradient estimator for the train cells; 'fzoo' "
                         "compiles the batched-seed one-sided step "
                         "(--batch-seeds streams, one vmapped forward)")
    ap.add_argument("--batch-seeds", type=int, default=8,
                    help="seed streams per step for --estimator fzoo")
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="perturbation backend for the train cells")
    ap.add_argument("--exec-plan", default="local",
                    choices=["local", "seed_parallel"],
                    help="execution plan for the train cells (repro.exec)")
    ap.add_argument("--n-groups", type=int, default=2,
                    help="seed groups for --exec-plan seed_parallel")
    ap.add_argument("--select", default="full",
                    help="parameter selection for the train cells "
                         "(repro.select spec: full, leaves(<regex>), "
                         "block_cyclic(<k>), peft(lora|prefix), "
                         "moe_experts(<G>)) or 'auto' for the registry's "
                         "per-family default")
    ap.add_argument("--out", default="results/dryrun.jsonl")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    from repro.configs import ASSIGNED_ARCHS
    archs = [args.arch] if args.arch else ASSIGNED_ARCHS

    overrides = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        fields = {f.name: f.type for f in dataclasses.fields(
            all_archs()[archs[0]].cfg)}
        if v.isdigit():
            v = int(v)
        elif v in ("True", "False"):
            v = v == "True"
        else:
            try:
                v = float(v)
            except ValueError:
                pass
        overrides[k] = v

    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single", False))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi", True))

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for mesh_name, multi in meshes:
            for arch_id in archs:
                cfg = all_archs()[arch_id].cfg
                if args.ep_mesh:
                    mesh = make_ep_mesh(cfg.n_experts or 8, multi_pod=multi)
                    mesh_label = mesh_name + "-ep"
                elif args.mesh_shape:
                    d, m = (int(x) for x in args.mesh_shape.split(","))
                    mesh = jax.make_mesh((d, m), ("data", "model"))
                    mesh_label = f"{mesh_name}-{d}x{m}"
                else:
                    mesh = make_production_mesh(multi_pod=multi)
                    mesh_label = mesh_name
                cells = cells_for(cfg)
                if args.cell:
                    cells = [c for c in ALL_CELLS if c.name == args.cell]
                    if cells[0] not in cells_for(cfg):
                        print(f"[dryrun] {arch_id} {args.cell}: skipped "
                              f"(N/A per DESIGN.md §4)", flush=True)
                        continue
                selection = args.select
                if selection == "auto":
                    # registry per-family default (same hook as launch/train)
                    from repro.models import default_selection
                    selection = default_selection(
                        dataclasses.replace(cfg, **overrides)
                        if overrides else cfg)
                for cell in cells:
                    # the roofline table is single-pod; the multi-pod pass
                    # proves the 'pod' axis shards (compile success + memory)
                    rec = run_case(arch_id, cell, mesh, mesh_label, overrides,
                                   calibrate=(mesh_name == "single"),
                                   backend=args.backend,
                                   estimator=args.estimator,
                                   batch_seeds=args.batch_seeds,
                                   exec_plan=args.exec_plan,
                                   n_groups=args.n_groups,
                                   selection=selection)
                    if args.tag:
                        rec["tag"] = args.tag
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    n_ok += rec["status"] == "ok"
                    n_fail += rec["status"] != "ok"
    print(f"[dryrun] done: {n_ok} ok, {n_fail} failed", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
