"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Spins up the continuous-batching engine, optionally restoring fine-tuned
weights from either a tensor checkpoint or a MeZO scalar ledger (the 0.1 MB
deployment artifact), and runs a synthetic request workload.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro import zo
from repro.core import TrajectoryLedger, replay
from repro.models import all_archs, bundle
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--ledger", default=None,
                    help="MeZO ledger file: replay onto the init params")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = all_archs()[args.arch]
    cfg = arch.smoke_cfg if args.smoke else arch.cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(args.seed))
    if args.ledger and os.path.exists(args.ledger):
        with open(args.ledger, "rb") as f:
            led = TrajectoryLedger.from_bytes(f.read())
        # the ledger header records the run's full seed-schedule coordinates
        # (backend, batch_seeds, n_groups, selection); build the matching
        # composition — replay is ledger-driven, mismatches would raise
        sel = None
        if led.selection != "full" or led.sel_phase:
            from repro.select import parse_selection
            sel = parse_selection(led.selection)._replace(
                phase_offset=int(led.sel_phase))
        if led.batch_seeds > 1:
            opt = zo.fzoo(batch_seeds=led.batch_seeds, backend=led.backend,
                          selection=sel)
        else:
            opt = zo.mezo(backend=led.backend, selection=sel)
        params = replay(params, led, opt)
        print(f"[serve] replayed {len(led)} ledger steps "
              f"({os.path.getsize(args.ledger)} bytes, "
              f"backend={led.backend})")

    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                         seed=args.seed)
    key = jax.random.PRNGKey(args.seed)
    reqs = []
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        plen = int(jax.random.randint(k, (), 2, 9))
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 1, cfg.vocab_size - 1)]
        r = Request(i, prompt, max_new_tokens=args.new_tokens)
        reqs.append(r)
        engine.submit(r)

    t0 = time.time()
    steps = 0
    while any(not r.done for r in reqs):
        engine.step()
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(r.out_ids) for r in reqs)
    print(f"[serve] {len(reqs)} requests / {tokens} tokens in {steps} decode "
          f"steps, {dt:.2f}s ({tokens/dt:.1f} tok/s on this host)")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.prompt_ids} -> {r.out_ids}")


if __name__ == "__main__":
    main()
