"""Serving launcher: ``python -m repro.launch.serve --arch <id> --smoke``.

Spins up the continuous-batching engine, optionally restoring fine-tuned
weights from either a tensor checkpoint or a MeZO scalar ledger (the 0.1 MB
deployment artifact), and runs a synthetic request workload.

Multi-tenant mode (``--tenants N``): trains N synthetic peft(lora) fine-tunes
over the frozen base, registers their ledgers in an ``AdapterStore``, and
serves a skewed request mix across all of them through ONE engine —
materialized deltas ride a byte-budgeted LRU (``--cache-mb``) and long
ledgers can be folded to delta + tail (``--compact-tail``); see
``repro.serve.tenants``.
"""
from __future__ import annotations

import argparse
import os
import time

import jax

from repro.core import TrajectoryLedger, replay
from repro.models import all_archs, bundle
from repro.serve.engine import Request, ServeEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--ledger", default=None,
                    help="MeZO ledger file: replay onto the init params")
    ap.add_argument("--tenants", type=int, default=0,
                    help="serve N synthetic LoRA tenants over the frozen "
                         "base through one engine (0 = single-model mode)")
    ap.add_argument("--cache-mb", type=float, default=64.0,
                    help="delta-cache byte budget in MB (tenant mode)")
    ap.add_argument("--compact-every", type=int, default=0,
                    help="fold each tenant ledger to delta + an N-record "
                         "replayable tail before serving (0 = no compaction)")
    ap.add_argument("--tenant-steps", type=int, default=10,
                    help="fine-tune steps per synthetic tenant")
    ap.add_argument("--block", type=int, default=16,
                    help="paged KV block size in tokens (dense/moe engines)")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="KV pool size in blocks (default: 2x slot demand)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the radix prefix cache (paged pool stays)")
    ap.add_argument("--templates", type=int, default=0,
                    help="tenant mode: draw prompts from N shared task "
                         "templates per tenant (Zipf) instead of fully "
                         "random prompts — exercises the prefix cache")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    arch = all_archs()[args.arch]
    cfg = arch.smoke_cfg if args.smoke else arch.cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(args.seed))
    if args.ledger and os.path.exists(args.ledger):
        with open(args.ledger, "rb") as f:
            led = TrajectoryLedger.from_bytes(f.read())
        # the ledger header records the run's full seed-schedule coordinates
        # (backend, batch_seeds, n_groups, selection); rebuild the matching
        # composition — replay is ledger-driven, mismatches would raise
        from repro.serve.tenants import composition_for_ledger
        params = replay(params, led, composition_for_ledger(led))
        print(f"[serve] replayed {len(led)} ledger steps "
              f"({os.path.getsize(args.ledger)} bytes, "
              f"backend={led.backend})")

    engine = ServeEngine(cfg, params, slots=args.slots, max_len=args.max_len,
                         seed=args.seed, block=args.block,
                         pool_blocks=args.pool_blocks,
                         prefix_cache=not args.no_prefix_cache)
    if engine.paged:
        print(f"[serve] paged KV: block={args.block} tokens, "
              f"pool={engine.pool.n_blocks} blocks, prefix cache "
              f"{'off' if args.no_prefix_cache else 'on'}")

    if args.tenants > 0:
        from repro.serve.tenants import (lora_runtime, make_lora_tenants,
                                         serve_load, synthetic_requests,
                                         template_requests)
        t0 = time.time()
        store = make_lora_tenants(cfg, params, args.tenants,
                                  steps=args.tenant_steps,
                                  seed0=args.seed + 100)
        print(f"[serve] trained {len(store)} LoRA tenants in "
              f"{time.time() - t0:.1f}s; ledgers total {store.nbytes()} bytes")
        runtime = lora_runtime(cfg, params, store,
                               cache_bytes=int(args.cache_mb * 1e6))
        if args.compact_every > 0:
            for t in store.tenants():
                comp = runtime.compact_tenant(t, keep_tail=args.compact_every)
            print(f"[serve] compacted every ledger to delta + "
                  f"{args.compact_every}-record tail "
                  f"(last: {comp.nbytes} bytes)")
        if args.templates > 0:
            tagged = template_requests(
                args.requests, cfg.vocab_size, store.tenants(),
                n_templates=args.templates,
                template_len=min(48, args.max_len // 2), seed=args.seed,
                max_new_tokens=args.new_tokens)
        else:
            tagged = synthetic_requests(args.requests, cfg.vocab_size,
                                        store.tenants(), seed=args.seed,
                                        max_new_tokens=args.new_tokens)
        t0 = time.time()
        rows = serve_load(engine, runtime, tagged)
        dt = time.time() - t0
        tokens = sum(r["n_out"] for r in rows)
        ttfts = sorted(r["ttft_s"] for r in rows)
        st = runtime.stats
        print(f"[serve] {len(rows)} requests / {len(store)} tenants / "
              f"{tokens} tokens in {dt:.2f}s ({tokens / dt:.1f} tok/s)")
        print(f"[serve] cache hit rate {st.get('hit_rate', 0):.2f} "
              f"({st.get('hits', 0)} hits, {st.get('misses', 0)} misses, "
              f"{st.get('evictions', 0)} evictions); "
              f"{st['records_replayed']} ledger records replayed")
        print(f"[serve] TTFT p50 {ttfts[len(ttfts) // 2] * 1e3:.1f} ms / "
              f"p99 {ttfts[int(len(ttfts) * 0.99)] * 1e3:.1f} ms")
        ps = engine.prefix_stats()
        print(f"[serve] prefill: {ps['prefill_tokens_computed']}/"
              f"{ps['prefill_tokens_submitted']} tokens computed "
              f"({ps['token_reuse_rate']:.0%} reused), prefix hit rate "
              f"{ps['prefix_hit_rate']:.2f}, "
              f"{ps['prefill_batches']} prefill batches, "
              f"{ps['evicted_blocks']} blocks evicted")
        return

    key = jax.random.PRNGKey(args.seed)
    reqs = []
    for i in range(args.requests):
        k = jax.random.fold_in(key, i)
        plen = int(jax.random.randint(k, (), 2, 9))
        prompt = [int(t) for t in
                  jax.random.randint(k, (plen,), 1, cfg.vocab_size - 1)]
        r = Request(i, prompt, max_new_tokens=args.new_tokens)
        reqs.append(r)
        engine.submit(r)

    t0 = time.time()
    steps = 0
    while any(not r.done for r in reqs):
        engine.step()
        steps += 1
    dt = time.time() - t0
    tokens = sum(len(r.out_ids) for r in reqs)
    print(f"[serve] {len(reqs)} requests / {tokens} tokens in {steps} decode "
          f"steps, {dt:.2f}s ({tokens/dt:.1f} tok/s on this host)")
    if engine.paged:
        ps = engine.prefix_stats()
        print(f"[serve] prefill: {ps['prefill_tokens_computed']}/"
              f"{ps['prefill_tokens_submitted']} tokens computed, prefix "
              f"hit rate {ps['prefix_hit_rate']:.2f}")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {r.prompt_ids} -> {r.out_ids}")


if __name__ == "__main__":
    main()
