"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Single-host entry point wiring the full stack: registry model, MeZO (or
backprop-Adam baseline), step-indexed data, checkpoint manager + scalar
ledger, heartbeat.  On a real cluster each host runs this with
``jax.distributed.initialize`` handled by the scheduler; the step function
and data pipeline are already multi-host-safe (pure step-indexed batches,
pjit-ready shardings from repro.distributed).
"""
from __future__ import annotations

import argparse

import jax

from repro import exec as zexec
from repro import zo
from repro.checkpoint.manager import CheckpointManager
from repro.core import TrajectoryLedger
from repro.data.pipeline import DataSpec, Pipeline
from repro.models import FAMILY_ARCHS, OBJECTIVES, all_archs, bundle
from repro.train.adam import Adam, AdamConfig
from repro.train.loop import HeartbeatMonitor, train
from repro.tree_utils import tree_size


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--model-family", default=None,
                    choices=sorted(FAMILY_ARCHS),
                    help="architecture-family quickstart: picks the "
                         "representative registry arch for the family "
                         "(overrides --arch); e.g. --model-family moe "
                         "--select auto runs mixtral with expert-wise "
                         "selection")
    ap.add_argument("--optimizer", default="mezo",
                    choices=["mezo", "mezo-adam", "adam", "sgd"])
    ap.add_argument("--estimator", default="spsa",
                    choices=["spsa", "one_point", "fzoo"],
                    help="gradient estimator for --optimizer mezo; 'fzoo' is "
                         "the batched-seed one-sided estimator "
                         "(--batch-seeds streams per step, one vmapped "
                         "forward, loss-diff-std step normalization)")
    ap.add_argument("--batch-seeds", type=int, default=8,
                    help="seed streams per step for --estimator fzoo")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=None)
    ap.add_argument("--eps", type=float, default=1e-3)
    ap.add_argument("--backend", default="xla",
                    choices=["xla", "pallas", "pallas-interpret"],
                    help="perturbation backend (repro.perturb): xla threefry "
                         "or the VMEM-fused pallas kernel")
    ap.add_argument("--select", default="full",
                    help="parameter selection (repro.select) for the ZO "
                         "optimizers: 'full', 'leaves(<regex>)', "
                         "'block_cyclic(<k>)' (rotating leaf blocks, ~1/k of "
                         "the tree perturbed per step), "
                         "'peft(lora|prefix)' for a merged PEFT tree, "
                         "'moe_experts(<G>)' (router frozen, one expert "
                         "group per step; needs --expert-groups G), or "
                         "'auto' for the registry's per-family default; "
                         "recorded in ckpt meta + the MZOL5 ledger header")
    ap.add_argument("--objective", default="ce", choices=list(OBJECTIVES),
                    help="training objective: 'ce' (cross-entropy) or the "
                         "non-differentiable 'accuracy'/'f1' metrics (paper "
                         "§3.3) — zero gradient a.e., so they require a ZO "
                         "optimizer (--optimizer mezo)")
    ap.add_argument("--expert-groups", type=int, default=None,
                    help="MoE only: split the expert tensors into G leaf "
                         "groups (cfg.expert_groups) so moe_experts(G) "
                         "selection can cycle one group per step")
    ap.add_argument("--scan-mode", default=None,
                    choices=["chunk", "fused_recurrent"],
                    help="ssm/hybrid forward mode: 'chunk' (chunked-matmul, "
                         "default) or 'fused_recurrent' (exact per-token "
                         "recurrence; parity-tested oracle)")
    ap.add_argument("--exec-plan", default="local",
                    choices=["local", "seed_parallel"],
                    help="execution plan (repro.exec): 'local' is the "
                         "jit+donate loop step; 'seed_parallel' splits the "
                         "batch into --n-groups slices, evaluates seed "
                         "group g on slice g at the step's center, and "
                         "averages the n rank-1 directions (cross-device "
                         "traffic: loss scalars only)")
    ap.add_argument("--n-groups", type=int, default=1,
                    help="seed groups per step for --exec-plan seed_parallel")
    ap.add_argument("--seed-parallel", type=int, default=None,
                    help="DEPRECATED alias for "
                         "--exec-plan seed_parallel --n-groups N")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="use the arch's reduced smoke config")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-interval", type=int, default=100)
    args = ap.parse_args()

    if args.model_family is not None:
        args.arch = FAMILY_ARCHS[args.model_family]
    arch = all_archs()[args.arch]
    cfg = arch.smoke_cfg if args.smoke else arch.cfg
    if args.expert_groups is not None:
        if not cfg.n_experts:
            raise SystemExit(f"--expert-groups needs an MoE arch "
                             f"(got {args.arch!r}, family {cfg.family!r})")
        cfg = cfg.replace(expert_groups=args.expert_groups)
    if args.scan_mode is not None:
        cfg = cfg.replace(scan_mode=args.scan_mode)
    b = bundle(cfg)
    if args.select == "auto":
        # the registry's per-family default (MoE: expert-wise cycling with
        # the router frozen; everything else: full)
        args.select = b.default_selection()
        print(f"[train] --select auto -> {args.select!r}")
    params = b.init(jax.random.PRNGKey(args.seed))
    print(f"[train] {cfg.name}: {tree_size(params)/1e6:.1f} M params, "
          f"optimizer={args.optimizer}")

    pipe = Pipeline(DataSpec("lm", batch=args.batch, seq=args.seq,
                             vocab=cfg.vocab_size, seed=args.seed))
    if args.objective != "ce" and args.optimizer not in ("mezo", "mezo-adam"):
        # argmax metrics have zero gradient a.e. — backprop would "train"
        # without ever changing the loss; refuse instead of silently stalling
        raise SystemExit(f"--objective {args.objective!r} is "
                         "non-differentiable and needs a ZO optimizer "
                         f"(--optimizer mezo); got {args.optimizer!r}")
    if args.select != "full" and args.optimizer != "mezo":
        # fail loudly: every other branch would silently train the full tree
        # (adam/sgd have no selection support; mezo-adam's applier transform
        # refuses selections at composition time)
        raise SystemExit(f"--select {args.select!r} requires --optimizer mezo "
                         f"(got {args.optimizer!r})")
    ledger = None
    if args.optimizer == "mezo":
        if args.estimator == "fzoo":
            opt = zo.fzoo(lr=args.lr or 1e-6, eps=args.eps,
                          batch_seeds=args.batch_seeds, backend=args.backend,
                          selection=args.select)
        else:
            opt = zo.mezo(lr=args.lr or 1e-5, eps=args.eps,
                          estimator=args.estimator, backend=args.backend,
                          selection=args.select)
        if args.select != "full":
            print(f"[train] parameter selection: {opt.selection_spec}")
        ledger = TrajectoryLedger(base_seed=args.seed, grad_dtype="float32",
                                  backend=opt.backend_name,
                                  batch_seeds=opt.batch_seeds,
                                  selection=opt.selection_spec,
                                  sel_phase=opt.selection_phase)
    elif args.optimizer == "mezo-adam":
        opt = zo.mezo_adam(lr=args.lr or 1e-4, eps=args.eps,
                           backend=args.backend)
    elif args.optimizer == "adam":
        opt = Adam(AdamConfig(lr=args.lr or 1e-4, total_steps=args.steps))
    else:
        opt = Adam(AdamConfig(lr=args.lr or 1e-3, sgd=True,
                              total_steps=args.steps))

    if args.seed_parallel is not None:       # deprecated alias
        print("[train] --seed-parallel is deprecated; use "
              "--exec-plan seed_parallel --n-groups N")
        args.exec_plan, args.n_groups = "seed_parallel", args.seed_parallel
    if args.exec_plan == "seed_parallel":
        if args.optimizer != "mezo":
            raise SystemExit("--exec-plan seed_parallel needs a "
                             "seed-replayable ZO optimizer (--optimizer mezo,"
                             " any --estimator)")
        if args.batch % args.n_groups:
            raise SystemExit(f"--batch {args.batch} must divide evenly into "
                             f"--n-groups {args.n_groups} slices")
        opt = zexec.StepProgram(opt, zexec.seed_parallel(args.n_groups))
        print(f"[train] exec plan: seed_parallel(n_groups={args.n_groups})")

    ckpt = (CheckpointManager(args.ckpt_dir, interval=args.ckpt_interval)
            if args.ckpt_dir else None)
    res = train(b.loss_fn(objective=args.objective), params, opt, pipe,
                total_steps=args.steps,
                ckpt=ckpt, ledger=ledger, monitor=HeartbeatMonitor(),
                log_every=max(args.steps // 10, 1), verbose=True,
                seed=args.seed)
    print(f"[train] done: {res.steps_run} steps "
          f"(resumed from {res.resumed_from}); "
          f"final loss {res.losses[-1][1]:.4f}")
    if ledger is not None:
        print(f"[train] ledger: {len(ledger)} entries, "
              f"{ledger.nbytes()} bytes")


if __name__ == "__main__":
    main()
