"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — required because the
dry-run forces 512 host-platform devices while tests/benches must see 1.

Mesh axes:
  single-pod : (data=16, model=16)          — 256 chips (one v5e pod)
  multi-pod  : (pod=2, data=16, model=16)   — 512 chips across 2 pods

'model' is the tensor-parallel axis (intra-pod, ICI-local); 'data' (and
'pod') carry pure data parallelism.  Under MeZO the cross-'pod' traffic is
two f32 scalars per step — see DESIGN.md §5.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_elastic_mesh(n_devices: int | None = None, model_parallel: int | None = None):
    """Derive a mesh from whatever devices are alive (elastic scaling /
    degraded restart).  Chooses the largest model axis that divides the
    device count, capped at ``model_parallel`` (default 16)."""
    n = n_devices if n_devices is not None else len(jax.devices())
    cap = model_parallel or 16
    model = 1
    for m in range(min(cap, n), 0, -1):
        if n % m == 0:
            model = m
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_ep_mesh(n_experts: int, *, multi_pod: bool = False):
    """Expert-parallel mesh refactorization used by the MoE hillclimb: the 16
    'model' ways are split into (expert, tp) with expert | n_experts.  Device
    count is unchanged (256 / 512); only the logical factorization differs."""
    ep = 1
    for cand in (16, 8, 4, 2):
        if n_experts % cand == 0 and 16 % cand == 0:
            ep = cand
            break
    tp = 16 // ep
    if multi_pod:
        return jax.make_mesh((2, 16, ep, tp), ("pod", "data", "expert", "model"))
    return jax.make_mesh((16, ep, tp), ("data", "expert", "model"))
