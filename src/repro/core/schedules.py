"""Learning-rate / perturbation / sample-count schedules.

The paper uses a constant lr for MeZO (App. E.3) and linear decay for FT; the
n-SPSA sample schedules (constant / linear, App. A.2) are exposed for the
Table-6 reproduction benchmark.
"""
from __future__ import annotations

import jax.numpy as jnp


def lr_at(name: str, base_lr: float, step, total_steps: int = 0,
          warmup_steps: int = 0):
    step = jnp.asarray(step, jnp.float32)
    lr = jnp.float32(base_lr)
    if name == "constant":
        out = lr
    elif name == "linear":
        t = jnp.clip(step / jnp.maximum(total_steps, 1), 0.0, 1.0)
        out = lr * (1.0 - t)
    elif name == "cosine":
        t = jnp.clip(step / jnp.maximum(total_steps, 1), 0.0, 1.0)
        out = 0.5 * lr * (1.0 + jnp.cos(jnp.pi * t))
    else:
        raise ValueError(f"unknown lr schedule {name!r}")
    if warmup_steps > 0:
        warm = jnp.clip((step + 1.0) / warmup_steps, 0.0, 1.0)
        out = out * warm
    return out


def n_spsa_at(name: str, base_n: int, step, total_steps: int = 0) -> int:
    """Sample-count schedule for n-SPSA (paper App. A.2).  Python-level (the
    step function is retraced when n changes — n changes are rare)."""
    if name == "constant":
        return base_n
    if name == "linear":
        frac = min(max(step / max(total_steps, 1), 0.0), 1.0)
        return max(1, int(round(base_n * frac)))
    raise ValueError(f"unknown n schedule {name!r}")
