"""Non-differentiable objectives for MeZO (paper §3.3, Table 3).

ZO needs only function *values*, so the "loss" may be any scalar metric.
These objectives are deliberately built from argmax / comparisons — they have
zero gradient a.e. and backprop cannot optimize them; MeZO can.

All functions return a MINIMIZATION objective (negated metric).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def negative_accuracy(logits: jnp.ndarray, labels: jnp.ndarray,
                      mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """−accuracy of argmax predictions.  logits (..., C), labels (...)."""
    pred = jnp.argmax(logits, axis=-1)
    correct = (pred == labels).astype(jnp.float32)
    if mask is not None:
        m = mask.astype(jnp.float32)
        return -jnp.sum(correct * m) / jnp.maximum(jnp.sum(m), 1.0)
    return -jnp.mean(correct)


def token_f1(pred_ids: jnp.ndarray, gold_ids: jnp.ndarray,
             pad_id: int = 0) -> jnp.ndarray:
    """Bag-of-tokens F1 between a predicted and a gold id sequence (the SQuAD
    metric applied at the token level, vectorized / sort-free).

    pred_ids, gold_ids: (B, T) int32 with pad_id padding.
    """
    def pair_f1(p, g):
        pm = (p != pad_id)
        gm = (g != pad_id)
        # overlap = Σ_tokens min(count_pred, count_gold); computed via a
        # pairwise-equality matrix with double-count correction.
        eq = (p[:, None] == g[None, :]) & pm[:, None] & gm[None, :]
        # Greedy matching bound: min(row sums, col sums) summed is an upper
        # bound; exact multiset overlap = Σ_v min(c_p(v), c_g(v)).  Compute
        # exactly with a vocabulary-free trick: for each pred position, count
        # its matches among gold and among earlier equal preds.
        p_eq_p = (p[:, None] == p[None, :]) & pm[:, None] & pm[None, :]
        rank_p = jnp.sum(jnp.tril(p_eq_p, -1), axis=1)        # occurrence index
        gold_count = jnp.sum(eq, axis=1)                      # count in gold
        matched = (rank_p < gold_count) & pm
        overlap = jnp.sum(matched.astype(jnp.float32))
        n_p = jnp.sum(pm.astype(jnp.float32))
        n_g = jnp.sum(gm.astype(jnp.float32))
        prec = overlap / jnp.maximum(n_p, 1.0)
        rec = overlap / jnp.maximum(n_g, 1.0)
        return jnp.where(overlap > 0, 2 * prec * rec / jnp.maximum(prec + rec, 1e-9), 0.0)

    return jnp.mean(jax.vmap(pair_f1)(pred_ids, gold_ids))


def negative_f1(pred_ids: jnp.ndarray, gold_ids: jnp.ndarray,
                pad_id: int = 0) -> jnp.ndarray:
    return -token_f1(pred_ids, gold_ids, pad_id)


def make_accuracy_objective(apply_fn: Callable, label_positions=None) -> Callable:
    """Wrap a model ``apply_fn(params, batch) -> logits`` into a
    −accuracy objective over ``batch['labels']``."""
    def objective(params, batch):
        logits = apply_fn(params, batch)
        mask = batch.get("loss_mask") if isinstance(batch, dict) else None
        return negative_accuracy(logits, batch["labels"], mask)
    return objective


def make_f1_objective(greedy_decode_fn: Callable, pad_id: int = 0) -> Callable:
    """Wrap a greedy decoder ``(params, batch) -> pred_ids`` into −F1 against
    ``batch['gold_ids']`` (paper's SQuAD-F1 setup, App. E.6)."""
    def objective(params, batch):
        pred = greedy_decode_fn(params, batch)
        return negative_f1(pred, batch["gold_ids"], pad_id)
    return objective
