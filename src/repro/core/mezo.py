"""MeZO: memory-efficient ZO-SGD (paper Algorithm 1).

.. deprecated::
    ``MeZO`` is a thin shim over the composable API in :mod:`repro.zo` —
    ``zo.mezo(lr=..., eps=...)`` builds the identical optimizer (bitwise-equal
    steps, enforced by tests/test_zo_api.py) as::

        ZOOptimizer(estimators.spsa(eps),
                    chain(clip_projected_grad?, scale_by_schedule(lr),
                          add_weight_decay(λ)))

    New code should use ``repro.zo`` directly; new estimators and update
    rules plug in as components there instead of new optimizer classes.

Usage (unchanged):
    opt = MeZO(MeZOConfig(lr=1e-6, eps=1e-3))
    state = opt.init(seed=0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    params, state, metrics = step(params, state, batch)

Design notes (now implemented by ``repro.zo.estimators.spsa``)
--------------------------------------------------------------
* The *whole step* is one jitted function with ``params`` donated: XLA reuses
  the parameter buffers across the perturb/forward/perturb/forward/update
  chain, so the live set is params + one forward pass — the paper's
  inference-memory property, expressed through buffer donation rather than
  Python-level in-place mutation.
* z is regenerated from ``(base_key, step, leaf_idx)`` at each use: 3 tree
  passes per step (the paper's Algorithm 1 uses 4 — we fuse its reset and
  descent loops into one, see ``perturb.fused_restore_update``).
* The projected gradient is a *scalar*; under data parallelism the only
  cross-replica communication is the mean of ℓ± over the batch — already
  performed by the loss reduction itself.
* ``n > 1`` runs n-SPSA sequentially (Algorithm 2).  The seed-parallel
  variant lives in ``repro.distributed.collectives``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import schedules
from repro.core.perturb import Distribution
from repro.tree_utils import PyTree
from repro.zo.base import ZOOptimizer, ZOState
from repro.zo.presets import mezo as _mezo_preset
from repro.zo.updates import apply_rank1


@dataclasses.dataclass(frozen=True)
class MeZOConfig:
    lr: float = 1e-6
    eps: float = 1e-3
    n: int = 1                          # n-SPSA samples per step
    dist: Distribution = "gaussian"
    weight_decay: float = 0.0
    estimator: str = "spsa"             # "spsa" | "one_point"
    lr_schedule: str = "constant"       # see core/schedules.py
    total_steps: int = 0                # required by decaying schedules
    warmup_steps: int = 0
    sequential_perturb: bool = True     # paper-faithful in-place chain
    clip_projected_grad: float = 0.0    # 0 = off; else |g| clamp (stability)

    def lr_at(self, step) -> jnp.ndarray:
        return schedules.lr_at(self.lr_schedule, self.lr, step,
                               self.total_steps, self.warmup_steps)


# Uniform optimizer state (deprecated alias — kept for old imports; the
# estimator/transform carries replaced the one-point-specific field).
MeZOState = ZOState


class MeZO(ZOOptimizer):
    """Deprecated shim: ZO-SGD as the ``repro.zo`` composition above."""

    def __init__(self, config: MeZOConfig):
        self.config = config
        composed = _mezo_preset(
            lr=config.lr, eps=config.eps, n=config.n, dist=config.dist,
            weight_decay=config.weight_decay, estimator=config.estimator,
            lr_schedule=config.lr_schedule, total_steps=config.total_steps,
            warmup_steps=config.warmup_steps,
            sequential_perturb=config.sequential_perturb,
            clip_projected_grad=config.clip_projected_grad)
        super().__init__(composed.estimator, composed.transform, name="mezo")

    def init(self, seed_or_params=0, *, seed: Optional[int] = None,
             params: Optional[PyTree] = None) -> ZOState:
        """Accepts both the legacy form ``init(seed)`` and the protocol form
        ``init(params, seed=...)`` (ints are seeds, pytrees are params)."""
        if seed is None and isinstance(seed_or_params, (int, np.integer)):
            return ZOOptimizer.init(self, params, seed=int(seed_or_params))
        if not isinstance(seed_or_params, (int, np.integer)):
            params = seed_or_params
        return ZOOptimizer.init(self, params, seed=int(seed or 0))


def apply_projected_update(params: PyTree, skey: jax.Array, projected_grad,
                           lr, weight_decay: float = 0.0,
                           dist: Distribution = "gaussian",
                           d_tree: Optional[PyTree] = None) -> PyTree:
    """θ ← (1 − η·λ)·θ − η·g·z(skey)   (Algorithm 1's descent loop).

    Deprecated alias for :func:`repro.zo.updates.apply_rank1` with the
    (g, η) factorization pre-multiplied; kept because the trajectory
    replayer, async path, and tests address updates as (seed, g, lr) triples.
    ``d_tree`` rescales z per-leaf (Definitions 6/7).
    """
    return apply_rank1(params, skey, lr * projected_grad, lr * weight_decay,
                       dist, d_tree=d_tree)
