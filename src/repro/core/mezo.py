"""MeZO: memory-efficient ZO-SGD (paper Algorithm 1), as a pure-JAX step.

Usage:
    opt = MeZO(MeZOConfig(lr=1e-6, eps=1e-3))
    state = opt.init(seed=0)
    step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    params, state, metrics = step(params, state, batch)

Design notes
------------
* The *whole step* is one jitted function with ``params`` donated: XLA reuses
  the parameter buffers across the perturb/forward/perturb/forward/update
  chain, so the live set is params + one forward pass — the paper's
  inference-memory property, expressed through buffer donation rather than
  Python-level in-place mutation.
* z is regenerated from ``(base_key, step, leaf_idx)`` at each use: 3 tree
  passes per step (the paper's Algorithm 1 uses 4 — we fuse its reset and
  descent loops into one, see ``perturb.fused_restore_update``).
* The projected gradient is a *scalar*; under data parallelism the only
  cross-replica communication is the mean of ℓ± over the batch — already
  performed by the loss reduction itself, so a sharded-batch MeZO step
  all-reduces exactly two partial scalars per seed.
* ``n > 1`` runs n-SPSA sequentially (Algorithm 2).  The seed-parallel
  variant lives in ``repro.distributed.collectives``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core import schedules
from repro.core.perturb import (Distribution, fused_restore_update, leaf_key,
                                perturb, sample_leaf_z, step_key)
from repro.core.spsa import (LossFn, OnePointState, one_point_init,
                             one_point_projected_grad)
from repro.tree_utils import PyTree, tree_map_with_index


@dataclasses.dataclass(frozen=True)
class MeZOConfig:
    lr: float = 1e-6
    eps: float = 1e-3
    n: int = 1                          # n-SPSA samples per step
    dist: Distribution = "gaussian"
    weight_decay: float = 0.0
    estimator: str = "spsa"             # "spsa" | "one_point"
    lr_schedule: str = "constant"       # see core/schedules.py
    total_steps: int = 0                # required by decaying schedules
    warmup_steps: int = 0
    sequential_perturb: bool = True     # paper-faithful in-place chain
    clip_projected_grad: float = 0.0    # 0 = off; else |g| clamp (stability)

    def lr_at(self, step) -> jnp.ndarray:
        return schedules.lr_at(self.lr_schedule, self.lr, step,
                               self.total_steps, self.warmup_steps)


class MeZOState(NamedTuple):
    step: jnp.ndarray                 # int32 scalar
    base_key: jax.Array               # the single run seed (paper §2.1)
    one_point: OnePointState          # only used when estimator == one_point
    last_projected_grad: jnp.ndarray  # for the trajectory ledger / logging


class MeZO:
    """ZO-SGD with in-place seed-replay perturbations (paper Algorithm 1)."""

    def __init__(self, config: MeZOConfig):
        self.config = config

    def init(self, seed: int = 0) -> MeZOState:
        return MeZOState(
            step=jnp.int32(0),
            base_key=jax.random.PRNGKey(seed),
            one_point=one_point_init(),
            last_projected_grad=jnp.float32(0.0),
        )

    def _one_seed(self, loss_fn: LossFn, params: PyTree, batch, skey: jax.Array,
                  lr_eff, weight_decay_eff) -> tuple[PyTree, jnp.ndarray, jnp.ndarray]:
        """One SPSA seed: perturb → ℓ+ → perturb → ℓ− → fused restore+update.

        Written as a single sequential chain over ONE live parameter tree so
        that, with the step's ``donate_argnums``, XLA keeps exactly one
        parameter-sized buffer alive (the paper's in-place property).
        """
        c = self.config
        if c.sequential_perturb:
            p_plus = perturb(params, skey, c.eps, c.dist)
            l_plus = loss_fn(p_plus, batch)
            p_minus = perturb(p_plus, skey, -2.0 * c.eps, c.dist)
            l_minus = loss_fn(p_minus, batch)
            g = (l_plus - l_minus) / (2.0 * c.eps)
            if c.clip_projected_grad > 0:
                g = jnp.clip(g, -c.clip_projected_grad, c.clip_projected_grad)
            new_params = fused_restore_update(p_minus, skey, c.eps, lr_eff * g,
                                              weight_decay=weight_decay_eff,
                                              dist=c.dist)
        else:
            l_plus = loss_fn(perturb(params, skey, c.eps, c.dist), batch)
            l_minus = loss_fn(perturb(params, skey, -c.eps, c.dist), batch)
            g = (l_plus - l_minus) / (2.0 * c.eps)
            if c.clip_projected_grad > 0:
                g = jnp.clip(g, -c.clip_projected_grad, c.clip_projected_grad)
            new_params = apply_projected_update(params, skey, g, lr_eff,
                                                weight_decay_eff, c.dist)
        return new_params, g, 0.5 * (l_plus + l_minus)

    def step_fn(self, loss_fn: LossFn) -> Callable[[PyTree, MeZOState, Any],
                                                   tuple[PyTree, MeZOState, dict]]:
        c = self.config

        def step(params: PyTree, state: MeZOState, batch):
            skey0 = step_key(state.base_key, state.step)
            lr = c.lr_at(state.step)

            if c.estimator == "one_point":
                g, l_pert, op_state = one_point_projected_grad(
                    loss_fn, params, batch, skey0, c.eps, state.one_point, c.dist)
                if c.clip_projected_grad > 0:
                    g = jnp.clip(g, -c.clip_projected_grad, c.clip_projected_grad)
                new_params = apply_projected_update(params, skey0, g, lr,
                                                    c.weight_decay, c.dist)
                new_state = MeZOState(state.step + 1, state.base_key, op_state, g)
                return new_params, new_state, {"loss": l_pert,
                                               "projected_grad": g, "lr": lr}

            # n-SPSA, sequential over seeds (Algorithm 2); n == 1 is the
            # paper default.  lr/n per seed; weight decay applied once.
            p = params
            gs, losses = [], []
            for j in range(c.n):
                skey = jax.random.fold_in(skey0, j) if c.n > 1 else skey0
                wd = c.weight_decay if j == 0 else 0.0
                p, g, loss = self._one_seed(loss_fn, p, batch, skey,
                                            lr / c.n, lr * wd)
                gs.append(g)
                losses.append(loss)

            g_mean = jnp.mean(jnp.stack(gs))
            loss = jnp.mean(jnp.stack(losses))
            new_state = MeZOState(state.step + 1, state.base_key,
                                  state.one_point, g_mean)
            return p, new_state, {"loss": loss, "projected_grad": g_mean,
                                  "lr": lr}

        return step


def apply_projected_update(params: PyTree, skey: jax.Array, projected_grad,
                           lr, weight_decay: float = 0.0,
                           dist: Distribution = "gaussian",
                           d_tree: Optional[PyTree] = None) -> PyTree:
    """θ ← (1 − η·λ)·θ − η·g·z(skey)   (Algorithm 1's descent loop).

    Shared by: the center-perturb step variant, the one-point estimator, the
    trajectory replayer (``core.trajectory``), and the async/straggler path
    (``distributed.async_zo``) — all of which apply updates from ``(seed, g)``
    scalars alone.  ``d_tree`` rescales z per-leaf (Definitions 6/7).
    """
    d_leaves = jax.tree_util.tree_leaves(d_tree) if d_tree is not None else None

    def one(i, p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        z = sample_leaf_z(leaf_key(skey, i), p, dist)
        if d_leaves is not None:
            z = z * jnp.asarray(d_leaves[i], p.dtype)
        step_ = jnp.asarray(lr * projected_grad, p.dtype)
        decay = jnp.asarray(1.0 - lr * weight_decay, p.dtype)
        return decay * p - step_ * z

    return tree_map_with_index(one, params)
