"""Compatibility shim — the seeded-perturbation machinery moved to
``repro.perturb`` (the pluggable backend layer).

This module re-exports the threefry (``xla`` backend) primitives so legacy
imports keep working; new code should go through ``repro.perturb``:

    from repro.perturb import StreamRef, get_backend
    backend = get_backend("xla")          # or "pallas" — VMEM z generation
    p_plus = backend.perturb(params, StreamRef(key), eps)

Everything here is the *same object* as in ``repro.perturb.xla`` (moved, not
copied), so arithmetic — and therefore every existing ledger and checkpoint —
is bit-identical.
"""
from __future__ import annotations

from repro.perturb.stream import step_key
from repro.perturb.xla import (Distribution, fused_restore_update, leaf_key,
                               perturb, perturb_jit, sample_leaf_z,
                               sample_z_tree, _sphere_scale)

__all__ = [
    "Distribution", "fused_restore_update", "leaf_key", "perturb",
    "perturb_jit", "sample_leaf_z", "sample_z_tree", "step_key",
    "_sphere_scale",
]
