"""Trajectory ledger: the paper's §2.1 storage trick, promoted to a
first-class checkpoint/recovery mechanism.

A MeZO run is fully determined by ``(base_seed, [(lr_t, g_t)])`` — the paper
notes this needs "the seed plus 20,000 steps × 2 bytes ... less than 0.1 MB"
for a 66 B model.  We store g in fp16 (2 bytes, as the paper counts it) or
fp32, and reconstruct parameters by replaying through the execution engine
(``repro.exec``) step by step — no data access, no forward passes.

Fault-tolerance use: every worker appends (step, g) scalars to the ledger; a
replacement node restores the last full tensor checkpoint and replays the
ledger tail to rejoin *bitwise-identically* (tested in
tests/test_trajectory.py and tests/test_fault_tolerance.py).

The header records the full seed-schedule coordinates of the run — the
perturbation backend, ``batch_seeds`` (B streams per group, FZOO), the
execution plan (``exec_plan``, ``n_groups`` — seed-parallel groups, async
workers, or local n-SPSA's interleaved seeds, which all share one fold
schedule), and the parameter selection (``selection`` spec + ``sel_phase``
block-schedule offset, ``repro.select``).  Replay refuses mismatched
coordinates (``BackendMismatchError`` / ``PlanMismatchError`` /
``SelectionMismatchError``) instead of silently pairing the recorded scalars
with different z streams or a different parameter support.
"""
from __future__ import annotations

import dataclasses
import hashlib
import io
import struct
from typing import Optional

import numpy as np

from repro.tree_utils import PyTree

_MAGIC = b"MZOL1\x00"          # legacy format: no backend record (implies xla)
_MAGIC2 = b"MZOL2\x00"         # adds the perturbation-backend name
_MAGIC3 = b"MZOL3\x00"         # adds batch_seeds (B per-seed scalars per step)
_MAGIC4 = b"MZOL4\x00"         # adds the execution plan (exec_plan, n_groups)
_MAGIC5 = b"MZOL5\x00"         # adds the parameter selection (spec + phase)


@dataclasses.dataclass
class TrajectoryLedger:
    """Append-only scalar record of a MeZO run.

    ``backend`` records which perturbation backend generated the run's z
    streams (``repro.perturb``); replay refuses a mismatched backend because
    the streams differ (``BackendMismatchError``).  Legacy ``MZOL1`` files
    deserialize with ``backend="xla"`` (the only backend that existed).

    ``batch_seeds`` records how many seed streams each *group* evaluated
    (FZOO's B); ``n_groups``/``exec_plan`` record the execution plan's group
    count and kind (seed-parallel batch groups, async workers, local n-SPSA
    seeds — one shared fold schedule).  Each step's record is the
    ``n_groups × batch_seeds`` per-stream g vector, which is exactly what the
    engine's group replay needs to refold the rank-1 updates.

    ``selection``/``sel_phase`` record the run's parameter selection
    (``repro.select`` spec string + block-schedule phase offset): the
    selection decides which leaves each recorded scalar's update touches, so
    replay under a mismatched selection refuses (``SelectionMismatchError``).

    Plain B=1 single-group full-selection runs keep serializing as ``MZOL2``
    (batched single-group runs as ``MZOL3``, multi-group runs as ``MZOL4``)
    so old readers keep working; ``MZOL5`` — the superset header — is written
    only when the selection is not ``full``.  All coordinates are fixed per
    ledger — they are properties of the recorded run."""
    base_seed: int
    grad_dtype: str = "float16"       # the paper's 2-bytes-per-step accounting
    backend: str = "xla"              # perturbation backend of the run
    batch_seeds: int = 1              # seed streams (g scalars) per group
    exec_plan: str = "local"          # execution plan kind of the run
    n_groups: int = 1                 # seed groups per step (plan-level)
    selection: str = "full"           # parameter-selection spec of the run
    sel_phase: int = 0                # selection block-schedule phase offset
    steps: list = dataclasses.field(default_factory=list)    # step indices
    grads: list = dataclasses.field(default_factory=list)    # projected grads
    lrs: list = dataclasses.field(default_factory=list)      # lr actually used

    def _streams_per_step(self) -> int:
        return int(self.batch_seeds) * int(self.n_groups)

    def append(self, step: int, projected_grad, lr: float) -> None:
        """Record one step.  ``projected_grad`` is a scalar (one stream) or a
        length-``n_groups·batch_seeds`` vector of per-stream scalars."""
        arr = np.atleast_1d(np.asarray(projected_grad)).astype(self.grad_dtype)
        if arr.ndim != 1:
            raise ValueError(f"projected_grad must be scalar or 1-D, "
                             f"got shape {arr.shape}")
        if not self.steps and self._streams_per_step() == 1:
            # default-constructed ledger: infer B from the first record
            self.batch_seeds = int(arr.size)
        elif int(arr.size) != self._streams_per_step():
            # a constructor-declared stream count is a promise, not a
            # default — a mismatched first record fails HERE (the recording
            # site), not later at replay time with a ledger-vs-optimizer error
            raise ValueError(
                f"this ledger records {self._streams_per_step()} seed "
                f"scalar(s) per step (n_groups={self.n_groups} × "
                f"batch_seeds={self.batch_seeds}); got {arr.size} — the "
                "stream count is fixed per run")
        self.steps.append(int(step))
        # stored after quantization; scalars stay plain floats (legacy shape)
        self.grads.append(float(arr[0]) if arr.size == 1
                          else [float(x) for x in arr])
        self.lrs.append(float(lr))

    def __len__(self) -> int:
        return len(self.steps)

    # -- identity / slicing (the serving layer's cache-key primitives) ------ #
    def content_hash(self, upto: Optional[int] = None) -> str:
        """Stable hex digest over the header coordinates + the first ``upto``
        records (all of them when ``None``).  This is THE cache key of the
        multi-tenant serving layer (``repro.serve.tenants``): two ledgers
        share a hash iff they would replay the identical parameter delta, so
        a materialized delta keyed on ``(content_hash, n_records)`` can be
        reused across processes and hosts.  Records hash over their *stored*
        (post-quantization) values, so the digest survives a
        ``to_bytes``/``from_bytes`` round trip (test-enforced)."""
        n = len(self.steps) if upto is None else int(upto)
        if not 0 <= n <= len(self.steps):
            raise ValueError(f"content_hash upto={n} outside the ledger's "
                             f"{len(self.steps)} records")
        h = hashlib.sha256()
        h.update(repr((self.base_seed, self.grad_dtype, self.backend,
                       self.batch_seeds, self.exec_plan, self.n_groups,
                       self.selection, self.sel_phase)).encode("utf-8"))
        h.update(np.asarray(self.steps[:n], np.int64).tobytes())
        h.update(np.asarray(self.grads[:n], self.grad_dtype).tobytes())
        h.update(np.asarray(self.lrs[:n], np.float32).tobytes())
        return h.hexdigest()

    def slice(self, from_idx: int, to_idx: Optional[int] = None) \
            -> "TrajectoryLedger":
        """A new ledger with the same header coordinates holding records
        ``[from_idx, to_idx)``.  Records keep their original step indices, so
        replaying a slice folds the exact same per-step seeds as replaying
        the corresponding span of the full ledger — this is what makes a
        compacted adapter's *tail* (``repro.serve.tenants.compact``) replay
        bitwise-identically to the full-ledger suffix."""
        to_idx = len(self.steps) if to_idx is None else int(to_idx)
        out = TrajectoryLedger(
            base_seed=self.base_seed, grad_dtype=self.grad_dtype,
            backend=self.backend, batch_seeds=self.batch_seeds,
            exec_plan=self.exec_plan, n_groups=self.n_groups,
            selection=self.selection, sel_phase=self.sel_phase)
        out.steps = list(self.steps[from_idx:to_idx])
        out.grads = list(self.grads[from_idx:to_idx])
        out.lrs = list(self.lrs[from_idx:to_idx])
        return out

    # -- serialization ----------------------------------------------------- #
    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        selected = self.selection != "full" or self.sel_phase != 0
        planned = self.n_groups > 1
        batched = self.batch_seeds > 1
        buf.write(_MAGIC5 if selected else
                  (_MAGIC4 if planned else (_MAGIC3 if batched else _MAGIC2)))
        buf.write(struct.pack("<qi", self.base_seed,
                              1 if self.grad_dtype == "float16" else 4))
        bname = self.backend.encode("utf-8")
        buf.write(struct.pack("<i", len(bname)))
        buf.write(bname)
        if selected or planned or batched:
            buf.write(struct.pack("<i", self.batch_seeds))
        if selected or planned:
            buf.write(struct.pack("<i", self.n_groups))
            pname = self.exec_plan.encode("utf-8")
            buf.write(struct.pack("<i", len(pname)))
            buf.write(pname)
        if selected:
            sname = self.selection.encode("utf-8")
            buf.write(struct.pack("<i", len(sname)))
            buf.write(sname)
            buf.write(struct.pack("<i", self.sel_phase))
        buf.write(struct.pack("<q", len(self.steps)))
        buf.write(np.asarray(self.steps, np.int64).tobytes())
        buf.write(np.asarray(self.grads, self.grad_dtype).tobytes())
        buf.write(np.asarray(self.lrs, np.float32).tobytes())
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "TrajectoryLedger":
        buf = io.BytesIO(raw)
        magic = buf.read(len(_MAGIC))
        assert magic in (_MAGIC, _MAGIC2, _MAGIC3, _MAGIC4, _MAGIC5), \
            "not a MeZO ledger"
        seed, dcode = struct.unpack("<qi", buf.read(12))
        backend = "xla"                       # MZOL1 predates backend choice
        batch_seeds = 1
        n_groups = 1
        exec_plan = "local"
        selection = "full"                    # MZOL1-4 predate selections
        sel_phase = 0
        if magic != _MAGIC:
            blen, = struct.unpack("<i", buf.read(4))
            backend = buf.read(blen).decode("utf-8")
        if magic in (_MAGIC3, _MAGIC4, _MAGIC5):
            batch_seeds, = struct.unpack("<i", buf.read(4))
        if magic in (_MAGIC4, _MAGIC5):
            n_groups, = struct.unpack("<i", buf.read(4))
            plen, = struct.unpack("<i", buf.read(4))
            exec_plan = buf.read(plen).decode("utf-8")
        if magic == _MAGIC5:
            slen, = struct.unpack("<i", buf.read(4))
            selection = buf.read(slen).decode("utf-8")
            sel_phase, = struct.unpack("<i", buf.read(4))
        n, = struct.unpack("<q", buf.read(8))
        dtype = "float16" if dcode == 1 else "float32"
        itemsize = np.dtype(dtype).itemsize
        per_step = batch_seeds * n_groups
        steps = np.frombuffer(buf.read(8 * n), np.int64)
        grads = np.frombuffer(buf.read(itemsize * n * per_step), dtype)
        lrs = np.frombuffer(buf.read(4 * n), np.float32)
        led = cls(base_seed=seed, grad_dtype=dtype, backend=backend,
                  batch_seeds=batch_seeds, exec_plan=exec_plan,
                  n_groups=n_groups, selection=selection,
                  sel_phase=sel_phase)
        led.steps = [int(s) for s in steps]
        if per_step == 1:
            led.grads = [float(g) for g in grads]
        else:
            led.grads = [[float(g) for g in row]
                         for row in grads.reshape(n, per_step)]
        led.lrs = [float(l) for l in lrs]
        return led

    def nbytes(self) -> int:
        return len(self.to_bytes())


def replay(params0: PyTree, ledger: TrajectoryLedger, optimizer,
           from_idx: int = 0, to_idx: Optional[int] = None) -> PyTree:
    """Reconstruct θ_T from θ_0 (or a mid-run checkpoint) by replaying the
    scalar ledger through the execution engine (``StepProgram.replay``).
    Uses the exact same write path as training, so the reconstruction is
    bitwise when grad_dtype='float32' and the training loop records the
    quantized g it actually applied.

    ``optimizer`` is a ``repro.exec.StepProgram`` (whose plan must match the
    ledger's — the resume path) or anything ``as_zo_optimizer`` accepts,
    which is wrapped on the ledger-driven ``replay()`` plan (adopting the
    ledger's recorded ``n_groups``).  Mismatched seed-schedule coordinates
    raise ``BackendMismatchError`` / ``PlanMismatchError`` — the z streams
    differ, so the reconstruction would silently diverge."""
    from repro.exec import StepProgram, as_step_program
    from repro.exec import plan as plan_mod
    if isinstance(optimizer, StepProgram):
        prog = optimizer
    else:
        prog = as_step_program(optimizer, plan_mod.replay())
    return prog.replay(params0, ledger, from_idx=from_idx, to_idx=to_idx)


def storage_report(n_steps: int, grad_dtype: str = "float16") -> dict:
    """Paper §2.1 numbers: ledger bytes vs. LoRA / prefix checkpoint bytes."""
    itemsize = np.dtype(grad_dtype).itemsize
    return {
        "ledger_bytes": 8 + n_steps * itemsize,
        "lora_opt66b_bytes": 19_000_000 * 2,     # 19 M params, bf16 (paper: 38 MB)
        "prefix_opt66b_bytes": 6_000_000 * 2,    # 6 M params (paper: 12 MB)
    }
