"""Trajectory ledger: the paper's §2.1 storage trick, promoted to a
first-class checkpoint/recovery mechanism.

A MeZO run is fully determined by ``(base_seed, [(lr_t, g_t)])`` — the paper
notes this needs "the seed plus 20,000 steps × 2 bytes ... less than 0.1 MB"
for a 66 B model.  We store g in fp16 (2 bytes, as the paper counts it) or
fp32, and reconstruct parameters by replaying ``apply_projected_update``
step by step — no data access, no forward passes.

Fault-tolerance use: every worker appends (step, g) scalars to the ledger; a
replacement node restores the last full tensor checkpoint and replays the
ledger tail to rejoin *bitwise-identically* (tested in
tests/test_trajectory.py and tests/test_fault_tolerance.py).
"""
from __future__ import annotations

import dataclasses
import io
import struct
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.perturb import step_key
from repro.perturb import check_replay_backend
from repro.tree_utils import PyTree
from repro.zo.presets import as_zo_optimizer

_MAGIC = b"MZOL1\x00"          # legacy format: no backend record (implies xla)
_MAGIC2 = b"MZOL2\x00"         # adds the perturbation-backend name
_MAGIC3 = b"MZOL3\x00"         # adds batch_seeds (B per-seed scalars per step)


@dataclasses.dataclass
class TrajectoryLedger:
    """Append-only scalar record of a MeZO run.

    ``backend`` records which perturbation backend generated the run's z
    streams (``repro.perturb``); replay refuses a mismatched backend because
    the streams differ (``BackendMismatchError``).  Legacy ``MZOL1`` files
    deserialize with ``backend="xla"`` (the only backend that existed).

    ``batch_seeds`` records how many seed streams each step evaluated: plain
    MeZO records one scalar per step (B=1, serialized as ``MZOL2`` so old
    readers keep working); a batched-seed FZOO run records the (B,) per-seed
    g vector per step (serialized as ``MZOL3``), which is exactly what
    ``replay_update`` needs to refold the B rank-1 updates.  B is fixed per
    ledger — it is a property of the recorded optimizer."""
    base_seed: int
    grad_dtype: str = "float16"       # the paper's 2-bytes-per-step accounting
    backend: str = "xla"              # perturbation backend of the run
    batch_seeds: int = 1              # seed streams (g scalars) per step
    steps: list = dataclasses.field(default_factory=list)    # step indices
    grads: list = dataclasses.field(default_factory=list)    # projected grads
    lrs: list = dataclasses.field(default_factory=list)      # lr actually used

    def append(self, step: int, projected_grad, lr: float) -> None:
        """Record one step.  ``projected_grad`` is a scalar (B=1) or a
        length-B vector of per-seed scalars (batched-seed estimators)."""
        arr = np.atleast_1d(np.asarray(projected_grad)).astype(self.grad_dtype)
        if arr.ndim != 1:
            raise ValueError(f"projected_grad must be scalar or 1-D, "
                             f"got shape {arr.shape}")
        if not self.steps and self.batch_seeds == 1:
            # default-constructed ledger: infer B from the first record
            self.batch_seeds = int(arr.size)
        elif int(arr.size) != self.batch_seeds:
            # a constructor-declared B is a promise, not a default — a
            # mismatched first record fails HERE (the recording site), not
            # later at replay time with a ledger-vs-optimizer error
            raise ValueError(
                f"this ledger records {self.batch_seeds} seed scalar(s) per "
                f"step; got {arr.size} — batch_seeds is fixed per run")
        self.steps.append(int(step))
        # stored after quantization; scalars stay plain floats (legacy shape)
        self.grads.append(float(arr[0]) if arr.size == 1
                          else [float(x) for x in arr])
        self.lrs.append(float(lr))

    def __len__(self) -> int:
        return len(self.steps)

    # -- serialization ----------------------------------------------------- #
    def to_bytes(self) -> bytes:
        buf = io.BytesIO()
        batched = self.batch_seeds > 1
        buf.write(_MAGIC3 if batched else _MAGIC2)
        buf.write(struct.pack("<qi", self.base_seed,
                              1 if self.grad_dtype == "float16" else 4))
        bname = self.backend.encode("utf-8")
        buf.write(struct.pack("<i", len(bname)))
        buf.write(bname)
        if batched:
            buf.write(struct.pack("<i", self.batch_seeds))
        buf.write(struct.pack("<q", len(self.steps)))
        buf.write(np.asarray(self.steps, np.int64).tobytes())
        buf.write(np.asarray(self.grads, self.grad_dtype).tobytes())
        buf.write(np.asarray(self.lrs, np.float32).tobytes())
        return buf.getvalue()

    @classmethod
    def from_bytes(cls, raw: bytes) -> "TrajectoryLedger":
        buf = io.BytesIO(raw)
        magic = buf.read(len(_MAGIC))
        assert magic in (_MAGIC, _MAGIC2, _MAGIC3), "not a MeZO ledger"
        seed, dcode = struct.unpack("<qi", buf.read(12))
        backend = "xla"                       # MZOL1 predates backend choice
        batch_seeds = 1
        if magic in (_MAGIC2, _MAGIC3):
            blen, = struct.unpack("<i", buf.read(4))
            backend = buf.read(blen).decode("utf-8")
        if magic == _MAGIC3:
            batch_seeds, = struct.unpack("<i", buf.read(4))
        n, = struct.unpack("<q", buf.read(8))
        dtype = "float16" if dcode == 1 else "float32"
        itemsize = np.dtype(dtype).itemsize
        steps = np.frombuffer(buf.read(8 * n), np.int64)
        grads = np.frombuffer(buf.read(itemsize * n * batch_seeds), dtype)
        lrs = np.frombuffer(buf.read(4 * n), np.float32)
        led = cls(base_seed=seed, grad_dtype=dtype, backend=backend,
                  batch_seeds=batch_seeds)
        led.steps = [int(s) for s in steps]
        if batch_seeds == 1:
            led.grads = [float(g) for g in grads]
        else:
            led.grads = [[float(g) for g in row]
                         for row in grads.reshape(n, batch_seeds)]
        led.lrs = [float(l) for l in lrs]
        return led

    def nbytes(self) -> int:
        return len(self.to_bytes())


def replay(params0: PyTree, ledger: TrajectoryLedger, optimizer,
           from_idx: int = 0, to_idx: Optional[int] = None) -> PyTree:
    """Reconstruct θ_T from θ_0 (or a mid-run checkpoint) by replaying the
    scalar ledger through the optimizer protocol's ``replay_update``.  Uses
    the exact same update primitive as training, so the reconstruction is
    bitwise when grad_dtype='float32' and the training loop records the
    quantized g it actually applied.

    ``optimizer`` is anything conforming to the ``repro.zo`` protocol (a
    ``ZOOptimizer``, a shim, or — for backward compatibility — a legacy
    ``MeZOConfig``-like object, converted via ``as_zo_optimizer``).  If the
    ledger records a perturbation backend different from the optimizer's,
    replay raises ``BackendMismatchError`` — the z streams differ, so the
    reconstruction would silently diverge."""
    opt = as_zo_optimizer(optimizer)
    check_replay_backend(ledger.backend,
                         getattr(opt, "backend_name", None), "trajectory ledger")
    opt_bs = int(getattr(opt, "batch_seeds", 1))
    if len(ledger) and ledger.batch_seeds != opt_bs:
        raise ValueError(
            f"trajectory ledger records {ledger.batch_seeds} seed scalar(s) "
            f"per step but the optimizer evaluates batch_seeds={opt_bs}; the "
            "seed fold schedule (and the per-step g shape) differ, so replay "
            "would misapply the updates — replay with a matching "
            "fzoo(batch_seeds=...) composition")
    base_key = jax.random.PRNGKey(ledger.base_seed)
    to_idx = len(ledger) if to_idx is None else to_idx

    @jax.jit
    def one(params, step, g, lr):
        skey = step_key(base_key, step)
        return opt.replay_update(params, skey, g, lr)

    p = params0
    for i in range(from_idx, to_idx):
        p = one(p, jnp.int32(ledger.steps[i]),
                jnp.float32(ledger.grads[i]), jnp.float32(ledger.lrs[i]))
    return p


def storage_report(n_steps: int, grad_dtype: str = "float16") -> dict:
    """Paper §2.1 numbers: ledger bytes vs. LoRA / prefix checkpoint bytes."""
    itemsize = np.dtype(grad_dtype).itemsize
    return {
        "ledger_bytes": 8 + n_steps * itemsize,
        "lora_opt66b_bytes": 19_000_000 * 2,     # 19 M params, bf16 (paper: 38 MB)
        "prefix_opt66b_bytes": 6_000_000 * 2,    # 6 M params (paper: 12 MB)
    }
