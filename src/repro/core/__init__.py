# The paper's primary contribution: MeZO — in-place zeroth-order optimization
# with seed-replayed perturbations (NeurIPS 2023, Malladi et al.).
from repro.core.mezo import MeZO, MeZOConfig, MeZOState, apply_projected_update
from repro.core.mezo_adam import MeZOAdam, MeZOAdamConfig, MeZOAdamState
from repro.core.perturb import (fused_restore_update, leaf_key,
                                sample_leaf_z, sample_z_tree, step_key)
from repro.core.perturb import perturb as perturb_params  # `perturb` is the submodule
from repro.core.spsa import (SPSAResult, one_point_projected_grad,
                             spsa_full_gradient_oracle, spsa_projected_grad,
                             zo_grad_norm)
from repro.core.trajectory import TrajectoryLedger, replay, storage_report

__all__ = [
    "MeZO", "MeZOConfig", "MeZOState", "MeZOAdam", "MeZOAdamConfig",
    "MeZOAdamState", "apply_projected_update", "perturb_params",
    "fused_restore_update", "sample_leaf_z", "sample_z_tree", "leaf_key",
    "step_key", "SPSAResult", "spsa_projected_grad",
    "spsa_full_gradient_oracle", "one_point_projected_grad", "zo_grad_norm",
    "TrajectoryLedger", "replay", "storage_report",
]
