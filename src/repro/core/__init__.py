# The paper's primary contribution: MeZO — in-place zeroth-order optimization
# with seed-replayed perturbations (NeurIPS 2023, Malladi et al.).
#
# The optimizer surface is now the composable ``repro.zo`` layer (estimator ×
# transform chains behind one protocol); ``MeZO`` / ``MeZOAdam`` /
# ``MeZOVariant`` are deprecated shims over those compositions, re-exported
# here together with the new surface.
#
# Exports resolve lazily (PEP 562): the shims import ``repro.zo``, which
# imports the primitive submodules ``repro.core.perturb`` / ``core.schedules``
# — lazy resolution lets either package be imported first without a cycle.
from __future__ import annotations

import importlib

_EXPORTS = {
    # primitives -------------------------------------------------------------
    "repro.core.perturb": ["fused_restore_update", "leaf_key", "sample_leaf_z",
                           "sample_z_tree", "step_key"],
    "repro.core.spsa": ["SPSAResult", "one_point_projected_grad",
                        "spsa_full_gradient_oracle", "spsa_projected_grad",
                        "zo_grad_norm"],
    # deprecated optimizer shims --------------------------------------------
    "repro.core.mezo": ["MeZO", "MeZOConfig", "MeZOState",
                        "apply_projected_update"],
    "repro.core.mezo_adam": ["MeZOAdam", "MeZOAdamConfig", "MeZOAdamState"],
    "repro.core.mezo_variants": ["MeZOVariant", "MeZOVariantConfig",
                                 "MeZOVariantState"],
    # trajectory ledger ------------------------------------------------------
    "repro.core.trajectory": ["TrajectoryLedger", "replay", "storage_report"],
    # the composable surface (estimator × transforms behind one protocol) ----
    "repro.zo": ["Optimizer", "ZOOptimizer", "ZOState", "ZOEstimator",
                 "ZOTransform", "apply_rank1", "as_zo_optimizer", "chain"],
}
_LOOKUP = {name: module for module, names in _EXPORTS.items() for name in names}
_ALIASES = {"perturb_params": ("repro.core.perturb", "perturb")}

__all__ = sorted(_LOOKUP) + sorted(_ALIASES)


def __getattr__(name: str):
    if name in _LOOKUP:
        value = getattr(importlib.import_module(_LOOKUP[name]), name)
    elif name in _ALIASES:
        module, attr = _ALIASES[name]
        value = getattr(importlib.import_module(module), attr)
    else:
        try:  # plain submodule access: ``repro.core.mezo`` after ``import repro.core``
            value = importlib.import_module(f"{__name__}.{name}")
        except ModuleNotFoundError:
            raise AttributeError(
                f"module {__name__!r} has no attribute {name!r}") from None
    globals()[name] = value            # cache: resolve each name once
    return value


def __dir__():
    return sorted(set(__all__))
