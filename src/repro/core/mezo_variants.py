"""MeZO variants from paper Appendix B.3/B.4: variance- and expectation-
modified SPSA with a block-diagonal rescaling D (one scalar per parameter
group/leaf).

.. deprecated::
    ``MeZOVariant`` is a thin shim over the composable API —
    ``zo.mezo_rescaled`` builds the identical optimizer as::

        ZOOptimizer(estimators.rescaled_spsa(eps, d_source, ...),
                    chain(clip?, scale_by_schedule(lr), add_weight_decay(λ)))

* D = parameter norms  -> layerwise-adaptive-style rescaling (Table 9).
* D = gradient norms   -> control-variate rescaling; norms estimated with
  Proposition 1's ZO probe (no backprop) (Table 8).
* ``modify_expectation=True`` multiplies the update by z (not D·z): the
  biased normalized-gradient estimate of Definition 7 (Table 10).

The paper found none of these beat plain MeZO at equal forward passes — our
bench (benchmarks/bench_variants.py) reproduces that negative result — but
they demonstrate how cheaply the estimator family extends.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.core.mezo import MeZOConfig
from repro.tree_utils import PyTree
from repro.zo.base import ZOOptimizer, ZOState
from repro.zo.presets import mezo_rescaled as _mezo_rescaled_preset


@dataclasses.dataclass(frozen=True)
class MeZOVariantConfig(MeZOConfig):
    d_source: str = "param_norm"        # param_norm | grad_norm_zo | ones
    modify_expectation: bool = False    # Definition 7 vs Definition 6
    d_refresh_every: int = 0            # 0 = once at init (paper: per epoch)
    d_probe_eps: float = 1e-4


# Deprecated alias: the D-tree now lives in the estimator carry of ``ZOState``.
MeZOVariantState = ZOState


class MeZOVariant(ZOOptimizer):
    """Definition 6/7 optimizer: perturb by ε·(d⁻¹ ⊙ z), update along
    (D or I)·z with the same regenerated z.  Deprecated shim over
    ``zo.mezo_rescaled``."""

    def __init__(self, config: MeZOVariantConfig):
        self.config = config
        composed = self._compose(None, None)
        super().__init__(composed.estimator, composed.transform,
                         name="mezo_rescaled")

    def _compose(self, probe_loss_fn, probe_batch) -> ZOOptimizer:
        c = self.config
        return _mezo_rescaled_preset(
            lr=c.lr, eps=c.eps, dist=c.dist, d_source=c.d_source,
            modify_expectation=c.modify_expectation,
            probe_loss_fn=probe_loss_fn, probe_batch=probe_batch,
            probe_eps=c.d_probe_eps, weight_decay=c.weight_decay,
            lr_schedule=c.lr_schedule, total_steps=c.total_steps,
            warmup_steps=c.warmup_steps,
            clip_projected_grad=c.clip_projected_grad)

    def init(self, params: PyTree, loss_fn: Optional[Callable] = None,
             batch=None, *, seed: int = 0) -> ZOState:
        """Legacy signature: ``d_source='grad_norm_zo'`` estimates D with
        Proposition-1 probes, which need the loss and a batch at init time.
        The composable API passes these to the estimator factory instead
        (``zo.estimators.rescaled_spsa(probe_loss_fn=..., probe_batch=...)``)."""
        if self.config.d_source == "grad_norm_zo":
            assert loss_fn is not None and batch is not None
            self.estimator = self._compose(loss_fn, batch).estimator
        return ZOOptimizer.init(self, params, seed=seed)
