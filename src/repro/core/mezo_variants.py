"""MeZO variants from paper Appendix B.3/B.4: variance- and expectation-
modified SPSA with a block-diagonal rescaling D (one scalar per parameter
group/leaf).

* D = parameter norms  -> layerwise-adaptive-style rescaling (Table 9).
* D = gradient norms   -> control-variate rescaling; norms estimated with
  Proposition 1's ZO probe (no backprop) or recomputed per epoch (Table 8).
* ``modify_expectation=True`` multiplies the update by z (not D·z): the
  biased normalized-gradient estimate of Definition 7 (Table 10).

The paper found none of these beat plain MeZO at equal forward passes — our
bench (benchmarks/bench_variants.py) reproduces that negative result — but
they demonstrate how cheaply the estimator family extends.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mezo import MeZOConfig, apply_projected_update
from repro.core.perturb import leaf_key, perturb, sample_leaf_z, step_key
from repro.core.spsa import zo_grad_norm
from repro.tree_utils import PyTree, tree_map_with_index


@dataclasses.dataclass(frozen=True)
class MeZOVariantConfig(MeZOConfig):
    d_source: str = "param_norm"        # param_norm | grad_norm_zo | ones
    modify_expectation: bool = False    # Definition 7 vs Definition 6
    d_refresh_every: int = 0            # 0 = once at init (paper: per epoch)
    d_probe_eps: float = 1e-4


class MeZOVariantState(NamedTuple):
    step: jnp.ndarray
    base_key: jax.Array
    d_tree: PyTree                      # one positive scalar per leaf
    last_projected_grad: jnp.ndarray


def _leaf_norms(params: PyTree) -> PyTree:
    """RMS per leaf (size-free, unlike the raw norm) with a floor so that
    zero-initialized leaves (norm scales, biases) don't poison the geometric
    mean and starve every other leaf's perturbation."""
    return jax.tree_util.tree_map(
        lambda p: jnp.maximum(
            jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2)), 1e-2), params)


def _grad_norms_zo(loss_fn, params, batch, key, eps, n_probe: int = 4) -> PyTree:
    """Proposition 1 per-leaf gradient-norm estimates (no backprop): RMS over
    n_probe single-leaf probes."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i in range(len(leaves)):
        acc = 0.0
        for j in range(n_probe):
            k = jax.random.fold_in(jax.random.fold_in(key, i), j)
            g = zo_grad_norm(loss_fn, params, batch, k, eps, leaf_indices=[i])
            acc = acc + g.astype(jnp.float32) ** 2
        out.append(jnp.maximum(jnp.sqrt(acc / n_probe), 1e-6))
    return jax.tree_util.tree_unflatten(treedef, out)


class MeZOVariant:
    """Definition 6/7 optimizer: perturb by ε·(d⁻¹ ⊙ z), update along
    (D or I)·z with the same regenerated z."""

    def __init__(self, config: MeZOVariantConfig):
        self.config = config

    def init(self, params: PyTree, loss_fn: Callable = None, batch=None,
             seed: int = 0) -> MeZOVariantState:
        c = self.config
        key = jax.random.PRNGKey(seed)
        if c.d_source == "param_norm":
            d = _leaf_norms(params)
        elif c.d_source == "grad_norm_zo":
            assert loss_fn is not None and batch is not None
            d = _grad_norms_zo(loss_fn, params, batch, key, c.d_probe_eps)
        else:
            d = jax.tree_util.tree_map(lambda p: jnp.float32(1.0), params)
        # normalize D to unit geometric mean so the global lr keeps its scale
        logs = jnp.stack([jnp.log(x) for x in jax.tree_util.tree_leaves(d)])
        scale = jnp.exp(jnp.mean(logs))
        d = jax.tree_util.tree_map(lambda x: x / scale, d)
        return MeZOVariantState(jnp.int32(0), key, d, jnp.float32(0.0))

    def step_fn(self, loss_fn: Callable):
        c = self.config

        def step(params: PyTree, state: MeZOVariantState, batch):
            skey = step_key(state.base_key, state.step)
            lr = c.lr_at(state.step)
            d_leaves = jax.tree_util.tree_leaves(state.d_tree)

            def pert(i, p, sign):
                if not jnp.issubdtype(p.dtype, jnp.floating):
                    return p
                z = sample_leaf_z(leaf_key(skey, i), p, c.dist)
                dinv = (1.0 / d_leaves[i]).astype(p.dtype)
                return p + sign * jnp.asarray(c.eps, p.dtype) * dinv * z

            p_plus = tree_map_with_index(lambda i, p: pert(i, p, 1.0), params)
            l_plus = loss_fn(p_plus, batch)
            p_minus = tree_map_with_index(lambda i, p: pert(i, p, -2.0), p_plus)
            l_minus = loss_fn(p_minus, batch)
            g = (l_plus - l_minus) / (2.0 * c.eps)
            if c.clip_projected_grad > 0:
                g = jnp.clip(g, -c.clip_projected_grad, c.clip_projected_grad)
            restored = tree_map_with_index(lambda i, p: pert(i, p, 1.0), p_minus)
            d_for_update = (None if c.modify_expectation else state.d_tree)
            new_params = apply_projected_update(
                restored, skey, g, lr, c.weight_decay, c.dist,
                d_tree=d_for_update)
            new_state = MeZOVariantState(state.step + 1, state.base_key,
                                         state.d_tree, g)
            return new_params, new_state, {"loss": 0.5 * (l_plus + l_minus),
                                           "projected_grad": g, "lr": lr}

        return step
