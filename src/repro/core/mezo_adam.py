"""MeZO-Adam / MeZO-momentum (paper §2.2 + Appendix B.2).

.. deprecated::
    ``MeZOAdam`` is a thin shim over the composable API — ``zo.mezo_adam``
    builds the identical optimizer (bitwise-equal steps) as::

        ZOOptimizer(estimators.spsa(eps),
                    chain(clip_projected_grad?, scale_by_schedule(lr),
                          scale_by_zo_adam(β1, β2, materialized, window)))

The SPSA gradient at step τ is the rank-1 tensor g_τ·z_τ with z_τ a pure
function of (base_key, τ).  Therefore *any* moving average of gradients is a
pure function of the scalar history {g_τ} — it can be recomputed instead of
stored.  Two modes (see ``repro.zo.transforms.scale_by_zo_adam``):

* ``materialized=True``  — conventional Adam: m, v stored as full trees
  (2× parameter memory; the thing the paper avoids).  Used as the oracle.
* ``materialized=False`` — the paper's trick: keep a ring buffer of W scalars
  g_{t−W+1..t}; at update time recompute, leaf by leaf,

      m_t ≈ (1−β1) Σ_{j<W} β1^j · g_{t−j} · z_{t−j}
      v_t ≈ (1−β2) Σ_{j<W} β2^j · g_{t−j}² · z_{t−j}²

  Each leaf's accumulators are transient (freed after that leaf's update), so
  the extra live memory is O(largest leaf) + W scalars.  Truncation error
  decays as β^W; tests compare against the materialized oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.mezo import MeZOConfig
from repro.tree_utils import PyTree
from repro.zo.base import ZOOptimizer, ZOState
from repro.zo.presets import mezo_adam as _mezo_adam_preset


@dataclasses.dataclass(frozen=True)
class MeZOAdamConfig(MeZOConfig):
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    materialized: bool = False
    window: int = 32                # ring-buffer length for recomputed mode
    momentum_only: bool = False     # True -> SGD+momentum (no v, no bias corr on v)


# Deprecated alias: the g-history ring buffer and m/v trees now live inside
# the uniform ``ZOState``'s transform carry.
MeZOAdamState = ZOState


class MeZOAdam(ZOOptimizer):
    """Deprecated shim: ZO-Adam as the ``repro.zo`` composition above."""

    def __init__(self, config: MeZOAdamConfig):
        self.config = config
        composed = _mezo_adam_preset(
            lr=config.lr, eps=config.eps, beta1=config.beta1,
            beta2=config.beta2, adam_eps=config.adam_eps,
            materialized=config.materialized, window=config.window,
            momentum_only=config.momentum_only, dist=config.dist,
            weight_decay=config.weight_decay, lr_schedule=config.lr_schedule,
            total_steps=config.total_steps, warmup_steps=config.warmup_steps,
            clip_projected_grad=config.clip_projected_grad)
        super().__init__(composed.estimator, composed.transform,
                         name="mezo_adam")

    def init(self, params: Optional[PyTree] = None, seed: int = 0) -> ZOState:
        # legacy positional order preserved: init(params, seed)
        return ZOOptimizer.init(self, params, seed=seed)
