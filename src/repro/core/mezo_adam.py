"""MeZO-Adam / MeZO-momentum (paper §2.2 + Appendix B.2).

The SPSA gradient at step τ is the rank-1 tensor g_τ·z_τ with z_τ a pure
function of (base_key, τ).  Therefore *any* moving average of gradients is a
pure function of the scalar history {g_τ} — it can be recomputed instead of
stored.  Two modes:

* ``materialized=True``  — conventional Adam: m, v stored as full trees
  (2× parameter memory; the thing the paper avoids).  Used as the oracle.
* ``materialized=False`` — the paper's trick: keep a ring buffer of W scalars
  g_{t−W+1..t}; at update time recompute, leaf by leaf,

      m_t ≈ (1−β1) Σ_{j<W} β1^j · g_{t−j} · z_{t−j}
      v_t ≈ (1−β2) Σ_{j<W} β2^j · g_{t−j}² · z_{t−j}²

  Each leaf's accumulators are transient (freed after that leaf's update), so
  the extra live memory is O(largest leaf) + W scalars, matching the paper's
  "perturb an entire weight matrix at a time" memory note.  Truncation error
  decays as β^W; tests compare against the materialized oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.mezo import MeZOConfig
from repro.core.perturb import leaf_key, perturb, sample_leaf_z, step_key, fused_restore_update
from repro.core.spsa import LossFn
from repro.tree_utils import PyTree, tree_map_with_index, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class MeZOAdamConfig(MeZOConfig):
    beta1: float = 0.9
    beta2: float = 0.999
    adam_eps: float = 1e-8
    materialized: bool = False
    window: int = 32                # ring-buffer length for recomputed mode
    momentum_only: bool = False     # True -> SGD+momentum (no v, no bias corr on v)


class MeZOAdamState(NamedTuple):
    step: jnp.ndarray
    base_key: jax.Array
    g_history: jnp.ndarray          # (window,) most-recent-first scalar ledger
    m: Any                          # trees (materialized mode) or () sentinel
    v: Any
    last_projected_grad: jnp.ndarray


class MeZOAdam:
    def __init__(self, config: MeZOAdamConfig):
        self.config = config

    def init(self, params: PyTree, seed: int = 0) -> MeZOAdamState:
        c = self.config
        if c.materialized:
            m, v = tree_zeros_like(params), tree_zeros_like(params)
        else:
            m, v = (), ()
        return MeZOAdamState(jnp.int32(0), jax.random.PRNGKey(seed),
                             jnp.zeros((c.window,), jnp.float32), m, v,
                             jnp.float32(0.0))

    def step_fn(self, loss_fn: LossFn):
        c = self.config

        def step(params: PyTree, state: MeZOAdamState, batch):
            skey = step_key(state.base_key, state.step)
            lr = c.lr_at(state.step)

            # --- SPSA forward passes (identical to MeZO) -------------------
            p_plus = perturb(params, skey, c.eps, c.dist)
            l_plus = loss_fn(p_plus, batch)
            p_minus = perturb(p_plus, skey, -2.0 * c.eps, c.dist)
            l_minus = loss_fn(p_minus, batch)
            g = (l_plus - l_minus) / (2.0 * c.eps)
            if c.clip_projected_grad > 0:
                g = jnp.clip(g, -c.clip_projected_grad, c.clip_projected_grad)
            # restore θ (scalar-scale zero update) — one fused pass
            params0 = fused_restore_update(p_minus, skey, c.eps, 0.0, 0.0, c.dist)

            g_hist = jnp.concatenate([jnp.reshape(g, (1,)),
                                      state.g_history[:-1]])
            t = state.step + 1  # Adam bias-correction time index

            if c.materialized:
                new_params, m, v = self._materialized_update(
                    params0, state, skey, g, lr, t)
            else:
                new_params = self._recomputed_update(
                    params0, state.base_key, state.step, g_hist, lr, t)
                m, v = (), ()

            new_state = MeZOAdamState(state.step + 1, state.base_key, g_hist,
                                      m, v, g)
            return new_params, new_state, {"loss": 0.5 * (l_plus + l_minus),
                                           "projected_grad": g, "lr": lr}

        return step

    # ------------------------------------------------------------------ #
    def _materialized_update(self, params: PyTree, state: MeZOAdamState,
                             skey: jax.Array, g, lr, t):
        c = self.config

        def upd(i, p, m, v):
            z = sample_leaf_z(leaf_key(skey, i), p, c.dist).astype(jnp.float32)
            ghat = g.astype(jnp.float32) * z
            m_new = c.beta1 * m + (1.0 - c.beta1) * ghat
            if c.momentum_only:
                delta = m_new
            else:
                v_new = c.beta2 * v + (1.0 - c.beta2) * ghat * ghat
                m_hat = m_new / (1.0 - c.beta1 ** t.astype(jnp.float32))
                v_hat = v_new / (1.0 - c.beta2 ** t.astype(jnp.float32))
                delta = m_hat / (jnp.sqrt(v_hat) + c.adam_eps)
            p_new = (p.astype(jnp.float32) - lr * delta
                     - lr * c.weight_decay * p.astype(jnp.float32)).astype(p.dtype)
            return p_new, m_new, (m_new * 0 if c.momentum_only else v_new)

        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_m = jax.tree_util.tree_leaves(state.m)
        leaves_v = jax.tree_util.tree_leaves(state.v)
        new_p, new_m, new_v = [], [], []
        for i, (p, m, v) in enumerate(zip(leaves_p, leaves_m, leaves_v)):
            a, b, cc = upd(i, p, m, v)
            new_p.append(a); new_m.append(b); new_v.append(cc)
        unf = jax.tree_util.tree_unflatten
        return unf(treedef, new_p), unf(treedef, new_m), unf(treedef, new_v)

    # ------------------------------------------------------------------ #
    def _recomputed_update(self, params: PyTree, base_key: jax.Array,
                           cur_step, g_hist: jnp.ndarray, lr, t):
        """Paper App. B.2: rebuild m (and v) from the scalar ledger, one leaf
        at a time, by replaying the window's z's.  O(W) forward-free tree
        passes of compute, O(largest leaf) extra memory."""
        c = self.config
        W = c.window
        j_idx = jnp.arange(W, dtype=jnp.float32)           # 0 = most recent
        valid = (cur_step.astype(jnp.float32) - j_idx) >= 0  # steps < 0 never happened
        cm = jnp.where(valid, (1.0 - c.beta1) * c.beta1 ** j_idx * g_hist, 0.0)
        cv = jnp.where(valid, (1.0 - c.beta2) * c.beta2 ** j_idx * g_hist ** 2, 0.0)

        def upd(i, p):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return p

            def body(j, acc):
                m_acc, v_acc = acc
                skey_j = step_key(base_key, cur_step - j)
                z = sample_leaf_z(leaf_key(skey_j, i), p, c.dist).astype(jnp.float32)
                m_acc = m_acc + cm[j] * z
                v_acc = v_acc + cv[j] * z * z
                return (m_acc, v_acc)

            zero = jnp.zeros(p.shape, jnp.float32)
            m, v = jax.lax.fori_loop(0, W, body, (zero, zero))
            if c.momentum_only:
                delta = m
            else:
                m_hat = m / (1.0 - c.beta1 ** t.astype(jnp.float32))
                v_hat = v / (1.0 - c.beta2 ** t.astype(jnp.float32))
                delta = m_hat / (jnp.sqrt(v_hat) + c.adam_eps)
            return (p.astype(jnp.float32) - lr * delta
                    - lr * c.weight_decay * p.astype(jnp.float32)).astype(p.dtype)

        return tree_map_with_index(upd, params)
