"""SPSA-family gradient estimators (paper §2, Definitions 1/6/7/8).

Every estimator here consumes only *forward passes* of a loss function
``loss_fn(params, batch) -> scalar`` and returns ``projected_grad`` scalars —
the full gradient estimate ``g·z`` is never materialized; the optimizer applies
it by regenerating z (see ``repro.core.mezo``).

Estimators:
  * ``spsa_projected_grad``        — two-point SPSA (Definition 1), n=1.
  * ``nspsa_projected_grads``      — n-SPSA: n independent seeds, averaged by
                                     the caller (Algorithm 2).
  * ``one_point_projected_grad``   — residual-feedback one-point estimate
                                     (Definition 8, Zhang et al. 2022).
  * ``variance_modified``          — Definition 6: block-diagonal rescaled
                                     SPSA (control-variate style).
  * ``zo_grad_norm``               — Proposition 1: ZO estimate of a layer's
                                     gradient norm (no backprop).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Sequence

import jax
import jax.numpy as jnp

from repro.core.perturb import Distribution, leaf_key, perturb, sample_leaf_z
from repro.tree_utils import PyTree, tree_map_with_index

LossFn = Callable[[PyTree, Any], jnp.ndarray]


class SPSAResult(NamedTuple):
    projected_grad: jnp.ndarray   # (ℓ+ − ℓ−) / 2ε  — a scalar
    loss: jnp.ndarray             # (ℓ+ + ℓ−) / 2   — unbiased loss estimate
    l_plus: jnp.ndarray
    l_minus: jnp.ndarray


def spsa_projected_grad(loss_fn: LossFn, params: PyTree, batch, key: jax.Array,
                        eps: float, dist: Distribution = "gaussian",
                        sequential: bool = True) -> SPSAResult:
    """Two-point SPSA projected gradient (paper Algorithm 1 lines 3–8).

    ``sequential=True`` is the paper-faithful memory profile: the chain
    ``θ → θ+εz → θ−εz`` is computed by successive in-place-able perturbations
    so that (with buffer donation) only one parameter-sized buffer lives.
    ``sequential=False`` perturbs from the center twice — numerically cleaner
    (θ is never touched) at the cost of one more live buffer; used as the
    beyond-paper variant when activations dominate memory anyway.
    """
    if sequential:
        p_plus = perturb(params, key, eps, dist)
        l_plus = loss_fn(p_plus, batch)
        p_minus = perturb(p_plus, key, -2.0 * eps, dist)
        l_minus = loss_fn(p_minus, batch)
    else:
        l_plus = loss_fn(perturb(params, key, eps, dist), batch)
        l_minus = loss_fn(perturb(params, key, -eps, dist), batch)
    g = (l_plus - l_minus) / (2.0 * eps)
    return SPSAResult(g, 0.5 * (l_plus + l_minus), l_plus, l_minus)


def nspsa_projected_grads(loss_fn: LossFn, params: PyTree, batch, keys: Sequence[jax.Array],
                          eps: float, dist: Distribution = "gaussian") -> tuple[jnp.ndarray, jnp.ndarray]:
    """n-SPSA: one projected grad per key (Algorithm 2's inner loop).

    Returns (projected_grads[n], mean_loss).  Sequential over seeds to keep
    the inference-memory property; see ``distributed.collectives`` for the
    seed-parallel variant that spreads seeds across data-parallel groups.
    """
    gs, losses = [], []
    for k in keys:
        r = spsa_projected_grad(loss_fn, params, batch, k, eps, dist)
        gs.append(r.projected_grad)
        losses.append(r.loss)
    return jnp.stack(gs), jnp.mean(jnp.stack(losses))


class OnePointState(NamedTuple):
    """Carry for the residual-feedback one-point estimator (Definition 8)."""
    prev_perturbed_loss: jnp.ndarray  # L(θ_{t-1} + ε z_{t-1}; B_{t-1})


def one_point_init() -> OnePointState:
    return OnePointState(jnp.float32(0.0))


def one_point_projected_grad(loss_fn: LossFn, params: PyTree, batch, key: jax.Array,
                             eps: float, state: OnePointState,
                             dist: Distribution = "gaussian") -> tuple[jnp.ndarray, jnp.ndarray, OnePointState]:
    """One forward pass per step:  g_t = (L(θ_t + εz_t) − L_prev) / ε.

    Twice as fast per step as SPSA but empirically far less query-efficient
    (paper Table 11) — included for the benchmark reproduction.
    """
    l_pert = loss_fn(perturb(params, key, eps, dist), batch)
    g = (l_pert - state.prev_perturbed_loss) / eps
    return g, l_pert, OnePointState(l_pert)


def variance_modified_projected_grad(loss_fn: LossFn, params: PyTree, batch, key: jax.Array,
                                     eps: float, d_tree: PyTree,
                                     modify_expectation: bool = False) -> jnp.ndarray:
    """Definition 6 (and 7 with ``modify_expectation=True``).

    ``d_tree`` holds one positive scalar per leaf (a block of the diagonal D).
    Perturbs by ε·(d⁻¹ ⊙ z); the estimate multiplies the projected grad by
    (d ⊙ z) [Def. 6, unbiased] or by z [Def. 7, biased / normalized-gradient].
    The caller applies the update by regenerating z with the same key and the
    same d_tree (see mezo.apply_projected_update's ``d_tree`` argument).
    """
    def pert(i, p):
        z = sample_leaf_z(leaf_key(key, i), p)
        dinv = 1.0 / jnp.asarray(d_tree_leaves[i], p.dtype)
        return p + jnp.asarray(eps, p.dtype) * dinv * z
    d_tree_leaves = jax.tree_util.tree_leaves(d_tree)
    p_plus = tree_map_with_index(pert, params)
    l_plus = loss_fn(p_plus, batch)
    def pert_m(i, p):
        z = sample_leaf_z(leaf_key(key, i), p)
        dinv = 1.0 / jnp.asarray(d_tree_leaves[i], p.dtype)
        return p - 2.0 * jnp.asarray(eps, p.dtype) * dinv * z
    p_minus = tree_map_with_index(pert_m, p_plus)
    l_minus = loss_fn(p_minus, batch)
    del modify_expectation  # the D vs identity factor is applied at update time
    return (l_plus - l_minus) / (2.0 * eps)


def zo_grad_norm(loss_fn: LossFn, params: PyTree, batch, key: jax.Array, eps: float,
                 leaf_indices: Sequence[int]) -> jnp.ndarray:
    """Proposition 1: |L(θ+εz_ℓ) − L(θ−εz_ℓ)| / 2ε estimates ‖∇_ℓ L‖ where
    z_ℓ is nonzero only on the leaves in ``leaf_indices``."""
    idx = set(leaf_indices)
    def pert(i, p):
        if i not in idx:
            return p
        z = sample_leaf_z(leaf_key(key, i), p)
        return p + jnp.asarray(eps, p.dtype) * z
    def pert_m(i, p):
        if i not in idx:
            return p
        z = sample_leaf_z(leaf_key(key, i), p)
        return p - 2.0 * jnp.asarray(eps, p.dtype) * z
    p_plus = tree_map_with_index(pert, params)
    l_plus = loss_fn(p_plus, batch)
    p_minus = tree_map_with_index(pert_m, p_plus)
    l_minus = loss_fn(p_minus, batch)
    return jnp.abs(l_plus - l_minus) / (2.0 * eps)


def spsa_full_gradient_oracle(loss_fn: LossFn, params: PyTree, batch, key: jax.Array,
                              eps: float, dist: Distribution = "gaussian") -> PyTree:
    """Materialized ĝ = projected_grad · z.  TEST/ANALYSIS ONLY — this is the
    object the paper's memory trick avoids ever constructing."""
    r = spsa_projected_grad(loss_fn, params, batch, key, eps, dist, sequential=False)
    def one(i, p):
        z = sample_leaf_z(leaf_key(key, i), p, dist)
        return (r.projected_grad.astype(jnp.float32) * z.astype(jnp.float32))
    return tree_map_with_index(one, params)
