"""Self-contained byte-level tokenizer (no external vocab files).

ids 0..255 = bytes; 256 = PAD, 257 = BOS, 258 = EOS, 259 = MASK.
Enough to drive the prompt-based fine-tuning examples offline; production
deployments would plug a sentencepiece model into the same interface.
"""
from __future__ import annotations

import numpy as np

PAD, BOS, EOS, MASK = 256, 257, 258, 259
VOCAB = 260


class ByteTokenizer:
    vocab_size = VOCAB
    pad_id, bos_id, eos_id, mask_id = PAD, BOS, EOS, MASK

    def encode(self, text: str, add_bos: bool = True,
               add_eos: bool = False) -> list[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids) -> str:
        b = bytes(i for i in ids if 0 <= i < 256)
        return b.decode("utf-8", errors="replace")

    def pad_to(self, ids: list[int], length: int) -> np.ndarray:
        out = np.full((length,), PAD, np.int32)
        out[:min(len(ids), length)] = ids[:length]
        return out
