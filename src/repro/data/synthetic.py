"""Synthetic task generators used by the paper-claims reproductions.

Two kinds:

1. ``lm_batch`` — stateless, step-indexed language-model batches (hash-driven
   markov-ish token streams).  ``batch_for_step(step)`` is a pure function of
   (seed, step), which is the fault-tolerance contract: restart at step k
   regenerates bitwise-identical data with no iterator state to checkpoint.

2. ``PromptClassification`` — a separable prompt-based classification task in
   the style of the paper's RoBERTa experiments (App. E.2): each example is
   `<pattern tokens> It was [label-word]`; training minimizes cross entropy
   of the label-word token given a prompt template, evaluation measures label
   accuracy.  Class signal is planted as token-distribution shifts so a small
   LM can learn it in hundreds of steps on CPU — enabling MeZO-vs-FT quality
   comparisons (Table 18 proxies) without pretrained checkpoints.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------- #
# Stateless step-indexed LM stream
# --------------------------------------------------------------------------- #
def lm_batch(seed: int, step: int, batch: int, seq: int, vocab: int) -> dict:
    """Deterministic (seed, step) -> batch.  Tokens follow a hash-chained
    sequence so there is learnable next-token structure."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    base = jax.random.randint(key, (batch, seq), 0, vocab, jnp.int32)
    # plant structure: every other token is a function of its predecessor
    shifted = (base * 1103515245 + 12345) % vocab
    alt = jnp.arange(seq) % 2 == 1
    tokens = jnp.where(alt[None, :], jnp.roll(shifted, 1, axis=1), base)
    labels = jnp.roll(tokens, -1, axis=1)
    mask = jnp.ones((batch, seq), jnp.float32).at[:, -1].set(0.0)
    return {"tokens": tokens, "labels": labels, "loss_mask": mask}


# --------------------------------------------------------------------------- #
# Prompt-based classification (paper App. A: MeZO NEEDS the prompt)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class PromptClassification:
    """k-way classification rendered as an LM prompt.

    Example layout (token ids), seq_len = body + 3:
        [body tokens … class-dependent distribution …] [SEP] [label_word]
    The loss mask covers ONLY the label-word position (prompt-based FT);
    with ``prompt=False`` the label word is replaced by a bare class id token
    with no template — the ablation showing MeZO fails without prompts.
    """
    vocab: int = 256
    n_classes: int = 2
    body_len: int = 29
    seed: int = 0
    prompt: bool = True

    @property
    def seq_len(self) -> int:
        return self.body_len + 3

    def label_word(self, cls) -> jnp.ndarray:
        # well-separated "words" (e.g. 'great'/'terrible' analogues)
        return 10 + 7 * jnp.asarray(cls)

    def sample(self, key: jax.Array, n: int) -> dict:
        kc, kb, kn = jax.random.split(key, 3)
        cls = jax.random.randint(kc, (n,), 0, self.n_classes)
        # class-dependent token distribution: class c draws from a band
        lo = 100 + cls * 60
        body = lo[:, None] + jax.random.randint(kb, (n, self.body_len), 0, 50)
        noise = jax.random.randint(kn, (n, self.body_len), 0, self.vocab)
        keep = jax.random.bernoulli(kb, 0.8, (n, self.body_len))
        body = jnp.where(keep, body, noise)
        sep = jnp.full((n, 1), 5, jnp.int32)          # "It was" analogue
        if self.prompt:
            lab = self.label_word(cls)[:, None]
        else:
            lab = cls[:, None] + 1                    # bare class id, no template
        pad = jnp.zeros((n, 1), jnp.int32)
        tokens = jnp.concatenate([body, sep, lab, pad], axis=1).astype(jnp.int32)
        labels = jnp.roll(tokens, -1, axis=1)         # next-token targets
        mask = jnp.zeros((n, self.seq_len), jnp.float32)
        mask = mask.at[:, self.body_len].set(1.0)     # only the label position
        return {"tokens": tokens, "labels": labels, "loss_mask": mask,
                "cls": cls}

    def batch_for_step(self, step: int, batch: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return self.sample(key, batch)

    def eval_accuracy(self, cfg, forward_logits, params, key: jax.Array,
                      n: int = 256) -> float:
        """Accuracy of argmax over the class label-words at the label slot."""
        batch = self.sample(key, n)
        logits = forward_logits(params, batch)        # (n, S, V)
        slot = logits[:, self.body_len, :]
        words = self.label_word(jnp.arange(self.n_classes))
        pred = jnp.argmax(slot[:, words], axis=-1)
        return float(jnp.mean((pred == batch["cls"]).astype(jnp.float32)))

    def icl_batch(self, key: jax.Array, n: int, k_shots: int) -> dict:
        """In-context learning episodes (paper Table 1's ICL column):
        k labelled demonstrations concatenated before the test example; the
        model predicts the test label word with NO parameter updates."""
        ks = jax.random.split(key, k_shots + 1)
        demo_parts = []
        for j in range(k_shots):
            d = self.sample(ks[j], n)
            demo_parts.append(d["tokens"][:, :self.body_len + 2])  # body+sep+label
        test = self.sample(ks[-1], n)
        ctx = jnp.concatenate(
            demo_parts + [test["tokens"][:, :self.body_len + 1]], axis=1)
        slot = k_shots * (self.body_len + 2) + self.body_len
        return {"tokens": ctx, "cls": test["cls"], "slot": slot}

    def eval_icl(self, cfg, forward_logits, params, key: jax.Array,
                 k_shots: int = 4, n: int = 256) -> float:
        batch = self.icl_batch(key, n, k_shots)
        logits = forward_logits(params, batch)
        slot = logits[:, batch["slot"], :]
        words = self.label_word(jnp.arange(self.n_classes))
        pred = jnp.argmax(slot[:, words], axis=-1)
        return float(jnp.mean((pred == batch["cls"]).astype(jnp.float32)))


# --------------------------------------------------------------------------- #
# Synthetic span-extraction (SQuAD-F1 proxy, paper Table 3)
# --------------------------------------------------------------------------- #
@dataclasses.dataclass
class SpanExtraction:
    """Copy task: the answer is a span of the context marked by delimiters;
    gold output = the span tokens.  Greedy-decode F1 is the metric."""
    vocab: int = 256
    ctx_len: int = 24
    span_len: int = 4
    seed: int = 0

    @property
    def seq_len(self) -> int:
        return self.ctx_len + 2 + self.span_len

    def sample(self, key: jax.Array, n: int) -> dict:
        kc, kp = jax.random.split(key)
        ctx = jax.random.randint(kc, (n, self.ctx_len), 32, self.vocab, jnp.int32)
        start = jax.random.randint(kp, (n,), 1, self.ctx_len - self.span_len - 1)
        # mark span with delimiter token 7
        idx = jnp.arange(self.ctx_len)[None]
        in_span = (idx >= start[:, None]) & (idx < start[:, None] + self.span_len)
        gold = jnp.take_along_axis(
            ctx, start[:, None] + jnp.arange(self.span_len)[None], axis=1)
        marked = jnp.where((idx == start[:, None] - 1) |
                           (idx == start[:, None] + self.span_len), 7, ctx)
        sep = jnp.full((n, 2), 9, jnp.int32)
        tokens = jnp.concatenate([marked, sep, gold], axis=1)
        labels = jnp.roll(tokens, -1, axis=1)
        mask = jnp.zeros((n, self.seq_len), jnp.float32)
        mask = mask.at[:, self.ctx_len + 1:-1].set(1.0)   # answer positions
        return {"tokens": tokens, "labels": labels, "loss_mask": mask,
                "gold_ids": gold, "answer_start": self.ctx_len + 2,
                "in_span": in_span}

    def batch_for_step(self, step: int, batch: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        return self.sample(key, batch)
