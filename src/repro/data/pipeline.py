"""Deterministic, shardable, resumable data pipeline.

Contract: ``pipeline.batch(step)`` is a pure function of (spec, step) — no
iterator state exists, so checkpoints carry only the step counter and
restarts (including elastic restarts onto different topologies) are exactly
reproducible.  Sharding: the pipeline yields the GLOBAL batch; under pjit the
in_sharding on the batch places each row on its data-parallel owner (each
host materializes only its addressable shard via jax.make_array_from_callback
in multi-host deployments — single-host here).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import numpy as np

from repro.data.synthetic import PromptClassification, SpanExtraction, lm_batch


@dataclasses.dataclass(frozen=True)
class DataSpec:
    kind: str                   # "lm" | "prompt_cls" | "span"
    batch: int
    seq: int = 0
    vocab: int = 0
    seed: int = 0
    n_classes: int = 2
    prompt: bool = True


class Pipeline:
    def __init__(self, spec: DataSpec):
        self.spec = spec
        if spec.kind == "prompt_cls":
            self.task = PromptClassification(vocab=spec.vocab or 256,
                                             n_classes=spec.n_classes,
                                             seed=spec.seed, prompt=spec.prompt)
        elif spec.kind == "span":
            self.task = SpanExtraction(vocab=spec.vocab or 256, seed=spec.seed)
        else:
            self.task = None

    def batch(self, step: int) -> dict:
        s = self.spec
        if s.kind == "lm":
            return lm_batch(s.seed, step, s.batch, s.seq, s.vocab)
        return self.task.batch_for_step(step, s.batch)

    @property
    def seq_len(self) -> int:
        return self.spec.seq if self.spec.kind == "lm" else self.task.seq_len
