"""Batched serving engine: continuous-batching prefill + decode.

A slot-based engine in the vLLM mold, adapted to what ZO fine-tuning
produces (a model whose checkpoints are tiny seed-chains — see
checkpoint/manager.py):

  * fixed number of SLOTS (the decode batch); each slot holds one request's
    generation state;
  * ``submit`` queues requests; ``step`` runs one decode for every live slot
    (one jitted serve_step, all slots in lockstep);
  * greedy or temperature sampling; EOS or max-token termination frees the
    slot for the next queued request.

KV layout is PAGED for cache families with absolute-position rows
(dense/moe, sliding_window=0): KV lives in fixed-size token blocks owned by
a refcounted ``KVBlockPool`` (serve/paged.py), each slot holds a block
table, and a per-adapter-scoped ``RadixCache`` lets a request whose prompt
extends an already-served prefix prefill only the suffix.  Prefill is
CHUNKED and BATCHED: one admission wave's uncached suffixes are packed into
length-bucketed groups (pad widths derived from the prompt limit, powers of
two — no hard-coded width) and each group runs as ONE jitted
``chunk_prefill`` call resuming from the gathered prefix KV.  Decode
assembles each slot's logical cache row from its block table (XLA gather by
default; the ``kernels/paged`` pallas kernel under REPRO_BACKEND=pallas),
feeds the UNCHANGED registry decode, and scatters the newly written row back
into the pool.  The contract is token-identity: output ids with the prefix
cache on equal output ids with it off (test_serve_paged.py).

SWA/ring caches and recurrent families keep the legacy per-slot dense path
(their cache rows are not absolute-position addressed), with the same
bucket-derived prefill widths.

Family dispatch (cache / recurrent state / cross-attention) reuses
models.registry's prefill/decode fns.

Multi-tenant serving (``repro.serve.tenants``): ``register_adapter`` hands
the engine a named changed-leaf delta over the frozen base, requests carry an
``adapter`` name, and each slot remembers which adapter it decodes with.  One
decode step batches heterogeneous adapters:

  * selection-sized deltas on dense/moe families take the STACKED path — the
    varying leaves are stacked along a slot axis and one ``jax.vmap`` over
    slots decodes every adapter in a single call (base leaves broadcast,
    never duplicated);
  * full-tree deltas (or recurrent families) fall back to GROUPED decode —
    one call per distinct adapter, merging only that group's slot rows
    (cache/state axis 1) into the step result.

Requests with no adapter and engines with no registered adapters take the
original single-model path unchanged.  Prefix-cache scoping follows adapter
identity: each adapter name roots its own radix subtree, so KV computed
under one tenant's delta is never served as another's (or the base's).
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import bundle as make_bundle
from repro.models.config import ModelConfig
from repro.serve.paged import (KVBlockPool, RadixCache, bucket_for,
                               pow2ceil, prefill_buckets)


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    adapter: Optional[str] = None           # registered adapter name, or base
    times: dict = dataclasses.field(default_factory=dict)  # lifecycle stamps
    out_ids: list = dataclasses.field(default_factory=list)
    done: bool = False


@functools.partial(jax.jit, static_argnames=("cfg",))
def _chunk_prefill(cfg, params, tokens, ck, cv, cpos, plens):
    """Module-level jit so the compile cache is keyed on (cfg, shapes) and
    shared by every engine in the process — a second engine (or a second
    traffic wave) over the same config re-uses the bucket's executable
    instead of re-compiling per engine instance."""
    fn = make_bundle(cfg).chunk_prefill_fn()
    return fn(params, {"tokens": tokens,
                       "cache": {"k": ck, "v": cv, "pos": cpos},
                       "cache_pos": plens})


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None,
                 seed: int = 0, block: int = 16,
                 pool_blocks: Optional[int] = None, prefix_cache: bool = True,
                 paged: Optional[bool] = None, gather_impl: str = "auto"):
        assert cfg.family in ("dense", "moe", "hybrid", "ssm"), cfg.family
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.bundle = make_bundle(cfg)
        self.key = jax.random.PRNGKey(seed)

        paged_ok = cfg.family in ("dense", "moe") and cfg.sliding_window == 0
        self.paged = paged_ok if paged is None else bool(paged)
        if self.paged and not paged_ok:
            raise ValueError(
                f"paged KV requires absolute-position cache rows; family="
                f"{cfg.family!r} sliding_window={cfg.sliding_window} keeps "
                "the legacy dense-slab path (pass paged=None/False)")

        from repro.models import attention as attn_lib
        from repro.models import ssm as ssm_lib
        from repro.models import rwkv6 as rwkv_lib
        self.pool = self.radix = None
        if self.paged:
            self.cache = None                  # assembled per decode step
            self.block = block
            self._nblk_slot = -(-max_len // block)
            if pool_blocks is None:
                pool_blocks = 1 + 2 * slots * self._nblk_slot
            self.pool = KVBlockPool(cfg, pool_blocks, block, cfg.param_dtype)
            self.radix = RadixCache(self.pool) if prefix_cache else None
            self.tables: list[list] = [[] for _ in range(slots)]
            impl = gather_impl
            if impl == "auto":
                impl = os.environ.get("REPRO_BACKEND", "xla")
            self._gather_pallas = impl == "pallas"
        elif cfg.family != "ssm":
            self.cache = attn_lib.init_cache(cfg, slots, max_len,
                                             cfg.param_dtype, per_slot=True)
        else:
            self.cache = None
        if cfg.family == "hybrid":
            self.state = ssm_lib.init_ssm_state(cfg, slots)
        elif cfg.family == "ssm":
            self.state = rwkv_lib.init_rwkv_state(cfg, slots)
        else:
            self.state = None

        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros((slots,), np.int32)       # next position per slot

        # adapter identity: name -> delta; per-slot assignment; derived trees
        self.adapters: dict = {}
        self.slot_adapter: list[Optional[str]] = [None] * slots
        self._adapter_params: dict = {None: params}   # name -> full tree view
        self._mixed_fns: dict = {}       # varying-index tuple -> vmapped decode
        self._stack_sig = None           # slot_adapter snapshot the stack fits
        self._stack = None               # (vidx, [stacked leaf arrays])

        self._decode = jax.jit(self.bundle.decode_fn())
        # prefill pad widths: powers of two derived from the prompt limit
        # (replaces the old hard-coded 64-wide pad)
        self._buckets = prefill_buckets(self._prompt_limit())
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("plen",))
        self.stats = {"requests": 0, "prefill_tokens_submitted": 0,
                      "prefill_tokens_computed": 0, "prefix_hits": 0,
                      "prefix_tokens_reused": 0, "prefill_batches": 0,
                      "evicted_blocks": 0}

    # ------------------------------------------------------------------ #
    # Adapters
    # ------------------------------------------------------------------ #
    def register_adapter(self, name: str, delta) -> None:
        """Attach a named ``AdapterDelta`` over the frozen base.  Applying a
        delta is pure leaf replacement, so the per-adapter 'full tree' is a
        view sharing every unchanged buffer with the base — registering many
        adapters costs only their delta buffers.  Re-registering the same
        delta object is a no-op (the cache-hit path); re-registering a
        DIFFERENT delta under an existing name invalidates that name's radix
        scope (its cached prefix KV was computed under the old weights)."""
        if self.adapters.get(name) is delta:
            return
        if self.radix is not None and name in self.adapters:
            self.radix.drop_scope(name)
        self._adapter_params[name] = delta.apply(self.params)  # shape check
        self.adapters[name] = delta
        self._stack_sig = None          # stacked leaves may be stale

    def _params_for(self, adapter: Optional[str]):
        return self._adapter_params[adapter]

    # ------------------------------------------------------------------ #
    def _prefill_impl(self, params, tokens, plen):
        """Single-request prefill on a width-``plen`` padded prompt; returns
        (last_logits, per-layer kv (L,plen,KV,hd) pair, ssm/rwkv state)."""
        cfg = self.cfg
        from repro.models import attention as attn_lib, ssm as ssm_lib
        from repro.models import rwkv6 as rwkv_lib
        from repro.models import transformer
        if cfg.family == "ssm":
            logits, st = rwkv_lib.forward(cfg, params, tokens=tokens,
                                          state=rwkv_lib.init_rwkv_state(cfg, 1))
            return logits, None, st
        cache = attn_lib.init_cache(cfg, 1, plen, cfg.param_dtype)
        ssm_state = ssm_lib.init_ssm_state(cfg, 1) if cfg.family == "hybrid" else None
        r = transformer.forward(cfg, params, tokens=tokens, cache=cache,
                                cache_pos=None, ssm_state=ssm_state)
        return r.logits, r.cache, r.ssm_state

    def _prompt_limit(self) -> int:
        """Longest admissible prompt: the slot's KV capacity must hold the
        whole prefix (SWA caches are ``sliding_window`` wide; paged tables
        hold ceil(max_len/block) blocks) and one decode position must remain
        below ``max_len``."""
        limit = self.max_len - 1
        if not self.paged and self.cache is not None:
            limit = min(limit, int(self.cache["k"].shape[2]))
        return limit

    def submit(self, req: Request) -> None:
        limit = self._prompt_limit()
        if len(req.prompt_ids) > limit:
            # admitting would write a truncated prefix into the slot's KV
            # and decode against silently-corrupt context — refuse here
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt_ids)} tokens "
                f"exceeds this engine's limit of {limit} (max_len="
                f"{self.max_len}); raise max_len or truncate the prompt "
                "upstream")
        if req.adapter is not None and req.adapter not in self.adapters:
            raise KeyError(
                f"request {req.rid}: adapter {req.adapter!r} is not "
                f"registered (have: {sorted(self.adapters)[:8]}); call "
                "register_adapter first")
        req.times.setdefault("queued", time.perf_counter())
        self.queue.append(req)

    def _activate(self, slot: int, req: Request) -> None:
        self.active[slot] = req
        if self.slot_adapter[slot] != req.adapter:
            self.slot_adapter[slot] = req.adapter
            self._stack_sig = None
        self.pos[slot] = len(req.prompt_ids)
        req.times.setdefault("prefill", time.perf_counter())

    def _release_slot(self, slot: int) -> None:
        self.active[slot] = None
        if self.paged:
            for b in self.tables[slot]:
                self.pool.unref(b)
            self.tables[slot] = []

    def _admit(self) -> None:
        if self.paged:
            self._admit_paged()
            return
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            npr = len(req.prompt_ids)
            if self.cfg.family in ("ssm", "hybrid"):
                # recurrent state integrates every token it sees: prefill
                # EXACT length (padding after the prompt would corrupt the
                # carried state); jit buckets by prompt length.
                plen = npr
            else:
                plen = bucket_for(npr, self._buckets)
            toks = np.zeros((1, plen), np.int32)
            toks[0, :npr] = req.prompt_ids
            logits, kv, state = self._prefill(self._params_for(req.adapter),
                                              jnp.asarray(toks), plen=plen)
            # write this request's prefix into the engine-wide slot caches
            if self.cache is not None and kv is not None:
                span = min(npr, self.cache["k"].shape[2])
                self.cache["k"] = self.cache["k"].at[:, slot, :span].set(
                    kv["k"][:, 0, :span])
                self.cache["v"] = self.cache["v"].at[:, slot, :span].set(
                    kv["v"][:, 0, :span])
                self.cache["pos"] = self.cache["pos"].at[:, slot, :span].set(
                    jnp.arange(span, dtype=jnp.int32)[None])
                self.cache["pos"] = self.cache["pos"].at[:, slot, span:].set(-1)
            if self.state is not None and state is not None:
                self.state = jax.tree_util.tree_map(
                    lambda full, one: full.at[:, slot].set(one[:, 0]),
                    self.state, state)
            # first generated token from the last prompt logit
            last = logits[0, npr - 1, :self.cfg.vocab_size]
            tok = self._sample(last, req.temperature)
            req.out_ids.append(int(tok))
            self.stats["requests"] += 1
            self.stats["prefill_tokens_submitted"] += npr
            self.stats["prefill_tokens_computed"] += npr
            self.stats["prefill_batches"] += 1
            self._activate(slot, req)

    # ------------------------------------------------------------------ #
    # Paged admission: radix match -> bucketed batched suffix prefill
    # ------------------------------------------------------------------ #
    def _alloc_blocks(self, n: int) -> list:
        if n == 0:
            return []
        if self.radix is not None and n > self.pool.n_free:
            self.stats["evicted_blocks"] += self.radix.evict(
                n - self.pool.n_free)
        return self.pool.alloc(n)

    def _gather_blocks(self, tabs: np.ndarray):
        """Assemble (L, B, nblk·block, KV, hd) K and V from per-row block
        tables ``tabs (B, nblk)`` (trash-padded).  XLA advanced-indexing
        gather by default; the pallas kernel under REPRO_BACKEND=pallas."""
        L, NT, KV, hd = self.pool.k.shape
        B, nblk = tabs.shape
        flat = jnp.asarray(tabs.reshape(-1), jnp.int32)
        xk = self.pool.k.reshape(L, NT, KV * hd)
        xv = self.pool.v.reshape(L, NT, KV * hd)
        if self._gather_pallas:
            from repro.kernels.paged import paged_gather
            interpret = jax.default_backend() != "tpu"
            gk = paged_gather(xk, flat, self.block, interpret=interpret)
            gv = paged_gather(xv, flat, self.block, interpret=interpret)
        else:
            from repro.kernels.paged import paged_gather_ref
            gk = paged_gather_ref(xk, flat, self.block)
            gv = paged_gather_ref(xv, flat, self.block)
        shape = (L, B, nblk * self.block, KV, hd)
        return gk.reshape(shape), gv.reshape(shape)

    def _admit_paged(self) -> None:
        free = [s for s in range(self.slots) if self.active[s] is None]
        pending = []
        while free and self.queue:
            pending.append((free.pop(0), self.queue.popleft()))
        if not pending:
            return
        blk = self.block
        plans = []
        for slot, req in pending:
            if self.radix is not None:
                cached, nc = self.radix.match(req.adapter, req.prompt_ids)
            else:
                cached, nc = [], 0
            npr = len(req.prompt_ids)
            new_blocks = self._alloc_blocks(-(-npr // blk) - nc // blk)
            for b in cached:
                self.pool.ref(b)          # slot's own pin on shared prefix
            st = self.stats
            st["requests"] += 1
            st["prefill_tokens_submitted"] += npr
            st["prefill_tokens_computed"] += npr - nc
            if nc:
                st["prefix_hits"] += 1
                st["prefix_tokens_reused"] += nc
            plans.append((slot, req, nc, cached, new_blocks))
        groups: dict = {}
        for plan in plans:
            _, req, nc, _, _ = plan
            pcap = blk * pow2ceil(nc // blk) if nc else 0
            scap = bucket_for(len(req.prompt_ids) - nc, self._buckets)
            groups.setdefault((req.adapter, pcap, scap), []).append(plan)
        for (adapter, pcap, scap), grp in groups.items():
            self._prefill_group(adapter, pcap, scap, grp)

    def _prefill_group(self, adapter, pcap: int, scap: int, grp: list) -> None:
        """One jitted chunk-prefill for every queued request sharing
        (adapter, prefix-pad, suffix-bucket): gather cached prefix KV from
        the pool, run the batched suffix forward, scatter the new suffix KV
        into each request's fresh blocks, thread the full chunks into the
        radix cache, and activate the slots."""
        cfg = self.cfg
        blk = self.block
        L, _, KV, hd = self.pool.k.shape
        B = len(grp)
        dtype = cfg.param_dtype
        toks = np.zeros((B, scap), np.int32)
        plens = np.zeros((B,), np.int32)
        for i, (_, req, nc, _, _) in enumerate(grp):
            suf = req.prompt_ids[nc:]
            toks[i, :len(suf)] = suf
            plens[i] = nc
        if pcap:
            tabs = np.zeros((B, pcap // blk), np.int32)
            ppos = np.full((B, pcap), -1, np.int32)
            for i, (_, _, nc, cached, _) in enumerate(grp):
                tabs[i, :len(cached)] = cached
                ppos[i, :nc] = np.arange(nc, dtype=np.int32)
            pk, pv = self._gather_blocks(tabs)
            ppos_j = jnp.asarray(ppos)
        else:
            pk = jnp.zeros((L, B, 0, KV, hd), dtype)
            pv = jnp.zeros((L, B, 0, KV, hd), dtype)
            ppos_j = jnp.zeros((B, 0), jnp.int32)
        ck = jnp.concatenate([pk, jnp.zeros((L, B, scap, KV, hd), dtype)],
                             axis=2)
        cv = jnp.concatenate([pv, jnp.zeros((L, B, scap, KV, hd), dtype)],
                             axis=2)
        cpos = jnp.concatenate(
            [jnp.broadcast_to(ppos_j[None], (L, B, pcap)),
             jnp.full((L, B, scap), -1, jnp.int32)], axis=2)
        logits, cache = _chunk_prefill(cfg, self._params_for(adapter),
                                       jnp.asarray(toks), ck, cv, cpos,
                                       jnp.asarray(plens))
        self.stats["prefill_batches"] += 1
        # last real prompt logit per request, one bucketed gather + transfer
        s_last = np.array([len(req.prompt_ids) - nc - 1
                           for _, req, nc, _, _ in grp], np.int32)
        last = np.asarray(jnp.take_along_axis(
            logits, jnp.asarray(s_last)[:, None, None], axis=1
        )[:, 0, :cfg.vocab_size])
        # suffix KV landed at cache rows [plen, plen+scap) — row index IS the
        # absolute position.  Extract the whole bucketed window per request
        # (one gather, shape keyed on (pcap, scap) only) and scatter real
        # rows into each request's fresh blocks; pad rows go to the trash.
        sidx = (jnp.asarray(plens)[:, None]
                + jnp.arange(scap, dtype=jnp.int32)[None])
        sel = sidx[None, :, :, None, None]
        ksuf = jnp.take_along_axis(cache["k"], sel, axis=2)
        vsuf = jnp.take_along_axis(cache["v"], sel, axis=2)
        L_, B_ = ksuf.shape[:2]
        rows = np.zeros((B_ * scap,), np.int32)         # default: trash row 0
        for i, (slot, req, nc, cached, new_blocks) in enumerate(grp):
            npr = len(req.prompt_ids)
            req.out_ids.append(int(self._sample(jnp.asarray(last[i]),
                                                req.temperature)))
            for j in range(npr - nc):
                p = nc + j
                rows[i * scap + j] = (new_blocks[(p - nc) // blk] * blk
                                      + p % blk)
            self.tables[slot] = list(cached) + list(new_blocks)
            if self.radix is not None:
                chunk_blocks = (list(cached)
                                + list(new_blocks[:npr // blk - nc // blk]))
                if chunk_blocks:
                    self.radix.insert(req.adapter, req.prompt_ids,
                                      chunk_blocks)
            self._activate(slot, req)
        self.pool.write(rows,
                        ksuf.reshape(L_, B_ * scap, *ksuf.shape[3:]),
                        vsuf.reshape(L_, B_ * scap, *vsuf.shape[3:]))

    # ------------------------------------------------------------------ #
    # Paged decode: block-table gather -> registry decode -> row writeback
    # ------------------------------------------------------------------ #
    def _ensure_decode_blocks(self, live: list) -> None:
        blk = self.block
        for s in live:
            bi = int(self.pos[s]) // blk
            while len(self.tables[s]) <= bi:
                self.tables[s].extend(self._alloc_blocks(1))

    def _assemble_decode_cache(self) -> dict:
        """Dense (L, slots, T, KV, hd) view of every slot's block table,
        T = ceil(max_len/block)·block — STATIC, so the decode executable
        compiles once.  Inactive slots gather the trash block with pos=-1
        everywhere; their masked junk writes are never copied back."""
        blk = self.block
        T = self._nblk_slot * blk
        tabs = np.zeros((self.slots, self._nblk_slot), np.int32)
        valid = np.zeros((self.slots, 1), np.int32)
        for s in range(self.slots):
            tabs[s, :len(self.tables[s])] = self.tables[s]
            if self.active[s] is not None:
                valid[s, 0] = int(self.pos[s])
        gk, gv = self._gather_blocks(tabs)
        ar = np.arange(T, dtype=np.int32)[None]
        pos_rows = np.where(ar < valid, ar, -1)
        L = self.pool.k.shape[0]
        cpos = jnp.broadcast_to(jnp.asarray(pos_rows)[None],
                                (L, self.slots, T))
        return {"k": gk, "v": gv, "pos": cpos}

    def _writeback_decode(self, live: list) -> None:
        """Scatter each live slot's freshly written decode row (cache row
        pos[s] — absolute position) back into its tail pool block."""
        blk = self.block
        idx = jnp.asarray(live)
        pj = jnp.asarray(self.pos[np.asarray(live)])
        krow = self.cache["k"][:, idx, pj]             # (L, n, KV, hd)
        vrow = self.cache["v"][:, idx, pj]
        rows = np.array(
            [self.tables[s][int(self.pos[s]) // blk] * blk
             + int(self.pos[s]) % blk for s in live], np.int32)
        self.pool.write(rows, krow, vrow)
        self.cache = None

    def _sample(self, logits: jnp.ndarray, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature)

    # ------------------------------------------------------------------ #
    # Mixed-adapter decode
    # ------------------------------------------------------------------ #
    def _mixed_decode_fn(self, vidx: tuple):
        """One jitted vmap-over-slots decode for a given set of varying leaf
        indices.  Base leaves are closure constants (broadcast, in_axes=None
        in effect); only the ``vidx`` leaves arrive stacked with a leading
        slot axis.  Inside, each slot re-adds its size-1 batch axis so the
        registry decode runs its per-slot (continuous-batching) path."""
        if vidx in self._mixed_fns:
            return self._mixed_fns[vidx]
        decode = self.bundle.decode_fn()
        base_leaves, treedef = jax.tree_util.tree_flatten(self.params)

        def one(varying, token, cpos, cache):
            leaves = list(base_leaves)
            for i, v in zip(vidx, varying):
                leaves[i] = v
            params = jax.tree_util.tree_unflatten(treedef, leaves)
            batch = {"token": token[None],                       # (1, 1)
                     "cache_pos": cpos[None],                    # (1,)
                     "cache": jax.tree_util.tree_map(
                         lambda a: a[:, None], cache)}           # (L,1,...)
            logits, cache_out = decode(params, batch)
            return logits[0], jax.tree_util.tree_map(
                lambda a: a[:, 0], cache_out)

        fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 1), out_axes=(0, 1)))
        self._mixed_fns[vidx] = fn
        return fn

    def _stacked_leaves(self):
        """(vidx, stacked) for the current slot→adapter assignment: the union
        of the live adapters' changed-leaf indices, each stacked (slot axis 0)
        from the per-adapter value or the base leaf.  Rebuilt only when the
        assignment changes (``_stack_sig``)."""
        sig = tuple(self.slot_adapter)
        if self._stack_sig == sig:
            return self._stack
        base_leaves, _ = jax.tree_util.tree_flatten(self.params)
        names = {a for a in sig if a is not None}
        vidx = tuple(sorted({i for n in names
                             for i in self.adapters[n].indices}))
        by_name = {n: dict(zip(self.adapters[n].indices,
                               self.adapters[n].values)) for n in names}
        stacked = [jnp.stack([by_name.get(a, {}).get(i, base_leaves[i])
                              for a in sig], axis=0) for i in vidx]
        self._stack_sig, self._stack = sig, (vidx, stacked)
        return self._stack

    def _grouped_decode(self, toks, live):
        """Fallback: one decode per distinct live adapter.  Every group call
        decodes the full slot batch against the PRE-step cache/state, then
        only that group's slot rows (cache/state axis 1, logits axis 0) are
        merged into the step result — other slots' rows stay untouched."""
        groups: dict = {}
        for s in live:
            groups.setdefault(self.slot_adapter[s], []).append(s)
        pre_cache, pre_state = self.cache, self.state
        new_cache, new_state = pre_cache, pre_state
        logits_all = None
        for name, slots_g in groups.items():
            batch = {"token": jnp.asarray(toks),
                     "cache_pos": jnp.asarray(self.pos, jnp.int32)}
            params = self._params_for(name)
            if self.cfg.family == "ssm":
                batch["state"] = pre_state
                logits, state_g = self._decode(params, batch)
                cache_g = None
            elif self.cfg.family == "hybrid":
                batch["cache"], batch["state"] = pre_cache, pre_state
                logits, (cache_g, state_g) = self._decode(params, batch)
            else:
                batch["cache"] = pre_cache
                logits, cache_g = self._decode(params, batch)
                state_g = None
            idx = jnp.asarray(slots_g)
            if logits_all is None:
                logits_all = logits
            else:
                logits_all = logits_all.at[idx].set(logits[idx])
            if cache_g is not None:
                new_cache = jax.tree_util.tree_map(
                    lambda acc, out: acc.at[:, idx].set(out[:, idx]),
                    new_cache, cache_g)
            if state_g is not None:
                new_state = jax.tree_util.tree_map(
                    lambda acc, out: acc.at[:, idx].set(out[:, idx]),
                    new_state, state_g)
        self.cache, self.state = new_cache, new_state
        return logits_all

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One lockstep decode over all live slots; returns #live slots."""
        self._admit()
        live = [s for s, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        if self.paged:
            self._ensure_decode_blocks(live)
            self.cache = self._assemble_decode_cache()
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].out_ids[-1]
        names = {self.slot_adapter[s] for s in live}
        if names == {None}:
            # per-slot positions: every row decodes at its own absolute
            # position (continuous batching); inactive rows write masked junk
            # that the next admission overwrites.
            batch = {"token": jnp.asarray(toks),
                     "cache_pos": jnp.asarray(self.pos, jnp.int32)}
            if self.cfg.family == "ssm":
                batch["state"] = self.state
                logits, self.state = self._decode(self.params, batch)
            elif self.cfg.family == "hybrid":
                batch["cache"], batch["state"] = self.cache, self.state
                logits, (self.cache, self.state) = self._decode(self.params,
                                                                batch)
            else:
                batch["cache"] = self.cache
                logits, self.cache = self._decode(self.params, batch)
        elif self.cfg.family in ("dense", "moe") and not any(
                self.adapters[n].full_tree for n in names if n is not None):
            vidx, stacked = self._stacked_leaves()
            fn = self._mixed_decode_fn(vidx)
            logits, self.cache = fn(stacked, jnp.asarray(toks),
                                    jnp.asarray(self.pos, jnp.int32),
                                    self.cache)
        else:
            logits = self._grouped_decode(toks, live)
        if self.paged:
            self._writeback_decode(live)
        now = time.perf_counter()
        for s in live:
            req = self.active[s]
            tok = int(self._sample(logits[s, 0, :self.cfg.vocab_size],
                                   req.temperature))
            req.out_ids.append(tok)
            req.times.setdefault("decode", now)
            self.pos[s] += 1
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out_ids) >= req.max_new_tokens
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                req.times["done"] = time.perf_counter()
                self._release_slot(s)
        return len(live)

    def prefix_stats(self) -> dict:
        """Prefill-economy counters: tokens submitted vs actually computed,
        request-level prefix hits, blocks evicted.  ``token_reuse_rate`` is
        the fraction of submitted prompt tokens served from the radix cache."""
        st = dict(self.stats)
        st["prefix_hit_rate"] = (st["prefix_hits"] / st["requests"]
                                 if st["requests"] else 0.0)
        st["token_reuse_rate"] = (
            st["prefix_tokens_reused"] / st["prefill_tokens_submitted"]
            if st["prefill_tokens_submitted"] else 0.0)
        if self.pool is not None:
            st["pool_blocks"] = self.pool.n_blocks
            st["pool_free_blocks"] = self.pool.n_free
            st["radix_nodes"] = self.radix.n_nodes if self.radix else 0
        return st

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self.step()
