"""Batched serving engine: continuous-batching prefill + decode.

A slot-based engine in the vLLM mold, adapted to what ZO fine-tuning
produces (a model whose checkpoints are tiny seed-chains — see
checkpoint/manager.py):

  * fixed number of SLOTS (the decode batch); each slot holds one request's
    cache row and generation state;
  * ``submit`` queues requests; ``step`` runs one decode for every live slot
    (one jitted serve_step, all slots in lockstep);
  * prefill runs per-request (padded to the slot width) and writes that
    slot's cache row;
  * greedy or temperature sampling; EOS or max-token termination frees the
    slot for the next queued request.

Family dispatch (cache / recurrent state / cross-attention) reuses
models.registry's prefill/decode fns.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import bundle as make_bundle
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    out_ids: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None, seed: int = 0):
        assert cfg.family in ("dense", "moe", "hybrid", "ssm"), cfg.family
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.bundle = make_bundle(cfg)
        self.key = jax.random.PRNGKey(seed)

        from repro.models import attention as attn_lib
        from repro.models import ssm as ssm_lib
        from repro.models import rwkv6 as rwkv_lib
        if cfg.family != "ssm":
            self.cache = attn_lib.init_cache(cfg, slots, max_len,
                                             cfg.param_dtype, per_slot=True)
        else:
            self.cache = None
        if cfg.family == "hybrid":
            self.state = ssm_lib.init_ssm_state(cfg, slots)
        elif cfg.family == "ssm":
            self.state = rwkv_lib.init_rwkv_state(cfg, slots)
        else:
            self.state = None

        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros((slots,), np.int32)       # next position per slot

        self._decode = jax.jit(self.bundle.decode_fn())
        self._prefill_len = 64                         # padded prefill width
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("plen",))

    # ------------------------------------------------------------------ #
    def _prefill_impl(self, params, tokens, plen):
        """Single-request prefill on a width-``plen`` padded prompt; returns
        (last_logits, per-layer kv (L,plen,KV,hd) pair, ssm/rwkv state)."""
        cfg = self.cfg
        from repro.models import attention as attn_lib, ssm as ssm_lib
        from repro.models import rwkv6 as rwkv_lib
        from repro.models import transformer
        if cfg.family == "ssm":
            logits, st = rwkv_lib.forward(cfg, params, tokens=tokens,
                                          state=rwkv_lib.init_rwkv_state(cfg, 1))
            return logits, None, st
        cache = attn_lib.init_cache(cfg, 1, plen, cfg.param_dtype)
        ssm_state = ssm_lib.init_ssm_state(cfg, 1) if cfg.family == "hybrid" else None
        r = transformer.forward(cfg, params, tokens=tokens, cache=cache,
                                cache_pos=None, ssm_state=ssm_state)
        return r.logits, r.cache, r.ssm_state

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            if self.cfg.family in ("ssm", "hybrid"):
                # recurrent state integrates every token it sees: prefill
                # EXACT length (padding after the prompt would corrupt the
                # carried state); jit buckets by prompt length.
                plen = len(req.prompt_ids)
            else:
                plen = self._prefill_len
                while plen < len(req.prompt_ids):
                    plen *= 2
            toks = np.zeros((1, plen), np.int32)
            toks[0, :len(req.prompt_ids)] = req.prompt_ids
            logits, kv, state = self._prefill(self.params, jnp.asarray(toks),
                                              plen=plen)
            npr = len(req.prompt_ids)
            # write this request's prefix into the engine-wide slot caches
            if self.cache is not None and kv is not None:
                span = min(npr, self.cache["k"].shape[2])
                self.cache["k"] = self.cache["k"].at[:, slot, :span].set(
                    kv["k"][:, 0, :span])
                self.cache["v"] = self.cache["v"].at[:, slot, :span].set(
                    kv["v"][:, 0, :span])
                self.cache["pos"] = self.cache["pos"].at[:, slot, :span].set(
                    jnp.arange(span, dtype=jnp.int32)[None])
                self.cache["pos"] = self.cache["pos"].at[:, slot, span:].set(-1)
            if self.state is not None and state is not None:
                self.state = jax.tree_util.tree_map(
                    lambda full, one: full.at[:, slot].set(one[:, 0]),
                    self.state, state)
            # first generated token from the last prompt logit
            last = logits[0, npr - 1, :self.cfg.vocab_size]
            tok = self._sample(last, req.temperature)
            req.out_ids.append(int(tok))
            self.active[slot] = req
            self.pos[slot] = npr

    def _sample(self, logits: jnp.ndarray, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature)

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One lockstep decode over all live slots; returns #live slots."""
        self._admit()
        live = [s for s, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].out_ids[-1]
        # per-slot positions: every row decodes at its own absolute position
        # (continuous batching); inactive rows write masked junk that the
        # next admission overwrites.
        batch = {"token": jnp.asarray(toks),
                 "cache_pos": jnp.asarray(self.pos, jnp.int32)}
        if self.cfg.family == "ssm":
            batch["state"] = self.state
            logits, self.state = self._decode(self.params, batch)
        elif self.cfg.family == "hybrid":
            batch["cache"], batch["state"] = self.cache, self.state
            logits, (self.cache, self.state) = self._decode(self.params, batch)
        else:
            batch["cache"] = self.cache
            logits, self.cache = self._decode(self.params, batch)
        for s in live:
            req = self.active[s]
            tok = int(self._sample(logits[s, 0, :self.cfg.vocab_size],
                                   req.temperature))
            req.out_ids.append(tok)
            self.pos[s] += 1
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out_ids) >= req.max_new_tokens
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                self.active[s] = None
        return len(live)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self.step()
