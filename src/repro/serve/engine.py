"""Batched serving engine: continuous-batching prefill + decode.

A slot-based engine in the vLLM mold, adapted to what ZO fine-tuning
produces (a model whose checkpoints are tiny seed-chains — see
checkpoint/manager.py):

  * fixed number of SLOTS (the decode batch); each slot holds one request's
    cache row and generation state;
  * ``submit`` queues requests; ``step`` runs one decode for every live slot
    (one jitted serve_step, all slots in lockstep);
  * prefill runs per-request (padded to the slot width) and writes that
    slot's cache row;
  * greedy or temperature sampling; EOS or max-token termination frees the
    slot for the next queued request.

Family dispatch (cache / recurrent state / cross-attention) reuses
models.registry's prefill/decode fns.

Multi-tenant serving (``repro.serve.tenants``): ``register_adapter`` hands
the engine a named changed-leaf delta over the frozen base, requests carry an
``adapter`` name, and each slot remembers which adapter it decodes with.  One
decode step batches heterogeneous adapters:

  * selection-sized deltas on dense/moe families take the STACKED path — the
    varying leaves are stacked along a slot axis and one ``jax.vmap`` over
    slots decodes every adapter in a single call (base leaves broadcast,
    never duplicated);
  * full-tree deltas (or recurrent families) fall back to GROUPED decode —
    one call per distinct adapter, merging only that group's slot rows
    (cache/state axis 1) into the step result.

Requests with no adapter and engines with no registered adapters take the
original single-model path unchanged.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import bundle as make_bundle
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    rid: int
    prompt_ids: list
    max_new_tokens: int = 16
    temperature: float = 0.0
    adapter: Optional[str] = None           # registered adapter name, or base
    times: dict = dataclasses.field(default_factory=dict)  # lifecycle stamps
    out_ids: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, slots: int = 4,
                 max_len: int = 256, eos_id: Optional[int] = None, seed: int = 0):
        assert cfg.family in ("dense", "moe", "hybrid", "ssm"), cfg.family
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.bundle = make_bundle(cfg)
        self.key = jax.random.PRNGKey(seed)

        from repro.models import attention as attn_lib
        from repro.models import ssm as ssm_lib
        from repro.models import rwkv6 as rwkv_lib
        if cfg.family != "ssm":
            self.cache = attn_lib.init_cache(cfg, slots, max_len,
                                             cfg.param_dtype, per_slot=True)
        else:
            self.cache = None
        if cfg.family == "hybrid":
            self.state = ssm_lib.init_ssm_state(cfg, slots)
        elif cfg.family == "ssm":
            self.state = rwkv_lib.init_rwkv_state(cfg, slots)
        else:
            self.state = None

        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * slots
        self.pos = np.zeros((slots,), np.int32)       # next position per slot

        # adapter identity: name -> delta; per-slot assignment; derived trees
        self.adapters: dict = {}
        self.slot_adapter: list[Optional[str]] = [None] * slots
        self._adapter_params: dict = {None: params}   # name -> full tree view
        self._mixed_fns: dict = {}       # varying-index tuple -> vmapped decode
        self._stack_sig = None           # slot_adapter snapshot the stack fits
        self._stack = None               # (vidx, [stacked leaf arrays])

        self._decode = jax.jit(self.bundle.decode_fn())
        self._prefill_len = 64                         # padded prefill width
        self._prefill = jax.jit(self._prefill_impl,
                                static_argnames=("plen",))

    # ------------------------------------------------------------------ #
    # Adapters
    # ------------------------------------------------------------------ #
    def register_adapter(self, name: str, delta) -> None:
        """Attach a named ``AdapterDelta`` over the frozen base.  Applying a
        delta is pure leaf replacement, so the per-adapter 'full tree' is a
        view sharing every unchanged buffer with the base — registering many
        adapters costs only their delta buffers.  Re-registering the same
        delta object is a no-op (the cache-hit path)."""
        if self.adapters.get(name) is delta:
            return
        self._adapter_params[name] = delta.apply(self.params)  # shape check
        self.adapters[name] = delta
        self._stack_sig = None          # stacked leaves may be stale

    def _params_for(self, adapter: Optional[str]):
        return self._adapter_params[adapter]

    # ------------------------------------------------------------------ #
    def _prefill_impl(self, params, tokens, plen):
        """Single-request prefill on a width-``plen`` padded prompt; returns
        (last_logits, per-layer kv (L,plen,KV,hd) pair, ssm/rwkv state)."""
        cfg = self.cfg
        from repro.models import attention as attn_lib, ssm as ssm_lib
        from repro.models import rwkv6 as rwkv_lib
        from repro.models import transformer
        if cfg.family == "ssm":
            logits, st = rwkv_lib.forward(cfg, params, tokens=tokens,
                                          state=rwkv_lib.init_rwkv_state(cfg, 1))
            return logits, None, st
        cache = attn_lib.init_cache(cfg, 1, plen, cfg.param_dtype)
        ssm_state = ssm_lib.init_ssm_state(cfg, 1) if cfg.family == "hybrid" else None
        r = transformer.forward(cfg, params, tokens=tokens, cache=cache,
                                cache_pos=None, ssm_state=ssm_state)
        return r.logits, r.cache, r.ssm_state

    def _prompt_limit(self) -> int:
        """Longest admissible prompt: the slot cache row must hold the whole
        prefix (SWA caches are ``sliding_window`` wide) and one decode
        position must remain below ``max_len``."""
        limit = self.max_len - 1
        if self.cache is not None:
            limit = min(limit, int(self.cache["k"].shape[2]))
        return limit

    def submit(self, req: Request) -> None:
        limit = self._prompt_limit()
        if len(req.prompt_ids) > limit:
            # admitting would write a truncated prefix into the slot's cache
            # row and decode against silently-corrupt context — refuse here
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt_ids)} tokens "
                f"exceeds this engine's limit of {limit} (max_len="
                f"{self.max_len}, cache rows hold "
                f"{int(self.cache['k'].shape[2]) if self.cache is not None else self.max_len} "
                "positions); raise max_len or truncate the prompt upstream")
        if req.adapter is not None and req.adapter not in self.adapters:
            raise KeyError(
                f"request {req.rid}: adapter {req.adapter!r} is not "
                f"registered (have: {sorted(self.adapters)[:8]}); call "
                "register_adapter first")
        req.times.setdefault("queued", time.perf_counter())
        self.queue.append(req)

    def _admit(self) -> None:
        for slot in range(self.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.popleft()
            if self.cfg.family in ("ssm", "hybrid"):
                # recurrent state integrates every token it sees: prefill
                # EXACT length (padding after the prompt would corrupt the
                # carried state); jit buckets by prompt length.
                plen = len(req.prompt_ids)
            else:
                plen = self._prefill_len
                while plen < len(req.prompt_ids):
                    plen *= 2
            toks = np.zeros((1, plen), np.int32)
            toks[0, :len(req.prompt_ids)] = req.prompt_ids
            logits, kv, state = self._prefill(self._params_for(req.adapter),
                                              jnp.asarray(toks), plen=plen)
            npr = len(req.prompt_ids)
            # write this request's prefix into the engine-wide slot caches
            if self.cache is not None and kv is not None:
                span = min(npr, self.cache["k"].shape[2])
                self.cache["k"] = self.cache["k"].at[:, slot, :span].set(
                    kv["k"][:, 0, :span])
                self.cache["v"] = self.cache["v"].at[:, slot, :span].set(
                    kv["v"][:, 0, :span])
                self.cache["pos"] = self.cache["pos"].at[:, slot, :span].set(
                    jnp.arange(span, dtype=jnp.int32)[None])
                self.cache["pos"] = self.cache["pos"].at[:, slot, span:].set(-1)
            if self.state is not None and state is not None:
                self.state = jax.tree_util.tree_map(
                    lambda full, one: full.at[:, slot].set(one[:, 0]),
                    self.state, state)
            # first generated token from the last prompt logit
            last = logits[0, npr - 1, :self.cfg.vocab_size]
            tok = self._sample(last, req.temperature)
            req.out_ids.append(int(tok))
            self.active[slot] = req
            if self.slot_adapter[slot] != req.adapter:
                self.slot_adapter[slot] = req.adapter
                self._stack_sig = None
            self.pos[slot] = npr
            req.times.setdefault("prefill", time.perf_counter())

    def _sample(self, logits: jnp.ndarray, temperature: float):
        if temperature <= 0:
            return jnp.argmax(logits)
        self.key, sub = jax.random.split(self.key)
        return jax.random.categorical(sub, logits / temperature)

    # ------------------------------------------------------------------ #
    # Mixed-adapter decode
    # ------------------------------------------------------------------ #
    def _mixed_decode_fn(self, vidx: tuple):
        """One jitted vmap-over-slots decode for a given set of varying leaf
        indices.  Base leaves are closure constants (broadcast, in_axes=None
        in effect); only the ``vidx`` leaves arrive stacked with a leading
        slot axis.  Inside, each slot re-adds its size-1 batch axis so the
        registry decode runs its per-slot (continuous-batching) path."""
        if vidx in self._mixed_fns:
            return self._mixed_fns[vidx]
        decode = self.bundle.decode_fn()
        base_leaves, treedef = jax.tree_util.tree_flatten(self.params)

        def one(varying, token, cpos, cache):
            leaves = list(base_leaves)
            for i, v in zip(vidx, varying):
                leaves[i] = v
            params = jax.tree_util.tree_unflatten(treedef, leaves)
            batch = {"token": token[None],                       # (1, 1)
                     "cache_pos": cpos[None],                    # (1,)
                     "cache": jax.tree_util.tree_map(
                         lambda a: a[:, None], cache)}           # (L,1,...)
            logits, cache_out = decode(params, batch)
            return logits[0], jax.tree_util.tree_map(
                lambda a: a[:, 0], cache_out)

        fn = jax.jit(jax.vmap(one, in_axes=(0, 0, 0, 1), out_axes=(0, 1)))
        self._mixed_fns[vidx] = fn
        return fn

    def _stacked_leaves(self):
        """(vidx, stacked) for the current slot→adapter assignment: the union
        of the live adapters' changed-leaf indices, each stacked (slot axis 0)
        from the per-adapter value or the base leaf.  Rebuilt only when the
        assignment changes (``_stack_sig``)."""
        sig = tuple(self.slot_adapter)
        if self._stack_sig == sig:
            return self._stack
        base_leaves, _ = jax.tree_util.tree_flatten(self.params)
        names = {a for a in sig if a is not None}
        vidx = tuple(sorted({i for n in names
                             for i in self.adapters[n].indices}))
        by_name = {n: dict(zip(self.adapters[n].indices,
                               self.adapters[n].values)) for n in names}
        stacked = [jnp.stack([by_name.get(a, {}).get(i, base_leaves[i])
                              for a in sig], axis=0) for i in vidx]
        self._stack_sig, self._stack = sig, (vidx, stacked)
        return self._stack

    def _grouped_decode(self, toks, live):
        """Fallback: one decode per distinct live adapter.  Every group call
        decodes the full slot batch against the PRE-step cache/state, then
        only that group's slot rows (cache/state axis 1, logits axis 0) are
        merged into the step result — other slots' rows stay untouched."""
        groups: dict = {}
        for s in live:
            groups.setdefault(self.slot_adapter[s], []).append(s)
        pre_cache, pre_state = self.cache, self.state
        new_cache, new_state = pre_cache, pre_state
        logits_all = None
        for name, slots_g in groups.items():
            batch = {"token": jnp.asarray(toks),
                     "cache_pos": jnp.asarray(self.pos, jnp.int32)}
            params = self._params_for(name)
            if self.cfg.family == "ssm":
                batch["state"] = pre_state
                logits, state_g = self._decode(params, batch)
                cache_g = None
            elif self.cfg.family == "hybrid":
                batch["cache"], batch["state"] = pre_cache, pre_state
                logits, (cache_g, state_g) = self._decode(params, batch)
            else:
                batch["cache"] = pre_cache
                logits, cache_g = self._decode(params, batch)
                state_g = None
            idx = jnp.asarray(slots_g)
            if logits_all is None:
                logits_all = logits
            else:
                logits_all = logits_all.at[idx].set(logits[idx])
            if cache_g is not None:
                new_cache = jax.tree_util.tree_map(
                    lambda acc, out: acc.at[:, idx].set(out[:, idx]),
                    new_cache, cache_g)
            if state_g is not None:
                new_state = jax.tree_util.tree_map(
                    lambda acc, out: acc.at[:, idx].set(out[:, idx]),
                    new_state, state_g)
        self.cache, self.state = new_cache, new_state
        return logits_all

    # ------------------------------------------------------------------ #
    def step(self) -> int:
        """One lockstep decode over all live slots; returns #live slots."""
        self._admit()
        live = [s for s, r in enumerate(self.active) if r is not None]
        if not live:
            return 0
        toks = np.zeros((self.slots, 1), np.int32)
        for s in live:
            toks[s, 0] = self.active[s].out_ids[-1]
        names = {self.slot_adapter[s] for s in live}
        if names == {None}:
            # per-slot positions: every row decodes at its own absolute
            # position (continuous batching); inactive rows write masked junk
            # that the next admission overwrites.
            batch = {"token": jnp.asarray(toks),
                     "cache_pos": jnp.asarray(self.pos, jnp.int32)}
            if self.cfg.family == "ssm":
                batch["state"] = self.state
                logits, self.state = self._decode(self.params, batch)
            elif self.cfg.family == "hybrid":
                batch["cache"], batch["state"] = self.cache, self.state
                logits, (self.cache, self.state) = self._decode(self.params,
                                                                batch)
            else:
                batch["cache"] = self.cache
                logits, self.cache = self._decode(self.params, batch)
        elif self.cfg.family in ("dense", "moe") and not any(
                self.adapters[n].full_tree for n in names if n is not None):
            vidx, stacked = self._stacked_leaves()
            fn = self._mixed_decode_fn(vidx)
            logits, self.cache = fn(stacked, jnp.asarray(toks),
                                    jnp.asarray(self.pos, jnp.int32),
                                    self.cache)
        else:
            logits = self._grouped_decode(toks, live)
        now = time.perf_counter()
        for s in live:
            req = self.active[s]
            tok = int(self._sample(logits[s, 0, :self.cfg.vocab_size],
                                   req.temperature))
            req.out_ids.append(tok)
            req.times.setdefault("decode", now)
            self.pos[s] += 1
            if ((self.eos_id is not None and tok == self.eos_id)
                    or len(req.out_ids) >= req.max_new_tokens
                    or self.pos[s] >= self.max_len - 1):
                req.done = True
                req.times["done"] = time.perf_counter()
                self.active[s] = None
        return len(live)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if not self.queue and all(r is None for r in self.active):
                break
            self.step()
