"""Serving layer: the continuous-batching engine plus the ledger-native
multi-tenant adapter runtime.

``repro.serve.engine`` is the slot-based decode engine (one frozen model,
continuous batching).  ``repro.serve.tenants`` is what makes it a
*multi-tenant* product: a MeZO fine-tune is fully determined by its scalar
trajectory ledger (paper §2.1), so per-user adapters are cheap enough to
store by the thousands and are materialized on demand by ledger replay —
content-hash keyed, delta-cached, compacted, and batch-served across
heterogeneous adapters in one decode step.
"""
from repro.serve.engine import Request, ServeEngine

__all__ = ["Request", "ServeEngine"]
