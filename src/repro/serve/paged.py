"""Paged KV-block pool + radix prefix cache for the serving engine.

The engine's original KV layout was a dense per-slot slab — every slot owns
``(L, max_len, KV, hd)`` rows whether its request uses 5 tokens or 250, and
every request recomputes its full prompt even when thousands of requests
share the same task template.  This module replaces that with the vLLM-style
paged layout, sized for what ZO serving actually sees (few-hundred-token
classification prompts dominated by shared templates — PAPER §3):

``KVBlockPool``
    One pool tensor per K and V, ``(L, n_blocks·block, KV, hd)``: KV lives in
    fixed-size token *blocks* (16/32 tokens).  Blocks are refcounted; a block
    is shared freely between a decoding slot and the prefix cache (and
    between slots) because prefix KV is immutable once written — decode
    writes always land in a block owned by exactly one slot (the tail block
    the slot allocated for itself).  Block 0 is the *trash block*: it is
    permanently pinned and absorbs the masked junk writes of inactive decode
    rows, so block tables can always be padded to a static width.

``RadixCache``
    A trie over ``block``-sized token chunks whose nodes each pin one pool
    block (the trie holds its own ref).  Lookup walks the prompt chunk by
    chunk and returns the longest cached prefix — ALWAYS strictly shorter
    than the prompt, so prefill still produces at least one real suffix
    position to sample the first token from.  Scoping rule: every adapter
    identity gets its own root (``scope`` = adapter name, ``None`` = base),
    because adapter deltas change attention projections — a prefix computed
    under tenant A's LoRA is NOT the base model's prefix for those tokens,
    and must never be served as one.  Eviction is LRU over *unpinned leaves*:
    a node can be dropped only if it has no children and no one but the trie
    holds its block (``refs == 1``) — interior nodes and blocks live in some
    slot's table are never touched.

Bucket helpers (``pow2ceil`` / ``prefill_buckets``) replace the old
hard-coded ``_prefill_len = 64``: pad widths are powers of two derived from
the engine's actual prompt limit, so a 65-token prompt compiles the 128
bucket instead of silently interacting with a fixed 64.
"""
from __future__ import annotations

import itertools

import jax
import jax.numpy as jnp
import numpy as np


class PoolExhaustedError(RuntimeError):
    """The pool has fewer free blocks than an allocation needs — after radix
    eviction has already been tried.  Raise loudly rather than silently
    dropping KV: the caller must raise ``pool_blocks`` or lower ``slots``."""


def pow2ceil(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    p = 1
    while p < n:
        p *= 2
    return p


def prefill_buckets(limit: int, lo: int = 16) -> tuple:
    """Static pad widths for prefill, derived from the engine's prompt
    ``limit`` (not a magic constant): powers of two from ``lo`` up to
    ``pow2ceil(limit)``.  Every admissible prompt maps to the first bucket
    that holds it, so the jit cache is bounded at log2(limit) entries."""
    top = pow2ceil(max(limit, lo))
    return tuple(itertools.takewhile(
        lambda b: b <= top, (lo * 2 ** i for i in range(64))))


def bucket_for(n: int, buckets: tuple) -> int:
    for b in buckets:
        if b >= n:
            return b
    raise ValueError(f"length {n} exceeds largest prefill bucket "
                     f"{buckets[-1]} (buckets={buckets})")


# --------------------------------------------------------------------------- #
class KVBlockPool:
    """Refcounted pool of fixed-size KV token blocks.

    ``k``/``v`` are ``(L, n_blocks·block, KV, hd)``; block ``b`` owns token
    rows ``[b·block, (b+1)·block)``.  Refcounts are host-side ints: 0 = free,
    and a block may be referenced simultaneously by the radix trie and any
    number of slot tables.  Block 0 (``trash``) is pinned forever and used to
    pad block tables to static shapes.
    """

    def __init__(self, cfg, n_blocks: int, block: int, dtype):
        assert n_blocks >= 2, "pool needs the trash block plus one real block"
        L, KV, hd = cfg.n_layers, cfg.kv_heads, cfg.hd
        self.block = block
        self.n_blocks = n_blocks
        self.k = jnp.zeros((L, n_blocks * block, KV, hd), dtype)
        self.v = jnp.zeros((L, n_blocks * block, KV, hd), dtype)
        self.refs = [0] * n_blocks
        self.refs[0] = 1                          # trash: pinned forever
        self.trash = 0
        self._free = list(range(n_blocks - 1, 0, -1))

    @property
    def n_free(self) -> int:
        return len(self._free)

    def alloc(self, n: int) -> list:
        """Take ``n`` blocks (each with refcount 1).  Raises
        ``PoolExhaustedError`` if the free list is short — callers evict
        through the radix cache first and re-try."""
        if n > len(self._free):
            raise PoolExhaustedError(
                f"need {n} KV blocks, only {len(self._free)} of "
                f"{self.n_blocks} free (block={self.block} tokens); raise "
                "pool_blocks or let the prefix cache evict")
        out = [self._free.pop() for _ in range(n)]
        for b in out:
            self.refs[b] = 1
        return out

    def ref(self, b: int) -> None:
        assert self.refs[b] > 0, f"ref on free block {b}"
        self.refs[b] += 1

    def unref(self, b: int) -> None:
        assert self.refs[b] > 0, f"unref on free block {b}"
        self.refs[b] -= 1
        if self.refs[b] == 0:
            self._free.append(b)

    def write(self, rows: np.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> None:
        """Scatter token rows: ``k``/``v`` are ``(L, n, KV, hd)`` landing at
        pool token-row indices ``rows (n,)`` (row = block_id·block + offset).

        The row count is padded to a power of two with TRASH-block rows
        (junk by contract) so the jitted scatter compiles O(log) executables
        instead of one per distinct suffix-length sum."""
        n = int(rows.shape[0])
        m = pow2ceil(max(n, 1))
        if m != n:
            rows = np.concatenate(
                [np.asarray(rows, np.int32),
                 np.zeros((m - n,), np.int32)])        # trash rows
            pad = ((0, 0), (0, m - n)) + ((0, 0),) * (k.ndim - 2)
            k = jnp.pad(k, pad)
            v = jnp.pad(v, pad)
        idx = jnp.asarray(rows, jnp.int32)
        self.k = _scatter_rows(self.k, idx, k)
        self.v = _scatter_rows(self.v, idx, v)


@jax.jit
def _scatter_rows(dst, idx, src):
    """``dst (L, NT, ...)[:, idx] = src`` — jitted so repeated pool writes of
    a bucketed shape reuse one executable.  Duplicate indices (trash-row
    padding) may land in any order; the trash block holds junk by contract."""
    return dst.at[:, idx].set(src, unique_indices=False)


# --------------------------------------------------------------------------- #
class _Node:
    __slots__ = ("chunk", "block", "children", "parent", "last_use")

    def __init__(self, chunk, block, parent):
        self.chunk = chunk          # tuple of ``block``-many token ids
        self.block = block          # pool block id this node pins
        self.children = {}          # chunk tuple -> _Node
        self.parent = parent        # _Node | scope root dict sentinel (None)
        self.last_use = 0


class RadixCache:
    """Prefix trie over block-sized token chunks, scoped per adapter.

    Each node pins exactly one pool block (the trie's own ref).  ``match``
    returns (cached block ids, cached token count) for the longest cached
    prefix that still leaves >= 1 prompt token uncached; ``insert`` records a
    freshly prefilled prompt's full chunks; ``evict`` releases LRU unpinned
    leaves.  All bookkeeping is host-side — the KV bytes themselves never
    move on a hit.
    """

    def __init__(self, pool: KVBlockPool):
        self.pool = pool
        self._roots: dict = {}               # scope -> {chunk: _Node}
        self._clock = 0
        self.n_nodes = 0

    def _tick(self) -> int:
        self._clock += 1
        return self._clock

    def match(self, scope, tokens) -> tuple:
        """Longest cached prefix of ``tokens`` under ``scope``; strictly
        shorter than the prompt so at least one suffix token remains to
        prefill (the first sampled token needs a real logit row)."""
        blk = self.pool.block
        cur = self._roots.get(scope)
        blocks: list = []
        end = 0
        t = self._tick()
        while cur is not None and end + blk < len(tokens):
            child = cur.get(tuple(tokens[end:end + blk]))
            if child is None:
                break
            child.last_use = t
            blocks.append(child.block)
            end += blk
            cur = child.children
        return blocks, end

    def insert(self, scope, tokens, chunk_blocks: list) -> None:
        """Record a prefilled prompt: ``chunk_blocks[i]`` is the pool block
        holding tokens ``[i·blk, (i+1)·blk)`` (matched prefix blocks first,
        then the slot's fresh blocks).  Existing nodes are kept (a same-wave
        duplicate keeps its private copy, unshared); new nodes take one trie
        ref on their block."""
        blk = self.pool.block
        cur = self._roots.setdefault(scope, {})
        parent = None
        t = self._tick()
        for i, b in enumerate(chunk_blocks):
            chunk = tuple(tokens[i * blk:(i + 1) * blk])
            node = cur.get(chunk)
            if node is None:
                node = _Node(chunk, b, parent)
                cur[chunk] = node
                self.pool.ref(b)
                self.n_nodes += 1
            node.last_use = t
            parent = node
            cur = node.children

    # -- eviction ---------------------------------------------------------- #
    def _leaves(self):
        out = []
        stack = [n for root in self._roots.values() for n in root.values()]
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            else:
                out.append(n)
        return out

    def evict(self, n_blocks: int) -> int:
        """Release up to ``n_blocks`` pool blocks, LRU leaves first.  A leaf
        is evictable only when the trie holds the ONLY ref on its block
        (``refs == 1``): blocks pinned by a live slot's table — or interior
        nodes, which always have children — are never released.  Removing a
        leaf may expose its parent as the next candidate."""
        freed = 0
        while freed < n_blocks:
            cands = [n for n in self._leaves() if self.pool.refs[n.block] == 1]
            if not cands:
                break
            victim = min(cands, key=lambda n: n.last_use)
            holder = (victim.parent.children if victim.parent is not None
                      else self._first_root_holding(victim))
            del holder[victim.chunk]
            self.pool.unref(victim.block)
            self.n_nodes -= 1
            freed += 1
        return freed

    def _first_root_holding(self, node: "_Node") -> dict:
        for root in self._roots.values():
            if root.get(node.chunk) is node:
                return root
        raise KeyError("radix node detached from every scope root")

    def drop_scope(self, scope) -> int:
        """Invalidate every cached prefix of one adapter identity (called
        when an adapter re-registers with different weights — its old KV is
        wrong, not merely stale).  Blocks still pinned by live slots survive
        in the pool until those slots release them."""
        root = self._roots.pop(scope, None)
        if root is None:
            return 0
        dropped = 0
        stack = list(root.values())
        while stack:
            n = stack.pop()
            stack.extend(n.children.values())
            self.pool.unref(n.block)
            self.n_nodes -= 1
            dropped += 1
        return dropped
