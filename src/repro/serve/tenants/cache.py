"""Byte-budgeted LRU cache of materialized adapter deltas.

Materializing an adapter means replaying its ledger (O(steps) ``apply_rank1``
folds) or applying its compacted delta+tail; both are orders of magnitude
more expensive than a slot admission.  ``DeltaCache`` keeps the materialized
``AdapterDelta`` buffers of the hottest adapters resident so a warm adapter
swap costs *zero* replay folds — the cache hands back the exact buffers the
first materialization produced, and applying them is pure leaf replacement
(``AdapterDelta.apply``).

Keys are ``AdapterStore`` keys — ``(ledger content hash, n_records)`` — so
cache identity inherits the replay-determinism invariant: a hit can never
return stale weights for a retrained tenant, because retraining changes the
ledger and therefore the key.

Accounting is in bytes of delta buffers (``AdapterDelta.nbytes``), not entry
counts: a peft(lora) delta is ~3% of param bytes while a full-tune delta is
~100%, and a budget in entries would let a handful of full-tune tenants evict
thirty LoRA tenants' worth of reuse.  Eviction is LRU; an entry larger than
the whole budget is refused outright (``oversize``) rather than evicting
everything else for a single tenant.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional

from repro.serve.tenants.store import AdapterDelta


class DeltaCache:
    """LRU over ``AdapterDelta`` values with a byte budget.

    ``get`` / ``put`` are the whole interface a runtime needs; ``stats``
    feeds the serving bench (hit rate is its headline number)."""

    def __init__(self, budget_bytes: int):
        if budget_bytes <= 0:
            raise ValueError(f"budget_bytes must be positive, got {budget_bytes}")
        self.budget_bytes = int(budget_bytes)
        self._entries: OrderedDict = OrderedDict()   # key -> AdapterDelta
        self.bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.oversize = 0

    def get(self, key) -> Optional[AdapterDelta]:
        """The delta for ``key`` (refreshing its recency), or ``None``."""
        hit = self._entries.get(key)
        if hit is None:
            self.misses += 1
            return None
        self._entries.move_to_end(key)
        self.hits += 1
        return hit

    def put(self, key, delta: AdapterDelta) -> bool:
        """Insert ``delta``, evicting least-recently-used entries until the
        budget holds.  Returns False (and counts ``oversize``) when the delta
        alone exceeds the whole budget — caching it would evict every other
        tenant for one adapter's benefit."""
        nb = delta.nbytes
        if nb > self.budget_bytes:
            self.oversize += 1
            return False
        if key in self._entries:
            self.bytes -= self._entries.pop(key).nbytes
        self._entries[key] = delta
        self.bytes += nb
        while self.bytes > self.budget_bytes:
            _, evicted = self._entries.popitem(last=False)
            self.bytes -= evicted.nbytes
            self.evictions += 1
        return True

    def __contains__(self, key) -> bool:
        """Budget-planning peek — does NOT count as a hit/miss or refresh
        recency (use ``get`` on the serving path)."""
        return key in self._entries

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def stats(self) -> dict:
        total = self.hits + self.misses
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "oversize": self.oversize,
                "entries": len(self._entries), "bytes": self.bytes,
                "budget_bytes": self.budget_bytes,
                "hit_rate": (self.hits / total) if total else 0.0}
