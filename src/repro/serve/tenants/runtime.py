"""TenantRuntime: store + cache + compaction wired to a serving engine.

The runtime owns the adapter lifecycle on a serving host:

    store.put(tenant, ledger)            # register the 0.1 MB artifact
    runtime.delta(tenant)                # materialize (replay) or cache-hit
    engine.register_adapter(t, delta)    # hand the engine its leaf delta
    runtime.compact_tenant(tenant)       # fold a long ledger to O(tail)

Materialization is ledger replay through the run's recorded composition —
``composition_for_ledger`` rebuilds the exact optimizer (estimator family,
backend, selection, batch_seeds) from the MZOL header, so the runtime uses
the SAME ``PerturbBackend.apply_rank1`` write path training used and a cached
delta is bitwise-equal to a fresh replay.

``records_replayed`` counts every ledger record the runtime folded; the
serving bench asserts it does NOT move on cache hits — the warm path's cost
is leaf replacement only, zero ``apply_rank1`` folds.
"""
from __future__ import annotations

from typing import Callable, Optional

from repro.core.trajectory import TrajectoryLedger, replay
from repro.serve.tenants.cache import DeltaCache
from repro.serve.tenants.compact import CompactedAdapter, compact, materialize
from repro.serve.tenants.store import (AdapterDelta, AdapterStore,
                                       LedgerHashMismatchError)
from repro.tree_utils import PyTree


def composition_for_ledger(led: TrajectoryLedger):
    """The ZO composition whose replay reproduces ``led``'s run, rebuilt from
    the header coordinates alone (the launcher pattern, shared here so every
    serving path derives it identically).

    The header's ``backend`` field is the *stream id* — registry name plus a
    z-generator version suffix (``"pallas+z2"``) — while ``zo.mezo/fzoo``
    take the registry name; strip the suffix for construction and let
    ``check_replay_backend`` still compare full stream ids at replay time,
    so a ledger from a since-revised z generator refuses rather than
    silently diverging."""
    from repro import zo
    sel = None
    if led.selection != "full" or led.sel_phase:
        from repro.select import parse_selection
        sel = parse_selection(led.selection)._replace(
            phase_offset=int(led.sel_phase))
    backend = led.backend.partition("+z")[0]
    if led.batch_seeds > 1:
        return zo.fzoo(batch_seeds=led.batch_seeds, backend=backend,
                       selection=sel)
    return zo.mezo(backend=backend, selection=sel)


class TenantRuntime:
    """Materializes per-tenant serving deltas from stored ledgers.

    ``base_params`` is the frozen tree the serving engine runs (deltas are
    diffed against it).  ``params0_fn(ledger)`` rebuilds the tenant's
    *training* start tree — for peft(lora) runs that is the merged
    ``{"base": ..., "lora": init}`` tree, seeded from the ledger's
    ``base_seed`` so the ledger alone determines the adapter.  ``serve_map``
    maps a tuned training tree to the serving tree (e.g. ``merge_lora``);
    identity for runs that train the serving tree directly."""

    def __init__(self, base_params: PyTree, store: AdapterStore,
                 cache: Optional[DeltaCache] = None,
                 params0_fn: Optional[Callable] = None,
                 serve_map: Optional[Callable] = None,
                 optimizer_fn: Callable = composition_for_ledger):
        self.base_params = base_params
        self.store = store
        self.cache = cache
        self.params0_fn = params0_fn or (lambda led: base_params)
        self.serve_map = serve_map or (lambda tree: tree)
        self.optimizer_fn = optimizer_fn
        self.records_replayed = 0        # apply_rank1 fold counter (bench)
        self.materializations = 0        # cold/compacted materializations

    # ------------------------------------------------------------------ #
    def delta(self, tenant) -> AdapterDelta:
        """The tenant's serving-tree delta: cache hit (zero folds) or
        materialization (compacted O(tail) if a record exists, else full
        ledger replay), diffed against ``base_params`` and cached."""
        key = self.store.key(tenant)
        if self.cache is not None:
            hit = self.cache.get(key)
            if hit is not None:
                return hit
        delta = AdapterDelta.diff(self.base_params,
                                  self.serve_map(self._materialize(tenant)))
        if self.cache is not None:
            self.cache.put(key, delta)
        return delta

    def _materialize(self, tenant) -> PyTree:
        led = self.store.ledger(tenant)
        opt = self.optimizer_fn(led)
        params0 = self.params0_fn(led)
        comp = self.store.compacted(tenant)
        self.materializations += 1
        if comp is not None:
            tuned = materialize(params0, comp, opt, ledger=led)
            self.records_replayed += len(comp.tail)
        else:
            tuned = replay(params0, led, opt)
            self.records_replayed += len(led)
        return tuned

    def warmup(self, tenants=None) -> int:
        """Pre-materialize ``tenants`` (default: every registered tenant, in
        sorted order — under a tight budget the LAST warmed tenants stay
        resident).  Returns how many deltas were materialized or touched."""
        names = list(tenants) if tenants is not None else self.store.tenants()
        for t in names:
            self.delta(t)
        return len(names)

    def compact_tenant(self, tenant, keep_tail: int = 64) -> CompactedAdapter:
        """Fold the tenant's stored ledger (one full prefix replay now, every
        later cold materialization O(tail)) and attach the record."""
        led = self.store.ledger(tenant)
        comp = compact(self.params0_fn(led), led, self.optimizer_fn(led),
                       keep_tail=keep_tail)
        self.records_replayed += comp.upto
        self.store.put_compacted(tenant, comp)
        return comp

    @property
    def stats(self) -> dict:
        out = {"records_replayed": self.records_replayed,
               "materializations": self.materializations,
               "tenants": len(self.store),
               "store_bytes": self.store.nbytes()}
        if self.cache is not None:
            out.update(self.cache.stats)
        return out
