"""Ledger compaction: fold a long trajectory into one delta + replayable tail.

A tenant who fine-tuned for 10k steps costs 10k ``apply_rank1`` folds per cold
materialization.  Compaction replays the first ``len − keep_tail`` records
ONCE, stores the resulting changed-leaf delta, and keeps the remaining records
as a *tail* ledger — so every later materialization is one leaf-replacement
apply plus O(tail) folds.

The construction is bitwise by design, not by tolerance:

* the prefix delta is extracted from a replay through the SAME
  ``PerturbBackend.apply_rank1`` path training used, and applying it is pure
  leaf replacement (no float re-arithmetic);
* the tail is ``ledger.slice(upto)`` — records keep their original step
  indices, so the tail's seed folds are the exact folds the full replay would
  have performed for those steps.

Hence ``materialize(params0, compact(params0, led, opt), opt)`` equals
``replay(params0, led, opt)`` bit for bit (test-enforced on xla AND
pallas-interpret).

Identity is hash-anchored: the record carries the full ledger's content hash
(its ``AdapterStore`` key) and the hash of the folded prefix.  ``materialize``
re-checks the prefix hash whenever the caller supplies the ledger it believes
the record compacts — a record paired with a retrained or truncated ledger
refuses (``LedgerHashMismatchError``) instead of silently serving weights the
ledger does not describe.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

from repro.core.trajectory import TrajectoryLedger, replay
from repro.serve.tenants.store import AdapterDelta, LedgerHashMismatchError
from repro.tree_utils import PyTree


class CompactedAdapter(NamedTuple):
    """A folded ledger prefix + its replayable tail.

    ``full_hash`` keys the record to the complete ledger it compacts (the
    adapter's store key); ``prefix_hash`` = ``ledger.content_hash(upto)``
    pins exactly which records the delta folded."""
    full_hash: str
    prefix_hash: str
    upto: int
    delta: AdapterDelta          # changed leaves of replay(params0, led[:upto])
    tail: TrajectoryLedger       # led[upto:], original step indices

    @property
    def nbytes(self) -> int:
        """Stored footprint: delta buffers + serialized tail (the number
        bench_storage compares against the raw ledger)."""
        return self.delta.nbytes + self.tail.nbytes()


def compact(params0: PyTree, ledger: TrajectoryLedger, optimizer,
            keep_tail: int = 64) -> CompactedAdapter:
    """Fold ``ledger``'s first ``len − keep_tail`` records into a stored
    delta (one full replay, paid once) and keep the last ``keep_tail`` as the
    replayable tail.  ``keep_tail ≥ len`` degenerates to an empty fold — the
    record is still valid, just all-tail."""
    if keep_tail < 0:
        raise ValueError(f"keep_tail must be >= 0, got {keep_tail}")
    upto = max(0, len(ledger) - int(keep_tail))
    mid = replay(params0, ledger, optimizer, to_idx=upto)
    return CompactedAdapter(
        full_hash=ledger.content_hash(),
        prefix_hash=ledger.content_hash(upto),
        upto=upto,
        delta=AdapterDelta.diff(params0, mid),
        tail=ledger.slice(upto))


def materialize(params0: PyTree, compacted: CompactedAdapter, optimizer,
                ledger: Optional[TrajectoryLedger] = None) -> PyTree:
    """Reconstruct the tuned parameters from a compaction record in O(tail):
    apply the stored prefix delta, then replay the tail through the same
    optimizer composition.  Pass the ``ledger`` the record is believed to
    compact to get the hash cross-check (refuses on mismatch)."""
    if ledger is not None:
        if ledger.content_hash() != compacted.full_hash:
            raise LedgerHashMismatchError(
                f"compaction record folds a ledger with content hash "
                f"{compacted.full_hash[:12]}… but was asked to materialize "
                f"one hashing to {ledger.content_hash()[:12]}…; the tenant "
                "was retrained — recompact instead of serving stale weights")
        if ledger.content_hash(compacted.upto) != compacted.prefix_hash:
            raise LedgerHashMismatchError(
                f"compaction record folded records [0, {compacted.upto}) "
                f"with hash {compacted.prefix_hash[:12]}… but the supplied "
                "ledger's prefix hashes differently; refusing to splice a "
                "delta onto a tail it does not precede")
    mid = compacted.delta.apply(params0)
    if len(compacted.tail) == 0:
        return mid
    return replay(mid, compacted.tail, optimizer)
