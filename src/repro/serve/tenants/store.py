"""Content-addressed adapter storage: the ledger IS the fine-tune.

A MeZO fine-tune is fully determined by its trajectory ledger — a few KB of
seeds + projected-grad scalars (paper §2.1) — so a *store of per-tenant
fine-tunes* is a store of ledger blobs.  ``AdapterStore`` keeps them
content-addressed: the key of an adapter is ``(ledger.content_hash(), steps)``
— two tenants whose ledgers would replay the identical delta share a key (and
therefore share every cache entry downstream).

``AdapterDelta`` is the materialized form: the subset of parameter leaves a
replayed ledger actually changed, stored by flattened leaf index.  It is
*selection-sized* — a ``peft(lora)`` fine-tune's delta holds only the leaves
the LoRA merge touches; a ``block_cyclic``/``leaves`` fine-tune's delta holds
only the selected leaves — which is what makes caching thousands of
materialized adapters per host feasible.  Applying a delta is pure leaf
replacement (zero arithmetic, zero ``apply_rank1`` folds), so a cached delta
is bitwise-identical to the fresh replay it was extracted from *by
construction*.

``LedgerHashMismatchError`` joins the Backend/Plan/SelectionMismatchError
refusal family: any path that pairs stored artifacts by content hash (blob
integrity on read, compaction-record vs ledger prefix) refuses loudly on
mismatch instead of silently serving a different tenant's weights.
"""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trajectory import TrajectoryLedger
from repro.tree_utils import PyTree

AdapterKey = tuple  # (content_hash: str, n_records: int)


class LedgerHashMismatchError(RuntimeError):
    """Two artifacts that must describe the same recorded trajectory (a
    stored blob and its content-hash key; a compaction record and the ledger
    prefix it folded) disagree.  Continuing would silently materialize — and
    serve — different parameters than the tenant's ledger describes, so
    refuse instead (mirrors Backend/Plan/SelectionMismatchError)."""


class AdapterDelta(NamedTuple):
    """The changed-leaf subset of a materialized adapter.

    ``indices`` are flattened-leaf positions (``jax.tree_util.tree_flatten``
    order of the tree it was diffed against), ``values`` the leaf arrays at
    those positions.  ``n_leaves`` / ``n_float_leaves`` record the diffed
    tree's totals so ``full_tree`` (every floating leaf changed — the signal
    the serving engine's batched decode falls back to per-adapter grouping
    on) is decidable without the tree."""
    indices: tuple
    values: tuple
    n_leaves: int
    n_float_leaves: int

    @classmethod
    def diff(cls, base: PyTree, tuned: PyTree) -> "AdapterDelta":
        """Extract the leaves of ``tuned`` that differ from ``base`` by even
        one bit.  Replay only ever writes the leaves it updates, so the diff
        recovers exactly the replayed support; a selected leaf that happens
        to round-trip to its base value is *safely* droppable (applying the
        delta still reproduces ``tuned`` bitwise)."""
        b_leaves, b_def = jax.tree_util.tree_flatten(base)
        t_leaves, t_def = jax.tree_util.tree_flatten(tuned)
        if b_def != t_def:
            raise ValueError("AdapterDelta.diff needs structurally identical "
                             f"trees; got {b_def} vs {t_def}")
        idx, vals = [], []
        n_float = 0
        for i, (b, t) in enumerate(zip(b_leaves, t_leaves)):
            if jnp.issubdtype(jnp.asarray(b).dtype, jnp.floating):
                n_float += 1
            nb, nt = np.asarray(b), np.asarray(t)
            if nb.shape != nt.shape or nb.dtype != nt.dtype \
                    or nb.tobytes() != nt.tobytes():
                idx.append(i)
                vals.append(jnp.asarray(t))
        return cls(tuple(idx), tuple(vals), len(b_leaves), n_float)

    @property
    def nbytes(self) -> int:
        """Bytes the delta's buffers occupy — the unit the ``DeltaCache``
        budget is accounted in."""
        return sum(int(v.size) * v.dtype.itemsize for v in self.values)

    @property
    def full_tree(self) -> bool:
        """True when every floating leaf changed (a full fine-tune): the
        batched-decode stacking would duplicate the whole model per slot, so
        the engine groups these per adapter instead."""
        return len(self.indices) >= self.n_float_leaves

    def apply(self, base: PyTree) -> PyTree:
        """``base`` with the delta's leaves swapped in — pure structural leaf
        replacement (no copies, no arithmetic): the returned tree references
        the stored buffers directly, so applying a cached delta costs zero
        ``apply_rank1`` folds and zero parameter-sized traffic."""
        leaves, treedef = jax.tree_util.tree_flatten(base)
        for i, v in zip(self.indices, self.values):
            if leaves[i].shape != v.shape or leaves[i].dtype != v.dtype:
                raise ValueError(
                    f"delta leaf {i} has shape/dtype {v.shape}/{v.dtype} but "
                    f"the base tree's leaf is {leaves[i].shape}/"
                    f"{leaves[i].dtype}; this delta was extracted against a "
                    "different parameter tree")
            leaves[i] = v
        return jax.tree_util.tree_unflatten(treedef, leaves)


class AdapterStore:
    """Content-addressed store of tenant fine-tune artifacts.

    Tenants map to adapter keys; keys map to serialized ledger blobs (the
    MZOL wire format — what a training host would ship) and, optionally, a
    compaction record (``repro.serve.tenants.compact``).  Two tenants with
    identical ledgers share one blob and one key — dedup falls out of content
    addressing.  ``ledger()`` re-verifies the content hash on read, so a
    corrupted or mis-filed blob refuses (``LedgerHashMismatchError``) instead
    of materializing silently wrong weights."""

    def __init__(self):
        self._blobs: dict = {}          # content_hash -> bytes
        self._tenants: dict = {}        # tenant -> AdapterKey
        self._compacted: dict = {}      # content_hash -> CompactedAdapter

    # -- writes ------------------------------------------------------------- #
    def put(self, tenant, ledger: TrajectoryLedger) -> AdapterKey:
        """Register ``tenant``'s fine-tune; returns its content-hash key."""
        chash = ledger.content_hash()
        key = (chash, len(ledger))
        self._blobs.setdefault(chash, ledger.to_bytes())
        self._tenants[tenant] = key
        return key

    def put_compacted(self, tenant, compacted) -> None:
        """Attach a compaction record to ``tenant``'s adapter (keyed on the
        same content hash, so equal ledgers share the compacted form too)."""
        chash, n = self.key(tenant)
        if compacted.full_hash != chash:
            raise LedgerHashMismatchError(
                f"compaction record was built from a ledger with content "
                f"hash {compacted.full_hash[:12]}… but tenant {tenant!r}'s "
                f"stored ledger hashes to {chash[:12]}…; attaching it would "
                "materialize a different tenant's parameters")
        self._compacted[chash] = compacted

    # -- reads -------------------------------------------------------------- #
    def tenants(self) -> list:
        return sorted(self._tenants)

    def key(self, tenant) -> AdapterKey:
        if tenant not in self._tenants:
            raise KeyError(f"unknown tenant {tenant!r}; registered: "
                           f"{self.tenants()[:8]}...")
        return self._tenants[tenant]

    def ledger(self, tenant) -> TrajectoryLedger:
        """Deserialize ``tenant``'s ledger, re-verifying its content hash —
        the read-side half of the refuse-on-mismatch contract."""
        chash, _ = self.key(tenant)
        led = TrajectoryLedger.from_bytes(self._blobs[chash])
        actual = led.content_hash()
        if actual != chash:
            raise LedgerHashMismatchError(
                f"stored blob for adapter {chash[:12]}… deserializes to a "
                f"ledger with content hash {actual[:12]}…; the artifact was "
                "corrupted or mis-filed — refusing to materialize from it")
        return led

    def compacted(self, tenant):
        """The tenant's compaction record, or ``None``."""
        chash, _ = self.key(tenant)
        return self._compacted.get(chash)

    def nbytes(self) -> int:
        """Total stored ledger bytes (the 'thousands of fine-tunes per host'
        accounting: a few KB per tenant, before any compaction records)."""
        return sum(len(b) for b in self._blobs.values())

    def __len__(self) -> int:
        return len(self._tenants)
