"""Ledger-native multi-tenant adapter serving.

MeZO's storage property (paper §2.1) makes per-user fine-tunes a few KB of
seeds + scalars, cheap enough to store by the thousands; this package turns
that into a serving product:

    train → MZOL ledger → AdapterStore (content-hash keyed)
                       → compact()     (delta + replayable tail, O(tail))
                       → DeltaCache    (byte-budgeted LRU of materialized
                                        selection-sized deltas)
                       → ServeEngine   (cross-adapter batched decode)

Every materialization replays through the SAME ``PerturbBackend.apply_rank1``
write path training used, so cached, compacted, and freshly-replayed deltas
are bitwise-equal (test-enforced); identity mismatches refuse loudly
(``LedgerHashMismatchError``, joining the Backend/Plan/SelectionMismatchError
family).
"""
from repro.serve.tenants.cache import DeltaCache
from repro.serve.tenants.compact import CompactedAdapter, compact, materialize
from repro.serve.tenants.runtime import TenantRuntime, composition_for_ledger
from repro.serve.tenants.store import (AdapterDelta, AdapterStore,
                                       LedgerHashMismatchError)
from repro.serve.tenants.synth import (lora_runtime, make_lora_tenants,
                                       serve_load, synthetic_requests,
                                       template_requests, tenant_name)

__all__ = [
    "AdapterDelta", "AdapterStore", "CompactedAdapter", "DeltaCache",
    "LedgerHashMismatchError", "TenantRuntime", "compact",
    "composition_for_ledger", "lora_runtime", "make_lora_tenants",
    "materialize", "serve_load", "synthetic_requests", "template_requests",
    "tenant_name",
]
