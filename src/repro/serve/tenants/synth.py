"""Synthetic many-tenant fixtures: train N LoRA tenants, generate load.

The acceptance scenario of the serving layer — N peft(lora) fine-tunes over
ONE frozen base, each persisted as nothing but its scalar ledger — needs to
be constructible cheaply in tests, the example, the bench, and the launcher.
This module is that shared fixture:

* ``make_lora_tenants`` trains N tiny LoRA runs (one jitted step function,
  reused across tenants — only the seed and LoRA init differ) and registers
  each ledger in an ``AdapterStore``.  The ledger's ``base_seed`` doubles as
  the tenant's LoRA-init seed, so the ledger alone determines the adapter —
  a serving host reconstructs the tenant from the 0.1 MB artifact and the
  shared base, nothing else.
* ``lora_runtime`` builds the matching ``TenantRuntime`` (params0 from the
  ledger seed, ``merge_lora`` as the serve map).
* ``synthetic_requests`` / ``serve_load`` generate a skewed request mix over
  the tenants and drive one engine through it, returning per-request
  timestamp trails (the bench's TTFT source).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro import zo
from repro.core.trajectory import TrajectoryLedger
from repro.data.synthetic import PromptClassification
from repro.models.config import ModelConfig
from repro.models.peft import init_lora, merge_lora, peft_loss_fn
from repro.select import peft as peft_select
from repro.serve.engine import Request, ServeEngine
from repro.serve.tenants.cache import DeltaCache
from repro.serve.tenants.runtime import TenantRuntime
from repro.serve.tenants.store import AdapterStore

LORA_RANK = 2          # one convention shared by trainer and serving host
LORA_ALPHA = 16.0
LORA_TARGETS = ("wq", "wv")


def tenant_name(i: int) -> str:
    return f"tenant-{i:03d}"


def lora_params0(cfg: ModelConfig, base_params, ledger: TrajectoryLedger):
    """The tenant's training start tree, reconstructed from the ledger alone:
    merged ``{"base", "lora"}`` with the LoRA init seeded by ``base_seed``."""
    lora = init_lora(cfg, jax.random.PRNGKey(ledger.base_seed),
                     rank=LORA_RANK, alpha=LORA_ALPHA, targets=LORA_TARGETS)
    return {"base": base_params, "lora": lora}


def lora_runtime(cfg: ModelConfig, base_params, store: AdapterStore,
                 cache_bytes: int = 0) -> TenantRuntime:
    """A ``TenantRuntime`` for LoRA tenants over ``base_params``: the serving
    delta is ``merge_lora(base, tuned_lora)`` diffed against the base — the
    targeted attention leaves only, ~r/d of the parameter bytes."""
    return TenantRuntime(
        base_params, store,
        cache=DeltaCache(cache_bytes) if cache_bytes > 0 else None,
        params0_fn=lambda led: lora_params0(cfg, base_params, led),
        serve_map=lambda merged: merge_lora(merged["base"], merged["lora"]))


def make_lora_tenants(cfg: ModelConfig, base_params, n_tenants: int,
                      steps: int = 10, batch: int = 8, lr: float = 2e-4,
                      eps: float = 1e-3, backend=None,
                      seed0: int = 100) -> AdapterStore:
    """Train ``n_tenants`` LoRA fine-tunes of the shared frozen base, each on
    its own synthetic task, recording ONLY the scalar ledger (grad_dtype
    float32 → bitwise replay).  One composition and one jitted step serve all
    tenants; per-tenant state differs only in seed and LoRA init, so tenant
    i+1 reuses tenant 0's compilation."""
    opt = zo.mezo(lr=lr, eps=eps, backend=backend,
                  selection=peft_select("lora"))
    step = jax.jit(opt.step_fn(peft_loss_fn(cfg, "lora")))
    store = AdapterStore()

    def clamp(batch):
        # the task's class-band token ids reach ~210 regardless of its vocab
        # arg; fold them into this model's vocab (an out-of-range id would
        # gather NaN embeddings and poison every projected grad)
        return {**batch, "tokens": batch["tokens"] % cfg.vocab_size,
                "labels": batch["labels"] % cfg.vocab_size}

    for i in range(n_tenants):
        bseed = seed0 + i
        task = PromptClassification(vocab=cfg.vocab_size, seed=bseed)
        led = TrajectoryLedger(
            base_seed=bseed, grad_dtype="float32",
            backend=opt.backend_name, batch_seeds=opt.batch_seeds,
            selection=opt.selection_spec, sel_phase=opt.selection_phase)
        p = lora_params0(cfg, base_params, led)
        state = opt.init(p, seed=bseed)
        for s in range(steps):
            p, state, m = step(p, state, clamp(task.batch_for_step(s, batch)))
            led.append(s, float(m["projected_grad"]), float(m["lr"]))
        store.put(tenant_name(i), led)
    return store


# --------------------------------------------------------------------------- #
# Load generation + the shared serve driver
# --------------------------------------------------------------------------- #
def synthetic_requests(n_requests: int, vocab_size: int, tenants: list,
                       seed: int = 0, max_new_tokens: int = 8,
                       skew: float = 2.0) -> list:
    """``[(tenant, Request), ...]`` with a skewed tenant popularity (low
    indices hot — ``skew > 1`` concentrates traffic, which is what gives a
    byte-budgeted cache something to exploit; ``skew=1`` is uniform)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_requests):
        t = tenants[int(len(tenants) * rng.random() ** skew)]
        plen = int(rng.integers(2, 9))
        prompt = [int(x) for x in rng.integers(1, vocab_size - 1, plen)]
        out.append((t, Request(i, prompt, max_new_tokens=max_new_tokens)))
    return out


def template_requests(n_requests: int, vocab_size: int, tenants: list,
                      n_templates: int = 4, template_len: int = 48,
                      suffix_len: tuple = (2, 8), seed: int = 0,
                      max_new_tokens: int = 8, skew: float = 1.2,
                      rid0: int = 0, template_seed=None) -> list:
    """``[(tenant, Request), ...]`` with the SHARED-TEMPLATE shape real
    prompt-heavy ZO workloads have (paper §3: classification/MC prompts =
    one task template + a short per-example suffix).

    Each tenant owns ``n_templates`` fixed ``template_len``-token templates;
    every request draws a template Zipf-style (``skew`` > 0 concentrates
    traffic on low template indices — the regime where a radix prefix cache
    pays) and appends a fresh random suffix of ``suffix_len=(lo, hi)``
    tokens.  Template tokens are deterministic in (template_seed, tenant
    index, template) — ``template_seed`` defaults to ``seed``; pass it
    explicitly to draw successive WAVES with fresh suffixes over the SAME
    templates, which is what bench_serve uses to measure
    prefill-tokens-computed vs submitted and warm-prefix TTFT."""
    rng = np.random.default_rng(seed)
    if template_seed is None:
        template_seed = seed
    templates: dict = {}
    for ti, t in enumerate(tenants):
        trng = np.random.default_rng((template_seed, ti))
        templates[t] = [
            [int(x) for x in trng.integers(1, vocab_size - 1, template_len)]
            for _ in range(n_templates)]
    ranks = np.arange(1, n_templates + 1, dtype=np.float64)
    pz = ranks ** -(1.0 + skew)
    pz /= pz.sum()
    out = []
    for i in range(n_requests):
        t = tenants[int(rng.integers(0, len(tenants)))]
        k = int(rng.choice(n_templates, p=pz))
        slen = int(rng.integers(suffix_len[0], suffix_len[1] + 1))
        suffix = [int(x) for x in rng.integers(1, vocab_size - 1, slen)]
        out.append((t, Request(rid0 + i, templates[t][k] + suffix,
                               max_new_tokens=max_new_tokens)))
    return out


def serve_load(engine: ServeEngine, runtime: TenantRuntime,
               tagged_requests: list) -> list:
    """Drive ``engine`` through ``(tenant, Request)`` pairs: materialize (or
    cache-hit) each tenant's delta, register it, submit, and drain.  The
    queued stamp is taken BEFORE materialization so a cold adapter's replay
    cost lands in its requests' time-to-first-token — exactly the cold/warm
    spread the bench reports.  Returns per-request timing rows."""
    for tenant, req in tagged_requests:
        req.times.setdefault("queued", time.perf_counter())
        if tenant is not None:
            engine.register_adapter(tenant, runtime.delta(tenant))
            req.adapter = tenant
        engine.submit(req)
    engine.run()
    rows = []
    for tenant, req in tagged_requests:
        q = req.times["queued"]
        rows.append({
            "rid": req.rid, "tenant": tenant,
            "n_out": len(req.out_ids),
            "ttft_s": req.times.get("prefill", q) - q,
            "total_s": req.times.get("done", q) - q,
        })
    return rows
