"""Pytree helpers shared across the framework.

Leaf indexing must be *stable* (same tree structure -> same leaf order) because
MeZO regenerates the perturbation z for each leaf from ``fold_in(key, leaf_idx)``;
a reordering would silently change the sampled direction.  ``jax.tree_util``
flattening order is deterministic for a fixed structure, which is what we rely
on (and test in tests/test_perturb.py).
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def tree_map_with_index(fn: Callable[[int, jnp.ndarray], jnp.ndarray], tree: PyTree) -> PyTree:
    """Map ``fn(leaf_index, leaf)`` over a pytree with a stable leaf index."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return jax.tree_util.tree_unflatten(treedef, [fn(i, x) for i, x in enumerate(leaves)])


def tree_map_with_path_str(fn: Callable[[str, jnp.ndarray], jnp.ndarray], tree: PyTree) -> PyTree:
    """Map ``fn(path_string, leaf)``; path strings are stable and human readable."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = [fn(jax.tree_util.keystr(path), leaf) for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, out)


def tree_size(tree: PyTree) -> int:
    """Total number of scalar parameters in the tree."""
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(tree))


def tree_bytes(tree: PyTree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(tree))


def tree_dot(a: PyTree, b: PyTree) -> jnp.ndarray:
    """Global dot product of two same-structure trees (f32 accumulation)."""
    parts = jax.tree_util.tree_map(
        lambda x, y: jnp.sum(x.astype(jnp.float32) * y.astype(jnp.float32)), a, b
    )
    return jax.tree_util.tree_reduce(jnp.add, parts, jnp.float32(0))


def tree_sq_norm(tree: PyTree) -> jnp.ndarray:
    return tree_dot(tree, tree)


def tree_add_scaled(a: PyTree, b: PyTree, scale) -> PyTree:
    """a + scale * b, elementwise over matching trees (in a's dtype)."""
    return jax.tree_util.tree_map(
        lambda x, y: (x + scale * y.astype(x.dtype)).astype(x.dtype), a, b
    )


def tree_cast(tree: PyTree, dtype) -> PyTree:
    return jax.tree_util.tree_map(lambda x: x.astype(dtype), tree)


def tree_zeros_like(tree: PyTree) -> PyTree:
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def tree_allclose(a: PyTree, b: PyTree, rtol=1e-5, atol=1e-6) -> bool:
    oks = jax.tree_util.tree_map(
        lambda x, y: bool(jnp.allclose(x, y, rtol=rtol, atol=atol)), a, b
    )
    return all(jax.tree_util.tree_leaves(oks))


def tree_max_abs_diff(a: PyTree, b: PyTree) -> float:
    ds = jax.tree_util.tree_map(
        lambda x, y: jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))), a, b
    )
    return float(jax.tree_util.tree_reduce(jnp.maximum, ds, jnp.float32(0)))
