"""OPT-66B (paper Table 2): 64L d_model=9216 72H d_ff=36864 vocab=50272."""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="opt-66b", family="dense",
    n_layers=64, d_model=9216, n_heads=72, n_kv_heads=72, d_ff=36864,
    vocab_size=50272, activation="relu", gated_ffn=False, norm="layernorm",
    max_seq=2048, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="opt-66b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, activation="relu", gated_ffn=False, norm="layernorm",
    max_seq=128, dtype="float32",
)

register("opt-66b", CONFIG, SMOKE, notes="paper's model (Table 2)")
