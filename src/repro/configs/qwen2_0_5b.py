"""qwen2-0.5b [dense] — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151936.
GQA with QKV bias.  [arXiv:2407.10671; hf]

Qwen2-0.5B ties embeddings in the released weights; we keep them untied so the
vocab head can be TP-sharded while the embedding table is d-sharded (gathers
stay shard-local) — noted in DESIGN.md §5.  This is the dev architecture.
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-0.5b", family="dense",
    n_layers=24, d_model=896, n_heads=14, n_kv_heads=2, d_ff=4864,
    vocab_size=151936, qkv_bias=True, activation="silu", gated_ffn=True,
    norm="rmsnorm", rope_theta=1_000_000.0, max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen2-0.5b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, qkv_bias=True, activation="silu", gated_ffn=True,
    norm="rmsnorm", max_seq=128, dtype="float32",
)

register("qwen2-0.5b", CONFIG, SMOKE, notes="GQA kv=2, QKV bias")
