"""OPT-13B — the paper's main large autoregressive LM (Table 1).
40L d_model=5120 40H d_ff=20480 vocab=50272, ReLU FFN, LayerNorm.
(Positions: OPT uses learned absolute; we use RoPE — structural proxy,
noted in DESIGN.md §10.)
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="opt-13b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=40, d_ff=20480,
    vocab_size=50272, activation="relu", gated_ffn=False, norm="layernorm",
    max_seq=2048, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="opt-13b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, activation="relu", gated_ffn=False, norm="layernorm",
    max_seq=128, dtype="float32",
)

register("opt-13b", CONFIG, SMOKE, notes="paper's model (Table 1)")
