"""phi-3-vision-4.2b [vlm] — 32L d_model=3072 32H (kv=32, MHA) d_ff=8192
vocab=32064.  phi3-mini backbone + CLIP vision tower.
[hf:microsoft/Phi-3-vision-128k-instruct; hf]

The vision frontend is a STUB per the assignment: input_specs() provides the
merged text+patch embedding sequence (B, S, 3072) directly
(models/frontends.py documents what the CLIP tower + projector would emit).
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, d_ff=8192,
    vocab_size=32064, activation="silu", gated_ffn=True, norm="rmsnorm",
    rope_theta=10000.0, frontend="vision_stub", max_seq=131072,
    dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="phi-3-vision-4.2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, activation="silu", gated_ffn=True, norm="rmsnorm",
    frontend="vision_stub", max_seq=128, dtype="float32",
)

register("phi-3-vision-4.2b", CONFIG, SMOKE,
         notes="VLM backbone; patch embeddings stubbed")
