"""qwen2-7b [dense] — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064.
GQA, QKV bias.  [arXiv:2407.10671; hf]
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="qwen2-7b", family="dense",
    n_layers=28, d_model=3584, n_heads=28, n_kv_heads=4, d_ff=18944,
    vocab_size=152064, qkv_bias=True, activation="silu", gated_ffn=True,
    norm="rmsnorm", rope_theta=1_000_000.0, max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="qwen2-7b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=4, n_kv_heads=4, d_ff=192,
    vocab_size=256, qkv_bias=True, activation="silu", gated_ffn=True,
    norm="rmsnorm", max_seq=128, dtype="float32",
)

register("qwen2-7b", CONFIG, SMOKE, notes="GQA kv=4, QKV bias")
