"""OPT-30B (paper Table 2): 48L d_model=7168 56H d_ff=28672 vocab=50272."""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="opt-30b", family="dense",
    n_layers=48, d_model=7168, n_heads=56, n_kv_heads=56, d_ff=28672,
    vocab_size=50272, activation="relu", gated_ffn=False, norm="layernorm",
    max_seq=2048, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="opt-30b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=256,
    vocab_size=256, activation="relu", gated_ffn=False, norm="layernorm",
    max_seq=128, dtype="float32",
)

register("opt-30b", CONFIG, SMOKE, notes="paper's model (Table 2)")
