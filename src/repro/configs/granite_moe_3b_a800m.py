"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512
vocab=49155, MoE 40 experts top-8.
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]

NOTE (source discrepancy): the assignment's shape spec says "MoE 40e top-8"
while its trailing comment says "32 experts top-8".  We implement the shape
spec (40 experts) — recorded in DESIGN.md §4.
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m", family="moe",
    n_layers=32, d_model=1536, n_heads=24, n_kv_heads=8, d_ff=512,
    vocab_size=49155, n_experts=40, top_k=8,
    activation="silu", gated_ffn=True, norm="rmsnorm",
    rope_theta=10000.0, max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=32,
    vocab_size=256, n_experts=5, top_k=2, moe_group_size=32,
    activation="silu", gated_ffn=True, norm="rmsnorm",
    max_seq=128, dtype="float32",
)

register("granite-moe-3b-a800m", CONFIG, SMOKE, notes="40 experts top-8")
