"""nemotron-4-340b [dense] — 96L d_model=18432 96H (GQA kv=8) d_ff=73728
vocab=256000.  GQA, squared-ReLU (non-gated) FFN.  [arXiv:2402.16819; unverified]

The largest assigned cell: 340B parameters.  MeZO's memory story is most
dramatic here — the dry-run's memory_analysis shows the train step fitting in
inference-level HBM (no optimizer state, no activation stash).
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="nemotron-4-340b", family="dense",
    n_layers=96, d_model=18432, n_heads=96, n_kv_heads=8, d_ff=73728,
    vocab_size=256000, activation="sq_relu", gated_ffn=False,
    norm="layernorm", rope_theta=10000.0, max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="nemotron-4-340b-smoke", family="dense",
    n_layers=3, d_model=96, n_heads=6, n_kv_heads=2, d_ff=384,
    vocab_size=256, activation="sq_relu", gated_ffn=False,
    norm="layernorm", max_seq=128, dtype="float32",
)

register("nemotron-4-340b", CONFIG, SMOKE, notes="GQA kv=8, squared-ReLU")
