"""RoBERTa-large (355M) — the paper's medium masked LM (Figure 2, Table 18).
24L d_model=1024 16H d_ff=4096 vocab=50265, bidirectional (causal=False),
GELU, LayerNorm.  Used by the paper-claims quality benchmarks (prompt-based
classification with [MASK] label words, scaled down for CPU).
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="roberta-large", family="dense",
    n_layers=24, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab_size=50265, causal=False, activation="gelu", gated_ffn=False,
    norm="layernorm", use_rope=False, max_seq=512, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="roberta-large-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
    vocab_size=256, causal=False, activation="gelu", gated_ffn=False,
    norm="layernorm", use_rope=False, max_seq=128, dtype="float32",
)

register("roberta-large", CONFIG, SMOKE, notes="paper's masked LM; encoder-only")
