"""rwkv6-3b [ssm] — 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536.
RWKV-6 "Finch": data-dependent decay.  [arXiv:2404.05892; hf]

Head layout: 40 heads x head_dim 64 (RWKV6 uses head_size 64).  O(1) decode
state -> runs long_500k natively.
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="rwkv6-3b", family="ssm",
    n_layers=32, d_model=2560, n_heads=40, head_dim=64, d_ff=8960,
    vocab_size=65536, use_rope=False, norm="rmsnorm", scan_chunk=16,
    max_seq=1_048_576, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="rwkv6-3b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=4, head_dim=16, d_ff=128,
    vocab_size=256, use_rope=False, norm="rmsnorm", scan_chunk=16,
    max_seq=128, dtype="float32",
)

register("rwkv6-3b", CONFIG, SMOKE, notes="Finch data-dependent decay; attn-free")
