"""yi-6b [dense] — 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.
Llama-architecture GQA.  [arXiv:2403.04652; hf]
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab_size=64000, activation="silu", gated_ffn=True, norm="rmsnorm",
    rope_theta=5_000_000.0, max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="yi-6b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=160,
    vocab_size=256, activation="silu", gated_ffn=True, norm="rmsnorm",
    max_seq=128, dtype="float32",
)

register("yi-6b", CONFIG, SMOKE, notes="llama-arch GQA kv=4")
