"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16 — parallel attention+mamba heads per layer.
[arXiv:2411.13676; hf]

Hymba fuses attention heads and SSM (mamba) heads in parallel inside each
block; attention is sliding-window (2048) in our config so the KV cache is
bounded and the hybrid runs the long_500k cell (SSM state is O(1)).
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="hymba-1.5b", family="hybrid",
    n_layers=32, d_model=1600, n_heads=25, n_kv_heads=5, d_ff=5504,
    vocab_size=32001, ssm_state=16, ssm_heads=25, sliding_window=2048,
    activation="silu", gated_ffn=True, norm="rmsnorm",
    rope_theta=10000.0, max_seq=1_048_576, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke", family="hybrid",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
    vocab_size=256, ssm_state=4, ssm_heads=4, sliding_window=16,
    activation="silu", gated_ffn=True, norm="rmsnorm",
    max_seq=128, dtype="float32",
)

register("hymba-1.5b", CONFIG, SMOKE,
         notes="parallel attn+mamba heads; SWA 2048 -> long_500k eligible")
