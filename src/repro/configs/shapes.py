"""The assigned shape-cell table and arch id lists (see DESIGN.md §4)."""

ASSIGNED_ARCHS = [
    "phi-3-vision-4.2b",
    "hymba-1.5b",
    "whisper-large-v3",
    "qwen2-0.5b",
    "yi-6b",
    "qwen2-7b",
    "nemotron-4-340b",
    "granite-moe-3b-a800m",
    "mixtral-8x7b",
    "rwkv6-3b",
]

PAPER_ARCHS = ["opt-13b", "opt-30b", "opt-66b", "roberta-large"]
