"""mixtral-8x7b [moe] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000,
MoE 8 experts top-2, sliding-window attention.  [arXiv:2401.04088; hf]
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="mixtral-8x7b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=32000, n_experts=8, top_k=2, sliding_window=4096,
    activation="silu", gated_ffn=True, norm="rmsnorm",
    rope_theta=1_000_000.0, max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="mixtral-8x7b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=96,
    vocab_size=256, n_experts=4, top_k=2, sliding_window=32,
    moe_group_size=32, activation="silu", gated_ffn=True, norm="rmsnorm",
    max_seq=128, dtype="float32",
)

register("mixtral-8x7b", CONFIG, SMOKE, notes="8 experts top-2, SWA 4096")
