"""Architecture configs.  Importing this package registers every arch.

Assigned pool (10 archs × their shape cells) + the paper's own models
(OPT-13B/30B/66B, RoBERTa-large) used by the paper-claims benchmarks.
"""
from repro.configs import (granite_moe_3b_a800m, hymba_1_5b, mixtral_8x7b,
                           nemotron_4_340b, opt_13b, opt_30b, opt_66b,
                           phi_3_vision_4_2b, qwen2_0_5b, qwen2_7b,
                           roberta_large, rwkv6_3b, whisper_large_v3, yi_6b)
from repro.configs.shapes import ASSIGNED_ARCHS, PAPER_ARCHS

__all__ = ["ASSIGNED_ARCHS", "PAPER_ARCHS"]
