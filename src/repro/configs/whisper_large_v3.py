"""whisper-large-v3 [audio] — enc-dec, 32L (each side) d_model=1280 20H (MHA)
d_ff=5120 vocab=51866, conv frontend STUB.  [arXiv:2212.04356; unverified]

The conv1d audio frontend is stubbed: input_specs() provides precomputed
frame embeddings (B, S_enc, 1280).  Cells: train_4k = enc 4096 frames + dec
4096 tokens (teacher forcing); prefill_32k = encode 32768 frames; decode_32k
= one decoder token against a 32768-token decoder cache with a realistic
1504-frame encoder context (DESIGN.md §4).
"""
from repro.models import ModelConfig, register

CONFIG = ModelConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, encoder_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab_size=51866, cross_attention=True,
    activation="gelu", gated_ffn=False, norm="layernorm", use_rope=False,
    frontend="audio_stub", max_seq=32768, dtype="bfloat16",
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke", family="encdec",
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=256, cross_attention=True,
    activation="gelu", gated_ffn=False, norm="layernorm", use_rope=False,
    frontend="audio_stub", max_seq=128, dtype="float32",
)

register("whisper-large-v3", CONFIG, SMOKE,
         notes="enc-dec; conv frontend stubbed; sinusoidal positions")
