"""Checkpoint manager: rotation, resume, and the MeZO seed-chain ledger.

Two artifact kinds per run directory:
  * ``ckpt_<step>.mz``   — full tensor checkpoints (params + optimizer state
                           + step), written every ``interval`` steps, keeping
                           the newest ``keep``.
  * ``ledger.mzl``       — the MeZO (seed, projected_grad, lr) scalar ledger,
                           appended every step (~2–6 bytes/step).

Recovery = newest full checkpoint + replay of the ledger tail: a node can
rejoin from a ~0.1 MB object at any step (paper §2.1 promoted to fault
tolerance; bitwise-equality tested).

Both artifacts record the run's full seed-schedule coordinates — checkpoint
meta carries ``perturb_backend``/``batch_seeds``/``exec_plan``/``n_groups``,
the ledger the same fields in its header — and recovery refuses mismatched
coordinates (``BackendMismatchError`` / ``PlanMismatchError``) instead of
silently reconstructing different parameters from different z streams.
"""
from __future__ import annotations

import glob
import os
import re
from typing import Any, Optional

import jax

from repro.checkpoint.io import load_meta, load_tree, save_tree
from repro.core.trajectory import TrajectoryLedger, replay
from repro.tree_utils import PyTree


class CheckpointManager:
    def __init__(self, directory: str, interval: int = 100, keep: int = 3):
        self.dir = directory
        self.interval = interval
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ---- full tensor checkpoints ---------------------------------------- #
    def _path(self, step: int) -> str:
        return os.path.join(self.dir, f"ckpt_{step:09d}.mz")

    def steps(self) -> list[int]:
        out = []
        for p in glob.glob(os.path.join(self.dir, "ckpt_*.mz")):
            m = re.search(r"ckpt_(\d+)\.mz$", p)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def maybe_save(self, step: int, params: PyTree, opt_state: Any = None,
                   meta: Optional[dict] = None, force: bool = False) -> bool:
        if not force and (self.interval <= 0 or step % self.interval != 0):
            return False
        tree = {"params": params}
        if opt_state is not None:
            tree["opt_state"] = opt_state
        save_tree(self._path(step), tree,
                  {"step": step, **(meta or {})})
        for old in self.steps()[:-self.keep]:
            os.remove(self._path(old))
        return True

    def restore_latest(self, like_params: PyTree, like_opt: Any = None):
        steps = self.steps()
        if not steps:
            return None
        step = steps[-1]
        like = {"params": like_params}
        if like_opt is not None:
            like["opt_state"] = like_opt
        tree, meta = load_tree(self._path(step), like)
        return {"step": step, "params": tree["params"],
                "opt_state": tree.get("opt_state"), "meta": meta}

    # ---- MeZO scalar ledger ---------------------------------------------- #
    @property
    def ledger_path(self) -> str:
        return os.path.join(self.dir, "ledger.mzl")

    def save_ledger(self, ledger: TrajectoryLedger) -> int:
        raw = ledger.to_bytes()
        tmp = self.ledger_path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(raw)
        os.replace(tmp, self.ledger_path)
        return len(raw)

    def load_ledger(self) -> Optional[TrajectoryLedger]:
        if not os.path.exists(self.ledger_path):
            return None
        with open(self.ledger_path, "rb") as f:
            return TrajectoryLedger.from_bytes(f.read())

    def recover_via_ledger(self, params_at_ckpt: PyTree, ckpt_step: int,
                           optimizer) -> tuple[PyTree, int]:
        """Full ckpt at ``ckpt_step`` + ledger tail -> params at ledger head.
        No data access, no forward passes (paper §2.1).  ``optimizer`` is a
        ``repro.exec.StepProgram`` (the resume path — its plan must match the
        ledger's) or any ``repro.zo`` protocol conformer / legacy config,
        replayed through the engine's ledger-driven plan.  Raises
        ``BackendMismatchError`` / ``PlanMismatchError`` on mismatched
        seed-schedule coordinates."""
        ledger = self.load_ledger()
        if ledger is None or len(ledger) == 0:
            return params_at_ckpt, ckpt_step
        tail_start = next((i for i, s in enumerate(ledger.steps)
                           if s >= ckpt_step), len(ledger))
        params = replay(params_at_ckpt, ledger, optimizer, from_idx=tail_start)
        return params, (ledger.steps[-1] + 1 if len(ledger) else ckpt_step)
