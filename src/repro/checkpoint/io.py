"""Tensor checkpoint store: msgpack index + raw little-endian buffers.

Self-contained (no orbax offline); stores leaves UNSHARDED with their tree
paths, so a checkpoint written on one mesh restores onto any other topology
(the elastic-scaling contract, tested in tests/test_fault_tolerance.py).
Writes are atomic (tmp + rename) so a crash mid-save never corrupts the
latest checkpoint.
"""
from __future__ import annotations

import os
import struct

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

from repro.tree_utils import PyTree

_DTYPES = {"float32": np.float32, "float16": np.float16, "int32": np.int32,
           "int64": np.int64, "uint32": np.uint32, "uint8": np.uint8,
           "bool": np.bool_, "bfloat16": None}


def _to_numpy(x) -> tuple[np.ndarray, str]:
    x = np.asarray(jax.device_get(x))
    if x.dtype == jnp.bfloat16:
        return x.view(np.uint16), "bfloat16"
    return x, str(x.dtype)


def _from_numpy(buf: bytes, dtype: str, shape) -> np.ndarray:
    if dtype == "bfloat16":
        arr = np.frombuffer(buf, np.uint16).reshape(shape)
        return arr.view(jnp.bfloat16)
    return np.frombuffer(buf, _DTYPES.get(dtype, dtype)).reshape(shape)


def save_tree(path: str, tree: PyTree, extra_meta: dict | None = None) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    index = {"leaves": [], "meta": extra_meta or {}}
    blobs = []
    offset = 0
    for kp, leaf in flat:
        arr, dtype = _to_numpy(leaf)
        raw = arr.tobytes()
        index["leaves"].append({"path": jax.tree_util.keystr(kp),
                                "dtype": dtype, "shape": list(arr.shape),
                                "offset": offset, "nbytes": len(raw)})
        blobs.append(raw)
        offset += len(raw)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        head = msgpack.packb(index)
        f.write(struct.pack("<q", len(head)))
        f.write(head)
        for b in blobs:
            f.write(b)
    os.replace(tmp, path)


def load_tree(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore into the structure of ``like`` (paths must match)."""
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<q", f.read(8))
        index = msgpack.unpackb(f.read(hlen))
        base = f.tell()
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        by_path = {e["path"]: e for e in index["leaves"]}
        leaves = []
        for kp, leaf in flat:
            e = by_path[jax.tree_util.keystr(kp)]
            f.seek(base + e["offset"])
            arr = _from_numpy(f.read(e["nbytes"]), e["dtype"], e["shape"])
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), index["meta"]


def load_meta(path: str) -> dict:
    with open(path, "rb") as f:
        (hlen,) = struct.unpack("<q", f.read(8))
        return msgpack.unpackb(f.read(hlen))["meta"]
