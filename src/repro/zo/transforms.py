"""Scalar-ledger transforms for the ``ZOTransform`` chain.

Because the SPSA gradient at step τ is the rank-1 tensor g_τ·z_τ with z_τ a
pure function of ``(base_key, τ)``, every transform here operates on (or is
reconstructed from) the *scalar* g-history — state stays O(window) scalars,
never O(parameters), except in the explicitly-materialized oracle modes.

Ordering is significant, exactly as in optax:

    chain(clip_projected_grad(c),      # on the raw scalar g
          scale_by_schedule(lr, ...),  # sets Updates.lr and η-scales coeff
          add_weight_decay(λ))         # reads Updates.lr

Applier transforms (``scale_by_zo_adam`` / ``trace``) materialize the whole
update themselves and ignore the scalar decay slot — give them their own
``weight_decay=`` instead of chaining ``add_weight_decay`` (the facade
rejects that combination):

    chain(clip_projected_grad(c),
          scale_by_schedule(lr, ...),
          scale_by_zo_adam(..., weight_decay=λ))
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import schedules
from repro.perturb import StreamRef, get_backend, step_key
from repro.tree_utils import PyTree, tree_map_with_index, tree_zeros_like
from repro.zo.base import TransformCtx, Updates, ZOTransform


# --------------------------------------------------------------------------- #
# Scalar transforms
# --------------------------------------------------------------------------- #
def clip_projected_grad(clip: float) -> ZOTransform:
    """|g| ← min(|g|, clip) — the stability clamp on the raw projected
    gradient.  Place before ``scale_by_schedule``."""
    if clip <= 0:
        raise ValueError("clip must be positive; omit the transform to disable")

    def update(u: Updates, state, ctx: TransformCtx):
        return u._replace(g=jnp.clip(u.g, -clip, clip)), state

    return ZOTransform(lambda params: (), update,
                       {"clip_projected_grad": clip})


def scale_by_schedule(lr: float, schedule: str = "constant",
                      total_steps: int = 0,
                      warmup_steps: int = 0) -> ZOTransform:
    """coeff ← (η_t / n_seeds)·g and record η_t for downstream transforms.
    Each of the n interleaved SPSA seeds carries η_t/n, matching
    Algorithm 2's averaging."""

    def lr_at(step):
        return schedules.lr_at(schedule, lr, step, total_steps, warmup_steps)

    def update(u: Updates, state, ctx: TransformCtx):
        lr_t = lr_at(ctx.step)
        return u._replace(coeff=(lr_t / ctx.n_seeds) * u.g, lr=lr_t), state

    return ZOTransform(lambda params: (), update, {"lr_at": lr_at})


def add_weight_decay(weight_decay: float) -> ZOTransform:
    """Decoupled weight decay: decay term η_t·λ, applied once per step (on
    the first seed under n-SPSA, matching Algorithm 2).  Must follow
    ``scale_by_schedule`` so ``Updates.lr`` is populated.  Incompatible with
    applier transforms, which bypass the scalar decay slot — pass
    ``weight_decay=`` to ``scale_by_zo_adam`` instead."""

    def update(u: Updates, state, ctx: TransformCtx):
        lr_t = u.lr if u.lr is not None else jnp.float32(1.0)
        # The η·λ product is formed even when λ == 0 so the update graph is
        # identical whether decay is on or off (λ enters as η·λ, never as a
        # foldable constant): bitwise parity with the legacy optimizers.
        wd_j = weight_decay if ctx.seed_index == 0 else 0.0
        return u._replace(decay=lr_t * wd_j), state

    return ZOTransform(lambda params: (), update,
                       {"weight_decay": weight_decay,
                        "scalar_decay": True})


def scale_by_fzoo_std(std_floor: float = 1e-8) -> ZOTransform:
    """FZOO's adaptive step size (Dang et al., 2025): divide the per-seed
    projected gradients by the standard deviation of the step's B one-sided
    loss differences d_j = ℓ_j − ℓ₀ = ε·g_j.

    Operates on the raw (B,) g vector of a batched-seed estimator, so place
    it FIRST in the chain (before ``clip_projected_grad`` /
    ``scale_by_schedule``).  With B == 1 the std is identically zero and the
    transform is a no-op (the update reduces to one-sided SPSA — the
    property-test contract); otherwise the divisor is floored at
    ``std_floor`` so a flat loss landscape cannot blow up the step."""
    if std_floor <= 0:
        raise ValueError("std_floor must be positive")

    def update(u: Updates, state, ctx: TransformCtx):
        if jnp.ndim(u.g) == 0 or u.g.shape[0] < 2:
            return u, state                     # B == 1: σ ≡ 0, no-op
        sigma = jnp.std(u.g * ctx.eps)          # std of the loss diffs
        return u._replace(g=u.g / jnp.maximum(sigma, std_floor)), state

    return ZOTransform(lambda params: (), update, {"fzoo_std_floor": std_floor})


# --------------------------------------------------------------------------- #
# ZO-Adam / momentum (paper §2.2 + Appendix B.2)
# --------------------------------------------------------------------------- #
def scale_by_zo_adam(beta1: float = 0.9, beta2: float = 0.999,
                     adam_eps: float = 1e-8, materialized: bool = False,
                     window: int = 32, momentum_only: bool = False,
                     weight_decay: float = 0.0) -> ZOTransform:
    """Adam (or momentum) preconditioning of the rank-1 ZO gradient.

    Any moving average of g_τ·z_τ is a pure function of the scalar history
    {g_τ}, so two modes share one formula:

    * ``materialized=True``  — conventional Adam: m, v stored as full trees
      (2× parameter memory — the thing the paper avoids).  The oracle.
    * ``materialized=False`` — the paper's trick: a ring buffer of W scalars;
      at update time m, v are recomputed leaf by leaf by replaying the
      window's z's:  m_t ≈ (1−β1) Σ_{j<W} β1^j g_{t−j} z_{t−j}  (App. B.2).
      Extra live memory is O(largest leaf) + W scalars; truncation error
      decays as β^W.

    This transform materializes its own update (sets ``final_params``), so it
    keeps one ledger entry per step and must be the last applier in a chain.
    """

    def init(params):
        g_hist = jnp.zeros((window,), jnp.float32)
        if materialized:
            if params is None:
                raise ValueError("materialized scale_by_zo_adam needs params "
                                 "at init")
            return (g_hist, tree_zeros_like(params), tree_zeros_like(params))
        return (g_hist, (), ())

    def _materialized_update(params, m_tree, v_tree, skey, g, lr, t, dist,
                             backend):
        ref = StreamRef(skey)

        def upd(i, p, m, v):
            z = backend.leaf_z(ref, i, p, dist).astype(jnp.float32)
            ghat = g.astype(jnp.float32) * z
            m_new = beta1 * m + (1.0 - beta1) * ghat
            if momentum_only:
                delta = m_new
            else:
                v_new = beta2 * v + (1.0 - beta2) * ghat * ghat
                m_hat = m_new / (1.0 - beta1 ** t.astype(jnp.float32))
                v_hat = v_new / (1.0 - beta2 ** t.astype(jnp.float32))
                delta = m_hat / (jnp.sqrt(v_hat) + adam_eps)
            p_new = (p.astype(jnp.float32) - lr * delta
                     - lr * weight_decay * p.astype(jnp.float32)).astype(p.dtype)
            return p_new, m_new, (m_new * 0 if momentum_only else v_new)

        leaves_p, treedef = jax.tree_util.tree_flatten(params)
        leaves_m = jax.tree_util.tree_leaves(m_tree)
        leaves_v = jax.tree_util.tree_leaves(v_tree)
        new_p, new_m, new_v = [], [], []
        for i, (p, m, v) in enumerate(zip(leaves_p, leaves_m, leaves_v)):
            a, b, c = upd(i, p, m, v)
            new_p.append(a); new_m.append(b); new_v.append(c)
        unf = jax.tree_util.tree_unflatten
        return unf(treedef, new_p), unf(treedef, new_m), unf(treedef, new_v)

    def _recomputed_update(params, base_key, cur_step, g_hist, lr, t, dist,
                           backend):
        """App. B.2: rebuild m (and v) from the scalar ledger, one leaf at a
        time, by replaying the window's z's.  O(W) forward-free tree passes
        of compute, O(largest leaf) extra memory."""
        W = window
        j_idx = jnp.arange(W, dtype=jnp.float32)            # 0 = most recent
        valid = (cur_step.astype(jnp.float32) - j_idx) >= 0
        cm = jnp.where(valid, (1.0 - beta1) * beta1 ** j_idx * g_hist, 0.0)
        cv = jnp.where(valid, (1.0 - beta2) * beta2 ** j_idx * g_hist ** 2, 0.0)

        def upd(i, p):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return p

            def body(j, acc):
                m_acc, v_acc = acc
                skey_j = step_key(base_key, cur_step - j)
                z = backend.leaf_z(StreamRef(skey_j), i, p,
                                   dist).astype(jnp.float32)
                m_acc = m_acc + cm[j] * z
                v_acc = v_acc + cv[j] * z * z
                return (m_acc, v_acc)

            zero = jnp.zeros(p.shape, jnp.float32)
            m, v = jax.lax.fori_loop(0, W, body, (zero, zero))
            if momentum_only:
                delta = m
            else:
                m_hat = m / (1.0 - beta1 ** t.astype(jnp.float32))
                v_hat = v / (1.0 - beta2 ** t.astype(jnp.float32))
                delta = m_hat / (jnp.sqrt(v_hat) + adam_eps)
            return (p.astype(jnp.float32) - lr * delta
                    - lr * weight_decay * p.astype(jnp.float32)).astype(p.dtype)

        return tree_map_with_index(upd, params)

    def update(u: Updates, state, ctx: TransformCtx):
        g_hist, m, v = state
        g_hist = jnp.concatenate([jnp.reshape(u.g, (1,)), g_hist[:-1]])
        t = ctx.step + 1                      # Adam bias-correction index
        lr = u.lr if u.lr is not None else jnp.float32(1.0)
        params0 = ctx.restore()
        be = get_backend(ctx.backend)
        if materialized:
            new_params, m, v = _materialized_update(
                params0, m, v, ctx.key, u.g, lr, t, ctx.dist, be)
        else:
            new_params = _recomputed_update(
                params0, ctx.base_key, ctx.step, g_hist, lr, t, ctx.dist, be)
            m, v = (), ()
        return u._replace(final_params=new_params), (g_hist, m, v)

    return ZOTransform(init, update,
                       {"applier": True, "window": window,
                        "weight_decay": weight_decay})


def trace(decay: float = 0.9, window: int = 32,
          materialized: bool = False) -> ZOTransform:
    """SGD-momentum on the rank-1 ZO gradient: m_t = β·m_{t−1} + (1−β)·g_t·z_t,
    reconstructed from the scalar ring buffer exactly like ZO-Adam's first
    moment (no second moment, no bias correction)."""
    return scale_by_zo_adam(beta1=decay, materialized=materialized,
                            window=window, momentum_only=True)
