"""Protocol types and the ``ZOOptimizer`` facade.

Three pieces, optax-style but specialized to the scalar structure of
zeroth-order updates (a MeZO step is fully determined by ``(seed, g)`` pairs):

* ``ZOEstimator`` — produces the scalar projected gradient from forward
  passes only.  ``estimate`` returns a ``ZOEstimate`` whose ``apply_update``
  and ``restore`` closures preserve the estimator's own perturbation chain
  (for sequential SPSA that is the donation-friendly in-place chain of
  ``core/mezo.py``: the closure continues from θ−εz with one fused pass).
* ``ZOTransform`` — rewrites the scalar ledger entry (clip, η-scale, decay)
  or, for preconditioners like ZO-Adam, takes over the whole update via
  ``Updates.final_params``.  State is O(window) scalars by construction.
* ``ZOOptimizer`` — the single facade every consumer talks to:
  ``init(params, *, seed)`` / ``step_fn(loss_fn)`` / ``restore(state, step)``
  plus ``replay_update`` for scalar-ledger replay (checkpoint recovery,
  async straggler application).
"""
from __future__ import annotations

import functools
from typing import Any, Callable, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.perturb import PerturbBackend, StreamRef, get_backend, step_key
from repro.tree_utils import PyTree

ZOLossFn = Callable[[PyTree, Any], jnp.ndarray]


# --------------------------------------------------------------------------- #
# Estimator protocol
# --------------------------------------------------------------------------- #
class ZOEstimate(NamedTuple):
    """One seed's worth of estimation, plus how to act on it.

    ``apply_update(coeff, decay_term)`` applies θ ← (1−decay)·θ − coeff·z
    continuing from wherever the estimator left the parameter tree (fused
    restore+update for the sequential chain).  ``restore()`` returns the
    un-perturbed center parameters — used when a transform materializes its
    own update (ZO-Adam) instead of the default rank-1 form.
    """
    projected_grad: jnp.ndarray            # scalar g (pre-transform)
    loss: jnp.ndarray                      # scalar loss estimate for logging
    apply_update: Callable[[Any, Any], PyTree]
    restore: Callable[[], PyTree]
    est_state: Any                         # carry (e.g. one-point residual)
    aux: dict                              # extra metrics, merged into step's


class ZOEstimator(NamedTuple):
    """Factory-produced estimator: ``init(params, key) -> state`` and
    ``estimate(loss_fn, params, batch, key, state) -> ZOEstimate``.

    ``n_seeds > 1`` asks the facade to run the estimator once per folded
    seed key, interleaving updates (Algorithm 2's sequential n-SPSA).

    ``replayable`` declares that the estimator's update is the plain rank-1
    θ ← (1−ηλ)θ − η·g·z(seed) — i.e. a ledger's (seed, g, lr) triple alone
    reproduces it.  Definition-6 rescaled updates (along D·z) are not.

    ``backend`` is the resolved ``repro.perturb.PerturbBackend`` the
    estimator's perturbation chain runs through (``None`` → the default
    ``xla``); the facade exposes it for metadata recording and routes
    ``replay_update`` through the same backend.

    ``batch_seeds > 1`` declares a batched-seed estimator (FZOO): one
    ``estimate`` call evaluates B perturbations and its ``projected_grad`` is
    a (B,)-vector of per-seed scalars rather than a scalar.  The transform
    chain applies elementwise, the facade exposes the vector as the
    ``projected_grads`` metric for per-seed ledger recording, and
    ``replay_update`` replays the B folded rank-1 updates.

    ``selection`` is the resolved ``repro.select.Selection`` scoping the
    estimator's perturbations to a parameter subset (``None`` = full tree —
    the zero-overhead default).  When the selection carries a block schedule
    (``n_phases > 1``), ``estimate`` must accept a static ``phase=`` kwarg
    and the facade dispatches the step over phases."""
    init: Callable[[Optional[PyTree], jax.Array], Any]
    estimate: Callable[..., ZOEstimate]
    n_seeds: int = 1
    eps: float = 1e-3
    dist: str = "gaussian"
    name: str = "spsa"
    replayable: bool = True
    backend: Optional[PerturbBackend] = None
    batch_seeds: int = 1
    selection: Any = None


# --------------------------------------------------------------------------- #
# Transform protocol
# --------------------------------------------------------------------------- #
class Updates(NamedTuple):
    """The value threaded through a transform chain, per seed.

    ``g`` is the ledger scalar (what gets recorded/averaged); ``coeff`` the
    η-scaled update coefficient; ``lr`` the schedule's learning rate (set by
    ``scale_by_schedule`` so later transforms — weight decay, Adam — can see
    it); ``decay`` the decoupled weight-decay term η·λ; ``final_params``
    short-circuits the default rank-1 application when a transform has
    materialized the whole update itself.
    """
    g: jnp.ndarray
    coeff: Optional[jnp.ndarray] = None
    lr: Optional[jnp.ndarray] = None
    decay: Any = 0.0
    final_params: Optional[PyTree] = None


class TransformCtx(NamedTuple):
    """Read-only step context handed to every transform."""
    step: jnp.ndarray                      # int32 step counter
    base_key: jax.Array                    # run seed (for window replay)
    key: jax.Array                         # this seed's perturbation key
    seed_index: int                        # python int, 0..n_seeds-1
    n_seeds: int
    eps: float
    dist: str
    restore: Callable[[], PyTree]          # center params, estimator-specific
    backend: Any = None                    # the run's PerturbBackend


class ZOTransform(NamedTuple):
    """``init(params) -> state`` / ``update(updates, state, ctx)``.

    ``info`` carries static metadata the facade introspects: ``lr_at`` (the
    schedule), ``weight_decay`` (for ledger replay), ``applier: True`` for
    transforms that set ``final_params`` (these keep per-step state and are
    incompatible with interleaved n-SPSA)."""
    init: Callable[[Optional[PyTree]], Any]
    update: Callable[[Updates, Any, TransformCtx], tuple[Updates, Any]]
    info: dict


def identity() -> ZOTransform:
    """The do-nothing transform (coeff = g, no decay)."""
    return ZOTransform(lambda params: (),
                       lambda u, state, ctx: (u, state),
                       {})


def chain(*transforms: ZOTransform) -> ZOTransform:
    """Compose transforms left-to-right, optax-style.

    Ordering matters exactly as in optax: ``clip_projected_grad`` operates on
    the raw scalar so it precedes ``scale_by_schedule``; ``add_weight_decay``
    and ``scale_by_zo_adam`` read ``Updates.lr`` so they follow it.
    """
    if len(transforms) == 1:
        return transforms[0]

    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(u, state, ctx):
        new_state = []
        for t, s in zip(transforms, state):
            u, s = t.update(u, s, ctx)
            new_state.append(s)
        return u, tuple(new_state)

    info: dict = {}
    for t in transforms:
        info.update(t.info)
    return ZOTransform(init, update, info)


# --------------------------------------------------------------------------- #
# Optimizer protocol + facade
# --------------------------------------------------------------------------- #
@runtime_checkable
class Optimizer(Protocol):
    """The uniform optimizer surface every consumer programs against —
    ZO compositions and backprop baselines alike.  No isinstance dispatch:
    the training loop, checkpoint recovery, and distributed paths only ever
    call these three methods."""

    def init(self, params: Optional[PyTree], *, seed: int = 0) -> Any: ...

    def step_fn(self, loss_fn: ZOLossFn) -> Callable: ...

    def restore(self, state: Any, step: int) -> Any: ...


class ZOState(NamedTuple):
    """Uniform optimizer state: a step counter, the run seed, and whatever
    scalar carry the estimator/transforms declared.  Checkpointable as a
    plain pytree; resumable via ``ZOOptimizer.restore``."""
    step: jnp.ndarray
    base_key: jax.Array
    est_state: Any
    tf_state: Any
    last_projected_grad: jnp.ndarray


class ZOOptimizer:
    """estimator × transform-chain behind the uniform protocol.

    >>> opt = ZOOptimizer(estimators.spsa(eps=1e-3),
    ...                   chain(transforms.clip_projected_grad(1.0),
    ...                         transforms.scale_by_schedule(1e-6),
    ...                         transforms.add_weight_decay(0.01)))
    >>> state = opt.init(params, seed=0)
    >>> step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
    >>> params, state, metrics = step(params, state, batch)
    """

    def __init__(self, estimator: ZOEstimator,
                 transform: Optional[ZOTransform] = None,
                 name: Optional[str] = None):
        self.estimator = estimator
        self.transform = transform if transform is not None else identity()
        self.name = name or estimator.name
        if estimator.n_seeds > 1 and self.transform.info.get("applier"):
            raise ValueError(
                "stateful applier transforms (scale_by_zo_adam / trace) keep "
                "one ledger entry per step and cannot run under interleaved "
                "n-SPSA; use n_seeds=1")
        if getattr(estimator, "batch_seeds", 1) > 1 and \
                self.transform.info.get("applier"):
            raise ValueError(
                "applier transforms (scale_by_zo_adam / trace) reconstruct "
                "their update from one scalar per step and cannot consume a "
                "batched-seed estimator's per-seed g vector; use "
                "batch_seeds=1 or a scalar transform chain")
        if self.transform.info.get("applier") and \
                self.transform.info.get("scalar_decay"):
            raise ValueError(
                "add_weight_decay sets the scalar decay slot, which applier "
                "transforms (scale_by_zo_adam / trace) bypass — pass "
                "weight_decay= to the applier transform instead")
        if getattr(estimator, "selection", None) is not None and \
                self.transform.info.get("applier"):
            raise ValueError(
                "applier transforms (scale_by_zo_adam / trace) materialize "
                "their update over the FULL tree from the g-history, which "
                "would write unselected leaves; parameter selections "
                "(repro.select) compose with rank-1 scalar chains only")

    # -- introspection (used for ledger replay and by distributed paths) ---- #
    @property
    def info(self) -> dict:
        return self.transform.info

    @property
    def backend(self) -> "PerturbBackend":
        """The perturbation backend this composition runs through."""
        return get_backend(self.estimator.backend)

    @property
    def backend_name(self) -> str:
        """Identity recorded in checkpoint/ledger metadata — the backend's
        ``stream_id`` (name plus z-generator version suffix) — so replay
        under a different backend OR an artifact from a since-revised
        z generator fails loudly instead of silently diverging."""
        return self.backend.stream_id

    @property
    def batch_seeds(self) -> int:
        """Seed streams evaluated per step by a batched estimator (FZOO);
        1 for everything else.  Recorded in checkpoint/ledger metadata."""
        return int(getattr(self.estimator, "batch_seeds", 1))

    @property
    def selection(self):
        """The resolved ``repro.select.Selection`` scoping this composition's
        perturbations (``None`` = full tree)."""
        return getattr(self.estimator, "selection", None)

    @property
    def selection_spec(self) -> str:
        """Canonical selection spec recorded in checkpoint/ledger metadata
        (``"full"`` when no selection is set) — replay under a different
        selection fails loudly (``SelectionMismatchError``) instead of
        applying the recorded scalars to a different parameter support."""
        sel = self.selection
        return "full" if sel is None else sel.spec

    @property
    def selection_phase(self) -> int:
        """The selection's block-schedule phase offset (0 when unscheduled);
        recorded alongside the spec — phase(t) = (t + offset) mod n_phases."""
        sel = self.selection
        return 0 if sel is None else int(sel.phase_offset)

    @property
    def weight_decay(self) -> float:
        return self.info.get("weight_decay", 0.0)

    def lr_at(self, step) -> jnp.ndarray:
        fn = self.info.get("lr_at")
        return fn(step) if fn is not None else jnp.float32(1.0)

    # -- protocol ----------------------------------------------------------- #
    def init(self, params: Optional[PyTree] = None, *, seed: int = 0) -> ZOState:
        base_key = jax.random.PRNGKey(seed)
        return ZOState(step=jnp.int32(0), base_key=base_key,
                       est_state=self.estimator.init(params, base_key),
                       tf_state=self.transform.init(params),
                       last_projected_grad=jnp.float32(0.0))

    def restore(self, state: ZOState, step: int) -> ZOState:
        """Resume bookkeeping: after ledger replay advanced the parameters
        past a tensor checkpoint, realign the step counter (the seed source
        and lr index) — the protocol form of what used to be an ad-hoc
        ``_replace(step=...)`` in the training loop."""
        return state._replace(step=jnp.int32(step))

    def replay_update(self, params: PyTree, skey: jax.Array, g, lr,
                      phase: int = 0) -> PyTree:
        """Apply one scalar-ledger entry: θ ← (1−η·λ)·θ − η·g·z(skey).
        Used by trajectory replay and checkpoint recovery — no forward
        passes, no data access (paper §2.1).  ``phase`` is the static
        block-schedule phase of the replayed step (0 for unscheduled
        selections) — the caller derives it from the step index exactly as
        the live step did.

        Only rank-1 compositions are replayable from (seed, g, lr) triples:
        an applier transform's step (ZO-Adam / trace) also depends on its
        g-history window, and a Definition-6 rescaled step on its D-tree —
        neither of which the ledger alone can reconstruct."""
        if self.info.get("applier"):
            raise ValueError(
                f"{self.name}: scalar-ledger replay cannot reproduce applier "
                "transforms (scale_by_zo_adam / trace); resume from a full "
                "state checkpoint instead of a ledger tail")
        if not self.estimator.replayable:
            raise ValueError(
                f"{self.name}: the {self.estimator.name!r} estimator updates "
                "along D·z (Definition 6), which a (seed, g, lr) ledger entry "
                "cannot reproduce; resume from a full state checkpoint")
        sel = self.selection
        if self.batch_seeds > 1:
            # batched-seed (FZOO) entry: g is the (B,) per-seed vector and the
            # step was B folded rank-1 applications — replay them identically
            from repro.zo.updates import apply_rank1_batch
            return apply_rank1_batch(params, skey, lr * jnp.asarray(g),
                                     lr * self.weight_decay,
                                     dist=self.estimator.dist,
                                     backend=self.backend,
                                     selection=sel, phase=phase)
        ref = StreamRef(skey)
        if sel is not None:
            ref = ref.with_selection(sel, phase)
        return self.backend.apply_rank1(params, ref, lr * g,
                                        lr * self.weight_decay,
                                        self.estimator.dist)

    def step_fn(self, loss_fn: ZOLossFn) -> Callable[
            [PyTree, ZOState, Any], tuple[PyTree, ZOState, dict]]:
        est = self.estimator
        tf = self.transform
        n = est.n_seeds
        backend = self.backend
        sel = self.selection
        n_phases = 1 if sel is None else int(sel.n_phases)

        def body(params: PyTree, state: ZOState, batch, phase: int):
            skey0 = step_key(state.base_key, state.step)
            p = params
            est_state, tf_state = state.est_state, state.tf_state
            gs, losses = [], []
            aux: dict = {}
            lr_metric = None
            for j in range(n):
                skey = jax.random.fold_in(skey0, j) if n > 1 else skey0
                if n_phases > 1:
                    e = est.estimate(loss_fn, p, batch, skey, est_state,
                                     phase=phase)
                else:
                    e = est.estimate(loss_fn, p, batch, skey, est_state)
                est_state = e.est_state
                ctx = TransformCtx(step=state.step, base_key=state.base_key,
                                   key=skey, seed_index=j, n_seeds=n,
                                   eps=est.eps, dist=est.dist,
                                   restore=e.restore, backend=backend)
                u = Updates(g=e.projected_grad)
                u, tf_state = tf.update(u, tf_state, ctx)
                if u.final_params is not None:
                    p = u.final_params
                else:
                    coeff = u.coeff if u.coeff is not None else u.g
                    p = e.apply_update(coeff, u.decay)
                gs.append(u.g)
                losses.append(e.loss)
                if e.aux:
                    aux.update(e.aux)
                lr_metric = u.lr
            g_mean = jnp.mean(jnp.stack(gs))
            loss = jnp.mean(jnp.stack(losses))
            if lr_metric is None:
                lr_metric = jnp.float32(1.0)
            new_state = ZOState(state.step + 1, state.base_key,
                                est_state, tf_state, g_mean)
            metrics = {"loss": loss, "projected_grad": g_mean,
                       "lr": lr_metric, **aux}
            if n > 1:
                # interleaved n-SPSA: expose the per-seed scalars (fold
                # schedule fold(skey0, j)) so the ledger records what the
                # engine's group replay needs — one g per stream, flattened
                # to the ledger's (n_groups·batch_seeds,) record shape
                metrics["projected_grads"] = jnp.stack(gs).reshape(-1)
            elif jnp.ndim(gs[0]) > 0:
                # batched-seed estimator: expose the per-seed scalars so the
                # ledger records what replay_update needs (one g per stream)
                metrics["projected_grads"] = gs[0]
            return p, new_state, metrics

        if n_phases == 1:
            def step(params: PyTree, state: ZOState, batch):
                return body(params, state, batch, 0)
        else:
            # block-scheduled selection: the active leaf block is a STATIC
            # trace-time property (skipped leaves cost zero z generation), so
            # the step dispatches over the n_phases static bodies with
            # lax.switch on phase(t) = (t + offset) mod n_phases — a pure
            # function of the step counter, hence identical under every
            # execution plan
            branches = [functools.partial(body, phase=ph)
                        for ph in range(n_phases)]

            def step(params: PyTree, state: ZOState, batch):
                return jax.lax.switch(sel.phase_at(state.step), branches,
                                      params, state, batch)

        return step
