"""Named compositions: the paper's optimizers as estimator × transform chains.

These are the blessed recipes — each returns a plain ``ZOOptimizer``; nothing
here is a class of its own.  ``repro.core.MeZO`` / ``MeZOAdam`` /
``MeZOVariant`` are deprecated shims over exactly these compositions
(bitwise-equal steps, enforced by tests/test_zo_api.py).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from repro.perturb.base import BackendSpec
from repro.zo import estimators, transforms
from repro.zo.base import ZOOptimizer, chain


def _scalar_chain(lr: float, n_seeds: int, weight_decay: float,
                  lr_schedule: str, total_steps: int, warmup_steps: int,
                  clip_projected_grad: float, extra=()):
    """clip → η-schedule → weight decay (→ extra applier), the legacy order."""
    del n_seeds  # the facade hands n_seeds to transforms via the ctx
    tfs = []
    if clip_projected_grad > 0:
        tfs.append(transforms.clip_projected_grad(clip_projected_grad))
    tfs.append(transforms.scale_by_schedule(lr, lr_schedule, total_steps,
                                            warmup_steps))
    if not extra:
        # Always present (λ may be 0): keeps the η·λ term in the update graph
        # so composed steps are bitwise-identical to the legacy optimizers.
        tfs.append(transforms.add_weight_decay(weight_decay))
    tfs.extend(extra)
    return chain(*tfs)


def mezo(lr: float = 1e-6, eps: float = 1e-3, n: int = 1,
         dist: str = "gaussian", weight_decay: float = 0.0,
         estimator: str = "spsa", lr_schedule: str = "constant",
         total_steps: int = 0, warmup_steps: int = 0,
         sequential_perturb: bool = True,
         clip_projected_grad: float = 0.0,
         backend: BackendSpec = None, selection=None) -> ZOOptimizer:
    """ZO-SGD with in-place seed-replay perturbations (paper Algorithm 1;
    Algorithm 2 when ``n > 1``).  Composition::

        ZOOptimizer(spsa(eps) | n_spsa(n, eps) | one_point(eps),
                    chain(clip?, scale_by_schedule(lr), add_weight_decay?))

    ``backend`` selects the z-generation strategy (``"xla"`` threefry HBM
    temporaries, ``"pallas"`` VMEM-fused kernel with interpret-mode CPU
    fallback) — see :mod:`repro.perturb`.  ``selection`` scopes the
    perturbation/update to a parameter subset (``repro.select.Selection`` or
    spec string, e.g. ``"block_cyclic(4)"`` or ``select.peft("lora")``).
    """
    if estimator == "one_point":
        est = estimators.one_point(eps=eps, dist=dist, backend=backend,
                                   selection=selection)
    elif estimator == "spsa":
        est = (estimators.n_spsa(n, eps=eps, dist=dist,
                                 sequential=sequential_perturb,
                                 backend=backend, selection=selection)
               if n > 1 else
               estimators.spsa(eps=eps, dist=dist,
                               sequential=sequential_perturb,
                               backend=backend, selection=selection))
    else:
        raise ValueError(f"unknown estimator {estimator!r}")
    tf = _scalar_chain(lr, n, weight_decay, lr_schedule, total_steps,
                       warmup_steps, clip_projected_grad)
    return ZOOptimizer(est, tf, name="mezo")


def fzoo(lr: float = 1e-5, eps: float = 1e-3, batch_seeds: int = 8,
         dist: str = "gaussian", weight_decay: float = 0.0,
         lr_schedule: str = "constant", total_steps: int = 0,
         warmup_steps: int = 0, clip_projected_grad: float = 0.0,
         std_floor: float = 1e-8,
         backend: BackendSpec = None, selection=None) -> ZOOptimizer:
    """FZOO (Dang et al., 2025): B batched one-sided seed perturbations per
    step — one vmapped forward over the ``perturb_many`` stacked-params view —
    with the step size normalized by the std of the B loss differences.
    Composition::

        ZOOptimizer(fzoo(batch_seeds, eps),
                    chain(scale_by_fzoo_std(std_floor), clip?,
                          scale_by_schedule(lr), add_weight_decay))

    The per-seed g vector rides the scalar transform chain elementwise and is
    recorded per step in the trajectory ledger (``MZOL3``), so crash-resume
    and trajectory replay reproduce the B folded rank-1 updates exactly.
    ``backend`` picks the z strategy: ``"xla"`` vectorizes threefry over the
    stacked keys; ``"pallas"`` runs the batched-seed kernel (B z-streams per
    VMEM tile).
    """
    est = estimators.fzoo(batch_seeds=batch_seeds, eps=eps, dist=dist,
                          backend=backend, selection=selection)
    tfs = [transforms.scale_by_fzoo_std(std_floor)]
    if clip_projected_grad > 0:
        tfs.append(transforms.clip_projected_grad(clip_projected_grad))
    tfs.append(transforms.scale_by_schedule(lr, lr_schedule, total_steps,
                                            warmup_steps))
    tfs.append(transforms.add_weight_decay(weight_decay))
    return ZOOptimizer(est, chain(*tfs), name="fzoo")


def mezo_adam(lr: float = 1e-4, eps: float = 1e-3, beta1: float = 0.9,
              beta2: float = 0.999, adam_eps: float = 1e-8,
              materialized: bool = False, window: int = 32,
              momentum_only: bool = False, dist: str = "gaussian",
              weight_decay: float = 0.0, lr_schedule: str = "constant",
              total_steps: int = 0, warmup_steps: int = 0,
              clip_projected_grad: float = 0.0,
              backend: BackendSpec = None, selection=None) -> ZOOptimizer:
    """MeZO-Adam / MeZO-momentum (paper §2.2 + App. B.2): the SPSA estimator
    with the Adam preconditioner reconstructed from the scalar g-history
    (ring buffer of ``window`` scalars) or materialized as the m/v oracle.
    ``selection`` is accepted for interface symmetry but refused by the
    facade (applier transforms materialize full-tree updates)."""
    est = estimators.spsa(eps=eps, dist=dist, sequential=True,
                          backend=backend, selection=selection)
    adam = transforms.scale_by_zo_adam(
        beta1=beta1, beta2=beta2, adam_eps=adam_eps, materialized=materialized,
        window=window, momentum_only=momentum_only, weight_decay=weight_decay)
    tf = _scalar_chain(lr, 1, 0.0, lr_schedule, total_steps, warmup_steps,
                       clip_projected_grad, extra=(adam,))
    return ZOOptimizer(est, tf, name="mezo_adam")


def mezo_rescaled(lr: float = 1e-6, eps: float = 1e-3,
                  dist: str = "gaussian", d_source: str = "param_norm",
                  modify_expectation: bool = False,
                  probe_loss_fn: Optional[Callable] = None,
                  probe_batch: Any = None, probe_eps: float = 1e-4,
                  weight_decay: float = 0.0, lr_schedule: str = "constant",
                  total_steps: int = 0, warmup_steps: int = 0,
                  clip_projected_grad: float = 0.0,
                  backend: BackendSpec = None, selection=None) -> ZOOptimizer:
    """Variance/expectation-modified SPSA (paper App. B.3/B.4, Definitions
    6/7): perturb by ε·(d⁻¹⊙z), update along (D or I)·z.  The paper found no
    consistent win over plain MeZO at equal forward budget — kept because it
    shows how cheaply the estimator family extends."""
    est = estimators.rescaled_spsa(
        eps=eps, dist=dist, d_source=d_source,
        modify_expectation=modify_expectation, probe_loss_fn=probe_loss_fn,
        probe_batch=probe_batch, probe_eps=probe_eps, backend=backend,
        selection=selection)
    tf = _scalar_chain(lr, 1, weight_decay, lr_schedule, total_steps,
                       warmup_steps, clip_projected_grad)
    return ZOOptimizer(est, tf, name="mezo_rescaled")


# --------------------------------------------------------------------------- #
# Legacy-config interop
# --------------------------------------------------------------------------- #
def from_config(config) -> ZOOptimizer:
    """Build the composition equivalent of a legacy ``MeZOConfig`` /
    ``MeZOAdamConfig`` / ``MeZOVariantConfig`` (duck-typed — any object with
    the same fields works)."""
    common = dict(lr=config.lr, eps=config.eps, dist=config.dist,
                  weight_decay=config.weight_decay,
                  lr_schedule=config.lr_schedule,
                  total_steps=config.total_steps,
                  warmup_steps=config.warmup_steps,
                  clip_projected_grad=config.clip_projected_grad,
                  backend=getattr(config, "backend", None),
                  selection=getattr(config, "selection", None))
    if getattr(config, "d_source", None) is not None:
        return mezo_rescaled(d_source=config.d_source,
                             modify_expectation=config.modify_expectation,
                             probe_eps=config.d_probe_eps, **common)
    if getattr(config, "beta1", None) is not None:
        return mezo_adam(beta1=config.beta1, beta2=config.beta2,
                         adam_eps=config.adam_eps,
                         materialized=config.materialized,
                         window=config.window,
                         momentum_only=config.momentum_only, **common)
    return mezo(n=config.n, estimator=config.estimator,
                sequential_perturb=config.sequential_perturb, **common)


def as_zo_optimizer(optimizer_or_config) -> ZOOptimizer:
    """Accept either a protocol-conforming ZO optimizer or a legacy config
    object, returning something with ``replay_update`` / ``lr_at`` /
    ``estimator``.  This is the compatibility seam that lets the trajectory
    replayer, checkpoint recovery, and distributed paths consume the facade
    while old call sites still pass bare configs."""
    if callable(getattr(optimizer_or_config, "replay_update", None)):
        return optimizer_or_config
    return from_config(optimizer_or_config)
