"""``repro.zo`` — composable zeroth-order optimization (estimator × transforms).

The paper's key structural insight ("Fine-Tuning Language Models with Just
Forward Passes", Malladi et al., NeurIPS 2023) is that a MeZO update is fully
determined by scalar pairs ``(seed, projected_grad)``.  This package turns
that insight into an optax-style composition layer: estimators produce the
scalar, transforms rewrite the scalar ledger, and one facade speaks a uniform
protocol to the training loop, checkpoint recovery, and distributed paths.

Mapping onto the paper
----------------------
=====================================  =======================================
Paper                                  Component
=====================================  =======================================
Algorithm 1 (MeZO)                     ``estimators.spsa(eps)`` — lines 3–8:
                                       the sequential perturb → ℓ+ → perturb
                                       → ℓ− chain with one fused
                                       restore+descent pass (4 z-regens → 3).
Algorithm 1's descent loop             ``updates.apply_rank1`` — the single
                                       θ ← (1−ηλ)θ − η·g·z(seed) primitive
                                       shared by steps, ledger replay, and
                                       async application.
Algorithm 2 (n-SPSA)                   ``estimators.n_spsa(n, eps)`` — n
                                       folded seed keys, updates interleaved
                                       at η/n per seed; plus
                                       ``transforms.scale_by_schedule``'s
                                       per-seed η/n scaling.
Definition 6 (variance-modified,       ``estimators.rescaled_spsa(...)`` —
unbiased: perturb ε·d⁻¹⊙z, update       block-diagonal D-tree (one scalar per
along D·z)                             leaf) from parameter norms or
                                       Proposition-1 ZO grad-norm probes.
Definition 7 (expectation-modified,    ``estimators.rescaled_spsa(
biased normalized-gradient: update       modify_expectation=True)`` — same
along z, not D·z)                      perturbation, identity update scaling.
Definition 8 (one-point residual       ``estimators.one_point(eps)`` — one
feedback)                              forward pass/step, previous perturbed
                                       loss carried as estimator state.
§2.1 storage trick (seed + scalar      ``ZOOptimizer.replay_update`` consumed
ledger reconstructs the run)           by ``core.trajectory.replay`` and
                                       ``checkpoint.manager`` recovery.
§2.2 / App. B.2 (MeZO-Adam from the    ``transforms.scale_by_zo_adam`` —
scalar history)                        ring-buffer recomputed mode (O(window)
                                       scalars) or materialized m/v oracle;
                                       ``transforms.trace`` is the
                                       momentum-only special case.
=====================================  =======================================

Quick start
-----------
>>> from repro import zo
>>> opt = zo.mezo(lr=1e-6, eps=1e-3)                 # Algorithm 1
>>> opt = zo.mezo(lr=1e-6, eps=1e-3, backend="pallas")   # z in VMEM, not HBM
>>> opt = zo.mezo(lr=1e-6, selection="block_cyclic(4)")  # repro.select: ~1/4
...     # of the tree perturbed per step (zero z generation for the rest)
>>> opt = zo.fzoo(lr=1e-6, eps=1e-3, batch_seeds=8)  # FZOO: B batched
...     # one-sided seed streams per step, one vmapped forward, step size
...     # normalized by the std of the B loss differences
>>> # ...or compose by hand:
>>> opt = zo.ZOOptimizer(
...     zo.estimators.spsa(eps=1e-3),
...     zo.chain(zo.transforms.clip_projected_grad(1.0),
...              zo.transforms.scale_by_schedule(1e-6, "linear", 10_000),
...              zo.transforms.add_weight_decay(0.01)))
>>> state = opt.init(params, seed=0)
>>> step = jax.jit(opt.step_fn(loss_fn), donate_argnums=(0,))
>>> params, state, metrics = step(params, state, batch)
>>> state = opt.restore(state, 5_000)                # resume bookkeeping

New estimators (MeZO-SVRG-style variance reduction; FZOO's batched seeds
landed exactly this way: ``estimators.fzoo`` + ``transforms.scale_by_fzoo_std``)
and new update rules plug in as components — one ``ZOEstimator`` or one
``ZOTransform``, not a new monolithic optimizer class.  Every composition
takes a ``backend=`` kwarg selecting the z-generation strategy
(:mod:`repro.perturb`): ``"xla"`` threefry (default) or ``"pallas"`` — the
fused kernel generating z inside VMEM, with interpret-mode CPU fallback.
The choice is recorded in checkpoint/ledger metadata; replay under the wrong
backend raises ``BackendMismatchError`` instead of silently diverging.
"""
from repro.zo import estimators, transforms
from repro.zo.base import (Optimizer, TransformCtx, Updates, ZOEstimate,
                           ZOEstimator, ZOLossFn, ZOOptimizer, ZOState,
                           ZOTransform, chain, identity)
from repro.zo.presets import (as_zo_optimizer, from_config, fzoo, mezo,
                              mezo_adam, mezo_rescaled)
from repro.zo.updates import apply_rank1, apply_rank1_batch

__all__ = [
    # protocol
    "Optimizer", "ZOOptimizer", "ZOState", "ZOEstimator", "ZOEstimate",
    "ZOTransform", "TransformCtx", "Updates", "ZOLossFn",
    # composition
    "chain", "identity", "estimators", "transforms",
    # primitives
    "apply_rank1", "apply_rank1_batch",
    # presets / interop
    "mezo", "fzoo", "mezo_adam", "mezo_rescaled", "from_config",
    "as_zo_optimizer",
]
