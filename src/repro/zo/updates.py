"""The one primitive every ZO consumer shares: the seeded rank-1 update.

A zeroth-order step is fully described by scalars — ``(key, coeff, decay)``
with ``coeff = η·g`` — because the direction z is a pure function of the PRNG
key (paper §2.1).  ``apply_rank1`` is therefore the single code path through
which the optimizer facade, the trajectory-ledger replayer, the async
straggler path, and the seed-parallel collective all write parameters:

    θ ← (1 − decay) · θ − coeff · z(key)        [z optionally ⊙ d per leaf]

Keeping one implementation means a ledger replay, a late async contribution,
and a live training step are guaranteed to perform the identical arithmetic —
the property the bitwise crash-recovery tests rely on.

The z generation itself is delegated to a ``repro.perturb`` backend
(``xla`` threefry by default; ``pallas`` for VMEM-resident generation) — the
same backend the producing step used, so the consistency guarantee holds per
backend and cross-backend replay is refused upstream
(``BackendMismatchError``).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.perturb import StreamRef, get_backend
from repro.perturb.base import BackendSpec
from repro.perturb.xla import Distribution
from repro.tree_utils import PyTree


def apply_rank1(params: PyTree, key: jax.Array, coeff, decay_term=0.0,
                dist: Distribution = "gaussian",
                d_tree: Optional[PyTree] = None,
                backend: BackendSpec = None,
                selection=None, phase: int = 0) -> PyTree:
    """θ ← (1 − decay_term)·θ − coeff·z(key), regenerating z leaf by leaf.

    ``coeff`` is the full η-scaled scalar (η·g, or η/n·g per seed);
    ``decay_term`` is the decoupled weight-decay coefficient η·λ.  ``d_tree``
    holds one positive scalar per leaf and rescales z (Definition 6's
    block-diagonal D); ``None`` leaves z unscaled (Definition 7 / plain SPSA).
    ``backend`` selects the z-generation strategy (default ``xla``);
    ``selection``/``phase`` scope the update to a parameter subset
    (``repro.select`` — unselected leaves are untouched, decay included).
    Non-floating leaves pass through untouched.
    """
    ref = StreamRef(key)
    if selection is not None:
        ref = ref.with_selection(selection, phase)
    return get_backend(backend).apply_rank1(params, ref, coeff,
                                            decay_term, dist, d_tree=d_tree)


def apply_rank1_batch(params: PyTree, skey: jax.Array, coeff_vec,
                      decay_term=0.0, dist: Distribution = "gaussian",
                      backend: BackendSpec = None,
                      selection=None, phase: int = 0) -> PyTree:
    """The batched-seed (FZOO) step as B sequential rank-1 applications:

        for j in 0..B-1:  θ ← (1 − [j==0]·decay)·θ − (coeff_j / B)·z(fold(skey, j))

    ``coeff_vec`` holds one η-scaled coefficient per seed stream (η·g_j for a
    replayed ledger entry; the transform chain's output for a live step);
    ``decay_term`` is the decoupled η·λ, applied once on the first stream.
    ``selection``/``phase`` scope every stream's update to the same parameter
    subset (a step has ONE schedule phase — the streams share it).
    This is the ONE code path shared by the live fzoo estimator's
    ``apply_update`` and ``ZOOptimizer.replay_update`` — keeping the fold /
    divide / decay schedule in a single place is what makes a ledger replay
    perform arithmetic identical to the recorded step.

    The fold itself is handed to the backend as ONE ``affine_many`` call:
    the ``xla`` fallback is the literal sequential chain above (bitwise the
    pre-fusion path by construction), while ``pallas`` runs the fused chain
    kernel — all B streams folded per resident VMEM tile, one HBM round-trip
    of θ instead of B (bitwise-equal to the sequential chain,
    contract-tested)."""
    be = get_backend(backend)
    coeff_vec = jnp.asarray(coeff_vec)
    if coeff_vec.ndim != 1:
        raise ValueError(f"apply_rank1_batch needs a (B,) coefficient "
                         f"vector; got shape {coeff_vec.shape}")
    n = coeff_vec.shape[0]
    refs, coeffs, decays = [], [], []
    for j in range(n):
        ref = StreamRef(jax.random.fold_in(skey, j))
        if selection is not None:
            ref = ref.with_selection(selection, phase)
        refs.append(ref)
        coeffs.append(coeff_vec[j] / n)
        decays.append(decay_term if j == 0 else 0.0)
    return be.affine_many(params, refs, coeffs, decays, dist)
