"""The one primitive every ZO consumer shares: the seeded rank-1 update.

A zeroth-order step is fully described by scalars — ``(key, coeff, decay)``
with ``coeff = η·g`` — because the direction z is a pure function of the PRNG
key (paper §2.1).  ``apply_rank1`` is therefore the single code path through
which the optimizer facade, the trajectory-ledger replayer, the async
straggler path, and the seed-parallel collective all write parameters:

    θ ← (1 − decay) · θ − coeff · z(key)        [z optionally ⊙ d per leaf]

Keeping one implementation means a ledger replay, a late async contribution,
and a live training step are guaranteed to perform the identical arithmetic —
the property the bitwise crash-recovery tests rely on.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.perturb import Distribution, leaf_key, sample_leaf_z
from repro.tree_utils import PyTree, tree_map_with_index


def apply_rank1(params: PyTree, key: jax.Array, coeff, decay_term=0.0,
                dist: Distribution = "gaussian",
                d_tree: Optional[PyTree] = None) -> PyTree:
    """θ ← (1 − decay_term)·θ − coeff·z(key), regenerating z leaf by leaf.

    ``coeff`` is the full η-scaled scalar (η·g, or η/n·g per seed);
    ``decay_term`` is the decoupled weight-decay coefficient η·λ.  ``d_tree``
    holds one positive scalar per leaf and rescales z (Definition 6's
    block-diagonal D); ``None`` leaves z unscaled (Definition 7 / plain SPSA).
    Non-floating leaves pass through untouched.
    """
    d_leaves = jax.tree_util.tree_leaves(d_tree) if d_tree is not None else None

    def one(i, p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        z = sample_leaf_z(leaf_key(key, i), p, dist)
        if d_leaves is not None:
            z = z * jnp.asarray(d_leaves[i], p.dtype)
        coeff_ = jnp.asarray(coeff, p.dtype)
        decay = jnp.asarray(1.0 - decay_term, p.dtype)
        return decay * p - coeff_ * z

    return tree_map_with_index(one, params)
