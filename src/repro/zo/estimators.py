"""ZO estimator implementations behind the ``ZOEstimator`` protocol.

Each factory returns a ``ZOEstimator`` whose ``estimate`` preserves the
donation-friendly sequential perturbation chain of ``core/mezo.py``: with the
whole step jitted and ``params`` donated, XLA keeps exactly one
parameter-sized buffer alive across perturb → ℓ+ → perturb → ℓ− → fused
restore+update (the paper's inference-memory property).

Every perturbation and parameter write goes through a ``repro.perturb``
backend (``backend=`` kwarg on every factory): ``"xla"`` (default) generates
z as threefry HBM temporaries, ``"pallas"`` generates z tile-by-tile in VMEM
via the fused kernel — same estimator chain, different point in the memory
hierarchy.  Unsupported (backend, dist) pairs fail loudly at factory time.

Every factory also accepts ``selection=`` (a ``repro.select.Selection`` or
spec string): the perturbation/update chain is scoped to the selected leaves
— unselected leaves cost zero z generation and are never written.  Block
schedules (``select.block_cyclic(k)``) make ``estimate`` phase-aware: the
facade passes the static schedule phase of the step.

* ``spsa``          — two-point SPSA (Definition 1 / Algorithm 1 lines 3–8).
* ``n_spsa``        — n independent seeds, interleaved updates (Algorithm 2);
                      the facade folds the step key once per seed.
* ``one_point``     — residual-feedback single-forward estimator
                      (Definition 8); carries the previous perturbed loss.
* ``rescaled_spsa`` — block-diagonal rescaled SPSA (Definitions 6/7): perturb
                      by ε·(d⁻¹⊙z), update along (D or I)·z.  The D-tree is
                      one positive scalar per leaf, computed at ``init`` from
                      parameter norms or Proposition-1 ZO gradient-norm
                      probes.
* ``fzoo``          — FZOO-style batched seeds (Dang et al., 2025): B
                      one-sided perturbations per step evaluated by ONE
                      batched forward (vmap over the stacked-params view from
                      ``PerturbBackend.perturb_many``), per-seed projected
                      gradients g_j = (ℓ_j − ℓ₀)/ε applied as B folded rank-1
                      updates at η/B each; compose with
                      ``transforms.scale_by_fzoo_std`` for the paper's
                      loss-diff-std step-size normalization.
"""
from __future__ import annotations

from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.spsa import OnePointState, one_point_init, zo_grad_norm
from repro.perturb import StreamRef, get_backend
from repro.perturb.base import BackendSpec
from repro.perturb.xla import Distribution
from repro.select import resolve_selection
from repro.tree_utils import PyTree, tree_map_with_index
from repro.zo.base import ZOEstimate, ZOEstimator
from repro.zo.updates import apply_rank1_batch


# --------------------------------------------------------------------------- #
# SPSA (Definition 1) and n-SPSA (Algorithm 2)
# --------------------------------------------------------------------------- #
def spsa(eps: float = 1e-3, dist: Distribution = "gaussian",
         sequential: bool = True, backend: BackendSpec = None,
         selection=None) -> ZOEstimator:
    """Two-point SPSA.  ``sequential=True`` is the paper-faithful in-place
    chain θ → θ+εz → θ−εz with a fused restore+descent pass; ``False``
    perturbs from the center twice (one more live buffer, numerically
    cleaner — θ itself is never touched).  ``selection`` scopes the
    perturbation to a parameter subset (``repro.select``); skipped leaves
    cost zero z generation."""
    be = get_backend(backend)
    be.check_dist(dist)
    sel = resolve_selection(selection)

    def init(params, key):
        del params, key
        return ()

    def estimate(loss_fn, params, batch, key, est_state, phase: int = 0):
        ref = StreamRef(key) if sel is None else \
            StreamRef(key).with_selection(sel, phase)
        if sequential:
            p_plus = be.perturb(params, ref, eps, dist)
            l_plus = loss_fn(p_plus, batch)
            p_minus = be.perturb(p_plus, ref, -2.0 * eps, dist)
            l_minus = loss_fn(p_minus, batch)
            g = (l_plus - l_minus) / (2.0 * eps)

            def apply_update(coeff, decay_term):
                return be.fused_restore_update(p_minus, ref, eps, coeff,
                                               weight_decay=decay_term,
                                               dist=dist)

            def restore():
                return be.fused_restore_update(p_minus, ref, eps, 0.0, 0.0,
                                               dist)
        else:
            # both center perturbations as ONE antithetic fan-out: the ±ε
            # views share a single perturb_many (per-stream scales), so the
            # pallas backend generates both streams' z from one HBM read of
            # θ per tile instead of two separate kernel chains.  The losses
            # stay two separate forwards over the sliced views — the
            # estimator's arithmetic, not the generation, is unchanged.
            pair = be.perturb_many(params, [ref, ref], (eps, -eps), dist)
            l_plus = loss_fn(jax.tree_util.tree_map(lambda s: s[0], pair),
                             batch)
            l_minus = loss_fn(jax.tree_util.tree_map(lambda s: s[1], pair),
                              batch)
            g = (l_plus - l_minus) / (2.0 * eps)

            def apply_update(coeff, decay_term):
                return be.apply_rank1(params, ref, coeff, decay_term, dist)

            def restore():
                return params

        return ZOEstimate(projected_grad=g, loss=0.5 * (l_plus + l_minus),
                          apply_update=apply_update, restore=restore,
                          est_state=est_state, aux={})

    return ZOEstimator(init=init, estimate=estimate, n_seeds=1, eps=eps,
                       dist=dist, name="spsa", backend=be, selection=sel)


def n_spsa(n: int, eps: float = 1e-3, dist: Distribution = "gaussian",
           sequential: bool = True, backend: BackendSpec = None,
           selection=None) -> ZOEstimator:
    """n-SPSA, sequential over seeds (Algorithm 2): the facade runs the
    two-point estimate once per folded seed key and applies each seed's
    update (η/n per seed) before the next seed's perturbation — the same
    one-live-buffer chain as n=1.  The seed-parallel variant that trades this
    for batch slicing lives in ``repro.distributed.collectives``."""
    base = spsa(eps=eps, dist=dist, sequential=sequential, backend=backend,
                selection=selection)
    return base._replace(n_seeds=int(n), name="n_spsa")


# --------------------------------------------------------------------------- #
# FZOO batched seeds (Dang et al., 2025)
# --------------------------------------------------------------------------- #
def fzoo(batch_seeds: int = 8, eps: float = 1e-3, dist: Distribution = "gaussian",
         backend: BackendSpec = None, selection=None) -> ZOEstimator:
    """Batched-seed one-sided estimator: per step, B seed streams
    z_1..z_B (folded from the step key exactly as ``replay_update`` refolds
    them), ONE batched forward over the stacked θ+εz_j views produced by
    ``perturb_many``, plus the center forward ℓ₀ — B+1 losses for 2 forward
    dispatches instead of 2B.

    ``estimate`` returns the (B,) vector of per-seed projected gradients
    g_j = (ℓ_j − ℓ₀)/ε; the scalar transform chain applies elementwise and
    ``apply_update`` walks the B rank-1 updates (η/B per stream, decoupled
    decay once) through the backend primitive — arithmetic identical to
    ``updates.apply_rank1_batch``, which ledger replay uses.  FZOO's
    Adam-scale convergence comes from normalizing the step by the std of the
    B loss differences — that is ``transforms.scale_by_fzoo_std``, kept
    separate so the estimator stays a pure gradient estimator."""
    be = get_backend(backend)
    be.check_dist(dist)
    sel = resolve_selection(selection)
    n_batch = int(batch_seeds)
    if n_batch < 1:
        raise ValueError(f"batch_seeds must be >= 1, got {batch_seeds}")

    def init(params, key):
        del params, key
        return ()

    def estimate(loss_fn, params, batch, key, est_state, phase: int = 0):
        # B == 1 degenerates to one-sided SPSA on the unfolded step key (the
        # property-test contract, and what scalar-ledger replay refolds);
        # B > 1 folds one stream per seed exactly as apply_rank1_batch does.
        if n_batch == 1:
            refs = [StreamRef(key)]
        else:
            refs = [StreamRef(jax.random.fold_in(key, j))
                    for j in range(n_batch)]
        if sel is not None:
            refs = [r.with_selection(sel, phase) for r in refs]
        stacked = be.perturb_many(params, refs, eps, dist)
        losses = jax.vmap(lambda p: loss_fn(p, batch))(stacked)
        l0 = loss_fn(params, batch)
        diffs = losses - l0
        g_vec = diffs / eps                       # (B,) per-seed projected g

        def apply_update(coeff, decay_term):
            # coeff is the η-scaled per-seed coefficient (vector for B > 1)
            # from the transform chain; the batched application delegates to
            # updates.apply_rank1_batch — the SAME code path ledger replay
            # uses, so a (seed, g, lr) entry reproduces this step.
            if n_batch == 1:
                return be.apply_rank1(params, refs[0], coeff, decay_term,
                                      dist)
            return apply_rank1_batch(params, key, coeff, decay_term, dist,
                                     backend=be, selection=sel, phase=phase)

        def restore():
            return params

        return ZOEstimate(projected_grad=g_vec[0] if n_batch == 1 else g_vec,
                          loss=l0,
                          apply_update=apply_update, restore=restore,
                          est_state=est_state,
                          aux={"fzoo_loss_std": jnp.std(diffs)})

    return ZOEstimator(init=init, estimate=estimate, n_seeds=1, eps=eps,
                       dist=dist, name="fzoo", replayable=True, backend=be,
                       batch_seeds=n_batch, selection=sel)


# --------------------------------------------------------------------------- #
# One-point residual feedback (Definition 8)
# --------------------------------------------------------------------------- #
def one_point(eps: float = 1e-3, dist: Distribution = "gaussian",
              backend: BackendSpec = None, selection=None) -> ZOEstimator:
    """g_t = (L(θ_t + εz_t) − L_prev) / ε — one forward pass per step, the
    previous perturbed loss carried as estimator state.  Twice as fast per
    step as SPSA but far less query-efficient (paper Table 11)."""
    be = get_backend(backend)
    be.check_dist(dist)
    sel = resolve_selection(selection)

    def init(params, key):
        del params, key
        return one_point_init()

    def estimate(loss_fn, params, batch, key, est_state: OnePointState,
                 phase: int = 0):
        ref = StreamRef(key) if sel is None else \
            StreamRef(key).with_selection(sel, phase)
        l_pert = loss_fn(be.perturb(params, ref, eps, dist), batch)
        g = (l_pert - est_state.prev_perturbed_loss) / eps

        def apply_update(coeff, decay_term):
            return be.apply_rank1(params, ref, coeff, decay_term, dist)

        def restore():
            return params

        return ZOEstimate(projected_grad=g, loss=l_pert,
                          apply_update=apply_update, restore=restore,
                          est_state=OnePointState(l_pert), aux={})

    return ZOEstimator(init=init, estimate=estimate, n_seeds=1, eps=eps,
                       dist=dist, name="one_point", backend=be, selection=sel)


# --------------------------------------------------------------------------- #
# Rescaled SPSA (Definitions 6/7) — block-diagonal D-trees
# --------------------------------------------------------------------------- #
def _leaf_norms(params: PyTree) -> PyTree:
    """RMS per leaf (size-free) with a floor so zero-initialized leaves don't
    poison the geometric-mean normalization."""
    return jax.tree_util.tree_map(
        lambda p: jnp.maximum(
            jnp.sqrt(jnp.mean(p.astype(jnp.float32) ** 2)), 1e-2), params)


def _grad_norms_zo(loss_fn, params, batch, key, eps, n_probe: int = 4) -> PyTree:
    """Proposition 1 per-leaf gradient-norm estimates (no backprop): RMS over
    ``n_probe`` single-leaf probes."""
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i in range(len(leaves)):
        acc = 0.0
        for j in range(n_probe):
            k = jax.random.fold_in(jax.random.fold_in(key, i), j)
            g = zo_grad_norm(loss_fn, params, batch, k, eps, leaf_indices=[i])
            acc = acc + g.astype(jnp.float32) ** 2
        out.append(jnp.maximum(jnp.sqrt(acc / n_probe), 1e-6))
    return jax.tree_util.tree_unflatten(treedef, out)


def compute_d_tree(params: PyTree, key: jax.Array, d_source: str = "param_norm",
                   probe_loss_fn: Optional[Callable] = None,
                   probe_batch: Any = None, probe_eps: float = 1e-4) -> PyTree:
    """Build the block-diagonal D (one positive scalar per leaf), normalized
    to unit geometric mean so the global lr keeps its scale."""
    if d_source == "param_norm":
        d = _leaf_norms(params)
    elif d_source == "grad_norm_zo":
        if probe_loss_fn is None or probe_batch is None:
            raise ValueError("d_source='grad_norm_zo' needs probe_loss_fn and "
                             "probe_batch at init time (Proposition 1 probes)")
        d = _grad_norms_zo(probe_loss_fn, params, probe_batch, key, probe_eps)
    elif d_source == "ones":
        d = jax.tree_util.tree_map(lambda p: jnp.float32(1.0), params)
    else:
        raise ValueError(f"unknown d_source {d_source!r}")
    logs = jnp.stack([jnp.log(x) for x in jax.tree_util.tree_leaves(d)])
    scale = jnp.exp(jnp.mean(logs))
    return jax.tree_util.tree_map(lambda x: x / scale, d)


def rescaled_spsa(eps: float = 1e-3, dist: Distribution = "gaussian",
                  d_source: str = "param_norm",
                  modify_expectation: bool = False,
                  probe_loss_fn: Optional[Callable] = None,
                  probe_batch: Any = None,
                  probe_eps: float = 1e-4,
                  d_tree: Optional[PyTree] = None,
                  backend: BackendSpec = None, selection=None) -> ZOEstimator:
    """Definition 6 (unbiased, update along D·z) / Definition 7
    (``modify_expectation=True``: biased normalized-gradient estimate, update
    along z).  The D-tree lives in the estimator state, so it rides through
    checkpoints like any other scalar carry.  Pass ``d_tree`` to skip the
    init-time computation entirely."""
    be = get_backend(backend)
    be.check_dist(dist)
    sel = resolve_selection(selection)
    if sel is not None and sel.kind == "rows":
        raise ValueError(
            "rescaled_spsa builds its perturbation from per-leaf D·z "
            "(leaf_z + whole-leaf mask math), which cannot honor sub-leaf "
            "rows(...) selections — the perturbation would touch whole "
            "leaves while the update writes only the selected row blocks. "
            "Use a whole-leaf selection kind (full / block_cyclic / leaves "
            "/ peft / moe_experts) or the spsa/fzoo estimators with "
            "rows(...)")

    def init(params, key):
        if d_tree is not None:
            return d_tree
        if params is None:
            raise ValueError("rescaled_spsa.init needs params to build D")
        return compute_d_tree(params, key, d_source, probe_loss_fn,
                              probe_batch, probe_eps)

    def estimate(loss_fn, params, batch, key, est_state, phase: int = 0):
        ref = StreamRef(key) if sel is None else \
            StreamRef(key).with_selection(sel, phase)
        mask = ref.selection_mask(params)
        d = est_state
        d_leaves = jax.tree_util.tree_leaves(d)

        def pert(i, p, sign):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return p
            if mask is not None and not mask[i]:
                return p
            z = be.leaf_z(ref, i, p, dist)
            dinv = (1.0 / d_leaves[i]).astype(p.dtype)
            return p + sign * jnp.asarray(eps, p.dtype) * dinv * z

        p_plus = tree_map_with_index(lambda i, p: pert(i, p, 1.0), params)
        l_plus = loss_fn(p_plus, batch)
        p_minus = tree_map_with_index(lambda i, p: pert(i, p, -2.0), p_plus)
        l_minus = loss_fn(p_minus, batch)
        g = (l_plus - l_minus) / (2.0 * eps)
        d_for_update = None if modify_expectation else d

        def restore():
            return tree_map_with_index(lambda i, p: pert(i, p, 1.0), p_minus)

        def apply_update(coeff, decay_term):
            return be.apply_rank1(restore(), ref, coeff, decay_term, dist,
                                  d_tree=d_for_update)

        return ZOEstimate(projected_grad=g, loss=0.5 * (l_plus + l_minus),
                          apply_update=apply_update, restore=restore,
                          est_state=est_state, aux={})

    # Definition 7 updates along plain z — a ledger triple reproduces it;
    # Definition 6 updates along D·z, which only the live est_state carries.
    return ZOEstimator(init=init, estimate=estimate, n_seeds=1, eps=eps,
                       dist=dist, name="rescaled_spsa",
                       replayable=bool(modify_expectation), backend=be,
                       selection=sel)
