"""MeZO-specific collective patterns.

The punchline (DESIGN.md §2): under data parallelism MeZO's *entire*
inter-replica traffic per step is the scalar loss all-reduce — two f32 per
seed — because every shard regenerates the same z locally (threefry is
counter-based and partitionable, so ``jax.random.normal(key, global_shape)``
yields identical values under any sharding).

Beyond-paper feature — **seed-parallel n-SPSA**: Algorithm 2 evaluates n
seeds *sequentially* on the full batch (2n forward passes).  Here the global
batch is split into n slices; seed g is evaluated only on slice g.  Under
pjit with batch sharded over 'data', slice g's ℓ± reductions are data-local
to the devices holding it, so the step costs the same wall-clock and FLOPs
as plain 1-SPSA on the full batch while averaging n independent rank-1
directions — n× direction-variance reduction for free.  The cross-device
traffic is the 2n loss scalars.

This module consumes the ``repro.zo`` facade: hyperparameters (ε, dist, the
lr schedule, λ) come from the optimizer protocol — pass ``zo.mezo(...)`` (or,
for backward compatibility, a legacy ``MeZOConfig``) — and every parameter
write goes through the shared ``apply_rank1`` primitive, the same arithmetic
a ledger replay performs.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.perturb import step_key
from repro.perturb import StreamRef, get_backend
from repro.tree_utils import PyTree
from repro.zo.presets import as_zo_optimizer


def psum_scalar(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Scalar all-reduce — MeZO's only gradient communication."""
    return jax.lax.psum(x, axis_name)


class SeedParallelState(NamedTuple):
    step: jnp.ndarray
    base_key: jax.Array


def seed_parallel_init(seed: int = 0) -> SeedParallelState:
    return SeedParallelState(jnp.int32(0), jax.random.PRNGKey(seed))


def seed_parallel_step_fn(loss_fn: Callable, optimizer, n_groups: int):
    """Build ``step(params, state, batch) -> (params, state, metrics)``.

    ``optimizer`` is a ``repro.zo`` protocol conformer (or legacy config).
    ``batch`` leaves must have leading dim divisible by ``n_groups``; slice g
    is evaluated under seed g.  jit with batch sharded over 'data' makes each
    slice's evaluation group-local (see module docstring).
    """
    opt = as_zo_optimizer(optimizer)
    eps, dist = opt.estimator.eps, opt.estimator.dist
    weight_decay = opt.weight_decay
    backend = opt.backend

    def step(params: PyTree, state: SeedParallelState, batch):
        skey0 = step_key(state.base_key, state.step)
        lr = opt.lr_at(state.step)

        def slice_g(tree, g):
            def cut(x):
                per = x.shape[0] // n_groups
                return jax.lax.dynamic_slice_in_dim(x, g * per, per, axis=0)
            return jax.tree_util.tree_map(cut, tree)

        gs, losses = [], []
        for g in range(n_groups):
            ref = StreamRef(jax.random.fold_in(skey0, g))
            bg = slice_g(batch, g)
            p_plus = backend.perturb(params, ref, eps, dist)
            l_plus = loss_fn(p_plus, bg)
            p_minus = backend.perturb(p_plus, ref, -2.0 * eps, dist)
            l_minus = loss_fn(p_minus, bg)
            # restore to center before the next group's perturbation
            params = backend.perturb(p_minus, ref, eps, dist)
            gs.append((l_plus - l_minus) / (2.0 * eps))
            losses.append(0.5 * (l_plus + l_minus))

        p = apply_seed_parallel_update(params, state.base_key, state.step,
                                       jnp.stack(gs), lr, n_groups,
                                       weight_decay, dist, backend=backend)
        new_state = SeedParallelState(state.step + 1, state.base_key)
        return p, new_state, {"loss": jnp.mean(jnp.stack(losses)),
                              "projected_grads": jnp.stack(gs), "lr": lr}

    return step


def seed_parallel_grads(loss_fn: Callable, params: PyTree, batches: PyTree,
                        base_key, step_idx, eps: float, n_groups: int,
                        dist: str = "gaussian", backend=None) -> jnp.ndarray:
    """Pure estimator form (used by tests): group g evaluates seed g on
    ``batches[g]``; returns the n projected-grad scalars."""
    be = get_backend(backend)
    skey0 = step_key(base_key, step_idx)
    gs = []
    for g in range(n_groups):
        ref = StreamRef(jax.random.fold_in(skey0, g))
        bg = jax.tree_util.tree_map(lambda x: x[g], batches)
        p_plus = be.perturb(params, ref, eps, dist)
        l_plus = loss_fn(p_plus, bg)
        p_minus = be.perturb(p_plus, ref, -2.0 * eps, dist)
        l_minus = loss_fn(p_minus, bg)
        gs.append((l_plus - l_minus) / (2.0 * eps))
    return jnp.stack(gs)


def apply_seed_parallel_update(params: PyTree, base_key, step_idx,
                               grads: jnp.ndarray, lr, n_groups: int,
                               weight_decay: float = 0.0,
                               dist: str = "gaussian",
                               backend=None) -> PyTree:
    """θ ← θ − (η/n) Σ_g g_g · z_g  (identical on every replica), via the
    backend's rank-1 primitive; decay applied once, on the first group."""
    be = get_backend(backend)
    skey0 = step_key(base_key, step_idx)
    lr_g = lr / n_groups
    p = params
    for g in range(n_groups):
        ref = StreamRef(jax.random.fold_in(skey0, g))
        wd = weight_decay if g == 0 else 0.0
        p = be.apply_rank1(p, ref, lr_g * grads[g], lr_g * wd, dist)
    return p
