"""MeZO-specific collective patterns — now thin policy over ``repro.exec``.

The punchline (DESIGN.md §2): under data parallelism MeZO's *entire*
inter-replica traffic per step is the scalar loss all-reduce — two f32 per
seed — because every shard regenerates the same z locally (threefry is
counter-based and partitionable, so ``jax.random.normal(key, global_shape)``
yields identical values under any sharding).

Beyond-paper feature — **seed-parallel n-SPSA**: Algorithm 2 evaluates n
seeds *sequentially* on the full batch (2n forward passes).  Here the global
batch is split into n slices; seed g is evaluated only on slice g.  Under
pjit with batch sharded over 'data', slice g's ℓ± reductions are data-local
to the devices holding it, so the step costs the same wall-clock and FLOPs
as plain 1-SPSA on the full batch while averaging n independent rank-1
directions — n× direction-variance reduction for free.  The cross-device
traffic is the 2n loss scalars.

Since the execution engine landed, the step itself lives in
``repro.exec.StepProgram`` (plan ``seed_parallel(n)``), which lowers ANY
``repro.zo`` optimizer — spsa, n_spsa, fzoo's batched seeds, any transform
chain, any ``PerturbBackend`` — onto the sliced-batch schedule.  What remains
here is the slicing policy re-exported for its historical callers: every
perturbation runs through the optimizer's estimator and every parameter
write through ``PerturbBackend.apply_rank1`` (the engine's shared write
path, identical to ledger replay).
"""
from __future__ import annotations

import warnings
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.exec import StepProgram, apply_group_updates, group_key
from repro.exec import plan as plan_mod
from repro.perturb import get_backend, step_key
from repro.tree_utils import PyTree
from repro.zo.base import ZOState
from repro.zo.presets import as_zo_optimizer


def psum_scalar(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Scalar all-reduce — MeZO's only gradient communication."""
    return jax.lax.psum(x, axis_name)


class SeedParallelState(NamedTuple):
    """Deprecated pre-engine state (step, base_key).  The engine runs on the
    uniform ``ZOState``; this shape is still accepted by the step function
    built below (converted on the fly) so legacy callers keep working."""
    step: jnp.ndarray
    base_key: jax.Array


def seed_parallel_init(seed: int = 0) -> SeedParallelState:
    return SeedParallelState(jnp.int32(0), jax.random.PRNGKey(seed))


def seed_parallel_step_fn(loss_fn: Callable, optimizer, n_groups: int,
                          mesh=None):
    """Build ``step(params, state, batch) -> (params, state, metrics)`` on
    the engine's seed-parallel plan.

    ``optimizer`` is a ``repro.zo`` protocol conformer (or legacy config).
    ``batch`` leaves must have leading dim divisible by ``n_groups``; slice g
    is evaluated under seed group g.  jit with batch sharded over 'data'
    makes each slice's evaluation group-local (see module docstring).

    Accepts both the engine's ``ZOState`` and the deprecated
    ``SeedParallelState`` (scalar-chain optimizers only).
    """
    opt = as_zo_optimizer(optimizer)
    prog = StepProgram(opt, plan_mod.seed_parallel(n_groups, mesh=mesh))
    engine_step = prog.step_fn(loss_fn)

    def step(params: PyTree, state, batch):
        if isinstance(state, SeedParallelState):
            est_state = opt.estimator.init(None, state.base_key)
            tf_state = opt.transform.init(None)
            if jax.tree_util.tree_leaves(est_state) or \
                    jax.tree_util.tree_leaves(tf_state):
                # the legacy (step, base_key) state has nowhere to carry
                # estimator/transform arrays across steps — re-initializing
                # them every call would silently bias stateful estimators
                # (one_point's residual, rescaled's D-tree)
                raise ValueError(
                    "the legacy SeedParallelState supports stateless "
                    "estimator/transform chains only; drive this optimizer "
                    "through repro.exec.StepProgram with its ZOState "
                    "(prog.init(params, seed=...))")
            zstate = ZOState(step=state.step, base_key=state.base_key,
                             est_state=est_state, tf_state=tf_state,
                             last_projected_grad=jnp.float32(0.0))
            p, zs, metrics = engine_step(params, zstate, batch)
            return p, SeedParallelState(zs.step, zs.base_key), metrics
        return engine_step(params, state, batch)

    return step


def seed_parallel_grads(loss_fn: Callable, params: PyTree, batches: PyTree,
                        base_key, step_idx, eps: float, n_groups: int,
                        dist: str = "gaussian", backend=None) -> jnp.ndarray:
    """Pure estimator form (used by tests): group g evaluates seed g on
    ``batches[g]``; returns the n projected-grad scalars.  Each group runs
    the standard SPSA estimator chain at the step's center parameters.

    BEHAVIOR CHANGE (engine canonicalization): at ``n_groups == 1`` the
    stream key is the unfolded step key (== the local plan), where the
    pre-engine helper folded group 0 — pre-engine single-group results are
    not reproducible through this helper (warned loudly below)."""
    from repro.zo import estimators
    if n_groups == 1:
        warnings.warn(
            "seed_parallel_grads(n_groups=1) now uses the engine's unfolded "
            "step key (aligned with the local plan); the pre-engine helper "
            "folded group 0, so results differ from pre-engine runs",
            UserWarning, stacklevel=2)
    est = estimators.spsa(eps=eps, dist=dist, backend=get_backend(backend))
    skey0 = step_key(base_key, step_idx)
    gs = []
    for g in range(n_groups):
        bg = jax.tree_util.tree_map(lambda x: x[g], batches)
        e = est.estimate(loss_fn, params, bg,
                         group_key(skey0, g, n_groups), ())
        gs.append(e.projected_grad)
    return jnp.stack(gs)


def apply_seed_parallel_update(params: PyTree, base_key, step_idx,
                               grads: jnp.ndarray, lr, n_groups: int,
                               weight_decay: float = 0.0,
                               dist: str = "gaussian",
                               backend=None) -> PyTree:
    """θ ← θ − (η/n) Σ_g g_g · z_g  (identical on every replica), via the
    engine's shared write path (``PerturbBackend.apply_rank1`` underneath);
    decay applied once, on the first group — the same floats a ledger replay
    of this step performs.

    BEHAVIOR CHANGES (engine canonicalization, warned loudly): the decay
    term is the transform chain's η·λ once per step (pre-engine: (η/n)·λ),
    and at ``n_groups == 1`` the stream key is the unfolded step key
    (pre-engine: folded group 0)."""
    be = get_backend(backend)
    if n_groups == 1:
        warnings.warn(
            "apply_seed_parallel_update(n_groups=1) now uses the engine's "
            "unfolded step key (aligned with the local plan); pre-engine "
            "single-group updates folded group 0 and are not reproducible "
            "through this helper", UserWarning, stacklevel=2)
    if weight_decay:
        warnings.warn(
            "apply_seed_parallel_update now applies the decoupled decay as "
            "η·λ once per step (the transform chain's add_weight_decay "
            "rule); the pre-engine helper applied (η/n)·λ — reconstructions "
            "of pre-engine decayed runs will differ", UserWarning,
            stacklevel=2)
    skey0 = step_key(base_key, step_idx)
    coeffs = [(lr / n_groups) * grads[g] for g in range(n_groups)]
    return apply_group_updates(params, skey0, coeffs, lr * weight_decay,
                               n_groups, 1, dist, be)
