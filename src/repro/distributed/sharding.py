"""Logical-axis sharding rules with divisibility fallbacks (MaxText-style).

Parameters are matched by path pattern to a *candidate dim order*; the first
candidate whose size divides the tensor-parallel axis is sharded, otherwise
the leaf is replicated.  This single rule engine shards all 14 registered
architectures on the fixed production meshes with no bespoke code — uneven
head counts (25, 14, 28…) fall back from per-head to flattened-feature or
input-dim sharding automatically.

Conventions:
  * stacked block leaves have a leading 'layers' axis (never sharded);
  * 'model' (or 'expert'+'model' on the EP mesh) is tensor parallel;
  * 'data' (+ 'pod') shard the batch;
  * the KV-cache sequence axis shards over 'model' in decode
    (flash-decoding style partial-softmax; XLA inserts the combine).
"""
from __future__ import annotations

import re
from typing import Optional, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.tree_utils import PyTree


# --------------------------------------------------------------------------- #
# Mesh-axis helpers
# --------------------------------------------------------------------------- #
def batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def tp_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("expert", "model") if a in mesh.axis_names) or ("model",)


def axis_size(mesh: Mesh, axes) -> int:
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


# --------------------------------------------------------------------------- #
# Parameter rules
# --------------------------------------------------------------------------- #
# (path regex, candidate shard dims counted from the END of the shape,
#  mesh axis group).  First divisible candidate wins; none -> replicated.
# Dims are negative indices so rules are agnostic to the stacked layer axis.
_PARAM_RULES: list[tuple[str, Sequence[int], str]] = [
    # embeddings: shard d_model (gathers stay shard-local); head: shard vocab
    (r"\['embed'\]$",               (-1,),      "tp"),
    (r"\['head'\]$",                (-1,),      "tp"),
    # attention: column-parallel qkv, row-parallel o (Megatron)
    (r"\['attn'\]\['w[qkv]'\]$",    (-1, -2),   "tp"),
    (r"\['attn'\]\['wo'\]$",        (-2,),      "tp"),
    (r"\['xattn'\]\['w[qkv]'\]$",   (-1, -2),   "tp"),
    (r"\['xattn'\]\['wo'\]$",       (-2,),      "tp"),
    (r"\['b[qkv]'\]$",              (-1,),      "tp"),
    # dense FFN: column w1/w3, row w2
    (r"\['mlp'\]\['w[13]'\]$",      (-1,),      "tp"),
    (r"\['mlp'\]\['w2'\]$",         (-2,),      "tp"),
    # MoE: experts on 'expert' axis when present/divisible, else ff dim on tp
    (r"\['moe'\]\['router'\]$",     (),         "tp"),
    (r"\['moe'\]\['w[13]'\]$",      (-3, -1),   "moe"),
    (r"\['moe'\]\['w2'\]$",         (-3, -2),   "moe"),
    # grouped expert layout (cfg.expert_groups > 1): each "eg{j}" sub-leaf
    # holds E/G experts on the same (-3) experts dim — same sharding rules
    (r"\['moe'\]\['eg\d+'\]\['w[13]'\]$", (-3, -1), "moe"),
    (r"\['moe'\]\['eg\d+'\]\['w2'\]$",    (-3, -2), "moe"),
    # Hymba SSM projections
    (r"\['ssm'\]\['in_proj'\]$",    (-1,),      "tp"),
    (r"\['ssm'\]\['out_proj'\]$",   (-2,),      "tp"),
    (r"\['ssm'\]\['[bc]_proj'\]$",  (-1,),      "tp"),
    # RWKV time/channel mix
    (r"\['tm'\]\['w[rkvg]'\]$",     (-1,),      "tp"),
    (r"\['tm'\]\['wo'\]$",          (-2,),      "tp"),
    (r"\['tm'\]\['w_lora_a'\]$",    (),         "tp"),
    (r"\['tm'\]\['w_lora_b'\]$",    (-1,),      "tp"),
    (r"\['cm'\]\['wk'\]$",          (-1,),      "tp"),
    (r"\['cm'\]\['wv'\]$",          (-2,),      "tp"),
    (r"\['cm'\]\['wr'\]$",          (-1,),      "tp"),
    # LoRA PEFT trees
    (r"\['w[qkvo]'\]\['a'\]$",      (),         "tp"),
    (r"\['w[qkvo]'\]\['b'\]$",      (-1,),      "tp"),
]


def _spec_with(mesh: Mesh, shape: tuple, dim: int, axes) -> P:
    """PartitionSpec sharding ``dim`` (negative index) over ``axes``."""
    nd = len(shape)
    entries: list = [None] * nd
    entries[dim % nd] = axes if not (isinstance(axes, tuple) and len(axes) == 1) else axes[0]
    return P(*entries)


def infer_param_spec(path: str, shape: tuple, mesh: Mesh) -> P:
    """Rule-engine lookup with divisibility fallback."""
    if len(shape) == 0:
        return P()
    tp = tp_axes(mesh)
    has_expert = "expert" in mesh.axis_names
    for pattern, cands, group in _PARAM_RULES:
        if re.search(pattern, path):
            if group == "moe":
                # candidate -3 is the experts dim -> 'expert' axis if present;
                # candidate -1/-2 is the ff dim -> 'model'.
                for dim in cands:
                    is_expert_dim = (dim == -3)
                    axes = ("expert",) if (is_expert_dim and has_expert) else ("model",)
                    if is_expert_dim and not has_expert:
                        continue
                    if len(shape) >= -dim and shape[dim] % axis_size(mesh, axes) == 0:
                        return _spec_with(mesh, shape, dim, axes)
                return P()
            axes = tp if group == "tp" else (group,)
            size = axis_size(mesh, axes)
            for dim in cands:
                if len(shape) >= -dim and shape[dim] % size == 0:
                    return _spec_with(mesh, shape, dim,
                                      axes if len(axes) > 1 else axes[0])
            # fall back to 'model' only (smaller factor) on the EP mesh
            if len(axes) > 1:
                for dim in cands:
                    if len(shape) >= -dim and shape[dim] % mesh.shape["model"] == 0:
                        return _spec_with(mesh, shape, dim, "model")
            return P()
    return P()   # norms, scalars, anything unmatched: replicated


def param_specs(params: PyTree, mesh: Mesh) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [infer_param_spec(jax.tree_util.keystr(kp), tuple(leaf.shape), mesh)
             for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params: PyTree, mesh: Mesh) -> PyTree:
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s),
                                  param_specs(params, mesh))


# --------------------------------------------------------------------------- #
# Batch / cache / state rules
# --------------------------------------------------------------------------- #
def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


def infer_batch_spec(name: str, shape: tuple, mesh: Mesh) -> P:
    """Input specs for step-function batches (tokens/labels/caches/states)."""
    ba = batch_axes(mesh)
    bsz = axis_size(mesh, ba)
    model = mesh.shape["model"]
    b_ax: object = ba if len(ba) > 1 else (ba[0] if ba else None)

    def batch_ok(dim_size):
        return _div(dim_size, bsz)

    if len(shape) == 0:
        return P()
    if name in ("tokens", "labels", "loss_mask", "token", "gold_ids"):
        return P(b_ax if batch_ok(shape[0]) else None, *([None] * (len(shape) - 1)))
    if name in ("embeds", "frames", "embed"):
        return P(b_ax if batch_ok(shape[0]) else None, None, None)
    if name in ("cache_k", "cache_v"):
        # (L, B, cap, KV, hd): batch -> data, cache seq -> model (flash-decode)
        L, B, cap = shape[0], shape[1], shape[2]
        return P(None, b_ax if batch_ok(B) else None,
                 "model" if _div(cap, model) else None, None, None)
    if name == "cache_pos_arr":
        return P(None, "model" if _div(shape[1], model) else None)
    if name == "cross_k" or name == "cross_v":
        return P(None, b_ax if batch_ok(shape[1]) else None,
                 "model" if _div(shape[2], model) else None, None, None)
    if name == "ssm_state":
        # (L, B, SH, hd, N): batch -> data; head-dim -> model if divisible
        return P(None, b_ax if batch_ok(shape[1]) else None,
                 "model" if _div(shape[2], model) else None,
                 "model" if not _div(shape[2], model) and _div(shape[3], model) else None,
                 None)
    if name == "rwkv_wkv":
        # (L, B, H, hd, hd): shard key head_dim over model if heads don't divide
        return P(None, b_ax if batch_ok(shape[1]) else None,
                 "model" if _div(shape[2], model) else None,
                 "model" if not _div(shape[2], model) and _div(shape[3], model) else None,
                 None)
    if name == "rwkv_shift":
        return P(None, b_ax if batch_ok(shape[1]) else None,
                 "model" if _div(shape[2], model) else None)
    return P()


def batch_shardings(batch_specs_tree: PyTree, mesh: Mesh, names: PyTree) -> PyTree:
    return jax.tree_util.tree_map(
        lambda n, s: NamedSharding(mesh, infer_batch_spec(n, tuple(s.shape), mesh)),
        names, batch_specs_tree)


# --------------------------------------------------------------------------- #
# Activation resolver (installed around traces via models.common.shard_resolver)
# --------------------------------------------------------------------------- #
def make_activation_resolver(mesh: Mesh, cfg=None):
    ba = batch_axes(mesh)
    b_ax: object = ba if len(ba) > 1 else (ba[0] if ba else None)
    bsz = axis_size(mesh, ba)
    model = mesh.shape["model"]
    has_expert = "expert" in mesh.axis_names
    heads_fallback = getattr(cfg, "shard_heads_fallback", "compiler")
    seq_parallel = getattr(cfg, "sequence_parallel", False)

    def resolve(logical: str, shape: tuple) -> Optional[P]:
        def b0():
            return b_ax if _div(shape[0], bsz) else None
        if logical == "act_btd" and len(shape) == 3:
            if seq_parallel and _div(shape[1], model):
                return P(b0(), "model", None)
            return P(b0(), None, None)
        if logical == "act_ff" and len(shape) >= 2:
            return P(b0(), *([None] * (len(shape) - 2)),
                     "model" if _div(shape[-1], model) else None)
        if logical == "act_vocab" and len(shape) == 3:
            return P(b0(), None, "model" if _div(shape[-1], model) else None)
        if logical in ("act_heads", "act_kv_heads") and len(shape) == 4:
            # (B,S,H,hd): prefer head sharding; fallback per config — GSPMD's
            # own choice can shard the CONTRACTION dim (hd) and all-reduce the
            # S×S scores (measured 124 GB/layer on qwen2-7b prefill_32k).
            if getattr(cfg, "attention_cp", False) and logical == "act_heads" \
                    and _div(shape[1], model) and shape[1] > 1:
                # context parallelism: q's sequence over 'model'; per-chip
                # score traffic drops by TP (K/V stay batch-local)
                return P(b0(), "model", None, None)
            if _div(shape[2], model):
                return P(b0(), None, "model", None)
            if heads_fallback == "batch":
                return P(b0(), None, None, None)
            if getattr(cfg, "attention_cp", False) and logical == "act_kv_heads":
                return P(b0(), None, None, None)
            return None
        if logical == "act_ssd" and len(shape) == 5:
            # (B, nc, C, SH, ·): chunk axis == sequence; shard over 'model'
            # under context parallelism (the SSD analogue of CP attention)
            if getattr(cfg, "attention_cp", False) and _div(shape[1], model):
                return P(b0(), "model", None, None, None)
            return P(b0(), None, None, None, None)
        if logical == "act_experts" and len(shape) == 4:
            # (E, G, C, d): experts -> expert/model axis; groups -> batch axes
            g_ax = b_ax if _div(shape[1], bsz) else None
            if has_expert and _div(shape[0], mesh.shape["expert"]):
                return P("expert", g_ax, None,
                         "model" if _div(shape[3], model) else None)
            if _div(shape[0], model):
                return P("model", g_ax, None, None)
            return P(None, g_ax, None, None)
        return None

    return resolve
