from repro.distributed.sharding import (batch_axes, infer_batch_spec,
                                        infer_param_spec,
                                        make_activation_resolver, param_specs,
                                        param_shardings, tp_axes)

__all__ = ["infer_param_spec", "infer_batch_spec", "param_specs",
           "param_shardings", "make_activation_resolver", "batch_axes",
           "tp_axes"]
