"""Bounded-staleness asynchronous MeZO — straggler mitigation (beyond-paper).

Because a ZO update is the rank-1 tensor −η·g·z(seed) with a SCALAR
coefficient, updates commute cheaply and can be applied late: a straggling
worker's (step, seed-id, g) contribution can reach peers a few steps after
the fact, and every worker folds it in whenever it arrives.  Workers never
exchange tensors — the wire format is a few bytes per contribution.

Since the execution engine landed, the worker is pure *policy* (outbox,
staleness window, dedup) over ``repro.exec.StepProgram`` on the
``async_worker`` plan: local evaluation is the optimizer's estimator plus
the scalar transform chain (``contribution_eval_fn``), and remote
application is the engine's shared write path (``apply_contribution_fn`` →
``PerturbBackend.apply_rank1``), so a late contribution regenerates the
identical z (same backend, same seed schedule) and performs floats identical
to a seed-parallel step of the same round — and to a ledger replay of it.

The seed schedule is the engine's: worker w's stream at step t is
``fold_in(step_key(base, t), w)`` (unfolded at n_workers == 1), i.e. the SAME
schedule seed-parallel groups and local n-SPSA seeds use — an async
staleness-0 round, a seed-parallel step, and a ledger replay are the same
multiset of rank-1 updates.

Model (synchronous-equivalent at staleness 0):
  * each worker w at step t evaluates seed group (t, w) on its batch shard
    and broadcasts g_{t,w};
  * a worker applies contribution (t', w') when it has it, up to
    ``max_staleness`` steps late;
  * convergence: stale rank-1 SGD with bounded delay — the classic
    asynchronous-SGD regime, but with exact replay (z regenerated from the
    seed), so workers remain bitwise-consistent once the same multiset of
    contributions is applied.  tests/test_async_zo.py and tests/test_exec.py
    check (a) staleness-0 == synchronous seed-parallel, (b) convergence on a
    quadratic under delay, (c) order-invariance of the applied updates
    (within fp tolerance), and (d) ledger round-trip through the engine.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Sequence, Union

import jax
import jax.numpy as jnp

from repro.exec import StepProgram, group_stream_key
from repro.exec import plan as plan_mod
from repro.perturb import step_key
from repro.tree_utils import PyTree
from repro.zo.presets import as_zo_optimizer


@dataclasses.dataclass
class Contribution:
    step: int
    worker: int
    # one scalar per stream: a float (B=1) or a length-B tuple (batched-seed
    # estimators — fzoo workers put their per-stream vector on the wire)
    projected_grad: Union[float, tuple]
    lr: float


def worker_seed_key(base_key: jax.Array, step: int, worker: int,
                    n_workers: int) -> jax.Array:
    """Deprecated alias for the engine's seed schedule.  The legacy
    ``1000 + worker`` offset is gone — the engine's one fold schedule is
    shared with seed-parallel and local n-SPSA, which is what makes the
    plans' artifacts interchangeable.  ``n_workers`` is REQUIRED because the
    schedule depends on it (one worker uses the unfolded step key); legacy
    3-argument callers fail loudly here instead of silently deriving a
    stream that matches neither schedule."""
    return group_stream_key(base_key, step, worker, n_workers)


class AsyncZOWorker:
    """One logical worker of the gossip ring (driven in-process by tests and
    by the simulated-cluster example; a deployment pushes Contribution
    records over its own transport).

    ``optimizer`` is a ``repro.zo`` protocol conformer (``zo.mezo(...)``,
    ``zo.fzoo(...)``) or, for backward compatibility, a legacy
    ``MeZOConfig``."""

    def __init__(self, worker_id: int, n_workers: int, params: PyTree,
                 loss_fn: Callable, optimizer, base_seed: int = 0,
                 max_staleness: int = 4):
        self.w = worker_id
        self.n = n_workers
        self.params = params
        self.loss_fn = loss_fn
        self.opt = as_zo_optimizer(optimizer)
        self.prog = StepProgram(
            self.opt, plan_mod.async_worker(n_workers, max_staleness))
        self.base_key = jax.random.PRNGKey(base_seed)
        self.max_staleness = max_staleness
        self.outbox: deque[Contribution] = deque()
        self.applied: set = set()
        self.step = 0
        self._est_state = self.opt.estimator.init(params, self.base_key)
        if jax.tree_util.tree_leaves(self._est_state) and \
                self.opt.estimator.name != "rescaled_spsa":
            # A carried estimator state (e.g. one_point's residual) would be
            # frozen into the jitted closure below and never advance; the
            # async path supports stateless-per-step estimators only.  (The
            # rescaled D-tree is constant after init, so it is fine.)
            raise ValueError(
                f"AsyncZOWorker needs a stateless estimator; "
                f"{self.opt.estimator.name!r} carries per-step state")
        # the selection's block-schedule phase is STATIC (it decides which
        # leaves are touched); workers derive it from the step index in
        # Python — phase(t) is the same pure function every plan uses, so an
        # async round's contributions land on the same leaf blocks a
        # seed-parallel step (or a ledger replay) of that round would touch
        self._sel = self.prog.selection
        self._jit_eval = jax.jit(self.prog.contribution_eval_fn(
            loss_fn, worker_id, est_state=self._est_state),
            static_argnames=("phase",))
        # group feeds only the fold_in inside group_key, which takes traced
        # ints — keeping it dynamic means ONE compiled apply kernel serves
        # every worker id instead of one retrace per peer
        self._jit_apply = jax.jit(self.prog.apply_contribution_fn(),
                                  static_argnames=("phase",))

    def _phase(self, step: int) -> int:
        return 0 if self._sel is None else int(self._sel.phase_at(int(step)))

    # ---- local estimation (the optimizer's own estimator chain) ---------- #
    def produce(self, batch) -> Contribution:
        """Evaluate this worker's seed group for its current step and run the
        scalar transform chain — what goes on the wire is the post-transform
        g, the same scalar a seed-parallel step of this round records."""
        g, lr, _ = self._jit_eval(self.params, self.base_key,
                                  jnp.int32(self.step), batch,
                                  phase=self._phase(self.step))
        g_wire = (tuple(float(x) for x in g) if jnp.ndim(g) > 0
                  else float(g))
        contrib = Contribution(self.step, self.w, g_wire, float(lr))
        self.outbox.append(contrib)
        self.step += 1
        return contrib

    def consume(self, contrib: Contribution) -> bool:
        """Apply a (possibly remote, possibly stale) contribution through the
        engine's shared write path.

        Decay caveat (weight_decay > 0): the step's decoupled η·λ decay
        rides worker 0's contribution (the engine's group-0 rule, matching
        seed-parallel and ledger replay).  If worker 0's contribution for a
        step exceeds the staleness window and is dropped, that step's decay
        is dropped with it — peers that did apply it diverge by the
        (1 − η·λ) factor, not just the missing rank-1 term.  Deployments
        with nonzero decay should size ``max_staleness`` so worker 0's
        contributions are never dropped (or route decay through a local
        step schedule)."""
        key = (contrib.step, contrib.worker)
        if key in self.applied:
            return False
        if contrib.step < self.step - self.max_staleness:
            return False          # too stale: dropped (bounded staleness)
        skey0 = step_key(self.base_key, jnp.int32(contrib.step))
        g = jnp.float32(contrib.projected_grad)
        self.params = self._jit_apply(
            self.params, skey0, jnp.int32(contrib.worker), g,
            jnp.float32(contrib.lr),
            jnp.float32(1.0 if contrib.worker == 0 else 0.0),
            phase=self._phase(contrib.step))
        self.applied.add(key)
        return True


def run_sync_equivalent(workers: list[AsyncZOWorker], batches_for) -> None:
    """Drive one fully-synchronous round: every worker produces, then every
    worker consumes every contribution (staleness 0)."""
    contribs = [w.produce(batches_for(w.w, w.step)) for w in workers]
    for w in workers:
        for cb in contribs:
            w.consume(cb)


def contributions_to_ledger(ledger, contribs: Sequence[Contribution],
                            n_workers: int, selection: str = "full",
                            sel_phase: int = 0) -> tuple[int, int]:
    """Fold a collection of contributions into a trajectory ledger: one
    record per fully-contributed step, streams in worker order — exactly the
    MZOL record a seed-parallel step of the same round appends, so the
    assembled ledger replays under the engine's ``replay()`` plan.

    An empty default-constructed ledger is stamped with the async plan's
    coordinates (``n_groups`` = worker count, ``exec_plan``, ``batch_seeds``
    from the wire vectors) — without the stamp the first append would
    mis-infer the worker count as FZOO's per-group B and replay would
    refuse.  ``n_workers`` is required: inferring it from a step's delivered
    contributions would record an incomplete round of a larger cluster as a
    complete smaller one (wrong 1/n rescale on replay).

    Returns ``(recorded, skipped)`` — steps appended vs. steps dropped for
    missing contributions; a nonzero ``skipped`` means the assembled ledger
    reconstructs parameters BEHIND what live workers applied, so callers
    must check it before treating the ledger as the run's full record."""
    by_step: dict = {}
    for c in contribs:
        by_step.setdefault(c.step, {})[c.worker] = c
    n = int(n_workers)
    recorded = skipped = 0
    for step in sorted(by_step):
        row = by_step[step]
        if sorted(row) != list(range(n)):
            skipped += 1                  # incomplete round: not recordable
            continue
        if len(ledger) == 0 and ledger.n_groups == 1 and n > 1:
            g0 = row[0].projected_grad
            ledger.n_groups = n
            ledger.exec_plan = "async_worker"
            ledger.batch_seeds = len(g0) if isinstance(g0, tuple) else 1
        if len(ledger) == 0 and ledger.selection == "full":
            # the selection spec is not on the wire (contributions are pure
            # scalars) — callers of selected runs pass it so the assembled
            # ledger records the right parameter support (stamped even at
            # n_workers == 1: replaying a selected run's scalars as 'full'
            # would silently apply them to the whole tree)
            ledger.selection = selection
            ledger.sel_phase = int(sel_phase)
        flat: list = []
        for w in range(n):
            g = row[w].projected_grad
            flat.extend(g if isinstance(g, tuple) else (g,))
        ledger.append(step, flat if len(flat) > 1 else flat[0], row[0].lr)
        recorded += 1
    return recorded, skipped
