"""Bounded-staleness asynchronous MeZO — straggler mitigation (beyond-paper).

Because a MeZO update is the rank-1 tensor −η·g·z(seed) with a SCALAR
coefficient, updates commute cheaply and can be applied late: a straggling
worker's (step, seed-id, g) contribution can reach peers a few steps after
the fact, and every worker folds it in whenever it arrives.  Workers never
exchange tensors — the wire format is 16 bytes per contribution.

Model (synchronous-equivalent at staleness 0):
  * each worker w at step t evaluates seed (t, w) on its batch shard and
    broadcasts g_{t,w};
  * a worker applies contribution (t', w') when it has it, up to
    ``max_staleness`` steps late;
  * convergence: stale rank-1 SGD with bounded delay — the classic
    asynchronous-SGD regime, but with exact replay (z regenerated from the
    seed), so workers remain bitwise-consistent once the same multiset of
    contributions is applied.  tests/test_async_zo.py checks (a) staleness-0
    == synchronous MeZO, (b) convergence on a quadratic under delay, and
    (c) order-invariance of the applied updates (within fp tolerance).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core.mezo import MeZOConfig, apply_projected_update
from repro.core.perturb import perturb, step_key
from repro.tree_utils import PyTree


@dataclasses.dataclass
class Contribution:
    step: int
    worker: int
    projected_grad: float
    lr: float


def worker_seed_key(base_key: jax.Array, step: int, worker: int) -> jax.Array:
    return jax.random.fold_in(step_key(base_key, step), 1000 + worker)


class AsyncZOWorker:
    """One logical worker of the gossip ring (driven in-process by tests and
    by the simulated-cluster example; a deployment pushes Contribution
    records over its own transport)."""

    def __init__(self, worker_id: int, n_workers: int, params: PyTree,
                 loss_fn: Callable, config: MeZOConfig, base_seed: int = 0,
                 max_staleness: int = 4):
        self.w = worker_id
        self.n = n_workers
        self.params = params
        self.loss_fn = loss_fn
        self.c = config
        self.base_key = jax.random.PRNGKey(base_seed)
        self.max_staleness = max_staleness
        self.outbox: deque[Contribution] = deque()
        self.applied: set = set()
        self.step = 0
        self._jit_eval = jax.jit(self._eval)
        self._jit_apply = jax.jit(self._apply)

    # ---- local SPSA evaluation ------------------------------------------ #
    def _eval(self, params, skey, batch):
        p_plus = perturb(params, skey, self.c.eps, self.c.dist)
        l_plus = self.loss_fn(p_plus, batch)
        p_minus = perturb(p_plus, skey, -2.0 * self.c.eps, self.c.dist)
        l_minus = self.loss_fn(p_minus, batch)
        return (l_plus - l_minus) / (2.0 * self.c.eps), 0.5 * (l_plus + l_minus)

    def _apply(self, params, skey, g, lr):
        return apply_projected_update(params, skey, g, lr / self.n,
                                      self.c.weight_decay, self.c.dist)

    def produce(self, batch) -> Contribution:
        """Evaluate this worker's seed for its current step."""
        skey = worker_seed_key(self.base_key, self.step, self.w)
        lr = float(self.c.lr_at(jnp.int32(self.step)))
        g, _ = self._jit_eval(self.params, skey, batch)
        contrib = Contribution(self.step, self.w, float(g), lr)
        self.outbox.append(contrib)
        self.step += 1
        return contrib

    def consume(self, contrib: Contribution) -> bool:
        """Apply a (possibly remote, possibly stale) contribution."""
        key = (contrib.step, contrib.worker)
        if key in self.applied:
            return False
        if contrib.step < self.step - self.max_staleness:
            return False          # too stale: dropped (bounded staleness)
        skey = worker_seed_key(self.base_key, contrib.step, contrib.worker)
        self.params = self._jit_apply(self.params, skey,
                                      jnp.float32(contrib.projected_grad),
                                      jnp.float32(contrib.lr))
        self.applied.add(key)
        return True


def run_sync_equivalent(workers: list[AsyncZOWorker], batches_for) -> None:
    """Drive one fully-synchronous round: every worker produces, then every
    worker consumes every contribution (staleness 0)."""
    contribs = [w.produce(batches_for(w.w, w.step)) for w in workers]
    for w in workers:
        for cb in contribs:
            w.consume(cb)
