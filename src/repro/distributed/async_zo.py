"""Bounded-staleness asynchronous MeZO — straggler mitigation (beyond-paper).

Because a ZO update is the rank-1 tensor −η·g·z(seed) with a SCALAR
coefficient, updates commute cheaply and can be applied late: a straggling
worker's (step, seed-id, g) contribution can reach peers a few steps after
the fact, and every worker folds it in whenever it arrives.  Workers never
exchange tensors — the wire format is 16 bytes per contribution.

The worker consumes the ``repro.zo`` facade: its local evaluation is the
optimizer's *estimator* (the same sequential SPSA chain as a training step)
and remote application is the optimizer's perturbation backend's
``apply_rank1`` primitive — so a late contribution regenerates the identical
z (same backend, same ``StreamRef``) and performs arithmetic identical to a
live step.

Model (synchronous-equivalent at staleness 0):
  * each worker w at step t evaluates seed (t, w) on its batch shard and
    broadcasts g_{t,w};
  * a worker applies contribution (t', w') when it has it, up to
    ``max_staleness`` steps late;
  * convergence: stale rank-1 SGD with bounded delay — the classic
    asynchronous-SGD regime, but with exact replay (z regenerated from the
    seed), so workers remain bitwise-consistent once the same multiset of
    contributions is applied.  tests/test_async_zo.py checks (a) staleness-0
    == synchronous MeZO, (b) convergence on a quadratic under delay, and
    (c) order-invariance of the applied updates (within fp tolerance).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.perturb import step_key
from repro.perturb import StreamRef
from repro.tree_utils import PyTree
from repro.zo.presets import as_zo_optimizer


@dataclasses.dataclass
class Contribution:
    step: int
    worker: int
    projected_grad: float
    lr: float


def worker_seed_key(base_key: jax.Array, step: int, worker: int) -> jax.Array:
    return jax.random.fold_in(step_key(base_key, step), 1000 + worker)


class AsyncZOWorker:
    """One logical worker of the gossip ring (driven in-process by tests and
    by the simulated-cluster example; a deployment pushes Contribution
    records over its own transport).

    ``optimizer`` is a ``repro.zo`` protocol conformer (``zo.mezo(...)``) or,
    for backward compatibility, a legacy ``MeZOConfig``."""

    def __init__(self, worker_id: int, n_workers: int, params: PyTree,
                 loss_fn: Callable, optimizer, base_seed: int = 0,
                 max_staleness: int = 4):
        self.w = worker_id
        self.n = n_workers
        self.params = params
        self.loss_fn = loss_fn
        self.opt = as_zo_optimizer(optimizer)
        self.base_key = jax.random.PRNGKey(base_seed)
        self.max_staleness = max_staleness
        self.outbox: deque[Contribution] = deque()
        self.applied: set = set()
        self.step = 0
        self._est_state = self.opt.estimator.init(params, self.base_key)
        if jax.tree_util.tree_leaves(self._est_state) and \
                self.opt.estimator.name != "rescaled_spsa":
            # A carried estimator state (e.g. one_point's residual) would be
            # frozen into the jitted closure below and never advance; the
            # async path supports stateless-per-step estimators only.  (The
            # rescaled D-tree is constant after init, so it is fine.)
            raise ValueError(
                f"AsyncZOWorker needs a stateless estimator; "
                f"{self.opt.estimator.name!r} carries per-step state")
        if not self.opt.estimator.replayable:
            # _apply is the plain rank-1 primitive; a Definition-6 estimator
            # updates along D·z, so remote application would perform
            # different arithmetic than the producing worker's live step.
            raise ValueError(
                f"AsyncZOWorker contributions apply as plain rank-1 updates; "
                f"{self.opt.estimator.name!r} (Definition 6, D-scaled) is "
                "not wire-replayable")
        self._jit_eval = jax.jit(self._eval)
        self._jit_apply = jax.jit(self._apply)

    # ---- local estimation (the optimizer's own estimator chain) ---------- #
    def _eval(self, params, skey, batch):
        e = self.opt.estimator.estimate(self.loss_fn, params, batch, skey,
                                        self._est_state)
        return e.projected_grad, e.loss

    def _apply(self, params, skey, g, lr):
        # the optimizer's own backend: a late remote application performs the
        # identical z regeneration + arithmetic as the producer's live step
        lr_w = lr / self.n
        return self.opt.backend.apply_rank1(params, StreamRef(skey), lr_w * g,
                                            lr_w * self.opt.weight_decay,
                                            self.opt.estimator.dist)

    def produce(self, batch) -> Contribution:
        """Evaluate this worker's seed for its current step."""
        skey = worker_seed_key(self.base_key, self.step, self.w)
        lr = float(self.opt.lr_at(jnp.int32(self.step)))
        g, _ = self._jit_eval(self.params, skey, batch)
        contrib = Contribution(self.step, self.w, float(g), lr)
        self.outbox.append(contrib)
        self.step += 1
        return contrib

    def consume(self, contrib: Contribution) -> bool:
        """Apply a (possibly remote, possibly stale) contribution."""
        key = (contrib.step, contrib.worker)
        if key in self.applied:
            return False
        if contrib.step < self.step - self.max_staleness:
            return False          # too stale: dropped (bounded staleness)
        skey = worker_seed_key(self.base_key, contrib.step, contrib.worker)
        self.params = self._jit_apply(self.params, skey,
                                      jnp.float32(contrib.projected_grad),
                                      jnp.float32(contrib.lr))
        self.applied.add(key)
        return True


def run_sync_equivalent(workers: list[AsyncZOWorker], batches_for) -> None:
    """Drive one fully-synchronous round: every worker produces, then every
    worker consumes every contribution (staleness 0)."""
    contribs = [w.produce(batches_for(w.w, w.step)) for w in workers]
    for w in workers:
        for cb in contribs:
            w.consume(cb)
