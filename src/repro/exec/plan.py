"""Execution plans: *where/how* a ZO step runs, orthogonal to *what* it is.

A MeZO step is fully determined by a seed and a handful of scalars (paper
§2.1), so one step definition can be lowered onto very different execution
strategies.  An ``ExecPlan`` names the strategy; ``repro.exec.engine`` owns
the lowering:

``local()``
    Today's single-program step: the optimizer facade's jit+donate loop step,
    unchanged (the engine delegates to ``ZOOptimizer.step_fn``).

``seed_parallel(n_groups, mesh=None)``
    The global batch is split into ``n_groups`` slices; seed group g is
    evaluated only on slice g, all groups at the step's center parameters,
    and the n rank-1 directions are averaged (η/n each).  Under jit with the
    batch sharded over 'data' (pass ``mesh`` and use
    ``StepProgram.shardings``), slice g's loss reductions are data-local, so
    the only cross-device traffic is the 2n loss scalars.

``async_worker(n_workers, max_staleness=4)``
    The gossip-ring contribution protocol: worker w evaluates seed group w of
    each step on its own shard and broadcasts the scalar; contributions apply
    up to ``max_staleness`` steps late.  Staleness 0 is seed_parallel with
    per-worker jits.

``replay()``
    Ledger-driven: no forward passes, no data — reconstruct parameters from
    (seed, g, lr) records.  The engine reads the plan coordinates
    (``n_groups``, ``batch_seeds``, backend) from the ledger header.

One seed schedule serves every plan: stream g of step t is
``fold_in(step_key(base, t), g)`` when ``n_groups > 1`` and the unfolded
``step_key(base, t)`` when ``n_groups == 1`` — which is exactly the local
facade's per-seed fold, so ``seed_parallel(1)`` is bitwise-identical to
``local`` and a ledger written under any plan replays under ``replay()``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

PLAN_KINDS = ("local", "seed_parallel", "async_worker", "replay")


@dataclasses.dataclass(frozen=True)
class ExecPlan:
    """One execution strategy for a ZO step program.

    ``n_groups`` is the number of independent seed streams folded per step at
    the group level (batch slices for seed_parallel, workers for
    async_worker).  ``mesh`` optionally names the jax device mesh the
    seed-parallel plan shards over (metadata never records it — the stream
    schedule is mesh-invariant, that is the point).  ``max_staleness`` only
    applies to async_worker.
    """
    kind: str
    n_groups: int = 1
    mesh: Optional[object] = None
    max_staleness: int = 4

    def __post_init__(self):
        if self.kind not in PLAN_KINDS:
            raise ValueError(f"unknown exec plan kind {self.kind!r}; "
                             f"available: {PLAN_KINDS}")
        if self.n_groups < 1:
            raise ValueError(f"n_groups must be >= 1, got {self.n_groups}")


def local() -> ExecPlan:
    return ExecPlan("local")


def seed_parallel(n_groups: int, mesh=None) -> ExecPlan:
    return ExecPlan("seed_parallel", n_groups=int(n_groups), mesh=mesh)


def async_worker(n_workers: int, max_staleness: int = 4) -> ExecPlan:
    return ExecPlan("async_worker", n_groups=int(n_workers),
                    max_staleness=int(max_staleness))


def replay() -> ExecPlan:
    return ExecPlan("replay")


class PlanMismatchError(RuntimeError):
    """A seed-replay artifact (ledger / checkpoint) was produced under one
    execution plan's seed schedule and is being resumed/replayed under a
    different one.  ``n_groups`` determines the batch-slice → seed-stream
    assignment (the fold schedule), so continuing would silently assign
    different z streams to the recorded scalars — refuse instead."""


def check_replay_plan(recorded_n_groups: Optional[int],
                      active_n_groups: Optional[int], what: str,
                      recorded_kind: Optional[str] = None,
                      active_kind: Optional[str] = None) -> None:
    """Raise ``PlanMismatchError`` on an ``n_groups`` mismatch.

    The seed schedule is a pure function of ``n_groups`` (plan kinds share
    it), so kind differences at equal ``n_groups`` are allowed — an async
    staleness-0 ledger replays under ``replay()``, a seed-parallel checkpoint
    resumes under local n-SPSA with the same n.  ``None`` on either side (a
    pre-engine artifact, or a non-ZO optimizer) skips the check.
    """
    if recorded_n_groups is None or active_n_groups is None:
        return
    if int(recorded_n_groups) != int(active_n_groups):
        rk = f" ({recorded_kind})" if recorded_kind else ""
        ak = f" ({active_kind})" if active_kind else ""
        raise PlanMismatchError(
            f"{what} was recorded with n_groups={int(recorded_n_groups)}{rk} "
            f"but the active step program runs n_groups="
            f"{int(active_n_groups)}{ak}; the batch-slice → seed-stream "
            "assignment (the per-step fold schedule) differs, so resuming "
            "would silently pair the recorded scalars with different z "
            "streams.  Re-create the program with a matching plan (e.g. "
            f"exec.seed_parallel({int(recorded_n_groups)})).")
