"""``repro.exec`` — one mesh-aware ZO step engine for every execution mode.

MeZO's defining property (paper §2.1) is that a step is fully determined by a
seed and a handful of scalars.  One step definition can therefore serve local
training, sharded seed-parallelism, bounded-staleness async workers, and
ledger replay — this package owns that lowering:

* :mod:`repro.exec.plan` — the plans (``local``, ``seed_parallel``,
  ``async_worker``, ``replay``) and the ``PlanMismatchError`` refusal for
  artifacts recorded under a different seed schedule;
* :mod:`repro.exec.engine` — ``StepProgram``, which lowers any ``repro.zo``
  optimizer (estimator × transform chain) onto a plan, routing every
  parameter write through ``PerturbBackend``.

Quick start
-----------
>>> from repro import exec as zexec, zo
>>> prog = zexec.StepProgram(zo.fzoo(lr=1e-6, batch_seeds=8),
...                          zexec.seed_parallel(4))
>>> state = prog.init(params, seed=0)
>>> step = jax.jit(prog.step_fn(loss_fn), donate_argnums=(0,))
>>> params, state, metrics = step(params, state, batch)
>>> rec = prog.replay(params0, ledger)          # ledger-driven, no forwards

Guarantees (test-enforced in tests/test_exec.py):

* ``seed_parallel(1)`` is bitwise-equal to ``local`` (spsa and fzoo, xla);
* a ledger written under any plan replays under ``replay()`` — live
  seed-parallel application, async contribution application, and ledger
  replay share one write path (``engine.apply_group_update``);
* mismatched plan coordinates refuse (``PlanMismatchError``) instead of
  silently re-pairing recorded scalars with different z streams.
"""
from repro.exec.engine import (StepProgram, apply_group_update,
                               apply_group_updates, as_step_program,
                               group_key, group_stream_key, slice_group)
from repro.exec.plan import (ExecPlan, PlanMismatchError, async_worker,
                             check_replay_plan, local, replay, seed_parallel)

__all__ = [
    "ExecPlan", "PlanMismatchError", "StepProgram",
    "apply_group_update", "apply_group_updates", "as_step_program",
    "async_worker", "check_replay_plan", "group_key", "group_stream_key",
    "local", "replay", "seed_parallel", "slice_group",
]
