"""``StepProgram`` — one mesh-aware ZO step engine for every execution plan.

The repo used to hand-roll four step builders (the facade's local loop,
seed-parallel collectives, the async gossip worker, ledger replay), and the
scaling-critical ones bypassed the perturbation-backend layer entirely.  The
engine collapses them: a ``StepProgram`` lowers any ``repro.zo`` optimizer
(spsa, n_spsa, one_point, rescaled_spsa, fzoo, plus any transform chain) onto
an :mod:`repro.exec.plan` and routes **every** parameter write through
``PerturbBackend`` (``perturb`` / ``perturb_many`` / ``apply_rank1``) — never
through raw key chains.

The one seed schedule (``group_key``): stream g of step t is
``fold_in(step_key(base, t), g)`` when ``n_groups > 1``, the unfolded step
key when ``n_groups == 1``.  This is exactly the local facade's per-seed fold,
so:

* ``seed_parallel(1)`` is **bitwise-identical** to ``local`` (test-enforced
  for spsa and fzoo on the xla backend);
* a local n-SPSA run, a seed-parallel run, and an async staleness-0 round
  with the same ``n_groups`` record interchangeable ledger entries;
* ``apply_group_update`` is the ONE write path shared by the live
  seed-parallel step, async contribution application, and ledger replay —
  identical floats by construction, which is what makes a ledger written
  under any plan replay under ``replay()``.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.exec import plan as plan_mod
from repro.exec.plan import ExecPlan, check_replay_plan
from repro.perturb import StreamRef, check_replay_backend, step_key
from repro.select import check_replay_selection
from repro.tree_utils import PyTree
from repro.zo.base import TransformCtx, Updates, ZOState
from repro.zo.presets import as_zo_optimizer
from repro.zo.updates import apply_rank1_batch


# --------------------------------------------------------------------------- #
# The one seed schedule
# --------------------------------------------------------------------------- #
def group_key(skey0: jax.Array, group: int, n_groups: int) -> jax.Array:
    """Stream ``group`` of a step: fold when there are several streams, the
    unfolded step key when there is one (== the local facade's schedule)."""
    return jax.random.fold_in(skey0, group) if n_groups > 1 else skey0


def group_stream_key(base_key: jax.Array, step, group: int,
                     n_groups: int) -> jax.Array:
    """run key → step t → group g, composed from the canonical folds."""
    return group_key(step_key(base_key, step), group, n_groups)


# --------------------------------------------------------------------------- #
# The one write path (live seed-parallel step == async apply == replay)
# --------------------------------------------------------------------------- #
def apply_group_update(params: PyTree, skey0: jax.Array, group: int,
                       n_groups: int, coeff, decay_term, batch_seeds: int,
                       dist: str, backend, selection=None,
                       phase: int = 0) -> PyTree:
    """Apply one group's rank-1 update(s) through the backend primitive.

    ``coeff`` is the fully η-scaled coefficient — a scalar, or the (B,)
    per-stream vector of a batched-seed estimator (``apply_rank1_batch``
    divides by B and folds the per-stream keys itself).  ``selection`` /
    ``phase`` scope the update to the step's selected leaves — the phase is
    the STEP's (a pure function of t), shared by every group of the step."""
    gkey = group_key(skey0, group, n_groups)
    if batch_seeds == 1:
        ref = StreamRef(gkey)
        if selection is not None:
            ref = ref.with_selection(selection, phase)
        return backend.apply_rank1(params, ref, coeff, decay_term, dist)
    return apply_rank1_batch(params, gkey, coeff, decay_term, dist,
                             backend=backend, selection=selection,
                             phase=phase)


def apply_group_updates(params: PyTree, skey0: jax.Array, coeffs: Sequence,
                        decay_term, n_groups: int, batch_seeds: int,
                        dist: str, backend, selection=None,
                        phase: int = 0) -> PyTree:
    """All groups of one step, in group order; decoupled decay applied once,
    on group 0 (matching ``add_weight_decay``'s seed-0 rule).

    The whole step's n_groups × batch_seeds streams are flattened — in the
    exact order the per-group sequential fold applies them — into ONE
    ``backend.affine_many`` call: on xla that call IS the sequential
    ``apply_rank1`` fold (bitwise the pre-fusion path), on pallas it is the
    fused chain kernel, θ round-tripping HBM once for the entire step's
    update chain instead of once per stream."""
    refs, cs, ds = [], [], []
    for g in range(n_groups):
        gkey = group_key(skey0, g, n_groups)
        decay_g = decay_term if g == 0 else 0.0
        if batch_seeds == 1:
            ref = StreamRef(gkey)
            if selection is not None:
                ref = ref.with_selection(selection, phase)
            refs.append(ref)
            cs.append(coeffs[g])
            ds.append(decay_g)
        else:
            cvec = jnp.asarray(coeffs[g])
            for j in range(batch_seeds):
                ref = StreamRef(jax.random.fold_in(gkey, j))
                if selection is not None:
                    ref = ref.with_selection(selection, phase)
                refs.append(ref)
                cs.append(cvec[j] / batch_seeds)
                ds.append(decay_g if j == 0 else 0.0)
    return backend.affine_many(params, refs, cs, ds, dist)


def slice_group(batch, group: int, n_groups: int):
    """Slice ``group``'s shard of the global batch (leading-dim split);
    identity when there is a single group (bitwise parity with local).
    Leading dims must divide evenly — shapes are known at trace time, and
    silently dropping trailing rows would train on truncated data."""
    if n_groups == 1 or batch is None:
        return batch

    def cut(x):
        if jnp.ndim(x) == 0:
            return x                      # scalar leaves ride along unsliced
        if x.shape[0] % n_groups:
            raise ValueError(
                f"batch leading dim {x.shape[0]} does not divide into "
                f"n_groups={n_groups} slices; {x.shape[0] % n_groups} "
                "trailing row(s) would silently never be evaluated — pad or "
                "resize the batch")
        per = x.shape[0] // n_groups
        return jax.lax.dynamic_slice_in_dim(x, group * per, per, axis=0)

    return jax.tree_util.tree_map(cut, batch)


# --------------------------------------------------------------------------- #
# The engine
# --------------------------------------------------------------------------- #
class StepProgram:
    """Lower a ``repro.zo`` optimizer onto an execution plan.

    >>> prog = StepProgram(zo.fzoo(lr=1e-6, batch_seeds=8),
    ...                    exec.seed_parallel(4))
    >>> state = prog.init(params, seed=0)
    >>> step = jax.jit(prog.step_fn(loss_fn), donate_argnums=(0,))
    >>> params, state, metrics = step(params, state, batch)

    Non-ZO optimizers (the backprop baselines) are accepted for the ``local``
    plan only and pass straight through (``meta`` reports no plan
    coordinates, matching their absent seed schedule).
    """

    def __init__(self, optimizer, plan: Optional[ExecPlan] = None):
        self.plan = plan if plan is not None else plan_mod.local()
        if callable(getattr(optimizer, "replay_update", None)) or \
                getattr(optimizer, "estimator", None) is not None or \
                (hasattr(optimizer, "eps") and hasattr(optimizer, "dist")):
            self.opt = as_zo_optimizer(optimizer)
            self.is_zo = True
        else:
            self.opt = optimizer
            self.is_zo = False
            if self.plan.kind != "local":
                raise ValueError(
                    f"{type(optimizer).__name__} is not a seed-replayable ZO "
                    f"optimizer; only the local plan can run it "
                    f"(got {self.plan.kind!r})")
            return
        est = self.opt.estimator
        n = self.plan.n_groups
        if self.plan.kind in ("seed_parallel", "async_worker"):
            if est.n_seeds not in (1, n):
                raise ValueError(
                    f"estimator {est.name!r} declares n_seeds={est.n_seeds} "
                    f"but the {self.plan.kind} plan runs n_groups={n}; the "
                    "plan's groups ARE the seed streams — use n_seeds=1 or "
                    f"n_seeds={n}")
            if self.opt.info.get("applier") and \
                    not (self.plan.kind == "seed_parallel" and n == 1):
                raise ValueError(
                    "applier transforms (scale_by_zo_adam / trace) "
                    "materialize their update from the live tree and "
                    "g-history; group updates are wire-replayable rank-1 "
                    "applications — run appliers under the local plan")
            if not est.replayable and \
                    not (self.plan.kind == "seed_parallel" and n == 1):
                raise ValueError(
                    f"the {est.name!r} estimator updates along D·z "
                    "(Definition 6), which the plan's rank-1 group updates "
                    "cannot reproduce; use modify_expectation=True or the "
                    "local plan")
            if n > 1 and self.opt.info.get("lr_at") is None:
                # group plans (and their ledger/wire replay) reconstruct the
                # update coefficient as (η/n)·g from the recorded schedule;
                # a chain without scale_by_schedule records no η, so the
                # live coefficient (raw g) would silently diverge from the
                # reconstructed one
                raise ValueError(
                    f"the {self.plan.kind} plan needs a transform chain with "
                    "scale_by_schedule (its group updates and their replay "
                    "reconstruct coefficients as (η/n)·g from the recorded "
                    "learning rate); compose via zo.mezo/zo.fzoo or add "
                    "transforms.scale_by_schedule to the chain")

    # -- identity ----------------------------------------------------------- #
    @property
    def n_groups(self) -> Optional[int]:
        """Independent seed streams folded per step at the group level: the
        plan's groups, or — under the local plan — the estimator's
        interleaved n_seeds (same fold schedule, so the artifacts are
        interchangeable)."""
        if not self.is_zo:
            return None
        if self.plan.kind == "local":
            return int(self.opt.estimator.n_seeds)
        return int(self.plan.n_groups)

    @property
    def batch_seeds(self) -> Optional[int]:
        return self.opt.batch_seeds if self.is_zo else None

    @property
    def backend_name(self) -> Optional[str]:
        return self.opt.backend_name if self.is_zo else None

    @property
    def selection(self):
        """The composition's ``repro.select.Selection`` (None = full tree /
        non-ZO).  Every plan carries it: the schedule phase is a pure
        function of the step counter, so it is plan-invariant."""
        return self.opt.selection if self.is_zo else None

    @property
    def meta(self) -> dict:
        """The artifact stamp: everything a resume/replay needs to re-derive
        (or refuse to re-derive) the run's seed schedule."""
        return {"perturb_backend": self.backend_name,
                "batch_seeds": self.batch_seeds,
                "exec_plan": self.plan.kind if self.is_zo else None,
                "n_groups": self.n_groups,
                "selection": self.opt.selection_spec if self.is_zo else None,
                "sel_phase": self.opt.selection_phase if self.is_zo else None}

    # -- protocol delegation ------------------------------------------------ #
    def init(self, params: Optional[PyTree] = None, *, seed: int = 0):
        return self.opt.init(params, seed=seed)

    def restore(self, state, step: int):
        return self.opt.restore(state, step)

    def step_fn(self, loss_fn) -> Callable:
        if not self.is_zo or self.plan.kind == "local":
            return self.opt.step_fn(loss_fn)
        if self.plan.kind == "seed_parallel":
            if self.plan.n_groups == 1:
                # one group == one unfolded seed stream == the local plan;
                # delegating makes the bitwise guarantee true by construction
                return self.opt.step_fn(loss_fn)
            return self._seed_parallel_step_fn(loss_fn)
        if self.plan.kind == "async_worker":
            raise ValueError(
                "the async_worker plan has no monolithic step function — "
                "drive it through repro.distributed.async_zo.AsyncZOWorker "
                "(contribution_eval_fn / apply_contribution)")
        raise ValueError(
            "the replay plan is ledger-driven (no forward passes): call "
            "StepProgram.replay(params0, ledger) instead of step_fn")

    def compiled_step_fn(self, loss_fn, donate: bool = True) -> Callable:
        """``step_fn`` jitted with the parameter buffer DONATED (matching
        ``train.loop``'s jit): θ, the perturbed views, and θ_new alias one
        HBM allocation across the perturb → loss → update chain instead of
        holding a second parameter-sized buffer live per step — the paper's
        inference-memory property, and the fix for the seed-parallel
        CPU-mesh overhead measured in benchmarks/bench_exec.py.  Callers
        must treat the passed params as consumed and continue from the
        returned tree (``params, state, metrics = step(params, ...)``)."""
        return jax.jit(self.step_fn(loss_fn),
                       donate_argnums=(0,) if donate else ())

    # -- seed-parallel lowering (n_groups > 1; n == 1 delegates to local) --- #
    def _seed_parallel_step_fn(self, loss_fn) -> Callable:
        opt = self.opt
        est, tf = opt.estimator, opt.transform
        n = self.plan.n_groups
        backend = opt.backend
        batch_seeds = opt.batch_seeds
        sel = opt.selection
        n_phases = 1 if sel is None else int(sel.n_phases)

        def body(params: PyTree, state: ZOState, batch, phase: int):
            skey0 = step_key(state.base_key, state.step)
            p = params
            est_state, tf_state = state.est_state, state.tf_state
            gs, losses, coeffs = [], [], []
            aux: dict = {}
            lr_metric = None
            decay0 = 0.0
            for g in range(n):
                skey = group_key(skey0, g, n)
                if n_phases > 1:
                    e = est.estimate(loss_fn, p, slice_group(batch, g, n),
                                     skey, est_state, phase=phase)
                else:
                    e = est.estimate(loss_fn, p, slice_group(batch, g, n),
                                     skey, est_state)
                est_state = e.est_state
                ctx = TransformCtx(step=state.step, base_key=state.base_key,
                                   key=skey, seed_index=g, n_seeds=n,
                                   eps=est.eps, dist=est.dist,
                                   restore=e.restore, backend=backend)
                u, tf_state = tf.update(Updates(g=e.projected_grad), tf_state,
                                        ctx)
                if u.final_params is not None:
                    # unreachable behind the __init__ applier guard; loud
                    # (not silently dropped) if that guard is ever relaxed
                    raise ValueError(
                        "a transform materialized final_params under a "
                        "multi-group plan; group updates are rank-1 "
                        "applications and cannot honor it")
                # evaluations stay at the step's center; directions are
                # averaged afterwards through the shared write path
                p = e.restore()
                coeffs.append(u.coeff if u.coeff is not None else u.g)
                if g == 0:
                    decay0 = u.decay
                gs.append(u.g)
                losses.append(e.loss)
                if e.aux:
                    aux.update(e.aux)
                lr_metric = u.lr
            p = apply_group_updates(p, skey0, coeffs, decay0, n,
                                    batch_seeds, est.dist, backend,
                                    selection=sel, phase=phase)
            g_mean = jnp.mean(jnp.stack(gs))
            if lr_metric is None:
                lr_metric = jnp.float32(1.0)
            new_state = ZOState(state.step + 1, state.base_key,
                                est_state, tf_state, g_mean)
            metrics = {"loss": jnp.mean(jnp.stack(losses)),
                       "projected_grad": g_mean, "lr": lr_metric, **aux,
                       "projected_grads": jnp.stack(gs).reshape(-1)}
            return p, new_state, metrics

        if n_phases == 1:
            def step(params: PyTree, state: ZOState, batch):
                return body(params, state, batch, 0)
        else:
            # block schedule: same static-phase lax.switch dispatch as the
            # local facade — phase(t) is a pure function of the step counter,
            # so the selection schedule is identical under every plan
            branches = [functools.partial(body, phase=ph)
                        for ph in range(n_phases)]

            def step(params: PyTree, state: ZOState, batch):
                return jax.lax.switch(sel.phase_at(state.step), branches,
                                      params, state, batch)

        return step

    def shardings(self, params_like: PyTree, batch_like=None,
                  state_like=None):
        """(params, state, batch) ``in_shardings`` for jitting the step under
        the plan's mesh: parameters through the ``sharding.py`` rule engine,
        optimizer state replicated when ``state_like`` is given (``None`` is
        returned otherwise — GSPMD then picks the layout; the state is a few
        scalars, so either is safe), batch leaves split on their leading axis
        over the mesh's batch axes — MeZO's cross-device traffic stays the
        loss scalars."""
        mesh = self.plan.mesh
        if mesh is None:
            raise ValueError("this plan carries no mesh; construct it as "
                             "exec.seed_parallel(n, mesh=...)")
        from jax.sharding import NamedSharding, PartitionSpec as P

        from repro.distributed.sharding import batch_axes, param_shardings
        pshard = param_shardings(params_like, mesh)
        sshard = None
        if state_like is not None:
            sshard = jax.tree_util.tree_map(
                lambda _: NamedSharding(mesh, P()), state_like)
        ba = batch_axes(mesh) or None
        if batch_like is None:
            bshard = None
        else:
            bshard = jax.tree_util.tree_map(
                lambda x: NamedSharding(
                    mesh, P(ba if ba and len(ba) > 1 else (ba[0] if ba else None))
                    if jnp.ndim(x) else P()),
                batch_like)
        return pshard, sshard, bshard

    # -- async building blocks (consumed by distributed.async_zo) ----------- #
    def contribution_eval_fn(self, loss_fn, worker: int,
                             est_state=None) -> Callable:
        """jit-able ``fn(params, base_key, step, batch, phase=0) ->
        (g, lr, loss)``: evaluate this worker's seed group of one step
        through the estimator and the scalar transform chain (what goes on
        the wire is the post-transform g — the same scalar a seed-parallel
        step records).  ``phase`` is the step's static block-schedule phase
        (jit it with ``static_argnames=("phase",)``); the worker derives it
        from its step counter — the same t-pure function every plan uses."""
        opt = self.opt
        est, tf = opt.estimator, opt.transform
        n = self.plan.n_groups
        sel = opt.selection

        def fn(params, base_key, step, batch, phase=0):
            skey = group_stream_key(base_key, step, worker, n)
            e_state = (est_state if est_state is not None
                       else est.init(None, base_key))
            if sel is None:
                e = est.estimate(loss_fn, params, batch, skey, e_state)
            else:
                e = est.estimate(loss_fn, params, batch, skey, e_state,
                                 phase=phase)
            ctx = TransformCtx(step=step, base_key=base_key, key=skey,
                               seed_index=worker, n_seeds=n, eps=est.eps,
                               dist=est.dist, restore=e.restore,
                               backend=opt.backend)
            u, _ = tf.update(Updates(g=e.projected_grad), tf.init(None), ctx)
            lr = u.lr if u.lr is not None else jnp.float32(1.0)
            return u.g, lr, e.loss

        return fn

    def apply_contribution_fn(self) -> Callable:
        """jit-able ``fn(params, skey0, group, g, lr, decay_on, phase=0) ->
        params`` applying one group's contribution for the step whose key is
        ``skey0`` — the identical floats a ledger replay of that group
        performs.  ``group`` stays a DYNAMIC (traced) argument: it only feeds
        the ``fold_in`` inside ``group_key``, so one compiled apply kernel
        serves every worker id (baking it static would retrace once per
        peer).  ``phase`` IS static (it selects which leaves the update
        touches — jit with ``static_argnames=("phase",)``): one compiled
        kernel per schedule phase, not per peer."""
        opt = self.opt
        n = self.plan.n_groups
        batch_seeds = opt.batch_seeds
        dist = opt.estimator.dist
        backend = opt.backend
        wd = opt.weight_decay
        sel = opt.selection

        def fn(params, skey0, group, g, lr, decay_on, phase=0):
            coeff = (lr / n) * g
            decay = (lr * wd) * decay_on
            return apply_group_update(params, skey0, group, n, coeff, decay,
                                      batch_seeds, dist, backend,
                                      selection=sel, phase=phase)

        return fn

    # -- ledger replay ------------------------------------------------------ #
    def replay(self, params0: PyTree, ledger, from_idx: int = 0,
               to_idx: Optional[int] = None) -> PyTree:
        """Reconstruct parameters from a scalar ledger — no forward passes,
        no data (paper §2.1), under ANY plan's records.

        Ledger-coordinate checks mirror the artifact stamps: backend
        (``BackendMismatchError``), batch_seeds, and n_groups
        (``PlanMismatchError``).  A program built on the ``replay()`` plan is
        ledger-driven and adopts the ledger's n_groups; any other plan must
        match it (that is the resume path, where training continues under the
        active schedule)."""
        opt = self.opt
        check_replay_backend(getattr(ledger, "backend", None),
                             self.backend_name, "trajectory ledger")
        check_replay_selection(getattr(ledger, "selection", None),
                               opt.selection_spec, "trajectory ledger",
                               getattr(ledger, "sel_phase", 0),
                               opt.selection_phase)
        led_bs = int(getattr(ledger, "batch_seeds", 1))
        if len(ledger.steps) and led_bs != int(opt.batch_seeds):
            raise ValueError(
                f"trajectory ledger records {led_bs} seed scalar(s) per "
                f"group but the optimizer evaluates batch_seeds="
                f"{opt.batch_seeds}; the seed fold schedule (and the "
                "per-step g shape) differ, so replay would misapply the "
                "updates — replay with a matching fzoo(batch_seeds=...) "
                "composition")
        n = led_n = int(getattr(ledger, "n_groups", 1))
        if self.plan.kind != "replay":    # the replay plan is ledger-driven
            check_replay_plan(led_n, self.n_groups, "trajectory ledger",
                              recorded_kind=getattr(ledger, "exec_plan", None),
                              active_kind=self.plan.kind)
        if n > 1:
            if opt.info.get("applier"):
                raise ValueError(
                    f"{opt.name}: scalar-ledger replay cannot reproduce "
                    "applier transforms (scale_by_zo_adam / trace); resume "
                    "from a full state checkpoint instead of a ledger tail")
            if not opt.estimator.replayable:
                raise ValueError(
                    f"{opt.name}: the {opt.estimator.name!r} estimator "
                    "updates along D·z (Definition 6), which a (seed, g, lr) "
                    "ledger entry cannot reproduce; resume from a full state "
                    "checkpoint")
            if opt.info.get("lr_at") is None:
                raise ValueError(
                    f"{opt.name}: multi-group replay reconstructs "
                    "coefficients as (η/n)·g from the recorded learning "
                    "rate, but this transform chain has no "
                    "scale_by_schedule — the live step applied raw g, which "
                    "a (seed, g, lr) entry cannot re-scale; resume from a "
                    "full state checkpoint")
        base_key = jax.random.PRNGKey(ledger.base_seed)
        to_idx = len(ledger.steps) if to_idx is None else to_idx
        batch_seeds = int(opt.batch_seeds)
        sel = opt.selection
        dist = opt.estimator.dist if n > 1 else None
        backend = opt.backend if n > 1 else None
        wd = opt.weight_decay if n > 1 else None

        # the block-schedule phase is static (it decides WHICH leaves the
        # rank-1 update touches), so it is a static jit argument: replay
        # compiles one kernel per phase, exactly as the live step's
        # lax.switch carries one branch per phase
        @functools.partial(jax.jit, static_argnames=("phase",))
        def one(params, step, g, lr, phase=0):
            skey0 = step_key(base_key, step)
            if n == 1:
                # single-stream entries: the optimizer's own replay primitive
                # (bitwise with the local and seed_parallel(1) plans)
                if sel is None:
                    return opt.replay_update(params, skey0, g, lr)
                return opt.replay_update(params, skey0, g, lr, phase=phase)
            g_mat = jnp.reshape(jnp.asarray(g), (n, batch_seeds))
            coeffs = [(lr / n) * (g_mat[i] if batch_seeds > 1
                                  else g_mat[i, 0]) for i in range(n)]
            return apply_group_updates(params, skey0, coeffs, lr * wd, n,
                                       batch_seeds, dist, backend,
                                       selection=sel, phase=phase)

        p = params0
        for i in range(from_idx, to_idx):
            ph = 0 if sel is None else int(sel.phase_at(int(ledger.steps[i])))
            p = one(p, jnp.int32(ledger.steps[i]),
                    jnp.float32(ledger.grads[i]), jnp.float32(ledger.lrs[i]),
                    phase=ph)
        return p

    def replay_update(self, params, skey, g, lr):
        """Single-entry delegation (kept for protocol compatibility)."""
        return self.opt.replay_update(params, skey, g, lr)


def as_step_program(optimizer, plan: Optional[ExecPlan] = None) -> StepProgram:
    """Accept a ``StepProgram`` or anything ``as_zo_optimizer`` accepts (a
    protocol conformer, a legacy config, a backprop baseline) — the
    compatibility seam that lets the training loop, checkpoint recovery, and
    trajectory replay consume the engine while old call sites still pass
    bare optimizers."""
    if isinstance(optimizer, StepProgram):
        if plan is not None and plan != optimizer.plan:
            raise ValueError("optimizer is already a StepProgram with a "
                             f"{optimizer.plan.kind!r} plan; cannot re-plan "
                             f"it as {plan.kind!r} — build a new StepProgram")
        return optimizer
    return StepProgram(optimizer, plan)
