"""Selective SSM heads (Hymba's parallel-mamba side), in SSD chunked-matmul
form (the mamba-2 duality) — the TPU-native formulation.

Recurrence per head (decay SCALAR per head, mamba-2 style — a deliberate
hardware adaptation recorded in DESIGN.md §10: per-channel decay has no
matmul form, scalar-per-head decay turns the scan into MXU matmuls):

    h_t = exp(−Δ_t·a) · h_{t−1} + Δ_t · (x_t ⊗ B_t)        h ∈ R^{hd×N}
    y_t = h_t · C_t + D ⊙ x_t

Chunked evaluation (chunk length C, no sequential while-loop — everything is
batched matmuls + one log-depth ``associative_scan`` over chunk states, so
XLA's cost analysis counts every FLOP and the MXU sees dense GEMMs):

  within-chunk:  M[t,s] = exp(lc_t − lc_s)·Δ_s·(C_t·B_s)  (s ≤ t);  y = M@x
  carry-in:      y_t   += exp(lc_t) · C_t @ h_inᵀ
  chunk state:   h_out  = exp(lc_C)·h_in + Σ_s exp(lc_C − lc_s)·Δ_s·(x_s⊗B_s)
  across chunks: associative_scan over (decay, state) pairs.

``ssm_scan_ref`` keeps the naive ``lax.scan`` semantics as the test oracle;
decode (S == 1) is the direct single-step recurrence.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import dense_init


def ssm_params(cfg, kg, dtype) -> dict:
    d = cfg.d_model
    SH, hd, N = cfg.ssm_heads, cfg.hd, cfg.ssm_state
    inner = SH * hd
    return {
        "in_proj": dense_init(kg(), (d, 2 * inner), dtype),       # x and gate
        "dt_proj": dense_init(kg(), (d, SH), dtype),
        "b_proj": dense_init(kg(), (d, SH * N), dtype),
        "c_proj": dense_init(kg(), (d, SH * N), dtype),
        "a_log": jnp.zeros((SH,), dtype),                         # a = exp(a_log)
        "d_skip": jnp.ones((SH, hd), dtype),
        "out_proj": dense_init(kg(), (inner, d), dtype, fan_in=inner),
    }


def _project(cfg, p, u):
    """Shared input projections.  u (B,S,d)."""
    B, S, _ = u.shape
    SH, hd, N = cfg.ssm_heads, cfg.hd, cfg.ssm_state
    xz = u @ p["in_proj"]
    x, z = jnp.split(xz, 2, axis=-1)
    x = x.reshape(B, S, SH, hd).astype(jnp.float32)
    dt = jax.nn.softplus((u @ p["dt_proj"]).astype(jnp.float32))   # (B,S,SH)
    bmat = (u @ p["b_proj"]).reshape(B, S, SH, N).astype(jnp.float32)
    cmat = (u @ p["c_proj"]).reshape(B, S, SH, N).astype(jnp.float32)
    a = jnp.exp(p["a_log"].astype(jnp.float32))                    # (SH,) > 0
    return x, z, dt, bmat, cmat, a


def _finish(cfg, p, u, y, x, z):
    B, S = u.shape[:2]
    SH, hd = cfg.ssm_heads, cfg.hd
    y = y + p["d_skip"].astype(jnp.float32)[None, None] * x
    y = (y.reshape(B, S, SH * hd) * jax.nn.silu(z.astype(jnp.float32))).astype(u.dtype)
    return y @ p["out_proj"]


# --------------------------------------------------------------------------- #
# Chunked SSD path (training / prefill)
# --------------------------------------------------------------------------- #
SCAN_MODES = ("chunk", "fused_recurrent")


def ssm_scan(cfg, p: dict, u: jnp.ndarray, state: Optional[jnp.ndarray] = None,
             chunk: int = 0, mode: Optional[str] = None):
    """u (B,S,d) -> (y (B,S,d), final_state (B,SH,hd,N)).

    ``mode`` (default ``cfg.scan_mode``) selects the fla-style dual modes:
    "chunk" is the SSD chunked-matmul path, "fused_recurrent" the exact
    per-token ``lax.scan`` recurrence (``ssm_scan_ref``); parity between them
    is test-enforced (tests/test_zoo_conformance.py)."""
    B, S, d = u.shape
    SH, hd, N = cfg.ssm_heads, cfg.hd, cfg.ssm_state
    chunk = chunk or cfg.scan_chunk
    mode = mode or cfg.scan_mode
    if mode not in SCAN_MODES:
        raise ValueError(f"unknown scan mode {mode!r}; available: {SCAN_MODES}")
    if S == 1:
        if state is None:
            state = jnp.zeros((B, SH, hd, N), jnp.float32)
        return ssm_decode_step(cfg, p, u, state)   # one-step: modes coincide
    if mode == "fused_recurrent":
        return ssm_scan_ref(cfg, p, u, state)
    x, z, dt, bmat, cmat, a = _project(cfg, p, u)
    if state is None:
        state = jnp.zeros((B, SH, hd, N), jnp.float32)

    C = min(chunk, S)
    S_real = S
    if S % C:
        # pad to a chunk multiple with IDENTITY tokens: dt = 0 -> decay 1,
        # drive 0 — the state passes through unchanged; padded y rows are
        # sliced off below.
        pad = C - S % C
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        bmat = jnp.pad(bmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cmat = jnp.pad(cmat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        S = S + pad
    nc = S // C
    from repro.models.common import shard_hint
    # per-token log decay (negative): (B,S,SH) -> chunked (B,nc,C,SH)
    ldec = (-dt * a[None, None, :]).reshape(B, nc, C, SH)
    lc = jnp.cumsum(ldec, axis=2)                        # inclusive within chunk
    # chunk axis == sequence: shard over 'model' under context parallelism
    # (heads SH=25 can't shard; nc can — the SSD analogue of CP attention)
    xc = shard_hint(x.reshape(B, nc, C, SH, hd), "act_ssd")
    dtc = dt.reshape(B, nc, C, SH)
    bc = shard_hint(bmat.reshape(B, nc, C, SH, N), "act_ssd")
    cc = shard_hint(cmat.reshape(B, nc, C, SH, N), "act_ssd")

    # ---- chunk-local states: h_loc = Σ_s exp(lc_C − lc_s)·Δ_s·(x_s ⊗ B_s)
    wE = jnp.exp(lc[:, :, -1:, :] - lc)                  # (B,nc,C,SH) ≤ 1
    b_hat = bc * (wE * dtc)[..., None]                   # (B,nc,C,SH,N)
    h_loc = jnp.einsum("bnchd,bnchk->bnhdk", xc, b_hat)  # (B,nc,SH,hd,N)
    dec_chunk = jnp.exp(lc[:, :, -1, :])                 # (B,nc,SH)

    # ---- propagate states across chunks (log-depth, loop-free)
    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, d2[..., None, None] * s1 + s2

    dec_all, h_all = jax.lax.associative_scan(
        combine, (dec_chunk, h_loc), axis=1)
    # state entering chunk i = dec_all[i-1]·state0 + h_all[i-1]; chunk 0: state0
    dec_in = jnp.concatenate([jnp.ones_like(dec_chunk[:, :1]),
                              dec_all[:, :-1]], axis=1)
    h_prev = jnp.concatenate([jnp.zeros_like(h_loc[:, :1]),
                              h_all[:, :-1]], axis=1)
    h_in = dec_in[..., None, None] * state[:, None] + h_prev   # (B,nc,SH,hd,N)
    final_state = dec_all[:, -1][..., None, None] * state + h_all[:, -1]

    # ---- within-chunk attention-like matmul
    # Mask the exponent BEFORE exp: for s > t, lc_t − lc_s is positive and can
    # overflow exp to inf, and inf · 0 from a post-hoc tril mask is NaN.
    ldiff = lc[:, :, :, None, :] - lc[:, :, None, :, :]           # (B,nc,t,s,SH)
    tri = jnp.tril(jnp.ones((C, C), bool))
    gate = jnp.exp(jnp.where(tri[None, None, :, :, None], ldiff, -jnp.inf))
    scores = jnp.einsum("bnthk,bnshk->bntsh", cc, bc)             # C_t·B_s
    M = scores * gate * dtc[:, :, None, :, :]
    y = jnp.einsum("bntsh,bnshd->bnthd", M, xc)

    # ---- carry-in contribution: exp(lc_t)·C_t @ h_inᵀ
    c_tilde = cc * jnp.exp(lc)[..., None]                         # (B,nc,C,SH,N)
    y = y + jnp.einsum("bnchk,bnhdk->bnchd", c_tilde, h_in)

    y = y.reshape(B, S, SH, hd)[:, :S_real]
    return _finish(cfg, p, u, y, x[:, :S_real], z), final_state


# --------------------------------------------------------------------------- #
# Reference (naive lax.scan) — oracle for tests
# --------------------------------------------------------------------------- #
def ssm_scan_ref(cfg, p: dict, u: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None):
    B, S, d = u.shape
    SH, hd, N = cfg.ssm_heads, cfg.hd, cfg.ssm_state
    x, z, dt, bmat, cmat, a = _project(cfg, p, u)
    if state is None:
        state = jnp.zeros((B, SH, hd, N), jnp.float32)

    xs = (x.transpose(1, 0, 2, 3), dt.transpose(1, 0, 2),
          bmat.transpose(1, 0, 2, 3), cmat.transpose(1, 0, 2, 3))

    def step(h, xs_t):
        x_t, dt_t, b_t, c_t = xs_t
        decay = jnp.exp(-dt_t * a[None, :])[..., None, None]       # (B,SH,1,1)
        drive = dt_t[..., None, None] * x_t[..., None] * b_t[..., None, :]
        h = decay * h + drive                                      # (B,SH,hd,N)
        y_t = jnp.einsum("bhdn,bhn->bhd", h, c_t)
        return h, y_t

    final_state, ys = jax.lax.scan(step, state, xs)
    y = ys.transpose(1, 0, 2, 3)
    return _finish(cfg, p, u, y, x, z), final_state


# --------------------------------------------------------------------------- #
# Decode (single step, O(1) state)
# --------------------------------------------------------------------------- #
def ssm_decode_step(cfg, p: dict, u: jnp.ndarray, state: jnp.ndarray):
    """u (B,1,d) single-token step with O(1) state carry."""
    x, z, dt, bmat, cmat, a = _project(cfg, p, u)
    decay = jnp.exp(-dt[:, 0] * a[None, :])[..., None, None]       # (B,SH,1,1)
    drive = dt[:, 0][..., None, None] * x[:, 0][..., None] * bmat[:, 0][..., None, :]
    h = decay * state + drive
    y = jnp.einsum("bhdn,bhn->bhd", h, cmat[:, 0])[:, None]        # (B,1,SH,hd)
    return _finish(cfg, p, u, y, x, z), h


def init_ssm_state(cfg, batch: int, layers: Optional[int] = None) -> jnp.ndarray:
    L = layers if layers is not None else cfg.n_layers
    return jnp.zeros((L, batch, cfg.ssm_heads, cfg.hd, cfg.ssm_state), jnp.float32)
