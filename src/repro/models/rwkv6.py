"""RWKV6 "Finch" (arXiv:2404.05892): attention-free LM with data-dependent
per-channel decay.

Per layer: TimeMix (the WKV6 linear recurrence) + ChannelMix.  The WKV state
is O(H·hd²) per sequence regardless of length — this is why rwkv6 runs the
long_500k cell natively.

TimeMix recurrence (per head, key index i, value index j):

    S_t[i,j] = w_t[i] · S_{t−1}[i,j] + k_t[i] · v_t[j]
    y_t[j]   = Σ_i r_t[i] · (S_{t−1}[i,j] + u[i] · k_t[i] · v_t[j])

with w_t = exp(−exp(w0 + lora_w(x_w))) the *data-dependent decay* (the Finch
novelty vs RWKV5), r/k/v/g from token-shifted lerps.  Training uses a
``lax.scan`` over time (the chunked-matmul Pallas kernel in
``repro.kernels.rwkv6`` is the MXU-friendly variant); decode is a single
recurrence step.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.common import KeyGen, dense_init, embed_init, rmsnorm, shard_hint

LORA_R = 64


class RWKVLayerState(NamedTuple):
    shift_tm: jnp.ndarray     # (B, d) last token for TimeMix token-shift
    shift_cm: jnp.ndarray     # (B, d) last token for ChannelMix token-shift
    wkv: jnp.ndarray          # (B, H, hd, hd) recurrence state (f32)


def rwkv_layer_params(cfg, kg: KeyGen, dtype) -> dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    inner = H * hd
    return {
        "tm": {
            "norm_scale": jnp.zeros((d,), dtype),
            "mu_r": jnp.full((d,), 0.5, dtype),
            "mu_k": jnp.full((d,), 0.5, dtype),
            "mu_v": jnp.full((d,), 0.5, dtype),
            "mu_g": jnp.full((d,), 0.5, dtype),
            "mu_w": jnp.full((d,), 0.5, dtype),
            "wr": dense_init(kg(), (d, inner), dtype),
            "wk": dense_init(kg(), (d, inner), dtype),
            "wv": dense_init(kg(), (d, inner), dtype),
            "wg": dense_init(kg(), (d, inner), dtype),
            "wo": dense_init(kg(), (inner, d), dtype, fan_in=inner),
            "w0": jnp.full((H, hd), -1.0, dtype),      # base decay logit
            "w_lora_a": dense_init(kg(), (d, LORA_R), dtype),
            "w_lora_b": (jnp.zeros((LORA_R, inner), dtype)),
            "u": jnp.zeros((H, hd), dtype),            # first-token bonus
            "ln_out_scale": jnp.zeros((inner,), dtype),
        },
        "cm": {
            "norm_scale": jnp.zeros((d,), dtype),
            "mu_k": jnp.full((d,), 0.5, dtype),
            "mu_r": jnp.full((d,), 0.5, dtype),
            "wk": dense_init(kg(), (d, cfg.d_ff), dtype),
            "wv": dense_init(kg(), (cfg.d_ff, d), dtype, fan_in=cfg.d_ff),
            "wr": dense_init(kg(), (d, d), dtype),
        },
    }


def _token_shift(x: jnp.ndarray, last: Optional[jnp.ndarray]) -> jnp.ndarray:
    """xx_t = x_{t-1}; position 0 uses ``last`` (carried state) or zeros."""
    B, S, d = x.shape
    first = jnp.zeros((B, 1, d), x.dtype) if last is None else last[:, None, :]
    return jnp.concatenate([first, x[:, :-1, :]], axis=1)


def _tm_projections(cfg, p: dict, x: jnp.ndarray, state):
    """Shared TimeMix input path: token shift, lerps, projections, decay."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    xn = rmsnorm(x, p["norm_scale"])
    xx = _token_shift(xn, state.shift_tm if state is not None else None)

    def lerp(mu):
        return xn + (xx - xn) * mu.astype(xn.dtype)

    r = (lerp(p["mu_r"]) @ p["wr"]).reshape(B, S, H, hd).astype(jnp.float32)
    k = (lerp(p["mu_k"]) @ p["wk"]).reshape(B, S, H, hd).astype(jnp.float32)
    v = (lerp(p["mu_v"]) @ p["wv"]).reshape(B, S, H, hd).astype(jnp.float32)
    g = jax.nn.silu(lerp(p["mu_g"]) @ p["wg"])                     # (B,S,H*hd)
    w_in = lerp(p["mu_w"])
    w_logit = (w_in @ p["w_lora_a"]) @ p["w_lora_b"]               # (B,S,H*hd)
    w_logit = w_logit.reshape(B, S, H, hd) + p["w0"].astype(w_logit.dtype)
    # per-channel log decay, data-dependent (Finch): log w = −exp(logit) < 0.
    # The logit is clamped to [−8, 1] (decay rate ≤ e per token, as in the
    # official RWKV6 setup) so that the chunked factorization's exponent range
    # C·rate stays within f32 (chunk 16 → ≤ 43.5; see time_mix docstring).
    logw = -jnp.exp(jnp.clip(w_logit.astype(jnp.float32), -8.0, 1.0))
    u = p["u"].astype(jnp.float32)
    wkv0 = (state.wkv if state is not None
            else jnp.zeros((B, H, hd, hd), jnp.float32))
    return xn, r, k, v, g, logw, u, wkv0


def _tm_output(cfg, p: dict, x, xn, y, g):
    B, S = x.shape[:2]
    y = y.reshape(B, S, cfg.n_heads * cfg.hd)
    y = rmsnorm(y, p["ln_out_scale"])                              # group-ish norm
    out = (y * g.astype(y.dtype)) @ p["wo"]
    return out.astype(x.dtype), xn[:, -1, :]


_CLIP = 50.0  # f32 overflow guard; never active in the valid decay regime
              # (rate ≤ e, chunk 16 → exponents ≤ 43.5)

SCAN_MODES = ("chunk", "fused_recurrent")


def _resolve_mode(cfg, mode: Optional[str]) -> str:
    """fla-style dual-mode switch: ``"chunk"`` is the chunked-matmul WKV
    (MXU-native), ``"fused_recurrent"`` the exact per-token recurrence.
    ``mode=None`` falls back to ``cfg.scan_mode``; unknown modes refuse."""
    m = mode or cfg.scan_mode
    if m not in SCAN_MODES:
        raise ValueError(f"unknown scan mode {m!r}; available: {SCAN_MODES}")
    return m


def time_mix(cfg, p: dict, x: jnp.ndarray, state: Optional[RWKVLayerState],
             mode: Optional[str] = None):
    """WKV6 in chunked matmul form (no sequential while-loop).

    With lc = cumsum(log w) within a chunk, the strict-past contribution is
        y_t += Σ_{s<t} (r_t·Π_{s+1..t−1}w ⊙ k_s) v_s
             = Σ_{s<t} (r̃_t · k̃_s) v_s,   r̃_t = r_t·e^{lc_{t−1}},
                                            k̃_s = k_s·e^{−lc_s}
    — a causal linear-attention matmul; the bonus term is the diagonal, the
    carried state enters as r̃ @ S_in, and chunk states compose by a log-depth
    associative_scan.

    Numerics: the k̃ factor grows as e^{rate·C}; with the decay-rate clamp
    (≤ e per token, see _tm_projections) and chunk C = 16 the exponent is
    ≤ 43.5, well inside f32 — the factorization is then EXACT (products are
    the true ≤ O(1) weights; only the factors are large).  _CLIP = 50 is a
    pure overflow guard.  This is the MXU-native WKV the Pallas kernel
    (repro.kernels.rwkv6) implements tile-wise; ``time_mix_ref`` is the exact
    recurrence oracle.
    """
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    if S == 1 and state is not None:
        return time_mix_decode(cfg, p, x, state)   # one-step: modes coincide
    if _resolve_mode(cfg, mode) == "fused_recurrent":
        return time_mix_ref(cfg, p, x, state)
    xn, r, k, v, g, logw, u, wkv0 = _tm_projections(cfg, p, x, state)

    C = min(cfg.scan_chunk, S)
    S_real = S
    if S % C:
        # pad with identity tokens: log w = 0 (decay 1), k = v = r = 0 —
        # the state passes through untouched; padded rows sliced off below.
        pad = C - S % C
        pad4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        r = jnp.pad(r, pad4)
        k = jnp.pad(k, pad4)
        v = jnp.pad(v, pad4)
        logw = jnp.pad(logw, pad4)
        S = S + pad
    nc = S // C
    rc = r.reshape(B, nc, C, H, hd)
    kc = k.reshape(B, nc, C, H, hd)
    vc = v.reshape(B, nc, C, H, hd)
    lw = logw.reshape(B, nc, C, H, hd)
    lc = jnp.cumsum(lw, axis=2)                                    # inclusive
    lc_prev = lc - lw                                              # exclusive (lc_{t-1})

    r_t = rc * jnp.exp(jnp.maximum(lc_prev, -_CLIP))               # r̃ (≤ 1 safe)
    k_t = kc * jnp.exp(jnp.minimum(-lc, _CLIP))                    # k̃ (clipped)
    A = jnp.einsum("bnchd,bnshd->bnhcs", r_t, k_t)                 # (B,nc,H,C,C)
    tri = jnp.tril(jnp.ones((C, C), jnp.float32), -1)              # strict past
    A = A * tri[None, None, None]
    bonus = jnp.einsum("bnchd,bnchd->bnch", rc, u[None, None, None] * kc)
    y = jnp.einsum("bnhcs,bnshd->bnchd", A, vc)
    y = y + bonus[..., None] * vc                                  # diagonal term
    # carried-state contribution: r̃_t @ S_in
    dec_chunk = jnp.exp(lc[:, :, -1])                              # (B,nc,H,hd)
    k_hat = kc * jnp.exp(jnp.maximum(lc[:, :, -1:] - lc, -_CLIP))  # ≤ 1 safe
    s_loc = jnp.einsum("bnchi,bnchj->bnhij", k_hat, vc)            # (B,nc,H,hd,hd)

    def combine(left, right):
        d1, s1 = left
        d2, s2 = right
        return d1 * d2, d2[..., None] * s1 + s2

    dec_all, s_all = jax.lax.associative_scan(combine, (dec_chunk, s_loc), axis=1)
    dec_in = jnp.concatenate([jnp.ones_like(dec_chunk[:, :1]),
                              dec_all[:, :-1]], axis=1)
    s_prev = jnp.concatenate([jnp.zeros_like(s_loc[:, :1]),
                              s_all[:, :-1]], axis=1)
    s_in = dec_in[..., None] * wkv0[:, None] + s_prev              # (B,nc,H,hd,hd)
    wkv_final = dec_all[:, -1][..., None] * wkv0 + s_all[:, -1]
    y = y + jnp.einsum("bnchi,bnhij->bnchj", r_t, s_in)

    y = y.reshape(B, S, H, hd)[:, :S_real]
    out, shift = _tm_output(cfg, p, x, xn, y, g)
    return out, (shift, wkv_final)


def time_mix_ref(cfg, p: dict, x: jnp.ndarray, state: Optional[RWKVLayerState]):
    """Exact per-token recurrence (lax.scan) — the test oracle."""
    B, S, d = x.shape
    xn, r, k, v, g, logw, u, wkv0 = _tm_projections(cfg, p, x, state)
    w = jnp.exp(logw)

    rs = r.transpose(1, 0, 2, 3)
    ks = k.transpose(1, 0, 2, 3)
    vs = v.transpose(1, 0, 2, 3)
    ws = w.transpose(1, 0, 2, 3)

    def step(S_prev, xs_t):
        r_t, k_t, v_t, w_t = xs_t                                   # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]                  # (B,H,hd,hd)
        y_t = jnp.einsum("bhi,bhij->bhj", r_t, S_prev + u[None, :, :, None] * kv)
        S_new = w_t[..., :, None] * S_prev + kv
        return S_new, y_t

    wkv_final, ys = jax.lax.scan(step, wkv0, (rs, ks, vs, ws))
    y = ys.transpose(1, 0, 2, 3)
    out, shift = _tm_output(cfg, p, x, xn, y, g)
    return out, (shift, wkv_final)


def time_mix_decode(cfg, p: dict, x: jnp.ndarray, state: RWKVLayerState):
    """Single-token step: one rank-1 state update (O(1) per token)."""
    xn, r, k, v, g, logw, u, wkv0 = _tm_projections(cfg, p, x, state)
    r1, k1, v1 = r[:, 0], k[:, 0], v[:, 0]
    w1 = jnp.exp(logw[:, 0])
    kv = k1[..., :, None] * v1[..., None, :]
    y = jnp.einsum("bhi,bhij->bhj", r1, wkv0 + u[None, :, :, None] * kv)
    wkv_new = w1[..., :, None] * wkv0 + kv
    out, shift = _tm_output(cfg, p, x, xn, y[:, None], g)
    return out, (shift, wkv_new)


def channel_mix(cfg, p: dict, x: jnp.ndarray, state: Optional[RWKVLayerState]):
    xn = rmsnorm(x, p["norm_scale"])
    xx = _token_shift(xn, state.shift_cm if state is not None else None)
    xk = xn + (xx - xn) * p["mu_k"].astype(xn.dtype)
    xr = xn + (xx - xn) * p["mu_r"].astype(xn.dtype)
    k = jax.nn.relu(xk @ p["wk"])
    k = k * k                                                       # relu²
    k = shard_hint(k, "act_ff")
    kv = k @ p["wv"]
    out = jax.nn.sigmoid(xr @ p["wr"]) * kv
    return out.astype(x.dtype), xn[:, -1, :]


def rwkv_block(cfg, p: dict, x: jnp.ndarray,
               state: Optional[RWKVLayerState] = None,
               mode: Optional[str] = None):
    tm_out, (shift_tm, wkv) = time_mix(cfg, p["tm"], x, state, mode=mode)
    x = x + tm_out
    cm_out, shift_cm = channel_mix(cfg, p["cm"], x, state)
    x = x + cm_out
    return x, RWKVLayerState(shift_tm, shift_cm, wkv)


def init_rwkv_state(cfg, batch: int) -> RWKVLayerState:
    """Stacked-over-layers recurrent state."""
    L, d, H, hd = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.hd
    dt = cfg.param_dtype
    return RWKVLayerState(
        shift_tm=jnp.zeros((L, batch, d), dt),
        shift_cm=jnp.zeros((L, batch, d), dt),
        wkv=jnp.zeros((L, batch, H, hd, hd), jnp.float32),
    )


# --------------------------------------------------------------------------- #
# Full model (family = "ssm")
# --------------------------------------------------------------------------- #
def init_params(cfg, key: jax.Array) -> dict:
    dtype = cfg.param_dtype
    kg = KeyGen(key)
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    layers = jax.vmap(lambda k: rwkv_layer_params(cfg, KeyGen(k), dtype))(layer_keys)
    return {
        "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dtype),
        "ln_in_scale": jnp.zeros((cfg.d_model,), dtype),
        "layers": layers,
        "ln_f_scale": jnp.zeros((cfg.d_model,), dtype),
        "head": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dtype),
    }


def forward(cfg, params: dict, *, tokens: jnp.ndarray,
            state: Optional[RWKVLayerState] = None,
            mode: Optional[str] = None):
    """tokens (B,S) -> (logits (B,S,V), new_state).  ``state`` is the
    stacked-over-layers recurrent state; pass it for decode (S may be 1),
    None for training-from-scratch.  ``mode`` overrides ``cfg.scan_mode``
    ("chunk" | "fused_recurrent"); both modes are parity-checked in
    tests/test_zoo_conformance.py."""
    mode = _resolve_mode(cfg, mode)
    x = jnp.take(params["embed"], tokens, axis=0)
    x = rmsnorm(x, params["ln_in_scale"])
    x = shard_hint(x, "act_btd")
    use_state = state is not None

    def body(x, layer_in):
        lp, state_l = layer_in
        x, new_state_l = rwkv_block(cfg, lp, x, state_l if use_state else None,
                                    mode=mode)
        return x, new_state_l

    xs = (params["layers"],
          state if use_state else jnp.zeros((cfg.n_layers,), jnp.int8))
    if cfg.scan_layers:
        x, new_state = jax.lax.scan(body, x, xs)
    else:
        outs = []
        for i in range(cfg.n_layers):
            layer_in = jax.tree_util.tree_map(lambda a: a[i], xs)
            x, ns = body(x, layer_in)
            outs.append(ns)
        new_state = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *outs)

    x = rmsnorm(x, params["ln_f_scale"])
    logits = x @ params["head"]
    logits = shard_hint(logits, "act_vocab")
    return logits, new_state
