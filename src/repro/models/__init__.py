from repro.models.config import (ALL_CELLS, DECODE_32K, LONG_500K, ModelConfig,
                                 PREFILL_32K, ShapeCell, TRAIN_4K, cells_for)
from repro.models.registry import (Arch, Bundle, FAMILY_ARCHS, OBJECTIVES,
                                   all_archs, bundle, default_selection,
                                   family_arch, get, register)

__all__ = ["ModelConfig", "ShapeCell", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
           "LONG_500K", "ALL_CELLS", "cells_for", "Arch", "Bundle", "register",
           "get", "all_archs", "bundle", "FAMILY_ARCHS", "OBJECTIVES",
           "default_selection", "family_arch"]
