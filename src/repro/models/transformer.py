"""Decoder-only transformer stack: dense GQA, MoE, and hybrid (attn ⊕ SSM)
families, with scan-over-layers (stacked parameters) so the lowered HLO is
O(1) in depth — essential both for the 96-layer dry-run compiles and for
keeping MeZO's per-leaf z regeneration to a handful of large leaves.

Params layout (all block leaves stacked over layers on axis 0):
    {"embed": (V, d),
     "layers": {"ln1": …, "attn": …, ("mlp"|"moe"): …,
                ["ln_ssm": …, "ssm": …, "mix": …], "ln2": …},
     "ln_f": …, ["head": (d, V)]}
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import ssm as ssm_lib
from repro.models.common import (KeyGen, apply_norm, dense_init, embed_init,
                                 norm_params, shard_hint)
from repro.models.config import ModelConfig
from repro.models.ffn import ffn, ffn_params
from repro.models.moe import moe_ffn, moe_params


# --------------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------------- #
def _layer_params(cfg: ModelConfig, key: jax.Array, dtype) -> dict:
    kg = KeyGen(key)
    p = {
        "ln1": norm_params(cfg, cfg.d_model, dtype),
        "attn": attn_lib.attention_params(cfg, kg, dtype),
        "ln2": norm_params(cfg, cfg.d_model, dtype),
    }
    if cfg.n_experts:
        p["moe"] = moe_params(cfg, kg, dtype)
    else:
        p["mlp"] = ffn_params(cfg, kg, dtype)
    if cfg.family == "hybrid":
        p["ln_ssm"] = norm_params(cfg, cfg.d_model, dtype)
        p["ssm"] = ssm_lib.ssm_params(cfg, kg, dtype)
        p["mix"] = jnp.full((2,), 0.5, dtype)   # learned attn/ssm combination
    return p


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = cfg.param_dtype
    kg = KeyGen(key)
    V = cfg.padded_vocab
    layer_keys = jax.random.split(kg(), cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_params(cfg, k, dtype))(layer_keys)
    params = {
        "embed": embed_init(kg(), (V, cfg.d_model), dtype),
        "layers": layers,
        "ln_f": norm_params(cfg, cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["head"] = dense_init(kg(), (cfg.d_model, V), dtype)
    return params


# --------------------------------------------------------------------------- #
# One block
# --------------------------------------------------------------------------- #
def block(cfg: ModelConfig, p: dict, x: jnp.ndarray, positions: jnp.ndarray,
          cache: Optional[dict], cache_pos, ssm_state: Optional[jnp.ndarray]):
    h = apply_norm(cfg, x, p["ln1"])
    attn_out, new_cache = attn_lib.self_attention(cfg, p["attn"], h, positions,
                                                  cache, cache_pos)
    new_ssm_state = None
    if cfg.family == "hybrid":
        hs = apply_norm(cfg, x, p["ln_ssm"])
        ssm_out, new_ssm_state = ssm_lib.ssm_scan(cfg, p["ssm"], hs, ssm_state)
        mix = p["mix"].astype(attn_out.dtype)
        x = x + mix[0] * attn_out + mix[1] * ssm_out
    else:
        x = x + attn_out

    h2 = apply_norm(cfg, x, p["ln2"])
    aux = jnp.float32(0.0)
    if cfg.n_experts:
        mo, aux = moe_ffn(cfg, p["moe"], h2)
        x = x + mo
    else:
        x = x + ffn(cfg, p["mlp"], h2)
    x = shard_hint(x, "act_btd")
    return x, new_cache, new_ssm_state, aux


# --------------------------------------------------------------------------- #
# Full forward
# --------------------------------------------------------------------------- #
class ForwardResult(NamedTuple):
    logits: jnp.ndarray
    cache: Optional[dict]
    ssm_state: Optional[jnp.ndarray]
    aux_loss: jnp.ndarray


def forward(cfg: ModelConfig, params: dict, *, tokens: Optional[jnp.ndarray] = None,
            embeds: Optional[jnp.ndarray] = None,
            positions: Optional[jnp.ndarray] = None,
            cache: Optional[dict] = None, cache_pos=None,
            ssm_state: Optional[jnp.ndarray] = None) -> ForwardResult:
    """tokens (B,S) int32 or embeds (B,S,d) (stub frontends).  ``cache`` /
    ``ssm_state`` are stacked over layers (leading L axis)."""
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    else:
        x = embeds.astype(cfg.param_dtype)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if not cfg.use_rope:
        # absolute sinusoidal positions (OPT/RoBERTa-style proxy)
        from repro.models.common import sinusoidal_at
        x = x + sinusoidal_at(positions, cfg.d_model, x.dtype)[None]
    x = shard_hint(x, "act_btd")

    use_cache = cache is not None
    use_ssm = cfg.family == "hybrid" and ssm_state is not None

    def body(carry, layer_in):
        x, aux_acc = carry
        lp, cache_l, state_l = layer_in
        x, new_cache_l, new_state_l, aux = block(
            cfg, lp, x, positions,
            cache_l if use_cache else None, cache_pos,
            state_l if use_ssm else None)
        outs = (new_cache_l if use_cache else 0,
                new_state_l if use_ssm else 0)
        return (x, aux_acc + aux), outs

    xs = (params["layers"],
          cache if use_cache else jnp.zeros((cfg.n_layers,), jnp.int8),
          ssm_state if use_ssm else jnp.zeros((cfg.n_layers,), jnp.int8))

    if cfg.scan_layers:
        (x, aux_total), (new_cache, new_ssm) = jax.lax.scan(body, (x, jnp.float32(0.0)), xs)
    else:
        aux_total = jnp.float32(0.0)
        new_cache_list, new_ssm_list = [], []
        for i in range(cfg.n_layers):
            layer_in = jax.tree_util.tree_map(lambda a: a[i], xs)
            (x, aux_total), (nc, ns) = body((x, aux_total), layer_in)
            new_cache_list.append(nc)
            new_ssm_list.append(ns)
        stack = lambda l: jax.tree_util.tree_map(lambda *a: jnp.stack(a), *l)
        new_cache = stack(new_cache_list) if use_cache else 0
        new_ssm = stack(new_ssm_list) if use_ssm else 0

    x = apply_norm(cfg, x, params["ln_f"])
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    logits = x @ head
    logits = shard_hint(logits, "act_vocab")
    return ForwardResult(logits,
                         new_cache if use_cache else None,
                         new_ssm if use_ssm else None,
                         aux_total)


# --------------------------------------------------------------------------- #
# Losses
# --------------------------------------------------------------------------- #
def lm_loss(cfg: ModelConfig, logits: jnp.ndarray, labels: jnp.ndarray,
            loss_mask: Optional[jnp.ndarray] = None,
            aux_loss: jnp.ndarray = 0.0, aux_coef: float = 0.01) -> jnp.ndarray:
    """Teacher-forcing cross entropy with padded-vocab masking, f32 logsumexp."""
    lg = logits.astype(jnp.float32)
    if cfg.padded_vocab != cfg.vocab_size:
        pad = cfg.padded_vocab - cfg.vocab_size
        neg = jnp.full(lg.shape[:-1] + (pad,), -1e30, jnp.float32)
        lg = jnp.concatenate([lg[..., :cfg.vocab_size], neg], axis=-1)
    if cfg.logit_softcap > 0:
        lg = cfg.logit_softcap * jnp.tanh(lg / cfg.logit_softcap)
    logz = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    nll = logz - gold
    if loss_mask is not None:
        m = loss_mask.astype(jnp.float32)
        loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    else:
        loss = jnp.mean(nll)
    return loss + aux_coef * jnp.asarray(aux_loss, jnp.float32)


def train_loss_fn(cfg: ModelConfig):
    """(params, batch) -> scalar loss.  batch: {"tokens"|"embeds", "labels",
    optional "loss_mask"}.  This is the function MeZO's two forward passes
    evaluate."""
    def loss_fn(params, batch):
        r = forward(cfg, params, tokens=batch.get("tokens"),
                    embeds=batch.get("embeds"))
        return lm_loss(cfg, r.logits, batch["labels"], batch.get("loss_mask"),
                       r.aux_loss)
    return loss_fn
