"""Parameter-efficient fine-tuning: LoRA and prefix tuning (paper §3 / App. E.5).

MeZO composes with PEFT by construction: the optimizer perturbs whatever tree
it is given.  The unified path merges the frozen base and the PEFT tree into
ONE parameter tree (``peft_params``) consumed by ``peft_loss_fn``, with a
``repro.select`` ``peft(mode)`` selection scoping the optimizer to the PEFT
subtree — the base leaves ride along untouched (zero z generation, zero
writes, no decay).  This replaces the bespoke tree-swap entry points
(``lora_loss_fn`` / ``prefix_loss_fn``, kept as deprecated bitwise-equal
shims): PEFT is now an ordinary parameter selection, composable with every
estimator, backend, and execution plan.

LoRA (Hu et al. 2022):   W_eff = W + (α/r)·A·B on attention q and v
                         projections (paper's setting, r=8, α=16).
Prefix (Li & Liang 2021): m virtual K/V pairs per layer, prepended at
                         attention time; initialized from *real token
                         activations* (the paper's stability trick, Tab. 17).
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.attention import project_qkv
from repro.models.common import dense_init
from repro.models.config import ModelConfig
from repro.select import PEFT_MODES  # one source of truth for valid modes

PREFIX_POS = -2  # sentinel k_pos: always attendable (see attention._mask)


# --------------------------------------------------------------------------- #
# LoRA
# --------------------------------------------------------------------------- #
def init_lora(cfg: ModelConfig, key: jax.Array, rank: int = 8,
              alpha: float = 16.0, targets: tuple = ("wq", "wv")) -> dict:
    """LoRA trees for stacked attention projections.  B zero-init (standard:
    the delta starts at exactly zero)."""
    dtype = cfg.param_dtype
    L, d, H, KV, hd = cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    outs = {"wq": H * hd, "wk": KV * hd, "wv": KV * hd, "wo": d}
    tree = {}
    for i, t in enumerate(targets):
        k = jax.random.fold_in(key, i)
        tree[t] = {
            "a": dense_init(k, (L, d if t != "wo" else H * hd, rank), dtype),
            "b": jnp.zeros((L, rank, outs[t]), dtype),
        }
    tree["_scale"] = jnp.asarray(alpha / rank, dtype)
    return tree


def merge_lora(base_params: dict, lora: dict) -> dict:
    """Return params with W := W + (α/r)·A·B applied to the targeted stacked
    attention leaves.  Cheap (rank-r matmuls) and traced inside the loss, so
    MeZO's perturbation of A/B flows through exactly."""
    scale = lora["_scale"]
    attn = dict(base_params["layers"]["attn"])
    for t, ab in lora.items():
        if t.startswith("_"):
            continue
        delta = jnp.einsum("ldr,lro->ldo", ab["a"], ab["b"]) * scale
        attn[t] = base_params["layers"]["attn"][t] + delta.astype(
            base_params["layers"]["attn"][t].dtype)
    layers = dict(base_params["layers"])
    layers["attn"] = attn
    out = dict(base_params)
    out["layers"] = layers
    return out


def lora_loss_fn(cfg: ModelConfig, base_params: dict) -> Callable:
    """DEPRECATED tree-swap entry point — the unified path is
    ``peft_loss_fn(cfg, "lora")`` over ``peft_params(base, lora, "lora")``
    with a ``repro.select.peft("lora")`` selection.  This shim wraps exactly
    that loss (bitwise-equal, test-enforced in tests/test_select.py),
    mirroring the ``core/perturb.py`` shim pattern."""
    unified = peft_loss_fn(cfg, "lora")
    def loss(lora_params, batch):
        return unified({"base": base_params, "lora": lora_params}, batch)
    return loss


# --------------------------------------------------------------------------- #
# Prefix tuning
# --------------------------------------------------------------------------- #
def init_prefix(cfg: ModelConfig, key: jax.Array, m: int = 5) -> dict:
    """Random-init prefixes (ablation baseline)."""
    dtype = cfg.param_dtype
    L, KV, hd = cfg.n_layers, cfg.kv_heads, cfg.hd
    k1, k2 = jax.random.split(key)
    return {"pk": jax.random.normal(k1, (L, m, KV, hd), dtype) * 0.02,
            "pv": jax.random.normal(k2, (L, m, KV, hd), dtype) * 0.02}


def init_prefix_from_tokens(cfg: ModelConfig, params: dict, key: jax.Array,
                            m: int = 5) -> dict:
    """The paper's real-activation init (App. E.5, Table 17): sample m random
    vocabulary tokens, run the frozen LM, and harvest their per-layer K/V."""
    toks = jax.random.randint(key, (1, m), 0, cfg.vocab_size)

    x = jnp.take(params["embed"], toks, axis=0)
    x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    positions = jnp.arange(m, dtype=jnp.int32)

    def body(x, lp):
        from repro.models.common import apply_norm
        h = apply_norm(cfg, x, lp["ln1"])
        _, k, v = project_qkv(cfg, lp["attn"], h, h)
        # advance x through the real block so deeper layers see real inputs
        x_next, _, _, _ = transformer.block(cfg, lp, x, positions, None, None, None)
        return x_next, (k[0], v[0])

    _, (ks, vs) = jax.lax.scan(body, x, params["layers"])
    return {"pk": ks.astype(cfg.param_dtype), "pv": vs.astype(cfg.param_dtype)}


def _forward_with_prefix(cfg: ModelConfig, params: dict, prefix: dict, batch):
    """Forward pass where each layer's attention sees [prefix_kv ; kv].

    Implemented by a scan mirroring transformer.forward but concatenating the
    per-layer prefix K/V with sentinel positions (always attendable)."""
    from repro.models import attention as attn_lib
    from repro.models.common import apply_norm, shard_hint
    from repro.models.ffn import ffn
    from repro.models.moe import moe_ffn
    from repro.models import ssm as ssm_lib

    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    if embeds is None:
        x = jnp.take(params["embed"], tokens, axis=0)
        x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    else:
        x = embeds.astype(cfg.param_dtype)
    B, S = x.shape[:2]
    positions = jnp.arange(S, dtype=jnp.int32)
    m = prefix["pk"].shape[1]
    prefix_pos = jnp.full((m,), PREFIX_POS, jnp.int32)

    def body(carry, layer_in):
        x, aux_acc = carry
        lp, pk, pv = layer_in
        h = apply_norm(cfg, x, lp["ln1"])
        q, k, v = attn_lib.project_qkv(cfg, lp["attn"], h, h)
        if cfg.use_rope:
            from repro.models.common import apply_rope, rope_cos_sin
            cos, sin = rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        pkb = jnp.broadcast_to(pk[None], (B,) + pk.shape).astype(k.dtype)
        pvb = jnp.broadcast_to(pv[None], (B,) + pv.shape).astype(v.dtype)
        k_all = jnp.concatenate([pkb, k], axis=1)
        v_all = jnp.concatenate([pvb, v], axis=1)
        k_pos = jnp.concatenate([prefix_pos, positions])
        out = attn_lib.attend(cfg, q, k_all, v_all, q_pos=positions,
                              k_pos=k_pos, causal=True,
                              window=cfg.sliding_window)
        out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"]
        if cfg.family == "hybrid":
            hs = apply_norm(cfg, x, lp["ln_ssm"])
            ssm_out, _ = ssm_lib.ssm_scan(cfg, lp["ssm"], hs, None)
            mix = lp["mix"].astype(out.dtype)
            x = x + mix[0] * out + mix[1] * ssm_out
        else:
            x = x + out
        h2 = apply_norm(cfg, x, lp["ln2"])
        aux = jnp.float32(0.0)
        if cfg.n_experts:
            mo, aux = moe_ffn(cfg, lp["moe"], h2)
            x = x + mo
        else:
            x = x + ffn(cfg, lp["mlp"], h2)
        x = shard_hint(x, "act_btd")
        return (x, aux_acc + aux), 0

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)),
                               (params["layers"], prefix["pk"], prefix["pv"]))
    x = apply_norm(cfg, x, params["ln_f"])
    head = params.get("head")
    if head is None:
        head = params["embed"].T
    return x @ head, aux


def prefix_loss_fn(cfg: ModelConfig, base_params: dict) -> Callable:
    """DEPRECATED tree-swap entry point — the unified path is
    ``peft_loss_fn(cfg, "prefix")`` over ``peft_params(base, prefix,
    "prefix")`` with a ``repro.select.peft("prefix")`` selection.  Bitwise-
    equal shim over that loss (test-enforced), mirroring the
    ``core/perturb.py`` shim pattern."""
    unified = peft_loss_fn(cfg, "prefix")
    def loss(prefix_params, batch):
        return unified({"base": base_params, "prefix": prefix_params}, batch)
    return loss


# --------------------------------------------------------------------------- #
# The unified merged-tree path (repro.select integration)
# --------------------------------------------------------------------------- #
def peft_params(base_params: dict, peft_tree: dict, mode: str) -> dict:
    """Merge the frozen base and the PEFT tree into the ONE parameter tree
    the unified loss consumes: ``{"base": base, mode: peft_tree}``.  The
    optimizer sees the whole tree; a ``repro.select.peft(mode)`` selection
    scopes perturbation and updates to the PEFT subtree, so the base leaves
    are never touched (test-enforced)."""
    if mode not in PEFT_MODES:
        raise ValueError(f"unknown peft mode {mode!r}; available: {PEFT_MODES}")
    return {"base": base_params, mode: peft_tree}


def peft_loss_fn(cfg: ModelConfig, mode: str) -> Callable:
    """``loss(merged, batch)`` over a ``peft_params`` merged tree — the one
    loss the unified PEFT path uses for MeZO and the backprop baselines
    alike.  The merge arithmetic is identical to the legacy tree-swap
    closures, so the deprecated shims are bitwise-equal wrappers of this."""
    if mode == "lora":
        base_loss = transformer.train_loss_fn(cfg)

        def loss(merged, batch):
            return base_loss(merge_lora(merged["base"], merged["lora"]),
                             batch)
    elif mode == "prefix":
        def loss(merged, batch):
            logits, aux = _forward_with_prefix(cfg, merged["base"],
                                               merged["prefix"], batch)
            return transformer.lm_loss(cfg, logits, batch["labels"],
                                       batch.get("loss_mask"), aux)
    else:
        raise ValueError(f"unknown peft mode {mode!r}; available: {PEFT_MODES}")
    return loss


def peft_selection(mode: str):
    """The ``repro.select`` selection matching a ``peft_params`` merged tree
    (perturb only the ``mode`` subtree)."""
    from repro.select import peft as _peft_selection
    return _peft_selection(mode)
