"""Shared model building blocks: norms, activations, RoPE, initializers,
and the activation-sharding hook used by the distributed layer.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

# --------------------------------------------------------------------------- #
# Activation-sharding context: models call shard_hint(x, logical_name) at key
# points; the distributed layer installs a resolver mapping logical names to
# PartitionSpecs.  Outside any mesh/resolver this is the identity, so model
# code never imports mesh machinery.
# --------------------------------------------------------------------------- #
_tls = threading.local()


def set_shard_resolver(fn: Optional[Callable[[str], Optional[object]]]) -> None:
    _tls.resolver = fn


@contextlib.contextmanager
def shard_resolver(fn):
    prev = getattr(_tls, "resolver", None)
    _tls.resolver = fn
    try:
        yield
    finally:
        _tls.resolver = prev


def shard_hint(x: jnp.ndarray, logical: str) -> jnp.ndarray:
    """Annotate an activation with a logical sharding name.  The resolver
    (installed by repro.distributed) maps (logical, shape) -> PartitionSpec,
    checking divisibility; identity when no resolver is installed."""
    fn = getattr(_tls, "resolver", None)
    if fn is None:
        return x
    spec = fn(logical, x.shape)
    if spec is None:
        return x
    return jax.lax.with_sharding_constraint(x, spec)


# --------------------------------------------------------------------------- #
# Norms
# --------------------------------------------------------------------------- #
def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
              eps: float = 1e-5) -> jnp.ndarray:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.mean((x32 - mu) ** 2, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def apply_norm(cfg, x: jnp.ndarray, p: dict) -> jnp.ndarray:
    if cfg.norm == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def norm_params(cfg, d: int, dtype) -> dict:
    if cfg.norm == "rmsnorm":
        return {"scale": jnp.zeros((d,), dtype)}
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


# --------------------------------------------------------------------------- #
# Activations
# --------------------------------------------------------------------------- #
def activation(name: str, x: jnp.ndarray) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu":
        return jax.nn.relu(x)
    if name == "sq_relu":           # Nemotron-4: squared ReLU
        r = jax.nn.relu(x)
        return r * r
    raise ValueError(f"unknown activation {name!r}")


# --------------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------------- #
def rope_cos_sin(positions: jnp.ndarray, head_dim: int, theta: float,
                 dtype=jnp.float32) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) int -> cos/sin (..., head_dim//2)."""
    half = head_dim // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., S, H, hd); cos/sin (..., S, hd//2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def sinusoidal_embedding(seq: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    """Whisper-style absolute sinusoidal positions (S, d)."""
    pos = np.arange(seq)[:, None]
    dim = np.arange(d // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * dim / d)
    emb = np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)
    return jnp.asarray(emb, dtype)


def sinusoidal_at(positions: jnp.ndarray, d: int, dtype=jnp.float32) -> jnp.ndarray:
    """Sinusoidal positional rows for arbitrary (possibly traced) positions:
    positions (S,) -> (S, d).  Used when RoPE is disabled (OPT / RoBERTa /
    Whisper-decoder absolute-position proxies), including decode steps."""
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = positions.astype(jnp.float32)[:, None] / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# --------------------------------------------------------------------------- #
# Initializers
# --------------------------------------------------------------------------- #
def dense_init(key: jax.Array, shape: tuple, dtype, fan_in: Optional[int] = None) -> jnp.ndarray:
    fan_in = fan_in or shape[0]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: tuple, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


class KeyGen:
    """Deterministic named key dispenser for param init."""

    def __init__(self, key: jax.Array):
        self._key = key
        self._n = 0

    def __call__(self) -> jax.Array:
        self._n += 1
        return jax.random.fold_in(self._key, self._n)
