"""Mixture-of-Experts FFN with GShard-style capacity-based top-k dispatch.

Tokens are grouped (``moe_group_size``) and each group dispatches its top-k
choices into per-expert capacity slots via one-hot einsums — the standard
XLA-friendly formulation (no dynamic shapes, shards cleanly: the ``experts``
dimension maps to the 'model'/'expert' mesh axis, giving expert parallelism
when divisible, and the dispatch einsums lower to all-to-alls under EP).

Capacity C = ceil(top_k · M / E · capacity_factor); overflow tokens are
dropped (standard GShard semantics), and an auxiliary load-balancing loss is
returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init, shard_hint


def expert_group_count(cfg) -> int:
    """Number of expert-wise selection groups for ``cfg`` (≥ 1).

    ``cfg.expert_groups`` in (0, 1) means the legacy single-leaf layout
    (``w1: (E, d, ff)`` …); G > 1 means ``moe_params`` splits the expert
    tensors into G "eg{j}" sub-leaves of E/G experts each so that
    ``select.moe_experts(G)`` can cycle perturbation over one group per step.
    """
    G = int(cfg.expert_groups or 0)
    if G <= 1:
        return 1
    if cfg.n_experts % G:
        raise ValueError(
            f"expert_groups={G} does not divide n_experts={cfg.n_experts}; "
            "expert-wise selection needs equal-sized groups")
    return G


def _expert_leaves(cfg, kg, dtype, n_exp: int) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    p = {
        "w1": dense_init(kg(), (n_exp, d, ff), dtype, fan_in=d),
        "w2": dense_init(kg(), (n_exp, ff, d), dtype, fan_in=ff),
    }
    if cfg.gated_ffn:
        p["w3"] = dense_init(kg(), (n_exp, d, ff), dtype, fan_in=d)
    return p


def moe_params(cfg, kg, dtype) -> dict:
    d, E = cfg.d_model, cfg.n_experts
    G = expert_group_count(cfg)
    p = {"router": dense_init(kg(), (d, E), dtype)}
    if G == 1:
        p.update(_expert_leaves(cfg, kg, dtype, E))
    else:
        # grouped layout: experts [j·E/G, (j+1)·E/G) live in leaf "eg{j}" —
        # routing semantics are identical (groups concatenate back to E in
        # moe_ffn); only the LEAF STRUCTURE changes, which is what lets the
        # selection layer freeze/perturb one group at a time.
        for j in range(G):
            p[f"eg{j}"] = _expert_leaves(cfg, kg, dtype, E // G)
    return p


def _stacked_expert_weights(cfg, p: dict):
    """(w1, w2, w3-or-None) with experts stacked to (E, ...) regardless of
    whether ``p`` uses the legacy single-leaf or the grouped "eg{j}" layout."""
    if "w1" in p:
        return p["w1"], p["w2"], p.get("w3")
    G = expert_group_count(cfg)
    groups = [p[f"eg{j}"] for j in range(G)]
    w1 = jnp.concatenate([g["w1"] for g in groups], axis=0)
    w2 = jnp.concatenate([g["w2"] for g in groups], axis=0)
    w3 = (jnp.concatenate([g["w3"] for g in groups], axis=0)
          if cfg.gated_ffn else None)
    return w1, w2, w3


def _capacity(cfg, group_tokens: int) -> int:
    c = int(cfg.top_k * group_tokens / cfg.n_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)     # 8-aligned for TPU lanes


def moe_ffn(cfg, p: dict, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    M = min(cfg.moe_group_size, S)
    assert (B * S) % M == 0, f"tokens {B*S} not divisible by group {M}"
    G = (B * S) // M
    C = _capacity(cfg, M)

    xg = x.reshape(G, M, d)
    logits = (xg @ p["router"]).astype(jnp.float32)          # (G,M,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (G,M,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize top-k

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,M,K,E)
    # priority: k-th choices ordered by (k, token); cumulative count per expert
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * M, E)  # (G, K*M, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat)         # (G, K*M, E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1)              # (G, K*M)
    keep = pos < C
    pos = pos.reshape(G, K, M).transpose(0, 2, 1)             # (G,M,K)
    keep = keep.reshape(G, K, M).transpose(0, 2, 1)           # (G,M,K)

    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    cap_onehot = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    # dispatch (G,M,E,C) / combine weights
    dispatch = jnp.einsum("gmke,gmkc->gmec", onehot, cap_onehot)
    combine = jnp.einsum("gmk,gmke,gmkc->gmec", gate_vals, onehot, cap_onehot)

    cdtype = x.dtype
    expert_in = jnp.einsum("gmec,gmd->egcd", dispatch.astype(cdtype), xg)
    expert_in = shard_hint(expert_in, "act_experts")

    w1, w2, w3 = _stacked_expert_weights(cfg, p)
    h = jnp.einsum("egcd,edf->egcf", expert_in, w1)
    if cfg.gated_ffn:
        h = activation(cfg.activation, h) * jnp.einsum(
            "egcd,edf->egcf", expert_in, w3)
    else:
        h = activation(cfg.activation, h)
    expert_out = jnp.einsum("egcf,efd->egcd", h, w2)
    out = jnp.einsum("gmec,egcd->gmd", combine.astype(cdtype), expert_out)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                        # mean router prob
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))      # fraction routed
    aux = E * jnp.sum(me * ce)

    return out.reshape(B, S, d), aux
