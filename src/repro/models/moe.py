"""Mixture-of-Experts FFN with GShard-style capacity-based top-k dispatch.

Tokens are grouped (``moe_group_size``) and each group dispatches its top-k
choices into per-expert capacity slots via one-hot einsums — the standard
XLA-friendly formulation (no dynamic shapes, shards cleanly: the ``experts``
dimension maps to the 'model'/'expert' mesh axis, giving expert parallelism
when divisible, and the dispatch einsums lower to all-to-alls under EP).

Capacity C = ceil(top_k · M / E · capacity_factor); overflow tokens are
dropped (standard GShard semantics), and an auxiliary load-balancing loss is
returned for training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import activation, dense_init, shard_hint


def moe_params(cfg, kg, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(kg(), (d, E), dtype),
        "w1": dense_init(kg(), (E, d, ff), dtype, fan_in=d),
        "w2": dense_init(kg(), (E, ff, d), dtype, fan_in=ff),
    }
    if cfg.gated_ffn:
        p["w3"] = dense_init(kg(), (E, d, ff), dtype, fan_in=d)
    return p


def _capacity(cfg, group_tokens: int) -> int:
    c = int(cfg.top_k * group_tokens / cfg.n_experts * cfg.capacity_factor)
    return max(8, ((c + 7) // 8) * 8)     # 8-aligned for TPU lanes


def moe_ffn(cfg, p: dict, x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x (B,S,d) -> (out (B,S,d), aux_loss scalar)."""
    B, S, d = x.shape
    E, K = cfg.n_experts, cfg.top_k
    M = min(cfg.moe_group_size, S)
    assert (B * S) % M == 0, f"tokens {B*S} not divisible by group {M}"
    G = (B * S) // M
    C = _capacity(cfg, M)

    xg = x.reshape(G, M, d)
    logits = (xg @ p["router"]).astype(jnp.float32)          # (G,M,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (G,M,K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)    # renormalize top-k

    # position of each (token, k) inside its expert's capacity buffer
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,M,K,E)
    # priority: k-th choices ordered by (k, token); cumulative count per expert
    flat = onehot.transpose(0, 2, 1, 3).reshape(G, K * M, E)  # (G, K*M, E)
    pos_in_expert = (jnp.cumsum(flat, axis=1) - flat)         # (G, K*M, E)
    pos = jnp.sum(flat * pos_in_expert, axis=-1)              # (G, K*M)
    keep = pos < C
    pos = pos.reshape(G, K, M).transpose(0, 2, 1)             # (G,M,K)
    keep = keep.reshape(G, K, M).transpose(0, 2, 1)           # (G,M,K)

    gate_vals = gate_vals * keep.astype(gate_vals.dtype)
    cap_onehot = jax.nn.one_hot(pos, C, dtype=jnp.float32) * keep[..., None]
    # dispatch (G,M,E,C) / combine weights
    dispatch = jnp.einsum("gmke,gmkc->gmec", onehot, cap_onehot)
    combine = jnp.einsum("gmk,gmke,gmkc->gmec", gate_vals, onehot, cap_onehot)

    cdtype = x.dtype
    expert_in = jnp.einsum("gmec,gmd->egcd", dispatch.astype(cdtype), xg)
    expert_in = shard_hint(expert_in, "act_experts")

    h = jnp.einsum("egcd,edf->egcf", expert_in, p["w1"])
    if cfg.gated_ffn:
        h = activation(cfg.activation, h) * jnp.einsum(
            "egcd,edf->egcf", expert_in, p["w3"])
    else:
        h = activation(cfg.activation, h)
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["w2"])
    out = jnp.einsum("gmec,egcd->gmd", combine.astype(cdtype), expert_out)

    # load-balance auxiliary loss (Switch-style)
    me = jnp.mean(probs, axis=(0, 1))                        # mean router prob
    ce = jnp.mean(jnp.sum(onehot, axis=2), axis=(0, 1))      # fraction routed
    aux = E * jnp.sum(me * ce)

    return out.reshape(B, S, d), aux
