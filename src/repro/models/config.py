"""Unified model configuration covering all assigned architecture families.

One dataclass describes dense GQA transformers, MoE, SSM (RWKV6), hybrid
(Hymba), and encoder-decoder (Whisper) models; ``family`` selects the forward
implementation in ``models/registry.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


def pad_to_multiple(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab_size: int
    n_kv_heads: int = 0              # 0 -> = n_heads (MHA)
    head_dim: int = 0                # 0 -> d_model // n_heads
    activation: str = "silu"         # silu | gelu | sq_relu | relu
    gated_ffn: bool = True           # SwiGLU-style (w1*act(w3))·w2
    qkv_bias: bool = False
    causal: bool = True              # False -> bidirectional (masked LM)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    rope_theta: float = 10000.0
    use_rope: bool = True
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_group_size: int = 512        # GShard-style dispatch group length
    capacity_factor: float = 1.25
    # Grouped expert layout for expert-wise ZO selection: when > 1 the expert
    # tensors are split into ``expert_groups`` separate leaves ("eg0".."egG-1",
    # n_experts/G experts each) so ``select.moe_experts(G)`` can cycle the
    # perturbation over one group per step at LEAF granularity (sub-leaf
    # selection is a deferred follow-up).  0/1 keep the legacy stacked layout
    # bitwise-unchanged.
    expert_groups: int = 0

    # attention extent
    sliding_window: int = 0          # 0 = global causal

    # SSM / hybrid (Hymba parallel heads; RWKV6)
    ssm_state: int = 0
    ssm_heads: int = 0               # Hymba: number of parallel mamba heads
    scan_chunk: int = 32             # chunk length for SSD/WKV matmul forms
    # forward mode for the recurrent families (fla-style dual-mode idiom):
    # "chunk" = chunked-matmul SSD/WKV form (MXU-native, the default);
    # "fused_recurrent" = exact per-token lax.scan recurrence (the oracle).
    # Parity between the two is test-enforced (tests/test_zoo_conformance.py).
    scan_mode: str = "chunk"

    # encoder-decoder (Whisper)
    encoder_layers: int = 0
    cross_attention: bool = False

    # modality frontend (STUB: precomputed embeddings via input_specs)
    frontend: str = "none"           # none | vision_stub | audio_stub

    max_seq: int = 8192
    dtype: str = "float32"
    remat: bool = False              # only relevant for backprop baselines
    scan_layers: bool = True
    attention_impl: str = "xla"      # xla | chunked | pallas_flash
    attention_chunk: int = 1024      # kv-block for the chunked/flash paths
    attention_q_chunk: int = 0       # q-block tiling (0 = off)

    # vocab padding granularity: tp_size * 128 lanes (set by launcher)
    vocab_pad_multiple: int = 128

    # --- sharding strategy knobs (hillclimb levers; see EXPERIMENTS.md §Perf)
    # act_heads fallback when head count doesn't divide TP:
    #   "compiler" = leave to GSPMD (baseline; can pick contraction-dim
    #   sharding and all-reduce S×S scores), "batch" = constrain to
    #   batch-only sharding (replicated heads, no scores collective)
    shard_heads_fallback: str = "compiler"
    # shard the residual stream's sequence dim over 'model' between blocks
    # (Megatron-style sequence parallelism; turns row-parallel all-reduces
    # into reduce-scatter + all-gather pairs placed around the norms)
    sequence_parallel: bool = False
    # context-parallel attention: shard the QUERY sequence over 'model'
    # (keys/values batch-local); S×S score traffic per chip drops by TP
    attention_cp: bool = False

    # ---------------------------------------------------------------- #
    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads or self.n_heads

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return pad_to_multiple(self.vocab_size, self.vocab_pad_multiple)

    @property
    def param_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch run the long_500k cell? SSM: O(1) state.  Hybrid:
        SWA-bounded cache + O(1) SSM state.  Dense/MoE full attention: no."""
        return self.family == "ssm" or (self.family == "hybrid"
                                        and self.sliding_window > 0)

    def n_params(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.padded_vocab
        hd, H, KV = self.hd, self.n_heads, self.kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":  # RWKV6 block accounting
            tm = d * (H * hd) * 4 + d * (H * hd)        # r,k,v,g,o (o square)
            tm += 2 * (d * 64 + 64 * d)                  # decay/ddlerp loras (approx)
            cm = d * ff + ff * d
            return emb + self.n_layers * (tm + cm)
        att = d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d
        if self.qkv_bias:
            att += H * hd + 2 * KV * hd
        ffn = (3 if self.gated_ffn else 2) * d * ff
        if self.n_experts:
            ffn = ffn * self.n_experts + d * self.n_experts   # + router
        block = att + ffn
        if self.family == "hybrid":
            sh = self.ssm_heads * self.hd
            block += d * (2 * sh) + d * sh // 4 + 2 * sh * self.ssm_state + sh  # ssm projs
        layers = self.n_layers * block
        if self.family == "encdec":
            enc_block = d * (H * hd) * 2 + 2 * d * (KV * hd) + (2 if not self.gated_ffn else 3) * d * ff
            layers += self.encoder_layers * enc_block
            layers += self.n_layers * (d * (H * hd) + 2 * d * (KV * hd) + (H * hd) * d)  # cross-attn
        return emb + layers

    def n_active_params(self) -> int:
        """Active (per-token) parameters — MoE counts top_k of n_experts."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        dense_ffn = (3 if self.gated_ffn else 2) * d * ff
        total = self.n_params()
        return total - self.n_layers * dense_ffn * (self.n_experts - self.top_k)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (input-shape) cell."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str                 # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


TRAIN_4K = ShapeCell("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeCell("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeCell("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeCell("long_500k", 524288, 1, "decode")
ALL_CELLS = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def cells_for(cfg: ModelConfig) -> list[ShapeCell]:
    """The runnable shape cells for an architecture (skips per DESIGN.md §4)."""
    cells = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        cells.append(LONG_500K)
    return cells
