"""Attention: GQA / MHA, causal + sliding-window + cross, three impls:

* ``xla``      — straightforward einsum attention (materializes S×S scores);
                 reference semantics, fine for short sequences.
* ``chunked``  — lax.scan over KV blocks with an online softmax.  This is the
                 flash-attention *algorithm* expressed at the XLA level: O(S)
                 live memory instead of O(S²), compiles on every backend, and
                 is the memory-term hillclimb lever for the 32 K cells.
* ``pallas_flash`` — the Pallas TPU kernel (repro.kernels.flash_attention);
                 numerically identical to ``chunked``; validated in interpret
                 mode (kernel tests), selectable for real-TPU runs.

KV caches are per-layer dicts ``{"k": (B,S,KV,hd), "v": (B,S,KV,hd)}`` stacked
over layers by the model.  Decode writes at ``cache_pos`` via
dynamic_update_slice and attends over the full (mask-limited) cache.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, dense_init, rope_cos_sin, shard_hint

NEG_INF = -1e30


# --------------------------------------------------------------------------- #
# Params
# --------------------------------------------------------------------------- #
def attention_params(cfg, kg, dtype, cross: bool = False) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    p = {
        "wq": dense_init(kg(), (d, H * hd), dtype),
        "wk": dense_init(kg(), (d, KV * hd), dtype),
        "wv": dense_init(kg(), (d, KV * hd), dtype),
        "wo": dense_init(kg(), (H * hd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    return p


def project_qkv(cfg, p: dict, xq: jnp.ndarray, xkv: jnp.ndarray):
    """xq (B,Sq,d) -> q (B,Sq,H,hd);  xkv (B,Skv,d) -> k,v (B,Skv,KV,hd)."""
    H, KV, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = xq @ p["wq"]
    k = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if "bq" in p:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    B, Sq = xq.shape[:2]
    Skv = xkv.shape[1]
    q = q.reshape(B, Sq, H, hd)
    k = k.reshape(B, Skv, KV, hd)
    v = v.reshape(B, Skv, KV, hd)
    return q, k, v


# --------------------------------------------------------------------------- #
# Core attend (shared mask logic)
# --------------------------------------------------------------------------- #
def _mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, causal: bool,
          window: int, kv_len: Optional[jnp.ndarray]) -> jnp.ndarray:
    """Boolean mask.  window counts *inclusive* lookback tokens.
    k_pos == −1 marks invalid (unwritten ring-buffer) slots;
    k_pos == −2 marks prefix-tuning slots (always attendable).

    Shapes: q_pos (Q,) or (B,Q); k_pos (K,) or (B,K).  Result (Q,K) in the
    shared case, (B,Q,K) when either side is per-batch (continuous-batching
    serving uses per-slot positions)."""
    if q_pos.ndim == 1 and k_pos.ndim == 1:
        qp, kp = q_pos[:, None], k_pos[None, :]
    else:
        qp = (q_pos if q_pos.ndim == 2 else q_pos[None])[:, :, None]
        kp = (k_pos if k_pos.ndim == 2 else k_pos[None])[:, None, :]
    m = kp >= 0
    if causal:
        m &= kp <= qp
    if window > 0:
        m &= kp > qp - window
    if kv_len is not None:
        m &= kp < kv_len
    m |= (kp == -2)
    return m


def attend_xla(q, k, v, *, q_pos, k_pos, causal=True, window=0, kv_len=None,
               scale=None):
    """q (B,Q,H,hd), k/v (B,K,KV,hd) -> (B,Q,H,hd).  GQA via head grouping."""
    B, Q, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else (hd ** -0.5)
    qg = q.reshape(B, Q, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32) * scale
    m = _mask(q_pos, k_pos, causal, window, kv_len)
    m = m[None, None, None] if m.ndim == 2 else m[:, None, None]
    scores = jnp.where(m, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Q, H, hd)


def attend_chunked(q, k, v, *, q_pos, k_pos, causal=True, window=0,
                   kv_len=None, scale=None, chunk=1024, q_chunk=0,
                   arange_layout=False, _q_span=None):
    """Flash-style online-softmax attention, tiled over KV (and optionally Q)
    blocks at the XLA level.

    Live memory per block is O(B·H·q_block·kv_block) instead of O(B·H·Q·S).
    KV blocks are statically UNROLLED: (a) XLA frees each block's
    temporaries, keeping the flash memory profile, and (b) HLO cost analysis
    counts every block (while-loop bodies are counted once — see
    EXPERIMENTS.md §Dry-run methodology).

    ``arange_layout=True`` asserts q_pos == k_pos == arange(S) (the
    train/prefill self-attention layout): causal Q-blocks then statically
    skip KV blocks entirely in their future, and SWA additionally skips
    blocks beyond the window — the flash kernel's block-sparsity, in XLA.
    """
    B, Q, H, hd = q.shape
    if q_chunk and Q > q_chunk:
        outs = []
        for qs in range(0, Q, q_chunk):
            qe = min(qs + q_chunk, Q)
            outs.append(attend_chunked(
                q[:, qs:qe], k, v, q_pos=q_pos[qs:qe], k_pos=k_pos,
                causal=causal, window=window, kv_len=kv_len, scale=scale,
                chunk=chunk, q_chunk=0, arange_layout=arange_layout,
                _q_span=(qs, qe) if arange_layout else None))
        return jnp.concatenate(outs, axis=1)
    if arange_layout and _q_span is None:
        _q_span = (0, Q)

    S = k.shape[1]
    KV = k.shape[2]
    G = H // KV
    scale = scale if scale is not None else (hd ** -0.5)
    chunk = min(chunk, S)
    n_chunks = (S + chunk - 1) // chunk
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        pad_spec = ((0, pad),) if k_pos.ndim == 1 else ((0, 0), (0, pad))
        k_pos = jnp.pad(k_pos, pad_spec, constant_values=-1)
    qg = (q.reshape(B, Q, KV, G, hd).astype(jnp.float32) * scale)

    m0 = jnp.full((B, KV, G, Q), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, KV, G, Q), jnp.float32)
    a0 = jnp.zeros((B, KV, G, Q, hd), jnp.float32)
    m_prev, l_prev, acc = m0, l0, a0
    for c in range(n_chunks):
        if _q_span is not None:
            k_lo, k_hi = c * chunk, min((c + 1) * chunk, S) - 1
            if causal and k_lo > _q_span[1] - 1:
                continue            # block entirely in the future
            if window > 0 and k_hi <= _q_span[0] - window:
                continue            # block entirely beyond the SWA window
        kc = k[:, c * chunk:(c + 1) * chunk]
        vc = v[:, c * chunk:(c + 1) * chunk]
        kpc = (k_pos[c * chunk:(c + 1) * chunk] if k_pos.ndim == 1
               else k_pos[:, c * chunk:(c + 1) * chunk])
        s = jnp.einsum("bqkgh,bskh->bkgqs", qg, kc.astype(jnp.float32))
        msk = _mask(q_pos, kpc, causal, window, kv_len)
        msk = msk[None, None, None] if msk.ndim == 2 else msk[:, None, None]
        s = jnp.where(msk, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_cur[..., None])
        corr = jnp.exp(m_prev - m_cur)
        l_new = l_prev * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkgqs,bskh->bkgqh", p, vc.astype(jnp.float32))
        m_prev, l_prev = m_cur, l_new
    out = acc / jnp.maximum(l_prev, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(B, Q, H, hd).astype(q.dtype)


def attend(cfg, q, k, v, *, arange_layout=False, **kw):
    impl = cfg.attention_impl
    if impl == "chunked":
        q_chunk = getattr(cfg, "attention_q_chunk", 0)
        return attend_chunked(q, k, v, chunk=cfg.attention_chunk,
                              q_chunk=q_chunk, arange_layout=arange_layout,
                              **kw)
    if impl == "pallas_flash":
        # TPU kernel path: only causal self-attention without caches routes to
        # the kernel; other cases fall back to chunked (same numerics).
        from repro.kernels.flash_attention import ops as flash_ops
        if kw.get("causal", True) and kw.get("kv_len") is None and q.shape[1] == k.shape[1]:
            return flash_ops.flash_attention(
                q, k, v, window=kw.get("window", 0),
                block_q=min(cfg.attention_chunk, 512),
                block_k=min(cfg.attention_chunk, 512))
        return attend_chunked(q, k, v, chunk=cfg.attention_chunk,
                              arange_layout=arange_layout, **kw)
    return attend_xla(q, k, v, **kw)


# --------------------------------------------------------------------------- #
# Block-level entry points
# --------------------------------------------------------------------------- #
def init_cache(cfg, batch: int, max_len: int, dtype,
               layers: Optional[int] = None, per_slot: bool = False) -> dict:
    """Stacked-over-layers KV cache with a slot-position array.

    ``capacity`` is ``min(max_len, sliding_window)`` for SWA models: the cache
    is a *ring buffer* indexed by absolute-position mod capacity, and ``pos``
    records which absolute position each slot currently holds (−1 = empty).
    This is what makes sliding-window archs (Hymba, Mixtral) O(window) in
    decode regardless of context length.

    ``per_slot=True`` gives every batch row its own position array (shape
    (L, B, cap)) — required by the continuous-batching serving engine where
    requests at different positions share one decode batch.
    """
    L = layers if layers is not None else cfg.n_layers
    KV, hd = cfg.kv_heads, cfg.hd
    cap = max_len if cfg.sliding_window == 0 else min(max_len, cfg.sliding_window)
    shape = (L, batch, cap, KV, hd)
    pos_shape = (L, batch, cap) if per_slot else (L, cap)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.full(pos_shape, -1, jnp.int32)}


def self_attention(cfg, p: dict, x: jnp.ndarray, positions: jnp.ndarray,
                   cache: Optional[dict] = None,
                   cache_pos: Optional[jnp.ndarray] = None):
    """Causal (optionally sliding-window) self attention.

    Training: ``cache`` is None.
    Prefill:  ``cache`` is an empty per-layer cache; K/V written at [0, S).
    Decode:   x is (B,1,d); ``cache_pos`` is the absolute position — a scalar
              (lockstep batch; ``positions`` is (1,)) or a (B,) vector
              (continuous batching; ``positions`` is (B,1) and the cache's
              ``pos`` is (B,cap)).  Writes land at ``cache_pos % capacity``.
    Returns (out (B,S,d), new_cache | None).
    """
    B, S, _ = x.shape
    q, k, v = project_qkv(cfg, p, x, x)
    if cfg.use_rope:
        cos, sin = rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = shard_hint(q, "act_heads")
    k = shard_hint(k, "act_kv_heads")

    new_cache = None
    if cache is not None and cache_pos is not None and jnp.ndim(cache_pos) == 1:
        # per-slot decode (serving engine): one-hot scatter into each row's
        # ring slot; per-batch position masks keep rows independent.
        cap = cache["k"].shape[1]
        idx = jax.lax.rem(cache_pos, cap)                        # (B,)
        hot = idx[:, None] == jnp.arange(cap, dtype=jnp.int32)[None]  # (B,cap)
        ck = jnp.where(hot[..., None, None], k, cache["k"])
        cv = jnp.where(hot[..., None, None], v, cache["v"])
        cpos = jnp.where(hot, cache_pos[:, None].astype(jnp.int32),
                         cache["pos"])
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = attend(cfg, q, ck, cv, q_pos=positions, k_pos=cpos,
                     causal=cfg.causal, window=cfg.sliding_window)
    elif cache is not None and cache_pos is not None:
        cap = cache["k"].shape[1]
        idx = jax.lax.rem(cache_pos, cap)
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        cpos = jax.lax.dynamic_update_slice_in_dim(
            cache["pos"], positions.astype(jnp.int32), idx, axis=0)
        new_cache = {"k": ck, "v": cv, "pos": cpos}
        out = attend(cfg, q, ck, cv, q_pos=positions, k_pos=cpos, causal=cfg.causal,
                     window=cfg.sliding_window)
    else:
        out = attend(cfg, q, k, v, q_pos=positions, k_pos=positions,
                     causal=cfg.causal, window=cfg.sliding_window,
                     arange_layout=True)
        if cache is not None:
            # Prefill into a fresh cache.  Slot for absolute position p is
            # p % capacity (ring invariant shared with the decode path): keep
            # the last ``cap`` tokens and roll them into their ring slots.
            cap = cache["k"].shape[1]
            S_keep = min(S, cap)
            shift = S % cap if S > cap else 0
            kk = jnp.roll(k[:, S - S_keep:], shift, axis=1)
            vv = jnp.roll(v[:, S - S_keep:], shift, axis=1)
            pp = jnp.roll(positions[S - S_keep:].astype(jnp.int32), shift)
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], kk, 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], vv, 0, axis=1)
            cpos = jax.lax.dynamic_update_slice_in_dim(cache["pos"], pp, 0, axis=0)
            new_cache = {"k": ck, "v": cv, "pos": cpos}

    out = out.reshape(B, S, cfg.n_heads * cfg.hd)
    return out @ p["wo"], new_cache


def cross_attention(cfg, p: dict, x: jnp.ndarray, enc_k: jnp.ndarray,
                    enc_v: jnp.ndarray) -> jnp.ndarray:
    """Decoder->encoder attention (Whisper).  enc_k/v (B,Senc,KV,hd) are
    precomputed from the encoder output once per sequence."""
    B, S, _ = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    S_enc = enc_k.shape[1]
    out = attend(cfg, q, enc_k, enc_v,
                 q_pos=jnp.arange(S, dtype=jnp.int32),
                 k_pos=jnp.arange(S_enc, dtype=jnp.int32),
                 causal=False, window=0)
    out = out.reshape(B, S, H * hd)
    return out @ p["wo"]


def precompute_cross_kv(cfg, p: dict, enc_out: jnp.ndarray):
    B, S, _ = enc_out.shape
    KV, hd = cfg.kv_heads, cfg.hd
    k = (enc_out @ p["wk"]).reshape(B, S, KV, hd)
    v = (enc_out @ p["wv"]).reshape(B, S, KV, hd)
    return k, v
