"""Modality frontend STUBS (per the assignment: ``[audio]``/``[vlm]`` entries
specify the transformer BACKBONE only; ``input_specs()`` provides precomputed
frame/patch embeddings).

The stubs document exactly what a production frontend would compute and
provide deterministic synthetic embeddings for smoke tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def vision_stub_embeddings(key: jax.Array, batch: int, seq: int, d_model: int,
                           dtype=jnp.float32) -> jnp.ndarray:
    """Phi-3-vision: a CLIP-L/14 vision tower + projector would map image
    crops to patch embeddings that are spliced into the token stream.  The
    stub emits the post-projector sequence (text+patch embeddings merged)."""
    return jax.random.normal(key, (batch, seq, d_model), dtype) * 0.02


def audio_stub_embeddings(key: jax.Array, batch: int, frames: int, d_model: int,
                          dtype=jnp.float32) -> jnp.ndarray:
    """Whisper: two conv1d layers (stride 1 and 2) over 128-bin log-mel
    spectrograms produce frame embeddings at 50 Hz.  The stub emits the
    post-conv frame sequence directly."""
    return jax.random.normal(key, (batch, frames, d_model), dtype) * 0.02
