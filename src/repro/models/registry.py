"""Architecture registry: uniform init / train-loss / prefill / decode entry
points per family, plus ``input_specs`` (ShapeDtypeStruct stand-ins, no
allocation) for the multi-pod dry-run.

Step-function signatures (what dryrun.py lowers):
  train   loss_fn(params, batch)                        — inside a MeZO step
  prefill prefill_fn(params, batch)   -> (logits, cache-or-state)
  decode  decode_fn(params, batch)    -> (logits, cache-or-state)
          where batch carries {"token", "cache"/"state", "cache_pos", …}
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import encdec, rwkv6, ssm as ssm_lib, transformer
from repro.models.config import ModelConfig, ShapeCell

_REGISTRY: dict[str, "Arch"] = {}


@dataclasses.dataclass(frozen=True)
class Arch:
    """A registered architecture: production config + reduced smoke config."""
    arch_id: str
    cfg: ModelConfig
    smoke_cfg: ModelConfig
    notes: str = ""


def register(arch_id: str, cfg: ModelConfig, smoke_cfg: ModelConfig,
             notes: str = "") -> Arch:
    arch = Arch(arch_id, cfg, smoke_cfg, notes)
    _REGISTRY[arch_id] = arch
    return arch


def get(arch_id: str) -> Arch:
    if arch_id not in _REGISTRY:
        import repro.configs  # noqa: F401  (registers everything)
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, Arch]:
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


# --------------------------------------------------------------------------- #
class Bundle:
    """Callable surface for one ModelConfig."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- init ---------------------------------------------------------- #
    def init(self, key: jax.Array) -> dict:
        if self.cfg.family == "ssm":
            return rwkv6.init_params(self.cfg, key)
        if self.cfg.family == "encdec":
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    def param_shapes(self) -> Any:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ---- training loss (the function MeZO evaluates twice) -------------- #
    def loss_fn(self) -> Callable:
        cfg = self.cfg
        if cfg.family == "ssm":
            def loss(params, batch):
                logits, _ = rwkv6.forward(cfg, params, tokens=batch["tokens"])
                return transformer.lm_loss(cfg, logits, batch["labels"],
                                           batch.get("loss_mask"))
            return loss
        if cfg.family == "encdec":
            def loss(params, batch):
                logits = encdec.forward_train(cfg, params, batch["frames"],
                                              batch["tokens"])
                return transformer.lm_loss(cfg, logits, batch["labels"],
                                           batch.get("loss_mask"))
            return loss
        return transformer.train_loss_fn(cfg)

    # ---- serving ---------------------------------------------------------- #
    def prefill_fn(self) -> Callable:
        cfg = self.cfg

        def prefill(params, batch):
            if cfg.family == "ssm":
                logits, state = rwkv6.forward(cfg, params, tokens=batch["tokens"],
                                              state=rwkv6.init_rwkv_state(
                                                  cfg, batch["tokens"].shape[0]))
                return logits[:, -1:], state
            if cfg.family == "encdec":
                enc_out = encdec.encode(cfg, params, batch["frames"])
                cross_kv = encdec.precompute_cross_kv(cfg, params, enc_out)
                B = batch["frames"].shape[0]
                cache = attn_lib.init_cache(cfg, B, cfg.max_seq, cfg.param_dtype)
                r = encdec.decode(cfg, params, batch["tokens"], cross_kv,
                                  cache=cache, cache_pos=jnp.int32(0))
                return r.logits[:, -1:], (r.cache, cross_kv)
            tokens = batch.get("tokens")
            embeds = batch.get("embeds")
            B = (tokens if tokens is not None else embeds).shape[0]
            S = (tokens if tokens is not None else embeds).shape[1]
            cache = attn_lib.init_cache(cfg, B, max(S, cfg.max_seq), cfg.param_dtype)
            ssm_state = (ssm_lib.init_ssm_state(cfg, B)
                         if cfg.family == "hybrid" else None)
            # cache_pos=None -> prefill-write path (ring-rolled for SWA)
            r = transformer.forward(cfg, params, tokens=tokens, embeds=embeds,
                                    cache=cache, cache_pos=None,
                                    ssm_state=ssm_state)
            if cfg.family == "hybrid":
                return r.logits[:, -1:], (r.cache, r.ssm_state)
            return r.logits[:, -1:], r.cache

        return prefill

    def decode_fn(self) -> Callable:
        cfg = self.cfg

        def decode(params, batch):
            pos = batch["cache_pos"]
            if jnp.ndim(pos) == 1:          # per-slot (continuous batching)
                positions = pos[:, None].astype(jnp.int32)          # (B,1)
            else:                            # lockstep batch
                positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
            if cfg.family == "ssm":
                logits, state = rwkv6.forward(cfg, params, tokens=batch["token"],
                                              state=batch["state"])
                return logits, state
            if cfg.family == "encdec":
                r = encdec.decode(cfg, params, batch["token"], batch["cross_kv"],
                                  positions=positions, cache=batch["cache"],
                                  cache_pos=pos)
                return r.logits, r.cache
            ssm_state = batch.get("state") if cfg.family == "hybrid" else None
            r = transformer.forward(cfg, params, tokens=batch.get("token"),
                                    embeds=batch.get("embed"),
                                    positions=positions, cache=batch["cache"],
                                    cache_pos=pos, ssm_state=ssm_state)
            if cfg.family == "hybrid":
                return r.logits, (r.cache, r.ssm_state)
            return r.logits, r.cache

        return decode

    # ---- dry-run input specs (ShapeDtypeStruct; never allocates) --------- #
    def input_specs(self, cell: ShapeCell) -> dict:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32, f32 = jnp.int32, jnp.float32
        dt = cfg.param_dtype
        sds = jax.ShapeDtypeStruct

        def tok(shape):
            return sds(shape, i32)

        if cell.kind == "train":
            if cfg.family == "encdec":
                return {"frames": sds((B, S, cfg.d_model), dt),
                        "tokens": tok((B, S)), "labels": tok((B, S)),
                        "loss_mask": sds((B, S), f32)}
            if cfg.frontend == "vision_stub":
                return {"embeds": sds((B, S, cfg.d_model), dt),
                        "labels": tok((B, S)), "loss_mask": sds((B, S), f32)}
            return {"tokens": tok((B, S)), "labels": tok((B, S)),
                    "loss_mask": sds((B, S), f32)}

        if cell.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": sds((B, S, cfg.d_model), dt),
                        "tokens": tok((B, 1))}
            if cfg.frontend == "vision_stub":
                return {"embeds": sds((B, S, cfg.d_model), dt)}
            return {"tokens": tok((B, S))}

        # decode: one new token against a seq_len-long context
        specs = {"token": tok((B, 1)), "cache_pos": sds((), i32)}
        if cfg.family == "ssm":
            st = jax.eval_shape(lambda: rwkv6.init_rwkv_state(cfg, B))
            specs["state"] = st
            del specs["cache_pos"]
            specs["cache_pos"] = sds((), i32)
            return specs
        cache = jax.eval_shape(
            lambda: attn_lib.init_cache(cfg, B, S, cfg.param_dtype))
        specs["cache"] = cache
        if cfg.family == "hybrid":
            specs["state"] = jax.eval_shape(
                lambda: ssm_lib.init_ssm_state(cfg, B))
        if cfg.family == "encdec":
            # realistic encoder extent for the decode cells (Whisper: 1500
            # frames ≈ 30 s audio); the 32 K/500 K axis is the decoder cache.
            s_enc = 1504
            KV, hd, L = cfg.kv_heads, cfg.hd, cfg.n_layers
            specs["cross_kv"] = {"k": sds((L, B, s_enc, KV, hd), dt),
                                 "v": sds((L, B, s_enc, KV, hd), dt)}
        return specs

    # ---- smoke-test batch (small, actual arrays) ------------------------- #
    def make_batch(self, key: jax.Array, batch: int, seq: int) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        out: dict = {}
        if cfg.family == "encdec":
            out["frames"] = jax.random.normal(k3, (batch, seq, cfg.d_model),
                                              cfg.param_dtype) * 0.02
            out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
        elif cfg.frontend == "vision_stub":
            out["embeds"] = jax.random.normal(k3, (batch, seq, cfg.d_model),
                                              cfg.param_dtype) * 0.02
        else:
            out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
        out["labels"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)
        out["loss_mask"] = jnp.ones((batch, seq), jnp.float32)
        return out


def bundle(cfg_or_arch) -> Bundle:
    cfg = cfg_or_arch.cfg if isinstance(cfg_or_arch, Arch) else cfg_or_arch
    return Bundle(cfg)
