"""Architecture registry: uniform init / train-loss / prefill / decode entry
points per family, plus ``input_specs`` (ShapeDtypeStruct stand-ins, no
allocation) for the multi-pod dry-run.

Families (``ModelConfig.family``) and their forward implementations:

  ``dense``   decoder-only transformer (GQA / MHA, optional vision frontend
              via precomputed ``embeds``) — models/transformer.py
  ``moe``     dense transformer whose FFN is a GShard capacity-based top-k
              mixture of experts — models/moe.py; supports the grouped
              ``cfg.expert_groups`` leaf layout for expert-wise ZO selection
  ``ssm``     RWKV6 "Finch" attention-free recurrence — models/rwkv6.py;
              dual forward modes ``cfg.scan_mode`` ∈ {"chunk",
              "fused_recurrent"}
  ``hybrid``  Hymba-style parallel attention + mamba-2 SSD heads —
              models/transformer.py + models/ssm.py
  ``encdec``  Whisper-style encoder-decoder with cross-attention —
              models/encdec.py

Step-function signatures (what dryrun.py lowers):
  train   loss_fn(params, batch)                        — inside a MeZO step
  prefill prefill_fn(params, batch)   -> (logits, cache-or-state)
  decode  decode_fn(params, batch)    -> (logits, cache-or-state)
          where batch carries {"token", "cache"/"state", "cache_pos", …}

The registry also provides the per-family ZO defaults consumed by
``launch/train --select auto`` and ``benchmarks/bench_quality.py``:

>>> from repro.models.config import ModelConfig
>>> moe_cfg = ModelConfig(name="m", family="moe", n_layers=2, d_model=64,
...                       n_heads=4, d_ff=96, vocab_size=256, n_experts=4,
...                       top_k=2, expert_groups=2)
>>> default_selection(moe_cfg)           # router frozen, 1 group per step
'moe_experts(2)'
>>> default_selection(moe_cfg.replace(family="dense", n_experts=0,
...                                   expert_groups=0))
'full'
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import nondiff
from repro.models import attention as attn_lib
from repro.models import encdec, rwkv6, ssm as ssm_lib, transformer
from repro.models.config import ModelConfig, ShapeCell

_REGISTRY: dict[str, "Arch"] = {}

#: Registry-selectable training objectives (``Bundle.loss_fn(objective=...)``):
#: "ce" is token cross-entropy; "accuracy" / "f1" are the paper §3.3
#: NON-DIFFERENTIABLE objectives (argmax-based, zero gradient a.e. — only ZO
#: optimizers make progress on them; core/nondiff.py).
OBJECTIVES = ("ce", "accuracy", "f1")

#: Representative registry arch per family — the ``--model-family`` quickstart
#: alias in launch/train and the per-family axis of bench_quality /
#: test_zoo_conformance.
FAMILY_ARCHS = {
    "dense": "qwen2-0.5b",
    "moe": "mixtral-8x7b",
    "ssm": "rwkv6-3b",
    "hybrid": "hymba-1.5b",
    "encdec": "whisper-large-v3",
}


def default_selection(cfg: ModelConfig) -> str:
    """Per-family default parameter-selection spec (`repro.select` syntax).

    MoE: ``moe_experts(G)`` — the router is frozen bitwise and expert group
    ``t % G`` is perturbed at step t (G = ``cfg.expert_groups``, 1 when the
    legacy stacked layout is in use), so per-step ZO cost scales with
    *active* expert parameters.  Every other family defaults to ``full``.
    """
    if cfg.n_experts:
        from repro.models.moe import expert_group_count
        return f"moe_experts({expert_group_count(cfg)})"
    return "full"


@dataclasses.dataclass(frozen=True)
class Arch:
    """A registered architecture: production config + reduced smoke config.

    ``cfg`` is the full-scale (paper/hf) shape; ``smoke_cfg`` is the
    CPU-runnable reduction (2 layers, d_model 64) used by tests and
    ``launch/train --smoke``.  Both carry the same ``family`` and therefore
    the same forward implementation and ZO defaults."""
    arch_id: str
    cfg: ModelConfig
    smoke_cfg: ModelConfig
    notes: str = ""

    def default_selection(self, smoke: bool = False) -> str:
        """Canonical selection spec for this arch (see ``default_selection``)."""
        return default_selection(self.smoke_cfg if smoke else self.cfg)


def register(arch_id: str, cfg: ModelConfig, smoke_cfg: ModelConfig,
             notes: str = "") -> Arch:
    """Register an architecture under ``arch_id`` (see repro/configs/*)."""
    arch = Arch(arch_id, cfg, smoke_cfg, notes)
    _REGISTRY[arch_id] = arch
    return arch


def get(arch_id: str) -> Arch:
    """Look up one registered arch by id (importing repro.configs on demand).

    >>> get("rwkv6-3b").cfg.family
    'ssm'
    """
    if arch_id not in _REGISTRY:
        import repro.configs  # noqa: F401  (registers everything)
    return _REGISTRY[arch_id]


def all_archs() -> dict[str, Arch]:
    """All registered archs, keyed by arch_id (10 assigned + 4 paper archs).

    >>> sorted({a.cfg.family for a in all_archs().values()})
    ['dense', 'encdec', 'hybrid', 'moe', 'ssm']
    """
    import repro.configs  # noqa: F401
    return dict(_REGISTRY)


def family_arch(family: str, smoke: bool = True) -> ModelConfig:
    """The representative config for an architecture family (see
    ``FAMILY_ARCHS``); ``smoke=True`` returns the CPU-scale reduction."""
    if family not in FAMILY_ARCHS:
        raise ValueError(f"unknown family {family!r}; "
                         f"available: {sorted(FAMILY_ARCHS)}")
    arch = get(FAMILY_ARCHS[family])
    return arch.smoke_cfg if smoke else arch.cfg


# --------------------------------------------------------------------------- #
class Bundle:
    """Callable surface for one ModelConfig: ``init`` / ``loss_fn`` /
    ``prefill_fn`` / ``decode_fn`` / ``input_specs`` / ``make_batch``, with
    the family dispatch hidden inside — every caller (train launcher, exec
    plans, dry-run, benches, conformance tests) sees one uniform surface."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ---- init ---------------------------------------------------------- #
    def init(self, key: jax.Array) -> dict:
        if self.cfg.family == "ssm":
            return rwkv6.init_params(self.cfg, key)
        if self.cfg.family == "encdec":
            return encdec.init_params(self.cfg, key)
        return transformer.init_params(self.cfg, key)

    def param_shapes(self) -> Any:
        return jax.eval_shape(self.init, jax.random.PRNGKey(0))

    # ---- ZO defaults ----------------------------------------------------- #
    def default_selection(self) -> str:
        """Per-family default ``repro.select`` spec (see module-level
        ``default_selection``); the value behind ``--select auto``."""
        return default_selection(self.cfg)

    # ---- training objectives -------------------------------------------- #
    def train_logits_fn(self) -> Callable:
        """(params, batch) -> teacher-forcing logits (B, S, padded_vocab) —
        the shared forward under every training objective."""
        cfg = self.cfg
        if cfg.family == "ssm":
            def logits_fn(params, batch):
                lg, _ = rwkv6.forward(cfg, params, tokens=batch["tokens"])
                return lg
        elif cfg.family == "encdec":
            def logits_fn(params, batch):
                return encdec.forward_train(cfg, params, batch["frames"],
                                            batch["tokens"])
        else:
            def logits_fn(params, batch):
                r = transformer.forward(cfg, params,
                                        tokens=batch.get("tokens"),
                                        embeds=batch.get("embeds"))
                return r.logits
        return logits_fn

    # ---- training loss (the function MeZO evaluates twice) -------------- #
    def loss_fn(self, objective: str = "ce") -> Callable:
        """(params, batch) -> scalar minimization objective.

        ``objective`` selects from ``OBJECTIVES``:

        * ``"ce"`` — masked token cross-entropy (+ the MoE aux loss where the
          family has one); the default, differentiable.
        * ``"accuracy"`` — ``-accuracy`` of argmax predictions over
          ``batch["labels"]`` (paper §3.3: zero gradient a.e.; only ZO
          optimizers make progress).  Logits are sliced to the true
          ``vocab_size`` so padded vocab columns can never win the argmax.
        * ``"f1"`` — ``-token_f1`` between per-position argmax predictions
          and labels (mask-respecting; the SQuAD metric at token level).
        """
        cfg = self.cfg
        if objective == "ce":
            if cfg.family == "ssm":
                def loss(params, batch):
                    logits, _ = rwkv6.forward(cfg, params,
                                              tokens=batch["tokens"])
                    return transformer.lm_loss(cfg, logits, batch["labels"],
                                               batch.get("loss_mask"))
                return loss
            if cfg.family == "encdec":
                def loss(params, batch):
                    logits = encdec.forward_train(cfg, params, batch["frames"],
                                                  batch["tokens"])
                    return transformer.lm_loss(cfg, logits, batch["labels"],
                                               batch.get("loss_mask"))
                return loss
            return transformer.train_loss_fn(cfg)
        if objective not in OBJECTIVES:
            raise ValueError(f"unknown objective {objective!r}; "
                             f"available: {OBJECTIVES}")
        logits_fn = self.train_logits_fn()
        V = cfg.vocab_size
        if objective == "accuracy":
            def loss(params, batch):
                logits = logits_fn(params, batch)[..., :V]
                return nondiff.negative_accuracy(logits, batch["labels"],
                                                 batch.get("loss_mask"))
            return loss

        def loss(params, batch):      # objective == "f1"
            logits = logits_fn(params, batch)[..., :V]
            pred = jnp.argmax(logits, axis=-1)
            gold = batch["labels"]
            mask = batch.get("loss_mask")
            if mask is not None:
                # token id space is [0, V); -1 marks padded-out positions so
                # legitimate id-0 tokens still count toward the F1 multiset
                keep = mask > 0
                pred = jnp.where(keep, pred, -1)
                gold = jnp.where(keep, gold, -1)
            return nondiff.negative_f1(pred, gold, pad_id=-1)
        return loss

    # ---- serving ---------------------------------------------------------- #
    def prefill_fn(self) -> Callable:
        cfg = self.cfg

        def prefill(params, batch):
            if cfg.family == "ssm":
                logits, state = rwkv6.forward(cfg, params, tokens=batch["tokens"],
                                              state=rwkv6.init_rwkv_state(
                                                  cfg, batch["tokens"].shape[0]))
                return logits[:, -1:], state
            if cfg.family == "encdec":
                enc_out = encdec.encode(cfg, params, batch["frames"])
                cross_kv = encdec.precompute_cross_kv(cfg, params, enc_out)
                B = batch["frames"].shape[0]
                cache = attn_lib.init_cache(cfg, B, cfg.max_seq, cfg.param_dtype)
                r = encdec.decode(cfg, params, batch["tokens"], cross_kv,
                                  cache=cache, cache_pos=jnp.int32(0))
                return r.logits[:, -1:], (r.cache, cross_kv)
            tokens = batch.get("tokens")
            embeds = batch.get("embeds")
            B = (tokens if tokens is not None else embeds).shape[0]
            S = (tokens if tokens is not None else embeds).shape[1]
            cache = attn_lib.init_cache(cfg, B, max(S, cfg.max_seq), cfg.param_dtype)
            ssm_state = (ssm_lib.init_ssm_state(cfg, B)
                         if cfg.family == "hybrid" else None)
            # cache_pos=None -> prefill-write path (ring-rolled for SWA)
            r = transformer.forward(cfg, params, tokens=tokens, embeds=embeds,
                                    cache=cache, cache_pos=None,
                                    ssm_state=ssm_state)
            if cfg.family == "hybrid":
                return r.logits[:, -1:], (r.cache, r.ssm_state)
            return r.logits[:, -1:], r.cache

        return prefill

    def chunk_prefill_fn(self) -> Callable:
        """Suffix prefill against a pre-populated per-request cache — the
        paged serving engine's batched-prefill primitive.

        ``(params, batch) -> (logits (B, S, V), cache)`` where batch carries

        * ``"tokens"``    (B, S)  right-padded suffix tokens;
        * ``"cache"``     stacked (L, B, cap, KV, hd) with per-request
                          ``"pos"`` (L, B, cap): rows [0, plen_b) hold request
                          b's already-computed prefix KV (pos = arange), the
                          rest are -1;
        * ``"cache_pos"`` (B,)    per-request prefix lengths.

        Each request runs at its own absolute positions ``plen_b +
        arange(S)`` and DUS-writes its suffix KV at ``[plen_b, plen_b+S)`` —
        the scalar-``cache_pos`` branch of ``self_attention``, vmapped over
        requests (cache axis 1, matching the serving slot axis).  Rows past
        the real suffix hold junk KV at future positions; the causal mask
        excludes them from every real query, and the engine never copies them
        out.  Only cache families with absolute-position rows support this
        (no SWA ring, no recurrent state): dense/moe with sliding_window=0.
        """
        cfg = self.cfg
        if cfg.family not in ("dense", "moe") or cfg.sliding_window != 0:
            raise NotImplementedError(
                f"chunk_prefill_fn: family={cfg.family!r} with "
                f"sliding_window={cfg.sliding_window} has no "
                "absolute-position KV rows to resume from; the serving "
                "engine's legacy whole-prompt prefill handles it")

        def one(params, tokens, ck, cv, cpos, plen):
            S = tokens.shape[0]
            positions = plen + jnp.arange(S, dtype=jnp.int32)
            cache = {"k": ck[:, None], "v": cv[:, None], "pos": cpos}
            r = transformer.forward(cfg, params, tokens=tokens[None],
                                    positions=positions, cache=cache,
                                    cache_pos=plen)
            return r.logits[0], (r.cache["k"][:, 0], r.cache["v"][:, 0],
                                 r.cache["pos"])

        def chunk_prefill(params, batch):
            c = batch["cache"]
            logits, (ck, cv, cpos) = jax.vmap(
                one, in_axes=(None, 0, 1, 1, 1, 0),
                out_axes=(0, (1, 1, 1)))(
                params, batch["tokens"], c["k"], c["v"], c["pos"],
                batch["cache_pos"])
            return logits, {"k": ck, "v": cv, "pos": cpos}

        return chunk_prefill

    def decode_fn(self) -> Callable:
        cfg = self.cfg

        def decode(params, batch):
            pos = batch["cache_pos"]
            if jnp.ndim(pos) == 1:          # per-slot (continuous batching)
                positions = pos[:, None].astype(jnp.int32)          # (B,1)
            else:                            # lockstep batch
                positions = jnp.reshape(pos, (1,)).astype(jnp.int32)
            if cfg.family == "ssm":
                logits, state = rwkv6.forward(cfg, params, tokens=batch["token"],
                                              state=batch["state"])
                return logits, state
            if cfg.family == "encdec":
                r = encdec.decode(cfg, params, batch["token"], batch["cross_kv"],
                                  positions=positions, cache=batch["cache"],
                                  cache_pos=pos)
                return r.logits, r.cache
            ssm_state = batch.get("state") if cfg.family == "hybrid" else None
            r = transformer.forward(cfg, params, tokens=batch.get("token"),
                                    embeds=batch.get("embed"),
                                    positions=positions, cache=batch["cache"],
                                    cache_pos=pos, ssm_state=ssm_state)
            if cfg.family == "hybrid":
                return r.logits, (r.cache, r.ssm_state)
            return r.logits, r.cache

        return decode

    # ---- dry-run input specs (ShapeDtypeStruct; never allocates) --------- #
    def input_specs(self, cell: ShapeCell) -> dict:
        cfg = self.cfg
        B, S = cell.global_batch, cell.seq_len
        i32, f32 = jnp.int32, jnp.float32
        dt = cfg.param_dtype
        sds = jax.ShapeDtypeStruct

        def tok(shape):
            return sds(shape, i32)

        if cell.kind == "train":
            if cfg.family == "encdec":
                return {"frames": sds((B, S, cfg.d_model), dt),
                        "tokens": tok((B, S)), "labels": tok((B, S)),
                        "loss_mask": sds((B, S), f32)}
            if cfg.frontend == "vision_stub":
                return {"embeds": sds((B, S, cfg.d_model), dt),
                        "labels": tok((B, S)), "loss_mask": sds((B, S), f32)}
            return {"tokens": tok((B, S)), "labels": tok((B, S)),
                    "loss_mask": sds((B, S), f32)}

        if cell.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": sds((B, S, cfg.d_model), dt),
                        "tokens": tok((B, 1))}
            if cfg.frontend == "vision_stub":
                return {"embeds": sds((B, S, cfg.d_model), dt)}
            return {"tokens": tok((B, S))}

        # decode: one new token against a seq_len-long context
        specs = {"token": tok((B, 1)), "cache_pos": sds((), i32)}
        if cfg.family == "ssm":
            st = jax.eval_shape(lambda: rwkv6.init_rwkv_state(cfg, B))
            specs["state"] = st
            del specs["cache_pos"]
            specs["cache_pos"] = sds((), i32)
            return specs
        cache = jax.eval_shape(
            lambda: attn_lib.init_cache(cfg, B, S, cfg.param_dtype))
        specs["cache"] = cache
        if cfg.family == "hybrid":
            specs["state"] = jax.eval_shape(
                lambda: ssm_lib.init_ssm_state(cfg, B))
        if cfg.family == "encdec":
            # realistic encoder extent for the decode cells (Whisper: 1500
            # frames ≈ 30 s audio); the 32 K/500 K axis is the decoder cache.
            s_enc = 1504
            KV, hd, L = cfg.kv_heads, cfg.hd, cfg.n_layers
            specs["cross_kv"] = {"k": sds((L, B, s_enc, KV, hd), dt),
                                 "v": sds((L, B, s_enc, KV, hd), dt)}
        return specs

    # ---- smoke-test batch (small, actual arrays) ------------------------- #
    def make_batch(self, key: jax.Array, batch: int, seq: int) -> dict:
        cfg = self.cfg
        k1, k2, k3 = jax.random.split(key, 3)
        out: dict = {}
        if cfg.family == "encdec":
            out["frames"] = jax.random.normal(k3, (batch, seq, cfg.d_model),
                                              cfg.param_dtype) * 0.02
            out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
        elif cfg.frontend == "vision_stub":
            out["embeds"] = jax.random.normal(k3, (batch, seq, cfg.d_model),
                                              cfg.param_dtype) * 0.02
        else:
            out["tokens"] = jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size)
        out["labels"] = jax.random.randint(k2, (batch, seq), 0, cfg.vocab_size)
        out["loss_mask"] = jnp.ones((batch, seq), jnp.float32)
        return out


def bundle(cfg_or_arch) -> Bundle:
    cfg = cfg_or_arch.cfg if isinstance(cfg_or_arch, Arch) else cfg_or_arch
    return Bundle(cfg)
