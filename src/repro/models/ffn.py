"""Dense feed-forward blocks: gated (SwiGLU / GeGLU) and plain 2-matmul
(incl. Nemotron's squared-ReLU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.common import activation, dense_init, shard_hint


def ffn_params(cfg, kg, dtype) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    p = {"w1": dense_init(kg(), (d, ff), dtype),
         "w2": dense_init(kg(), (ff, d), dtype, fan_in=ff)}
    if cfg.gated_ffn:
        p["w3"] = dense_init(kg(), (d, ff), dtype)
    return p


def ffn(cfg, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    h = x @ p["w1"]
    if cfg.gated_ffn:
        h = activation(cfg.activation, h) * (x @ p["w3"])
    else:
        h = activation(cfg.activation, h)
    h = shard_hint(h, "act_ff")
    return h @ p["w2"]
