"""Encoder-decoder transformer (Whisper-large-v3 backbone).

The conv audio frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings (B, S_enc, d) — see
``models/frontends.py``.  The encoder is a non-causal transformer over those
frames with sinusoidal positions; the decoder is a causal LM with
cross-attention whose K/V are precomputed once per sequence (the standard
serving optimization).

Params:
    {"enc": {"layers": …, "ln_f": …},
     "dec": {"embed": (V,d), "layers": {… + "ln_x", "xattn"}, "ln_f", "head"}}
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models.common import (KeyGen, apply_norm, dense_init, embed_init,
                                 norm_params, shard_hint, sinusoidal_embedding)
from repro.models.config import ModelConfig
from repro.models.ffn import ffn, ffn_params


def _enc_layer_params(cfg, key, dtype):
    kg = KeyGen(key)
    return {
        "ln1": norm_params(cfg, cfg.d_model, dtype),
        "attn": attn_lib.attention_params(cfg, kg, dtype),
        "ln2": norm_params(cfg, cfg.d_model, dtype),
        "mlp": ffn_params(cfg, kg, dtype),
    }


def _dec_layer_params(cfg, key, dtype):
    kg = KeyGen(key)
    return {
        "ln1": norm_params(cfg, cfg.d_model, dtype),
        "attn": attn_lib.attention_params(cfg, kg, dtype),
        "ln_x": norm_params(cfg, cfg.d_model, dtype),
        "xattn": attn_lib.attention_params(cfg, kg, dtype, cross=True),
        "ln2": norm_params(cfg, cfg.d_model, dtype),
        "mlp": ffn_params(cfg, kg, dtype),
    }


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    dtype = cfg.param_dtype
    kg = KeyGen(key)
    enc_keys = jax.random.split(kg(), cfg.encoder_layers)
    dec_keys = jax.random.split(kg(), cfg.n_layers)
    return {
        "enc": {
            "layers": jax.vmap(lambda k: _enc_layer_params(cfg, k, dtype))(enc_keys),
            "ln_f": norm_params(cfg, cfg.d_model, dtype),
        },
        "dec": {
            "embed": embed_init(kg(), (cfg.padded_vocab, cfg.d_model), dtype),
            "layers": jax.vmap(lambda k: _dec_layer_params(cfg, k, dtype))(dec_keys),
            "ln_f": norm_params(cfg, cfg.d_model, dtype),
            "head": dense_init(kg(), (cfg.d_model, cfg.padded_vocab), dtype),
        },
    }


# --------------------------------------------------------------------------- #
def encode(cfg: ModelConfig, params: dict, frames: jnp.ndarray) -> jnp.ndarray:
    """frames (B, S_enc, d) precomputed embeddings -> encoder states."""
    B, S, d = frames.shape
    x = frames.astype(cfg.param_dtype) + sinusoidal_embedding(S, d, cfg.param_dtype)
    x = shard_hint(x, "act_btd")
    positions = jnp.arange(S, dtype=jnp.int32)

    def body(x, lp):
        h = apply_norm(cfg, x, lp["ln1"])
        # Non-causal self-attention: reuse the attend machinery directly.
        q, k, v = attn_lib.project_qkv(cfg, lp["attn"], h, h)
        out = attn_lib.attend(cfg, q, k, v, q_pos=positions, k_pos=positions,
                              causal=False, window=0)
        out = out.reshape(B, S, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"]
        x = x + out
        x = x + ffn(cfg, lp["mlp"], apply_norm(cfg, x, lp["ln2"]))
        x = shard_hint(x, "act_btd")
        return x, 0

    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc"]["layers"])
    else:
        for i in range(cfg.encoder_layers):
            lp = jax.tree_util.tree_map(lambda a: a[i], params["enc"]["layers"])
            x, _ = body(x, lp)
    return apply_norm(cfg, x, params["enc"]["ln_f"])


class DecodeResult(NamedTuple):
    logits: jnp.ndarray
    cache: Optional[dict]


def precompute_cross_kv(cfg: ModelConfig, params: dict, enc_out: jnp.ndarray):
    """Stacked (L, B, S_enc, KV, hd) cross K/V from encoder states."""
    def one(lp):
        return attn_lib.precompute_cross_kv(cfg, lp["xattn"], enc_out)
    if cfg.scan_layers:
        ks, vs = jax.lax.map(one, params["dec"]["layers"])
    else:
        outs = [one(jax.tree_util.tree_map(lambda a: a[i], params["dec"]["layers"]))
                for i in range(cfg.n_layers)]
        ks = jnp.stack([o[0] for o in outs])
        vs = jnp.stack([o[1] for o in outs])
    return {"k": ks, "v": vs}


def decode(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
           cross_kv: dict, positions: Optional[jnp.ndarray] = None,
           cache: Optional[dict] = None, cache_pos=None) -> DecodeResult:
    """Decoder forward (teacher forcing when cache is None; incremental when
    cache+cache_pos given).  ``cross_kv`` from :func:`precompute_cross_kv`."""
    dec = params["dec"]
    x = jnp.take(dec["embed"], tokens, axis=0)
    x = x * jnp.asarray(jnp.sqrt(cfg.d_model), x.dtype)
    B, S = x.shape[:2]
    if positions is None:
        positions = jnp.arange(S, dtype=jnp.int32)
    if not cfg.use_rope:
        from repro.models.common import sinusoidal_at
        x = x + sinusoidal_at(positions, cfg.d_model, x.dtype)[None]
    x = shard_hint(x, "act_btd")
    use_cache = cache is not None

    def body(x, layer_in):
        lp, xk, xv, cache_l = layer_in
        h = apply_norm(cfg, x, lp["ln1"])
        attn_out, new_cache_l = attn_lib.self_attention(
            cfg, lp["attn"], h, positions,
            cache_l if use_cache else None, cache_pos)
        x = x + attn_out
        hx = apply_norm(cfg, x, lp["ln_x"])
        x = x + attn_lib.cross_attention(cfg, lp["xattn"], hx, xk, xv)
        x = x + ffn(cfg, lp["mlp"], apply_norm(cfg, x, lp["ln2"]))
        x = shard_hint(x, "act_btd")
        return x, (new_cache_l if use_cache else 0)

    xs = (dec["layers"], cross_kv["k"], cross_kv["v"],
          cache if use_cache else jnp.zeros((cfg.n_layers,), jnp.int8))
    if cfg.scan_layers:
        x, new_cache = jax.lax.scan(body, x, xs)
    else:
        caches = []
        for i in range(cfg.n_layers):
            layer_in = jax.tree_util.tree_map(lambda a: a[i], xs)
            x, nc = body(x, layer_in)
            caches.append(nc)
        new_cache = (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *caches)
                     if use_cache else 0)

    x = apply_norm(cfg, x, dec["ln_f"])
    logits = x @ dec["head"]
    logits = shard_hint(logits, "act_vocab")
    return DecodeResult(logits, new_cache if use_cache else None)


def forward_train(cfg: ModelConfig, params: dict, frames: jnp.ndarray,
                  tokens: jnp.ndarray) -> jnp.ndarray:
    """End-to-end teacher-forcing forward: encode frames, decode tokens."""
    enc_out = encode(cfg, params, frames)
    cross_kv = precompute_cross_kv(cfg, params, enc_out)
    return decode(cfg, params, tokens, cross_kv).logits
