"""Backprop baselines in pure JAX: Adam (the paper's FT) and SGD (App. F.1).

These exist because the paper's central comparisons are MeZO-vs-FT quality
(Tables 1/18), memory (Fig. 3/4), and wall-clock (Tab. 23).  The train step
is ``value_and_grad`` + moment updates; activation rematerialization
(``cfg.remat``) applies ``jax.checkpoint`` over the layer scan — the
gradient-checkpointing lever the paper cites [18].
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import schedules
from repro.tree_utils import PyTree, tree_zeros_like


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-5
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.0
    grad_clip: float = 1.0
    lr_schedule: str = "linear"     # the paper's FT convention
    total_steps: int = 1000
    warmup_steps: int = 0
    sgd: bool = False               # True -> plain SGD (paper App. F.1)
    momentum: float = 0.0           # SGD momentum

    def lr_at(self, step):
        return schedules.lr_at(self.lr_schedule, self.lr, step,
                               self.total_steps, self.warmup_steps)


class AdamState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


class Adam:
    """Backprop Adam/SGD behind the same uniform optimizer protocol as the
    ZO compositions (``repro.zo.Optimizer``): init / step_fn / restore."""

    def __init__(self, config: AdamConfig):
        self.config = config

    def init(self, params: PyTree, *, seed: int = 0) -> AdamState:
        del seed  # deterministic init; accepted for protocol uniformity
        c = self.config
        m = tree_zeros_like(params) if (not c.sgd or c.momentum) else ()
        v = tree_zeros_like(params) if not c.sgd else ()
        return AdamState(jnp.int32(0), m, v)

    def restore(self, state: AdamState, step: int) -> AdamState:
        """Resume bookkeeping: realign the step counter (lr index and bias
        correction) after a checkpoint restore."""
        return state._replace(step=jnp.int32(step))

    def step_fn(self, loss_fn: Callable):
        c = self.config

        def step(params: PyTree, state: AdamState, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            if c.grad_clip > 0:
                gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                                     for g in jax.tree_util.tree_leaves(grads)))
                scale = jnp.minimum(1.0, c.grad_clip / jnp.maximum(gnorm, 1e-9))
                grads = jax.tree_util.tree_map(
                    lambda g: (g.astype(jnp.float32) * scale), grads)
            else:
                gnorm = jnp.float32(0)
                grads = jax.tree_util.tree_map(
                    lambda g: g.astype(jnp.float32), grads)
            lr = c.lr_at(state.step)
            t = (state.step + 1).astype(jnp.float32)

            if c.sgd:
                if c.momentum:
                    m = jax.tree_util.tree_map(
                        lambda mm, g: c.momentum * mm + g, state.m, grads)
                    upd = m
                else:
                    m, upd = (), grads
                new_params = jax.tree_util.tree_map(
                    lambda p, u: (p.astype(jnp.float32) - lr * u
                                  - lr * c.weight_decay * p.astype(jnp.float32)
                                  ).astype(p.dtype), params, upd)
                new_state = AdamState(state.step + 1, m, ())
                return new_params, new_state, {"loss": loss, "lr": lr,
                                               "grad_norm": gnorm}

            m = jax.tree_util.tree_map(
                lambda mm, g: c.beta1 * mm + (1 - c.beta1) * g, state.m, grads)
            v = jax.tree_util.tree_map(
                lambda vv, g: c.beta2 * vv + (1 - c.beta2) * g * g,
                state.v, grads)
            bc1 = 1.0 - c.beta1 ** t
            bc2 = 1.0 - c.beta2 ** t

            def upd(p, mm, vv):
                delta = (mm / bc1) / (jnp.sqrt(vv / bc2) + c.eps)
                return (p.astype(jnp.float32) - lr * delta
                        - lr * c.weight_decay * p.astype(jnp.float32)
                        ).astype(p.dtype)

            new_params = jax.tree_util.tree_map(upd, params, m, v)
            new_state = AdamState(state.step + 1, m, v)
            return new_params, new_state, {"loss": loss, "lr": lr,
                                           "grad_norm": gnorm}

        return step
