"""Checkpointed training loop with fault-tolerance hooks.

Responsibilities:
  * jit + donate the optimizer step once;
  * pure step-indexed data (restart-exact);
  * full checkpoints every K steps + per-step ZO scalar ledger;
  * resume: newest full ckpt, then *ledger replay* of the tail — the
    replacement worker rejoins bitwise-identically without data access;
  * straggler/failure hooks: a HeartbeatMonitor ABC the launcher wires to
    its process manager; ``FailureInjector`` drives the chaos tests.

The loop is execution-engine-aware but optimizer-agnostic: ``optimizer`` is a
``repro.exec.StepProgram`` (any ``repro.zo`` composition lowered onto any
execution plan — local, seed_parallel, ...) or a bare ``repro.zo.Optimizer``
protocol conformer, which is wrapped onto the local plan.  That covers the ZO
compositions (``zo.mezo(...)``, ``zo.fzoo(...)``, the deprecated
``MeZO``/``MeZOAdam``/``MeZOVariant`` shims) and the backprop baselines
(``train.adam.Adam``) alike.  There is no optimizer-type dispatch here:
resume bookkeeping goes through the protocol's ``restore``, and ledger
recording/recovery is enabled purely by passing a ``ledger`` (which requires
an optimizer whose metrics expose ``projected_grad``/``lr`` — i.e. a ZO one).

Every artifact is stamped with the program's seed-schedule coordinates
(``perturb_backend``, ``batch_seeds``, ``exec_plan``, ``n_groups``); resuming
under mismatched coordinates refuses (``BackendMismatchError`` /
``PlanMismatchError``) instead of silently re-pairing recorded scalars with
different z streams.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.trajectory import TrajectoryLedger
from repro.data.pipeline import Pipeline
from repro.exec import as_step_program, check_replay_plan
from repro.perturb import check_replay_backend
from repro.select import check_replay_selection
from repro.tree_utils import PyTree


class HeartbeatMonitor:
    """Launcher-facing hook: the loop beats every step; deployments override
    ``on_beat`` to feed a watchdog (k8s liveness, SLURM requeue, etc.)."""

    def __init__(self, timeout_s: float = 300.0):
        self.timeout_s = timeout_s
        self.last = time.monotonic()

    def beat(self, step: int) -> None:
        now = time.monotonic()
        self.on_beat(step, now - self.last)
        self.last = now

    def on_beat(self, step: int, dt: float) -> None:  # pragma: no cover
        pass


class FailureInjector:
    """Test hook: raise at a chosen step to simulate a node crash."""

    def __init__(self, fail_at_step: Optional[int] = None):
        self.fail_at_step = fail_at_step

    def check(self, step: int) -> None:
        if self.fail_at_step is not None and step == self.fail_at_step:
            raise RuntimeError(f"injected failure at step {step}")


@dataclasses.dataclass
class TrainResult:
    params: PyTree
    opt_state: Any
    losses: list
    steps_run: int
    resumed_from: int


def train(loss_fn: Callable, params: PyTree, optimizer, pipeline: Pipeline,
          total_steps: int, ckpt: Optional[CheckpointManager] = None,
          ledger: Optional[TrajectoryLedger] = None,
          monitor: Optional[HeartbeatMonitor] = None,
          injector: Optional[FailureInjector] = None,
          log_every: int = 50, donate: bool = True,
          eval_fn: Optional[Callable] = None, eval_every: int = 0,
          verbose: bool = False, seed: int = 0) -> TrainResult:
    """Run (or resume) a training job.  ``optimizer`` is a
    ``repro.exec.StepProgram`` or any ``repro.zo.Optimizer`` protocol
    conformer (wrapped onto the local execution plan)."""
    program = as_step_program(optimizer)
    opt_state = program.init(params, seed=seed)

    # the program's seed-schedule coordinates (None for non-ZO optimizers)
    # are stamped into every artifact so replay under the wrong backend or
    # execution plan — which would regenerate *different* z or re-pair the
    # recorded scalars with different streams — fails loudly
    meta = program.meta
    backend_name = meta["perturb_backend"]
    if ledger is not None and backend_name is not None:
        if len(ledger) == 0:
            ledger.backend = backend_name
            ledger.batch_seeds = int(meta["batch_seeds"])
            ledger.exec_plan = meta["exec_plan"]
            ledger.n_groups = int(meta["n_groups"])
            ledger.selection = meta["selection"] or "full"
            ledger.sel_phase = int(meta["sel_phase"] or 0)
        else:
            check_replay_backend(ledger.backend, backend_name,
                                 "the provided trajectory ledger")
            check_replay_plan(ledger.n_groups, meta["n_groups"],
                              "the provided trajectory ledger",
                              recorded_kind=ledger.exec_plan,
                              active_kind=meta["exec_plan"])
            check_replay_selection(getattr(ledger, "selection", None),
                                   meta["selection"],
                                   "the provided trajectory ledger",
                                   getattr(ledger, "sel_phase", 0),
                                   meta["sel_phase"])

    start_step = 0
    # ---- resume ---------------------------------------------------------- #
    if ckpt is not None:
        restored = ckpt.restore_latest(params, opt_state)
        if restored is not None:
            check_replay_backend(restored["meta"].get("perturb_backend"),
                                 backend_name, "checkpoint")
            ckpt_bs = restored["meta"].get("batch_seeds")
            if ckpt_bs is not None and meta["batch_seeds"] is not None \
                    and int(ckpt_bs) != int(meta["batch_seeds"]):
                raise ValueError(
                    f"checkpoint was written by an optimizer with "
                    f"batch_seeds={ckpt_bs} but the active optimizer uses "
                    f"batch_seeds={meta['batch_seeds']}; the seed fold "
                    "schedule (and the ledger's per-step record shape) "
                    "differ — resume with a matching fzoo(batch_seeds=...) "
                    "composition")
            check_replay_plan(restored["meta"].get("n_groups"),
                              meta["n_groups"], "checkpoint",
                              recorded_kind=restored["meta"].get("exec_plan"),
                              active_kind=meta["exec_plan"])
            check_replay_selection(restored["meta"].get("selection"),
                                   meta["selection"], "checkpoint",
                                   restored["meta"].get("sel_phase"),
                                   meta["sel_phase"])
            params = restored["params"]
            opt_state = restored["opt_state"] if restored["opt_state"] is not None else opt_state
            start_step = restored["step"]
            if ledger is not None:
                saved = ckpt.load_ledger()
                if saved is not None and len(saved) and saved.steps[-1] >= start_step:
                    # ledger replay advances params past the tensor ckpt;
                    # recovery consumes the execution engine directly
                    params, start_step = ckpt.recover_via_ledger(
                        params, start_step, program)
                    ledger.steps = saved.steps
                    ledger.grads = saved.grads
                    ledger.lrs = saved.lrs
                    ledger.batch_seeds = saved.batch_seeds
                    ledger.exec_plan = saved.exec_plan
                    ledger.n_groups = saved.n_groups
                    ledger.selection = saved.selection
                    ledger.sel_phase = saved.sel_phase
            # realign the optimizer's step counter (seed source + lr index)
            # with wherever resume landed — the protocol's resume hook
            opt_state = program.restore(opt_state, start_step)

    step_fn = jax.jit(program.step_fn(loss_fn),
                      donate_argnums=(0,) if donate else ())
    losses = []
    t0 = time.time()
    for step in range(start_step, total_steps):
        if injector is not None:
            injector.check(step)
        batch = pipeline.batch(step)
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if ledger is not None:
            if "projected_grad" not in metrics:
                raise ValueError(
                    "ledger recording requires a ZO optimizer whose step "
                    "metrics expose 'projected_grad'/'lr'; "
                    f"{type(optimizer).__name__} does not")
            # multi-stream steps (batched seeds, seed-parallel groups,
            # interleaved n-SPSA) expose the per-stream vector — record it so
            # replay can refold the rank-1 updates stream by stream
            g_rec = metrics.get("projected_grads")
            if g_rec is None:
                g_rec = float(metrics["projected_grad"])
            else:
                g_rec = np.asarray(g_rec)
            ledger.append(step, g_rec, float(metrics["lr"]))
            if ckpt is not None:
                ckpt.save_ledger(ledger)
        if ckpt is not None:
            ckpt.maybe_save(step + 1, params, opt_state, meta=meta)
        if monitor is not None:
            monitor.beat(step)
        if step % log_every == 0 or step == total_steps - 1:
            losses.append((step, float(metrics["loss"])))
            if verbose:
                print(f"step {step:6d} loss {float(metrics['loss']):.4f} "
                      f"({(time.time()-t0):.1f}s)", flush=True)
        if eval_fn is not None and eval_every and (step + 1) % eval_every == 0:
            eval_fn(step + 1, params)

    if ckpt is not None:
        ckpt.maybe_save(total_steps, params, opt_state, meta=meta, force=True)
    return TrainResult(params, opt_state, losses, total_steps - start_step,
                       start_step)
