"""The one canonical z-stream identity shared by every perturbation backend.

The paper's storage trick works because z is *regenerated, never stored*: the
direction for any parameter leaf must be a pure function of a small, stable
identifier.  Before this layer the repo had two incompatible derivations —
threefry ``fold_in`` chains in ``core/perturb.py`` and an ad-hoc murmur3
counter seed in ``kernels/zo_fused/ops.py``.  ``StreamRef`` is the single
contract both now share:

    StreamRef.derive(base_key, step, seed_index)      # run → step → seed
        .leaf_key(leaf_index)                         # threefry leaf stream
        .counter_seed() / .leaf_seed(leaf_index)      # int32 counter stream

A backend consumes whichever projection matches its RNG (the ``xla`` backend
folds threefry keys; the ``pallas`` kernel hashes 32-bit counters), but both
projections are pure functions of the same ``(run_seed, step, seed_index,
leaf_index)`` coordinates — so "same StreamRef ⇒ same z within a backend"
holds regardless of how the surrounding tree is restructured or padded.

Derivation is bit-compatible with the legacy code: ``derive(k, t)`` is
exactly ``fold_in(k, t)`` (the paper's "sample random seed s for step t") and
``derive(k, t, j)`` is exactly ``fold_in(fold_in(k, t), j)`` (Algorithm 2's
per-seed fold) — existing ledgers and checkpoints replay unchanged.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

# Multiplier decorrelating per-leaf counter streams (a large prime; inherited
# from the original zo_fused seed schedule so legacy kernel streams are
# preserved bit-for-bit).
_LEAF_STRIDE = 0x1000003


def step_key(base_key: jax.Array, step) -> jax.Array:
    """Per-step key: the paper's 'sample random seed s' for step t.

    THE canonical definition — ``repro.core.perturb.step_key`` and
    ``repro.perturb.xla.step_key`` are re-exports of this function, and
    ``StreamRef.derive(base_key, step)`` wraps exactly this fold (bitwise
    equality is contract-tested), so every execution plan, ledger replayer,
    and backend derives step seeds from one place.
    """
    return jax.random.fold_in(base_key, step)


class StreamRef(NamedTuple):
    """Identity of one per-seed perturbation stream.

    ``key`` is the fully-derived per-seed threefry key — the wire format the
    estimator protocol already passes around.  Wrap an existing key with
    ``StreamRef(key)``; derive one from run coordinates with
    ``StreamRef.derive``.

    ``selection``/``phase`` optionally scope the stream to a parameter
    subset (``repro.select.Selection`` + its static schedule phase): backends
    read ``selection_mask`` and *skip* unselected leaves — zero z generation
    and zero writes for them, not a masked multiply.  Both fields are static
    trace-time data (the ref never crosses a jit boundary as an argument);
    the default ``(None, 0)`` is the full selection and keeps every
    pre-selection code path bitwise-identical.
    """
    key: jax.Array
    selection: Any = None           # Optional[repro.select.Selection]
    phase: int = 0                  # static schedule phase (python int)

    @classmethod
    def derive(cls, base_key: jax.Array, step,
               seed_index: Optional[int] = None,
               selection: Any = None, phase: int = 0) -> "StreamRef":
        """run key → step t → (optional) seed j, the legacy fold chain —
        optionally scoped to a parameter selection at a schedule phase."""
        key = step_key(base_key, step)
        if seed_index is not None:
            key = jax.random.fold_in(key, seed_index)
        return cls(key, selection, phase)

    def with_selection(self, selection, phase: int = 0) -> "StreamRef":
        """The selection-aware derivation: same stream identity (key bits are
        untouched — selection scopes *which leaves* consume the stream, not
        the stream itself), scoped to ``selection`` at ``phase``."""
        return self._replace(selection=selection, phase=phase)

    def selection_mask(self, params) -> Optional[tuple]:
        """Static per-leaf active mask for ``params`` (flattening order), or
        ``None`` when the ref carries no selection (all leaves active)."""
        if self.selection is None:
            return None
        return self.selection.leaf_mask(params, self.phase)

    def selection_blocks(self, params) -> Optional[tuple]:
        """Static per-leaf SUB-LEAF plans (flattening order): a
        ``repro.select.RowBlocks`` per leaf under a ``rows`` selection, or
        ``None`` when the ref's selection has whole-leaf semantics (every
        non-``rows`` kind, including no selection at all).

        **The blocked index contract.**  Both stream projections index a leaf
        by *flat element position*: the xla projection samples whole-leaf z
        from ``leaf_key(i)`` and the banded path slices it, and the counter
        projection hashes ``leaf_seed(i) ⊕ element_index`` — so row-block
        ``b``'s z bits are a pure function of ``(leaf_seed, block_index)``
        via its element range ``[b*block_elems, ...)`` (see
        ``block_index_base``).  A block's bits are therefore identical
        whether the leaf is perturbed whole or block-by-block, and stable
        under restructuring/padding of the *surrounding tree* (the plan
        depends only on the leaf's own shape).  Full selection — including
        ``rows(..., k=1)``, where every block is selected — reproduces the
        whole-leaf bits exactly, so there is no stream-id bump.
        """
        if self.selection is None:
            return None
        bm = getattr(self.selection, "block_mask", None)
        if bm is None:
            return None
        flat = jax.tree_util.tree_leaves(params)
        blocks = tuple(bm(leaf, self.phase) for leaf in flat)
        if all(b is None for b in blocks):
            return None
        return blocks

    # -- threefry projection (xla backend) ---------------------------------- #
    def leaf_key(self, leaf_index: int) -> jax.Array:
        """Stable per-leaf PRNG key (the legacy ``leaf_key``)."""
        return jax.random.fold_in(self.key, leaf_index)

    # -- 32-bit counter projection (pallas / counter-hash backends) ---------- #
    def counter_seed(self) -> jnp.ndarray:
        """Fold the key material into one int32 seed for counter-hash RNGs.

        Pure function of the key (hence of run/step/seed coordinates), stable
        under jit tracing, and well-mixed: threefry key data is already a
        high-entropy function of the fold chain.
        """
        data = self.key
        if not jnp.issubdtype(data.dtype, jnp.integer):   # typed PRNG key
            data = jax.random.key_data(self.key)
        folded = (data[..., 0] ^ data[..., 1]).astype(jnp.uint32)
        return jax.lax.bitcast_convert_type(folded, jnp.int32)

    def leaf_seed(self, leaf_index: int) -> jnp.ndarray:
        """Per-leaf int32 counter seed (the legacy zo_fused schedule)."""
        return (self.counter_seed()
                + jnp.int32(_LEAF_STRIDE) * jnp.int32(leaf_index))

    @staticmethod
    def block_index_base(block_index: int, block_elems: int) -> int:
        """First counter index of row-block ``block_index`` within its leaf
        stream — the blocked index contract in one line: the counter-hash
        projection draws element ``e`` of a leaf from
        ``hash(leaf_seed(i), e)``, and block ``b`` owns the contiguous index
        range ``[b*block_elems, (b+1)*block_elems)``.  z for a row-block is
        thus derived from ``(leaf_seed, block_index)`` alone — never from
        which *other* blocks are selected, how the leaf is padded to kernel
        tiles, or how the surrounding tree is restructured."""
        return int(block_index) * int(block_elems)


def as_stream_ref(key_or_ref) -> StreamRef:
    """Accept either a raw per-seed key (the protocol wire format) or an
    already-wrapped ``StreamRef``."""
    if isinstance(key_or_ref, StreamRef):
        return key_or_ref
    return StreamRef(key_or_ref)
