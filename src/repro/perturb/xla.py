"""``xla`` backend: threefry z streams lowered by XLA (the default).

This is the original ``core/perturb.py`` machinery moved behind the
``PerturbBackend`` interface — the paper's "reset the RNG with seed s and
resample z" trick expressed as: *z for any leaf is a pure function of
(key, leaf_index)*.  Threefry is counter-based, so regeneration is exact,
needs no storage and no cross-host communication, and under ``pjit`` each
shard generates exactly its slice of the same global z regardless of the
mesh (XLA partitions the iota+hash lowering of ``jax.random.normal``).

Memory: z tiles live as short-lived HBM temporaries inside the jitted step;
under buffer donation the perturb → loss → perturb → loss → update chain
keeps one parameter-sized buffer alive.  The ``pallas`` backend pushes z one
level further down (generated in VMEM, never in HBM) — see
``repro.perturb.pallas``.

All arithmetic here is bit-identical to the legacy module (the functions
moved, they were not rewritten): existing ledgers, checkpoints, and the
shim-equivalence tests replay unchanged.
"""
from __future__ import annotations

import functools
from typing import Literal, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.perturb.base import PerturbBackend, per_stream_scales
from repro.perturb.stream import StreamRef, step_key  # noqa: F401  (canonical
# definition lives in repro.perturb.stream; re-exported here for the legacy
# core.perturb shim surface)
from repro.tree_utils import PyTree, tree_map_with_index, tree_sq_norm, tree_size

Distribution = Literal["gaussian", "rademacher", "sphere"]


def leaf_key(key: jax.Array, leaf_idx: int) -> jax.Array:
    """Stable per-leaf PRNG key."""
    return jax.random.fold_in(key, leaf_idx)


def sample_leaf_z(key: jax.Array, leaf: jnp.ndarray, dist: Distribution = "gaussian",
                  zo_dtype=None) -> jnp.ndarray:
    """Sample the perturbation direction for one leaf.

    ``zo_dtype`` controls the dtype z is *sampled* in (defaults to the leaf
    dtype); the result is cast back to the leaf dtype so perturbation is a
    same-dtype add, as in the paper's in-place implementation.
    """
    sdtype = zo_dtype or (leaf.dtype if jnp.issubdtype(leaf.dtype, jnp.floating) else jnp.float32)
    if dist == "gaussian":
        z = jax.random.normal(key, leaf.shape, sdtype)
    elif dist == "rademacher":
        z = jax.random.rademacher(key, leaf.shape, sdtype)
    elif dist == "sphere":
        # Direction only; the global sqrt(d)/||z|| rescale is applied by the
        # caller (it needs the full-tree norm).
        z = jax.random.normal(key, leaf.shape, sdtype)
    else:
        raise ValueError(f"unknown distribution {dist!r}")
    return z.astype(leaf.dtype)


def sample_z_tree(params: PyTree, key: jax.Array, dist: Distribution = "gaussian") -> PyTree:
    """Materialize the whole z tree.  Used by tests/oracles only — the actual
    optimizer never calls this (that is the point of the paper)."""
    z = tree_map_with_index(lambda i, p: sample_leaf_z(leaf_key(key, i), p, dist), params)
    if dist == "sphere":
        d = tree_size(params)
        scale = jnp.sqrt(d / tree_sq_norm(z))
        z = jax.tree_util.tree_map(lambda x: (x * scale.astype(x.dtype)), z)
    return z


def _leaf_blocks(blocks: Optional[tuple], i: int):
    """Static sub-leaf plan of leaf ``i`` — ``None`` (whole-leaf semantics)
    unless a ``rows`` selection supplied a partial plan.  An all-selected
    plan degrades to ``None`` here so the whole-leaf fast path (and its
    exact reduction/fusion shapes) is taken — ``rows(..., k=1)`` stays
    bitwise ≡ ``full``."""
    if blocks is None:
        return None
    rb = blocks[i]
    if rb is None or rb.all_selected:
        return None
    return rb


def _apply_banded(p: jnp.ndarray, rb, band_fn) -> jnp.ndarray:
    """Apply an elementwise update to the selected row bands of one leaf:
    whole-leaf z is generated once by the caller (threefry pairs element j
    with j + n/2 across the *whole* leaf, so per-band generation would
    change the stream — the counter-hash backend has no such coupling), and
    ``band_fn(lo, hi)`` computes the updated flat band, stitched over p with
    gather/scatter-free static slices + ``dynamic_update_slice``.  All ops
    are elementwise, so each band is bitwise-equal to the same slice of the
    whole-leaf update."""
    out = p.reshape(-1)
    for lo, hi in rb.ranges():
        out = jax.lax.dynamic_update_slice(out, band_fn(lo, hi), (lo,))
    return out.reshape(p.shape)


def _sphere_scale(params: PyTree, key: jax.Array,
                  mask: Optional[tuple] = None,
                  blocks: Optional[tuple] = None) -> jnp.ndarray:
    """sqrt(d)/||z|| for sphere sampling, computed by regenerating z leaf-wise
    (two-pass; still never stores the tree).  Under a selection ``mask`` the
    sphere lives in the selected subspace: d and ‖z‖ count selected leaves
    only (unselected leaves consume no z at all) — and under a sub-leaf
    ``blocks`` plan, selected row bands only."""
    leaves = jax.tree_util.tree_leaves(params)
    if mask is None:
        d = tree_size(params)
    else:
        d = sum(int(p.size) if _leaf_blocks(blocks, i) is None
                else _leaf_blocks(blocks, i).selected_elems()
                for i, (p, m) in enumerate(zip(leaves, mask)) if m)
    sq = jnp.float32(0)
    for i, p in enumerate(leaves):
        if mask is not None and not mask[i]:
            continue
        z = sample_leaf_z(leaf_key(key, i), p, "gaussian")
        rb = _leaf_blocks(blocks, i)
        if rb is None:
            sq = sq + jnp.sum(z.astype(jnp.float32) ** 2)
        else:
            zf = z.reshape(-1)
            for lo, hi in rb.ranges():
                sq = sq + jnp.sum(zf[lo:hi].astype(jnp.float32) ** 2)
    return jnp.sqrt(d / sq)


def perturb(params: PyTree, key: jax.Array, scale, dist: Distribution = "gaussian",
            mask: Optional[tuple] = None,
            blocks: Optional[tuple] = None) -> PyTree:
    """θ + scale · z(key)  — the paper's ``PerturbParameters(θ, scale, s)``.

    ``scale`` may be a traced scalar (used for the fused restore+update).
    Regenerating with the same ``key`` always yields the same z.  ``mask`` is
    a static per-leaf selection (repro.select): unselected leaves pass
    through with zero z generation.  ``blocks`` optionally adds per-leaf
    sub-leaf row-band plans (``rows`` selections): only the selected bands
    of a leaf are written (``_apply_banded``).
    """
    if dist == "sphere":
        sph = _sphere_scale(params, key, mask, blocks)
    def one(i: int, p: jnp.ndarray) -> jnp.ndarray:
        if mask is not None and not mask[i]:
            return p
        z = sample_leaf_z(leaf_key(key, i), p, dist)
        if dist == "sphere":
            z = z * sph.astype(z.dtype)
        s = jnp.asarray(scale, p.dtype) if jnp.issubdtype(p.dtype, jnp.floating) else scale
        rb = _leaf_blocks(blocks, i)
        if rb is None:
            return p + s * z
        flat, zf = p.reshape(-1), z.reshape(-1)
        return _apply_banded(p, rb, lambda lo, hi: flat[lo:hi] + s * zf[lo:hi])
    return tree_map_with_index(one, params)


def fused_restore_update(params_minus: PyTree, key: jax.Array, eps, lr_g, weight_decay=0.0,
                         dist: Distribution = "gaussian",
                         mask: Optional[tuple] = None,
                         blocks: Optional[tuple] = None) -> PyTree:
    """Given θ − εz (the state after the second perturbation), produce the
    post-step parameters in ONE pass over the tree:

        θ_new = (1 − η·λ) · (θ − εz + εz) − η·g·z
               = (1 − η·λ) · θ  − η·g·z        (decoupled weight decay)

    regenerating each leaf's z exactly once.  This fuses the paper's
    'reset parameters' and 'descent' loops and halves the number of z
    regenerations per step (4 -> 3).  Unselected ``mask`` leaves were never
    perturbed, so they pass through completely untouched — including the
    decay term (a PEFT selection must not decay the frozen base).
    """
    if dist == "sphere":
        sph = _sphere_scale(params_minus, key, mask, blocks)
    decay = 1.0 - weight_decay
    def one(i: int, p: jnp.ndarray) -> jnp.ndarray:
        if mask is not None and not mask[i]:
            return p
        z = sample_leaf_z(leaf_key(key, i), p, dist)
        if dist == "sphere":
            z = z * sph.astype(z.dtype)
        eps_ = jnp.asarray(eps, p.dtype)
        lr_g_ = jnp.asarray(lr_g, p.dtype)
        decay_ = jnp.asarray(decay, p.dtype)
        rb = _leaf_blocks(blocks, i)
        if rb is None:
            restored = p + eps_ * z
            return decay_ * restored - lr_g_ * z
        # unselected bands were never perturbed — they pass through with no
        # restore, no decay, no update (the sub-leaf analogue of the leaf rule)
        flat, zf = p.reshape(-1), z.reshape(-1)
        def band(lo, hi):
            restored = flat[lo:hi] + eps_ * zf[lo:hi]
            return decay_ * restored - lr_g_ * zf[lo:hi]
        return _apply_banded(p, rb, band)
    return tree_map_with_index(one, params_minus)


def apply_rank1(params: PyTree, key: jax.Array, coeff, decay_term=0.0,
                dist: Distribution = "gaussian",
                d_tree: Optional[PyTree] = None,
                mask: Optional[tuple] = None,
                blocks: Optional[tuple] = None) -> PyTree:
    """θ ← (1 − decay_term)·θ − coeff·z(key), regenerating z leaf by leaf.

    ``coeff`` is the full η-scaled scalar (η·g, or η/n·g per seed);
    ``decay_term`` is the decoupled weight-decay coefficient η·λ.  ``d_tree``
    holds one positive scalar per leaf and rescales z (Definition 6's
    block-diagonal D); ``None`` leaves z unscaled (Definition 7 / plain SPSA).
    Non-floating leaves and unselected ``mask`` leaves pass through untouched
    (no decay either — the update, decay included, is scoped to the
    selection).
    """
    d_leaves = jax.tree_util.tree_leaves(d_tree) if d_tree is not None else None

    def one(i, p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p
        if mask is not None and not mask[i]:
            return p
        z = sample_leaf_z(leaf_key(key, i), p, dist)
        if d_leaves is not None:
            z = z * jnp.asarray(d_leaves[i], p.dtype)
        coeff_ = jnp.asarray(coeff, p.dtype)
        decay = jnp.asarray(1.0 - decay_term, p.dtype)
        rb = _leaf_blocks(blocks, i)
        if rb is None:
            return decay * p - coeff_ * z
        flat, zf = p.reshape(-1), z.reshape(-1)
        return _apply_banded(
            p, rb, lambda lo, hi: decay * flat[lo:hi] - coeff_ * zf[lo:hi])

    return tree_map_with_index(one, params)


@functools.partial(jax.jit, static_argnames=("dist",))
def perturb_jit(params: PyTree, key: jax.Array, scale, dist: Distribution = "gaussian") -> PyTree:
    return perturb(params, key, scale, dist)


# --------------------------------------------------------------------------- #
# Backend adapter
# --------------------------------------------------------------------------- #
class XLABackend(PerturbBackend):
    """Threefry z streams, HBM-resident temporaries, all distributions.

    Selection-aware: a ``StreamRef`` carrying a ``repro.select.Selection``
    scopes every method to the selected leaves — unselected leaves are
    skipped at trace time (zero z generation, zero writes)."""

    name = "xla"
    dists = frozenset({"gaussian", "rademacher", "sphere"})

    def perturb(self, params: PyTree, ref: StreamRef, scale,
                dist: str = "gaussian") -> PyTree:
        self.check_dist(dist)
        return perturb(params, ref.key, scale, dist,
                       mask=ref.selection_mask(params),
                       blocks=ref.selection_blocks(params))

    def fused_restore_update(self, params_minus: PyTree, ref: StreamRef, eps,
                             lr_g, weight_decay=0.0,
                             dist: str = "gaussian") -> PyTree:
        self.check_dist(dist)
        return fused_restore_update(params_minus, ref.key, eps, lr_g,
                                    weight_decay, dist,
                                    mask=ref.selection_mask(params_minus),
                                    blocks=ref.selection_blocks(params_minus))

    def apply_rank1(self, params: PyTree, ref: StreamRef, coeff,
                    decay_term=0.0, dist: str = "gaussian",
                    d_tree: Optional[PyTree] = None) -> PyTree:
        self.check_dist(dist)
        return apply_rank1(params, ref.key, coeff, decay_term, dist,
                           d_tree=d_tree, mask=ref.selection_mask(params),
                           blocks=ref.selection_blocks(params))

    def leaf_z(self, ref: StreamRef, leaf_index: int, like: jnp.ndarray,
               dist: str = "gaussian") -> jnp.ndarray:
        self.check_dist(dist)
        return sample_leaf_z(ref.leaf_key(leaf_index), like, dist)

    def perturb_many(self, params: PyTree, refs: Sequence[StreamRef], scale,
                     dist: str = "gaussian") -> PyTree:
        """Vectorized threefry: one vmapped perturb over the stacked per-seed
        keys (and, when given, per-stream scales) instead of B sequential
        tree passes.  Threefry is a counter-based integer hash and the
        uniform→z conversion is elementwise, so the batched lowering is
        bitwise-equal to stacking per-ref ``perturb`` calls
        (contract-tested).  Unselected leaves never enter the vmapped
        generation and are returned as copy-free ``broadcast_to`` views
        (not B materialized HBM copies) — bitwise what stacking masked
        singles yields."""
        self.check_dist(dist)
        if not refs:
            raise ValueError("perturb_many needs at least one StreamRef")
        mask = refs[0].selection_mask(params)
        blocks = refs[0].selection_blocks(params)
        keys = jnp.stack([r.key for r in refs])
        per = per_stream_scales(scale, len(refs))
        if per is None:
            stacked = jax.vmap(lambda k: perturb(params, k, scale, dist,
                                                 mask=mask,
                                                 blocks=blocks))(keys)
        else:
            scales = jnp.stack([jnp.asarray(s, jnp.float32) for s in per])
            stacked = jax.vmap(lambda k, s: perturb(params, k, s, dist,
                                                    mask=mask,
                                                    blocks=blocks))(keys,
                                                                    scales)
        if mask is None:
            return stacked
        flat, treedef = jax.tree_util.tree_flatten(stacked)
        out = [jnp.broadcast_to(p, (len(refs),) + p.shape)
               if not mask[i] else st
               for i, (p, st) in
               enumerate(zip(jax.tree_util.tree_leaves(params), flat))]
        return jax.tree_util.tree_unflatten(treedef, out)
