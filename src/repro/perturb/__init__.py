"""``repro.perturb`` — pluggable perturbation backends behind one z-stream
contract.

The paper's entire memory story is that the perturbation direction z is
*regenerated from a seed, never stored*.  This package owns that regeneration:
``StreamRef`` is the one canonical identity of a z stream
(run seed → step → seed index → leaf index), and ``PerturbBackend`` is the
interface through which every consumer — estimators, the transform chain,
trajectory replay, checkpoint recovery, async workers, seed-parallel
collectives — perturbs or updates parameters.  Nothing outside this package
decides *how* z is generated.

Backend selection
-----------------
Pick per run via ``zo.mezo(..., backend=...)`` (or any preset /
``ZOEstimator`` factory); the choice is recorded in checkpoint and ledger
metadata so a replay under the wrong backend raises ``BackendMismatchError``
instead of silently reconstructing different parameters.

``backend="xla"`` (default)
    Threefry streams lowered by XLA.  z tiles are short-lived **HBM**
    temporaries inside the jitted step; with buffer donation the sequential
    perturb → loss → perturb → loss → update chain keeps one parameter-sized
    buffer alive (the paper's inference-memory property).  Partitioning-aware:
    under ``pjit`` each shard generates exactly its slice of the global z.
    Supports gaussian / rademacher / sphere.

``backend="pallas"``
    The fused Pallas kernel: z is generated tile-by-tile **inside VMEM** from
    a counter hash of (seed, element index) and never exists in HBM at all —
    perturb/update is one read-modify-write stream over the parameters at
    pure memory-bandwidth speed, with zero z traffic.  On TPU it runs
    compiled; off-TPU it transparently falls back to Pallas interpret mode
    (identical arithmetic, jnp-evaluated) so CPU runs and CI exercise the
    same stream.  Supports gaussian and rademacher (sign of one counter
    stream, generated in-kernel) — sphere raises ``NotImplementedError``
    (see the matrix in ``repro.perturb.base``).

``backend="pallas-interpret"``
    Same stream as ``pallas`` with interpret mode forced — for measuring
    interpreter overhead (``benchmarks/bench_perturb.py``) and for debugging
    kernel semantics under jnp.

The two backends generate *different* (both valid N(0,1)) z streams for the
same ``StreamRef``; within a backend the stream is bitwise-stable across
tree restructuring and padding boundaries (contract-tested in
``tests/test_perturb_backend.py``).

Batched multi-seed streams
--------------------------
``PerturbBackend.perturb_many`` stacks B perturbed views of θ for
batched-seed estimators (``zo.fzoo``).  Both backends override the
stacked-singles default with genuinely vectorized generation — ``xla`` vmaps
threefry over the stacked per-seed keys, ``pallas`` runs the batched-seed
kernel (B z-streams generated against each resident VMEM tile of x) — and
both are bitwise-equal to stacking per-ref ``perturb`` calls
(contract-tested for B ∈ {1, 3, 8} across dtypes).

The default backend honors the ``REPRO_BACKEND`` environment variable (CI's
pallas-interpret job runs the unmodified suite under the fused kernel).

Parameter selection
-------------------
A ``StreamRef`` may carry a ``repro.select.Selection`` (static leaf predicate
+ optional block schedule): both backends *skip* unselected leaves in every
method — zero z generation and zero writes, not a masked multiply.  See
:mod:`repro.select`.

Extending
---------
New strategies (quantized z, mixed-stream formats) implement
``PerturbBackend`` and register with ``register_backend``; every existing
estimator × transform composition picks them up through the same kwarg.
"""
from repro.perturb.base import (BackendMismatchError, PerturbBackend,
                                available_backends, check_replay_backend,
                                get_backend, register_backend)
from repro.perturb.stream import StreamRef, as_stream_ref, step_key
from repro.perturb.xla import XLABackend

register_backend("xla", XLABackend)


# The pallas module pulls in jax.experimental.pallas (slow import, and a hard
# dependency xla-only runs don't need) — defer it to first resolution.
def _pallas():
    from repro.perturb.pallas import PallasBackend
    return PallasBackend()


def _pallas_interpret():
    from repro.perturb.pallas import PallasBackend
    return PallasBackend(interpret=True)


register_backend("pallas", _pallas)
register_backend("pallas-interpret", _pallas_interpret)


def __getattr__(name):      # PEP 562: `from repro.perturb import PallasBackend`
    if name == "PallasBackend":
        from repro.perturb.pallas import PallasBackend
        return PallasBackend
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "BackendMismatchError", "PerturbBackend", "StreamRef", "as_stream_ref",
    "XLABackend", "PallasBackend",
    "available_backends", "check_replay_backend", "get_backend",
    "register_backend", "step_key",
]
