"""``PerturbBackend`` — the interface every z-generation strategy implements.

One backend = one way of materializing (or *not* materializing) the
perturbation direction z for a parameter tree, given a ``StreamRef``.  The
estimators, the transform chain, trajectory replay, checkpoint recovery, and
the distributed paths all write parameters exclusively through these methods,
so swapping the backend swaps the memory/compute strategy of *every* existing
estimator × transform composition at once.

Supported distribution matrix (see the package docstring for the memory
story):

    ==============  ========  ==========  ========
    backend         gaussian  rademacher  sphere
    ==============  ========  ==========  ========
    ``xla``         yes       yes         yes
    ``pallas``      yes       yes         yes [1]
    ==============  ========  ==========  ========

    [1] sphere on pallas is the kernel-fused two-pass rescale: pass 1
        accumulates ‖z‖² tile-by-tile with the ``zo_sqnorm`` kernel (z is
        measured, never materialized), pass 2 folds sqrt(d)/‖z‖ into the
        affine b coefficient — the gaussian/rademacher counter streams are
        untouched, so no ``stream_id`` bump.

Unsupported combinations raise ``NotImplementedError`` at backend-resolution
or call time with the matrix above spelled out.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.perturb.stream import StreamRef
from repro.tree_utils import PyTree


class BackendMismatchError(RuntimeError):
    """A seed-replay artifact (ledger / checkpoint) was produced under one
    perturbation backend and is being replayed under another.  The two
    backends generate *different* z for the same StreamRef, so continuing
    would silently reconstruct different parameters — refuse instead."""


def check_replay_backend(recorded: Optional[str], active: Optional[str],
                         what: str) -> None:
    """Raise ``BackendMismatchError`` if a recorded artifact's backend does
    not match the active optimizer's.  ``None`` on either side (a pre-backend
    artifact, or a non-ZO optimizer) skips the check.

    Recorded identities are the backend's ``stream_id`` — the registry name
    plus a ``+zN`` suffix whenever the backend's z-generator arithmetic has
    been revised (same name, different bits).  A same-name different-version
    mismatch gets its own message: selecting another backend cannot fix it."""
    if recorded is None or active is None:
        return
    if recorded != active:
        if recorded.partition("+z")[0] == active.partition("+z")[0]:
            raise BackendMismatchError(
                f"{what} was recorded under z-stream {recorded!r} but this "
                f"build's {active.partition('+z')[0]!r} backend generates "
                f"{active!r}: the backend's z-generator arithmetic changed "
                "between versions, so replay would silently reconstruct "
                "different parameters.  Resume from a full tensor checkpoint "
                "(or re-run) instead of replaying this artifact.")
        raise BackendMismatchError(
            f"{what} was recorded under the {recorded!r} perturbation backend "
            f"but is being replayed under {active!r}; the backends generate "
            "different z streams for the same seed, so replay would silently "
            "reconstruct different parameters.  Re-create the optimizer with "
            f"backend={recorded!r} (e.g. zo.mezo(..., backend={recorded!r})).")


def per_stream_scales(scale, n_refs: int):
    """Normalize ``perturb_many``'s ``scale`` argument: ``None`` for a shared
    scalar (the historical contract — backends keep their original batched
    graph for it), else the per-stream list.  A 1-D sequence/array must have
    one entry per ref."""
    if isinstance(scale, (tuple, list)):
        per = list(scale)
    elif jnp.ndim(scale) == 1:
        per = [scale[j] for j in range(scale.shape[0])]
    else:
        return None
    if len(per) != n_refs:
        raise ValueError(
            f"per-stream scale has {len(per)} entries for {n_refs} refs")
    return per


class PerturbBackend:
    """Interface.  All parameter-writing methods take a ``StreamRef`` and
    regenerate z internally — z is never part of any signature.

    ``dists`` declares the supported distribution set; ``check_dist`` is the
    loud-failure gate (see the matrix in the module docstring).
    """

    name: str = "?"
    dists: frozenset = frozenset()
    # bump when the backend's z-generator arithmetic changes (same name,
    # different bits): artifacts record stream_id, and replay of an
    # older-version artifact refuses instead of silently diverging
    stream_version: int = 1

    @property
    def stream_id(self) -> str:
        """Identity recorded in ledger/checkpoint metadata: the registry name
        plus ``+zN`` for revised z-generator arithmetic (v1 stays bare so
        existing artifacts keep their recorded identity)."""
        return (self.name if self.stream_version == 1
                else f"{self.name}+z{self.stream_version}")

    def check_dist(self, dist: str) -> None:
        if dist not in self.dists:
            raise NotImplementedError(
                f"perturbation backend {self.name!r} does not implement "
                f"dist={dist!r} (supported: {sorted(self.dists)}).  "
                "Distribution matrix — xla: gaussian/rademacher/sphere; "
                "pallas: gaussian/rademacher/sphere (kernel-fused two-pass "
                "rescale).  Use backend='xla' for this dist.")

    # -- core tree operations ----------------------------------------------- #
    def perturb(self, params: PyTree, ref: StreamRef, scale,
                dist: str = "gaussian") -> PyTree:
        """θ + scale · z(ref) — the paper's ``PerturbParameters``."""
        raise NotImplementedError

    def fused_restore_update(self, params_minus: PyTree, ref: StreamRef, eps,
                             lr_g, weight_decay=0.0,
                             dist: str = "gaussian") -> PyTree:
        """From θ − εz produce (1 − η·λ)·θ − η·g·z in one pass (the fusion of
        Algorithm 1's reset and descent loops).  ``weight_decay`` is the
        decoupled decay *term* η·λ."""
        raise NotImplementedError

    def apply_rank1(self, params: PyTree, ref: StreamRef, coeff,
                    decay_term=0.0, dist: str = "gaussian",
                    d_tree: Optional[PyTree] = None) -> PyTree:
        """θ ← (1 − decay_term)·θ − coeff·z(ref)  [z optionally ⊙ d per leaf].
        The single primitive shared by live steps, ledger replay, and async
        application — one implementation per backend keeps all three
        bitwise-consistent."""
        raise NotImplementedError

    def leaf_z(self, ref: StreamRef, leaf_index: int, like: jnp.ndarray,
               dist: str = "gaussian") -> jnp.ndarray:
        """Materialize one leaf's z (shape/dtype of ``like``).  Escape hatch
        for consumers that combine z non-affinely (rescaled-SPSA's d⁻¹⊙z
        perturbation, the materializing ZO-Adam path)."""
        raise NotImplementedError

    # -- batched multi-seed entry point (FZOO-style estimators) ------------- #
    def perturb_many(self, params: PyTree, refs: Sequence[StreamRef], scale,
                     dist: str = "gaussian") -> PyTree:
        """θ + scale_j · z(ref_j) for each ref, stacked on a new leading axis:
        each leaf of the result has shape ``(len(refs), *leaf.shape)``.
        ``scale`` is a shared scalar, or a length-``len(refs)`` sequence of
        per-stream scalars (the ±ε antithetic fan-out of two-point SPSA).

        Default implementation stacks per-ref ``perturb`` calls — bitwise
        identical to the sequential path by construction.  Both shipped
        backends override it with genuinely vectorized generation (``xla``:
        vmapped threefry over stacked keys; ``pallas``: the batched-seed /
        fused-multi kernel, B z-streams per VMEM tile) under the contract
        that the result stays bitwise-equal to stacked singles — the
        extension point batched-seed estimators (``zo.fzoo``; FZOO, Dang
        et al., 2025) build on."""
        self.check_dist(dist)
        if not refs:
            raise ValueError("perturb_many needs at least one StreamRef")
        per = per_stream_scales(scale, len(refs))
        cols = [self.perturb(params, r, scale if per is None else per[j],
                             dist) for j, r in enumerate(refs)]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cols)

    # -- fused multi-stream write path (one pass, B chained rank-1s) -------- #
    def affine_many(self, params: PyTree, refs: Sequence[StreamRef],
                    coeffs: Sequence, decay_terms: Sequence,
                    dist: str = "gaussian") -> PyTree:
        """The chained multi-stream rank-1 update — the one multi-seed write
        path:

            for j in stream order:
                θ ← (1 − decay_terms[j]) · θ − coeffs[j] · z(ref_j)

        One contract serves FZOO's B folded per-seed updates
        (``zo.updates.apply_rank1_batch``), the seed-parallel engine's
        whole-step group-update chain (``exec.engine.apply_group_updates``),
        and batched ledger replay — all three delegate here.

        This default implementation IS the ``xla`` fallback: a literal
        sequential ``apply_rank1`` fold, bitwise-identical to the pre-fusion
        write path by construction.  The ``pallas`` backend overrides it with
        the fused chain kernel (all B streams folded per resident VMEM tile —
        one HBM round-trip of θ instead of B) under the contract that the
        result stays bitwise-equal to this sequential fold."""
        self.check_dist(dist)
        if not refs:
            raise ValueError("affine_many needs at least one StreamRef")
        if not (len(refs) == len(coeffs) == len(decay_terms)):
            raise ValueError(
                f"affine_many needs one coefficient and one decay term per "
                f"stream; got {len(refs)} refs, {len(coeffs)} coeffs, "
                f"{len(decay_terms)} decay terms")
        p = params
        for ref, coeff, decay in zip(refs, coeffs, decay_terms):
            p = self.apply_rank1(p, ref, coeff, decay, dist)
        return p


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_FACTORIES: Dict[str, Callable[[], PerturbBackend]] = {}
_INSTANCES: Dict[str, PerturbBackend] = {}

BackendSpec = Union[None, str, PerturbBackend]


def register_backend(name: str, factory: Callable[[], PerturbBackend]) -> None:
    _FACTORIES[name] = factory
    _INSTANCES.pop(name, None)


def available_backends() -> list:
    return sorted(_FACTORIES)


def get_backend(spec: BackendSpec = None) -> PerturbBackend:
    """Resolve a backend: ``None`` → the session default (the
    ``REPRO_BACKEND`` environment variable, falling back to ``"xla"``); a
    string → the registry (``"xla"``, ``"pallas"``, ``"pallas-interpret"``);
    an instance → itself.  Instances are cached so every consumer of
    ``"xla"`` shares one object.

    The env hook exists for the CI matrix: ``REPRO_BACKEND=pallas pytest``
    runs every composition that didn't pin a backend through the fused
    kernel (interpret mode off-TPU), so the non-default backend is exercised
    on every push without a parallel test tree."""
    if spec is None:
        spec = os.environ.get("REPRO_BACKEND") or "xla"
    if isinstance(spec, PerturbBackend):
        return spec
    if spec not in _FACTORIES:
        raise KeyError(f"unknown perturbation backend {spec!r}; "
                       f"available: {available_backends()}")
    if spec not in _INSTANCES:
        _INSTANCES[spec] = _FACTORIES[spec]()
    return _INSTANCES[spec]
