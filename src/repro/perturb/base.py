"""``PerturbBackend`` — the interface every z-generation strategy implements.

One backend = one way of materializing (or *not* materializing) the
perturbation direction z for a parameter tree, given a ``StreamRef``.  The
estimators, the transform chain, trajectory replay, checkpoint recovery, and
the distributed paths all write parameters exclusively through these methods,
so swapping the backend swaps the memory/compute strategy of *every* existing
estimator × transform composition at once.

Supported distribution matrix (see the package docstring for the memory
story):

    ==============  ========  ==========  ========
    backend         gaussian  rademacher  sphere
    ==============  ========  ==========  ========
    ``xla``         yes       yes         yes
    ``pallas``      yes       yes         no [1]
    ==============  ========  ==========  ========

    [1] sphere needs the global sqrt(d)/‖z‖ rescale — a two-pass norm that is
        not kernel-fused yet; raising beats silently producing wrong-scale
        perturbations.

Unsupported combinations raise ``NotImplementedError`` at backend-resolution
or call time with the matrix above spelled out.
"""
from __future__ import annotations

import os
from typing import Callable, Dict, Optional, Sequence, Union

import jax
import jax.numpy as jnp

from repro.perturb.stream import StreamRef
from repro.tree_utils import PyTree


class BackendMismatchError(RuntimeError):
    """A seed-replay artifact (ledger / checkpoint) was produced under one
    perturbation backend and is being replayed under another.  The two
    backends generate *different* z for the same StreamRef, so continuing
    would silently reconstruct different parameters — refuse instead."""


def check_replay_backend(recorded: Optional[str], active: Optional[str],
                         what: str) -> None:
    """Raise ``BackendMismatchError`` if a recorded artifact's backend does
    not match the active optimizer's.  ``None`` on either side (a pre-backend
    artifact, or a non-ZO optimizer) skips the check.

    Recorded identities are the backend's ``stream_id`` — the registry name
    plus a ``+zN`` suffix whenever the backend's z-generator arithmetic has
    been revised (same name, different bits).  A same-name different-version
    mismatch gets its own message: selecting another backend cannot fix it."""
    if recorded is None or active is None:
        return
    if recorded != active:
        if recorded.partition("+z")[0] == active.partition("+z")[0]:
            raise BackendMismatchError(
                f"{what} was recorded under z-stream {recorded!r} but this "
                f"build's {active.partition('+z')[0]!r} backend generates "
                f"{active!r}: the backend's z-generator arithmetic changed "
                "between versions, so replay would silently reconstruct "
                "different parameters.  Resume from a full tensor checkpoint "
                "(or re-run) instead of replaying this artifact.")
        raise BackendMismatchError(
            f"{what} was recorded under the {recorded!r} perturbation backend "
            f"but is being replayed under {active!r}; the backends generate "
            "different z streams for the same seed, so replay would silently "
            "reconstruct different parameters.  Re-create the optimizer with "
            f"backend={recorded!r} (e.g. zo.mezo(..., backend={recorded!r})).")


class PerturbBackend:
    """Interface.  All parameter-writing methods take a ``StreamRef`` and
    regenerate z internally — z is never part of any signature.

    ``dists`` declares the supported distribution set; ``check_dist`` is the
    loud-failure gate (see the matrix in the module docstring).
    """

    name: str = "?"
    dists: frozenset = frozenset()
    # bump when the backend's z-generator arithmetic changes (same name,
    # different bits): artifacts record stream_id, and replay of an
    # older-version artifact refuses instead of silently diverging
    stream_version: int = 1

    @property
    def stream_id(self) -> str:
        """Identity recorded in ledger/checkpoint metadata: the registry name
        plus ``+zN`` for revised z-generator arithmetic (v1 stays bare so
        existing artifacts keep their recorded identity)."""
        return (self.name if self.stream_version == 1
                else f"{self.name}+z{self.stream_version}")

    def check_dist(self, dist: str) -> None:
        if dist not in self.dists:
            raise NotImplementedError(
                f"perturbation backend {self.name!r} does not implement "
                f"dist={dist!r} (supported: {sorted(self.dists)}).  "
                "Distribution matrix — xla: gaussian/rademacher/sphere; "
                "pallas: gaussian/rademacher (sphere needs a two-pass "
                "global-norm rescale that is not kernel-fused yet).  "
                "Use backend='xla' for this dist.")

    # -- core tree operations ----------------------------------------------- #
    def perturb(self, params: PyTree, ref: StreamRef, scale,
                dist: str = "gaussian") -> PyTree:
        """θ + scale · z(ref) — the paper's ``PerturbParameters``."""
        raise NotImplementedError

    def fused_restore_update(self, params_minus: PyTree, ref: StreamRef, eps,
                             lr_g, weight_decay=0.0,
                             dist: str = "gaussian") -> PyTree:
        """From θ − εz produce (1 − η·λ)·θ − η·g·z in one pass (the fusion of
        Algorithm 1's reset and descent loops).  ``weight_decay`` is the
        decoupled decay *term* η·λ."""
        raise NotImplementedError

    def apply_rank1(self, params: PyTree, ref: StreamRef, coeff,
                    decay_term=0.0, dist: str = "gaussian",
                    d_tree: Optional[PyTree] = None) -> PyTree:
        """θ ← (1 − decay_term)·θ − coeff·z(ref)  [z optionally ⊙ d per leaf].
        The single primitive shared by live steps, ledger replay, and async
        application — one implementation per backend keeps all three
        bitwise-consistent."""
        raise NotImplementedError

    def leaf_z(self, ref: StreamRef, leaf_index: int, like: jnp.ndarray,
               dist: str = "gaussian") -> jnp.ndarray:
        """Materialize one leaf's z (shape/dtype of ``like``).  Escape hatch
        for consumers that combine z non-affinely (rescaled-SPSA's d⁻¹⊙z
        perturbation, the materializing ZO-Adam path)."""
        raise NotImplementedError

    # -- batched multi-seed entry point (FZOO-style estimators) ------------- #
    def perturb_many(self, params: PyTree, refs: Sequence[StreamRef], scale,
                     dist: str = "gaussian") -> PyTree:
        """θ + scale · z(ref_j) for each ref, stacked on a new leading axis:
        each leaf of the result has shape ``(len(refs), *leaf.shape)``.

        Default implementation stacks per-ref ``perturb`` calls — bitwise
        identical to the sequential path by construction.  Both shipped
        backends override it with genuinely vectorized generation (``xla``:
        vmapped threefry over stacked keys; ``pallas``: the batched-seed
        kernel, B z-streams per VMEM tile) under the contract that the
        result stays bitwise-equal to stacked singles — the extension point
        batched-seed estimators (``zo.fzoo``; FZOO, Dang et al., 2025) build
        on."""
        self.check_dist(dist)
        if not refs:
            raise ValueError("perturb_many needs at least one StreamRef")
        cols = [self.perturb(params, r, scale, dist) for r in refs]
        return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *cols)


# --------------------------------------------------------------------------- #
# Registry
# --------------------------------------------------------------------------- #
_FACTORIES: Dict[str, Callable[[], PerturbBackend]] = {}
_INSTANCES: Dict[str, PerturbBackend] = {}

BackendSpec = Union[None, str, PerturbBackend]


def register_backend(name: str, factory: Callable[[], PerturbBackend]) -> None:
    _FACTORIES[name] = factory
    _INSTANCES.pop(name, None)


def available_backends() -> list:
    return sorted(_FACTORIES)


def get_backend(spec: BackendSpec = None) -> PerturbBackend:
    """Resolve a backend: ``None`` → the session default (the
    ``REPRO_BACKEND`` environment variable, falling back to ``"xla"``); a
    string → the registry (``"xla"``, ``"pallas"``, ``"pallas-interpret"``);
    an instance → itself.  Instances are cached so every consumer of
    ``"xla"`` shares one object.

    The env hook exists for the CI matrix: ``REPRO_BACKEND=pallas pytest``
    runs every composition that didn't pin a backend through the fused
    kernel (interpret mode off-TPU), so the non-default backend is exercised
    on every push without a parallel test tree."""
    if spec is None:
        spec = os.environ.get("REPRO_BACKEND") or "xla"
    if isinstance(spec, PerturbBackend):
        return spec
    if spec not in _FACTORIES:
        raise KeyError(f"unknown perturbation backend {spec!r}; "
                       f"available: {available_backends()}")
    if spec not in _INSTANCES:
        _INSTANCES[spec] = _FACTORIES[spec]()
    return _INSTANCES[spec]
