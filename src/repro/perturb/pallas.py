"""``pallas`` backend: z generated tile-by-tile inside VMEM by the fused
Pallas kernel — the paper's in-place trick taken one level further down the
memory hierarchy (z never exists in HBM on TPU).

Promoted from the legacy-only ``kernels/zo_fused/ops.py`` path (which was
reachable only through ``mezo_step_kernel``) to a first-class backend: every
estimator × transform composition in ``repro.zo`` can now run HBM-free by
selecting ``backend="pallas"``.

RNG: murmur3-finalizer counter hash + Box–Muller (32-bit ops only, TPU
native), seeded per leaf from ``StreamRef.leaf_seed`` — position-stable, so
the same (StreamRef, leaf) always yields the same z regardless of how the
tree around it changes or how leaves are padded to the kernel's blocked view.
The pure-jnp oracle in ``kernels/zo_fused/ref.py`` implements the identical
arithmetic bit-for-bit.

Interpret-mode fallback: off-TPU the kernel runs under
``pallas_call(..., interpret=True)`` (exact same arithmetic, evaluated with
jnp ops), so CPU CI and laptops exercise the real backend semantics.
``get_backend("pallas")`` auto-selects interpret off-TPU;
``get_backend("pallas-interpret")`` forces it (for benchmarking the overhead).

Supported distributions: gaussian (Box–Muller), rademacher (the sign of one
counter stream, generated in-kernel), and sphere — the kernel-fused two-pass
rescale: pass 1 measures ‖z‖² tile-by-tile with the ``zo_sqnorm`` kernel (z
is generated in VMEM and reduced, never materialized), pass 2 folds
sqrt(d)/‖z‖ into the affine b coefficient of any affine kernel.  The sphere
direction IS the gaussian counter stream (same salt-1/2 reads), so adding it
changes no gaussian/rademacher bits and needs no ``stream_id`` bump.

Multi-seed work goes through the fused-multi kernels
(``kernels/zo_fused/multi.py``): ``perturb_many`` fans B perturbed views out
of one HBM read of x per tile, and ``affine_many`` folds B chained rank-1
updates into one HBM round-trip of θ — both under bitwise stacked/sequential
-singles contracts.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.zo_fused.kernel import (BLOCK_COLS, BLOCK_ROWS,
                                           zo_affine_2d, zo_affine_2d_batched)
from repro.kernels.zo_fused.multi import (zo_affine_chain_2d,
                                          zo_affine_multi_2d, zo_sqnorm_2d)
from repro.kernels.zo_fused.rows import (tile_plan, zo_affine_2d_rows,
                                         zo_affine_chain_2d_rows,
                                         zo_affine_multi_2d_rows,
                                         zo_sqnorm_2d_rows)
from repro.perturb.base import PerturbBackend, per_stream_scales
from repro.perturb.stream import _LEAF_STRIDE, StreamRef
from repro.tree_utils import PyTree, tree_map_with_index


def _blocked_view(x: jnp.ndarray) -> tuple:
    """Pad/reshape an arbitrary-shape leaf to the kernel's 2-D blocked view.
    The padding tail consumes counter indices but its z values are discarded
    (the counter stream is position-stable, so the same (leaf, seed) always
    yields the same z regardless of how the tree around it changes).  One
    implementation for the single-seed and batched wrappers — the blocking
    scheme is part of the bitwise batched == singles contract."""
    n = x.size
    width = BLOCK_ROWS * BLOCK_COLS
    n_pad = ((n + width - 1) // width) * width
    return jnp.pad(x.reshape(-1), (0, n_pad - n)).reshape(-1, BLOCK_COLS), n


def _rows_plan(n: int, blocks) -> Optional[tuple]:
    """Static tile plan ``(sel_tiles, masked)`` of a *partial* sub-leaf
    ``RowBlocks``, or ``None`` for whole-leaf semantics (no plan, or every
    block selected — ``rows(..., k=1)`` must route through the unmodified
    full kernel so it stays bitwise ≡ ``full``)."""
    if blocks is None or blocks.all_selected:
        return None
    sel, pure = tile_plan(n, blocks.block_elems, blocks.k, blocks.phase)
    return sel, not pure


@functools.partial(jax.jit, static_argnames=("interpret", "dist", "blocks"))
def zo_affine(x: jnp.ndarray, seed, a, b, interpret: bool = True,
              dist: str = "gaussian", blocks=None) -> jnp.ndarray:
    """y = a·x + b·z(seed) for an arbitrary-shape leaf (blocked view, see
    ``_blocked_view``).  A partial ``blocks`` plan (``repro.select.RowBlocks``,
    static) launches only the tiles covering selected row-blocks — unselected
    rows are never read, never written, and generate no z."""
    flat2d, n = _blocked_view(x)
    plan = _rows_plan(n, blocks)
    if plan is None:
        y = zo_affine_2d(flat2d, jnp.asarray(seed, jnp.int32), a, b,
                         interpret=interpret, dist=dist)
    else:
        sel, masked = plan
        y = zo_affine_2d_rows(flat2d, jnp.asarray(seed, jnp.int32), a, b,
                              sel, blocks.block_elems, blocks.k,
                              blocks.phase, masked, interpret=interpret,
                              dist=dist)
    return y.reshape(-1)[:n].reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("interpret", "dist", "blocks"))
def zo_affine_batched(x: jnp.ndarray, seeds: jnp.ndarray, a, b,
                      interpret: bool = True,
                      dist: str = "gaussian", blocks=None) -> jnp.ndarray:
    """y[j] = a·x + b·z(seeds[j]) for an arbitrary-shape leaf, one launch.

    Same blocked/padded view as :func:`zo_affine`; the kernel's batch grid
    axis generates one z-stream per seed against each resident x tile, so the
    result's batch slices are bitwise-equal to B separate ``zo_affine`` calls
    while x is read once per tile instead of B times.  A partial ``blocks``
    plan routes through the multi-rows kernel with the shared (a, b)
    broadcast per stream — the per-tile arithmetic is the same
    ``_tile_affine`` on the same scalar values, so batch slices stay
    bitwise-equal to rows singles.
    """
    flat2d, n = _blocked_view(x)
    plan = _rows_plan(n, blocks)
    seeds = jnp.asarray(seeds, jnp.int32)
    if plan is None:
        y = zo_affine_2d_batched(flat2d, seeds, a, b,
                                 interpret=interpret, dist=dist)
    else:
        sel, masked = plan
        (batch,) = seeds.shape
        a_vec = jnp.broadcast_to(jnp.asarray(a, jnp.float32), (batch,))
        b_vec = jnp.broadcast_to(jnp.asarray(b, jnp.float32), (batch,))
        y = zo_affine_multi_2d_rows(flat2d, seeds, a_vec, b_vec, sel,
                                    blocks.block_elems, blocks.k,
                                    blocks.phase, masked,
                                    interpret=interpret, dist=dist)
    batch = y.shape[0]
    return y.reshape(batch, -1)[:, :n].reshape((batch,) + x.shape)


@functools.partial(jax.jit, static_argnames=("interpret", "dist", "blocks"))
def zo_affine_multi(x: jnp.ndarray, seeds: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, interpret: bool = True,
                    dist: str = "gaussian", blocks=None) -> jnp.ndarray:
    """y[j] = a_j·x + b_j·z(seeds[j]) for an arbitrary-shape leaf, one
    launch — :func:`zo_affine_batched` generalized to per-stream affine
    coefficients (the fused-multi fan-out kernel).  Batch slices are
    bitwise-equal to per-stream ``zo_affine`` singles, sub-leaf plans
    included."""
    flat2d, n = _blocked_view(x)
    plan = _rows_plan(n, blocks)
    if plan is None:
        y = zo_affine_multi_2d(flat2d, jnp.asarray(seeds, jnp.int32), a, b,
                               interpret=interpret, dist=dist)
    else:
        sel, masked = plan
        y = zo_affine_multi_2d_rows(flat2d, jnp.asarray(seeds, jnp.int32),
                                    a, b, sel, blocks.block_elems, blocks.k,
                                    blocks.phase, masked,
                                    interpret=interpret, dist=dist)
    batch = y.shape[0]
    return y.reshape(batch, -1)[:, :n].reshape((batch,) + x.shape)


@functools.partial(jax.jit, static_argnames=("interpret", "dist", "blocks"))
def zo_affine_chain(x: jnp.ndarray, seeds: jnp.ndarray, a: jnp.ndarray,
                    b: jnp.ndarray, interpret: bool = True,
                    dist: str = "gaussian", blocks=None) -> jnp.ndarray:
    """Chained y = fold_j (a_j·y + b_j·z(seeds[j])) for an arbitrary-shape
    leaf in ONE launch — bitwise-equal to the sequential per-stream
    ``zo_affine`` chain while x round-trips HBM once instead of B times.
    Under a partial ``blocks`` plan only selected tiles fold; unselected
    rows keep their bits."""
    flat2d, n = _blocked_view(x)
    plan = _rows_plan(n, blocks)
    if plan is None:
        y = zo_affine_chain_2d(flat2d, jnp.asarray(seeds, jnp.int32), a, b,
                               interpret=interpret, dist=dist)
    else:
        sel, masked = plan
        y = zo_affine_chain_2d_rows(flat2d, jnp.asarray(seeds, jnp.int32),
                                    a, b, sel, blocks.block_elems, blocks.k,
                                    blocks.phase, masked,
                                    interpret=interpret, dist=dist)
    return y.reshape(-1)[:n].reshape(x.shape)


def leaf_seed(seed: int, leaf_idx: int) -> jnp.ndarray:
    """Legacy per-leaf counter-seed schedule (kept bit-compatible; the same
    stride now lives in ``StreamRef.leaf_seed``)."""
    return jnp.asarray(seed, jnp.int32) + jnp.int32(_LEAF_STRIDE) * jnp.int32(leaf_idx)


def perturb_tree(params: PyTree, seed, scale, interpret: bool = True) -> PyTree:
    """θ + scale·z over a pytree (kernel-backed analogue of the xla perturb)."""
    return tree_map_with_index(
        lambda i, p: zo_affine(p, leaf_seed(seed, i), 1.0, scale,
                               interpret=interpret)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def update_tree(params: PyTree, seed, projected_grad, lr,
                weight_decay: float = 0.0, interpret: bool = True) -> PyTree:
    """θ·(1−ηλ) − η·g·z over a pytree (Algorithm 1's descent loop)."""
    a = 1.0 - lr * weight_decay
    return tree_map_with_index(
        lambda i, p: zo_affine(p, leaf_seed(seed, i), a, -lr * projected_grad,
                               interpret=interpret)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def mezo_step_kernel(loss_fn, params: PyTree, batch, seed: int, eps: float,
                     lr: float, weight_decay: float = 0.0,
                     interpret: bool = True):
    """One full MeZO step with every perturbation running through the Pallas
    kernel.  Legacy entry point — new code composes ``zo.mezo(...,
    backend="pallas")`` instead, which routes the same kernel through the
    estimator × transform protocol."""
    p_plus = perturb_tree(params, seed, eps, interpret)
    l_plus = loss_fn(p_plus, batch)
    p_minus = perturb_tree(p_plus, seed, -2.0 * eps, interpret)
    l_minus = loss_fn(p_minus, batch)
    g = (l_plus - l_minus) / (2.0 * eps)
    restored = perturb_tree(p_minus, seed, eps, interpret)
    new_params = update_tree(restored, seed, g, lr, weight_decay, interpret)
    return new_params, g, 0.5 * (l_plus + l_minus)


# --------------------------------------------------------------------------- #
# Backend adapter
# --------------------------------------------------------------------------- #
class PallasBackend(PerturbBackend):
    """Fused-kernel z streams: VMEM generation on TPU, interpret mode off-TPU.

    Selection-aware: a ``StreamRef`` carrying a ``repro.select.Selection``
    scopes every method to the selected leaves — unselected leaves get no
    kernel launch at all (zero z generation, zero writes)."""

    name = "pallas"
    dists = frozenset({"gaussian", "rademacher", "sphere"})
    # z2: transcendental-free polynomial Box–Muller (deterministic across
    # jitted graphs).  z1 artifacts (jnp.log/cos bits) refuse to replay.
    # (The in-kernel rademacher stream landed under z2 — a new dist adds a
    # stream, it does not change the gaussian bits, so no bump.  sphere is
    # the gaussian stream × a wrapper-level sqrt(d)/‖z‖ scalar — the counter
    # reads are identical, so again no bump.)
    stream_version = 2

    def __init__(self, interpret: Optional[bool] = None):
        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        self.interpret = bool(interpret)

    def _pin_scalars(self, *vals):
        """Pin the affine coefficients' rounding under interpret mode.

        The scalar algebra feeding the kernel (a = 1 − η·λ, b = decay·ε − η·g)
        contains mul-feeding-add/sub patterns that XLA may or may not contract
        into FMAs depending on the surrounding graph — a 1-ulp difference in
        ``a`` shifts every parameter by ~ulp(θ), breaking the bitwise
        live-step == ledger-replay contract.  Barriering the operands forces
        the separately-rounded form in every graph (see kernel.py's ``_pin``).
        """
        vals = tuple(jnp.asarray(v, jnp.float32) for v in vals)
        if not self.interpret:
            return vals
        return jax.lax.optimization_barrier(vals)

    @staticmethod
    def _leaf_blocks(blocks, i: int):
        """Static sub-leaf plan of leaf ``i``, or ``None`` for whole-leaf
        semantics (no ``rows`` selection, or every block selected — the
        route that keeps ``rows(..., k=1)`` bitwise ≡ ``full``)."""
        if blocks is None:
            return None
        rb = blocks[i]
        if rb is None or rb.all_selected:
            return None
        return rb

    def _map(self, params: PyTree, ref: StreamRef, fn) -> PyTree:
        seed = ref.counter_seed()
        mask = ref.selection_mask(params)
        blocks = ref.selection_blocks(params)
        return tree_map_with_index(
            lambda i, p: fn(p, leaf_seed(seed, i), i,
                            self._leaf_blocks(blocks, i))
            if jnp.issubdtype(p.dtype, jnp.floating)
            and (mask is None or mask[i]) else p, params)

    def _sphere_scale(self, params: PyTree, ref: StreamRef) -> jnp.ndarray:
        """sqrt(d)/‖z(ref)‖ over the selected floating leaves — pass 1 of the
        kernel-fused two-pass sphere rescale.  ‖z‖² is accumulated leaf by
        leaf by the ``zo_sqnorm`` kernel on the SAME per-leaf counter streams
        the affine kernels read (z is generated in VMEM and reduced, never
        materialized); d counts the same subspace.  Under a sub-leaf plan the
        sphere lives in the selected row-blocks: the ``zo_sqnorm_rows``
        kernel visits only selected tiles, and d counts selected elements.
        Every float stage is pinned so the scalar rounds identically in
        every consuming graph (perturb / fused restore / rank-1 / the fused
        multi passes) — the live == replay bitwise contract extends to
        sphere."""
        seed = ref.counter_seed()
        mask = ref.selection_mask(params)
        blocks = ref.selection_blocks(params)
        d = 0
        sq = None
        for i, p in enumerate(jax.tree_util.tree_leaves(params)):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                continue
            if mask is not None and not mask[i]:
                continue
            rb = self._leaf_blocks(blocks, i)
            if rb is None:
                d += int(p.size)
                part = zo_sqnorm_2d(int(p.size), leaf_seed(seed, i),
                                    interpret=self.interpret)
            else:
                sel, _ = tile_plan(int(p.size), rb.block_elems, rb.k,
                                   rb.phase)
                d += rb.selected_elems()
                part = zo_sqnorm_2d_rows(int(p.size), leaf_seed(seed, i),
                                         sel, rb.block_elems, rb.k, rb.phase,
                                         interpret=self.interpret)
            sq = part if sq is None else self._pin_scalars(sq + part)[0]
        if sq is None:
            raise ValueError(
                "sphere perturbation needs at least one selected floating "
                "leaf (the sqrt(d)/‖z‖ rescale is undefined on an empty "
                "subspace)")
        (ratio,) = self._pin_scalars(jnp.float32(d) / sq)
        return self._pin_scalars(jnp.sqrt(ratio))[0]

    def perturb(self, params: PyTree, ref: StreamRef, scale,
                dist: str = "gaussian") -> PyTree:
        self.check_dist(dist)
        if dist == "sphere":
            # pass 2: the global rescale rides the affine b coefficient of
            # the plain gaussian-stream kernel — one extra scalar mul, no
            # second z generation
            (b,) = self._pin_scalars(
                jnp.asarray(scale, jnp.float32) *
                self._sphere_scale(params, ref))
            return self._map(params, ref,
                             lambda p, s, i, rb: zo_affine(
                                 p, s, 1.0, b, interpret=self.interpret,
                                 dist="gaussian", blocks=rb))
        return self._map(params, ref,
                         lambda p, s, i, rb: zo_affine(
                             p, s, 1.0, scale, interpret=self.interpret,
                             dist=dist, blocks=rb))

    def fused_restore_update(self, params_minus: PyTree, ref: StreamRef, eps,
                             lr_g, weight_decay=0.0,
                             dist: str = "gaussian") -> PyTree:
        # decay·(θ − εz + εz) − η·g·z  =  decay·θ_minus + (decay·ε − η·g)·z:
        # restore AND descent collapse into a single kernel pass per leaf
        # (one z regeneration, never in HBM) — one fewer pass than the xla
        # backend needs for the same fusion.  Unselected leaves were never
        # perturbed and pass through completely (decay included).
        self.check_dist(dist)
        eps_, lr_g_, wd_ = self._pin_scalars(eps, lr_g, weight_decay)
        decay = 1.0 - wd_
        (de,) = self._pin_scalars(decay * eps_)
        b = de - lr_g_
        kdist = dist
        if dist == "sphere":
            (b,) = self._pin_scalars(
                b * self._sphere_scale(params_minus, ref))
            kdist = "gaussian"
        return self._map(params_minus, ref,
                         lambda p, s, i, rb: zo_affine(
                             p, s, decay, b, interpret=self.interpret,
                             dist=kdist, blocks=rb))

    def apply_rank1(self, params: PyTree, ref: StreamRef, coeff,
                    decay_term=0.0, dist: str = "gaussian",
                    d_tree: Optional[PyTree] = None) -> PyTree:
        self.check_dist(dist)
        coeff_, decay_ = self._pin_scalars(coeff, decay_term)
        a = 1.0 - decay_
        d_leaves = (jax.tree_util.tree_leaves(d_tree)
                    if d_tree is not None else None)
        # unlike xla's apply_rank1 (whose sphere callers pre-scale the
        # coefficient), the pallas primitive applies the sphere rescale
        # itself — live steps, affine_many, and ledger replay all route
        # through here, so the scalar is folded identically everywhere
        sph = self._sphere_scale(params, ref) if dist == "sphere" else None
        kdist = "gaussian" if dist == "sphere" else dist

        def one(p, s, i, rb):
            b = -coeff_ if d_leaves is None else -coeff_ * d_leaves[i]
            if sph is not None:
                (b,) = self._pin_scalars(b * sph)
            return zo_affine(p, s, a, b, interpret=self.interpret, dist=kdist,
                             blocks=rb)

        return self._map(params, ref, one)

    def leaf_z(self, ref: StreamRef, leaf_index: int, like: jnp.ndarray,
               dist: str = "gaussian") -> jnp.ndarray:
        self.check_dist(dist)
        zeros = jnp.zeros(like.shape, like.dtype if
                          jnp.issubdtype(like.dtype, jnp.floating)
                          else jnp.float32)
        # sphere: direction only, like the xla backend — the global
        # sqrt(d)/‖z‖ rescale needs the full tree and is applied by callers
        kdist = "gaussian" if dist == "sphere" else dist
        return zo_affine(zeros, ref.leaf_seed(leaf_index), 0.0, 1.0,
                         interpret=self.interpret, dist=kdist)

    def perturb_many(self, params: PyTree, refs: Sequence[StreamRef], scale,
                     dist: str = "gaussian") -> PyTree:
        """Genuinely batched θ + scale_j·z(ref_j): one kernel launch per leaf
        generates B z-streams per VMEM tile (x read once per tile) —
        bitwise-equal to stacking per-ref ``perturb`` calls, contract-tested
        in tests/test_perturb_backend.py.  A shared scalar ``scale`` runs the
        original batched kernel; per-stream scales (and sphere, whose
        per-stream ‖z_j‖ rescales differ) run the fused-multi fan-out with
        per-stream b_j.  Unselected leaves get no launch — they ride along
        as a copy-free broadcast view, bitwise what stacking masked singles
        yields."""
        self.check_dist(dist)
        if not refs:
            raise ValueError("perturb_many needs at least one StreamRef")
        mask = refs[0].selection_mask(params)
        blocks = refs[0].selection_blocks(params)
        seeds0 = jnp.stack([r.counter_seed() for r in refs])
        per = per_stream_scales(scale, len(refs))
        kdist = dist
        if dist == "sphere":
            base = [scale] * len(refs) if per is None else per
            per = [self._pin_scalars(jnp.asarray(s, jnp.float32) *
                                     self._sphere_scale(params, r))[0]
                   for s, r in zip(base, refs)]
            kdist = "gaussian"
        if per is not None:
            b_vec = jnp.stack([jnp.asarray(s, jnp.float32) for s in per])
            a_vec = jnp.ones_like(b_vec)

        def one(i, p):
            if not jnp.issubdtype(p.dtype, jnp.floating) or \
                    (mask is not None and not mask[i]):
                return jnp.broadcast_to(p, (len(refs),) + p.shape)
            seeds = seeds0 + jnp.int32(_LEAF_STRIDE) * jnp.int32(i)
            rb = self._leaf_blocks(blocks, i)
            if rb is not None:
                # partial sub-leaf plan: stack per-stream single-rows
                # launches — the EXACT graph ``perturb`` runs per stream, so
                # the bitwise many ≡ stacked-singles contract holds by
                # construction.  (The multi-rows kernel is bitwise against
                # the full multi kernel, but pairing it with the single-rows
                # graph trips the cross-graph FMA-contraction caveat in
                # kernel.py's ``_pin`` — ~1 ulp on rare elements — so the
                # fan-out fusion is not used here.)  Tiles are still
                # trace-time skipped: B × selected bytes, never B × leaf.
                bs = ([jnp.asarray(scale, jnp.float32)] * len(refs)
                      if per is None else
                      [b_vec[j] for j in range(len(refs))])
                return jnp.stack([
                    zo_affine(p, seeds[j], 1.0, bs[j],
                              interpret=self.interpret, dist=kdist,
                              blocks=rb)
                    for j in range(len(refs))])
            if per is None:
                return zo_affine_batched(p, seeds, 1.0, scale,
                                         interpret=self.interpret, dist=kdist)
            return zo_affine_multi(p, seeds, a_vec, b_vec,
                                   interpret=self.interpret, dist=kdist)

        return tree_map_with_index(one, params)

    def affine_many(self, params: PyTree, refs: Sequence[StreamRef],
                    coeffs: Sequence, decay_terms: Sequence,
                    dist: str = "gaussian") -> PyTree:
        """The fused chain kernel: all B streams of the multi-seed update
        chain folded per resident VMEM tile — θ round-trips HBM once instead
        of B times.  Bitwise-equal to the base class's sequential
        ``apply_rank1`` fold (contract-tested): per-stream scalars are pinned
        exactly as ``apply_rank1`` pins them, and the chain kernel casts to
        the leaf dtype between streams, reproducing the write/read rounding
        boundary of B separate launches."""
        self.check_dist(dist)
        if not refs:
            raise ValueError("affine_many needs at least one StreamRef")
        if not (len(refs) == len(coeffs) == len(decay_terms)):
            raise ValueError(
                f"affine_many needs one coefficient and one decay term per "
                f"stream; got {len(refs)} refs, {len(coeffs)} coeffs, "
                f"{len(decay_terms)} decay terms")
        mask = refs[0].selection_mask(params)
        blocks = refs[0].selection_blocks(params)
        seeds0 = jnp.stack([r.counter_seed() for r in refs])
        kdist = "gaussian" if dist == "sphere" else dist
        a_list, b_list = [], []
        for j, ref in enumerate(refs):
            coeff_, decay_ = self._pin_scalars(coeffs[j], decay_terms[j])
            a = 1.0 - decay_
            b = -coeff_
            if dist == "sphere":
                # ‖z_j‖ depends only on (seed_j, leaf sizes, mask), never on
                # the evolving θ — the chained fold sees the exact scalars
                # the sequential one would
                (b,) = self._pin_scalars(b * self._sphere_scale(params, ref))
            a_list.append(jnp.asarray(a, jnp.float32))
            b_list.append(jnp.asarray(b, jnp.float32))
        a_vec, b_vec = jnp.stack(a_list), jnp.stack(b_list)

        def one(i, p):
            if not jnp.issubdtype(p.dtype, jnp.floating) or \
                    (mask is not None and not mask[i]):
                return p
            seeds = seeds0 + jnp.int32(_LEAF_STRIDE) * jnp.int32(i)
            return zo_affine_chain(p, seeds, a_vec, b_vec,
                                   interpret=self.interpret, dist=kdist,
                                   blocks=self._leaf_blocks(blocks, i))

        return tree_map_with_index(one, params)
