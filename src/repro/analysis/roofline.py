"""Roofline terms from a compiled dry-run artifact (no real hardware).

    compute term    = HLO_FLOPs_per_chip / peak_FLOPs
    memory term     = HLO_bytes_per_chip / HBM_bw
    collective term = per-chip link bytes / link_bw

``compiled.cost_analysis()`` on the post-SPMD module reports the *per-device*
program, so terms are per-chip directly (equivalent to the global/chips form
in the spec).  Collective bytes are parsed from the compiled HLO text:
per-chip link traffic ≈ factor · operand_bytes with the standard ring
factors (all-reduce 2×, all-gather/reduce-scatter/all-to-all/permute 1×).

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import json
import re
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"%?([\w.\-]+)\s*=\s*(\(?[a-z0-9e\[\],{}\s/]*?\)?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(", re.IGNORECASE)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")

_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
           "all-to-all": 1.0, "collective-permute": 1.0}


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-chip link-byte estimate + op counts from compiled (per-device) HLO."""
    stats: dict = {k: {"count": 0, "bytes": 0} for k in _FACTOR}
    for line in hlo_text.splitlines():
        line_s = line.strip()
        m = _COLL_RE.search(line_s)
        if not m:
            continue
        kind = m.group(3).lower()
        if m.group(4) == "-done":
            continue  # paired with -start; avoid double counting
        # result shape(s) appear between '=' and the op name
        pre = line_s.split("=", 1)[1].split(kind)[0]
        rbytes = _shape_bytes(pre)
        stats[kind]["count"] += 1
        stats[kind]["bytes"] += int(rbytes * _FACTOR[kind])
    stats["total_bytes"] = sum(v["bytes"] for k, v in stats.items()
                               if isinstance(v, dict))
    stats["total_count"] = sum(v["count"] for k, v in stats.items()
                               if isinstance(v, dict))
    return stats


@dataclasses.dataclass
class Roofline:
    arch: str
    cell: str
    mesh: str
    chips: int
    flops_per_chip: float
    hbm_bytes_per_chip: float
    link_bytes_per_chip: float
    model_flops: int
    model_flops_6nd: int
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    step_s: float = 0.0
    useful_ratio: float = 0.0
    roofline_fraction: float = 0.0
    collectives: Optional[dict] = None
    memory_analysis: Optional[dict] = None

    def finalize(self) -> "Roofline":
        self.compute_s = self.flops_per_chip / PEAK_FLOPS
        self.memory_s = self.hbm_bytes_per_chip / HBM_BW
        self.collective_s = self.link_bytes_per_chip / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        # overlap model: perfectly-overlapped roofline step = max of terms
        self.step_s = max(terms.values())
        total_hlo_flops = self.flops_per_chip * self.chips
        self.useful_ratio = (self.model_flops / total_hlo_flops
                             if total_hlo_flops else 0.0)
        ideal_s = self.model_flops / (self.chips * PEAK_FLOPS)
        self.roofline_fraction = ideal_s / self.step_s if self.step_s else 0.0
        return self

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self))


def from_compiled(arch: str, cell: str, mesh_name: str, chips: int,
                  compiled, model_fl: dict) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):       # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = collective_stats(hlo) if hlo else {"total_bytes": 0}
    mem = None
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            mem = {k: int(getattr(ma, k)) for k in
                   ("argument_size_in_bytes", "output_size_in_bytes",
                    "temp_size_in_bytes", "generated_code_size_in_bytes")
                   if hasattr(ma, k)}
    except Exception:
        mem = None
    r = Roofline(arch=arch, cell=cell, mesh=mesh_name, chips=chips,
                 flops_per_chip=flops, hbm_bytes_per_chip=hbm,
                 link_bytes_per_chip=float(coll.get("total_bytes", 0)),
                 model_flops=model_fl["model_flops"],
                 model_flops_6nd=model_fl["model_flops_6nd"],
                 collectives=coll, memory_analysis=mem)
    return r.finalize()
