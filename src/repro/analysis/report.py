"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables."""
from __future__ import annotations

import json
from collections import OrderedDict


def load_latest(path: str, mesh: str | None = None, tag: str | None = None) -> dict:
    latest: "OrderedDict[tuple, dict]" = OrderedDict()
    with open(path) as f:
        for line in f:
            r = json.loads(line)
            if mesh and r.get("mesh") != mesh:
                continue
            if (r.get("tag") or "") != (tag or ""):
                continue
            latest[(r["arch"], r["cell"], r["mesh"])] = r
    return latest


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.0f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def roofline_table(path: str = "results/dryrun.jsonl", mesh: str = "single",
                   tag: str | None = None) -> str:
    rows = []
    header = ("| arch | cell | compute | memory | collective | bottleneck "
              "| MODEL_FLOPs | useful | roofline |")
    sep = "|---|---|---|---|---|---|---|---|---|"
    for (arch, cell, _), r in load_latest(path, mesh, tag).items():
        if r["status"] != "ok":
            rows.append(f"| {arch} | {cell} | — | — | — | FAILED | — | — | — |")
            continue
        rows.append(
            f"| {arch} | {cell} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['bottleneck']} | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.3f} | {r['roofline_fraction']:.3f} |")
    return "\n".join([header, sep] + rows)


def pick_hillclimb_cells(path: str = "results/dryrun.jsonl") -> dict:
    """worst roofline fraction / most collective-bound / paper-representative."""
    recs = [r for r in load_latest(path, "single").values()
            if r["status"] == "ok"]
    worst = min(recs, key=lambda r: r["roofline_fraction"] or 1.0)
    colls = [r for r in recs if r["collective_s"] > 0]
    most_coll = max(colls, key=lambda r: r["collective_s"] /
                    max(r["step_s"], 1e-12)) if colls else None
    return {"worst": (worst["arch"], worst["cell"]),
            "most_collective": (most_coll["arch"], most_coll["cell"])
            if most_coll else None}


if __name__ == "__main__":
    import sys
    mesh = sys.argv[1] if len(sys.argv) > 1 else "single"
    print(roofline_table(mesh=mesh))
    print()
    print(pick_hillclimb_cells())
