"""Analytic MODEL_FLOPS (the 'useful' compute) per architecture × cell.

Dense LM training: 6·N·D (N = params minus embedding table, D = tokens)
— the standard Chinchilla accounting (fwd 2ND + bwd 4ND).  MeZO performs
*two forwards + a rank-1 update* instead of fwd+bwd, so its useful compute is
4·N·D + Θ(N) ≈ 4·N·D; we report both so the MODEL_FLOPS/HLO_FLOPS ratio is
meaningful for either optimizer.  MoE uses N_active.  Decode: D = new tokens
(B·1), plus attention reads of the cache accounted separately.
"""
from __future__ import annotations

from repro.models.config import ModelConfig, ShapeCell


def backbone_params(cfg: ModelConfig, active: bool = False) -> int:
    """Matmul-participating params (excludes the embedding gather, includes
    the vocab head since logits are a matmul)."""
    n = cfg.n_active_params() if active else cfg.n_params()
    # embedding gather is not a matmul; head is.
    return n - cfg.padded_vocab * cfg.d_model * (1 if not cfg.tie_embeddings else 0)


def attention_flops(cfg: ModelConfig, batch: int, q_len: int, kv_len: int) -> int:
    """2 · (QK^T + PV) matmul flops over all layers/heads."""
    if cfg.family == "ssm":
        # WKV recurrence: per token per head: 3·hd·hd mults (state update + out)
        H, hd = cfg.n_heads, cfg.hd
        return 2 * 3 * cfg.n_layers * batch * q_len * H * hd * hd
    eff_kv = kv_len if cfg.sliding_window == 0 else min(kv_len, cfg.sliding_window)
    fl = 2 * 2 * cfg.n_layers * batch * q_len * eff_kv * cfg.n_heads * cfg.hd
    if cfg.family == "hybrid":
        SH, hd, N = cfg.ssm_heads, cfg.hd, cfg.ssm_state
        fl += 2 * 3 * cfg.n_layers * batch * q_len * SH * hd * N
    if cfg.family == "encdec":
        # cross attention: q_len x enc_len (enc_len ~ kv_len for train/prefill)
        fl += 2 * 2 * cfg.n_layers * batch * q_len * kv_len * cfg.n_heads * cfg.hd
    return fl


def model_flops(cfg: ModelConfig, cell: ShapeCell, optimizer: str = "mezo") -> dict:
    """Returns {'model_flops', 'model_flops_6nd', 'tokens'} for the cell."""
    B, S = cell.global_batch, cell.seq_len
    N = backbone_params(cfg, active=True)
    if cell.kind == "train":
        tokens = B * S
        fwd = 2 * N * tokens + attention_flops(cfg, B, S, S)
        if optimizer == "mezo":
            useful = 2 * fwd            # two forward passes, O(N) update
        else:
            useful = 3 * fwd            # fwd + ~2x bwd
        six_nd = 6 * N * tokens
    elif cell.kind == "prefill":
        tokens = B * S
        useful = 2 * N * tokens + attention_flops(cfg, B, S, S)
        six_nd = 2 * N * tokens
    else:  # decode: one token against a seq_len cache
        tokens = B
        useful = 2 * N * tokens + attention_flops(cfg, B, 1, S)
        six_nd = 2 * N * tokens
    return {"model_flops": int(useful), "model_flops_6nd": int(six_nd),
            "tokens": int(tokens), "backbone_params_active": int(N),
            "total_params": int(cfg.n_params())}
