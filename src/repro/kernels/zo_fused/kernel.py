"""Pallas TPU kernel for MeZO's fused perturb/update: y = a·x + b·z(seed).

This is the paper's in-place trick taken one level further down the memory
hierarchy: the Gaussian direction z is generated *inside VMEM*, tile by tile,
from a counter-based hash of (seed, global element index) — z never exists in
HBM at all.  One kernel serves all three uses in Algorithm 1 via the affine
scalars:

    perturb  +ε :  a = 1,        b = +ε
    perturb −2ε :  a = 1,        b = −2ε
    update      :  a = 1 − η·λ,  b = −η·g     (g = projected gradient)

RNG: a murmur3-finalizer counter hash (32-bit ops only — TPU native) feeding
a Box–Muller transform built from transcendental-free polynomial log/cos
(``_det_log`` / ``_det_cos2pi``) with per-stage rounding pins (``_pin``), so
every jitted graph — single-seed kernel, batched kernel, train step, ledger
replay, and the pure-jnp oracle in ref.py — generates bit-identical z.
``dist="rademacher"`` swaps Box–Muller for the sign of one counter stream
(``rademacher_from_counter``): comparison + select, no rounding at all.

Grid: 1-D over row-blocks of the (padded) 2-D view; BlockSpec keeps one
(block_rows × 128·lane_cols) tile of x and y in VMEM (~256 KB at f32).
``zo_affine_2d_batched`` adds an inner batch grid axis: B z-streams are
generated against each resident x tile (the ``perturb_many`` entry point for
batched-seed estimators).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
BLOCK_COLS = 512          # multiple of 128 lanes


def _murmur_mix(h: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (uint32)."""
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def _pin(val, pin: bool):
    """Materialize ``val`` behind an optimization barrier when ``pin``.

    Interpret-mode kernels are inlined jnp, and XLA CPU's rounding for the
    "same" arithmetic can differ between differently-shaped graphs — the
    single-seed vs the batched kernel, the live train step vs the jitted
    ledger replay — because fusion decides cluster shapes and the clusters
    decide codegen.  The stage barriers keep each float stage in its own
    uniformly-shaped cluster, which (together with the transcendental-free z
    generator below) makes every JITTED graph produce identical z bits.
    Note the limits: LLVM-level FMA contraction happens after barriers are
    erased, so op-by-op EAGER execution (no patterns to contract) can still
    differ from jitted graphs by 1 ulp on rare elements — bitwise contracts
    therefore compare jitted computations only.  Mosaic TPU has no
    optimization_barrier lowering, so compiled kernels pass ``pin=False``
    (bitwise contracts are asserted under interpret mode only)."""
    return jax.lax.optimization_barrier(val) if pin else val


def counter_uniform(idx: jnp.ndarray, seed: jnp.ndarray, salt: int,
                    pin: bool = False) -> jnp.ndarray:
    """uint32 counter + seed + salt -> uniform f32 in (0, 1)."""
    h = idx * jnp.uint32(0x9E3779B1)                 # golden-ratio spread
    h = h ^ (seed * jnp.uint32(0x7FEB352D))
    h = h + jnp.uint32(salt) * jnp.uint32(0x846CA68B)
    h = _murmur_mix(h)
    # 24 mantissa-ish bits -> (0,1); +1 avoids exactly 0 for the log
    u = _pin((h >> jnp.uint32(8)).astype(jnp.float32), pin)
    return u * (1.0 / 16777216.0) + (0.5 / 16777216.0)


_LN2 = 0.6931471805599453


def _det_log(u: jnp.ndarray, pin: bool) -> jnp.ndarray:
    """Deterministic ln(u) for u in (0, 1) from basic float ops only.

    ``jnp.log``'s rounding on XLA:CPU depends on which codegen path the
    fusion cluster takes (vectorized polynomial vs scalar libm), so the same
    u can yield 1-ulp-different logs in two graphs — fatal for the bitwise
    live-step == ledger-replay contract.  Exponent/mantissa split by integer
    bitcast (exact), ln(m) by the atanh series in s = (m−1)/(m+1) with every
    mul/add pinned: deterministic in any graph, ~1e-7 absolute error (the
    N(0,1) law of z is insensitive at that scale).
    """
    bits = jax.lax.bitcast_convert_type(u, jnp.uint32)            # exact
    e = (bits >> jnp.uint32(23)).astype(jnp.int32) - 127
    m = jax.lax.bitcast_convert_type(
        (bits & jnp.uint32(0x007FFFFF)) | jnp.uint32(0x3F800000),
        jnp.float32)                                              # m ∈ [1, 2)
    s = _pin((m - 1.0) / _pin(m + 1.0, pin), pin)                 # s ∈ [0, ⅓)
    s2 = _pin(s * s, pin)
    p = jnp.float32(1.0 / 13.0)
    for c in (1.0 / 11.0, 1.0 / 9.0, 1.0 / 7.0, 1.0 / 5.0, 1.0 / 3.0, 1.0):
        p = _pin(_pin(p * s2, pin) + jnp.float32(c), pin)
    log_m = _pin(jnp.float32(2.0) * _pin(s * p, pin), pin)
    return _pin(log_m + _pin(e.astype(jnp.float32) * jnp.float32(_LN2), pin),
                pin)


# cos/sin Taylor coefficients (highest order first), evaluated by pinned
# Horner on φ² with φ ∈ [0, π/2): ~6e-9 absolute truncation error.
_COS_COEFFS = (-1.0 / 87178291200.0, 1.0 / 479001600.0, -1.0 / 3628800.0,
               1.0 / 40320.0, -1.0 / 720.0, 1.0 / 24.0, -1.0 / 2.0, 1.0)
_SIN_COEFFS = (1.0 / 6227020800.0, -1.0 / 39916800.0, 1.0 / 362880.0,
               -1.0 / 5040.0, 1.0 / 120.0, -1.0 / 6.0, 1.0)


def _det_cos2pi(t: jnp.ndarray, pin: bool) -> jnp.ndarray:
    """Deterministic cos(2π·t) for t in (0, 1): exact quadrant reduction
    (4t and 4t−k are exact float ops) + pinned-Horner sin/cos polynomials —
    same rationale as ``_det_log``."""
    t4 = t * 4.0                                 # exact: power-of-two scale
    k = jnp.floor(t4)                            # exact
    f = t4 - k                                   # exact (Sterbenz)
    phi = _pin(f * jnp.float32(jnp.pi / 2), pin)
    p2 = _pin(phi * phi, pin)
    c = jnp.float32(_COS_COEFFS[0])
    for coef in _COS_COEFFS[1:]:
        c = _pin(_pin(c * p2, pin) + jnp.float32(coef), pin)
    s = jnp.float32(_SIN_COEFFS[0])
    for coef in _SIN_COEFFS[1:]:
        s = _pin(_pin(s * p2, pin) + jnp.float32(coef), pin)
    s = _pin(phi * s, pin)
    ki = k.astype(jnp.int32) & 3                 # quadrant
    return _pin(jnp.where(ki == 0, c,
                          jnp.where(ki == 1, -s,
                                    jnp.where(ki == 2, -c, s))), pin)


def gaussian_from_counter(idx: jnp.ndarray, seed: jnp.ndarray,
                          pin: bool = False) -> jnp.ndarray:
    """Box–Muller on two independent counter streams, built exclusively from
    rounding-deterministic ops (see ``_det_log`` / ``_det_cos2pi``) so the
    same (idx, seed) yields bitwise-identical z in every graph — the single
    kernel, the batched kernel, the jitted train step, and the jitted ledger
    replay.  ``pin`` additionally barriers each float stage (interpret mode /
    the jnp oracle); compiled TPU kernels pass ``False``."""
    u1 = _pin(counter_uniform(idx, seed, 1, pin), pin)
    u2 = _pin(counter_uniform(idx, seed, 2, pin), pin)
    t = _pin(jnp.float32(-2.0) * _det_log(u1, pin), pin)
    # the polynomial log's ~1e-7 absolute error can push −2·ln(u) fractionally
    # below zero for u within an ulp of 1 — clamp instead of NaN-ing the sqrt
    r = _pin(jnp.sqrt(jnp.maximum(t, 0.0)), pin)
    c = _det_cos2pi(u2, pin)
    return _pin(r * c, pin)


def rademacher_from_counter(idx: jnp.ndarray, seed: jnp.ndarray,
                            pin: bool = False) -> jnp.ndarray:
    """±1 from the sign of ONE counter stream: u >= ½ → +1, else −1.  Uses
    the same salt-1 stream the gaussian path reads as u1 (a different dist is
    a different z law, not a different stream identity).  Comparison + select
    involve no rounding at all, so the rademacher stream is bitwise-
    deterministic in every graph without any of the gaussian path's
    polynomial machinery."""
    u = _pin(counter_uniform(idx, seed, 1, pin), pin)
    return _pin(jnp.where(u >= jnp.float32(0.5),
                          jnp.float32(1.0), jnp.float32(-1.0)), pin)


def z_from_counter(idx: jnp.ndarray, seed: jnp.ndarray, dist: str,
                   pin: bool = False) -> jnp.ndarray:
    """Dispatch the kernel's in-VMEM z generation by distribution."""
    if dist == "gaussian":
        return gaussian_from_counter(idx, seed, pin)
    if dist == "rademacher":
        return rademacher_from_counter(idx, seed, pin)
    raise NotImplementedError(
        f"zo_fused kernel has no in-kernel generator for dist={dist!r} "
        "(implemented: gaussian, rademacher).  sphere is a *scaled* gaussian "
        "stream: the backend measures ‖z‖ with the zo_sqnorm kernel "
        "(kernels/zo_fused/multi.py, pass 1) and folds sqrt(d)/‖z‖ into the "
        "affine b coefficient (pass 2) — call the affine kernels with "
        "dist='gaussian' and the rescaled b, as PallasBackend does")


def _affine_combine(x: jnp.ndarray, z: jnp.ndarray, a, b,
                    interpret: bool) -> jnp.ndarray:
    """a·x + b·z with rounding pinned under interpret mode (see ``_pin``):
    the barriers isolate the z cluster and force separately-rounded
    mul/mul/add in every graph that inlines this kernel."""
    if interpret:
        x, z = jax.lax.optimization_barrier((x, z))
    ax, bz = a * x, b * z
    if interpret:
        ax, bz = jax.lax.optimization_barrier((ax, bz))
    return ax + bz


def _tile_affine(x: jnp.ndarray, row_block: jnp.ndarray, cols: int,
                 seed: jnp.ndarray, a, b, interpret: bool,
                 dist: str = "gaussian") -> jnp.ndarray:
    """One VMEM tile's worth of y = a·x + b·z(seed): the counter indices are
    global element positions (row_block picks the tile), so the stream is
    position-stable across padding and blocking.  Shared by the single-seed
    and batched kernels — the bitwise batched == singles contract is this
    function being the only implementation."""
    rows = x.shape[0]
    base = jnp.uint32(row_block * rows * cols)
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    idx = base + row_ids * jnp.uint32(cols) + col_ids
    z = z_from_counter(idx, seed, dist, pin=interpret)
    return _affine_combine(x.astype(jnp.float32), z, a, b, interpret)


def _zo_affine_kernel(x_ref, seed_ref, a_ref, b_ref, o_ref, *, cols: int,
                      interpret: bool, dist: str):
    i = pl.program_id(0)
    seed = seed_ref[0, 0].astype(jnp.uint32)
    y = _tile_affine(x_ref[...], i, cols, seed, a_ref[0, 0], b_ref[0, 0],
                     interpret, dist)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "dist"))
def zo_affine_2d(x: jnp.ndarray, seed: jnp.ndarray, a: jnp.ndarray,
                 b: jnp.ndarray, interpret: bool = True,
                 dist: str = "gaussian") -> jnp.ndarray:
    """y = a·x + b·z on a 2-D array whose shape is (R·BLOCK_ROWS, BLOCK_COLS)."""
    rows, cols = x.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_zo_affine_kernel, cols=cols, interpret=interpret,
                          dist=dist),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, seed.reshape(1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(1, 1),
      jnp.asarray(b, jnp.float32).reshape(1, 1))


def _zo_affine_batched_kernel(x_ref, seed_ref, a_ref, b_ref, o_ref, *,
                              cols: int, interpret: bool, dist: str):
    # Grid is (row_blocks, batch): the row-block axis is OUTER, so the x tile
    # for row-block i stays resident in VMEM while the inner batch axis
    # generates B z-streams against it (Pallas re-fetches a block only when
    # its index-map output changes between consecutive grid steps).  The tile
    # computation is _tile_affine — the same single implementation the
    # single-seed kernel runs, which is what makes the batched output
    # bitwise-equal to stacked single-seed calls.
    i = pl.program_id(0)
    seed = seed_ref[0, 0].astype(jnp.uint32)
    y = _tile_affine(x_ref[...], i, cols, seed, a_ref[0, 0], b_ref[0, 0],
                     interpret, dist)
    o_ref[0, ...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "dist"))
def zo_affine_2d_batched(x: jnp.ndarray, seeds: jnp.ndarray, a: jnp.ndarray,
                         b: jnp.ndarray, interpret: bool = True,
                         dist: str = "gaussian") -> jnp.ndarray:
    """y[j] = a·x + b·z(seeds[j]) for all j in one launch.

    ``x`` is the (R·BLOCK_ROWS, BLOCK_COLS) blocked view shared by every
    seed; ``seeds`` is a (B,) int32 vector of per-stream counter seeds.  The
    result has shape (B, rows, cols) and each batch slice is bitwise-equal to
    ``zo_affine_2d(x, seeds[j], a, b)`` — genuinely batched generation (B
    z-streams per VMEM tile of x), not B kernel launches.
    """
    rows, cols = x.shape
    (batch,) = seeds.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    grid = (rows // BLOCK_ROWS, batch)
    return pl.pallas_call(
        functools.partial(_zo_affine_batched_kernel, cols=cols,
                          interpret=interpret, dist=dist),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_ROWS, cols), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, rows, cols), x.dtype),
        interpret=interpret,
    )(x, seeds.reshape(-1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(1, 1),
      jnp.asarray(b, jnp.float32).reshape(1, 1))
