"""Pallas TPU kernel for MeZO's fused perturb/update: y = a·x + b·z(seed).

This is the paper's in-place trick taken one level further down the memory
hierarchy: the Gaussian direction z is generated *inside VMEM*, tile by tile,
from a counter-based hash of (seed, global element index) — z never exists in
HBM at all.  One kernel serves all three uses in Algorithm 1 via the affine
scalars:

    perturb  +ε :  a = 1,        b = +ε
    perturb −2ε :  a = 1,        b = −2ε
    update      :  a = 1 − η·λ,  b = −η·g     (g = projected gradient)

RNG: a murmur3-finalizer counter hash (32-bit ops only — TPU native) feeding
a Box–Muller transform.  The identical arithmetic is implemented in pure jnp
in ref.py, so kernel and oracle agree bit-for-bit on the generated bits.

Grid: 1-D over row-blocks of the (padded) 2-D view; BlockSpec keeps one
(block_rows × 128·lane_cols) tile of x and y in VMEM (~256 KB at f32).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 256
BLOCK_COLS = 512          # multiple of 128 lanes


def _murmur_mix(h: jnp.ndarray) -> jnp.ndarray:
    """murmur3 32-bit finalizer (uint32)."""
    h = h ^ (h >> jnp.uint32(16))
    h = h * jnp.uint32(0x85EBCA6B)
    h = h ^ (h >> jnp.uint32(13))
    h = h * jnp.uint32(0xC2B2AE35)
    h = h ^ (h >> jnp.uint32(16))
    return h


def counter_uniform(idx: jnp.ndarray, seed: jnp.ndarray, salt: int) -> jnp.ndarray:
    """uint32 counter + seed + salt -> uniform f32 in (0, 1)."""
    h = idx * jnp.uint32(0x9E3779B1)                 # golden-ratio spread
    h = h ^ (seed * jnp.uint32(0x7FEB352D))
    h = h + jnp.uint32(salt) * jnp.uint32(0x846CA68B)
    h = _murmur_mix(h)
    # 24 mantissa-ish bits -> (0,1); +1 avoids exactly 0 for the log
    return (h >> jnp.uint32(8)).astype(jnp.float32) * (1.0 / 16777216.0) \
        + (0.5 / 16777216.0)


def gaussian_from_counter(idx: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Box–Muller on two independent counter streams."""
    u1 = counter_uniform(idx, seed, 1)
    u2 = counter_uniform(idx, seed, 2)
    r = jnp.sqrt(-2.0 * jnp.log(u1))
    return r * jnp.cos((2.0 * jnp.pi) * u2)


def _zo_affine_kernel(x_ref, seed_ref, a_ref, b_ref, o_ref, *, cols: int):
    i = pl.program_id(0)
    seed = seed_ref[0, 0].astype(jnp.uint32)
    a = a_ref[0, 0]
    b = b_ref[0, 0]
    rows = x_ref.shape[0]
    base = jnp.uint32(i * rows * cols)
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    idx = base + row_ids * jnp.uint32(cols) + col_ids
    z = gaussian_from_counter(idx, seed)
    x = x_ref[...].astype(jnp.float32)
    o_ref[...] = (a * x + b * z).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def zo_affine_2d(x: jnp.ndarray, seed: jnp.ndarray, a: jnp.ndarray,
                 b: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """y = a·x + b·z on a 2-D array whose shape is (R·BLOCK_ROWS, BLOCK_COLS)."""
    rows, cols = x.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_zo_affine_kernel, cols=cols),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, seed.reshape(1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(1, 1),
      jnp.asarray(b, jnp.float32).reshape(1, 1))
