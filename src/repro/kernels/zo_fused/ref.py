"""Pure-jnp oracle for the zo_fused kernel — identical counter-hash and
Box–Muller (or rademacher sign) arithmetic, evaluated array-at-once.

The oracle is jit-compiled on purpose: the kernel's gaussian z generator is
built from rounding-pinned basic ops (see ``kernel._pin``), which makes every
JITTED graph agree bitwise, but op-by-op eager execution gives LLVM no
mul→add patterns to contract and so rounds a small fraction of elements
differently.  Keeping the oracle inside jit puts it in the same regime as the
interpret-mode kernels it checks.  (The rademacher stream is comparison +
select — no rounding — but rides the same jitted entry points.)"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.zo_fused.kernel import _affine_combine, z_from_counter


@functools.partial(jax.jit, static_argnames=("shape", "dist"))
def _z_for_jit(shape: tuple, seed, dist: str = "gaussian") -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    idx = jnp.arange(n, dtype=jnp.uint32)
    return z_from_counter(idx, jnp.asarray(seed, jnp.uint32), dist,
                          pin=True).reshape(shape)


def z_for(shape: tuple, seed, dist: str = "gaussian") -> jnp.ndarray:
    return _z_for_jit(tuple(shape), seed, dist)


@functools.partial(jax.jit, static_argnames=("dist",))
def zo_affine_ref(x: jnp.ndarray, seed, a, b,
                  dist: str = "gaussian") -> jnp.ndarray:
    """y = a·x + b·z with z from the same counter stream as the kernel."""
    z = _z_for_jit(x.shape, seed, dist)
    return _affine_combine(x.astype(jnp.float32), z,
                           jnp.asarray(a, jnp.float32),
                           jnp.asarray(b, jnp.float32),
                           interpret=True).astype(x.dtype)


def zo_affine_batched_ref(x: jnp.ndarray, seeds, a, b,
                          dist: str = "gaussian") -> jnp.ndarray:
    """Batched oracle: y[j] = zo_affine_ref(x, seeds[j], a, b), stacked."""
    return jnp.stack([zo_affine_ref(x, s, a, b, dist=dist) for s in seeds])


def zo_affine_multi_ref(x: jnp.ndarray, seeds, a, b,
                        dist: str = "gaussian") -> jnp.ndarray:
    """Fan-out oracle with per-stream coefficients:
    y[j] = zo_affine_ref(x, seeds[j], a[j], b[j]), stacked."""
    return jnp.stack([zo_affine_ref(x, s, aj, bj, dist=dist)
                      for s, aj, bj in zip(seeds, a, b)])


def zo_affine_chain_ref(x: jnp.ndarray, seeds, a, b,
                        dist: str = "gaussian") -> jnp.ndarray:
    """Chained oracle: the sequential per-seed fold
    ``for j: x = zo_affine_ref(x, seeds[j], a[j], b[j])`` that the fused
    chain kernel (``multi.zo_affine_chain_2d``) collapses into one launch —
    each fold rounds through x's dtype exactly as a separate launch would."""
    y = x
    for s, aj, bj in zip(seeds, a, b):
        y = zo_affine_ref(y, s, aj, bj, dist=dist)
    return y
