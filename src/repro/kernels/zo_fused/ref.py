"""Pure-jnp oracle for the zo_fused kernel — identical counter-hash and
Box–Muller arithmetic, evaluated array-at-once."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.zo_fused.kernel import gaussian_from_counter


def z_for(shape: tuple, seed) -> jnp.ndarray:
    n = 1
    for s in shape:
        n *= s
    idx = jnp.arange(n, dtype=jnp.uint32)
    return gaussian_from_counter(idx, jnp.asarray(seed, jnp.uint32)).reshape(shape)


def zo_affine_ref(x: jnp.ndarray, seed, a, b) -> jnp.ndarray:
    """y = a·x + b·z with z from the same counter stream as the kernel."""
    z = z_for(x.shape, seed)
    return (jnp.asarray(a, jnp.float32) * x.astype(jnp.float32)
            + jnp.asarray(b, jnp.float32) * z).astype(x.dtype)
