"""``zo_fused_multi`` — one VMEM pass serving every multi-seed affine need.

The single-seed kernel in ``kernel.py`` computes y = a·x + b·z(seed) one
stream at a time: every additional stream re-reads every parameter tile from
HBM.  But all multi-seed work in the repo — FZOO's B eval perturbations, the
seed-parallel engine's per-group restore/update chain, batched ledger replay
— shares one shape: *several affine ops against the same resident x*.  This
module generates all B z-streams per resident tile from a single HBM read of
x, in two lowerings:

``zo_affine_multi_2d``  (fan-out)
    y[j] = a_j·x + b_j·z(seed_j), stacked — the batched-seed kernel of PR 3
    generalized from shared (a, b) scalars to per-stream coefficients.  Grid
    is (row_blocks, B) with the row-block axis OUTER, so the x tile stays in
    VMEM while the inner batch axis emits B outputs against it.

``zo_affine_chain_2d``  (chained)
    y = fold_j (a_j·y + b_j·z(seed_j)) — the sequential per-seed update chain
    (B rank-1 applications = B kernel launches = B HBM round-trips of θ)
    collapsed into ONE launch: per resident tile the B streams are generated
    and folded in-register, with the intermediate cast to the output dtype
    between streams so the fold is **bitwise-identical** to B separate
    ``zo_affine_2d`` calls (each single-seed call writes y in x's dtype and
    the next call re-reads it; the in-register cast reproduces exactly that
    rounding boundary).

``zo_sqnorm_2d``  (sphere pass 1)
    Tile-by-tile accumulation of ‖z(seed)‖² over a leaf's real (un-padded)
    elements — the first pass of the two-pass sphere rescale.  Pass 2 is any
    affine kernel with b scaled by sqrt(d)/‖z‖ (the backend folds the scale
    into the affine coefficient, so sphere costs one extra scalar mul per
    stream, never a materialized z).

All three share ``_tile_affine`` / ``z_from_counter`` with the single-seed
kernel — the bitwise fused ≡ stacked-singles contract is those functions
being the only implementation of the per-tile arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.zo_fused.kernel import (BLOCK_COLS, BLOCK_ROWS, _pin,
                                           _tile_affine, z_from_counter)


# --------------------------------------------------------------------------- #
# Fan-out: B outputs, per-stream coefficients, one x read per tile
# --------------------------------------------------------------------------- #
def _zo_affine_multi_kernel(x_ref, seed_ref, a_ref, b_ref, o_ref, *,
                            cols: int, interpret: bool, dist: str):
    # Grid is (row_blocks, batch): row-block axis OUTER, so the x tile for
    # row-block i stays resident while the inner batch axis walks the B
    # (seed_j, a_j, b_j) triples against it.  Same structure as PR 3's
    # batched kernel; the per-stream a/b BlockSpecs are the generalization.
    i = pl.program_id(0)
    seed = seed_ref[0, 0].astype(jnp.uint32)
    y = _tile_affine(x_ref[...], i, cols, seed, a_ref[0, 0], b_ref[0, 0],
                     interpret, dist)
    o_ref[0, ...] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret", "dist"))
def zo_affine_multi_2d(x: jnp.ndarray, seeds: jnp.ndarray, a: jnp.ndarray,
                       b: jnp.ndarray, interpret: bool = True,
                       dist: str = "gaussian") -> jnp.ndarray:
    """y[j] = a_j·x + b_j·z(seeds[j]) for all j in one launch.

    ``x`` is the (R·BLOCK_ROWS, BLOCK_COLS) blocked view; ``seeds``/``a``/``b``
    are (B,) per-stream vectors.  Each batch slice of the (B, rows, cols)
    result is bitwise-equal to ``zo_affine_2d(x, seeds[j], a[j], b[j])``.
    """
    rows, cols = x.shape
    (batch,) = seeds.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    grid = (rows // BLOCK_ROWS, batch)
    return pl.pallas_call(
        functools.partial(_zo_affine_multi_kernel, cols=cols,
                          interpret=interpret, dist=dist),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_ROWS, cols), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, rows, cols), x.dtype),
        interpret=interpret,
    )(x, seeds.reshape(-1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(-1, 1),
      jnp.asarray(b, jnp.float32).reshape(-1, 1))


# --------------------------------------------------------------------------- #
# Chained: B affine folds per resident tile, one output, one x round-trip
# --------------------------------------------------------------------------- #
def _zo_affine_chain_kernel(x_ref, seed_ref, a_ref, b_ref, o_ref, *,
                            cols: int, n_streams: int, interpret: bool,
                            dist: str):
    # One resident tile, n_streams sequential affine folds.  The cast back to
    # the I/O dtype between streams is load-bearing: a separate single-seed
    # launch writes its y in x's dtype and the next launch re-reads it — the
    # in-register fold must reproduce that rounding boundary to stay bitwise
    # with the per-seed chain.  (The padding tail diverges — the chain keeps
    # b_j·z values there where re-padding would zero them — but padding never
    # feeds a real element: the ops are elementwise.)
    i = pl.program_id(0)
    y = x_ref[...]
    for j in range(n_streams):
        seed = seed_ref[j, 0].astype(jnp.uint32)
        y = _tile_affine(y, i, cols, seed, a_ref[j, 0], b_ref[j, 0],
                         interpret, dist).astype(x_ref.dtype)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("interpret", "dist"))
def zo_affine_chain_2d(x: jnp.ndarray, seeds: jnp.ndarray, a: jnp.ndarray,
                       b: jnp.ndarray, interpret: bool = True,
                       dist: str = "gaussian") -> jnp.ndarray:
    """y = fold over j of (a_j·y + b_j·z(seeds[j])), one launch.

    Bitwise-identical to the sequential per-seed chain
    ``for j: x = zo_affine_2d(x, seeds[j], a[j], b[j])`` on the real (un-
    padded) elements, while reading and writing x through HBM exactly once
    instead of B times — the multi-seed update chain (FZOO's B folded rank-1
    applications, the seed-parallel engine's per-group updates, batched
    ledger replay) at the memory cost of a single rank-1 apply.
    """
    rows, cols = x.shape
    (batch,) = seeds.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    grid = (rows // BLOCK_ROWS,)
    return pl.pallas_call(
        functools.partial(_zo_affine_chain_kernel, cols=cols,
                          n_streams=int(batch), interpret=interpret,
                          dist=dist),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((int(batch), 1), lambda i: (0, 0)),
            pl.BlockSpec((int(batch), 1), lambda i: (0, 0)),
            pl.BlockSpec((int(batch), 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x, seeds.reshape(-1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(-1, 1),
      jnp.asarray(b, jnp.float32).reshape(-1, 1))


# --------------------------------------------------------------------------- #
# Sphere pass 1: ‖z‖² accumulated tile-by-tile (padding masked out)
# --------------------------------------------------------------------------- #
def _sqnorm_tile(row_block, cols: int, seed: jnp.ndarray, n: int,
                 dist: str, pin: bool) -> jnp.ndarray:
    """One tile's Σ z², padding masked (idx ≥ n contributes exactly 0).
    Shared by the kernel body and the ref oracle — the bitwise kernel ==
    oracle contract is this being the only implementation."""
    base = jnp.uint32(row_block * BLOCK_ROWS * cols)
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, cols), 1)
    idx = base + row_ids * jnp.uint32(cols) + col_ids
    z = z_from_counter(idx, seed, dist, pin=pin)
    z = _pin(jnp.where(idx < jnp.uint32(n), z, jnp.float32(0.0)), pin)
    return _pin(jnp.sum(_pin(z * z, pin), dtype=jnp.float32), pin)


def _zo_sqnorm_kernel(seed_ref, o_ref, *, cols: int, n: int,
                      interpret: bool, dist: str):
    i = pl.program_id(0)
    seed = seed_ref[0, 0].astype(jnp.uint32)
    part = _sqnorm_tile(i, cols, seed, n, dist, pin=interpret)

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = part

    @pl.when(i > 0)
    def _acc():
        o_ref[0, 0] = o_ref[0, 0] + part


@functools.partial(jax.jit, static_argnames=("n", "interpret", "dist"))
def zo_sqnorm_2d(n: int, seed, interpret: bool = True,
                 dist: str = "gaussian") -> jnp.ndarray:
    """‖z(seed)[0:n]‖² as one f32 scalar: pass 1 of the two-pass sphere
    rescale.  The z stream is generated tile-by-tile (never materialized in
    HBM) and the per-tile partial sums accumulate across sequential grid
    steps into a single (1, 1) output block — the counter indices are the
    same global element positions the affine kernels use, so pass 2 rescales
    exactly the z this pass measured.  ``n`` (static) masks the padding tail
    of the blocked view out of the norm."""
    width = BLOCK_ROWS * BLOCK_COLS
    blocks = max(1, -(-int(n) // width))
    return pl.pallas_call(
        functools.partial(_zo_sqnorm_kernel, cols=BLOCK_COLS, n=int(n),
                          interpret=interpret, dist=dist),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(jnp.asarray(seed, jnp.int32).reshape(1, 1))[0, 0]


@functools.partial(jax.jit, static_argnames=("n", "dist"))
def zo_sqnorm_ref(n: int, seed, dist: str = "gaussian") -> jnp.ndarray:
    """Pure-jnp oracle for ``zo_sqnorm_2d``: the same per-tile sums
    (``_sqnorm_tile``) folded in the same sequential order, pinned like the
    interpret-mode kernel — bitwise-equal by construction."""
    width = BLOCK_ROWS * BLOCK_COLS
    blocks = max(1, -(-int(n) // width))
    seed_u = jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
    acc = _sqnorm_tile(0, BLOCK_COLS, seed_u, int(n), dist, pin=True)
    for i in range(1, blocks):
        acc = acc + _sqnorm_tile(i, BLOCK_COLS, seed_u, int(n), dist,
                                 pin=True)
    return acc
