"""``zo_fused_rows`` — sub-leaf tile skipping for the fused affine kernels.

The affine kernels in ``kernel.py`` / ``multi.py`` grid over *every* row-block
tile of a leaf's (rows, 512) blocked view.  Under a ``rows(block=R, k=K)``
selection only ~1/K of each leaf's row-blocks is perturbed per step, so a
full-grid launch would read, generate z for, and write K× more bytes than the
step touches.  This module launches **only the tiles covering selected
blocks**:

* the static tile plan (``tile_plan``) intersects the kernel's fixed
  131072-element tiles with the selection's ``block_elems``-sized row-blocks
  at trace time — unselected tiles are never gathered, never read by the
  kernel, and generate no z (the trace-time skip of PR 5's leaf semantics,
  one level down);
* selected tiles are gathered into a compact (n_sel·256, 512) operand, the
  kernel grids over the *compact* axis, and each grid step receives its
  original tile index through a scalar input — ``_tile_affine`` then derives
  counter indices from the **global** element position exactly as the full
  kernel does, so a selected tile's z bits are identical whether the leaf is
  perturbed whole or block-by-block (the blocked StreamRef index contract);
* tiles that straddle a block boundary (``block_elems`` not a multiple of the
  tile size) apply the modular block predicate in-register *after* the output
  dtype cast — unselected elements keep their x bits exactly;
* the compact result is stitched back over x with static
  ``dynamic_update_slice`` row bands (no gather/scatter).

Why a compact gather instead of a scalar-prefetch index map: the
``PrefetchScalarGridSpec`` machinery changes the inlined interpret-mode graph
shape around the z generator, and (as ``_pin``'s docstring warns) LLVM-level
FMA contraction after barrier erasure then breaks the 1-ulp bitwise contract
against the full kernel.  The compact form reuses the exact BlockSpec
machinery of ``kernel.py`` — bitwise equality is structural.

All variants share ``_tile_affine`` / ``z_from_counter`` with the full
kernels; the bitwise selected-tiles ≡ full-kernel contract is those functions
being the only implementation of the per-tile arithmetic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.zo_fused.kernel import (BLOCK_COLS, BLOCK_ROWS, _pin,
                                           _tile_affine, z_from_counter)

TILE_ELEMS = BLOCK_ROWS * BLOCK_COLS


# --------------------------------------------------------------------------- #
# Static tile plan
# --------------------------------------------------------------------------- #
def tile_plan(n: int, block_elems: int, k: int, phase: int) -> tuple:
    """Intersect the kernel's fixed tiles with a row-block selection.

    ``n`` is the leaf's real (un-padded) element count; row-block ``b``
    covers flat elements ``[b*block_elems, (b+1)*block_elems)`` and is
    selected iff ``b % k == phase``.  Returns ``(sel_tiles, pure)`` — the
    tuple of tile indices containing at least one selected element, and
    whether every launched tile is *purely* selected (no in-kernel mask
    needed).  Pure Python on static ints: the plan is trace-time data.
    """
    n = int(n)
    be, k, phase = int(block_elems), int(k), int(phase) % int(k)
    sel, pure = [], True
    for t in range(-(-n // TILE_ELEMS)):
        lo = t * TILE_ELEMS
        hi = min(lo + TILE_ELEMS, n)
        b0, b1 = lo // be, (hi - 1) // be
        # first selected block at or after b0
        first = b0 + (phase - b0) % k
        if first > b1:
            continue
        sel.append(t)
        pure = pure and (k == 1 or (b0 == b1))
    if not sel:
        raise ValueError(
            f"rows plan selects no tiles of a {n}-element leaf "
            f"(block_elems={be}, k={k}, phase={phase}); the selection layer "
            "should have excluded this leaf from the phase")
    return tuple(sel), pure


def _tile_sel_mask(row_block, cols: int, block_elems: int, k: int,
                   phase: int) -> jnp.ndarray:
    """Selected-element predicate of one tile, from the same global counter
    indices ``_tile_affine`` generates z with: element e is in row-block
    ``e // block_elems``, selected iff ``≡ phase (mod k)``."""
    base = jnp.uint32(row_block * BLOCK_ROWS * cols)
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, cols), 1)
    idx = base + row_ids * jnp.uint32(cols) + col_ids
    blk = idx // jnp.uint32(block_elems)
    return (blk % jnp.uint32(k)) == jnp.uint32(phase)


def _gather_tiles(x: jnp.ndarray, sel: tuple) -> jnp.ndarray:
    """Compact (n_sel·BLOCK_ROWS, cols) operand from static row-band
    slices — the only rows the kernel ever reads."""
    if len(sel) == 1:
        t = sel[0]
        return x[t * BLOCK_ROWS:(t + 1) * BLOCK_ROWS]
    return jnp.concatenate(
        [x[t * BLOCK_ROWS:(t + 1) * BLOCK_ROWS] for t in sel], axis=0)


def _scatter_tiles(x: jnp.ndarray, y: jnp.ndarray, sel: tuple) -> jnp.ndarray:
    """Stitch the compact kernel output back over x: one static
    ``dynamic_update_slice`` row band per selected tile."""
    out = x
    for j, t in enumerate(sel):
        out = jax.lax.dynamic_update_slice(
            out, y[j * BLOCK_ROWS:(j + 1) * BLOCK_ROWS],
            (t * BLOCK_ROWS, 0))
    return out


def _tiles_input(sel: tuple) -> jnp.ndarray:
    return jnp.asarray(sel, jnp.int32).reshape(-1, 1)


# --------------------------------------------------------------------------- #
# Single stream: y = a·x + b·z on selected tiles only
# --------------------------------------------------------------------------- #
def _zo_affine_rows_kernel(x_ref, tile_ref, seed_ref, a_ref, b_ref, o_ref, *,
                           cols: int, block_elems: int, k: int, phase: int,
                           masked: bool, interpret: bool, dist: str):
    # the grid walks the COMPACT tile axis; the original tile index arrives
    # as data, so _tile_affine's global counter base — and therefore the z
    # bits — match the full-grid kernel exactly
    t = tile_ref[0, 0]
    seed = seed_ref[0, 0].astype(jnp.uint32)
    x = x_ref[...]
    y = _tile_affine(x, t, cols, seed, a_ref[0, 0], b_ref[0, 0],
                     interpret, dist).astype(o_ref.dtype)
    if masked:
        y = jnp.where(_tile_sel_mask(t, cols, block_elems, k, phase), y, x)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("sel", "block_elems", "k",
                                             "phase", "masked", "interpret",
                                             "dist"))
def zo_affine_2d_rows(x: jnp.ndarray, seed: jnp.ndarray, a: jnp.ndarray,
                      b: jnp.ndarray, sel: tuple, block_elems: int, k: int,
                      phase: int, masked: bool, interpret: bool = True,
                      dist: str = "gaussian") -> jnp.ndarray:
    """``zo_affine_2d`` restricted to the selected tiles of a rows plan.

    Selected rows are bitwise-equal to the full kernel's output (same
    ``_tile_affine`` on the same global counter base); unselected rows keep
    x's bits exactly.  Only ``len(sel)`` tiles are read, generated, and
    written — perturbed bytes scale with the selected fraction.
    """
    rows, cols = x.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    n_sel = len(sel)
    xs = _gather_tiles(x, sel)
    y = pl.pallas_call(
        functools.partial(_zo_affine_rows_kernel, cols=cols,
                          block_elems=int(block_elems), k=int(k),
                          phase=int(phase), masked=masked,
                          interpret=interpret, dist=dist),
        grid=(n_sel,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xs.shape, x.dtype),
        interpret=interpret,
    )(xs, _tiles_input(sel), seed.reshape(1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(1, 1),
      jnp.asarray(b, jnp.float32).reshape(1, 1))
    return _scatter_tiles(x, y, sel)


# --------------------------------------------------------------------------- #
# Fan-out: B streams, per-stream coefficients, selected tiles only
# --------------------------------------------------------------------------- #
def _zo_affine_multi_rows_kernel(x_ref, tile_ref, seed_ref, a_ref, b_ref,
                                 o_ref, *, cols: int, block_elems: int,
                                 k: int, phase: int, masked: bool,
                                 interpret: bool, dist: str):
    # grid (n_sel, batch): compact tile axis OUTER so the x tile stays
    # resident while the inner batch axis walks the B streams against it —
    # the multi.py structure over the compact operand
    t = tile_ref[0, 0]
    seed = seed_ref[0, 0].astype(jnp.uint32)
    x = x_ref[...]
    y = _tile_affine(x, t, cols, seed, a_ref[0, 0], b_ref[0, 0],
                     interpret, dist).astype(o_ref.dtype)
    if masked:
        y = jnp.where(_tile_sel_mask(t, cols, block_elems, k, phase), y, x)
    o_ref[0, ...] = y


@functools.partial(jax.jit, static_argnames=("sel", "block_elems", "k",
                                             "phase", "masked", "interpret",
                                             "dist"))
def zo_affine_multi_2d_rows(x: jnp.ndarray, seeds: jnp.ndarray,
                            a: jnp.ndarray, b: jnp.ndarray, sel: tuple,
                            block_elems: int, k: int, phase: int,
                            masked: bool, interpret: bool = True,
                            dist: str = "gaussian") -> jnp.ndarray:
    """``zo_affine_multi_2d`` on selected tiles: y[j] = a_j·x + b_j·z_j on
    selected rows, x's bits elsewhere.  Result is (B, rows, cols); each batch
    slice's selected rows are bitwise-equal to the full multi kernel's."""
    rows, cols = x.shape
    (batch,) = seeds.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    n_sel = len(sel)
    xs = _gather_tiles(x, sel)
    y = pl.pallas_call(
        functools.partial(_zo_affine_multi_rows_kernel, cols=cols,
                          block_elems=int(block_elems), k=int(k),
                          phase=int(phase), masked=masked,
                          interpret=interpret, dist=dist),
        grid=(n_sel, batch),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((1, BLOCK_ROWS, cols), lambda i, j: (j, i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, n_sel * BLOCK_ROWS, cols),
                                       x.dtype),
        interpret=interpret,
    )(xs, _tiles_input(sel), seeds.reshape(-1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(-1, 1),
      jnp.asarray(b, jnp.float32).reshape(-1, 1))
    out = jnp.broadcast_to(x, (batch,) + x.shape)
    for j, t in enumerate(sel):
        out = jax.lax.dynamic_update_slice(
            out, y[:, j * BLOCK_ROWS:(j + 1) * BLOCK_ROWS, :],
            (0, t * BLOCK_ROWS, 0))
    return out


# --------------------------------------------------------------------------- #
# Chained: B affine folds per resident selected tile
# --------------------------------------------------------------------------- #
def _zo_affine_chain_rows_kernel(x_ref, tile_ref, seed_ref, a_ref, b_ref,
                                 o_ref, *, cols: int, n_streams: int,
                                 block_elems: int, k: int, phase: int,
                                 masked: bool, interpret: bool, dist: str):
    # the fold runs on the whole tile (every op is elementwise, so selected
    # elements' values never depend on unselected neighbours) and the block
    # predicate restores x's bits once at the end — equivalent to masking
    # every fold step, at one select instead of n_streams
    t = tile_ref[0, 0]
    x = x_ref[...]
    y = x
    for j in range(n_streams):
        seed = seed_ref[j, 0].astype(jnp.uint32)
        y = _tile_affine(y, t, cols, seed, a_ref[j, 0], b_ref[j, 0],
                         interpret, dist).astype(x_ref.dtype)
    if masked:
        y = jnp.where(_tile_sel_mask(t, cols, block_elems, k, phase), y, x)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("sel", "block_elems", "k",
                                             "phase", "masked", "interpret",
                                             "dist"))
def zo_affine_chain_2d_rows(x: jnp.ndarray, seeds: jnp.ndarray,
                            a: jnp.ndarray, b: jnp.ndarray, sel: tuple,
                            block_elems: int, k: int, phase: int,
                            masked: bool, interpret: bool = True,
                            dist: str = "gaussian") -> jnp.ndarray:
    """``zo_affine_chain_2d`` on selected tiles: the B-fold update chain
    applied to selected rows in one launch, x's bits elsewhere — selected
    rows bitwise-equal to the full chain kernel (same in-register dtype-cast
    rounding boundary between streams)."""
    rows, cols = x.shape
    (batch,) = seeds.shape
    assert rows % BLOCK_ROWS == 0 and cols == BLOCK_COLS, (rows, cols)
    n_sel = len(sel)
    xs = _gather_tiles(x, sel)
    y = pl.pallas_call(
        functools.partial(_zo_affine_chain_rows_kernel, cols=cols,
                          n_streams=int(batch), block_elems=int(block_elems),
                          k=int(k), phase=int(phase), masked=masked,
                          interpret=interpret, dist=dist),
        grid=(n_sel,),
        in_specs=[
            pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((int(batch), 1), lambda i: (0, 0)),
            pl.BlockSpec((int(batch), 1), lambda i: (0, 0)),
            pl.BlockSpec((int(batch), 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_ROWS, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xs.shape, x.dtype),
        interpret=interpret,
    )(xs, _tiles_input(sel), seeds.reshape(-1, 1).astype(jnp.int32),
      jnp.asarray(a, jnp.float32).reshape(-1, 1),
      jnp.asarray(b, jnp.float32).reshape(-1, 1))
    return _scatter_tiles(x, y, sel)


# --------------------------------------------------------------------------- #
# Sphere pass 1 over selected rows only
# --------------------------------------------------------------------------- #
def _sqnorm_rows_tile(row_block, cols: int, seed: jnp.ndarray, n: int,
                      block_elems: int, k: int, phase: int, dist: str,
                      pin: bool) -> jnp.ndarray:
    """One selected tile's Σ z² over its selected, real elements (padding
    and unselected blocks contribute exactly 0)."""
    base = jnp.uint32(row_block * BLOCK_ROWS * cols)
    row_ids = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, cols), 0)
    col_ids = jax.lax.broadcasted_iota(jnp.uint32, (BLOCK_ROWS, cols), 1)
    idx = base + row_ids * jnp.uint32(cols) + col_ids
    z = z_from_counter(idx, seed, dist, pin=pin)
    blk = idx // jnp.uint32(block_elems)
    keep = ((blk % jnp.uint32(k)) == jnp.uint32(phase)) & (idx < jnp.uint32(n))
    z = _pin(jnp.where(keep, z, jnp.float32(0.0)), pin)
    return _pin(jnp.sum(_pin(z * z, pin), dtype=jnp.float32), pin)


def _zo_sqnorm_rows_kernel(tile_ref, seed_ref, o_ref, *, cols: int, n: int,
                           block_elems: int, k: int, phase: int,
                           interpret: bool, dist: str):
    i = pl.program_id(0)
    t = tile_ref[0, 0]
    seed = seed_ref[0, 0].astype(jnp.uint32)
    part = _sqnorm_rows_tile(t, cols, seed, n, block_elems, k, phase, dist,
                             pin=interpret)

    @pl.when(i == 0)
    def _init():
        o_ref[0, 0] = part

    @pl.when(i > 0)
    def _acc():
        o_ref[0, 0] = o_ref[0, 0] + part


@functools.partial(jax.jit, static_argnames=("n", "sel", "block_elems", "k",
                                             "phase", "interpret", "dist"))
def zo_sqnorm_2d_rows(n: int, seed, sel: tuple, block_elems: int, k: int,
                      phase: int, interpret: bool = True,
                      dist: str = "gaussian") -> jnp.ndarray:
    """‖z restricted to the selected row-blocks‖² — sphere pass 1 under a
    rows selection.  Only the selected tiles are visited; the modular block
    predicate (and the real-element bound ``n``) masks inside them, so pass 2
    rescales exactly the z the selected rows will consume."""
    return pl.pallas_call(
        functools.partial(_zo_sqnorm_rows_kernel, cols=BLOCK_COLS, n=int(n),
                          block_elems=int(block_elems), k=int(k),
                          phase=int(phase), interpret=interpret, dist=dist),
        grid=(len(sel),),
        in_specs=[
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(_tiles_input(sel), jnp.asarray(seed, jnp.int32).reshape(1, 1))[0, 0]


@functools.partial(jax.jit, static_argnames=("n", "sel", "block_elems", "k",
                                             "phase", "dist"))
def zo_sqnorm_rows_ref(n: int, seed, sel: tuple, block_elems: int, k: int,
                       phase: int, dist: str = "gaussian") -> jnp.ndarray:
    """Pure-jnp oracle for ``zo_sqnorm_2d_rows``: the same per-tile sums in
    the same order, pinned like the interpret-mode kernel."""
    seed_u = jnp.asarray(seed, jnp.int32).astype(jnp.uint32)
    acc = _sqnorm_rows_tile(sel[0], BLOCK_COLS, seed_u, int(n),
                            int(block_elems), int(k), int(phase), dist,
                            pin=True)
    for t in sel[1:]:
        acc = acc + _sqnorm_rows_tile(t, BLOCK_COLS, seed_u, int(n),
                                      int(block_elems), int(k), int(phase),
                                      dist, pin=True)
    return acc
