"""jit'd public wrappers: arbitrary-shape ZO perturb/update on one leaf,
and a whole-tree MeZO step built on the kernel.

``zo_affine`` reshapes/pads any leaf to the kernel's 2-D blocked view; the
padding tail consumes counter indices but its z values are discarded (the
counter stream is position-stable, so the same (leaf, seed) always yields
the same z regardless of how the tree around it changes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.zo_fused.kernel import (BLOCK_COLS, BLOCK_ROWS,
                                           zo_affine_2d)
from repro.tree_utils import PyTree, tree_map_with_index


@functools.partial(jax.jit, static_argnames=("interpret",))
def zo_affine(x: jnp.ndarray, seed, a, b, interpret: bool = True) -> jnp.ndarray:
    """y = a·x + b·z(seed) for an arbitrary-shape leaf."""
    n = x.size
    width = BLOCK_ROWS * BLOCK_COLS
    n_pad = ((n + width - 1) // width) * width
    flat = jnp.pad(x.reshape(-1), (0, n_pad - n))
    y = zo_affine_2d(flat.reshape(-1, BLOCK_COLS),
                     jnp.asarray(seed, jnp.int32), a, b, interpret=interpret)
    return y.reshape(-1)[:n].reshape(x.shape)


def leaf_seed(seed: int, leaf_idx: int) -> jnp.ndarray:
    return jnp.asarray(seed, jnp.int32) + jnp.int32(0x1000003) * jnp.int32(leaf_idx)


def perturb_tree(params: PyTree, seed, scale, interpret: bool = True) -> PyTree:
    """θ + scale·z over a pytree (kernel-backed analogue of core.perturb)."""
    return tree_map_with_index(
        lambda i, p: zo_affine(p, leaf_seed(seed, i), 1.0, scale,
                               interpret=interpret)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def update_tree(params: PyTree, seed, projected_grad, lr,
                weight_decay: float = 0.0, interpret: bool = True) -> PyTree:
    """θ·(1−ηλ) − η·g·z over a pytree (Algorithm 1's descent loop)."""
    a = 1.0 - lr * weight_decay
    return tree_map_with_index(
        lambda i, p: zo_affine(p, leaf_seed(seed, i), a, -lr * projected_grad,
                               interpret=interpret)
        if jnp.issubdtype(p.dtype, jnp.floating) else p, params)


def mezo_step_kernel(loss_fn, params: PyTree, batch, seed: int, eps: float,
                     lr: float, weight_decay: float = 0.0,
                     interpret: bool = True):
    """One full MeZO step with every perturbation running through the Pallas
    kernel (z never materialized in HBM on TPU)."""
    p_plus = perturb_tree(params, seed, eps, interpret)
    l_plus = loss_fn(p_plus, batch)
    p_minus = perturb_tree(p_plus, seed, -2.0 * eps, interpret)
    l_minus = loss_fn(p_minus, batch)
    g = (l_plus - l_minus) / (2.0 * eps)
    restored = perturb_tree(p_minus, seed, eps, interpret)
    new_params = update_tree(restored, seed, g, lr, weight_decay, interpret)
    return new_params, g, 0.5 * (l_plus + l_minus)
