"""Compatibility shim — the kernel-backed tree operations moved to
``repro.perturb.pallas``, where they serve as the first-class ``pallas``
perturbation backend (selected via ``zo.mezo(..., backend="pallas")``).

Legacy entry points (``zo_affine``, ``perturb_tree``, ``update_tree``,
``mezo_step_kernel``, ``leaf_seed``) re-export unchanged; the counter-seed
schedule is bit-compatible, so z streams produced through either path are
identical.
"""
from __future__ import annotations

from repro.perturb.pallas import (leaf_seed, mezo_step_kernel, perturb_tree,
                                  update_tree, zo_affine)

__all__ = ["leaf_seed", "mezo_step_kernel", "perturb_tree", "update_tree",
           "zo_affine"]
