"""Pallas TPU flash attention (forward): causal / sliding-window GQA.

Grid (B, H, n_q, n_k) with the KV block axis innermost — TPU executes the
grid sequentially per core, so the (m, l, acc) online-softmax accumulators
live in VMEM scratch across the n_k steps of one q-block (the flash
algorithm's streaming structure, with HBM→VMEM tiling driven by BlockSpecs).

GQA is expressed in the K/V index maps: head h reads kv-head h // group —
no repeated K/V ever exists in HBM.  MeZO context: attention is the dominant
FLOP sink of the two forward passes, so this is the kernel the perf-critical
path runs (the XLA-level twin is models.attention.attend_chunked, numerics
identical; see tests/test_kernels.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, window: int, block_q: int,
                  block_k: int, n_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, hd)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))   # (bq, bk)
    q_pos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < seq_len
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    l_prev = l_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_cur)
    corr = jnp.exp(m_prev - m_cur)
    l_scr[...] = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    m_scr[...] = m_cur
    acc_scr[...] = acc_scr[...] * corr + jax.lax.dot(p.astype(v.dtype), v)

    @pl.when(ik == n_k - 1)
    def _finalize():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, window: int = 0,
                         block_q: int = 128, block_k: int = 128,
                         interpret: bool = True) -> jnp.ndarray:
    """q (B,H,S,hd), k/v (B,KV,S,hd) -> (B,H,S,hd).  S padded to blocks."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    scale = hd ** -0.5
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    n_q = (S + block_q - 1) // block_q
    n_k = (S + block_k - 1) // block_k
    pad_q = n_q * block_q - S
    pad_k = n_k * block_k - S
    if pad_q or pad_k:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))

    out = pl.pallas_call(
        functools.partial(_flash_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k, seq_len=S),
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik, G=G: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, n_q * block_q, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :S]
