"""jit'd wrapper matching the model-layer (B,S,H,hd) layout."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_bhsd


@functools.partial(jax.jit, static_argnames=("window", "block_q", "block_k",
                                             "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jnp.ndarray:
    """q (B,S,H,hd), k/v (B,S,KV,hd) -> (B,S,H,hd), causal (+optional SWA)."""
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    out = flash_attention_bhsd(qt, kt, vt, causal=True, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=interpret)
    return out.transpose(0, 2, 1, 3)
