"""Pure-jnp oracle for flash attention: dense masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (B,H,S,hd), k/v (B,KV,S,hd) -> (B,H,S,hd)."""
    B, H, S, hd = q.shape
    KV = k.shape[1]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (hd ** -0.5)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window > 0:
        mask &= pos[None, :] > pos[:, None] - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)
