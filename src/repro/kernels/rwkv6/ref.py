"""Pure-jnp oracle: the exact per-token WKV6 recurrence."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_ref(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, lw: jnp.ndarray,
             u: jnp.ndarray, s0: jnp.ndarray):
    """r/k/v/lw (BH, S, hd), u (BH, 1, hd), s0 (BH, hd, hd)
    -> (y (BH, S, hd), s_final)."""
    w = jnp.exp(lw.astype(jnp.float32))

    def step(S_prev, xs_t):
        r_t, k_t, v_t, w_t = xs_t                         # (BH, hd)
        kv = k_t[..., :, None] * v_t[..., None, :]        # (BH, hd, hd)
        y_t = jnp.einsum("bi,bij->bj", r_t,
                         S_prev + u[:, 0][..., :, None] * kv)
        S_new = w_t[..., :, None] * S_prev + kv
        return S_new, y_t

    xs = (r.transpose(1, 0, 2).astype(jnp.float32),
          k.transpose(1, 0, 2).astype(jnp.float32),
          v.transpose(1, 0, 2).astype(jnp.float32),
          w.transpose(1, 0, 2))
    s_final, ys = jax.lax.scan(step, s0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), s_final
