"""Pallas TPU kernel for the chunked WKV6 recurrence (RWKV-6 "Finch").

Implements the same chunked matmul factorization as
``models.rwkv6.time_mix`` (see its docstring for the math and numerics):
within a chunk the strict-past contribution is (r̃ @ k̃ᵀ masked) @ v, the
data-dependent per-channel decay enters through cumulated log-decays, and
the cross-chunk state is carried *sequentially through the grid* — grid
(B·H, n_chunks) with the chunk axis innermost, state (hd×hd) in VMEM
scratch.  This is the TPU-native analogue of the sequential CUDA WKV kernel:
the token loop becomes MXU matmuls, the state loop becomes the grid.

Inputs per (b,h): r,k,v,lw (S, hd) with lw = log decay (< 0), u (hd,),
s0 (hd, hd).  Outputs: y (S, hd) and the final state (hd, hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_CLIP = 50.0


def _wkv_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, sout_ref,
                state_scr, *, chunk: int, n_chunks: int):
    c = pl.program_id(1)

    @pl.when(c == 0)
    def _init():
        state_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0].astype(jnp.float32)          # (C, hd)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (1, hd)

    lc = jnp.cumsum(lw, axis=0)               # inclusive within-chunk
    lc_prev = lc - lw
    r_t = r * jnp.exp(jnp.maximum(lc_prev, -_CLIP))
    k_t = k * jnp.exp(jnp.minimum(-lc, _CLIP))

    A = jax.lax.dot_general(r_t, k_t, (((1,), (1,)), ((), ())))   # (C, C)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    s_ids = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    A = jnp.where(s_ids < t_ids, A, 0.0)                          # strict past
    bonus = jnp.sum(r * u * k, axis=1, keepdims=True)             # (C, 1)

    s_in = state_scr[...]
    y = jax.lax.dot(A, v) + bonus * v + jax.lax.dot(r_t, s_in)
    y_ref[0] = y.astype(y_ref.dtype)

    dec = jnp.exp(lc[-1:, :])                                     # (1, hd)
    k_hat = k * jnp.exp(jnp.maximum(lc[-1:, :] - lc, -_CLIP))
    s_new = dec.T * s_in + jax.lax.dot_general(
        k_hat, v, (((0,), (0,)), ((), ())))                       # (hd, hd)
    state_scr[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _final():
        sout_ref[0] = s_new.astype(sout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_chunked(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 lw: jnp.ndarray, u: jnp.ndarray, s0: jnp.ndarray, *,
                 chunk: int = 16, interpret: bool = True):
    """r/k/v/lw (BH, S, hd) f32, u (BH, 1, hd), s0 (BH, hd, hd)
    -> (y (BH, S, hd), s_final (BH, hd, hd))."""
    BH, S, hd = r.shape
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    y, s_final = pl.pallas_call(
        functools.partial(_wkv_kernel, chunk=chunk, n_chunks=n_chunks),
        grid=(BH, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, 1, hd), lambda b, c: (b, 0, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, hd), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, hd, hd), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((BH, hd, hd), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((hd, hd), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, s0)
    return y, s_final
