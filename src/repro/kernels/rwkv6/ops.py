"""jit'd wrapper matching the model layer's (B,S,H,hd) tensors."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6.kernel import wkv6_chunked


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, lw: jnp.ndarray,
         u: jnp.ndarray, s0: jnp.ndarray, *, chunk: int = 16,
         interpret: bool = True):
    """r/k/v/lw (B,S,H,hd); u (H,hd); s0 (B,H,hd,hd)
    -> (y (B,S,H,hd), s_final (B,H,hd,hd))."""
    B, S, H, hd = r.shape

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd).astype(jnp.float32)

    u_b = jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd)
    s0_b = s0.reshape(B * H, hd, hd).astype(jnp.float32)
    y, s_final = wkv6_chunked(fold(r), fold(k), fold(v), fold(lw),
                              u_b.astype(jnp.float32), s0_b,
                              chunk=chunk, interpret=interpret)
    y = y.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
    return y, s_final.reshape(B, H, hd, hd)
