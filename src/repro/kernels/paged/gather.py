"""``paged_gather`` — block-table KV assembly for the paged serving engine.

The paged KV pool (`repro.serve.paged`) stores every sequence's KV in
fixed-size token blocks scattered over one pool tensor ``(L, NT, D)``
(``NT = n_blocks * block`` token rows, ``D = KV·hd`` folded).  Decode needs
each slot's logical view — the blocks named by its block table, in order —
assembled into a dense ``(T, D)`` run.  This kernel is that gather:

    out[l, i*block : (i+1)*block, :] = x[l, table[i]*block : …, :]

following the ``rows.py`` tile-skip idiom: the *indices* ride in as a small
``(n, 1)`` int32 input blocked ``(1, 1)`` per grid step, the payload rows are
copied block-at-a-time, and the arithmetic is a pure copy — so the kernel is
bitwise-equal to the XLA gather by construction (``paged_gather_ref``,
test-enforced).  Unlike ``rows.py`` the table is *runtime* data (block tables
change every admission), so the source ref stays whole-array and the row
window is a dynamic slice on the token axis.

Interpret-mode fallback mirrors the other kernels: off-TPU the call runs
under ``interpret=True`` (CPU CI exercises the real kernel semantics).  On a
real TPU the whole-pool VMEM residency bounds pool size (~16 MB/core); the
compiled-Mosaic characterization harness owns that path — a scalar-prefetch
(``PrefetchScalarGridSpec``) variant that streams blocks HBM→VMEM is the
recorded follow-up there.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_kernel(tab_ref, x_ref, o_ref, *, block: int, cols: int):
    layer = pl.program_id(0)
    t = tab_ref[0, 0]
    o_ref[0] = jax.lax.dynamic_slice(
        x_ref[layer], (t * block, 0), (block, cols))


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def paged_gather(x: jnp.ndarray, table: jnp.ndarray, block: int,
                 interpret: bool = True) -> jnp.ndarray:
    """Gather block rows of ``x (L, NT, D)`` by ``table (n,)`` block ids.

    Returns ``(L, n*block, D)`` where entry ``i`` is the ``block`` token rows
    of pool block ``table[i]``, per layer.  ``table`` entries must lie in
    ``[0, NT // block)``; the caller pads unused entries with a trash block.
    """
    L, NT, D = x.shape
    n = int(table.shape[0])
    tab = table.reshape(n, 1).astype(jnp.int32)
    return pl.pallas_call(
        functools.partial(_gather_kernel, block=block, cols=D),
        grid=(L, n),
        in_specs=[
            pl.BlockSpec((1, 1), lambda l, i: (i, 0)),
            pl.BlockSpec((L, NT, D), lambda l, i: (0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, D), lambda l, i: (l, i, 0)),
        out_shape=jax.ShapeDtypeStruct((L, n * block, D), x.dtype),
        interpret=interpret,
    )(tab, x)


@functools.partial(jax.jit, static_argnames=("block",))
def paged_gather_ref(x: jnp.ndarray, table: jnp.ndarray,
                     block: int) -> jnp.ndarray:
    """XLA oracle: one advanced-indexing take over expanded token rows."""
    rows = (table[:, None] * block
            + jnp.arange(block, dtype=jnp.int32)[None, :]).reshape(-1)
    return x[:, rows]
