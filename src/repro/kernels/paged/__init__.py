from repro.kernels.paged.gather import paged_gather, paged_gather_ref

__all__ = ["paged_gather", "paged_gather_ref"]
