"""``Selection`` — which parameter leaves a ZO step perturbs, and when.

MeZO composes with parameter subsets by construction (the optimizer perturbs
whatever tree it is given — paper §3's PEFT results), and follow-up work
(Wang et al., 2024) shows *block-scheduled* sparse perturbation cuts both
compute and estimator variance.  Before this layer the repo had two disjoint
mechanisms: every estimator perturbed the full tree, and PEFT subsetting only
worked by swapping the whole params tree (``models/peft.py``).  ``Selection``
is the one contract both now share:

* a **static leaf predicate** — which leaves of the tree are trainable at a
  given schedule phase (pure function of the flattened tree structure, so it
  is decided at trace time: skipped leaves cost *zero* z generation and zero
  parameter writes, not a masked multiply);
* an optional **per-step block schedule** — ``n_phases`` rotating blocks with
  phase(t) = (t + phase_offset) mod n_phases, derived from the step counter
  of the one seed schedule, so the phase is identical under every execution
  plan (local, seed_parallel, async_worker, replay).

Built-in selections::

    full()                   # every leaf, every step (the default; zero-cost)
    leaves(pattern)          # regex over keystr leaf paths, static
    block_cyclic(k)          # leaf i active at phase i % k; phase = t % k
    peft("lora" | "prefix")  # the merged-tree PEFT subtree (models/peft.py)
    moe_experts(G)           # MoE: router frozen, expert group t % G active,
                             # every non-expert leaf active (architecture-aware
                             # block_cyclic; needs cfg.expert_groups=G layout)
    rows(block=R, k=K)       # SUB-LEAF: every leaf is cut into row-blocks of
                             # R rows; row-block b is active at phase b % K.
                             # The first selection whose perturbed bytes scale
                             # with a *fraction of each tensor*, not with the
                             # selected leaf set (Wang et al., 2024 sparse-ZO)

``rows`` is the sub-leaf selection: where every other kind decides *which
leaves* a step touches, ``rows`` decides *which row-blocks inside every
leaf*.  A leaf of shape ``(M, D...)`` is viewed as ``(M, prod(D))`` and cut
into ``ceil(M / R)`` row-blocks; step ``t`` perturbs the blocks with
``b % K == t % K``.  Backends consume the per-leaf :meth:`Selection.block_mask`
(a static :class:`RowBlocks` plan) and skip unselected blocks at *trace time*
— no z generation, no HBM reads, no writes — mirroring the leaf-skip
semantics.  The z bits of a selected block are identical whether the leaf is
perturbed whole or block-by-block (the blocked StreamRef index contract,
``repro.perturb.stream``), so ``rows(block=R, k=1)`` is bitwise ≡ ``full``.

Selections are plain hashable NamedTuples with a canonical string ``spec``
(``parse_selection`` round-trips it) — the form recorded in checkpoint meta
and the ``MZOL5`` trajectory-ledger header.  Replaying an artifact under a
different selection would pair the recorded scalars with different
perturbation supports, so ``check_replay_selection`` refuses the mismatch
(``SelectionMismatchError``), mirroring ``BackendMismatchError`` /
``PlanMismatchError``.

Unselected leaves are **completely untouched** by a step: no perturbation, no
rank-1 update, and no decoupled weight decay (a ``peft`` selection must not
decay the frozen base tree).
"""
from __future__ import annotations

import re
from typing import NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

SELECTION_KINDS = ("full", "leaves", "block_cyclic", "peft", "moe_experts",
                   "rows")
PEFT_MODES = ("lora", "prefix")

# grouped-MoE expert leaves: models/moe.py lays experts out as
# params[...]['moe']['eg{j}'][...] when cfg.expert_groups > 1
_EG_RE = re.compile(r"\['eg(\d+)'\]")
_ROUTER_KEY = "['router']"


class SelectionMismatchError(RuntimeError):
    """A seed-replay artifact (ledger / checkpoint) was recorded under one
    parameter selection and is being replayed under another.  The selection
    decides which leaves each recorded scalar's rank-1 update touches, so
    continuing would silently apply the updates to a different parameter
    support — refuse instead."""


class RowBlocks(NamedTuple):
    """Static sub-leaf row-block plan for ONE leaf under a ``rows``
    selection — the value of :meth:`Selection.block_mask`.

    A leaf of shape ``(M, D...)`` is viewed as ``(M, prod(D))``
    (``n_rows`` × ``row_width``; 1-D leaves get ``row_width=1``, scalars are
    one 1×1 row) and cut into ``ceil(n_rows / block_rows)`` row-blocks.
    Row-block ``b`` covers the contiguous flat element range
    ``[b*block_rows*row_width, min(n_rows, (b+1)*block_rows)*row_width)`` and
    is selected iff ``b % k == phase``.  All fields are Python ints, so a
    ``RowBlocks`` is hashable and rides jit ``static_argnames`` — backends
    branch on it at trace time.
    """
    block_rows: int        # R: rows per block
    row_width: int         # prod(shape[1:]) — elements per row
    n_rows: int            # shape[0] (or size, for 1-D leaves)
    k: int                 # schedule period (selection.n_phases)
    phase: int             # this step's phase, already reduced mod k

    @property
    def size(self) -> int:
        """Total element count of the leaf (``n_rows * row_width``)."""
        return self.n_rows * self.row_width

    @property
    def block_elems(self) -> int:
        """Flat elements per (full) row-block — the unit of the blocked
        StreamRef counter contract: block ``b`` owns counter indices
        ``[b*block_elems, (b+1)*block_elems)`` of its leaf stream."""
        return self.block_rows * self.row_width

    @property
    def n_blocks(self) -> int:
        return -(-self.n_rows // self.block_rows)

    @property
    def all_selected(self) -> bool:
        """True iff every row-block of this leaf is selected at ``phase`` —
        the signal backends use to route to the plain whole-leaf path
        (bitwise ≡ ``full``, zero sub-leaf overhead)."""
        return all(b % self.k == self.phase for b in range(self.n_blocks))

    def selected_blocks(self) -> tuple:
        """Indices of the row-blocks selected at ``phase``."""
        return tuple(b for b in range(self.n_blocks)
                     if b % self.k == self.phase)

    def block_range(self, b: int) -> tuple:
        """Flat element range ``(lo, hi)`` of row-block ``b``."""
        lo = b * self.block_elems
        hi = min(self.n_rows, (b + 1) * self.block_rows) * self.row_width
        return lo, hi

    def ranges(self) -> tuple:
        """Coalesced flat ``(lo, hi)`` element ranges of the selected blocks
        — what the xla backend's gather-free ``dynamic_slice`` banded path
        iterates over."""
        out = []
        for b in self.selected_blocks():
            lo, hi = self.block_range(b)
            if out and out[-1][1] == lo:
                out[-1] = (out[-1][0], hi)
            else:
                out.append((lo, hi))
        return tuple(out)

    def selected_elems(self) -> int:
        """Flat element count of the selected row-blocks."""
        return sum(hi - lo for lo, hi in self.ranges())

    def element_mask(self, flat_index):
        """Selected-predicate over flat element indices (vectorized; works on
        traced integer arrays) — the in-kernel mask for tiles that straddle a
        block boundary: element ``e`` lives in block ``e // block_elems``."""
        return (flat_index // self.block_elems) % self.k == self.phase


def leaf_row_blocks(leaf, block_rows: int, k: int, phase: int) -> RowBlocks:
    """Build the :class:`RowBlocks` plan of one leaf: shape ``(M, D...)`` →
    ``n_rows=M``, ``row_width=prod(D)``; 1-D → width 1; scalar → one 1×1
    row."""
    shape = tuple(leaf.shape)
    if len(shape) == 0:
        n_rows, width = 1, 1
    elif len(shape) == 1:
        n_rows, width = shape[0], 1
    else:
        n_rows = shape[0]
        width = 1
        for d in shape[1:]:
            width *= d
    return RowBlocks(block_rows=int(block_rows), row_width=int(width),
                     n_rows=int(n_rows), k=int(k), phase=int(phase) % int(k))


class Selection(NamedTuple):
    """One parameter-selection rule: ``kind`` + canonical argument, plus the
    block-schedule coordinates (``n_phases``, ``phase_offset``).  Hashable and
    comparable — it rides jit closures and ``functools.partial`` branches as
    static data."""
    kind: str
    arg: str = ""
    n_phases: int = 1
    phase_offset: int = 0

    # -- identity ----------------------------------------------------------- #
    @property
    def spec(self) -> str:
        """Canonical string form (``parse_selection`` round-trips it); the
        identity recorded in checkpoint meta and the MZOL5 ledger header.
        ``phase_offset`` is recorded separately (the ``sel_phase`` field)."""
        if self.kind == "full":
            return "full"
        if self.kind in ("block_cyclic", "moe_experts"):
            return f"{self.kind}({self.n_phases})"
        if self.kind == "rows":
            return f"rows(block={self.arg},k={self.n_phases})"
        return f"{self.kind}({self.arg})"

    def is_full(self) -> bool:
        return self.kind == "full"

    # -- schedule ----------------------------------------------------------- #
    def phase_at(self, step):
        """Schedule phase of step t: ``(t + phase_offset) mod n_phases``.
        A pure function of the step counter — the same coordinate every
        execution plan folds its seed streams from — so the phase is
        plan-invariant by construction.  Works on Python ints (replay, async
        application) and traced ints (the jitted step's ``lax.switch``
        index) alike."""
        return (step + self.phase_offset) % self.n_phases

    # -- the static predicate ----------------------------------------------- #
    def leaf_mask(self, params, phase: int = 0) -> Optional[tuple]:
        """Per-leaf boolean tuple for ``phase`` (flattening order), or
        ``None`` for the full selection (the no-overhead signal backends
        branch on).  Computed from the tree *structure* only — static at
        trace time, which is what lets backends skip unselected leaves
        entirely instead of masking them.  Non-floating leaves are never
        selected (the backends cannot perturb them; counting them would let
        a block phase — or a regex — select nothing perturbable).  An empty
        selection fails loudly: a step that perturbs nothing is a
        configuration error, not a no-op.
        """
        if self.kind == "full":
            return None
        flat, _ = jax.tree_util.tree_flatten_with_path(params)
        floating = [jnp.issubdtype(leaf.dtype, jnp.floating)
                    for _, leaf in flat]
        if self.kind == "block_cyclic":
            k = self.n_phases
            n_float = sum(floating)
            if n_float < k:
                raise ValueError(
                    f"block_cyclic({k}) over a tree with only {n_float} "
                    f"floating leaves leaves some phases with nothing to "
                    f"perturb; use k <= {n_float}")
            ph = int(phase) % k
            # block indices are assigned over the FLOATING leaves in
            # flattening order, so every phase owns perturbable leaves even
            # when integer leaves (token tables, masks) ride in the tree
            mask, j = [], 0
            for f in floating:
                mask.append(bool(f) and (j % k) == ph)
                j += 1 if f else 0
            mask = tuple(mask)
        elif self.kind == "moe_experts":
            mask = self._moe_experts_mask(flat, floating, phase)
        elif self.kind == "rows":
            # a leaf participates at this phase iff at least one of its
            # row-blocks is selected — blocks 0..n_blocks-1 hit phase p iff
            # p < n_blocks, so small leaves simply sit out the late phases
            # (their blocks come around on earlier ones)
            K = self.n_phases
            ph = int(phase) % K
            R = int(self.arg)
            mask = tuple(
                bool(f) and leaf_row_blocks(leaf, R, K, ph).n_blocks > ph
                for f, (_, leaf) in zip(floating, flat))
            if not any(mask):
                n_max = max((leaf_row_blocks(leaf, R, K, 0).n_blocks
                             for f, (_, leaf) in zip(floating, flat) if f),
                            default=0)
                raise ValueError(
                    f"rows(block={R},k={K}) selects nothing at phase {ph}: "
                    f"the largest floating leaf has only {n_max} row-blocks "
                    f"of {R} rows, so phases >= {n_max} would perturb "
                    f"nothing; use k <= {n_max} or a smaller block")
        else:
            paths = [jax.tree_util.keystr(p) for p, _ in flat]
            if self.kind == "leaves":
                rx = re.compile(self.arg)
                mask = tuple(bool(f) and bool(rx.search(s))
                             for f, s in zip(floating, paths))
            elif self.kind == "peft":
                prefix = f"['{self.arg}']"
                mask = tuple(bool(f) and s.startswith(prefix)
                             for f, s in zip(floating, paths))
            else:
                raise ValueError(f"unknown selection kind {self.kind!r}")
            if not any(mask):
                raise ValueError(
                    f"selection {self.spec!r} matches no floating leaves of "
                    f"the parameter tree (paths: {paths[:4]}...); an empty "
                    "selection would silently train nothing")
        return mask

    def _moe_experts_mask(self, flat, floating, phase) -> tuple:
        """Expert-wise MoE mask: the router is ALWAYS frozen (bitwise — its
        top-k dispatch decisions stay fixed within a step pair), expert-group
        leaf "eg{j}" is active iff ``j % G == phase``, and every other
        floating leaf (attention, norms, embeddings, head) is active every
        step — so the per-step perturbed bytes scale with ACTIVE experts,
        not total (ZO-cost ∝ active params, the MoE analogue of
        ``block_cyclic``).  Requires the grouped parameter layout
        (``cfg.expert_groups == G`` in models/moe.py) when G > 1."""
        G = self.n_phases
        ph = int(phase) % G
        paths = [jax.tree_util.keystr(p) for p, _ in flat]
        if not any(f and _ROUTER_KEY in s for f, s in zip(floating, paths)):
            raise ValueError(
                f"moe_experts({G}) over a tree with no ['router'] leaf — not "
                "an MoE parameter tree (build the model with cfg.n_experts > "
                "0, e.g. the mixtral-8x7b registry arch)")
        mask, groups_seen = [], set()
        for f, s in zip(floating, paths):
            if not f or _ROUTER_KEY in s:
                mask.append(False)
                continue
            m = _EG_RE.search(s)
            if m is None:
                mask.append(True)                  # non-expert leaf: always on
            else:
                j = int(m.group(1))
                groups_seen.add(j)
                mask.append(j % G == ph)
        if G > 1:
            covered = {j % G for j in groups_seen}
            if covered != set(range(G)):
                raise ValueError(
                    f"moe_experts({G}) needs the grouped expert layout with "
                    f"every phase owning a group, but the tree has expert "
                    f"groups {sorted(groups_seen)} (phases covered: "
                    f"{sorted(covered)} of {G}); build the model with "
                    f"cfg.replace(expert_groups={G})")
        return tuple(mask)

    # -- the sub-leaf plan --------------------------------------------------- #
    def block_mask(self, leaf, phase: int = 0) -> Optional[RowBlocks]:
        """Static sub-leaf row-block plan of ``leaf`` at ``phase``, or
        ``None`` for every non-``rows`` selection (whole-leaf semantics).
        Both backends consume this: the pallas backend launches only the
        tiles covering selected blocks (trace-time skip), the xla backend
        applies whole-leaf z over the selected row bands via gather-free
        ``dynamic_slice``.  The plan is a pure function of the leaf *shape*
        — restructuring or padding the surrounding tree never changes which
        counter indices a block consumes (the blocked StreamRef contract)."""
        if self.kind != "rows":
            return None
        return leaf_row_blocks(leaf, int(self.arg), self.n_phases, phase)

    # -- accounting (benchmarks / reporting) -------------------------------- #
    def selected_size(self, params, phase: int = 0) -> int:
        """Scalar count of the parameters active at ``phase`` — sub-leaf
        aware: under ``rows`` this counts only the selected row-blocks of
        each active leaf."""
        mask = self.leaf_mask(params, phase)
        leaves = jax.tree_util.tree_leaves(params)
        if mask is None:
            return sum(x.size for x in leaves)
        if self.kind == "rows":
            return sum(self.block_mask(x, phase).selected_elems()
                       for x, m in zip(leaves, mask) if m)
        return sum(x.size for x, m in zip(leaves, mask) if m)

    def selected_bytes(self, params, phase: int = 0) -> int:
        """Bytes of the parameters active at ``phase`` — the per-step
        perturbed (read-modify-write) traffic a backend pays under this
        selection.  Sub-leaf aware (see ``selected_size``)."""
        mask = self.leaf_mask(params, phase)
        leaves = jax.tree_util.tree_leaves(params)
        if mask is None:
            return sum(x.size * x.dtype.itemsize for x in leaves)
        if self.kind == "rows":
            return sum(self.block_mask(x, phase).selected_elems()
                       * x.dtype.itemsize
                       for x, m in zip(leaves, mask) if m)
        return sum(x.size * x.dtype.itemsize
                   for x, m in zip(leaves, mask) if m)


# --------------------------------------------------------------------------- #
# Factories
# --------------------------------------------------------------------------- #
def full() -> Selection:
    """Every leaf, every step — the default, and bitwise-identical to not
    passing a selection at all (estimators normalize it to ``None``)."""
    return Selection("full")


def leaves(pattern: str) -> Selection:
    """Static leaf selection by regex over ``jax.tree_util.keystr`` paths
    (e.g. ``leaves(r"\\['attn'\\]")`` perturbs only attention leaves)."""
    re.compile(pattern)            # fail at construction, not at trace time
    return Selection("leaves", arg=pattern)


def block_cyclic(k: int, phase_offset: int = 0) -> Selection:
    """k rotating leaf blocks: leaf i is active at phase i mod k, and step t
    runs phase (t + phase_offset) mod k — each step perturbs ~1/k of the
    tree, each leaf is visited every k steps (Wang et al., 2024's
    block-scheduled sparse ZO)."""
    k = int(k)
    if k < 1:
        raise ValueError(f"block_cyclic needs k >= 1, got {k}")
    return Selection("block_cyclic", n_phases=k,
                     phase_offset=int(phase_offset) % k)


def moe_experts(groups: int, phase_offset: int = 0) -> Selection:
    """Expert-wise MoE selection (ISSUE: ZO cost ∝ *active* params): step t
    perturbs expert group ``(t + phase_offset) % groups`` plus all non-expert
    leaves; the router is frozen bitwise every step so routing decisions are
    identical at θ+εz and θ−εz.  ``groups > 1`` requires the grouped
    parameter layout (``cfg.replace(expert_groups=groups)``); ``groups == 1``
    works on the legacy stacked layout and just freezes the router.

    >>> moe_experts(4).spec
    'moe_experts(4)'
    >>> parse_selection("moe_experts(4)") == moe_experts(4)
    True
    """
    g = int(groups)
    if g < 1:
        raise ValueError(f"moe_experts needs groups >= 1, got {g}")
    return Selection("moe_experts", n_phases=g,
                     phase_offset=int(phase_offset) % g)


def rows(block: int, k: int, phase_offset: int = 0) -> Selection:
    """Sub-leaf row-block selection: every leaf is viewed as ``(M, D...)``
    and cut into ``ceil(M / block)`` row-blocks of ``block`` rows; step t
    perturbs the blocks with ``b % k == (t + phase_offset) % k`` — each step
    touches ~1/k of *every tensor* (intra-tensor sparse ZO: perturbed bytes
    ∝ selected fraction, even for a single giant embedding), and every block
    is visited every k steps.  ``rows(block=R, k=1)`` selects everything and
    is bitwise ≡ ``full`` on both backends (the blocked StreamRef contract).

    >>> rows(block=256, k=4).spec
    'rows(block=256,k=4)'
    >>> parse_selection("rows(block=256,k=4)") == rows(256, 4)
    True
    """
    block = int(block)
    k = int(k)
    if block < 1:
        raise ValueError(f"rows needs block >= 1, got {block}")
    if k < 1:
        raise ValueError(f"rows needs k >= 1, got {k}")
    return Selection("rows", arg=str(block), n_phases=k,
                     phase_offset=int(phase_offset) % k)


def peft(mode: str) -> Selection:
    """The merged-tree PEFT selection: perturb only the ``mode`` subtree of a
    ``models.peft.peft_params(base, tree, mode)`` merged tree — LoRA / prefix
    become ordinary selections, replacing the bespoke tree-swap path."""
    if mode not in PEFT_MODES:
        raise ValueError(f"unknown peft mode {mode!r}; available: {PEFT_MODES}")
    return Selection("peft", arg=mode)


# --------------------------------------------------------------------------- #
# Spec parsing / normalization
# --------------------------------------------------------------------------- #
_SPEC_RE = re.compile(r"^(\w+)\((.*)\)$")
_ROWS_RE = re.compile(r"^block=(\d+)\s*,\s*k=(\d+)$")


def parse_selection(spec: str, phase_offset: int = 0) -> Selection:
    """Parse a canonical spec string (``Selection.spec`` round-trips):
    ``"full"``, ``"leaves(<regex>)"``, ``"block_cyclic(<k>)"``,
    ``"peft(lora|prefix)"``, ``"moe_experts(<G>)"``,
    ``"rows(block=<R>,k=<K>)"``.

    >>> parse_selection("block_cyclic(4)").spec
    'block_cyclic(4)'
    >>> parse_selection("rows(block=128,k=4)").spec
    'rows(block=128,k=4)'
    >>> parse_selection("leaves(\\\\['attn'\\\\])").spec
    "leaves(\\\\['attn'\\\\])"
    >>> parse_selection("moe_experts(2)").n_phases
    2
    >>> parse_selection("full").is_full()
    True
    """
    spec = spec.strip()
    if spec == "full":
        return full()
    m = _SPEC_RE.match(spec)
    if m is None:
        raise ValueError(
            f"unparseable selection spec {spec!r}; expected one of: full, "
            "leaves(<regex>), block_cyclic(<k>), peft(lora|prefix), "
            "moe_experts(<G>), rows(block=<R>,k=<K>)")
    kind, arg = m.group(1), m.group(2)
    if kind == "leaves":
        return leaves(arg)
    if kind == "block_cyclic":
        return block_cyclic(int(arg), phase_offset=phase_offset)
    if kind == "peft":
        return peft(arg)
    if kind == "moe_experts":
        return moe_experts(int(arg), phase_offset=phase_offset)
    if kind == "rows":
        rm = _ROWS_RE.match(arg.strip())
        if rm is None:
            raise ValueError(
                f"unparseable rows selection arguments {arg!r}; the "
                "canonical form is rows(block=<R>,k=<K>)")
        return rows(int(rm.group(1)), int(rm.group(2)),
                    phase_offset=phase_offset)
    raise ValueError(f"unknown selection kind {kind!r}; "
                     f"available: {SELECTION_KINDS}")


def resolve_selection(
        selection: Union[None, str, Selection]) -> Optional[Selection]:
    """Normalize an estimator-factory ``selection=`` argument: ``None`` and
    the full selection (object or ``"full"`` spec) become ``None`` — the
    zero-overhead signal that keeps the default path bitwise-identical to
    the pre-selection code — and spec strings are parsed."""
    if selection is None:
        return None
    if isinstance(selection, str):
        selection = parse_selection(selection)
    if not isinstance(selection, Selection):
        raise TypeError(f"selection must be a repro.select.Selection or spec "
                        f"string, got {type(selection).__name__}")
    if selection.is_full() and selection.phase_offset == 0:
        return None
    return selection


# --------------------------------------------------------------------------- #
# Replay-coordinate check (mirrors check_replay_backend / check_replay_plan)
# --------------------------------------------------------------------------- #
def check_replay_selection(recorded: Optional[str], active: Optional[str],
                           what: str,
                           recorded_phase: Optional[int] = None,
                           active_phase: Optional[int] = None) -> None:
    """Raise ``SelectionMismatchError`` if a recorded artifact's selection
    spec (or schedule phase offset) does not match the active optimizer's.
    ``None`` on either side (a pre-selection artifact, or a non-ZO optimizer)
    skips the check; MZOL1–4 ledgers deserialize with ``selection="full"``."""
    if recorded is None or active is None:
        return
    rp = int(recorded_phase or 0)
    ap = int(active_phase or 0)
    if recorded != active or rp != ap:
        raise SelectionMismatchError(
            f"{what} was recorded under parameter selection {recorded!r} "
            f"(phase offset {rp}) but the active optimizer runs {active!r} "
            f"(phase offset {ap}); the selection decides which leaves each "
            "recorded scalar's rank-1 update touches, so replay would "
            "silently apply the updates to a different parameter support.  "
            f"Re-create the optimizer with selection={recorded!r} (e.g. "
            f"zo.mezo(..., selection={recorded!r})).")
