"""``repro.select`` — the parameter-selection layer: masked & block-scheduled
ZO perturbation, honored by every estimator, backend, and execution plan.

One ``Selection`` (a static leaf predicate + an optional per-step block
schedule) threads through the whole stack:

* ``repro.perturb`` — ``StreamRef`` carries the selection; both backends
  (``xla``, ``pallas``) *skip* unselected leaves in ``perturb`` /
  ``fused_restore_update`` / ``apply_rank1`` / ``perturb_many`` (zero z
  generation, zero writes — not a masked multiply);
* ``repro.zo`` — every estimator factory accepts ``selection=``; the scalar
  transform chain is unchanged (selection lives below the scalars);
* ``repro.exec`` — every plan carries the selection, and the schedule phase
  is derived from the step counter of the one seed schedule, so it is
  plan-invariant (a block_cyclic ledger recorded under seed_parallel replays
  under ``replay()``);
* persistence — checkpoint meta and the ``MZOL5`` ledger header record the
  selection spec + phase offset; mismatched replay refuses
  (``SelectionMismatchError``).

Spec strings are the canonical persistence form (checkpoint meta, the MZOL5
ledger header, the ``--select`` launcher flag) and ``parse_selection``
round-trips every built-in kind:

>>> from repro import select
>>> select.parse_selection("full").spec
'full'
>>> select.parse_selection("block_cyclic(4)").spec
'block_cyclic(4)'
>>> select.parse_selection("peft(lora)").spec
'peft(lora)'
>>> select.parse_selection("moe_experts(2)").spec   # MoE expert-wise cycling
'moe_experts(2)'
>>> select.parse_selection("rows(block=256,k=4)").spec  # sub-leaf row blocks
'rows(block=256,k=4)'
>>> select.parse_selection(select.leaves(r"\\['attn'\\]").spec).arg
"\\\\['attn'\\\\]"

Factory objects and spec strings are interchangeable at every estimator
factory:

>>> from repro import zo
>>> opt = zo.mezo(lr=1e-6, selection=select.block_cyclic(4))
>>> opt = zo.fzoo(lr=1e-6, selection="leaves(\\\\['attn'\\\\])")
>>> opt = zo.mezo(lr=1e-3, selection=select.peft("lora"))   # merged-tree PEFT
>>> opt = zo.mezo(lr=1e-6, selection=select.moe_experts(2)) # router frozen
"""
from repro.select.base import (PEFT_MODES, SELECTION_KINDS, RowBlocks,
                               Selection, SelectionMismatchError,
                               block_cyclic, check_replay_selection, full,
                               leaf_row_blocks, leaves, moe_experts,
                               parse_selection, peft, resolve_selection, rows)

__all__ = [
    "PEFT_MODES", "SELECTION_KINDS", "RowBlocks", "Selection",
    "SelectionMismatchError", "block_cyclic", "check_replay_selection",
    "full", "leaf_row_blocks", "leaves", "moe_experts", "parse_selection",
    "peft", "resolve_selection", "rows",
]
