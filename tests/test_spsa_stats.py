"""Statistical properties of the SPSA estimator (paper Definition 1, Lemma 2):
unbiasedness, the (d+n−1)/n gradient-norm inflation, and exactness on linear
functions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import spsa
from repro.core.perturb import sample_z_tree
from repro.tree_utils import tree_size


D = 24


def quad_loss(p, batch):
    t = batch
    return 0.5 * jnp.sum((p["w"] - t) ** 2)


def linear_loss(p, batch):
    a = batch
    return jnp.sum(a * p["w"])


def test_spsa_exact_for_linear():
    """For L(θ)=aᵀθ: (ℓ+−ℓ−)/2ε == aᵀz exactly, for ANY ε (the odd Taylor
    terms vanish)."""
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (D,))
    p = {"w": jnp.zeros((D,))}
    for eps in (1e-1, 1e-3):
        r = spsa.spsa_projected_grad(linear_loss, p, a, key, eps)
        z = sample_z_tree(p, key)["w"]
        np.testing.assert_allclose(float(r.projected_grad), float(a @ z),
                                   rtol=2e-3)


def test_spsa_unbiased():
    """E[ĝ] == ∇L within Monte-Carlo error (scaled by the known variance)."""
    key = jax.random.PRNGKey(1)
    t = jax.random.normal(key, (D,))
    p = {"w": jnp.zeros((D,))}
    true_g = -t
    N = 3000
    oracle = jax.jit(lambda k: spsa.spsa_full_gradient_oracle(
        quad_loss, p, t, k, 1e-4)["w"])
    acc = np.zeros((D,), np.float64)
    for i in range(N):
        acc += np.asarray(oracle(jax.random.fold_in(key, i)), np.float64)
    acc /= N
    # per-coordinate std of the estimator is ~||∇L||·sqrt(2) (d-dim gaussian
    # quadratic forms); allow 5 sigma of the mean estimator
    sigma = float(np.linalg.norm(true_g)) * np.sqrt(2.0 / N)
    np.testing.assert_allclose(acc, np.asarray(true_g), atol=5 * sigma * 3)


def test_lemma2_norm_inflation():
    """E‖ĝ‖² == (d+n−1)/n · ‖∇L‖² (Lemma 2; batch noise zero here)."""
    key = jax.random.PRNGKey(2)
    t = jax.random.normal(key, (D,))
    p = {"w": jnp.zeros((D,))}
    gnorm2 = float(jnp.sum(t ** 2))
    N = 4000
    oracle = jax.jit(lambda k: spsa.spsa_full_gradient_oracle(
        quad_loss, p, t, k, 1e-4)["w"])
    sq = 0.0
    for i in range(N):
        g = oracle(jax.random.fold_in(key, i))
        sq += float(jnp.sum(g ** 2)) / N
    expected = (D + 1 - 1) / 1 * gnorm2      # n = 1 -> d·‖∇L‖²... exactly (d+2)
    # For gaussian z the exact factor is (d+2) (see paper App. G.2 footnote);
    # accept the (d .. d+2) band with MC slack.
    assert 0.85 * D * gnorm2 < sq < 1.15 * (D + 2) * gnorm2, (sq, D * gnorm2)


def test_one_point_vs_two_point_bias():
    """The residual-feedback one-point estimate has the same expectation but
    needs the carried state; first step with state 0 is biased — check the
    recurrence wiring rather than statistics."""
    key = jax.random.PRNGKey(3)
    t = jnp.ones((D,))
    p = {"w": jnp.zeros((D,))}
    st = spsa.one_point_init()
    g1, l1, st = spsa.one_point_projected_grad(quad_loss, p, t, key, 1e-3, st)
    assert float(st.prev_perturbed_loss) == pytest.approx(float(l1))
    g2, l2, st2 = spsa.one_point_projected_grad(
        quad_loss, p, t, jax.random.fold_in(key, 1), 1e-3, st)
    # second step uses the stored loss
    assert float(g2) == pytest.approx(
        (float(l2) - float(l1)) / 1e-3, rel=1e-4)


def test_zo_grad_norm_estimate():
    """Proposition 1: |ℓ+−ℓ−|/2ε on a single-leaf perturbation estimates the
    leaf's gradient norm (up to the 1-sample spread)."""
    key = jax.random.PRNGKey(4)
    t = jax.random.normal(key, (D,))
    p = {"w": jnp.zeros((D,)), "frozen": jnp.zeros((5,))}
    est = []
    for i in range(400):
        est.append(float(spsa.zo_grad_norm(
            lambda pp, b: quad_loss({"w": pp["w"]}, b), p, t,
            jax.random.fold_in(key, i), 1e-4, leaf_indices=[1])))
    # E[(aᵀz)²] = ‖a‖² -> sqrt of mean-square estimates the norm
    rms = np.sqrt(np.mean(np.square(est)))
    true = float(jnp.linalg.norm(t))
    assert abs(rms - true) / true < 0.15
