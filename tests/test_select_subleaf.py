"""Sub-leaf row-block selection (``rows(block=R,k=K)``) conformance.

The contracts, per ISSUE 9:

* ``rows(block=R, k=1)`` — every block selected — is BITWISE-identical to
  ``full`` on both backends (params AND z bits): the blocked StreamRef index
  contract means full selection needs no stream-id bump;
* a selected row-block's z bits are identical whether the leaf is perturbed
  whole or block-by-block, and stable under padding / restructuring of the
  surrounding tree (pallas counter streams are position-stable; the xla
  banded path slices the one whole-leaf z);
* unselected row-bands are completely untouched per step — no perturbation,
  no update, no weight decay (bitwise-frozen);
* ``seed_parallel(1)`` ≡ local bitwise under a rows selection;
* a rows run's MZOL5 ledger round-trips on {spsa, fzoo} × {xla,
  pallas-interpret}: replay-vs-replay bitwise, live-vs-replay < 2e-6;
* kernel level: the ``rows`` kernel variants launch only selected tiles and
  are bitwise-equal to the full kernels on selected elements — including
  tiles that straddle a block boundary (in-kernel modular mask) — while
  unselected elements keep x's bits exactly; the rows sqnorm kernel matches
  its pure-jnp oracle bitwise;
* guardrails: empty phases fail loudly, ``rescaled_spsa`` refuses rows
  selections, and the spec string round-trips.

Known, documented tolerance: the xla backend's *partial* banded application
is a differently-shaped graph than the whole-leaf fused multiply-add, so
selected bands may differ from the full graph's same elements by 1 ulp (FMA
contraction).  Only the pallas kernels hold the strict partial-selection
bitwise contract; the xla k=1 route goes through the unmodified whole-leaf
path and stays bitwise.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as zexec
from repro import select, zo
from repro.core.trajectory import TrajectoryLedger, replay
from repro.exec import StepProgram
from repro.kernels.zo_fused.kernel import (BLOCK_COLS, BLOCK_ROWS,
                                           zo_affine_2d)
from repro.kernels.zo_fused.multi import (zo_affine_chain_2d,
                                          zo_affine_multi_2d)
from repro.kernels.zo_fused.rows import (TILE_ELEMS, tile_plan,
                                         zo_affine_2d_rows,
                                         zo_affine_chain_2d_rows,
                                         zo_affine_multi_2d_rows,
                                         zo_sqnorm_2d_rows, zo_sqnorm_rows_ref)
from repro.perturb import StreamRef, get_backend
from repro.select import RowBlocks, SelectionMismatchError, leaf_row_blocks
from repro.tree_utils import tree_max_abs_diff

BACKENDS = ["xla", "pallas-interpret"]


def make_opt(estimator: str, backend: str, selection=None, lr=1e-3, eps=1e-3,
             weight_decay=0.0):
    if estimator == "spsa":
        return zo.mezo(lr=lr, eps=eps, backend=backend, selection=selection,
                       weight_decay=weight_decay)
    if estimator == "fzoo":
        return zo.fzoo(lr=lr, eps=eps, batch_seeds=3, backend=backend,
                       selection=selection, weight_decay=weight_decay)
    raise ValueError(estimator)


@pytest.fixture()
def problem():
    t = jax.random.normal(jax.random.PRNGKey(0), (12, 4))

    def loss_fn(p, b):
        scale = 1.0 if b is None else jnp.mean(b)
        return scale * (0.5 * jnp.sum((p["emb"] - t) ** 2)
                        + 0.1 * jnp.sum(p["w"] ** 2))

    params = {"emb": jnp.zeros((12, 4)), "w": jnp.ones((16,))}
    batch = jnp.linspace(0.5, 1.5, 8)
    return loss_fn, params, batch


def run_plan(opt, plan, loss_fn, params, batch, steps=4, seed=3, ledger=None):
    prog = StepProgram(opt, plan)
    state = prog.init(params, seed=seed)
    step = jax.jit(prog.step_fn(loss_fn))
    p = params
    for i in range(steps):
        p, state, m = step(p, state, batch)
        if ledger is not None:
            g = m.get("projected_grads")
            ledger.append(i, np.asarray(g) if g is not None
                          else float(m["projected_grad"]), float(m["lr"]))
    return p, prog


def ledger_for(prog, seed=3):
    meta = prog.meta
    return TrajectoryLedger(base_seed=seed, grad_dtype="float32",
                            backend=meta["perturb_backend"],
                            batch_seeds=meta["batch_seeds"],
                            exec_plan=meta["exec_plan"],
                            n_groups=meta["n_groups"],
                            selection=meta["selection"],
                            sel_phase=meta["sel_phase"])


def rows_elem_mask(leaf, block, k, phase):
    """Boolean selected-element mask of one leaf (numpy, flat order)."""
    rb = leaf_row_blocks(leaf, block, k, phase)
    idx = np.arange(leaf.size)
    return np.asarray(rb.element_mask(idx)).astype(bool)


# --------------------------------------------------------------------------- #
# rows(block=R, k=1) ≡ full, bitwise — params AND z bits, both backends
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
def test_rows_k1_bitwise_full(problem, estimator, backend):
    loss_fn, params, batch = problem
    p_none, _ = run_plan(make_opt(estimator, backend), zexec.local(),
                         loss_fn, params, batch)
    p_rows, _ = run_plan(make_opt(estimator, backend,
                                  selection=select.rows(block=4, k=1)),
                         zexec.local(), loss_fn, params, batch)
    assert tree_max_abs_diff(p_none, p_rows) == 0.0


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dist", ["gaussian", "rademacher", "sphere"])
def test_rows_k1_perturb_z_bits(problem, backend, dist):
    """The z bits (θ + εz views) of a k=1 rows selection match the
    no-selection views exactly — the blocked index contract at the backend
    primitive level."""
    _, params, _ = problem
    be = get_backend(backend)
    ref = StreamRef(jax.random.PRNGKey(5))
    ref_rows = ref.with_selection(select.rows(block=4, k=1), 0)
    a = be.perturb(params, ref, 1e-3, dist)
    b = be.perturb(params, ref_rows, 1e-3, dist)
    assert tree_max_abs_diff(a, b) == 0.0


# --------------------------------------------------------------------------- #
# Frozen unselected row-bands (perturb, update, AND decay)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
def test_rows_freezes_unselected_bands(problem, estimator, backend):
    loss_fn, params, batch = problem
    K = 3
    opt = make_opt(estimator, backend, selection=select.rows(block=4, k=K),
                   weight_decay=0.1)
    state = opt.init(params, seed=3)
    step = jax.jit(opt.step_fn(loss_fn))
    p = params
    for t in range(K):
        p_next, state, _ = step(p, state, batch)
        for name in ("emb", "w"):
            sel_mask = rows_elem_mask(params[name], 4, K, t)
            before = np.asarray(p[name]).reshape(-1)
            after = np.asarray(p_next[name]).reshape(-1)
            # unselected bands: bitwise-frozen despite nonzero weight decay
            np.testing.assert_array_equal(after[~sel_mask], before[~sel_mask])
            # selected bands moved
            assert np.max(np.abs(after[sel_mask] - before[sel_mask])) > 0.0
        p = p_next


def test_rows_every_block_visited_over_k_steps(problem):
    loss_fn, params, batch = problem
    p, _ = run_plan(make_opt("spsa", "xla",
                             selection=select.rows(block=4, k=2)),
                    zexec.local(), loss_fn, params, batch, steps=2)
    for name in ("emb", "w"):
        moved = np.asarray(p[name] != params[name]).reshape(-1)
        assert moved.all(), f"{name}: some rows never updated over k steps"


# --------------------------------------------------------------------------- #
# Block z stability: whole vs block-by-block, padding, tree restructuring
# --------------------------------------------------------------------------- #
def test_rows_pallas_block_bits_match_whole_leaf():
    """pallas: a selected block's perturbed values are bitwise the same as
    the whole-leaf perturbation's values at those elements."""
    be = get_backend("pallas-interpret")
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (12, 4))}
    ref = StreamRef(jax.random.PRNGKey(7))
    whole = be.perturb(params, ref, 1e-2)
    part = be.perturb(params, ref.with_selection(select.rows(block=4, k=3),
                                                 1), 1e-2)
    m = rows_elem_mask(params["w"], 4, 3, 1)
    w_whole = np.asarray(whole["w"]).reshape(-1)
    w_part = np.asarray(part["w"]).reshape(-1)
    np.testing.assert_array_equal(w_part[m], w_whole[m])
    np.testing.assert_array_equal(w_part[~m],
                                  np.asarray(params["w"]).reshape(-1)[~m])


def test_rows_xla_bands_slice_the_whole_leaf_z(problem):
    """xla: the banded path applies slices of the ONE whole-leaf z — so
    unselected bands are bitwise-frozen and selected bands match the
    whole-leaf graph within the documented 1-ulp FMA tolerance."""
    _, params, _ = problem
    be = get_backend("xla")
    ref = StreamRef(jax.random.PRNGKey(7))
    whole = be.perturb(params, ref, 1e-2)
    part = be.perturb(params, ref.with_selection(select.rows(block=4, k=3),
                                                 1), 1e-2)
    for name in ("emb", "w"):
        m = rows_elem_mask(params[name], 4, 3, 1)
        w_whole = np.asarray(whole[name]).reshape(-1)
        w_part = np.asarray(part[name]).reshape(-1)
        np.testing.assert_allclose(w_part[m], w_whole[m], rtol=0, atol=1e-6)
        np.testing.assert_array_equal(
            w_part[~m], np.asarray(params[name]).reshape(-1)[~m])


def test_rows_pallas_block_bits_stable_under_leaf_padding():
    """Appending rows to a leaf never changes the z an earlier block
    consumes: the counter stream indexes by flat element position."""
    be = get_backend("pallas-interpret")
    ref = StreamRef(jax.random.PRNGKey(3)).with_selection(
        select.rows(block=2, k=2), 0)
    small = {"w": jnp.ones((8, 4))}
    big = {"w": jnp.ones((14, 4))}                 # same leaf index, more rows
    p_small = np.asarray(be.perturb(small, ref, 1e-2)["w"]).reshape(-1)
    p_big = np.asarray(be.perturb(big, ref, 1e-2)["w"]).reshape(-1)
    np.testing.assert_array_equal(p_small, p_big[:p_small.size])


def test_rows_block_bits_stable_under_tree_restructuring():
    """Replacing a *sibling* leaf never changes another leaf's block z: the
    plan and the counter stream are pure functions of the leaf's own shape
    and index."""
    be = get_backend("pallas-interpret")
    ref = StreamRef(jax.random.PRNGKey(3)).with_selection(
        select.rows(block=2, k=2), 0)
    t1 = {"a": jnp.ones((8, 4)), "b": jnp.ones((4,))}
    t2 = {"a": jnp.ones((8, 4)), "b": jnp.zeros((10, 3))}
    p1 = be.perturb(t1, ref, 1e-2)
    p2 = be.perturb(t2, ref, 1e-2)
    assert tree_max_abs_diff({"a": p1["a"]}, {"a": p2["a"]}) == 0.0


# --------------------------------------------------------------------------- #
# perturb_many / affine_many contracts under a partial rows plan
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_rows_perturb_many_matches_stacked_singles(backend):
    be = get_backend(backend)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (12, 4))}
    sel = select.rows(block=4, k=3)
    base = jax.random.PRNGKey(7)
    refs = [StreamRef(jax.random.fold_in(base, j)).with_selection(sel, 1)
            for j in range(3)]
    for scale in (1e-2, (1e-2, -1e-2, 5e-3)):
        stacked = be.perturb_many(params, refs, scale, "gaussian")
        scales = [scale] * 3 if not isinstance(scale, tuple) else list(scale)
        singles = [be.perturb(params, r, s) for r, s in zip(refs, scales)]
        want = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *singles)
        assert tree_max_abs_diff(stacked, want) == 0.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_rows_affine_many_matches_sequential_fold(backend):
    be = get_backend(backend)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (12, 4))}
    sel = select.rows(block=4, k=3)
    base = jax.random.PRNGKey(9)
    refs = [StreamRef(jax.random.fold_in(base, j)).with_selection(sel, 1)
            for j in range(3)]
    coeffs = [1e-3, -5e-4, 2e-4]
    decays = [1e-4, 0.0, 0.0]
    fused = be.affine_many(params, refs, coeffs, decays, "gaussian")
    seq = params
    for r, c, d in zip(refs, coeffs, decays):
        seq = be.apply_rank1(seq, r, c, d, "gaussian")
    assert tree_max_abs_diff(fused, seq) == 0.0


# --------------------------------------------------------------------------- #
# sp(1) ≡ local, bitwise
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_rows_sp1_bitwise_local(problem, backend):
    loss_fn, params, batch = problem
    sel = select.rows(block=4, k=2)
    p_local, _ = run_plan(make_opt("spsa", backend, selection=sel),
                          zexec.local(), loss_fn, params, batch)
    p_sp1, _ = run_plan(make_opt("spsa", backend, selection=sel),
                        zexec.seed_parallel(1), loss_fn, params, batch)
    assert tree_max_abs_diff(p_local, p_sp1) == 0.0


# --------------------------------------------------------------------------- #
# MZOL5 ledger round-trip: {spsa, fzoo} × {xla, pallas-interpret}
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
def test_rows_ledger_roundtrip(problem, estimator, backend):
    loss_fn, params, batch = problem
    sel = select.rows(block=4, k=2)
    opt = make_opt(estimator, backend, selection=sel)
    prog = StepProgram(opt, zexec.local())
    led = ledger_for(prog)
    p_live, _ = run_plan(opt, zexec.local(), loss_fn, params, batch,
                         ledger=led)
    raw = led.to_bytes()
    assert raw.startswith(b"MZOL5")          # rows rides the MZOL5 header
    led2 = TrajectoryLedger.from_bytes(raw)
    assert (led2.selection, led2.sel_phase) == ("rows(block=4,k=2)", 0)
    mk = lambda: make_opt(estimator, backend, selection=sel)
    rec = replay(params, led2, mk())
    assert tree_max_abs_diff(rec, p_live) < 2e-6
    # replay is deterministic: replay-vs-replay bitwise
    assert tree_max_abs_diff(rec, replay(params, led2, mk())) == 0.0
    # replay under a different selection refuses
    with pytest.raises(SelectionMismatchError, match="rows"):
        replay(params, led2, make_opt(estimator, backend))


# --------------------------------------------------------------------------- #
# Kernel level: selected tiles ≡ full kernel, unselected rows keep x bits
# --------------------------------------------------------------------------- #
def _kernel_case(n_tiles=2, seed_val=11):
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (n_tiles * BLOCK_ROWS, BLOCK_COLS), jnp.float32)
    seed = jnp.int32(seed_val)
    return x, seed


def _sel_mask_2d(x, block_elems, k, phase):
    idx = np.arange(x.size)
    return (((idx // block_elems) % k) == phase).reshape(x.shape)


@pytest.mark.parametrize("block_rows,k,phase", [
    (BLOCK_ROWS, 2, 0),        # block == tile: pure tiles, no in-kernel mask
    (96, 2, 1),                # straddling blocks: in-kernel modular mask
    (96, 3, 0),
])
def test_rows_kernel_single_bitwise_vs_full(block_rows, k, phase):
    x, seed = _kernel_case()
    be_elems = block_rows * BLOCK_COLS
    sel, pure = tile_plan(x.size, be_elems, k, phase)
    y_full = np.asarray(zo_affine_2d(x, seed, 0.9, 1e-2, interpret=True))
    y_rows = np.asarray(zo_affine_2d_rows(
        x, seed, jnp.float32(0.9), jnp.float32(1e-2), sel, be_elems, k,
        phase, masked=not pure, interpret=True))
    m = _sel_mask_2d(x, be_elems, k, phase)
    np.testing.assert_array_equal(y_rows[m], y_full[m])
    np.testing.assert_array_equal(y_rows[~m], np.asarray(x)[~m])


def test_rows_kernel_multi_and_chain_bitwise_vs_full():
    x, seed = _kernel_case()
    seeds = jnp.asarray([11, 12, 13], jnp.int32)
    a = jnp.asarray([1.0, 0.9, 1.0], jnp.float32)
    b = jnp.asarray([1e-2, -1e-2, 5e-3], jnp.float32)
    be_elems = 96 * BLOCK_COLS
    k, phase = 2, 0
    sel, pure = tile_plan(x.size, be_elems, k, phase)
    m = _sel_mask_2d(x, be_elems, k, phase)

    y_full = np.asarray(zo_affine_multi_2d(x, seeds, a, b, interpret=True))
    y_rows = np.asarray(zo_affine_multi_2d_rows(
        x, seeds, a, b, sel, be_elems, k, phase, masked=not pure,
        interpret=True))
    for j in range(3):
        np.testing.assert_array_equal(y_rows[j][m], y_full[j][m])
        np.testing.assert_array_equal(y_rows[j][~m], np.asarray(x)[~m])

    c_full = np.asarray(zo_affine_chain_2d(x, seeds, a, b, interpret=True))
    c_rows = np.asarray(zo_affine_chain_2d_rows(
        x, seeds, a, b, sel, be_elems, k, phase, masked=not pure,
        interpret=True))
    np.testing.assert_array_equal(c_rows[m], c_full[m])
    np.testing.assert_array_equal(c_rows[~m], np.asarray(x)[~m])


def test_rows_sqnorm_kernel_matches_oracle():
    n = 2 * TILE_ELEMS - 777                     # ragged: padding masked out
    be_elems = 96 * BLOCK_COLS
    k, phase = 2, 1
    sel, _ = tile_plan(n, be_elems, k, phase)
    got = float(zo_sqnorm_2d_rows(n, 11, sel, be_elems, k, phase,
                                  interpret=True))
    want = float(zo_sqnorm_rows_ref(n, 11, sel, be_elems, k, phase))
    assert got == want                           # bitwise (same pinned sums)
    # sanity: roughly E[z²]·selected_elems for the gaussian stream
    rb = RowBlocks(96, BLOCK_COLS, -(-n // BLOCK_COLS), k, phase)
    n_sel = sum(min(hi, n) - lo for lo, hi in
                ((b * be_elems, (b + 1) * be_elems)
                 for b in range(-(-n // be_elems)) if b % k == phase)
                if lo < n)
    assert abs(got / n_sel - 1.0) < 0.05


def test_tile_plan_static_properties():
    # pure when blocks == tiles; masked when straddling
    sel, pure = tile_plan(4 * TILE_ELEMS, TILE_ELEMS, 2, 1)
    assert sel == (1, 3) and pure
    sel, pure = tile_plan(4 * TILE_ELEMS, 96 * BLOCK_COLS, 2, 0)
    assert not pure and len(sel) == 4            # every tile has a selected blk
    # k=1 selects every tile, purely
    sel, pure = tile_plan(3 * TILE_ELEMS - 5, TILE_ELEMS, 1, 0)
    assert sel == (0, 1, 2) and pure
    with pytest.raises(ValueError, match="selects no tiles"):
        tile_plan(TILE_ELEMS, 2 * TILE_ELEMS, 2, 1)


# --------------------------------------------------------------------------- #
# Spec round-trip, accounting, guardrails
# --------------------------------------------------------------------------- #
def test_rows_spec_roundtrip_and_accounting(problem):
    _, params, _ = problem                       # emb: 48 f32, w: 16 f32
    sel = select.rows(block=4, k=2)
    assert sel.spec == "rows(block=4,k=2)"
    assert select.parse_selection(sel.spec) == sel
    with pytest.raises(ValueError, match="unparseable rows"):
        select.parse_selection("rows(4,2)")
    with pytest.raises(ValueError, match="block >= 1"):
        select.rows(block=0, k=2)
    # emb (12,4): blocks of 16 elems → phase 0 selects blocks 0, 2 (32 elems);
    # w (16,): blocks of 4 elems → blocks 0, 2 (8 elems)
    assert sel.selected_size(params, phase=0) == 40
    assert sel.selected_bytes(params, phase=0) == 160
    # non-rows selections carry no sub-leaf plan
    assert select.block_cyclic(2).block_mask(params["emb"]) is None
    rb = sel.block_mask(params["emb"], phase=1)
    assert isinstance(rb, RowBlocks) and rb.selected_blocks() == (1,)


def test_rows_empty_phase_fails_loudly(problem):
    loss_fn, params, _ = problem
    # largest leaf (emb, 12 rows) has 3 blocks of 4 rows → k=5 leaves
    # phases 3, 4 with nothing to perturb
    opt = make_opt("spsa", "xla", selection=select.rows(block=4, k=5))
    state = opt.init(params, seed=0)
    with pytest.raises(ValueError, match="rows"):
        jax.jit(opt.step_fn(loss_fn))(params, state, None)


def test_rescaled_spsa_refuses_rows():
    with pytest.raises(ValueError, match="rows"):
        zo.estimators.rescaled_spsa(selection=select.rows(block=4, k=2))


def test_rows_small_leaf_sits_out_late_phases():
    """A scalar leaf (one block) participates only at phase 0; the selection
    layer excludes it from later phases instead of failing."""
    sel = select.rows(block=4, k=2)
    params = {"s": jnp.float32(1.0), "w": jnp.ones((16, 4))}
    m0 = sel.leaf_mask(params, 0)
    m1 = sel.leaf_mask(params, 1)
    assert m0 == (True, True)
    assert m1 == (False, True)
