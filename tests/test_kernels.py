"""Per-kernel validation (interpret=True on CPU): shape/dtype sweeps against
the pure-jnp ref oracles, plus hypothesis property tests."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.flash_attention import ops as flash_ops
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rwkv6 import ops as wkv_ops
from repro.kernels.rwkv6.ref import wkv6_ref
from repro.kernels.zo_fused import ops as zo_ops
from repro.kernels.zo_fused import ref as zo_ref


# --------------------------------------------------------------------------- #
# zo_fused
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("shape", [(8,), (100,), (33, 65), (4, 7, 9), (512, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_zo_affine_sweep(shape, dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), shape, dtype)
    got = zo_ops.zo_affine(x, 13, 0.9, 0.05)
    want = zo_ref.zo_affine_ref(x, 13, 0.9, 0.05)
    tol = 1e-6 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_zo_gaussianity():
    x = jnp.zeros((256, 1024))
    z = np.asarray(zo_ops.zo_affine(x, 5, 0.0, 1.0))
    assert abs(z.mean()) < 0.01
    assert abs(z.std() - 1.0) < 0.01
    assert abs(float((z ** 3).mean())) < 0.05      # symmetry


def test_zo_perturb_update_cycle():
    """kernel-backed MeZO chain: perturb/unperturb restores; update is the
    expected rank-1 step."""
    params = {"w": jax.random.normal(jax.random.PRNGKey(1), (70, 70)),
              "b": jnp.ones((31,))}
    p1 = zo_ops.perturb_tree(params, 3, 1e-3)
    p2 = zo_ops.perturb_tree(p1, 3, -1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(p2),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    upd = zo_ops.update_tree(params, 3, 2.0, 0.01)
    z0 = zo_ref.z_for(params["b"].shape, zo_ops.leaf_seed(3, 0))
    np.testing.assert_allclose(np.asarray(upd["b"]),
                               np.asarray(params["b"] - 0.01 * 2.0 * z0),
                               atol=1e-5)


def test_zo_mezo_step_kernel_descends():
    t = jax.random.normal(jax.random.PRNGKey(2), (64,))
    loss_fn = lambda p, b: 0.5 * jnp.sum((p["w"] - t) ** 2)
    params = {"w": jnp.zeros((64,))}
    for s in range(200):
        params, g, loss = zo_ops.mezo_step_kernel(loss_fn, params, None,
                                                  seed=s, eps=1e-3, lr=5e-3)
    assert float(loss_fn(params, None)) < 0.25 * 0.5 * float(jnp.sum(t ** 2))


# --------------------------------------------------------------------------- #
# flash attention
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,KV,hd,bq,bk", [
    (128, 4, 4, 64, 64, 64),     # MHA
    (128, 4, 2, 64, 32, 64),     # GQA 2x
    (96, 6, 2, 32, 32, 32),      # non-pow2 seq (padding path)
    (256, 2, 1, 128, 128, 128),  # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, H, KV, hd, bq, bk, dtype):
    key = jax.random.PRNGKey(0)
    B = 2
    q = jax.random.normal(key, (B, S, H, hd), dtype)
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd), dtype)
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd), dtype)
    got = flash_ops.flash_attention(q, k, v, block_q=bq, block_k=bk)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3)).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [1, 17, 64])
def test_flash_attention_sliding_window(window):
    key = jax.random.PRNGKey(3)
    q = jax.random.normal(key, (1, 128, 2, 32))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 128, 2, 32))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 128, 2, 32))
    got = flash_ops.flash_attention(q, k, v, window=window, block_q=32,
                                    block_k=32)
    want = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_matches_chunked_xla_twin():
    """The Pallas kernel and the XLA-level chunked attention are numerically
    the same algorithm."""
    from repro.models.attention import attend_chunked
    key = jax.random.PRNGKey(4)
    B, S, H, KV, hd = 1, 128, 4, 2, 32
    q = jax.random.normal(key, (B, S, H, hd))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, S, KV, hd))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, S, KV, hd))
    a = flash_ops.flash_attention(q, k, v, block_q=64, block_k=64)
    pos = jnp.arange(S, dtype=jnp.int32)
    b = attend_chunked(q, k, v, q_pos=pos, k_pos=pos, chunk=64)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


# --------------------------------------------------------------------------- #
# rwkv6 chunked WKV
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("S,H,hd,chunk", [
    (64, 2, 32, 16), (128, 3, 64, 16), (48, 1, 16, 16), (64, 2, 32, 8),
])  # chunk <= 16 is the supported envelope: exponent range rate*C <= 43.5
def test_wkv6_sweep(S, H, hd, chunk):
    key = jax.random.PRNGKey(0)
    B = 2
    shp = (B, S, H, hd)
    r = jax.random.normal(key, shp)
    k = jax.random.normal(jax.random.fold_in(key, 1), shp)
    v = jax.random.normal(jax.random.fold_in(key, 2), shp)
    lw = -jnp.exp(jnp.clip(jax.random.normal(jax.random.fold_in(key, 3), shp),
                           -8, 1))
    u = jax.random.normal(jax.random.fold_in(key, 4), (H, hd))
    s0 = jax.random.normal(jax.random.fold_in(key, 5), (B, H, hd, hd))
    y_k, s_k = wkv_ops.wkv6(r, k, v, lw, u, s0, chunk=chunk)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    y_r, s_r = wkv6_ref(fold(r), fold(k), fold(v), fold(lw),
                        jnp.broadcast_to(u[None], (B, H, hd)).reshape(B * H, 1, hd),
                        s0.reshape(B * H, hd, hd))
    np.testing.assert_allclose(
        np.asarray(y_k), np.asarray(y_r.reshape(B, H, S, hd).transpose(0, 2, 1, 3)),
        atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s_k),
                               np.asarray(s_r.reshape(B, H, hd, hd)),
                               atol=5e-4, rtol=1e-3)


@hypothesis.given(seed=st.integers(0, 10_000), decay=st.floats(0.05, 2.5))
@hypothesis.settings(max_examples=10, deadline=None)
def test_wkv6_property_decay_regimes(seed, decay):
    """Kernel == oracle across decay strengths (the numerically hard axis)."""
    key = jax.random.PRNGKey(seed)
    B, S, H, hd = 1, 32, 1, 16
    shp = (B, S, H, hd)
    r = jax.random.normal(key, shp)
    k = jax.random.normal(jax.random.fold_in(key, 1), shp)
    v = jax.random.normal(jax.random.fold_in(key, 2), shp)
    lw = jnp.full(shp, -decay)
    u = jnp.zeros((H, hd))
    s0 = jnp.zeros((B, H, hd, hd))
    y_k, _ = wkv_ops.wkv6(r, k, v, lw, u, s0, chunk=16)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    y_r, _ = wkv6_ref(fold(r), fold(k), fold(v), fold(lw),
                      jnp.zeros((B * H, 1, hd)), s0.reshape(B * H, hd, hd))
    np.testing.assert_allclose(
        np.asarray(y_k),
        np.asarray(y_r.reshape(B, H, S, hd).transpose(0, 2, 1, 3)),
        atol=1e-4, rtol=1e-3)
