"""Cross-backend contract tests for the pluggable perturbation layer
(``repro.perturb``): one ``StreamRef`` contract, two backends (``xla``
threefry, ``pallas`` fused-kernel counter hash), loud refusal of
backend-mismatched replay."""
import struct

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import zo
from repro.core.trajectory import TrajectoryLedger, replay
from repro.kernels.zo_fused import ref as zo_ref
from repro.perturb import (BackendMismatchError, StreamRef, get_backend,
                           pallas as pallas_mod)
from repro.tree_utils import tree_max_abs_diff

BACKENDS = ["xla", "pallas"]


def tree_a():
    k = jax.random.PRNGKey(0)
    return {"w": jax.random.normal(k, (70, 33)), "b": jnp.ones((31,))}


# --------------------------------------------------------------------------- #
# StreamRef: the one canonical derivation
# --------------------------------------------------------------------------- #
def test_stream_ref_derivation_is_legacy_fold_chain():
    """derive(k, t[, j]) must be the exact legacy fold chain — existing
    ledgers/checkpoints replay unchanged."""
    base = jax.random.PRNGKey(3)
    np.testing.assert_array_equal(
        np.asarray(StreamRef.derive(base, 7).key),
        np.asarray(jax.random.fold_in(base, 7)))
    np.testing.assert_array_equal(
        np.asarray(StreamRef.derive(base, 7, 2).key),
        np.asarray(jax.random.fold_in(jax.random.fold_in(base, 7), 2)))


def test_stream_ref_counter_projection_consistent():
    """leaf_seed follows the legacy zo_fused stride schedule from
    counter_seed, and is a deterministic function of the key."""
    ref = StreamRef.derive(jax.random.PRNGKey(1), 5)
    s0 = int(ref.counter_seed())
    for i in (0, 1, 7):
        assert int(ref.leaf_seed(i)) == int(pallas_mod.leaf_seed(s0, i))
    assert int(StreamRef.derive(jax.random.PRNGKey(1), 5).counter_seed()) == s0
    assert int(StreamRef.derive(jax.random.PRNGKey(1), 6).counter_seed()) != s0


def test_xla_backend_is_bitwise_legacy_core_perturb():
    from repro.core.perturb import perturb as legacy_perturb
    params = tree_a()
    key = jax.random.PRNGKey(9)
    got = get_backend("xla").perturb(params, StreamRef(key), 1e-3)
    want = legacy_perturb(params, key, 1e-3)
    for a, b in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# z stability across tree restructuring / padding (the StreamRef contract)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_z_stable_across_tree_restructuring(backend):
    """A leaf's z depends only on (StreamRef, leaf_index, shape) — nesting
    the tree differently or resizing *another* leaf must not change it."""
    be = get_backend(backend)
    ref = StreamRef.derive(jax.random.PRNGKey(0), 11)
    w = jnp.zeros((37, 5))
    flat = {"0w": w, "1b": jnp.zeros((8,))}            # leaf 0 = w
    nested = {"a": {"x": w}, "b": {"y": jnp.zeros((300,))}}  # leaf 0 = w too
    z_flat = be.perturb(flat, ref, 1.0)["0w"]
    z_nested = be.perturb(nested, ref, 1.0)["a"]["x"]
    np.testing.assert_array_equal(np.asarray(z_flat), np.asarray(z_nested))


def test_pallas_z_stable_across_padding_boundary():
    """The counter stream is position-stable: a leaf's leading elements don't
    change when the leaf (and hence its kernel padding) grows."""
    z8 = pallas_mod.zo_affine(jnp.zeros((8,)), 5, 0.0, 1.0, interpret=True)
    z100 = pallas_mod.zo_affine(jnp.zeros((100,)), 5, 0.0, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(z8), np.asarray(z100[:8]))


# --------------------------------------------------------------------------- #
# pallas interpret mode vs the pure-jnp oracle
# --------------------------------------------------------------------------- #
def test_pallas_interpret_z_matches_ref_oracle_bitwise():
    z = pallas_mod.zo_affine(jnp.zeros((100,)), 5, 0.0, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(z),
                                  np.asarray(zo_ref.z_for((100,), 5)))


def test_pallas_interpret_affine_matches_ref_oracle_bitwise():
    """Same arithmetic, same fusion: under jit the kernel (interpret) and the
    oracle produce identical bits."""
    x = jax.random.normal(jax.random.PRNGKey(0), (33, 65))
    got = pallas_mod.zo_affine(x, 13, 0.9, 0.05, interpret=True)
    want = jax.jit(zo_ref.zo_affine_ref)(x, 13, 0.9, 0.05)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_pallas_apply_rank1_is_the_expected_rank1_step():
    be = get_backend("pallas")
    params = tree_a()
    ref = StreamRef.derive(jax.random.PRNGKey(2), 0)
    out = be.apply_rank1(params, ref, 0.01, 0.001)
    z_b = zo_ref.z_for((31,), ref.leaf_seed(0).astype(jnp.uint32))  # "b" < "w"
    want = (1.0 - 0.001) * params["b"] - 0.01 * z_b
    np.testing.assert_allclose(np.asarray(out["b"]), np.asarray(want),
                               atol=1e-6, rtol=1e-6)


# --------------------------------------------------------------------------- #
# perturb_many (the batched multi-seed entry point)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("B", [1, 3, 8])
def test_perturb_many_matches_stacked_singles(backend, B):
    """Both backends override the stacked-singles default with genuinely
    batched generation (vmapped threefry / the batched-seed kernel) — the
    override must stay bitwise-equal to the sequential path."""
    be = get_backend(backend)
    params = tree_a()
    refs = [StreamRef.derive(jax.random.PRNGKey(0), 4, j) for j in range(B)]
    many = be.perturb_many(params, refs, 1e-3)
    for j, r in enumerate(refs):
        single = be.perturb(params, r, 1e-3)
        for a, b in zip(jax.tree_util.tree_leaves(
                jax.tree_util.tree_map(lambda x: x[j], many)),
                jax.tree_util.tree_leaves(single)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert many["w"].shape == (B, 70, 33)


@pytest.mark.parametrize("backend", BACKENDS)
def test_perturb_many_unselected_leaves_broadcast_bitwise(backend):
    """Unselected (and non-floating) leaves are returned as copy-free
    ``broadcast_to`` views rather than B materialized stacked copies — the
    bits must be exactly what the old ``jnp.stack([p] * B)`` produced."""
    from repro import select
    be = get_backend(backend)
    params = {"b": jnp.ones((31,)),
              "w": jax.random.normal(jax.random.PRNGKey(0), (70, 33))}
    sel = select.leaves(r"\['w'\]")
    refs = [StreamRef.derive(jax.random.PRNGKey(7), 0, j).with_selection(
        sel, 0) for j in range(4)]
    many = be.perturb_many(params, refs, 1e-2)
    np.testing.assert_array_equal(
        np.asarray(many["b"]), np.asarray(jnp.stack([params["b"]] * 4)))
    assert many["b"].shape == (4, 31)
    # selected leaf still perturbs per stream
    assert not np.array_equal(np.asarray(many["w"][0]),
                              np.asarray(many["w"][1]))


def test_pallas_batched_kernel_generates_b_streams_per_tile():
    """The batched kernel's per-stream slices equal single-seed kernel calls
    bitwise (one launch, B z-streams against each resident x tile)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (70, 33))
    seeds = jnp.asarray([5, 9, 123], jnp.int32)
    batched = pallas_mod.zo_affine_batched(x, seeds, 0.9, 0.05,
                                           interpret=True)
    for j in range(3):
        single = pallas_mod.zo_affine(x, int(seeds[j]), 0.9, 0.05,
                                      interpret=True)
        np.testing.assert_array_equal(np.asarray(batched[j]),
                                      np.asarray(single))


# --------------------------------------------------------------------------- #
# Distribution matrix: pallas now covers all three dists; unknown names
# still fail loudly (no wrong-scale silent fallback)
# --------------------------------------------------------------------------- #
def test_pallas_supports_full_dist_matrix():
    """Sphere joined the pallas matrix via the kernel-fused two-pass rescale
    (``zo_sqnorm`` pass + b-folded gaussian affine).  Every documented dist
    must now perturb on either backend, and the estimator factory composes."""
    be = get_backend("pallas")
    params = tree_a()
    ref = StreamRef.derive(jax.random.PRNGKey(0), 0)
    for dist in ("gaussian", "rademacher", "sphere"):
        out = be.perturb(params, ref, 1e-3, dist=dist)
        assert out["w"].shape == (70, 33)
    zo.mezo(lr=1e-3, eps=1e-3, dist="sphere", backend="pallas")


def test_pallas_sphere_matches_xla_semantics():
    """Pallas sphere uses the same z ⋅ sqrt(d)/‖z‖ construction as xla (over
    its own counter stream): the perturbation offset has squared norm ≈ d·ε²
    — the defining property of uniform-on-the-sphere scaling."""
    be = get_backend("pallas")
    params = {"w": jnp.zeros((300, 40)), "b": jnp.zeros((77,))}
    ref = StreamRef.derive(jax.random.PRNGKey(0), 2)
    out = be.perturb(params, ref, 1e-3, dist="sphere")
    sq = sum(float(jnp.sum(jnp.asarray(x, jnp.float32) ** 2))
             for x in jax.tree_util.tree_leaves(out))
    d = 300 * 40 + 77
    np.testing.assert_allclose(sq, d * 1e-6, rtol=1e-3)


def test_pallas_sphere_does_not_disturb_gaussian_bits():
    """Adding sphere must not have moved the gaussian/rademacher streams: the
    kernel is still called with the same seeds and the same coefficients, so
    the ref-oracle equalities (and hence every pre-PR ledger) still hold."""
    z = pallas_mod.zo_affine(jnp.zeros((100,)), 5, 0.0, 1.0, interpret=True)
    np.testing.assert_array_equal(np.asarray(z),
                                  np.asarray(zo_ref.z_for((100,), 5)))
    zr = pallas_mod.zo_affine(jnp.zeros((100,)), 5, 0.0, 1.0, interpret=True,
                              dist="rademacher")
    np.testing.assert_array_equal(
        np.asarray(zr), np.asarray(zo_ref.z_for((100,), 5,
                                                dist="rademacher")))


def test_pallas_stream_id_unchanged_by_sphere():
    """Sphere is a wrapper-level scalar on the existing gaussian stream —
    no new z generator, so the recorded stream identity must NOT bump (a
    bump would refuse replay of every ledger recorded since z2)."""
    assert get_backend("pallas").stream_id == "pallas+z2"


def test_unknown_dist_still_raises():
    be = get_backend("pallas")
    with pytest.raises(NotImplementedError, match="pallas"):
        be.perturb(tree_a(), StreamRef.derive(jax.random.PRNGKey(0), 0),
                   1e-3, dist="cauchy")


# --------------------------------------------------------------------------- #
# In-kernel rademacher (sign of one counter stream)
# --------------------------------------------------------------------------- #
def test_pallas_rademacher_matches_ref_oracle_bitwise():
    """The kernel's rademacher stream (interpret mode, XLA-lowered) equals
    the pure-jnp oracle bitwise and is a genuine ±1 stream."""
    z = pallas_mod.zo_affine(jnp.zeros((1000,)), 5, 0.0, 1.0, interpret=True,
                             dist="rademacher")
    np.testing.assert_array_equal(
        np.asarray(z), np.asarray(zo_ref.z_for((1000,), 5,
                                               dist="rademacher")))
    vals = set(np.unique(np.asarray(z)))
    assert vals == {-1.0, 1.0}
    assert abs(float(np.mean(np.asarray(z)))) < 0.1        # unbiased sign


def test_pallas_rademacher_batched_matches_singles_bitwise():
    x = jax.random.normal(jax.random.PRNGKey(0), (70, 33))
    seeds = jnp.asarray([5, 9, 123], jnp.int32)
    batched = pallas_mod.zo_affine_batched(x, seeds, 0.9, 0.05,
                                           interpret=True, dist="rademacher")
    for j in range(3):
        single = pallas_mod.zo_affine(x, int(seeds[j]), 0.9, 0.05,
                                      interpret=True, dist="rademacher")
        np.testing.assert_array_equal(np.asarray(batched[j]),
                                      np.asarray(single))


def test_pallas_rademacher_backend_roundtrip():
    """A full perturb → fused restore+update chain on the pallas backend with
    dist='rademacher': restore with g=0 reproduces the center bitwise (±1
    streams regenerate exactly), and the estimator factory accepts it."""
    be = get_backend("pallas")
    params = tree_a()
    ref = StreamRef.derive(jax.random.PRNGKey(2), 3)
    p_plus = be.perturb(params, ref, 1e-3, dist="rademacher")
    p_minus = be.perturb(p_plus, ref, -2e-3, dist="rademacher")
    restored = be.fused_restore_update(p_minus, ref, 1e-3, 0.0, 0.0,
                                       dist="rademacher")
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-6, rtol=0)
    zo.mezo(lr=1e-3, eps=1e-3, dist="rademacher", backend="pallas")


def test_xla_supports_full_dist_matrix():
    be = get_backend("xla")
    params = tree_a()
    ref = StreamRef.derive(jax.random.PRNGKey(0), 0)
    for dist in ("gaussian", "rademacher", "sphere"):
        out = be.perturb(params, ref, 1e-3, dist=dist)
        assert out["w"].shape == (70, 33)


# --------------------------------------------------------------------------- #
# Backend recording + mismatch refusal (ledger and checkpoint)
# --------------------------------------------------------------------------- #
def test_ledger_serialization_roundtrips_backend():
    led = TrajectoryLedger(base_seed=7, grad_dtype="float32", backend="pallas")
    led.append(0, 0.5, 1e-3)
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    assert led2.backend == "pallas"
    assert led2.steps == [0]


def test_legacy_mzol1_ledger_reads_as_xla():
    """Pre-backend ledgers (MZOL1) must keep deserializing, as xla."""
    buf = b"MZOL1\x00" + struct.pack("<qi", 42, 4) + struct.pack("<q", 1)
    buf += np.asarray([3], np.int64).tobytes()
    buf += np.asarray([0.25], np.float32).tobytes()
    buf += np.asarray([1e-3], np.float32).tobytes()
    led = TrajectoryLedger.from_bytes(buf)
    assert led.backend == "xla"
    assert led.base_seed == 42 and led.steps == [3]


def params0():
    return {"w": jnp.ones((12,)), "b": jnp.ones((3, 5))}


def test_replay_refuses_backend_mismatch():
    opt_pal = zo.mezo(lr=1e-3, eps=1e-3, backend="pallas")
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                           backend=opt_pal.backend_name)
    led.append(0, 0.5, 1e-3)
    opt_xla = zo.mezo(lr=1e-3, eps=1e-3, backend="xla")
    with pytest.raises(BackendMismatchError, match="pallas"):
        replay(params0(), led, opt_xla)
    # and matching backend replays fine
    replay(params0(), led, opt_pal)


def test_replay_refuses_older_pallas_stream_version():
    """The pallas z generator was revised (polynomial Box–Muller, stream id
    'pallas+z2'): artifacts recorded under the original 'pallas' stream must
    refuse to replay — the bits differ, silent divergence otherwise."""
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                           backend="pallas")       # v1-era recorded identity
    led.append(0, 0.5, 1e-3)
    opt_pal = zo.mezo(lr=1e-3, eps=1e-3, backend="pallas")
    assert opt_pal.backend_name == "pallas+z2"
    with pytest.raises(BackendMismatchError, match="z-stream"):
        replay(params0(), led, opt_pal)


def test_checkpoint_resume_refuses_backend_mismatch(tmp_path):
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import train

    target = {"w": jnp.zeros((12,)), "b": jnp.zeros((3, 5))}

    def loss_fn(p, batch):
        del batch
        return 0.5 * sum(jnp.sum((x - y) ** 2) for x, y in
                         zip(jax.tree_util.tree_leaves(p),
                             jax.tree_util.tree_leaves(target)))

    pipe = Pipeline(DataSpec("lm", batch=2, seq=4, vocab=11, seed=1))
    ck = CheckpointManager(str(tmp_path / "run"), interval=2)
    led = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    train(loss_fn, params0(), zo.mezo(lr=1e-3, eps=1e-3, backend="xla"),
          pipe, total_steps=4, ckpt=ck, ledger=led, donate=False)
    assert ck.load_ledger().backend == "xla"

    led2 = TrajectoryLedger(base_seed=0, grad_dtype="float32")
    with pytest.raises(BackendMismatchError):
        train(loss_fn, params0(), zo.mezo(lr=1e-3, eps=1e-3, backend="pallas"),
              pipe, total_steps=8, ckpt=ck, ledger=led2, donate=False)


def test_replay_is_deterministic_per_backend():
    """Two replays of the same ledger under the same backend are bitwise
    identical — the recovery invariant, per backend."""
    for backend in BACKENDS:
        opt = zo.mezo(lr=1e-3, eps=1e-3, backend=backend)
        led = TrajectoryLedger(base_seed=0, grad_dtype="float32",
                               backend=opt.backend_name)
        for i in range(4):
            led.append(i, 0.1 * (i + 1), 1e-3)
        r1 = replay(params0(), led, opt)
        r2 = replay(params0(), led, opt)
        for a, b in zip(jax.tree_util.tree_leaves(r1),
                        jax.tree_util.tree_leaves(r2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------- #
# Live step vs replay arithmetic, per backend
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_replay_update_matches_live_step(backend):
    """The ledger-recovery invariant holds through either backend: replaying
    the recorded (seed, g, lr) reproduces the live step's parameters."""
    target = {"w": jnp.zeros((12,)), "b": jnp.zeros((3, 5))}

    def loss_fn(p, batch):
        del batch
        return 0.5 * sum(jnp.sum((x - y) ** 2) for x, y in
                         zip(jax.tree_util.tree_leaves(p),
                             jax.tree_util.tree_leaves(target)))

    opt = zo.mezo(lr=1e-3, eps=1e-3, weight_decay=0.01, backend=backend)
    params = params0()
    state = opt.init(params, seed=4)
    p1, _, m = jax.jit(opt.step_fn(loss_fn))(params, state, None)
    from repro.core.perturb import step_key
    skey = step_key(opt.init(params, seed=4).base_key, jnp.int32(0))
    p_replayed = opt.replay_update(params, skey, m["projected_grad"], m["lr"])
    assert tree_max_abs_diff(p1, p_replayed) < 1e-6
