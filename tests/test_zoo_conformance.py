"""Architecture-axis conformance: every registry family under the ZO stack.

The matrix: (family ∈ {dense, moe, ssm, encdec, hybrid}) × (estimator ∈
{spsa, fzoo}) × (backend ∈ {xla, pallas-interpret}) × (plan ∈ {local,
seed_parallel, replay}), asserting on real model forwards what test_exec
proves on the toy problem:

* ``seed_parallel(1)`` ≡ ``local`` BITWISE on every family;
* a ledger written live replays to the live params within fp accumulation
  (< 2e-6, one f32 ulp of recorded-g reapplication); replay-vs-replay is
  BITWISE — the determinism contract of docs/ARCHITECTURE.md;
* ``seed_parallel(2)`` ledgers carry their plan coordinates and replay
  through a matching StepProgram (xla legs; the backend × plan full cross
  for n>1 lives in test_exec);
* MoE expert-wise selection (``moe_experts(G)``) perturbs ONLY the scheduled
  expert group: the router is bitwise-frozen always, the off-phase groups
  are bitwise-frozen this step and perturbed the next;
* the grouped ``cfg.expert_groups`` leaf layout is a pure re-chunking:
  regrouping legacy stacked weights reproduces the legacy loss bitwise;
* RWKV6 / SSD dual forward modes (``cfg.scan_mode`` ∈ {"chunk",
  "fused_recurrent"}) agree within documented tolerance (1e-4 abs at smoke
  scale; observed ~1e-6) at the model level and produce matching ZO losses.

The expensive fzoo × xla legs and the seed_parallel(2) legs carry the
``slow`` marker: the per-push CI lane (``-m "not slow"``) keeps one
estimator per backend per family; tier-1 (no filter) runs everything.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as zexec
from repro import zo
from repro.core.trajectory import TrajectoryLedger, replay
from repro.exec import StepProgram
from repro.models import bundle, family_arch
import repro.models.rwkv6 as R
import repro.models.ssm as S
from repro.tree_utils import tree_max_abs_diff

FAMILIES = ("dense", "moe", "ssm", "encdec", "hybrid")
BACKENDS = ("xla", "pallas-interpret")
STEPS, SEED, BATCH, SEQ = 2, 3, 2, 8
MOE_GROUPS = 2
SCAN_PARITY_ATOL = 1e-4     # documented chunk-vs-recurrent tolerance


def _family_setup(fam):
    cfg = family_arch(fam)          # registry smoke cfg for the family
    sel = None
    if fam == "moe":
        # grouped expert layout + the registry's default expert-wise
        # selection: router frozen, one group per step (MZOL5 ledger path)
        cfg = cfg.replace(expert_groups=MOE_GROUPS)
        sel = f"moe_experts({MOE_GROUPS})"
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    batch = b.make_batch(jax.random.PRNGKey(1), BATCH, SEQ)
    return cfg, b.loss_fn(), params, batch, sel


def _make_opt(estimator, backend, sel):
    if estimator == "spsa":
        return zo.mezo(lr=1e-4, eps=1e-3, backend=backend, selection=sel)
    return zo.fzoo(lr=1e-4, eps=1e-3, batch_seeds=2, backend=backend,
                   selection=sel)


def _run_plan(opt, plan, loss_fn, params, batch, ledger=None):
    prog = StepProgram(opt, plan)
    state = prog.init(params, seed=SEED)
    step = jax.jit(prog.step_fn(loss_fn))
    p = params
    for i in range(STEPS):
        p, state, m = step(p, state, batch)
        if ledger is not None:
            g = m.get("projected_grads")
            ledger.append(i, np.asarray(g) if g is not None
                          else float(m["projected_grad"]), float(m["lr"]))
    return p, prog


def _ledger_for(prog):
    meta = prog.meta
    return TrajectoryLedger(base_seed=SEED, grad_dtype="float32",
                            backend=meta["perturb_backend"],
                            batch_seeds=meta["batch_seeds"],
                            exec_plan=meta["exec_plan"],
                            n_groups=meta["n_groups"],
                            selection=meta["selection"],
                            sel_phase=meta["sel_phase"])


def _cells():
    """One conformance cell per (family, estimator, backend); the costly
    fzoo × xla legs are slow-marked (same invariants, heaviest compiles)."""
    out = []
    for fam in FAMILIES:
        for est in ("spsa", "fzoo"):
            for bk in BACKENDS:
                marks = ([pytest.mark.slow]
                         if (est == "fzoo" and bk == "xla") else [])
                out.append(pytest.param(fam, est, bk,
                                        id=f"{fam}-{est}-{bk}", marks=marks))
    return out


# --------------------------------------------------------------------------- #
# the matrix: local ≡ sp(1) bitwise + ledger replay, per family
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("fam,estimator,backend", _cells())
def test_family_conformance(fam, estimator, backend):
    cfg, loss_fn, params, batch, sel = _family_setup(fam)

    led = _ledger_for(StepProgram(_make_opt(estimator, backend, sel),
                                  zexec.local()))
    p_live, _ = _run_plan(_make_opt(estimator, backend, sel), zexec.local(),
                          loss_fn, params, batch, ledger=led)

    # seed_parallel(1) degenerates to the facade step bitwise — on the real
    # model forward, not just the toy problem
    p_sp1, _ = _run_plan(_make_opt(estimator, backend, sel),
                         zexec.seed_parallel(1), loss_fn, params, batch)
    assert tree_max_abs_diff(p_live, p_sp1) == 0.0

    # ledger round-trip (MZOL3/MZOL5 depending on coordinates) + replay
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    assert led2.selection == led.selection
    rec = replay(params, led2, _make_opt(estimator, backend, sel))
    assert tree_max_abs_diff(rec, p_live) < 2e-6
    # replay determinism is bitwise — the artifact IS the run
    rec2 = replay(params, led2, _make_opt(estimator, backend, sel))
    assert tree_max_abs_diff(rec, rec2) == 0.0


@pytest.mark.slow
@pytest.mark.parametrize("fam", FAMILIES)
def test_family_seed_parallel_2_replay(fam):
    """sp(2) on the xla leg: the ledger carries plan coordinates and replays
    through a matching StepProgram (backend × plan cross: test_exec)."""
    cfg, loss_fn, params, batch, sel = _family_setup(fam)
    opt = _make_opt("spsa", "xla", sel)
    prog = StepProgram(opt, zexec.seed_parallel(2))
    led = _ledger_for(prog)
    p_live, _ = _run_plan(opt, zexec.seed_parallel(2), loss_fn, params,
                          batch, ledger=led)
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    assert (led2.exec_plan, led2.n_groups) == ("seed_parallel", 2)
    rec = StepProgram(_make_opt("spsa", "xla", sel),
                      zexec.seed_parallel(2)).replay(params, led2)
    assert tree_max_abs_diff(rec, p_live) < 2e-6
    rec2 = StepProgram(_make_opt("spsa", "xla", sel),
                       zexec.seed_parallel(2)).replay(params, led2)
    assert tree_max_abs_diff(rec, rec2) == 0.0


# --------------------------------------------------------------------------- #
# MoE: expert-wise selection perturbs only the scheduled group
# --------------------------------------------------------------------------- #
def _leaf_diffs(a, b):
    """{keystr: max abs diff} over aligned leaves."""
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree_util.tree_leaves(b)
    return {jax.tree_util.keystr(k): float(jnp.max(jnp.abs(x - y)))
            for (k, x), y in zip(fa, fb)}


def test_moe_expert_wise_step_freezes_router_and_off_phase_group():
    cfg, loss_fn, params, batch, sel = _family_setup("moe")
    opt = _make_opt("spsa", "xla", sel)
    state = opt.init(params, seed=SEED)
    step = jax.jit(opt.step_fn(loss_fn))

    p1, state, _ = step(params, state, batch)
    d = _leaf_diffs(params, p1)
    router = {k: v for k, v in d.items() if "router" in k}
    eg0 = {k: v for k, v in d.items() if "'eg0'" in k}
    eg1 = {k: v for k, v in d.items() if "'eg1'" in k}
    rest = {k: v for k, v in d.items()
            if "router" not in k and "'eg" not in k}
    assert router and eg0 and eg1 and rest     # the partition is real
    # step 0 == phase 0: group 0 + every non-expert floating leaf move;
    # the router and group 1 are bitwise-frozen
    assert all(v == 0.0 for v in router.values()), router
    assert all(v == 0.0 for v in eg1.values()), eg1
    assert any(v > 0.0 for v in eg0.values())
    assert any(v > 0.0 for v in rest.values())

    # step 1 == phase 1: now group 1 moves and group 0 is frozen
    p2, state, _ = step(p1, state, batch)
    d2 = _leaf_diffs(p1, p2)
    assert all(d2[k] == 0.0 for k in router), {k: d2[k] for k in router}
    assert all(d2[k] == 0.0 for k in eg0), {k: d2[k] for k in eg0}
    assert any(d2[k] > 0.0 for k in eg1)


def test_moe_grouped_layout_is_pure_rechunking():
    """Slicing legacy stacked expert weights into eg{j} groups reproduces
    the legacy forward bitwise — grouping changes the ZO selection
    granularity, never the math."""
    legacy_cfg = family_arch("moe")
    grouped_cfg = legacy_cfg.replace(expert_groups=MOE_GROUPS)
    b = bundle(legacy_cfg)
    params = b.init(jax.random.PRNGKey(0))
    per = legacy_cfg.n_experts // MOE_GROUPS

    def regroup(tree):
        if isinstance(tree, dict):
            if "router" in tree and "w1" in tree:     # a legacy moe dict
                out = {"router": tree["router"]}
                for j in range(MOE_GROUPS):
                    # expert axis is -3 for w1/w2/w3 (E, d, ff)-family
                    # shapes, robust to a stacked scan_layers leading axis
                    out[f"eg{j}"] = {
                        k: tree[k][..., j * per:(j + 1) * per, :, :]
                        for k in ("w1", "w2", "w3") if k in tree}
                return out
            return {k: regroup(v) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(regroup(v) for v in tree)
        return tree

    gparams = regroup(params)
    batch = b.make_batch(jax.random.PRNGKey(1), BATCH, SEQ)
    l_legacy = jax.jit(bundle(legacy_cfg).loss_fn())(params, batch)
    l_grouped = jax.jit(bundle(grouped_cfg).loss_fn())(gparams, batch)
    assert float(l_legacy) == float(l_grouped)


# --------------------------------------------------------------------------- #
# RWKV6 / SSD dual forward modes: chunk ≡ fused_recurrent
# --------------------------------------------------------------------------- #
def test_rwkv6_scan_modes_agree():
    cfg = family_arch("ssm")
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (BATCH, 24), 0,
                                cfg.vocab_size)
    lg_c, st_c = R.forward(cfg, params, tokens=tokens, mode="chunk")
    lg_r, st_r = R.forward(cfg, params, tokens=tokens,
                           mode="fused_recurrent")
    np.testing.assert_allclose(np.asarray(lg_c), np.asarray(lg_r),
                               atol=SCAN_PARITY_ATOL, rtol=1e-3)
    # cfg-driven dispatch ≡ the explicit override, bitwise
    lg_cfg, _ = R.forward(cfg.replace(scan_mode="fused_recurrent"), params,
                          tokens=tokens)
    assert float(jnp.max(jnp.abs(lg_cfg - lg_r))) == 0.0


def test_ssd_scan_modes_agree():
    from repro.models import all_archs
    from repro.models.common import KeyGen
    cfg = all_archs()["hymba-1.5b"].smoke_cfg
    p = S.ssm_params(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32)
    u = jax.random.normal(jax.random.PRNGKey(2), (2, 48, cfg.d_model))
    y_c, h_c = S.ssm_scan(cfg, p, u, None, mode="chunk")
    y_r, h_r = S.ssm_scan(cfg, p, u, None, mode="fused_recurrent")
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               atol=SCAN_PARITY_ATOL, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               atol=SCAN_PARITY_ATOL, rtol=1e-3)


def test_ssm_zo_step_parity_across_modes():
    """One MeZO step under each scan mode: same seeds, losses within the
    documented forward tolerance — the estimator sees the same landscape."""
    cfg, _, params, batch, _ = _family_setup("ssm")
    losses = {}
    for mode in ("chunk", "fused_recurrent"):
        mcfg = cfg.replace(scan_mode=mode)
        opt = _make_opt("spsa", "xla", None)
        state = opt.init(params, seed=SEED)
        _, _, m = jax.jit(opt.step_fn(bundle(mcfg).loss_fn()))(
            params, state, batch)
        losses[mode] = float(m["loss"])
    assert abs(losses["chunk"] - losses["fused_recurrent"]) < SCAN_PARITY_ATOL


def test_scan_mode_validation():
    cfg = family_arch("ssm")
    with pytest.raises(ValueError, match="scan mode"):
        R.forward(cfg, bundle(cfg).init(jax.random.PRNGKey(0)),
                  tokens=jnp.zeros((1, 4), jnp.int32), mode="nope")
