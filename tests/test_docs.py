"""Docs quality gates: markdown link/anchor/file-reference checking over
README + docs/, and the registry/selection docstring examples run as
doctests.  Stdlib only — this is the CI docs-check job."""
import doctest
import pathlib
import re

import pytest

ROOT = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [ROOT / "README.md", *sorted((ROOT / "docs").glob("*.md"))]

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_CODE_SPAN = re.compile(r"`([^`\n]+)`")
# an inline-code repo path, optionally with a :line suffix
_PATH_REF = re.compile(
    r"^(?P<path>(?:src|tests|benchmarks|docs|examples)/[\w./\-]+"
    r"\.(?:py|md|yml|yaml|json|toml))(?::(?P<line>\d+))?$")
# runtime artifacts: referenced in prose, produced by benches, gitignored
_RUNTIME_PREFIXES = ("results/",)


def _strip_code_blocks(text: str) -> str:
    """Drop fenced code blocks — their contents aren't doc links."""
    return re.sub(r"```.*?```", "", text, flags=re.DOTALL)


def _slugify(heading: str) -> str:
    """GitHub's anchor slug: strip markup, lowercase, drop punctuation,
    spaces to hyphens."""
    h = re.sub(r"[`*_]", "", heading.strip())
    h = h.lower()
    h = "".join(c for c in h if c.isalnum() or c in " -")
    return h.replace(" ", "-")


def _anchors(md_path: pathlib.Path) -> set:
    out = set()
    for line in _strip_code_blocks(md_path.read_text()).splitlines():
        m = re.match(r"#{1,6}\s+(.*)", line)
        if m:
            out.add(_slugify(m.group(1)))
    return out


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_markdown_links_resolve(doc):
    """Every relative link in README/docs points at an existing file, and
    every #anchor at a real heading of its target document."""
    assert doc.exists(), doc
    text = _strip_code_blocks(doc.read_text())
    problems = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue                      # external: not checked offline
        if "actions/workflows" in target:
            continue                      # CI badge: resolves on the forge
        path_part, _, anchor = target.partition("#")
        base = (doc.parent / path_part).resolve() if path_part else doc
        if not base.exists():
            problems.append(f"{target}: missing file {path_part}")
            continue
        if anchor and base.suffix == ".md" and anchor not in _anchors(base):
            problems.append(f"{target}: no heading for #{anchor} "
                            f"(have {sorted(_anchors(base))})")
    assert not problems, f"{doc.name}:\n" + "\n".join(problems)


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_inline_file_references_exist(doc):
    """Inline-code repo paths (``src/...py``, ``tests/...py:123``) must point
    at real files, and a :line suffix at a real line — stale references
    fail the build instead of rotting."""
    problems = []
    for span in _CODE_SPAN.findall(doc.read_text()):
        if span.startswith(_RUNTIME_PREFIXES):
            continue                      # bench artifacts, gitignored
        m = _PATH_REF.match(span)
        if not m:
            continue
        p = ROOT / m.group("path")
        if not p.exists():
            problems.append(f"`{span}`: no such file")
        elif m.group("line"):
            n_lines = len(p.read_text().splitlines())
            if int(m.group("line")) > n_lines:
                problems.append(f"`{span}`: file has only {n_lines} lines")
    assert not problems, f"{doc.name}:\n" + "\n".join(problems)


def test_readme_model_zoo_covers_all_registry_families():
    """The README support matrix must keep a row per registry family."""
    from repro.models import all_archs
    text = (ROOT / "README.md").read_text()
    zoo = text[text.index("## Model zoo"):]
    for family in sorted({a.cfg.family for a in all_archs().values()}):
        assert re.search(rf"^\|\s*`{family}`", zoo, re.M), \
            f"README model-zoo matrix is missing family {family!r}"


# --------------------------------------------------------------------------- #
# docstring examples as doctests (registry + selection spec language)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("modname", [
    "repro.models.registry",
    "repro.select",
    "repro.select.base",
])
def test_docstring_examples(modname):
    import importlib
    mod = importlib.import_module(modname)
    res = doctest.testmod(mod, verbose=False)
    assert res.attempted > 0, f"{modname} lost its doctest examples"
    assert res.failed == 0, f"{modname}: {res.failed} doctest failures"
