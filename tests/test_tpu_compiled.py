"""Compiled-Mosaic characterization harness for the zo_fused kernels.

Everything in this file requires a REAL TPU: it exercises the compiled
(``interpret=False``, ``pin=False``) lowering, which is the one path the
interpret-mode contract suite cannot cover — Mosaic has no
``optimization_barrier`` lowering, so the compiled kernels run un-pinned and
their bit-exactness vs the jnp oracle (and vs the interpret kernels) is an
empirical property of the Mosaic compiler, not a constructive guarantee.

Run on a TPU host with::

    pytest tests/test_tpu_compiled.py -m tpu

Off-TPU the whole module skips (and the ``tpu`` marker keeps it deselected
from the default suite).  These are *characterization* tests: the
load-bearing production contract is live-step ≡ ledger-replay **within** the
compiled path — the same un-pinned kernel in both graphs.  The
kernel-vs-oracle equalities are reported expectations; if a Mosaic release
moves them, the right response is a pallas stream-id bump (see
``perturb.base``), not a silent tolerance widen.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytestmark = [
    pytest.mark.tpu,
    pytest.mark.skipif(jax.default_backend() != "tpu",
                       reason="compiled Mosaic path needs a real TPU; "
                              "off-TPU the pallas backend runs interpret "
                              "mode, covered by the main suite"),
]

from repro.kernels.zo_fused import multi as zo_multi            # noqa: E402
from repro.kernels.zo_fused import ref as zo_ref                # noqa: E402
from repro.perturb import StreamRef, get_backend                # noqa: E402
from repro.perturb import pallas as pallas_mod                  # noqa: E402


def x32():
    return jax.random.normal(jax.random.PRNGKey(0), (300, 40))


# --------------------------------------------------------------------------- #
# The production contract: same compiled kernel, different outer graphs
# --------------------------------------------------------------------------- #
def test_compiled_live_equals_replay_chain():
    """A live-shaped update chain and a replay-shaped one (same seeds, same
    coefficients, differently-structured surrounding graphs) must agree
    bitwise through the compiled chain kernel — the ledger invariant on the
    compiled path."""
    x = x32()
    seeds = jnp.asarray([5, 9, 123], jnp.int32)
    a = jnp.asarray([0.999, 1.0, 1.0])
    b = jnp.asarray([-0.01, 0.02, -0.003])
    live = pallas_mod.zo_affine_chain(x, seeds, a, b, interpret=False)
    replay = pallas_mod.zo_affine_chain(x, seeds, a, b, interpret=False)
    np.testing.assert_array_equal(np.asarray(live), np.asarray(replay))


def test_compiled_fanout_matches_compiled_singles():
    """Fused multi ≡ stacked compiled singles — the HBM-traffic optimization
    must not move bits within the compiled path."""
    x = x32()
    seeds = jnp.asarray([5, 9, 123], jnp.int32)
    a = jnp.linspace(0.5, 1.5, 3)
    b = jnp.linspace(-0.1, 0.1, 3)
    out = pallas_mod.zo_affine_multi(x, seeds, a, b, interpret=False)
    for j in range(3):
        single = pallas_mod.zo_affine(x, int(seeds[j]), float(a[j]),
                                      float(b[j]), interpret=False)
        np.testing.assert_array_equal(np.asarray(out[j]), np.asarray(single))


def test_compiled_chain_matches_sequential_compiled_singles():
    x = x32()
    seeds = jnp.asarray([5, 9, 123], jnp.int32)
    a = jnp.asarray([0.999, 1.0, 1.0])
    b = jnp.asarray([-0.01, 0.02, -0.003])
    fused = pallas_mod.zo_affine_chain(x, seeds, a, b, interpret=False)
    seq = x
    for j in range(3):
        seq = pallas_mod.zo_affine(seq, int(seeds[j]), float(a[j]),
                                   float(b[j]), interpret=False)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(seq))


def test_compiled_sphere_backend_roundtrip():
    """perturb → fused restore (g=0) on the compiled sphere path recovers
    the center to fp tolerance — the two-pass rescale composes on-device."""
    be = get_backend("pallas")
    assert be.interpret is False
    params = {"w": x32(), "b": jnp.ones((77,))}
    ref = StreamRef.derive(jax.random.PRNGKey(2), 3)
    p_plus = be.perturb(params, ref, 1e-3, dist="sphere")
    p_minus = be.perturb(p_plus, ref, -2e-3, dist="sphere")
    restored = be.fused_restore_update(p_minus, ref, 1e-3, 0.0, 0.0,
                                       dist="sphere")
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=0)


# --------------------------------------------------------------------------- #
# Characterization: compiled vs oracle / interpret (reported, not relied on)
# --------------------------------------------------------------------------- #
def _mismatch_frac(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.mean(a.view(np.uint32) != b.view(np.uint32)))


def test_characterize_compiled_vs_oracle():
    """Report the compiled kernel's agreement with the pinned jnp oracle.
    Un-pinned Mosaic may legally contract FMAs differently; this test
    asserts only closeness and *records* the bitwise mismatch fraction so a
    compiler shift is visible in CI logs."""
    z_c = pallas_mod.zo_affine(jnp.zeros((131072,)), 5, 0.0, 1.0,
                               interpret=False)
    z_o = zo_ref.z_for((131072,), 5)
    np.testing.assert_allclose(np.asarray(z_c), np.asarray(z_o),
                               rtol=1e-5, atol=1e-6)
    frac = _mismatch_frac(z_c, z_o)
    print(f"\ncompiled-vs-oracle bitwise mismatch fraction: {frac:.2e}")


def test_characterize_compiled_sqnorm_vs_ref():
    got = float(zo_multi.zo_sqnorm_2d(262161, 42, interpret=False))
    want = float(zo_multi.zo_sqnorm_ref(262161, 42))
    np.testing.assert_allclose(got, want, rtol=1e-5)
