"""Seeded perturbation machinery: determinism, restore cycles, distributions.
Includes hypothesis property tests on the system's core invariant (z is a
pure function of (key, leaf, shape) and the perturb chain is reversible)."""
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (requirements-dev.txt)")
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

import repro.core.perturb as P
from repro.tree_utils import tree_allclose, tree_max_abs_diff, tree_size


def make_tree(key, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"a": jax.random.normal(k1, (17, 33), dtype),
            "b": {"w": jax.random.normal(k2, (8,), dtype),
                  "v": jax.random.normal(k3, (4, 4, 4), dtype)}}


def test_z_is_deterministic():
    params = make_tree(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(7)
    z1 = P.sample_z_tree(params, key)
    z2 = P.sample_z_tree(params, key)
    assert tree_allclose(z1, z2, rtol=0, atol=0)


def test_z_differs_across_leaves_and_keys():
    params = {"a": jnp.zeros((16,)), "b": jnp.zeros((16,))}
    z = P.sample_z_tree(params, jax.random.PRNGKey(1))
    assert not np.allclose(z["a"], z["b"])
    z2 = P.sample_z_tree(params, jax.random.PRNGKey(2))
    assert not np.allclose(z["a"], z2["a"])


def test_perturb_cycle_restores():
    """θ +εz −2εz +εz == θ (the paper's in-place chain) to fp tolerance."""
    params = make_tree(jax.random.PRNGKey(3))
    key = jax.random.PRNGKey(11)
    eps = 1e-3
    p = P.perturb(params, key, eps)
    p = P.perturb(p, key, -2 * eps)
    p = P.perturb(p, key, eps)
    assert tree_max_abs_diff(p, params) < 1e-5


def test_fused_restore_update_matches_two_step():
    params = make_tree(jax.random.PRNGKey(4))
    key = jax.random.PRNGKey(12)
    eps, lr_g = 1e-3, 2.5e-4
    p_minus = P.perturb(P.perturb(params, key, eps), key, -2 * eps)
    fused = P.fused_restore_update(p_minus, key, eps, lr_g)
    restored = P.perturb(p_minus, key, eps)
    z = P.sample_z_tree(params, key)
    manual = jax.tree_util.tree_map(lambda p, zz: p - lr_g * zz, restored, z)
    assert tree_max_abs_diff(fused, manual) < 1e-6


def test_sphere_norm():
    params = {"a": jnp.zeros((64, 64)), "b": jnp.zeros((128,))}
    z = P.sample_z_tree(params, jax.random.PRNGKey(5), dist="sphere")
    d = tree_size(params)
    norm = float(jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree_util.tree_leaves(z))))
    assert abs(norm - np.sqrt(d)) / np.sqrt(d) < 1e-4


def test_rademacher():
    params = {"a": jnp.zeros((64, 64))}
    z = P.sample_z_tree(params, jax.random.PRNGKey(6), dist="rademacher")
    assert set(np.unique(np.asarray(z["a"]))) <= {-1.0, 1.0}


@hypothesis.given(
    seed=st.integers(0, 2**31 - 1),
    eps=st.floats(1e-5, 1e-1),
    rows=st.integers(1, 9),
    cols=st.integers(1, 9),
)
@hypothesis.settings(max_examples=20, deadline=None)
def test_property_perturb_reversible(seed, eps, rows, cols):
    params = {"w": jnp.ones((rows, cols)) * 0.5}
    key = jax.random.PRNGKey(seed)
    p = P.perturb(P.perturb(params, key, eps), key, -eps)
    assert tree_max_abs_diff(p, params) < 1e-4


@hypothesis.given(seed=st.integers(0, 2**31 - 1))
@hypothesis.settings(max_examples=15, deadline=None)
def test_property_scale_linearity(seed):
    """perturb(θ, a) − θ == a·z exactly reconstructible for two scales."""
    params = {"w": jnp.zeros((8, 8))}
    key = jax.random.PRNGKey(seed)
    d1 = P.perturb(params, key, 1.0)["w"]
    d3 = P.perturb(params, key, 3.0)["w"]
    np.testing.assert_allclose(np.asarray(3.0 * d1), np.asarray(d3),
                               rtol=1e-5, atol=1e-6)


def test_bf16_leaves_perturb():
    params = {"w": jnp.ones((32, 32), jnp.bfloat16)}
    p = P.perturb(params, jax.random.PRNGKey(0), 0.01)
    assert p["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p["w"].astype(jnp.float32))))


def test_int_leaves_passthrough():
    params = {"w": jnp.ones((4,)), "steps": jnp.int32(3)}
    from repro.core.mezo import apply_projected_update
    out = apply_projected_update(params, jax.random.PRNGKey(0), 1.0, 0.1)
    assert out["steps"] == params["steps"]
