"""Multi-device semantics, run in a SUBPROCESS with 8 forced host devices so
the main pytest process keeps its single device.

Checks:
  * a data-parallel sharded MeZO step produces the SAME parameters as the
    single-device step (z regeneration is sharding-invariant; the only
    cross-replica communication is the scalar loss reduction);
  * tensor-parallel forward == single-device forward;
  * seed-parallel n-SPSA step runs sharded and matches its reference;
  * the elastic path: params saved from a sharded run restore on one device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = textwrap.dedent(r"""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

    from repro.models import all_archs, bundle
    from repro.core import MeZO, MeZOConfig
    from repro.distributed.sharding import param_shardings
    from repro.tree_utils import tree_max_abs_diff

    mesh = Mesh(np.array(jax.devices()).reshape(2, 4), ("data", "model"))
    cfg = all_archs()["qwen2-0.5b"].smoke_cfg
    b = bundle(cfg)
    params = b.init(jax.random.PRNGKey(0))
    batch = b.make_batch(jax.random.PRNGKey(1), batch=4, seq=16)
    loss_fn = b.loss_fn()
    opt = MeZO(MeZOConfig(lr=1e-4, eps=1e-3))

    # single-device reference (replicated)
    state = opt.init(0)
    p_ref, _, m_ref = jax.jit(opt.step_fn(loss_fn))(params, state, batch)

    # sharded: params TP over model, batch DP over data
    pshard = param_shardings(params, mesh)
    params_sh = jax.device_put(params, pshard)
    batch_sh = jax.device_put(batch, NamedSharding(mesh, P("data")))
    state = opt.init(0)
    with mesh:
        step = jax.jit(opt.step_fn(loss_fn), in_shardings=(pshard, None, None))
        p_sh, _, m_sh = step(params_sh, state, batch_sh)

    d_loss = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
    d_g = abs(float(m_ref["projected_grad"]) - float(m_sh["projected_grad"]))
    d_p = tree_max_abs_diff(p_ref, jax.device_get(p_sh))
    assert d_loss < 1e-4, ("loss", d_loss)
    assert d_g < 5e-3, ("g", d_g)
    assert d_p < 1e-5, ("params", d_p)
    print("DP_TP_MEZO_OK", d_loss, d_g, d_p)

    # TP forward equivalence
    from repro.models import transformer
    logits_ref = transformer.forward(cfg, params, tokens=batch["tokens"]).logits
    with mesh:
        fwd = jax.jit(lambda p, t: transformer.forward(cfg, p, tokens=t).logits,
                      in_shardings=(pshard, NamedSharding(mesh, P("data"))))
        logits_sh = fwd(params_sh, batch_sh["tokens"])
    d_l = float(jnp.max(jnp.abs(logits_ref - jax.device_get(logits_sh))))
    assert d_l < 2e-3, ("logits", d_l)
    print("TP_FORWARD_OK", d_l)

    # seed-parallel n-SPSA sharded step
    from repro.distributed.collectives import (seed_parallel_init,
                                               seed_parallel_step_fn)
    sp_step = seed_parallel_step_fn(loss_fn, MeZOConfig(lr=1e-4, eps=1e-3), 2)
    st = seed_parallel_init(0)
    p1_ref, _, msp = jax.jit(sp_step)(params, st, batch)
    with mesh:
        sp_j = jax.jit(sp_step, in_shardings=(pshard, None, None))
        p1_sh, _, msp_sh = sp_j(params_sh, st, batch_sh)
    d_sp = tree_max_abs_diff(p1_ref, jax.device_get(p1_sh))
    assert d_sp < 1e-5, ("seed_parallel", d_sp)
    print("SEED_PARALLEL_OK", d_sp)

    # elastic: save sharded -> restore on host arrays
    import tempfile
    from repro.checkpoint.io import save_tree, load_tree
    with tempfile.TemporaryDirectory() as td:
        pth = os.path.join(td, "c.mz")
        save_tree(pth, p_sh)
        loaded, _ = load_tree(pth, params)
        d_e = tree_max_abs_diff(loaded, jax.device_get(p_sh))
        assert d_e == 0.0, d_e
    print("ELASTIC_OK")

    # THE paper-scale property: under PURE data parallelism (params
    # replicated, batch sharded), a MeZO step's ONLY collective traffic is
    # scalar loss reductions — no tensor all-reduces exist in the HLO.
    import re
    mesh_dp = Mesh(np.array(jax.devices()).reshape(8), ("data",))
    batch8 = b.make_batch(jax.random.PRNGKey(2), batch=8, seq=16)
    pshard_rep = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh_dp, P()), params)
    bshard = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh_dp, P("data")), batch8)
    with mesh_dp:
        compiled = jax.jit(opt.step_fn(loss_fn),
                           in_shardings=(pshard_rep, None, bshard)) \
            .lower(params, opt.init(0), batch8).compile()
    txt = compiled.as_text()
    biggest = 0
    for line in txt.splitlines():
        m = re.search(r"=\s*([a-z0-9]+)\[([\d,]*)\][^=]*?\s"
                      r"(all-reduce|all-gather|reduce-scatter|all-to-all)"
                      r"(?:-start)?\(", line)
        if m:
            dims = m.group(2)
            n = 1
            for dd in dims.split(","):
                if dd:
                    n *= int(dd)
            biggest = max(biggest, n)
    assert biggest <= 8, f"non-scalar collective in DP MeZO step: {biggest}"
    print("SCALAR_SYNC_OK", biggest)
""")


def _subprocess_default_platform(env) -> str:
    """The platform a fresh subprocess's jax will pick with JAX_PLATFORMS
    unset.  Containers with a baked-in accelerator runtime (libtpu et al.)
    hijack the default away from cpu, the forced host-device count is
    silently ignored, and the numerics drift (d_g moves by ~0.4) — a known
    environment condition, not a code regression."""
    try:
        probe = subprocess.run(
            [sys.executable, "-c",
             "import jax, sys; sys.stdout.write(jax.default_backend())"],
            env=env, capture_output=True, text=True, timeout=120)
    except subprocess.TimeoutExpired:
        # a hung accelerator-runtime init IS the drift condition
        return "hung"
    return probe.stdout.strip() if probe.returncode == 0 else "unknown"


@pytest.mark.slow
def test_multidevice_semantics():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("JAX_PLATFORMS", None)
    platform = _subprocess_default_platform(env)
    if platform != "cpu":
        pytest.skip(
            f"subprocess default jax platform is {platform!r} (baked-in "
            "accelerator runtime): --xla_force_host_platform_device_count "
            "is ignored there and the multi-device semantics drift; run on "
            "a cpu-default host (CI) to exercise this test")
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, out.stdout[-3000:] + out.stderr[-3000:]
    for marker in ("DP_TP_MEZO_OK", "TP_FORWARD_OK", "SEED_PARALLEL_OK",
                   "ELASTIC_OK", "SCALAR_SYNC_OK"):
        assert marker in out.stdout, (marker, out.stdout[-2000:])
