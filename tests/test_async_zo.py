"""Bounded-staleness async MeZO (straggler mitigation): staleness-0 equals a
synchronous seed-parallel step; stale application converges; the applied
update multiset is order-invariant."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MeZOConfig
from repro.distributed.async_zo import AsyncZOWorker, run_sync_equivalent
from repro.distributed.collectives import (apply_seed_parallel_update,
                                           seed_parallel_grads)
from repro.tree_utils import tree_max_abs_diff


def quad(t):
    return lambda p, b: 0.5 * jnp.sum((p["w"] - t) ** 2)


def test_staleness_zero_workers_stay_identical():
    t = jax.random.normal(jax.random.PRNGKey(0), (16,))
    loss_fn = quad(t)
    cfg = MeZOConfig(lr=5e-3, eps=1e-3)
    p0 = {"w": jnp.zeros((16,))}
    ws = [AsyncZOWorker(w, 3, p0, loss_fn, cfg, base_seed=1) for w in range(3)]
    for _ in range(10):
        run_sync_equivalent(ws, lambda w, s: None)
    for w in ws[1:]:
        assert tree_max_abs_diff(w.params, ws[0].params) == 0.0
    assert float(loss_fn(ws[0].params, None)) < float(loss_fn(p0, None))


def test_stale_application_order_invariance():
    """Applying the same multiset of contributions in different orders yields
    the same parameters up to fp commutation error."""
    t = jax.random.normal(jax.random.PRNGKey(1), (16,))
    loss_fn = quad(t)
    cfg = MeZOConfig(lr=1e-3, eps=1e-3)
    p0 = {"w": jnp.zeros((16,))}

    a = AsyncZOWorker(0, 2, p0, loss_fn, cfg, base_seed=2, max_staleness=10)
    b = AsyncZOWorker(1, 2, p0, loss_fn, cfg, base_seed=2, max_staleness=10)
    ca0 = a.produce(None)
    cb0 = b.produce(None)
    ca1 = a.produce(None)
    cb1 = b.produce(None)
    # a applies in order, b applies reversed
    for cb in (ca0, cb0, ca1, cb1):
        a.consume(cb)
    for cb in (cb1, ca1, cb0, ca0):
        b.consume(cb)
    assert tree_max_abs_diff(a.params, b.params) < 1e-6


def test_bounded_staleness_drops_old():
    t = jnp.ones((8,))
    cfg = MeZOConfig(lr=1e-3, eps=1e-3)
    w = AsyncZOWorker(0, 2, {"w": jnp.zeros((8,))}, quad(t), cfg,
                      max_staleness=2)
    for _ in range(5):
        w.produce(None)
    from repro.distributed.async_zo import Contribution
    old = Contribution(step=0, worker=1, projected_grad=1.0, lr=1e-3)
    assert not w.consume(old)      # step 0 is > 2 stale at step 5
    fresh = Contribution(step=4, worker=1, projected_grad=1.0, lr=1e-3)
    assert w.consume(fresh)


def test_async_converges_with_delay():
    """Workers exchange contributions one round late; loss still decreases to
    near zero (bounded-staleness SGD regime)."""
    t = jax.random.normal(jax.random.PRNGKey(3), (12,))
    loss_fn = quad(t)
    cfg = MeZOConfig(lr=4e-3, eps=1e-3)
    p0 = {"w": jnp.zeros((12,))}
    ws = [AsyncZOWorker(w, 2, p0, loss_fn, cfg, base_seed=5, max_staleness=4)
          for w in range(2)]
    pending = []
    for _ in range(400):
        newly = [w.produce(None) for w in ws]
        for cb in pending:             # deliver LAST round's contributions
            for w in ws:
                w.consume(cb)
        pending = newly
    l0 = float(loss_fn(p0, None))
    assert float(loss_fn(ws[0].params, None)) < 0.05 * l0


def test_seed_parallel_matches_manual_nspsa():
    """seed-parallel grads + update == sequential n-SPSA evaluated at the
    same seeds on the same batch slices."""
    t = jax.random.normal(jax.random.PRNGKey(4), (10,))
    def loss_fn(p, b):
        scale = 1.0 if b is None else jnp.mean(b)
        return 0.5 * scale * jnp.sum((p["w"] - t) ** 2)
    p0 = {"w": jnp.zeros((10,))}
    base = jax.random.PRNGKey(9)
    batches = jnp.stack([jnp.full((2,), 1.0), jnp.full((2,), 2.0)])
    gs = seed_parallel_grads(loss_fn, p0, batches, base, 0, 1e-3, n_groups=2)
    assert gs.shape == (2,)
    p1 = apply_seed_parallel_update(p0, base, 0, gs, 1e-3, n_groups=2)
    # manual
    from repro.core.mezo import apply_projected_update
    from repro.core.perturb import step_key
    skey0 = step_key(base, 0)
    p_manual = p0
    for g in range(2):
        skey = jax.random.fold_in(skey0, g)
        p_manual = apply_projected_update(p_manual, skey, gs[g], 1e-3 / 2)
    assert tree_max_abs_diff(p1, p_manual) < 1e-7
