"""Sharding-rule engine: every leaf of every production arch gets a spec
whose sharded dims divide the mesh axes (the invariant that makes the 40-cell
dry-run compile).  Pure spec-level test — no devices needed."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ASSIGNED_ARCHS
from repro.distributed.sharding import (infer_batch_spec, infer_param_spec,
                                        param_specs)
from repro.models import all_archs, bundle


class FakeMesh:
    """Shape-only stand-in (no devices needed for spec inference)."""
    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape.keys())


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})
MESH_EP = FakeMesh({"data": 16, "expert": 8, "model": 2})


def _axis_sizes(mesh, entry):
    if entry is None:
        return 1
    if isinstance(entry, tuple):
        return int(np.prod([mesh.shape[a] for a in entry]))
    return mesh.shape[entry]


@pytest.mark.parametrize("arch_id", ASSIGNED_ARCHS)
@pytest.mark.parametrize("mesh", [MESH, MESH_MP], ids=["single", "multi"])
def test_param_specs_divisible(arch_id, mesh):
    cfg = all_archs()[arch_id].cfg
    shapes = bundle(cfg).param_shapes()
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    n_sharded = 0
    for kp, leaf in flat:
        spec = infer_param_spec(jax.tree_util.keystr(kp), tuple(leaf.shape),
                                mesh)
        for dim, entry in enumerate(spec):
            size = _axis_sizes(mesh, entry)
            if size > 1:
                n_sharded += 1
                assert leaf.shape[dim] % size == 0, (
                    arch_id, jax.tree_util.keystr(kp), leaf.shape, spec)
    # the big weights must actually shard (not all-replicated).  Block
    # leaves are STACKED over layers, so the count is per matrix kind.
    assert n_sharded >= 6, (arch_id, n_sharded)


@pytest.mark.parametrize("arch_id", ["mixtral-8x7b", "granite-moe-3b-a800m"])
def test_moe_ep_mesh_specs(arch_id):
    cfg = all_archs()[arch_id].cfg
    shapes = bundle(cfg).param_shapes()
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    saw_expert_axis = False
    for kp, leaf in flat:
        path = jax.tree_util.keystr(kp)
        spec = infer_param_spec(path, tuple(leaf.shape), MESH_EP)
        for dim, entry in enumerate(spec):
            size = _axis_sizes(MESH_EP, entry)
            if size > 1:
                assert leaf.shape[dim] % size == 0, (path, leaf.shape, spec)
            if entry == "expert":
                saw_expert_axis = True
    if cfg.n_experts % 8 == 0:
        assert saw_expert_axis, arch_id


def test_batch_specs():
    s = infer_batch_spec("tokens", (256, 4096), MESH)
    assert s == P("data", None)
    s = infer_batch_spec("tokens", (1, 4096), MESH)       # long_500k: B=1
    assert s == P(None, None)
    s = infer_batch_spec("cache_k", (32, 128, 32768, 8, 128), MESH)
    assert s[1] == "data" and s[2] == "model"             # flash-decode split
    s = infer_batch_spec("tokens", (256, 4096), MESH_MP)
    assert s[0] == ("pod", "data")


def test_uneven_head_fallbacks():
    """qwen2-7b wq: (L, 3584, 3584): output dim divides -> 'model' on dim 2;
    a hypothetical odd width falls back to the input dim, then replicates."""
    s = infer_param_spec("['layers']['attn']['wq']", (28, 3584, 3584), MESH)
    assert s == P(None, None, "model")
    s = infer_param_spec("['layers']['attn']['wq']", (28, 3584, 1000), MESH)
    assert s == P(None, "model", None)
    s = infer_param_spec("['layers']['attn']['wq']", (28, 1000, 1000), MESH)
    assert s == P()
