"""Ledger record/replay: the paper's §2.1 storage trick.  Reconstruction must
be exact (same update function, same scalar sequence)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import MeZO, MeZOConfig, TrajectoryLedger, replay, storage_report
from repro.tree_utils import tree_max_abs_diff


def setup_run(steps=25, grad_dtype="float32"):
    key = jax.random.PRNGKey(0)
    t = {"w": jax.random.normal(key, (10,)), "b": jnp.ones((4, 4))}
    loss_fn = lambda p, batch: 0.5 * sum(
        jnp.sum((x - y) ** 2) for x, y in
        zip(jax.tree_util.tree_leaves(p), jax.tree_util.tree_leaves(t)))
    cfg = MeZOConfig(lr=1e-3, eps=1e-3)
    opt = MeZO(cfg)
    params0 = jax.tree_util.tree_map(jnp.zeros_like, t)
    state = opt.init(123)
    ledger = TrajectoryLedger(base_seed=123, grad_dtype=grad_dtype)
    step = jax.jit(opt.step_fn(loss_fn))
    p = params0
    for i in range(steps):
        p, state, m = step(p, state, None)
        ledger.append(i, float(m["projected_grad"]), float(m["lr"]))
    return params0, p, ledger, cfg


def test_replay_reconstructs_exactly():
    p0, pT, ledger, cfg = setup_run(grad_dtype="float32")
    rec = replay(p0, ledger, cfg)
    assert tree_max_abs_diff(rec, pT) < 1e-6


def test_replay_fp16_ledger_close():
    """2-byte grads (the paper's accounting) reconstruct to fp16 precision."""
    p0, pT, ledger, cfg = setup_run(grad_dtype="float16")
    rec = replay(p0, ledger, cfg)
    assert tree_max_abs_diff(rec, pT) < 5e-3


def test_partial_replay_from_midpoint():
    p0, pT, ledger, cfg = setup_run()
    mid = replay(p0, ledger, cfg, to_idx=10)
    rest = replay(mid, ledger, cfg, from_idx=10)
    assert tree_max_abs_diff(rest, pT) < 1e-6


def test_serialization_roundtrip():
    _, _, ledger, _ = setup_run(steps=7)
    raw = ledger.to_bytes()
    led2 = TrajectoryLedger.from_bytes(raw)
    assert led2.base_seed == ledger.base_seed
    assert led2.steps == ledger.steps
    np.testing.assert_allclose(led2.grads, ledger.grads)


def test_storage_is_tiny():
    """Paper: 20 K steps of a 66 B model -> < 0.1 MB; LoRA ckpt 38 MB."""
    rep = storage_report(20_000, "float16")
    assert rep["ledger_bytes"] < 100_000
    assert rep["lora_opt66b_bytes"] > 300 * rep["ledger_bytes"]
