"""Conformance matrix for the parameter-selection layer (``repro.select``).

The matrix: (selection ∈ {full, leaves, block_cyclic}) × (estimator ∈
{spsa, fzoo}) × (backend ∈ {xla, pallas-interpret}) × (plan ∈ {local,
seed_parallel(2), replay}), asserting

* ``selection="full"`` is BITWISE-identical to not passing a selection (the
  pre-selection behavior) for spsa and fzoo on both backends;
* unselected leaves are completely untouched — no perturbation, no update,
  no weight decay (the frozen-base guarantee PEFT selections rely on);
* a ``block_cyclic`` run's MZOL5 ledger round-trips and replays under the
  ledger-driven ``replay`` plan (replay-vs-replay bitwise, replay-vs-live
  within the established fp-fusion tolerance), while full-selection ledgers
  keep serializing as MZOL2/3/4 so MZOL4-era artifacts replay unchanged;
* mismatched selection coordinates refuse (``SelectionMismatchError``) for
  ledgers AND checkpoints;
* the schedule phase is plan-invariant (async staleness-0 ≡ seed_parallel at
  the same selection/step);
* the deprecated ``models/peft.py`` tree-swap loss entry points are
  bitwise-equal shims over the unified merged-tree path.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import exec as zexec
from repro import select, zo
from repro.core.trajectory import TrajectoryLedger, replay
from repro.exec import StepProgram
from repro.perturb import StreamRef, get_backend
from repro.select import Selection, SelectionMismatchError, parse_selection
from repro.tree_utils import tree_max_abs_diff

BACKENDS = ["xla", "pallas-interpret"]
W_ONLY = r"\['w'\]"


def make_opt(estimator: str, backend: str, selection=None, lr=1e-3, eps=1e-3,
             weight_decay=0.0):
    if estimator == "spsa":
        return zo.mezo(lr=lr, eps=eps, backend=backend, selection=selection,
                       weight_decay=weight_decay)
    if estimator == "fzoo":
        return zo.fzoo(lr=lr, eps=eps, batch_seeds=3, backend=backend,
                       selection=selection, weight_decay=weight_decay)
    raise ValueError(estimator)


@pytest.fixture()
def problem():
    t = jax.random.normal(jax.random.PRNGKey(0), (16,))

    def loss_fn(p, b):
        scale = 1.0 if b is None else jnp.mean(b)
        return scale * (0.5 * jnp.sum((p["w"] - t) ** 2)
                        + 0.1 * jnp.sum(p["v"] ** 2))

    params = {"v": jnp.ones((8,)), "w": jnp.zeros((16,))}
    batch = jnp.linspace(0.5, 1.5, 8)
    return loss_fn, params, batch


def run_plan(opt, plan, loss_fn, params, batch, steps=4, seed=3, ledger=None,
             donate=False):
    prog = StepProgram(opt, plan)
    state = prog.init(params, seed=seed)
    step = jax.jit(prog.step_fn(loss_fn),
                   donate_argnums=(0,) if donate else ())
    p = params
    for i in range(steps):
        p, state, m = step(p, state, batch)
        if ledger is not None:
            g = m.get("projected_grads")
            ledger.append(i, np.asarray(g) if g is not None
                          else float(m["projected_grad"]), float(m["lr"]))
    return p, prog


def ledger_for(prog, seed=3):
    meta = prog.meta
    return TrajectoryLedger(base_seed=seed, grad_dtype="float32",
                            backend=meta["perturb_backend"],
                            batch_seeds=meta["batch_seeds"],
                            exec_plan=meta["exec_plan"],
                            n_groups=meta["n_groups"],
                            selection=meta["selection"],
                            sel_phase=meta["sel_phase"])


# --------------------------------------------------------------------------- #
# The acceptance guarantee: full selection == pre-selection behavior, bitwise
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
def test_full_selection_bitwise_identical(problem, estimator, backend):
    loss_fn, params, batch = problem
    p_none, _ = run_plan(make_opt(estimator, backend), zexec.local(),
                         loss_fn, params, batch)
    p_full, _ = run_plan(make_opt(estimator, backend, selection="full"),
                         zexec.local(), loss_fn, params, batch)
    p_fullobj, _ = run_plan(make_opt(estimator, backend,
                                     selection=select.full()),
                            zexec.local(), loss_fn, params, batch)
    assert tree_max_abs_diff(p_none, p_full) == 0.0
    assert tree_max_abs_diff(p_none, p_fullobj) == 0.0
    # and the full selection resolves to None (the zero-overhead signal)
    assert make_opt(estimator, backend, selection="full").selection is None


# --------------------------------------------------------------------------- #
# Unselected leaves are untouched (perturb, update, AND decay)
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
@pytest.mark.parametrize("plan_name", ["local", "sp2"])
def test_static_selection_freezes_unselected(problem, estimator, backend,
                                             plan_name):
    loss_fn, params, batch = problem
    plan = {"local": zexec.local(),
            "sp2": zexec.seed_parallel(2)}[plan_name]
    opt = make_opt(estimator, backend, selection=select.leaves(W_ONLY),
                   weight_decay=0.1)
    # donate a copy so the original stays comparable (donation deletes it)
    p0 = jax.tree_util.tree_map(lambda x: x.copy(), params)
    p, _ = run_plan(opt, plan, loss_fn, p0, batch, donate=True)
    # 'v' is unselected: bitwise-identical despite nonzero weight decay
    assert tree_max_abs_diff({"v": p["v"]}, {"v": params["v"]}) == 0.0
    assert float(jnp.max(jnp.abs(p["w"] - params["w"]))) > 0.0


# --------------------------------------------------------------------------- #
# The conformance matrix: selection × estimator × backend × plan → replay
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("estimator", ["spsa", "fzoo"])
@pytest.mark.parametrize("plan_name", ["local", "sp2"])
@pytest.mark.parametrize("sel", ["leaves", "block_cyclic"])
def test_selection_ledger_roundtrip(problem, estimator, backend, plan_name,
                                    sel):
    loss_fn, params, batch = problem
    selection = {"leaves": select.leaves(W_ONLY),
                 "block_cyclic": select.block_cyclic(2)}[sel]
    plan = {"local": zexec.local(), "sp2": zexec.seed_parallel(2)}[plan_name]
    opt = make_opt(estimator, backend, selection=selection)
    prog = StepProgram(opt, plan)
    led = ledger_for(prog)
    p_live, _ = run_plan(opt, plan, loss_fn, params, batch, ledger=led)
    led2 = TrajectoryLedger.from_bytes(led.to_bytes())
    assert (led2.selection, led2.sel_phase) == (selection.spec, 0)
    mk = lambda: make_opt(estimator, backend, selection=selection)
    rec = replay(params, led2, mk())
    assert tree_max_abs_diff(rec, p_live) < 2e-6
    # replay is deterministic (bitwise) and plan-programs agree bitwise
    assert tree_max_abs_diff(rec, replay(params, led2, mk())) == 0.0
    rec3 = StepProgram(mk(), plan).replay(params, led2)
    assert tree_max_abs_diff(rec, rec3) == 0.0


def test_block_cyclic_phase_rotation(problem):
    """Phase t touches exactly the leaves with index ≡ t (mod k); the other
    block is bitwise-frozen for that step.  Leaf order: v=0, w=1."""
    loss_fn, params, batch = problem
    opt = make_opt("spsa", "xla", selection=select.block_cyclic(2))
    state = opt.init(params, seed=3)
    step = jax.jit(opt.step_fn(loss_fn))
    p1, state, _ = step(params, state, batch)       # phase 0: leaf 'v'
    assert tree_max_abs_diff({"w": p1["w"]}, {"w": params["w"]}) == 0.0
    assert float(jnp.max(jnp.abs(p1["v"] - params["v"]))) > 0.0
    p2, state, _ = step(p1, state, batch)           # phase 1: leaf 'w'
    assert tree_max_abs_diff({"v": p2["v"]}, {"v": p1["v"]}) == 0.0
    assert float(jnp.max(jnp.abs(p2["w"] - p1["w"]))) > 0.0


def test_block_cyclic_writes_mzol5_full_stays_legacy(problem):
    """MZOL5 is written only for non-full selections; full-selection ledgers
    keep their MZOL2/3/4 magic, so MZOL4-era readers (and artifacts) are
    untouched."""
    loss_fn, params, batch = problem
    opt = make_opt("spsa", "xla", selection=select.block_cyclic(2))
    prog = StepProgram(opt, zexec.seed_parallel(2))
    led = ledger_for(prog)
    p_live, _ = run_plan(opt, zexec.seed_parallel(2), loss_fn, params, batch,
                         ledger=led)
    raw = led.to_bytes()
    assert raw.startswith(b"MZOL5")
    led2 = TrajectoryLedger.from_bytes(raw)
    assert (led2.selection, led2.n_groups, led2.exec_plan) == \
        ("block_cyclic(2)", 2, "seed_parallel")
    rec = replay(params, led2,
                 make_opt("spsa", "xla", selection=select.block_cyclic(2)))
    assert tree_max_abs_diff(rec, p_live) < 2e-6

    # full-selection coordinates serialize exactly as before (MZOL4-era)
    full_prog = StepProgram(make_opt("spsa", "xla"), zexec.seed_parallel(2))
    led4 = ledger_for(full_prog)
    p4, _ = run_plan(make_opt("spsa", "xla"), zexec.seed_parallel(2),
                     loss_fn, params, batch, ledger=led4)
    raw4 = led4.to_bytes()
    assert raw4.startswith(b"MZOL4")
    led4b = TrajectoryLedger.from_bytes(raw4)
    assert (led4b.selection, led4b.sel_phase) == ("full", 0)
    rec4 = replay(params, led4b, make_opt("spsa", "xla"))
    assert tree_max_abs_diff(rec4, p4) < 2e-6
    # B=1 single-group full runs stay MZOL2
    led2b = ledger_for(StepProgram(make_opt("spsa", "xla"), zexec.local()))
    run_plan(make_opt("spsa", "xla"), zexec.local(), loss_fn, params, batch,
             ledger=led2b)
    assert led2b.to_bytes().startswith(b"MZOL2")


def test_selection_mismatch_refuses(problem, tmp_path):
    loss_fn, params, batch = problem
    opt = make_opt("spsa", "xla", selection=select.block_cyclic(2))
    prog = StepProgram(opt, zexec.local())
    led = ledger_for(prog)
    run_plan(opt, zexec.local(), loss_fn, params, batch, ledger=led)
    with pytest.raises(SelectionMismatchError, match="block_cyclic"):
        replay(params, led, make_opt("spsa", "xla"))
    with pytest.raises(SelectionMismatchError):
        replay(params, led,
               make_opt("spsa", "xla", selection=select.leaves(W_ONLY)))
    # checkpoint meta records the selection; resume under another refuses
    from repro.checkpoint.manager import CheckpointManager
    from repro.data.pipeline import DataSpec, Pipeline
    from repro.train.loop import train

    def loss2(p, b):
        return loss_fn(p, None)

    pipe = Pipeline(DataSpec("lm", batch=4, seq=4, vocab=11, seed=1))
    ck = CheckpointManager(str(tmp_path), interval=2)
    train(loss2, params, make_opt("spsa", "xla",
                                  selection=select.block_cyclic(2)),
          pipe, total_steps=2, ckpt=ck, donate=False)
    with pytest.raises(SelectionMismatchError):
        train(loss2, params, make_opt("spsa", "xla"), pipe, total_steps=4,
              ckpt=ck, donate=False)
    res = train(loss2, params,
                make_opt("spsa", "xla", selection=select.block_cyclic(2)),
                pipe, total_steps=4, ckpt=ck, donate=False)
    assert res.resumed_from == 2


# --------------------------------------------------------------------------- #
# Plan invariance of the schedule phase: async staleness-0 ≡ seed_parallel
# --------------------------------------------------------------------------- #
def test_async_staleness0_selection_matches_seed_parallel(problem):
    from repro.distributed.async_zo import (AsyncZOWorker,
                                            contributions_to_ledger)
    loss_fn, params, batch = problem
    n = 2
    sel = select.block_cyclic(2)
    mk = lambda: make_opt("spsa", "xla", selection=sel)
    ws = [AsyncZOWorker(w, n, params, loss_fn, mk(), base_seed=3)
          for w in range(n)]

    def shard(w):
        per = batch.shape[0] // n
        return batch[w * per:(w + 1) * per]

    contribs = []
    for _ in range(4):
        cs = [w.produce(shard(w.w)) for w in ws]
        contribs += cs
        for w in ws:
            for cb in cs:
                w.consume(cb)
    assert tree_max_abs_diff(ws[0].params, ws[1].params) == 0.0
    p_sp, _ = run_plan(mk(), zexec.seed_parallel(n), loss_fn, params, batch)
    assert tree_max_abs_diff(ws[0].params, p_sp) < 1e-6
    led = TrajectoryLedger(base_seed=3, grad_dtype="float32")
    recorded, skipped = contributions_to_ledger(led, contribs, n_workers=n,
                                                selection=sel.spec)
    assert (recorded, skipped) == (4, 0) and led.selection == sel.spec
    rec = replay(params, TrajectoryLedger.from_bytes(led.to_bytes()), mk())
    assert tree_max_abs_diff(rec, ws[0].params) < 5e-6


# --------------------------------------------------------------------------- #
# perturb_many under a selection: batched == stacked masked singles, bitwise
# --------------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_perturb_many_selection_contract(backend):
    be = get_backend(backend)
    params = {"b": jnp.ones((31,)),
              "w": jax.random.normal(jax.random.PRNGKey(0), (70, 33))}
    sel = select.leaves(W_ONLY)
    base = jax.random.PRNGKey(7)
    refs = [StreamRef(jax.random.fold_in(base, j)).with_selection(sel, 0)
            for j in range(3)]
    stacked = be.perturb_many(params, refs, 1e-2)
    singles = [be.perturb(params, r, 1e-2) for r in refs]
    want = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *singles)
    for a, b in zip(jax.tree_util.tree_leaves(stacked),
                    jax.tree_util.tree_leaves(want)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # unselected leaf: stacked copies of the original, untouched
    np.testing.assert_array_equal(
        np.asarray(stacked["b"]), np.asarray(jnp.stack([params["b"]] * 3)))


# --------------------------------------------------------------------------- #
# Spec round-trip, guardrails
# --------------------------------------------------------------------------- #
def test_selection_spec_roundtrip():
    for sel in (select.full(), select.leaves(W_ONLY),
                select.block_cyclic(4), select.peft("lora"),
                select.peft("prefix")):
        assert parse_selection(sel.spec) == sel
    assert parse_selection("block_cyclic(3)", phase_offset=2) == \
        Selection("block_cyclic", n_phases=3, phase_offset=2)
    with pytest.raises(ValueError, match="unparseable"):
        parse_selection("bogus")
    with pytest.raises(ValueError, match="peft mode"):
        select.peft("adapters")
    with pytest.raises(ValueError, match="k >= 1"):
        select.block_cyclic(0)


def test_selection_guardrails(problem):
    loss_fn, params, _ = problem
    # empty static selection fails loudly at trace time
    opt = make_opt("spsa", "xla", selection=select.leaves(r"\['nope'\]"))
    state = opt.init(params, seed=0)
    with pytest.raises(ValueError, match="matches no floating leaves"):
        jax.jit(opt.step_fn(loss_fn))(params, state, None)
    # block_cyclic with more phases than leaves fails loudly
    opt = make_opt("spsa", "xla", selection=select.block_cyclic(5))
    state = opt.init(params, seed=0)
    with pytest.raises(ValueError, match="block_cyclic"):
        jax.jit(opt.step_fn(loss_fn))(params, state, None)
    # applier transforms refuse selections (they write the full tree)
    with pytest.raises(ValueError, match="applier"):
        zo.mezo_adam(lr=1e-3, selection=select.block_cyclic(2))


# --------------------------------------------------------------------------- #
# PEFT: the deprecated tree-swap entry points are bitwise shims
# --------------------------------------------------------------------------- #
@pytest.fixture(scope="module")
def peft_setup():
    from repro.models import bundle
    from repro.models.config import ModelConfig
    cfg = ModelConfig(name="sel-peft", family="dense", n_layers=2, d_model=32,
                      n_heads=2, n_kv_heads=1, d_ff=64, vocab_size=64,
                      max_seq=16, dtype="float32")
    b = bundle(cfg)
    base = b.init(jax.random.PRNGKey(0))
    batch = b.make_batch(jax.random.PRNGKey(1), batch=2, seq=8)
    return cfg, base, batch


def test_peft_loss_shims_bitwise(peft_setup):
    from repro.models import peft
    cfg, base, batch = peft_setup
    lora = peft.init_lora(cfg, jax.random.PRNGKey(2))
    shim = peft.lora_loss_fn(cfg, base)(lora, batch)
    uni = peft.peft_loss_fn(cfg, "lora")(
        peft.peft_params(base, lora, "lora"), batch)
    assert float(shim) == float(uni)
    pre = peft.init_prefix_from_tokens(cfg, base, jax.random.PRNGKey(3), m=3)
    shim = peft.prefix_loss_fn(cfg, base)(pre, batch)
    uni = peft.peft_loss_fn(cfg, "prefix")(
        peft.peft_params(base, pre, "prefix"), batch)
    assert float(shim) == float(uni)


def test_peft_selection_freezes_base_and_replays(peft_setup):
    from repro.models import peft
    cfg, base, batch = peft_setup
    lora = peft.init_lora(cfg, jax.random.PRNGKey(2))
    merged = peft.peft_params(base, lora, "lora")
    sel = peft.peft_selection("lora")
    assert sel == select.peft("lora")
    opt = zo.mezo(lr=1e-3, eps=1e-3, weight_decay=0.1, selection=sel)
    prog = StepProgram(opt, zexec.local())
    led = ledger_for(prog, seed=0)
    loss_fn = peft.peft_loss_fn(cfg, "lora")
    state = prog.init(merged, seed=0)
    step = jax.jit(prog.step_fn(loss_fn))
    p = merged
    for i in range(3):
        p, state, m = step(p, state, batch)
        led.append(i, float(m["projected_grad"]), float(m["lr"]))
    # the frozen base is bitwise-untouched (decay included)
    assert tree_max_abs_diff(p["base"], base) == 0.0
    assert tree_max_abs_diff(p["lora"], lora) > 0.0
    # and the run ledger-replays on the unified path
    rec = replay(merged, TrajectoryLedger.from_bytes(led.to_bytes()),
                 zo.mezo(lr=1e-3, eps=1e-3, weight_decay=0.1, selection=sel))
    assert tree_max_abs_diff(rec["base"], base) == 0.0
    assert tree_max_abs_diff(rec, p) < 2e-6


def test_block_cyclic_assigns_phases_over_floating_leaves_only():
    """Integer leaves can never be perturbed (the backends skip them), so
    block phases are assigned over the floating leaves: no phase may end up
    owning only unperturbable leaves (which would silently train nothing
    that step)."""
    params = {"a": jnp.ones((4,)), "idx": jnp.arange(3, dtype=jnp.int32),
              "z": jnp.ones((2,))}                  # leaves: a, idx, z
    sel = select.block_cyclic(2)
    m0 = sel.leaf_mask(params, 0)
    m1 = sel.leaf_mask(params, 1)
    assert m0 == (True, False, False)               # a: floating block 0
    assert m1 == (False, False, True)               # z: floating block 1
    # every phase selects at least one floating leaf
    assert any(m0) and any(m1)
    # a regex matching only the int leaf is an empty (unperturbable)
    # selection and fails loudly
    with pytest.raises(ValueError, match="matches no floating leaves"):
        select.leaves(r"\['idx'\]").leaf_mask(params, 0)
    # k larger than the floating-leaf count fails loudly too
    with pytest.raises(ValueError, match="floating leaves"):
        select.block_cyclic(3).leaf_mask(params, 0)


def test_contributions_to_ledger_stamps_selection_at_one_worker(problem):
    """The selection stamp must not be gated on n_workers > 1: a
    single-worker selected run recorded as 'full' would replay its scalars
    onto the whole tree instead of the selected block."""
    from repro.distributed.async_zo import (AsyncZOWorker,
                                            contributions_to_ledger)
    loss_fn, params, _ = problem
    sel = select.block_cyclic(2)
    mk = lambda: make_opt("spsa", "xla", selection=sel)
    w = AsyncZOWorker(0, 1, params, loss_fn, mk(), base_seed=3)
    contribs = []
    for _ in range(3):
        c = w.produce(None)
        contribs.append(c)
        w.consume(c)
    led = TrajectoryLedger(base_seed=3, grad_dtype="float32")
    recorded, skipped = contributions_to_ledger(led, contribs, n_workers=1,
                                                selection=sel.spec)
    assert (recorded, skipped) == (3, 0)
    assert led.selection == sel.spec
    rec = replay(params, TrajectoryLedger.from_bytes(led.to_bytes()), mk())
    assert tree_max_abs_diff(rec, w.params) < 5e-6
    # ...and replaying it under a full-selection optimizer refuses
    with pytest.raises(SelectionMismatchError):
        replay(params, TrajectoryLedger.from_bytes(led.to_bytes()),
               make_opt("spsa", "xla"))


# --------------------------------------------------------------------------- #
# selected_size / selected_bytes accounting (the bench's perturbed-bytes)
# --------------------------------------------------------------------------- #
def test_selected_size_accounting(problem):
    _, params, _ = problem                    # v: 8 f32, w: 16 f32
    assert select.full().selected_size(params) == 24
    sel = select.leaves(W_ONLY)
    assert sel.selected_size(params) == 16
    assert sel.selected_bytes(params) == 64
    bc = select.block_cyclic(2)
    assert bc.selected_size(params, phase=0) == 8      # leaf 0 = v
    assert bc.selected_size(params, phase=1) == 16     # leaf 1 = w
