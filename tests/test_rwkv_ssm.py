"""Chunked recurrence forms (WKV6 / SSD) vs their exact lax.scan oracles —
the loop-free TPU formulations must be numerically faithful, including
carried state and decode chains."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.rwkv6 as R
import repro.models.ssm as S
from repro.models import all_archs
from repro.models.common import KeyGen


@pytest.fixture(scope="module")
def rwkv_setup():
    cfg = all_archs()["rwkv6-3b"].smoke_cfg
    p = R.rwkv_layer_params(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32)["tm"]
    p = dict(p)
    p["w_lora_b"] = jax.random.normal(jax.random.PRNGKey(1), p["w_lora_b"].shape) * 0.5
    p["w0"] = jax.random.normal(jax.random.PRNGKey(2), p["w0"].shape)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 48, cfg.d_model))
    return cfg, p, x


def test_wkv_chunked_vs_ref(rwkv_setup):
    cfg, p, x = rwkv_setup
    yc, (_, wc) = R.time_mix(cfg, p, x, None)
    yr, (_, wr) = R.time_mix_ref(cfg, p, x, None)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=2e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(wc), np.asarray(wr), atol=2e-5, rtol=1e-4)


def test_wkv_chunked_carried_state(rwkv_setup):
    cfg, p, x = rwkv_setup
    st = R.RWKVLayerState(jax.random.normal(jax.random.PRNGKey(4), (2, cfg.d_model)),
                          jnp.zeros((2, cfg.d_model)),
                          jax.random.normal(jax.random.PRNGKey(5),
                                            (2, cfg.n_heads, cfg.hd, cfg.hd)))
    yc, (_, wc) = R.time_mix(cfg, p, x, st)
    yr, (_, wr) = R.time_mix_ref(cfg, p, x, st)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=2e-5, rtol=1e-4)


def test_wkv_decode_chain_matches_full(rwkv_setup):
    cfg, p, x = rwkv_setup
    y_full, (shift_f, wkv_f) = R.time_mix_ref(cfg, p, x[:, :16], None)
    cur = R.RWKVLayerState(jnp.zeros((2, cfg.d_model)),
                           jnp.zeros((2, cfg.d_model)),
                           jnp.zeros((2, cfg.n_heads, cfg.hd, cfg.hd)))
    ys = []
    for t in range(16):
        y, (sh, wkv) = R.time_mix_decode(cfg, p, x[:, t:t + 1], cur)
        cur = R.RWKVLayerState(sh, cur.shift_cm, wkv)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-5, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(cur.wkv), np.asarray(wkv_f),
                               atol=1e-5, rtol=1e-4)


@pytest.fixture(scope="module")
def ssm_setup():
    cfg = all_archs()["hymba-1.5b"].smoke_cfg
    p = S.ssm_params(cfg, KeyGen(jax.random.PRNGKey(0)), jnp.float32)
    p = dict(p)
    p["a_log"] = jax.random.normal(jax.random.PRNGKey(1), p["a_log"].shape)
    u = jax.random.normal(jax.random.PRNGKey(2), (2, 64, cfg.d_model))
    return cfg, p, u


def test_ssd_chunked_vs_ref(ssm_setup):
    cfg, p, u = ssm_setup
    yc, hc = S.ssm_scan(cfg, p, u, None)
    yr, hr = S.ssm_scan_ref(cfg, p, u, None)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=1e-4, rtol=1e-3)


def test_ssd_decode_chain(ssm_setup):
    cfg, p, u = ssm_setup
    y_full, h_full = S.ssm_scan_ref(cfg, p, u[:, :16], None)
    h = jnp.zeros((2, cfg.ssm_heads, cfg.hd, cfg.ssm_state))
    ys = []
    for t in range(16):
        y, h = S.ssm_decode_step(cfg, p, u[:, t:t + 1], h)
        ys.append(y)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                               np.asarray(y_full), atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_full),
                               atol=1e-4, rtol=1e-3)


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_ssd_chunk_invariance(ssm_setup, chunk):
    cfg, p, u = ssm_setup
    y1, h1 = S.ssm_scan(cfg, p, u, None, chunk=chunk)
    y2, h2 = S.ssm_scan(cfg, p, u, None, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4,
                               rtol=1e-3)


def test_wkv_chunked_nondivisible_seq(rwkv_setup):
    """Identity-token padding: S not a multiple of the chunk still matches."""
    cfg, p, x = rwkv_setup
    x37 = x[:, :37]
    yc, (_, wc) = R.time_mix(cfg, p, x37, None)
    yr, (_, wr) = R.time_mix_ref(cfg, p, x37, None)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=2e-5,
                               rtol=1e-4)
    np.testing.assert_allclose(np.asarray(wc), np.asarray(wr), atol=2e-5,
                               rtol=1e-4)


def test_ssd_chunked_nondivisible_seq(ssm_setup):
    cfg, p, u = ssm_setup
    u41 = u[:, :41]
    yc, hc = S.ssm_scan(cfg, p, u41, None)
    yr, hr = S.ssm_scan_ref(cfg, p, u41, None)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yr), atol=1e-4,
                               rtol=1e-3)
    np.testing.assert_allclose(np.asarray(hc), np.asarray(hr), atol=1e-4,
                               rtol=1e-3)
