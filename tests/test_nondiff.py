"""Non-differentiable objectives (paper §3.3): metric correctness and that
MeZO actually optimizes them (backprop gets zero gradient)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import MeZO, MeZOConfig
from repro.core.nondiff import negative_accuracy, token_f1


def test_negative_accuracy():
    logits = jnp.asarray([[[2.0, 1.0], [0.0, 3.0]]])     # preds: 0, 1
    labels = jnp.asarray([[0, 0]])
    assert float(negative_accuracy(logits, labels)) == pytest.approx(-0.5)
    mask = jnp.asarray([[1.0, 0.0]])
    assert float(negative_accuracy(logits, labels, mask)) == pytest.approx(-1.0)


def _py_f1(pred, gold, pad=0):
    from collections import Counter
    p = [t for t in pred if t != pad]
    g = [t for t in gold if t != pad]
    common = Counter(p) & Counter(g)
    overlap = sum(common.values())
    if overlap == 0:
        return 0.0
    prec, rec = overlap / len(p), overlap / len(g)
    return 2 * prec * rec / (prec + rec)


@pytest.mark.parametrize("pred,gold", [
    ([1, 2, 3, 0], [1, 2, 3, 0]),
    ([1, 2, 0, 0], [3, 4, 0, 0]),
    ([1, 1, 2, 0], [1, 2, 2, 0]),        # multiset counting
    ([5, 0, 0, 0], [5, 6, 7, 8]),
    ([0, 0, 0, 0], [1, 2, 0, 0]),        # empty prediction
])
def test_token_f1_matches_python_reference(pred, gold):
    got = float(token_f1(jnp.asarray([pred]), jnp.asarray([gold])))
    want = _py_f1(pred, gold)
    assert got == pytest.approx(want, abs=1e-6), (pred, gold)


def test_backprop_gets_zero_gradient_mezo_does_not():
    """The defining property: d(accuracy)/dθ = 0 a.e., but the ZO estimate is
    informative."""
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (8,))
    xs = jax.random.normal(jax.random.fold_in(key, 1), (64, 8))
    ys = (xs @ w_true > 0).astype(jnp.int32)

    def objective(p, batch):
        logits = xs @ p["w"]
        pred = (logits > 0).astype(jnp.int32)
        return -jnp.mean((pred == ys).astype(jnp.float32))

    p0 = {"w": jnp.zeros((8,)) + 0.01}
    g_bp = jax.grad(objective)(p0, None)
    assert float(jnp.max(jnp.abs(g_bp["w"]))) == 0.0     # backprop: useless

    opt = MeZO(MeZOConfig(lr=5e-2, eps=1e-1))
    state = opt.init(0)
    step = jax.jit(opt.step_fn(objective))
    p = p0
    for _ in range(400):
        p, state, m = step(p, state, None)
    final_acc = -float(objective(p, None))
    assert final_acc > 0.9, final_acc                    # MeZO: optimizes it
